"""Training loop machinery + accuracy evaluation metrics."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import evaluate, train
from compile.layers import Ctx
from compile.models import FAMILIES, Family


def test_adam_converges_on_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    opt = train._adam_init(params)
    for _ in range(600):
        grads = {"x": 2 * params["x"]}
        params, opt = train._adam_update(params, grads, opt, lr=0.05)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_loss_cls_matches_manual():
    logits = jnp.array([[2.0, 0.0], [0.0, 2.0]])
    y = jnp.array([0, 1])
    want = -np.log(np.exp(2) / (np.exp(2) + 1))
    np.testing.assert_allclose(train._loss_cls(logits, y), want, rtol=1e-6)


def test_loss_seg_shape_handling():
    logits = jnp.zeros((2, 4, 4, 5))
    y = jnp.zeros((2, 4, 4), jnp.int32)
    np.testing.assert_allclose(train._loss_seg(logits, y), np.log(5), rtol=1e-6)


def test_params_save_load_roundtrip():
    fam = FAMILIES["mobilenet_v2_100"]
    params = fam.init(jax.random.PRNGKey(9))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.npz")
        train.save_params(path, params)
        loaded = train.load_params(path, fam)
        assert loaded is not None
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_load_params_missing_returns_none():
    fam = FAMILIES["mobilenet_v2_100"]
    assert train.load_params("/nonexistent/p.npz", fam) is None


def test_load_params_rejects_stale_cache():
    """A cache from a different architecture must be rejected, not loaded."""
    fam_a = FAMILIES["mobilenet_v2_100"]
    fam_b = FAMILIES["mobilenet_v2_140"]
    params = fam_a.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.npz")
        train.save_params(path, params)
        assert train.load_params(path, fam_b) is None


# ---------------------------------------------------------------------------
# evaluation metrics on fabricated models
# ---------------------------------------------------------------------------

def _const_family(task: str, res: int, out_fn) -> Family:
    return Family("fake", "Fake", task, res, lambda rng: {},
                  lambda p, x, ctx: out_fn(x))


def test_top1_perfect_and_constant_predictor():
    import compile.datasets as D

    x = np.zeros((40, 8, 8, 3), np.float32)
    y = np.random.default_rng(0).integers(0, D.NUM_CLASSES, 40).astype(np.int32)
    onehots = np.eye(D.NUM_CLASSES, dtype=np.float32)[y]
    perfect = _const_family("cls", 8, lambda xb: jnp.asarray(onehots[:xb.shape[0]]))
    # top1 batches internally; feeding all 40 in one go keeps indices aligned
    assert evaluate.top1(perfect, {}, x, y) == 1.0
    always0 = _const_family(
        "cls", 8,
        lambda xb: jnp.asarray(np.eye(D.NUM_CLASSES, dtype=np.float32)[
            np.zeros(xb.shape[0], np.int32)]))
    assert evaluate.top1(always0, {}, x, y) == float((y == 0).mean())


def test_miou_perfect_and_degenerate():
    import compile.datasets as D
    _, m = D.make_segmentation(10, 16, seed=0)
    onehot = np.eye(D.NUM_SEG_CLASSES, dtype=np.float32)[m]  # [N,H,W,C]
    fam = _const_family("seg", 16, lambda x: jnp.asarray(onehot[:x.shape[0]]))
    x = np.zeros((10, 16, 16, 3), np.float32)
    assert evaluate.miou(fam, {}, x, m) == 1.0
    # all-background predictor scores < 0.5
    fam0 = _const_family(
        "seg", 16,
        lambda x: jnp.asarray(np.eye(D.NUM_SEG_CLASSES, dtype=np.float32)[
            np.zeros((x.shape[0], 16, 16), np.int32)]))
    assert evaluate.miou(fam0, {}, x, m) < 0.5


def test_train_family_tiny_smoke():
    """One real (but tiny) training run: loss must drop from -log(1/10)."""
    fam0 = FAMILIES["mobilenet_v2_100"]
    fam = dataclasses.replace(fam0, train_steps=30)
    _, loss = train.train_family(fam, verbose=False)
    assert loss < np.log(10)  # better than uniform-random
