"""Model-zoo shape/cost/precision checks and pallas-vs-ref agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import model_costs, output_shape
from compile.datasets import NUM_CLASSES, NUM_SEG_CLASSES
from compile.layers import Ctx
from compile.models import FAMILIES, PRECISIONS
from compile.transform import apply_transform

ALL = list(FAMILIES.values())
SMALL = [FAMILIES["mobilenet_v2_100"], FAMILIES["deeplab_v3"]]


@pytest.mark.parametrize("fam", ALL, ids=lambda f: f.name)
def test_output_shape(fam):
    params = fam.init(jax.random.PRNGKey(0))
    x = jnp.zeros((2, fam.resolution, fam.resolution, 3))
    out = fam.apply(params, x, Ctx(impl="ref"))
    if fam.task == "cls":
        assert out.shape == (2, NUM_CLASSES)
    else:
        assert out.shape == (2, fam.resolution, fam.resolution, NUM_SEG_CLASSES)


@pytest.mark.parametrize("fam", ALL, ids=lambda f: f.name)
def test_init_deterministic(fam):
    p1 = fam.init(jax.random.PRNGKey(0))
    p2 = fam.init(jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("fam", SMALL, ids=lambda f: f.name)
@pytest.mark.parametrize("prec", PRECISIONS)
def test_pallas_matches_ref(fam, prec):
    """The AOT (pallas) path computes the same function as the eval path."""
    params = apply_transform(prec, fam.init(jax.random.PRNGKey(1)))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1, fam.resolution, fam.resolution, 3)).astype(np.float32))
    y_ref = fam.apply(params, x, Ctx(impl="ref"))
    y_pal = fam.apply(params, x, Ctx(impl="pallas"))
    np.testing.assert_allclose(y_ref, y_pal, rtol=2e-3, atol=2e-3)


def test_flops_ordering_mirrors_table2():
    """Relative compute cost ordering must match the paper's Table II."""
    flops = {}
    for fam in ALL:
        params = fam.init(jax.random.PRNGKey(0))
        flops[fam.name], _, _ = model_costs(fam, params)
    assert flops["mobilenet_v2_100"] < flops["mobilenet_v2_140"]
    assert flops["efficientnet_lite0"] < flops["efficientnet_lite4"]
    assert flops["mobilenet_v2_100"] < flops["inception_v3"]
    assert flops["efficientnet_lite4"] < flops["inception_v3"]
    assert flops["inception_v3"] < flops["resnet_v2"]  # ResNetV2 heaviest


def test_param_count_ordering():
    params_of = {}
    for fam in ALL:
        p = fam.init(jax.random.PRNGKey(0))
        _, n, _ = model_costs(fam, p)
        params_of[fam.name] = n
    assert params_of["mobilenet_v2_100"] < params_of["mobilenet_v2_140"]
    assert params_of["resnet_v2"] == max(params_of.values())


@pytest.mark.parametrize("fam", ALL, ids=lambda f: f.name)
def test_transform_size_shrinks(fam):
    """size(int8) < size(fp16) < size(fp32) for every family."""
    p = fam.init(jax.random.PRNGKey(0))
    sizes = {}
    for prec in PRECISIONS:
        _, _, sizes[prec] = model_costs(fam, apply_transform(prec, p))
    assert sizes["int8"] < sizes["fp16"] < sizes["fp32"]


def test_output_shape_helper_agrees():
    fam = FAMILIES["mobilenet_v2_100"]
    p = fam.init(jax.random.PRNGKey(0))
    assert output_shape(fam, p, 4) == [4, NUM_CLASSES]


def test_width_multiplier_rounds_to_8():
    from compile.models.mobilenet_v2 import _scale
    assert _scale(16, 1.0) == 16
    assert _scale(16, 1.4) == 24
    assert _scale(3, 1.0) == 8  # floor at 8
    assert all(_scale(c, 1.4) % 8 == 0 for c in (16, 24, 48, 96))
