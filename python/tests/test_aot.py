"""AOT path: HLO-text emission, manifest metadata, cost model."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.models import FAMILIES
from compile.train import save_params
from compile.transform import apply_transform


def test_to_hlo_text_tiny_fn():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


def test_to_hlo_text_contains_tuple_root():
    """Rust unwraps with to_tuple1 — the root must be a 1-tuple."""
    def fn(x):
        return (x + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "tuple" in text.lower()


def test_lower_variant_mobilenet_int8():
    """Full lowering of the smallest variant must produce parseable HLO with
    the pallas kernels inlined (no custom-calls — CPU-runnable)."""
    fam = FAMILIES["mobilenet_v2_100"]
    params = apply_transform("int8", fam.init(jax.random.PRNGKey(0)))
    text = aot.lower_variant(fam, params, batch=1)
    assert "HloModule" in text
    assert "custom-call" not in text  # interpret=True ⇒ plain HLO only
    assert f"f32[1,{fam.resolution},{fam.resolution},3]" in text
    assert "s8[" in text  # int8 weights baked as s8 constants


def test_model_costs_positive_and_consistent():
    fam = FAMILIES["mobilenet_v2_100"]
    params = fam.init(jax.random.PRNGKey(0))
    flops, n_params, size = aot.model_costs(fam, params)
    assert flops > 0 and n_params > 0
    assert size == pytest.approx(n_params * 4, rel=0.01)  # all-f32 reference


def test_model_costs_int8_size_ratio():
    fam = FAMILIES["mobilenet_v2_100"]
    p32 = fam.init(jax.random.PRNGKey(0))
    _, _, s32 = aot.model_costs(fam, p32)
    _, _, s8 = aot.model_costs(fam, apply_transform("int8", p32))
    assert s8 < s32 / 2.5  # close to 4x smaller, biases/scales stay f32


def test_build_family_manifest_schema(tmp_path, monkeypatch):
    """build_family emits one entry per (precision, batch) with all fields
    the Rust model registry requires."""
    fam = FAMILIES["mobilenet_v2_100"]
    # pre-seed the param cache so build_family doesn't train
    params = fam.init(jax.random.PRNGKey(0))
    cache = tmp_path / "params"
    save_params(str(cache / f"{fam.name}.npz"), params)
    monkeypatch.setattr(aot, "get_trained_params",
                        lambda f: params)
    monkeypatch.setattr(aot.evaluate, "evaluate", lambda f, p: 0.5)
    monkeypatch.setattr(aot, "lower_variant", lambda f, p, b: "HloModule fake")

    entries = aot.build_family(fam, str(tmp_path), skip_existing=False)
    assert len(entries) == 3 * 3  # 3 precisions x batches (1,4,8)
    required = {"name", "family", "paper_name", "task", "precision", "bits",
                "resolution", "batch", "input_shape", "output_shape",
                "params", "size_bytes", "flops", "accuracy",
                "accuracy_metric", "hlo"}
    for e in entries:
        assert required <= set(e)
        assert os.path.exists(tmp_path / e["hlo"])
    # int8 entries must be smaller than fp32 ones
    by_prec = {e["precision"]: e for e in entries if e["batch"] == 1}
    assert by_prec["int8"]["size_bytes"] < by_prec["fp32"]["size_bytes"]
    assert by_prec["fp16"]["bits"] == 16


def test_hlo_text_bakes_large_constants():
    """Regression: the default HLO printer elides big literals as
    `constant({...})`, which the Rust-side parser silently zero-fills —
    weights must be printed in full."""
    import numpy as np

    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 32)).astype(np.float32))

    def fn(x):
        return (x @ w,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 64), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "{...}" not in text
    # the 2048-element weight is present: expect thousands of commas
    assert text.count(",") > 2000
