"""OODIn transformation set T: structure preservation and numeric bounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile.transform import (TRANSFORMS, apply_transform, precision_bits,
                               register)


def _toy_params():
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    return {
        "stem": L.init_conv(k[0], 3, 3, 3, 8),
        "blocks": [L.init_inverted_residual(k[1], 8, 8, expand=4, stride=1)],
        "fc": L.init_dense(k[2], 8, 10),
    }


def test_fp32_is_identity():
    p = _toy_params()
    assert apply_transform("fp32", p) is p


def test_fp16_casts_weights_only():
    p = apply_transform("fp16", _toy_params())
    assert p["stem"]["w"].dtype == jnp.float16
    assert p["stem"]["b"].dtype == jnp.float32  # biases stay f32
    assert p["fc"]["w"].dtype == jnp.float16


def test_int8_replaces_weights_with_quant_pairs():
    p = apply_transform("int8", _toy_params())
    assert "w" not in p["stem"] and "w_q" in p["stem"] and "s" in p["stem"]
    assert p["stem"]["w_q"].dtype == jnp.int8
    # depthwise weights inside the IR block are quantised too (3-D path)
    dw = p["blocks"][0]["dw"]
    assert dw["w_q"].dtype == jnp.int8 and dw["w_q"].ndim == 3
    assert dw["s"].shape == (dw["w_q"].shape[2],)


def test_transform_preserves_structure():
    """None subtrees (no-expand blocks) and Meta nodes survive untouched."""
    p0 = {"blk": L.init_inverted_residual(jax.random.PRNGKey(1), 8, 8,
                                          expand=1, stride=1)}
    assert p0["blk"]["expand"] is None
    p = apply_transform("int8", p0)
    assert p["blk"]["expand"] is None
    assert isinstance(p["blk"]["meta"], L.Meta)
    assert dict(p["blk"]["meta"]) == dict(p0["blk"]["meta"])


def test_int8_dequant_close_to_original():
    p0 = _toy_params()
    p = apply_transform("int8", p0)
    w0 = np.asarray(p0["fc"]["w"])
    wq = np.asarray(p["fc"]["w_q"], np.float32) * np.asarray(p["fc"]["s"])
    assert np.abs(w0 - wq).max() <= np.asarray(p["fc"]["s"]).max() / 2 + 1e-7


def test_precision_bits():
    assert precision_bits("fp32") == 32
    assert precision_bits("fp16") == 16
    assert precision_bits("int8") == 8


def test_register_extends_T():
    def prune_identity(params):
        return params

    register("prune_test", prune_identity)
    try:
        assert apply_transform("prune_test", {"a": 1}) == {"a": 1}
    finally:
        TRANSFORMS.pop("prune_test")


def test_unknown_transform_raises():
    with pytest.raises(KeyError):
        apply_transform("int4", {})
