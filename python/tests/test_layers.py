"""L2 layer library: pallas impl vs ref impl, cost accounting, Meta statics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers as L
from compile.transform import apply_transform

SETTINGS = dict(max_examples=10, deadline=None)


def _x(rng, n, hw, c):
    return jnp.asarray(rng.normal(size=(n, hw, hw, c)).astype(np.float32))


def _both(p, x, fn):
    ref = fn(L.Ctx(impl="ref"), p, x)
    pal = fn(L.Ctx(impl="pallas"), p, x)
    np.testing.assert_allclose(ref, pal, rtol=1e-3, atol=1e-3)
    return ref


@settings(**SETTINGS)
@given(hw=st.integers(4, 12), cin=st.integers(1, 8), cout=st.integers(1, 12),
       stride=st.sampled_from([1, 2]), seed=st.integers(0, 1000))
def test_conv2d_impls_agree(hw, cin, cout, stride, seed):
    rng = np.random.default_rng(seed)
    p = L.init_conv(jax.random.PRNGKey(seed), 3, 3, cin, cout)
    _both(p, _x(rng, 2, hw, cin),
          lambda c, p_, x_: L.conv2d(c, p_, x_, stride=stride))


@settings(**SETTINGS)
@given(hw=st.integers(4, 12), c=st.integers(1, 12),
       stride=st.sampled_from([1, 2]), seed=st.integers(0, 1000))
def test_depthwise_impls_agree(hw, c, stride, seed):
    rng = np.random.default_rng(seed)
    p = L.init_dw(jax.random.PRNGKey(seed), 3, c)
    _both(p, _x(rng, 2, hw, c),
          lambda ctx, p_, x_: L.depthwise(ctx, p_, x_, stride=stride))


@pytest.mark.parametrize("prec", ["fp32", "fp16", "int8"])
def test_conv2d_impls_agree_all_precisions(prec):
    rng = np.random.default_rng(11)
    p = apply_transform(prec, L.init_conv(jax.random.PRNGKey(1), 3, 3, 6, 10))
    tol = 1e-3
    _both(p, _x(rng, 2, 9, 6), lambda c, p_, x_: L.conv2d(c, p_, x_))


@pytest.mark.parametrize("prec", ["fp32", "fp16", "int8"])
def test_inverted_residual_all_precisions(prec):
    rng = np.random.default_rng(4)
    p0 = L.init_inverted_residual(jax.random.PRNGKey(2), 8, 8, expand=4, stride=1)
    p = apply_transform(prec, p0)
    _both(p, _x(rng, 1, 8, 8), L.inverted_residual)


def test_inverted_residual_has_skip_connection():
    """stride=1, cin==cout must add the residual: zero weights -> identity-ish."""
    p = L.init_inverted_residual(jax.random.PRNGKey(0), 8, 8, expand=4, stride=1)
    p = jax.tree.map(jnp.zeros_like, p)
    x = jnp.ones((1, 6, 6, 8))
    y = L.inverted_residual(L.Ctx(impl="ref"), p, x)
    np.testing.assert_allclose(y, x)


def test_inverted_residual_stride2_no_skip():
    p = L.init_inverted_residual(jax.random.PRNGKey(0), 8, 16, expand=4, stride=2)
    x = jnp.ones((1, 8, 8, 8))
    y = L.inverted_residual(L.Ctx(impl="ref"), p, x)
    assert y.shape == (1, 4, 4, 16)


def test_dense_impls_agree():
    rng = np.random.default_rng(5)
    p = L.init_dense(jax.random.PRNGKey(3), 24, 10)
    x = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32))
    _both(p, x, L.dense)


def test_meta_is_static_under_jit():
    """Meta ints must survive jit tracing as python ints (control flow)."""
    p = L.init_conv(jax.random.PRNGKey(0), 3, 3, 4, 8)

    @jax.jit
    def fwd(p_, x):
        return L.conv2d(L.Ctx(impl="ref"), p_, x)

    out = fwd(p, jnp.ones((1, 6, 6, 4)))
    assert out.shape == (1, 6, 6, 8)


def test_meta_roundtrips_as_pytree():
    m = L.Meta(kh=3, kw=3, cin=4, cout=8)
    leaves, treedef = jax.tree.flatten(m)
    assert leaves == []  # static: no traced children
    m2 = jax.tree.unflatten(treedef, [])
    assert dict(m2) == dict(m)


def test_cost_accounting_conv_flops():
    """conv FLOPs = 2*N*Ho*Wo*kh*kw*cin*cout exactly."""
    p = L.init_conv(jax.random.PRNGKey(0), 3, 3, 4, 8)
    costs = []
    L.conv2d(L.Ctx(impl="ref", costs=costs), p, jnp.ones((2, 6, 6, 4)))
    (name, flops, wbytes) = costs[0]
    assert name == "conv3x3"
    assert flops == 2 * 2 * 6 * 6 * 3 * 3 * 4 * 8
    assert wbytes == 3 * 3 * 4 * 8 * 4


def test_cost_accounting_int8_weight_bytes():
    p = apply_transform("int8", L.init_conv(jax.random.PRNGKey(0), 1, 1, 16, 32))
    costs = []
    L.conv2d(L.Ctx(impl="ref", costs=costs), p, jnp.ones((1, 4, 4, 16)), pad=0)
    _, _, wbytes = costs[0]
    assert wbytes == 16 * 32 * 1 + 32 * 4  # int8 weights + f32 scales


def test_global_avg_pool_and_relu6():
    x = jnp.full((2, 3, 3, 5), 9.0)
    assert L.relu6(x).max() == 6.0
    assert L.relu6(-x).min() == 0.0
    np.testing.assert_allclose(L.global_avg_pool(x), np.full((2, 5), 9.0))


def test_avg_pool_3x3_same_shape_and_constant():
    x = jnp.full((1, 5, 5, 2), 4.0)
    y = L.avg_pool_3x3(x)
    assert y.shape == x.shape
    np.testing.assert_allclose(y, 4.0, rtol=1e-6)  # count-corrected at edges


def test_resize_bilinear_shape():
    y = L.resize_bilinear(jnp.ones((2, 6, 6, 5)), 12, 12)
    assert y.shape == (2, 12, 12, 5)
    np.testing.assert_allclose(y, 1.0, rtol=1e-6)
