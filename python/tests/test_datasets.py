"""Synthetic dataset generators: determinism, shapes, label semantics."""

import numpy as np

from compile import datasets as D


def test_classification_shapes_and_ranges():
    x, y = D.make_classification(50, 24, seed=0)
    assert x.shape == (50, 24, 24, 3) and x.dtype == np.float32
    assert y.shape == (50,) and y.dtype == np.int32
    assert y.min() >= 0 and y.max() < D.NUM_CLASSES


def test_classification_deterministic():
    x1, y1 = D.make_classification(20, 24, seed=5)
    x2, y2 = D.make_classification(20, 24, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_classification_seed_changes_data():
    x1, _ = D.make_classification(20, 24, seed=1)
    x2, _ = D.make_classification(20, 24, seed=2)
    assert not np.array_equal(x1, x2)


def test_classification_signal_at_class_position():
    """The class blob must be brighter at its ring position than opposite."""
    x, y = D.make_classification(200, 24, seed=3, noise=0.0)
    hits = 0
    for i in range(200):
        k = int(y[i])
        ang = 2 * np.pi * k / D.NUM_CLASSES
        cy = int(round(12 + 24 * 0.3 * np.sin(ang)))
        cx = int(round(12 + 24 * 0.3 * np.cos(ang)))
        oy, ox = 24 - 1 - cy, 24 - 1 - cx
        if x[i, cy, cx].sum() > x[i, oy, ox].sum():
            hits += 1
    assert hits > 150  # distractors may occasionally mask the signal


def test_segmentation_shapes_and_classes():
    x, m = D.make_segmentation(30, 48, seed=0)
    assert x.shape == (30, 48, 48, 3)
    assert m.shape == (30, 48, 48)
    assert m.min() >= 0 and m.max() < D.NUM_SEG_CLASSES
    # every image has at least one non-background region
    assert all((m[i] > 0).any() for i in range(30))


def test_segmentation_foreground_is_brighter():
    x, m = D.make_segmentation(20, 48, seed=1, noise=0.0)
    fg = x[m > 0].mean()
    bg = x[m == 0].mean()
    assert fg > bg + 0.5


def test_splits_disjoint_seeds():
    xtr, _, xte, _ = D.splits("cls", 24, n_train=30, n_test=30)
    assert xtr.shape[0] == 30 and xte.shape[0] == 30
    assert not np.array_equal(xtr[:10], xte[:10])


def test_splits_segmentation_task():
    xtr, ytr, xte, yte = D.splits("seg", 48, n_train=10, n_test=5)
    assert ytr.shape == (10, 48, 48) and yte.shape == (5, 48, 48)
