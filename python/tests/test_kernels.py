"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiple and degenerate sizes)
and dtypes; assert_allclose against ref.py is the core correctness signal
licensing the ref path for training/eval and the pallas path for AOT.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as kc
from compile.kernels import matmul as kmm
from compile.kernels import quantized as kq
from compile.kernels import ref as kref

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(m=st.integers(1, 70), k=st.integers(1, 90), n=st.integers(1, 140),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(kmm.matmul(x, w), kref.matmul_ref(x, w),
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(m=st.integers(1, 40), k=st.integers(1, 64), n=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_fp16_weights(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    w = _rand(rng, k, n).astype(jnp.float16)
    np.testing.assert_allclose(kmm.matmul(x, w), kref.matmul_ref(x, w),
                               rtol=1e-3, atol=1e-3)


def test_matmul_explicit_blocks():
    rng = np.random.default_rng(0)
    x, w = _rand(rng, 33, 47), _rand(rng, 47, 65)
    out = kmm.matmul(x, w, block_m=8, block_k=16, block_n=8)
    np.testing.assert_allclose(out, kref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_single_element():
    x, w = jnp.ones((1, 1)), jnp.full((1, 1), 3.0)
    np.testing.assert_allclose(kmm.matmul(x, w), [[3.0]])


def test_matmul_rejects_mismatched_inner():
    with pytest.raises(AssertionError):
        kmm.matmul(jnp.ones((2, 3)), jnp.ones((4, 2)))


def test_pick_blocks_bounds():
    for m, k, n in [(1, 1, 1), (7, 13, 200), (4096, 4096, 4096)]:
        bm, bk, bn = kmm.pick_blocks(m, k, n)
        assert bm <= 512 and bk <= 576 and bn <= 256
        assert bm % 8 == 0 or bm >= m
        # VMEM budget: a real TPU core has ~16 MB; our largest tile set
        # must fit with double-buffering headroom.
        assert kmm.vmem_bytes(bm, bk, bn) < 8 * 2**20


# ---------------------------------------------------------------------------
# quantized matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(m=st.integers(1, 50), k=st.integers(1, 70), n=st.integers(1, 130),
       seed=st.integers(0, 2**31 - 1))
def test_qmatmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    w_q, s = kq.quantize_weights(w)
    np.testing.assert_allclose(kq.qmatmul(x, w_q, s), kref.qmatmul_ref(x, w_q, s),
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(k=st.integers(1, 50), n=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_quantize_weights_error_bound(k, n, seed):
    """|w - w_q*s| <= scale/2 elementwise (symmetric rounding quantiser)."""
    rng = np.random.default_rng(seed)
    w = _rand(rng, k, n)
    w_q, s = kq.quantize_weights(w)
    err = np.abs(np.asarray(w) - np.asarray(w_q, np.float32) * np.asarray(s))
    assert (err <= np.asarray(s) / 2 + 1e-7).all()


def test_quantize_weights_per_channel_tighter():
    rng = np.random.default_rng(3)
    w = _rand(rng, 32, 16) * jnp.linspace(0.01, 10.0, 16)  # scale-skewed cols
    _, s_pt = kq.quantize_weights(w)
    wq_pc, s_pc = kq.quantize_weights_per_channel(w)
    err_pc = np.abs(np.asarray(w) - np.asarray(wq_pc, np.float32) * np.asarray(s_pc))
    # per-channel error bound honours each column's own scale
    assert (err_pc <= np.asarray(s_pc)[None, :] / 2 + 1e-7).all()
    assert np.asarray(s_pc).max() <= np.asarray(s_pt)[0] + 1e-7


def test_quantize_zero_weight():
    w_q, s = kq.quantize_weights(jnp.zeros((4, 4)))
    assert (np.asarray(w_q) == 0).all() and (np.asarray(s) == 1.0).all()


# ---------------------------------------------------------------------------
# depthwise
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(1, 3), hw=st.integers(3, 17), c=st.integers(1, 24),
       stride=st.sampled_from([1, 2]), seed=st.integers(0, 2**31 - 1))
def test_depthwise_matches_ref(n, hw, c, stride, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, n, hw, hw, c), _rand(rng, 3, 3, c)
    np.testing.assert_allclose(
        kc.depthwise(x, w, stride=stride),
        kref.depthwise_ref(x, w, stride=stride), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(hw=st.integers(3, 14), c=st.integers(1, 16),
       stride=st.sampled_from([1, 2]), seed=st.integers(0, 2**31 - 1))
def test_qdepthwise_matches_ref(hw, c, stride, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, 2, hw, hw, c), _rand(rng, 3, 3, c)
    w_q, s = kc.quantize_dw_weights(w)
    np.testing.assert_allclose(
        kc.qdepthwise(x, w_q, s, stride=stride),
        kref.qdepthwise_ref(x, w_q, s, stride=stride), rtol=1e-4, atol=1e-4)


def test_depthwise_5x5_kernel():
    rng = np.random.default_rng(1)
    x, w = _rand(rng, 1, 11, 11, 4), _rand(rng, 5, 5, 4)
    np.testing.assert_allclose(kc.depthwise(x, w), kref.depthwise_ref(x, w),
                               rtol=1e-4, atol=1e-4)


def test_depthwise_fp16_weights():
    rng = np.random.default_rng(2)
    x = _rand(rng, 1, 8, 8, 6)
    w = _rand(rng, 3, 3, 6).astype(jnp.float16)
    np.testing.assert_allclose(kc.depthwise(x, w), kref.depthwise_ref(x, w),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# im2col convolution path
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(hw=st.integers(5, 15), cin=st.integers(1, 8), cout=st.integers(1, 12),
       stride=st.sampled_from([1, 2]), dilation=st.sampled_from([1, 2]),
       seed=st.integers(0, 2**31 - 1))
def test_im2col_conv_matches_lax(hw, cin, cout, stride, dilation, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, 2, hw, hw, cin)
    w = _rand(rng, 3 * 3 * cin, cout)
    pad = kc.same_pad(3, dilation)
    ho = kc.out_size(hw, 3, stride, dilation, pad)
    cols = kc.im2col(x, 3, 3, stride, dilation, pad).reshape(-1, 3 * 3 * cin)
    got = kmm.matmul(cols, w).reshape(2, ho, ho, cout)
    want = kref.conv2d_ref(x, w, kh=3, kw=3, stride=stride,
                           dilation=dilation, pad=pad)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_out_size_formula():
    assert kc.out_size(24, 3, 2, 1, 1) == 12
    assert kc.out_size(24, 3, 1, 1, 1) == 24
    assert kc.out_size(12, 3, 1, 2, 2) == 12  # dilated SAME
    assert kc.out_size(5, 1, 1, 1, 0) == 5


def test_same_pad():
    assert kc.same_pad(3) == 1
    assert kc.same_pad(5) == 2
    assert kc.same_pad(3, dilation=2) == 2
    assert kc.same_pad(1) == 0
