#!/usr/bin/env python3
"""Independent oracle for the design-space layer's golden snapshots.

Re-implements, in plain Python, every deterministic component behind

  * ``rust/tests/golden/pareto_frontier.txt`` — the Pareto frontier of the
    canonical 4-app mix on all three Table I device profiles, and
  * ``rust/tests/golden/optbench_smoke.json`` — the ``oodin opt-bench
    --smoke`` payload,

namely: the zero-noise Measurer LUT (latencies are exactly the roofline
model's closed-form predictions), the design-space enumeration with its
constraint pre-filters, the canonical selection order, slice-local Pareto
dominance, conditions buckets, the frontier cache accounting, and the JSON
emission of `util::json::to_string`.

Why this exists: the golden snapshots must be producible *without* running
the Rust binary (the authoring container has no Rust toolchain), and they
double as an N-version check — Rust and Python implementations of the same
spec must agree byte-for-byte.

Exactness argument: with measurement noise at 0 every quantity is IEEE-754
double arithmetic (+, *, /, max, min) mirrored here in the same operation
order; 2^load appears only at bucket centres (exact powers of two) and
log2 is only taken of exact powers of two.  The oracle also re-runs the
full enumerative search at every event and asserts it picks the same
design as the frontier walk — an independent check of the exactness
theorem the Rust property tests pin.

Usage:  python3 python/golden_optbench.py [--check]
  default: writes both golden files
  --check: compares against the existing files, exit 1 on drift
"""

import math
import os
import sys

# --------------------------------------------------------------------------
# Device profiles (device/profiles.rs) — resource + calibration constants.
# --------------------------------------------------------------------------

GOV_ORDER = ["performance", "schedutil", "energy_step"]
FREQ_SCALE = {"performance": 1.0, "schedutil": 0.94, "energy_step": 0.78}
HEAT_FACTOR = {"performance": 1.0, "schedutil": 0.85, "energy_step": 0.58}
ENGINE_ORDER = ["cpu", "gpu", "nnapi"]


def engine(kind, peak, fp16, int8, bw, dispatch, parallel, heat):
    return dict(kind=kind, peak=peak, fp16=fp16, int8=int8, bw=bw,
                dispatch=dispatch, parallel=parallel, heat=heat)


DEVICES = {
    "sony_c5": dict(
        engines=[
            engine("cpu", 6.0, 0.85, 1.8, 2.5, 0.004, 0.80, 1.05),
            engine("gpu", 9.0, 1.7, 0.9, 3.5, 0.080, 0.0, 0.90),
        ],
        n_cores=8,
        mem_budget=4 * 1024 * 1024,
        governors=["performance", "schedutil"],
        max_deployable=8.0,
    ),
    "samsung_a71": dict(
        engines=[
            engine("cpu", 14.0, 0.95, 2.2, 8.0, 0.002, 0.85, 0.08),
            engine("gpu", 22.0, 1.9, 1.3, 11.0, 0.012, 0.0, 0.25),
            engine("nnapi", 16.0, 1.4, 4.0625, 9.0, 0.018, 0.0, 0.30),
        ],
        n_cores=8,
        mem_budget=12 * 1024 * 1024,
        governors=["energy_step", "performance", "schedutil"],
        max_deployable=25.0,
    ),
    "samsung_s20_fe": dict(
        engines=[
            engine("cpu", 30.0, 1.0, 2.5, 16.0, 0.0015, 0.85, 0.48),
            engine("gpu", 60.0, 1.9, 1.4, 22.0, 0.018, 0.0, 0.42),
            engine("nnapi", 20.0, 1.6, 7.5, 14.0, 0.030, 0.0, 0.66),
        ],
        n_cores=8,
        mem_budget=12 * 1024 * 1024,
        governors=["energy_step", "performance", "schedutil"],
        max_deployable=25.0,
    ),
}

NPU_PENALTY = {
    ("samsung_a71", "efficientnet_lite4"): 3.0,
    ("samsung_a71", "deeplab_v3"): 12.0,
    ("samsung_a71", "resnet_v2"): 1.8,
    ("samsung_s20_fe", "efficientnet_lite4"): 1.5,
    ("samsung_s20_fe", "deeplab_v3"): 110.0,
    ("samsung_s20_fe", "inception_v3"): 4.0,
    ("samsung_s20_fe", "resnet_v2"): 3.0,
}

# --------------------------------------------------------------------------
# Model fixture (model::test_fixtures::fake_registry).
# --------------------------------------------------------------------------

FAMS = [
    ("mobilenet_v2_100", "cls", 24, 4_000_000),
    ("efficientnet_lite4", "cls", 32, 40_000_000),
    ("inception_v3", "cls", 32, 90_000_000),
    ("deeplab_v3", "seg", 48, 50_000_000),
]
PRECS = [("fp32", 32, 0.90), ("fp16", 16, 0.899), ("int8", 8, 0.885)]


def variants():
    out = {}
    for fam, task, res, flops in FAMS:
        for prec, bits, acc in PRECS:
            name = f"{fam}__{prec}__b1"
            in_elems = res * res * 3
            out_elems = 10 if task == "cls" else res * res * 5
            size = 400_000 * bits // 32
            io = max(in_elems, out_elems) * 4
            out[name] = dict(
                name=name, family=fam, prec=prec, res=res, flops=flops,
                size=size, acc=acc, in_elems=in_elems, out_elems=out_elems,
                mem=size + in_elems * 4 + io * 2,
            )
    return out


VARIANTS = variants()
A_REF = {fam: 0.90 for fam, _, _, _ in FAMS}

# --------------------------------------------------------------------------
# Roofline latency (perf::latency_ms) and the zero-noise Measurer LUT.
# --------------------------------------------------------------------------


def thread_speedup(parallel, threads):
    if threads <= 1:
        return 1.0
    return 1.0 / ((1.0 - parallel) + parallel / float(threads))


def base_latency_ms(dev_name, spec, v, threads, governor):
    dev = DEVICES[dev_name]
    threads = max(min(threads, dev["n_cores"]), 1)
    if spec["kind"] == "cpu":
        allc = thread_speedup(spec["parallel"], dev["n_cores"])
        base = spec["peak"] / allc * thread_speedup(spec["parallel"], threads)
    else:
        base = spec["peak"]
    penalty = (NPU_PENALTY.get((dev_name, v["family"]), 1.0)
               if spec["kind"] == "nnapi" else 1.0)
    pm = {"fp32": 1.0, "fp16": spec["fp16"], "int8": spec["int8"]}[v["prec"]]
    gflops = base * pm * FREQ_SCALE[governor] * 1.0 / penalty
    compute = (float(v["flops"]) * 1.0) / (gflops * 1e6)
    act = (v["in_elems"] + v["out_elems"]) * 4
    memory = (float(v["size"]) + float(act)) / (spec["bw"] * 1e6)
    roof = max(compute, memory)
    return (spec["dispatch"] + roof) * 1.0  # contention(0) == 1.0


def percentile_sorted(s, p):
    if len(s) == 1:
        return s[0]
    rank = p / 100.0 * float(len(s) - 1)
    lo = int(math.floor(rank))
    hi = int(math.ceil(rank))
    frac = rank - float(lo)
    return s[lo] * (1.0 - frac) + s[hi] * frac


def stats_from_identical(base, runs):
    s = [base] * runs
    total = 0.0
    for x in s:
        total += x
    return {
        "avg": total / float(runs),
        "p90": percentile_sorted(s, 90.0),
    }


def thread_candidates(n_cores):
    t = [1]
    v = 2
    while v < n_cores:
        t.append(v)
        v *= 2
    if n_cores > 1:
        t.append(n_cores)
    return t


def build_lut(dev_name, runs=8):
    """(variant, engine, threads, governor) -> {avg, p90} — zero noise."""
    dev = DEVICES[dev_name]
    lut = {}
    for v in VARIANTS.values():
        for spec in dev["engines"]:
            threads = (thread_candidates(dev["n_cores"])
                       if spec["kind"] == "cpu" else [1])
            for t in threads:
                for g in dev["governors"]:
                    base = base_latency_ms(dev_name, spec, v, t, g)
                    lut[(v["name"], spec["kind"], t, g)] = \
                        stats_from_identical(base, runs)
    return lut


# --------------------------------------------------------------------------
# designspace: enumeration, canonical rank, dominance, buckets.
# --------------------------------------------------------------------------

RATES = [1.0, 0.5, 0.25]
CAMERA_FPS = 30.0
BUCKET_LOG2_STEP = 0.5


def rust_round(x):
    f = math.floor(x)
    return int(f) if x - f < 0.5 else int(f) + 1


def bucket_of(conds):
    """conds: {engine: load} ∪ {('thermal', engine): scale} -> bucket id."""
    steps = {}
    for e in ENGINE_ORDER:
        load = conds.get(e, 0.0)
        thermal = conds.get(("thermal", e), 1.0)
        mult = (2.0 ** max(load, 0.0)) / max(thermal, 1e-3)
        step = rust_round(math.log2(mult) / BUCKET_LOG2_STEP)
        if step != 0:
            steps[e] = step
    return steps


def bucket_id(steps):
    if not steps:
        return "idle"
    return ",".join(f"{e}{steps[e]:+d}" for e in ENGINE_ORDER if e in steps)


def bucket_representative(steps):
    return {e: s * BUCKET_LOG2_STEP for e, s in steps.items()}


def spec_of(dev_name, kind):
    for s in DEVICES[dev_name]["engines"]:
        if s["kind"] == kind:
            return s
    return None


def energy_proxy(spec, avg_ms, governor):
    f = FREQ_SCALE[governor]
    return avg_ms * spec["heat"] * f * f * HEAT_FACTOR[governor]


def lut_key_sorted(lut):
    """LUT keys in Rust BTreeMap order (variant, engine, threads, gov)."""
    return sorted(lut.keys(),
                  key=lambda k: (k[0], ENGINE_ORDER.index(k[1]),
                                 k[2], GOV_ORDER.index(k[3])))


def key_admitted(dev_name, lut, family, objective, key):
    """Mirror of DesignSpace::entry_admitted (condition-independent)."""
    variant, kind, threads, governor = key
    v = VARIANTS[variant]
    if v["family"] != family:
        return False
    if spec_of(dev_name, kind) is None:
        return False
    entry = lut.get(key)
    if entry is None:
        return False
    dev = DEVICES[dev_name]
    if not v["mem"] <= dev["mem_budget"]:
        return False
    if entry["avg"] > dev["max_deployable"]:
        return False
    eps = objective.get("eps")
    if eps is not None and A_REF[family] - v["acc"] > eps + 1e-12:
        return False
    return True


def eval_key(dev_name, lut, family, objective, rep_loads, key, r):
    """Mirror of DesignSpace::eval_candidate for one (key, rate)."""
    if not key_admitted(dev_name, lut, family, objective, key):
        return None
    variant, kind, threads, governor = key
    v = VARIANTS[variant]
    spec = spec_of(dev_name, kind)
    entry = lut[key]
    stat = objective["stat"]
    energy = energy_proxy(spec, entry["avg"], governor)
    mult = 2.0 ** max(rep_loads.get(kind, 0.0), 0.0)
    lat = entry[stat] * mult / 1.0
    avg = entry["avg"] * mult / 1.0
    fps = min(CAMERA_FPS * r, 1000.0 / avg)
    return dict(
        variant=variant, engine=kind, threads=threads,
        governor=governor, r=r, latency=lat, avg=avg, fps=fps,
        mem=v["mem"], acc=v["acc"], energy=energy,
    )


def enumerate_space(dev_name, lut, family, objective, rep_loads,
                    pred=None):
    """Mirror of DesignSpace::enumerate_where at representative
    conditions (``pred=None`` is exactly ``enumerate``)."""
    out = []
    for key in lut_key_sorted(lut):
        if pred is not None and not pred(key):
            continue
        for r in RATES:
            c = eval_key(dev_name, lut, family, objective, rep_loads,
                         key, r)
            if c is not None:
                out.append(c)
    return out


def score_of(objective, c):
    if objective["kind"] == "min_latency":
        return -c["latency"]
    if objective["kind"] == "max_fps":
        return c["fps"] - 1e-6 * c["avg"]
    raise AssertionError(objective)


def rank_key(c):
    return (-c["score"], c["energy"], c["latency"], -c["acc"], c["avg"],
            -c["r"], c["mem"], c["variant"],
            ENGINE_ORDER.index(c["engine"]), c["threads"],
            GOV_ORDER.index(c["governor"]))


def rank(cands, objective):
    scored = []
    for c in cands:
        s = score_of(objective, c)
        if s is None:
            continue
        c = dict(c)
        c["score"] = s
        scored.append(c)
    return sorted(scored, key=rank_key)


def dominates(p, q):
    if (p["engine"] != q["engine"] or p["r"] != q["r"]
            or p["threads"] != q["threads"]):
        return False
    quality_no_worse = (p["acc"] > q["acc"]
                        or (p["acc"] == q["acc"] and p["mem"] <= q["mem"]))
    no_worse = (p["latency"] <= q["latency"] and p["avg"] <= q["avg"]
                and p["energy"] <= q["energy"] and quality_no_worse)
    strict = (p["latency"] < q["latency"] or p["avg"] < q["avg"]
              or p["energy"] < q["energy"] or p["acc"] > q["acc"]
              or (p["acc"] == q["acc"] and p["mem"] < q["mem"]))
    return no_worse and strict


def build_frontier(dev_name, lut, family, objective, steps):
    rep = bucket_representative(steps)
    cands = enumerate_space(dev_name, lut, family, objective, rep)
    survivors = [q for q in cands
                 if not any(dominates(p, q) for p in cands)]
    return rank(survivors, objective), len(cands), cands


# --------------------------------------------------------------------------
# Incremental frontier maintenance (ParetoFrontier::apply_delta) and the
# frontier cache's byte accounting.
# --------------------------------------------------------------------------

FRONTIER_BASE_BYTES = 256
FRONTIER_POINT_BYTES = 192
APP_CACHE_BUDGET_BYTES = 256 * 1024


def prune_slice_local(cands):
    return [q for q in cands if not any(dominates(p, q) for p in cands)]


def lut_scaled_engine(lut, engine, factor):
    """Mirror of Lut::scaled_engine on the observed (avg, p90) stats."""
    out = {}
    for k, e in lut.items():
        if k[1] == engine:
            out[k] = {"avg": e["avg"] * factor, "p90": e["p90"] * factor}
        else:
            out[k] = e
    return out


def apply_delta_to_frontier(dev_name, old_lut, new_lut, family, obj,
                            steps, points, changed, removed, scales):
    """Mirror of ParetoFrontier::apply_delta — returns (points', touched).

    ``changed``/``removed`` are LUT keys, ``scales`` is {engine: factor};
    together they must cover every old→new difference (LutDelta).
    """
    rep = bucket_representative(steps)
    touched = 0
    # Entry-level changes perturb only their own (engine, threads) slices.
    slices = set()
    for (variant, kind, threads, gov) in list(changed) + list(removed):
        if VARIANTS[variant]["family"] == family:
            slices.add((kind, threads))
    kept = [p for p in points
            if (p["engine"], p["threads"]) not in slices]
    incoming = []
    if slices:
        cands = enumerate_space(
            dev_name, new_lut, family, obj, rep,
            pred=lambda k: (k[1], k[2]) in slices)
        touched += len(cands)
        incoming.extend(prune_slice_local(cands))
    # Per-engine scale: surviving points re-scored in place (within-slice
    # dominance membership is invariant under a uniform latency scale).
    for engine in sorted(scales.keys(), key=ENGINE_ORDER.index):
        factor = scales[engine]
        nxt = []
        for c in kept:
            if c["engine"] != engine:
                nxt.append(c)
                continue
            touched += 1
            key = (c["variant"], c["engine"], c["threads"], c["governor"])
            re = eval_key(dev_name, new_lut, family, obj, rep, key, c["r"])
            if re is not None:
                nxt.append(re)
        kept = nxt
        if factor < 1.0:
            # A speedup may pull previously-undeployable keys under the
            # sustained-latency bound (detected on the OLD LUT, exactly).
            news = [
                k for k in lut_key_sorted(new_lut)
                if k[1] == engine and (k[1], k[2]) not in slices
                and (k not in old_lut
                     or old_lut[k]["avg"]
                     > DEVICES[dev_name]["max_deployable"])
                and key_admitted(dev_name, new_lut, family, obj, k)
            ]
            if news:
                cands = enumerate_space(dev_name, new_lut, family, obj,
                                        rep, pred=lambda k: k in news)
                touched += len(cands)
                fresh = prune_slice_local(cands)
                fresh = [q for q in fresh
                         if not any(dominates(p, q)
                                    for p in kept + incoming)]
                kept = [q for q in kept
                        if not any(dominates(p, q) for p in fresh)]
                incoming = [q for q in incoming
                            if not any(dominates(p, q) for p in fresh)]
                incoming.extend(fresh)
    return rank(kept + incoming, obj), touched


# --------------------------------------------------------------------------
# The canonical mix + event sequence (experiments/optbench.rs).
# --------------------------------------------------------------------------

MIX = [
    ("ai_camera", "mobilenet_v2_100",
     dict(kind="min_latency", stat="avg", eps=0.05,
          label="min_latency(avg,eps=0.05)")),
    ("video_conference", "efficientnet_lite4",
     dict(kind="max_fps", stat="avg", eps=0.05, label="max_fps(eps=0.05)")),
    ("gallery_tagger", "inception_v3",
     dict(kind="min_latency", stat="avg", eps=0.05,
          label="min_latency(avg,eps=0.05)")),
    ("scene_segmenter", "deeplab_v3",
     dict(kind="min_latency", stat="p90", eps=0.05,
          label="min_latency(p90,eps=0.05)")),
]

EVENTS = [
    ("idle", {}),
    ("gpu_load", {"gpu": 1.0}),
    ("gpu_load_repeat", {"gpu": 1.0}),
    ("cpu_load", {"cpu": 2.0}),
    ("npu_throttle", {("thermal", "nnapi"): 0.5}),
    ("idle_return", {}),
    ("mixed", {"gpu": 1.0, ("thermal", "nnapi"): 0.5}),
    ("cpu_load_repeat", {"cpu": 2.0}),
]

SIM_NS_PER_EVAL = 150


def fmt_f64(x):
    """Rust `{}` Display for the f64 values we print (r, eps)."""
    if x == int(x):
        return str(int(x))
    return repr(x)


def jnum(n):
    f = float(n)
    if f == int(f) and abs(f) < 9e15:
        return str(int(f))
    return repr(f)


def jobj(fields):
    return "{" + ",".join(f'"{k}":{v}' for k, v in fields) + "}"


def r3(x):
    return rust_round(x * 1000.0) / 1000.0


def design_id(c):
    return (f"{c['variant']}|{c['engine']}|{c['threads']}|{c['governor']}"
            f"|r={fmt_f64(c['r'])}")


# --------------------------------------------------------------------------
# Golden 1: pareto_frontier.txt
# --------------------------------------------------------------------------


def render_frontier_snapshot():
    out = []
    for dev_name in ["sony_c5", "samsung_a71", "samsung_s20_fe"]:
        lut = build_lut(dev_name)
        for app, family, obj in MIX:
            points, space_size, _ = build_frontier(
                dev_name, lut, family, obj, {})
            out.append(f"== {dev_name} / {app} ({family}, {obj['label']}) "
                       f"space={space_size} frontier={len(points)}")
            for p in points:
                out.append(
                    f"{p['variant']}|{p['engine']}|{p['threads']}"
                    f"|{p['governor']}|r={fmt_f64(p['r'])}"
                    f" T={p['latency']:.4f}ms acc={p['acc']:.4f}"
                    f" E={p['energy']:.5f} mem={p['mem']}")
    return "\n".join(out) + "\n"


# --------------------------------------------------------------------------
# Golden 2: optbench_smoke.json
# --------------------------------------------------------------------------


def run_optbench_smoke():
    dev_name = "samsung_a71"
    lut = build_lut(dev_name)
    rows = []
    for app, family, obj in MIX:
        cache = {}
        cache_steps = {}
        builds = hits = build_evals = 0
        full_total = frontier_total = 0
        space_size = frontier_size_idle = 0
        events = []
        for name, conds in EVENTS:
            steps = bucket_of(conds)
            bid = bucket_id(steps)
            cache_steps[bid] = steps
            rep = bucket_representative(steps)
            full = rank(enumerate_space(dev_name, lut, family, obj, rep),
                        obj)
            full_evals = len(full)
            if bid in cache:
                hits += 1
                built = False
                points = cache[bid]
            else:
                points, sz, _ = build_frontier(dev_name, lut, family, obj,
                                               steps)
                assert sz == full_evals
                cache[bid] = points
                builds += 1
                build_evals += sz
                built = True
            frontier_evals = len(points)
            assert frontier_evals < full_evals, (app, name)
            pick = points[0]
            assert design_id(pick) == design_id(full[0]), \
                f"{app}@{name}: frontier {design_id(pick)} != " \
                f"full {design_id(full[0])}"
            space_size = full_evals
            if not steps:
                frontier_size_idle = frontier_evals
            full_total += full_evals
            frontier_total += frontier_evals
            events.append(jobj([
                ("name", f'"{name}"'),
                ("bucket", f'"{bid}"'),
                ("full_evals", jnum(full_evals)),
                ("frontier_evals", jnum(frontier_evals)),
                ("built", "true" if built else "false"),
                ("match", "true"),
                ("pick", f'"{design_id(pick)}"'),
                ("latency_ms", jnum(r3(pick["latency"]))),
            ]))
        # -- online LUT corrections through the incremental delta path,
        #    mirroring optbench::run_app's correction phase exactly -------
        fp32 = f"{family}__fp32__b1"
        int8 = f"{family}__int8__b1"
        lut1 = lut_scaled_engine(lut, "gpu", 1.25)
        lut2 = dict(lut1)
        changed2 = [k for k in lut1 if k[0] == fp32 and k[1] == "cpu"]
        for k in changed2:
            e = lut2[k]
            lut2[k] = {"avg": e["avg"] * 1.05, "p90": e["p90"] * 1.05}
        removed3 = [k for k in lut2 if k[0] == int8 and k[1] == "gpu"]
        lut3 = {k: v for k, v in lut2.items() if k not in removed3}
        sequence = [
            ("gpu_scale_1.25", lut, lut1, [], [], {"gpu": 1.25}),
            ("remeasure_fp32_cpu", lut1, lut2, changed2, [], {}),
            ("retire_int8_gpu", lut2, lut3, [], removed3, {}),
        ]
        corr_objs = []
        touched_total = rebuild_total = 0
        for cname, old_l, new_l, chg, rem, scales in sequence:
            sz_new = len(enumerate_space(dev_name, new_l, family, obj, {}))
            touched = 0
            for bid in cache:
                cache[bid], t = apply_delta_to_frontier(
                    dev_name, old_l, new_l, family, obj,
                    cache_steps[bid], cache[bid], chg, rem, scales)
                touched += t
            updated = len(cache)
            rebuild = updated * sz_new
            assert touched < rebuild, (app, cname, touched, rebuild)
            touched_total += touched
            rebuild_total += rebuild
            corr_objs.append(jobj([
                ("name", f'"{cname}"'),
                ("updated", jnum(updated)),
                ("points_touched", jnum(touched)),
                ("rebuild_points", jnum(rebuild)),
            ]))
        # Post-correction differential check (mirrors the binary's): the
        # carried frontiers select exactly like a full search over the
        # corrected LUT, with zero extra builds.
        for name, conds in EVENTS:
            steps = bucket_of(conds)
            bid = bucket_id(steps)
            rep = bucket_representative(steps)
            full = rank(enumerate_space(dev_name, lut3, family, obj, rep),
                        obj)
            assert design_id(cache[bid][0]) == design_id(full[0]), \
                f"{app}@{name}: post-correction pick drift"
        resident = sum(FRONTIER_BASE_BYTES
                       + FRONTIER_POINT_BYTES * len(pts)
                       for pts in cache.values())
        assert resident <= APP_CACHE_BUDGET_BYTES, (app, resident)
        n_events = float(len(EVENTS))
        dps = lambda evals: jnum(r3(  # noqa: E731
            n_events * 1e9 / (float(SIM_NS_PER_EVAL) * float(evals))))
        cost = lambda n: jnum(r3(n * float(SIM_NS_PER_EVAL) / 1000.0))  # noqa: E731
        rows.append(jobj([
            ("device", f'"{dev_name}"'),
            ("app", f'"{app}"'),
            ("family", f'"{family}"'),
            ("objective", f'"{obj["label"]}"'),
            ("space_size", jnum(space_size)),
            ("frontier_size_idle", jnum(frontier_size_idle)),
            ("events", "[" + ",".join(events) + "]"),
            ("full_evals_total", jnum(full_total)),
            ("frontier_evals_total", jnum(frontier_total)),
            ("frontier_build_evals", jnum(build_evals)),
            ("builds", jnum(builds)),
            ("hits", jnum(hits)),
            ("full_cost_us", cost(float(full_total))),
            ("frontier_walk_cost_us", cost(float(frontier_total))),
            ("frontier_cost_us_amortized",
             cost(float(frontier_total + build_evals))),
            ("walk_speedup",
             jnum(r3(float(full_total) / float(frontier_total)))),
            ("corrections", "[" + ",".join(corr_objs) + "]"),
            ("delta_points_touched", jnum(touched_total)),
            ("delta_rebuild_points", jnum(rebuild_total)),
            ("delta_lt_rebuild",
             "true" if touched_total < rebuild_total else "false"),
            ("post_correction_builds", jnum(0)),
            ("cache_resident_bytes", jnum(resident)),
            ("cache_mem_budget", jnum(APP_CACHE_BUDGET_BYTES)),
            ("cache_evictions", jnum(0)),
            ("cache_under_budget",
             "true" if resident <= APP_CACHE_BUDGET_BYTES else "false"),
            ("decisions_per_sec_full", dps(float(full_total))),
            ("decisions_per_sec_frontier", dps(float(frontier_total))),
        ]))
    inner = jobj([
        ("lut_runs", jnum(8)),
        ("noise_sigma", jnum(0.0)),
        ("sim_ns_per_eval", jnum(SIM_NS_PER_EVAL)),
        ("rows", "[" + ",".join(rows) + "]"),
    ])
    return jobj([("opt_bench", inner)]) + "\n"


# --------------------------------------------------------------------------
# Golden 3: coexec_smoke.json — intra-model co-execution.  A partitioned
# execution plan splits one variant into 2–3 layer-group segments pinned to
# distinct engines and runs them as a pipeline: steady-state latency is the
# bottleneck stage (stage roofline + inter-engine transfer), not the sum.
# Mirrors measurements::partition_plans / perf::plan cost / the plan-aware
# designspace enumeration in Rust bit-for-bit.
# --------------------------------------------------------------------------

HANDOFF_MS = 0.05
CUTS_2SEG = (250, 500, 750)
CUTS_3SEG = (250, 750)
COEXEC_EVENTS = [("idle", {}), ("cpu_load", {"cpu": 2.0})]


def boundary_elems(v, cut_pm):
    """Activation elements at a per-mille cut point: geometric
    interpolation between input and output widths, via a sqrt-only chain
    (IEEE sqrt is correctly rounded; powf is not, so Rust and Python agree
    bit-for-bit)."""
    i = float(v["in_elems"])
    o = float(v["out_elems"])
    if cut_pm == 0:
        return i
    if cut_pm == 1000:
        return o
    mid = math.sqrt(i * o)
    if cut_pm == 500:
        return mid
    if cut_pm == 250:
        return math.sqrt(i * mid)
    if cut_pm == 750:
        return math.sqrt(mid * o)
    raise AssertionError(cut_pm)


def partition_plans(dev_name):
    """The default partition grid: every ordered pair of distinct available
    engines at cuts {250, 500, 750}, every ordered triple of distinct
    engines at cuts (250, 750)."""
    avail = [s["kind"] for s in DEVICES[dev_name]["engines"]]
    plans = []
    for a in avail:
        for b in avail:
            if a == b:
                continue
            for cut in CUTS_2SEG:
                plans.append(((a, b), (cut,)))
    for a in avail:
        for b in avail:
            for c in avail:
                if len({a, b, c}) != 3:
                    continue
                plans.append(((a, b, c), CUTS_3SEG))
    return plans


def stage_gflops(dev_name, spec, v, threads):
    """perf::effective_gflops at performance governor, cool device."""
    dev = DEVICES[dev_name]
    threads = max(min(threads, dev["n_cores"]), 1)
    if spec["kind"] == "cpu":
        allc = thread_speedup(spec["parallel"], dev["n_cores"])
        base = spec["peak"] / allc * thread_speedup(spec["parallel"], threads)
    else:
        base = spec["peak"]
    penalty = (NPU_PENALTY.get((dev_name, v["family"]), 1.0)
               if spec["kind"] == "nnapi" else 1.0)
    pm = {"fp32": 1.0, "fp16": spec["fp16"], "int8": spec["int8"]}[v["prec"]]
    return base * pm * FREQ_SCALE["performance"] * 1.0 / penalty


def plan_stages(dev_name, v, engines, cuts):
    """Per-stage roofline costs of a partitioned plan (performance
    governor, cool, idle).  Returns (pipelined_ms, stages, threads) with
    stages = [(engine, stage_ms, xfer_ms)]; pipelined steady-state latency
    is the bottleneck max(xfer + stage)."""
    dev = DEVICES[dev_name]
    threads = dev["n_cores"] if "cpu" in engines else 1
    bounds = (0,) + tuple(cuts) + (1000,)
    stages = []
    pipelined = 0.0
    for i, kind in enumerate(engines):
        spec = spec_of(dev_name, kind)
        lo, hi = bounds[i], bounds[i + 1]
        frac = float(hi - lo) / 1000.0
        flops = float(v["flops"]) * frac
        size = float(v["size"]) * frac
        b_in = boundary_elems(v, lo)
        b_out = boundary_elems(v, hi)
        gflops = stage_gflops(dev_name, spec, v, threads)
        compute = flops / (gflops * 1e6)
        act = (b_in + b_out) * 4.0
        memory = (size + act) / (spec["bw"] * 1e6)
        stage_ms = spec["dispatch"] + max(compute, memory)
        if i == 0:
            xfer_ms = 0.0
        else:
            prev = spec_of(dev_name, engines[i - 1])
            bw = min(prev["bw"], spec["bw"])
            xfer_ms = (b_in * 4.0) / (bw * 1e6) + HANDOFF_MS
        stages.append((kind, stage_ms, xfer_ms))
        pipelined = max(pipelined, xfer_ms + stage_ms)
    return pipelined, stages, threads


def plan_mem_bytes(v, cuts):
    """Variant memory plus double-buffered fp32 activations at every
    interior segment boundary."""
    extra = 0
    for c in cuts:
        extra += int(math.ceil(boundary_elems(v, c))) * 8
    return v["mem"] + extra


def plan_sort_key(plan):
    """Rust ExecPlan Ord: Mono < Split, splits by (engines, cuts)."""
    if plan is None:
        return (0,)
    engines, cuts = plan
    return (1, tuple(ENGINE_ORDER.index(e) for e in engines), tuple(cuts))


def plan_id(plan):
    engines, cuts = plan
    return ">".join(engines) + "@" + "+".join(str(c) for c in cuts)


def build_coexec_lut(dev_name, runs=8):
    """Partition-extended LUT: the mono keys (exactly ``build_lut``) plus
    one key per (variant, partition plan), pinned to the performance
    governor.  Keys gain a 5th ``plan`` element (None = monolithic)."""
    lut = {}
    for k, e in build_lut(dev_name, runs).items():
        v = VARIANTS[k[0]]
        lut[k + (None,)] = dict(e, stages=(), mem=v["mem"])
    for v in VARIANTS.values():
        for engines, cuts in partition_plans(dev_name):
            pipelined, stages, threads = plan_stages(dev_name, v, engines,
                                                     cuts)
            key = (v["name"], engines[0], threads, "performance",
                   (engines, cuts))
            entry = stats_from_identical(pipelined, runs)
            entry["stages"] = stages
            entry["mem"] = plan_mem_bytes(v, cuts)
            lut[key] = entry
    return lut


def coexec_key_sorted(lut):
    return sorted(lut.keys(),
                  key=lambda k: (k[0], ENGINE_ORDER.index(k[1]), k[2],
                                 GOV_ORDER.index(k[3]), plan_sort_key(k[4])))


def coexec_key_admitted(dev_name, lut, family, objective, key):
    """entry_admitted with the plan-aware extensions: every engine the
    plan touches must exist, and memory includes boundary buffers."""
    variant, kind, threads, governor, plan = key
    v = VARIANTS[variant]
    if v["family"] != family:
        return False
    engines = (kind,) if plan is None else plan[0]
    for e in engines:
        if spec_of(dev_name, e) is None:
            return False
    entry = lut.get(key)
    if entry is None:
        return False
    dev = DEVICES[dev_name]
    if not entry["mem"] <= dev["mem_budget"]:
        return False
    if entry["avg"] > dev["max_deployable"]:
        return False
    eps = objective.get("eps")
    if eps is not None and A_REF[family] - v["acc"] > eps + 1e-12:
        return False
    return True


def coexec_eval_key(dev_name, lut, family, objective, rep_loads, key, r):
    """Plan-aware eval_candidate: a monolithic key scales by its engine's
    contention; a split key scales by the ratio of the condition-adjusted
    bottleneck to the base bottleneck (the loaded stage may change which
    stage bottlenecks the pipeline).  Split energy sums per-stage."""
    if not coexec_key_admitted(dev_name, lut, family, objective, key):
        return None
    variant, kind, threads, governor, plan = key
    v = VARIANTS[variant]
    entry = lut[key]
    stat = objective["stat"]
    if plan is None:
        spec = spec_of(dev_name, kind)
        energy = energy_proxy(spec, entry["avg"], governor)
        factor = 2.0 ** max(rep_loads.get(kind, 0.0), 0.0)
    else:
        energy = 0.0
        base_bn = 0.0
        cond_bn = 0.0
        for e, s, x in entry["stages"]:
            energy += energy_proxy(spec_of(dev_name, e), s, governor)
            mult = 2.0 ** max(rep_loads.get(e, 0.0), 0.0)
            base_bn = max(base_bn, x + s)
            cond_bn = max(cond_bn, x + s * mult)
        factor = cond_bn / base_bn
    lat = entry[stat] * factor
    avg = entry["avg"] * factor
    fps = min(CAMERA_FPS * r, 1000.0 / avg)
    return dict(variant=variant, engine=kind, threads=threads,
                governor=governor, plan=plan, r=r, latency=lat, avg=avg,
                fps=fps, mem=entry["mem"], acc=v["acc"], energy=energy)


def coexec_enumerate(dev_name, lut, family, objective, rep_loads,
                     mono_only=False):
    out = []
    for key in coexec_key_sorted(lut):
        if mono_only and key[4] is not None:
            continue
        for r in RATES:
            c = coexec_eval_key(dev_name, lut, family, objective,
                                rep_loads, key, r)
            if c is not None:
                out.append(c)
    return out


def coexec_rank(cands, objective):
    scored = []
    for c in cands:
        s = score_of(objective, c)
        if s is None:
            continue
        c = dict(c)
        c["score"] = s
        scored.append(c)
    return sorted(scored, key=lambda c: rank_key(c) + (plan_sort_key(c["plan"]),))


def coexec_dominates(p, q):
    """Dominance slices additionally require identical execution plans:
    different plans occupy different engine sets and are incomparable."""
    return p["plan"] == q["plan"] and dominates(p, q)


def coexec_frontier(cands, objective):
    survivors = [q for q in cands
                 if not any(coexec_dominates(p, q) for p in cands)]
    return coexec_rank(survivors, objective)


def coexec_design_id(c):
    label = c["engine"] if c["plan"] is None else plan_id(c["plan"])
    return (f"{c['variant']}|{label}|{c['threads']}|{c['governor']}"
            f"|r={fmt_f64(c['r'])}")


def run_coexec_smoke():
    dev_name = "samsung_a71"
    lut = build_coexec_lut(dev_name)
    n_split = sum(1 for k in lut if k[4] is not None)
    rows = []
    gate = False
    for app, family, obj in MIX:
        cache = {}
        ev_objs = []
        idle_pick = None
        space_size = mono_size = frontier_idle = 0
        for name, conds in COEXEC_EVENTS:
            steps = bucket_of(conds)
            bid = bucket_id(steps)
            rep = bucket_representative(steps)
            cands = coexec_enumerate(dev_name, lut, family, obj, rep)
            full = coexec_rank(cands, obj)
            if bid in cache:
                points, built = cache[bid], False
            else:
                points = coexec_frontier(cands, obj)
                cache[bid] = points
                built = True
            assert len(points) < len(full), (app, name)
            pick = points[0]
            assert coexec_design_id(pick) == coexec_design_id(full[0]), \
                f"{app}@{name}: frontier {coexec_design_id(pick)} != " \
                f"full {coexec_design_id(full[0])}"
            if not steps:
                idle_pick = pick
                space_size = len(full)
                mono_size = len([c for c in cands if c["plan"] is None])
                frontier_idle = len(points)
            ev_objs.append(jobj([
                ("name", f'"{name}"'),
                ("bucket", f'"{bid}"'),
                ("full_evals", jnum(len(full))),
                ("frontier_evals", jnum(len(points))),
                ("built", "true" if built else "false"),
                ("match", "true"),
                ("pick", f'"{coexec_design_id(pick)}"'),
                ("latency_ms", jnum(r3(pick["latency"]))),
                ("partitioned",
                 "true" if pick["plan"] is not None else "false"),
            ]))
        mono = coexec_rank(
            coexec_enumerate(dev_name, lut, family, obj, {},
                             mono_only=True), obj)[0]
        speedup = mono["avg"] / idle_pick["avg"]
        part = idle_pick["plan"] is not None
        if part and speedup >= 1.2:
            gate = True
        rows.append(jobj([
            ("device", f'"{dev_name}"'),
            ("app", f'"{app}"'),
            ("family", f'"{family}"'),
            ("objective", f'"{obj["label"]}"'),
            ("space_size", jnum(space_size)),
            ("mono_space_size", jnum(mono_size)),
            ("frontier_size_idle", jnum(frontier_idle)),
            ("events", "[" + ",".join(ev_objs) + "]"),
            ("best_mono", f'"{coexec_design_id(mono)}"'),
            ("best_mono_avg_ms", jnum(r3(mono["avg"]))),
            ("pick", f'"{coexec_design_id(idle_pick)}"'),
            ("pick_avg_ms", jnum(r3(idle_pick["avg"]))),
            ("speedup_vs_mono", jnum(r3(speedup))),
            ("partitioned_pick", "true" if part else "false"),
            ("sim_matches", "true"),
        ]))
    assert gate, "no app picked a partitioned plan with >= 1.2x speedup"
    inner = jobj([
        ("device", f'"{dev_name}"'),
        ("lut_runs", jnum(8)),
        ("noise_sigma", jnum(0.0)),
        ("handoff_ms", jnum(HANDOFF_MS)),
        ("split_keys", jnum(n_split)),
        ("rows", "[" + ",".join(rows) + "]"),
    ])
    return jobj([("coexec", inner)]) + "\n"


def main():
    golden_dir = os.path.normpath(os.path.join(
        os.path.dirname(__file__), "..", "rust", "tests", "golden"))
    outputs = {
        os.path.join(golden_dir, "pareto_frontier.txt"):
            render_frontier_snapshot(),
        os.path.join(golden_dir, "optbench_smoke.json"):
            run_optbench_smoke(),
        os.path.join(golden_dir, "coexec_smoke.json"):
            run_coexec_smoke(),
    }
    rc = 0
    for path, content in outputs.items():
        if "--check" in sys.argv:
            want = open(path).read()
            if want != content:
                print(f"DRIFT: {path} does not match oracle",
                      file=sys.stderr)
                rc = 1
            else:
                print(f"{path} matches oracle", file=sys.stderr)
        else:
            with open(path, "w") as f:
                f.write(content)
            print(f"wrote {path} ({len(content)} bytes)", file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
