#!/usr/bin/env python3
"""Independent oracle for `oodin serve-bench --smoke`.

Re-implements, in plain Python, every deterministic component on the
serve-bench smoke path — the SplitMix64 trace RNG, the roofline latency
model (Samsung A71 CPU, zero noise, cool thermal state), the bounded
deadline queue with degrade watermarks, the deadline-aware batch policy,
and the integer-microsecond event loop — and emits the exact JSON line the
Rust binary prints, regenerating `rust/tests/golden/serve_bench.json`.

Why this exists: the golden snapshot must be producible *without* running
the Rust binary (the authoring container has no Rust toolchain), and it
doubles as an N-version check — Rust and Python implementations of the
same spec must agree byte-for-byte.

Exactness argument: every quantity that reaches the snapshot is either
integer arithmetic (the µs event timeline), IEEE-754 double +,*,/,max
(the roofline latencies — exactly specified, identical in both
languages), or `log` used only to draw arrival gaps that are immediately
quantised to whole microseconds (a last-ulp `log` difference flips a
rounding only with probability ~1e-13 per draw).  The thermal model is
simulated only to *assert* the engine stays >2 degC below its throttle
point, where its frequency scale is exactly 1.0 and drops out.

Usage:  python3 python/golden_serve_bench.py [--check]
  default: writes rust/tests/golden/serve_bench.json
  --check: compares against the existing file, exit 1 on drift
"""

import heapq
import math
import os
import sys

# --------------------------------------------------------------------------
# util::rng::Rng (SplitMix64)
# --------------------------------------------------------------------------

M64 = (1 << 64) - 1
GOLDEN_GAMMA = 0x9E3779B97F4A7C15


class Rng:
    def __init__(self, seed):
        self.state = (seed + GOLDEN_GAMMA) & M64

    def next_u64(self):
        self.state = (self.state + GOLDEN_GAMMA) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return z ^ (z >> 31)

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def below(self, n):
        return self.next_u64() % n


def rust_round(x):
    """f64::round: half away from zero (positive inputs only here)."""
    f = math.floor(x)
    return int(f) if x - f < 0.5 else int(f) + 1


# --------------------------------------------------------------------------
# Roofline latency (perf::latency_ms) for the bench fixture on the
# Samsung A71 CPU engine (SimBackend default: threads=8, performance
# governor, zero noise, no external load, thermal scale 1.0 while cool).
# --------------------------------------------------------------------------

A71_CPU = dict(peak=14.0, int8_mult=2.2, bw=8.0, dispatch=0.002,
               parallel=0.85, n_cores=8,
               heat_per_ms=0.08, cool_rate=0.003, throttle=62.0)
RES = 16
NUM_CLASSES = 10
# (precision, batch) -> (flops per sample, weight bytes)
FIXTURE = {
    ("fp32", 1): (28_000_000, 400_000),
    ("fp32", 4): (21_000_000, 400_000),
    ("fp32", 8): (17_500_000, 400_000),
    ("int8", 1): (28_000_000, 100_000),
    ("int8", 4): (21_000_000, 100_000),
    ("int8", 8): (17_500_000, 100_000),
}


def thread_speedup(parallel, threads):
    if threads <= 1:
        return 1.0
    return 1.0 / ((1.0 - parallel) + parallel / threads)


def latency_busy_ms(prec, batch):
    """(latency_ms, busy_ms) — exact mirror of perf::latency_ms order."""
    spec = A71_CPU
    flops, size = FIXTURE[(prec, batch)]
    all_cores = thread_speedup(spec["parallel"], spec["n_cores"])
    base = spec["peak"] / all_cores * thread_speedup(spec["parallel"],
                                                    spec["n_cores"])
    pm = 1.0 if prec == "fp32" else spec["int8_mult"]
    # base * precision_mult * governor(1.0) * thermal(1.0) / penalty(1.0)
    gflops = base * pm * 1.0 * 1.0 / 1.0
    compute = (float(flops) * float(batch)) / (gflops * 1e6)
    in_elems = batch * RES * RES * 3
    out_elems = batch * NUM_CLASSES
    act = (in_elems + out_elems) * 4
    memory = (float(size) + float(act)) / (spec["bw"] * 1e6)
    roof = max(compute, memory)
    # contention(0.0) = 2^0 = 1.0 exactly
    return (spec["dispatch"] + roof) * 1.0, roof


SERVICE_MS = {k: latency_busy_ms(*k)[0] for k in FIXTURE}
BUSY_MS = {k: latency_busy_ms(*k)[1] for k in FIXTURE}


class Backend:
    """DeviceSim stand-in: constant latencies + a thermal guard asserting
    the CPU never comes within 2 degC of throttling (where the closed-form
    latencies would stop being exact)."""

    def __init__(self):
        self.clock_us = 0
        self.temp = 25.0
        self.last_ms = 0.0

    def _cool(self, dt_ms):
        self.temp = 25.0 + (self.temp - 25.0) * math.exp(
            -A71_CPU["cool_rate"] * dt_ms)

    def execute(self, prec, batch):
        now_ms = self.clock_us / 1e3
        # idle_until(now)
        dt = max(now_ms - self.last_ms, 0.0)
        self.last_ms = now_ms
        self._cool(dt)
        assert self.temp < A71_CPU["throttle"] - 2.0, (
            f"thermal margin lost: {self.temp:.2f} degC — golden latencies "
            "would no longer be closed-form")
        lat_ms = SERVICE_MS[(prec, batch)]
        self.clock_us += rust_round(lat_ms * 1e3)
        # record_work(now2, busy)
        now2 = self.clock_us / 1e3
        dt = max(now2 - self.last_ms, 0.0)
        self.last_ms = now2
        self.temp += A71_CPU["heat_per_ms"] * BUSY_MS[(prec, batch)]
        self._cool(dt)
        return max(rust_round(lat_ms * 1e3), 1)  # service µs


# --------------------------------------------------------------------------
# serving::queue::DeadlineQueue
# --------------------------------------------------------------------------

class DeadlineQueue:
    def __init__(self, cap, high, low):
        self.cap, self.high, self.low = cap, high, low
        self.entries = []  # (class, arrival_us, deadline_us)
        self.degraded = False
        self.sheds = 0
        self.max_depth = 0

    def admit(self, item, arrival, deadline):
        if len(self.entries) >= self.cap:
            self.sheds += 1
            return False
        self.entries.append((item, arrival, deadline))
        self.max_depth = max(self.max_depth, len(self.entries))
        if not self.degraded and len(self.entries) >= self.high:
            self.degraded = True
        return True

    def pop_chunk(self, n):
        take = min(n, len(self.entries))
        chunk = self.entries[:take]
        del self.entries[:take]
        if self.degraded and len(self.entries) <= self.low:
            self.degraded = False
        return chunk


# --------------------------------------------------------------------------
# serving::batch — pick_variant + decide
# --------------------------------------------------------------------------

LADDER = [1, 4, 8]
U64MAX = M64


def pick_variant(ladder, n, max_pad_ratio):
    n = max(n, 1)
    for b in ladder:
        if b == n:
            return b
    for b in ladder:
        if b > n and (b - n) / float(b) <= max_pad_ratio:
            return b
    for b in reversed(ladder):
        if b <= n:
            return b
    return ladder[0]


def decide(now, qlen, max_batch, oldest_arr, oldest_dl, est, max_wait, slack):
    """Returns ('full'|'maxwait'|'deadline', None) or (None, wake_us)."""
    if qlen >= max_batch:
        return "full", None
    wait_trigger = min(oldest_arr + max_wait, U64MAX)
    if now >= wait_trigger:
        return "maxwait", None
    if oldest_dl != U64MAX:
        margin = est + slack
        if min(now + margin, U64MAX) >= oldest_dl:
            return "deadline", None
        return None, max(min(wait_trigger, oldest_dl - margin), now + 1)
    return None, max(wait_trigger, now + 1)


# --------------------------------------------------------------------------
# serving::pipeline::EventPipeline (virtual event loop)
# --------------------------------------------------------------------------

class Report:
    def __init__(self):
        self.offered = 0
        self.shed = 0
        self.completions = []  # (class, arrival, done, deadline, batch, deg)
        self.degraded_served = 0
        self.executed_slots = 0
        self.padded_slots = 0
        self.max_depth = 0
        self.launches = {"full": 0, "maxwait": 0, "deadline": 0}
        self.makespan_us = 0


def run_events(pending, spawner, cfg):
    """pending: list of (at_us, seq, class); spawner: None or
    (duration_us, Rng, next_seq)."""
    backend = Backend()
    est = {}
    # calibrate(): primary then degraded ladder, sizes ascending
    for deg in (False, True):
        if deg and not cfg["degrade"]:
            continue
        prec = "int8" if deg else "fp32"
        for b in LADDER:
            est[(deg, b)] = backend.execute(prec, b)

    heapq.heapify(pending)
    queue = DeadlineQueue(cfg["queue_cap"], cfg["high"], cfg["low"])
    lanes = [0]
    rep = Report()
    now = 0
    max_wait = rust_round(cfg["max_wait_ms"] * 1e3)
    slack = rust_round(cfg["slack_ms"] * 1e3)
    dl_rel = (rust_round(cfg["deadline_ms"] * 1e3)
              if math.isfinite(cfg["deadline_ms"]) else U64MAX)
    while True:
        while pending and pending[0][0] <= now:
            at, _, cls = heapq.heappop(pending)
            rep.offered += 1
            queue.admit(cls, at, min(at + dl_rel, U64MAX))
        wake = None
        while queue.entries:
            lane, free_at = min(enumerate(lanes), key=lambda p: (p[1], p[0]))
            if free_at > now:
                break
            use_deg = queue.degraded and cfg["degrade"]
            prec = "int8" if use_deg else "fp32"
            bsz = pick_variant(LADDER, len(queue.entries),
                               cfg["max_pad_ratio"])
            e = est.get((use_deg, bsz), 0)
            earliest_dl = min(ent[2] for ent in queue.entries)
            reason, wake_at = decide(now, len(queue.entries), LADDER[-1],
                                     queue.entries[0][1], earliest_dl,
                                     e, max_wait, slack)
            if reason is None:
                wake = wake_at
                break
            rep.launches[reason] += 1
            chunk = queue.pop_chunk(min(bsz, len(queue.entries)))
            svc = backend.execute(prec, bsz)
            est[(use_deg, bsz)] = svc
            lanes[lane] = now + svc
            rep.executed_slots += bsz
            rep.padded_slots += bsz - len(chunk)
            if use_deg:
                rep.degraded_served += len(chunk)
            done = now + svc
            rep.makespan_us = max(rep.makespan_us, done)
            for cls, arr, dl in chunk:
                # Serving-span invariant (telemetry::spans): enqueue ≤
                # launch < complete, so queue-wait and service time are
                # both well-defined and non-negative.
                assert arr <= now < done, (arr, now, done)
                rep.completions.append((cls, arr, done, dl, bsz, use_deg))
                if spawner is not None and done < spawner[0]:
                    heapq.heappush(pending,
                                   (done, spawner[2], spawner[1].below(
                                       NUM_CLASSES)))
                    spawner[2] += 1
        nxt = U64MAX
        if pending:
            nxt = min(nxt, pending[0][0])
        if queue.entries:
            min_free = min(lanes)
            if min_free > now:
                nxt = min(nxt, min_free)
            else:
                assert wake is not None
                nxt = min(nxt, wake)
        if nxt == U64MAX:
            break
        now = nxt
    rep.max_depth = queue.max_depth
    rep.shed = queue.sheds
    # Span accounting closes: no request is left enqueued or in flight
    # (zero unclosed spans) and every offered request either completed
    # or was shed at admission.
    assert not queue.entries, "unclosed requests at end of run"
    assert rep.offered == len(rep.completions) + rep.shed, (
        rep.offered, len(rep.completions), rep.shed)
    return rep


# --------------------------------------------------------------------------
# experiments::loadgen — traces + smoke config + JSON emission
# --------------------------------------------------------------------------

def poisson_trace(rate_rps, duration_ms, seed):
    rng = Rng(seed)
    dur = rust_round(duration_ms * 1e3)
    t = 0
    out = []
    while True:
        gap_ms = -math.log(1.0 - rng.f64()) * 1000.0 / rate_rps
        t += max(rust_round(gap_ms * 1e3), 1)
        if t >= dur:
            break
        out.append((t, len(out), rng.below(NUM_CLASSES)))
    return out


def burst_trace(base, burst, period_ms, duty, duration_ms, seed):
    rng = Rng(seed)
    dur = rust_round(duration_ms * 1e3)
    period = rust_round(period_ms * 1e3)
    burst_span = rust_round(period_ms * duty * 1e3)
    t = 0
    out = []
    while True:
        rate = burst if t % period < burst_span else base
        gap_ms = -math.log(1.0 - rng.f64()) * 1000.0 / rate
        t += max(rust_round(gap_ms * 1e3), 1)
        if t >= dur:
            break
        out.append((t, len(out), rng.below(NUM_CLASSES)))
    return out


SMOKE = dict(device="samsung_a71", seed=42, duration_ms=2000.0,
             open_rates=[200.0, 500.0, 900.0],
             burst=dict(base=100.0, burst=3000.0, period_ms=500.0, duty=0.3),
             tight=dict(rate=400.0, deadline_ms=7.0),
             closed=[4, 32],
             queue_cap=64, max_wait_ms=5.0, deadline_ms=50.0, degrade=True)


def scen_cfg(deadline_ms):
    return dict(queue_cap=SMOKE["queue_cap"],
                high=SMOKE["queue_cap"] // 2,
                low=SMOKE["queue_cap"] // 8,
                max_wait_ms=SMOKE["max_wait_ms"],
                slack_ms=0.5,
                deadline_ms=deadline_ms,
                max_pad_ratio=0.25,
                degrade=SMOKE["degrade"])


def percentile(sorted_vals, p):
    n = len(sorted_vals)
    if n == 1:
        return sorted_vals[0]
    rank = p / 100.0 * float(n - 1)
    lo = int(math.floor(rank))
    hi = int(math.ceil(rank))
    frac = rank - float(lo)
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


def r3(x):
    return rust_round(x * 1000.0) / 1000.0


def jnum(n):
    f = float(n)
    if f == int(f) and abs(f) < 9e15:
        return str(int(f))
    return repr(f)


def report_fields(rep):
    comps = rep.completions
    lat = sorted((done - arr) / 1000.0 for _, arr, done, _, _, _ in comps)
    misses = sum(1 for _, _, done, dl, _, _ in comps if done > dl)
    correct = len(comps)  # accuracy-1.0 fixture: predictions are exact
    lateness = max((max(done - dl, 0) for _, _, done, dl, _, _ in comps),
                   default=0)
    thr = (float(len(comps)) * 1e6 / float(rep.makespan_us)
           if rep.makespan_us else 0.0)
    p = lambda q: percentile(lat, q) if lat else 0.0  # noqa: E731
    return [
        ("offered", jnum(rep.offered)),
        ("completed", jnum(len(comps))),
        ("shed", jnum(rep.shed)),
        ("deadline_miss", jnum(misses)),
        ("degraded_served", jnum(rep.degraded_served)),
        ("correct", jnum(correct)),
        ("executed_slots", jnum(rep.executed_slots)),
        ("padded_slots", jnum(rep.padded_slots)),
        ("queue_depth_max", jnum(rep.max_depth)),
        ("launch_full", jnum(rep.launches["full"])),
        ("launch_maxwait", jnum(rep.launches["maxwait"])),
        ("launch_deadline", jnum(rep.launches["deadline"])),
        ("throughput_rps", jnum(r3(thr))),
        ("p50_ms", jnum(r3(p(50.0)))),
        ("p95_ms", jnum(r3(p(95.0)))),
        ("p99_ms", jnum(r3(p(99.0)))),
        ("max_lateness_ms", jnum(r3(lateness / 1000.0))),
        ("makespan_ms", jnum(r3(rep.makespan_us / 1000.0))),
    ]


def obj(fields):
    return "{" + ",".join(f'"{k}":{v}' for k, v in fields) + "}"


def main():
    scenarios = []
    diag = []
    for rate in SMOKE["open_rates"]:
        rep = run_events(poisson_trace(rate, SMOKE["duration_ms"],
                                       SMOKE["seed"]),
                         None, scen_cfg(SMOKE["deadline_ms"]))
        scenarios.append(([("trace", '"poisson"'), ("rate_rps", jnum(rate))],
                          rep))
    b = SMOKE["burst"]
    rep = run_events(burst_trace(b["base"], b["burst"], b["period_ms"],
                                 b["duty"], SMOKE["duration_ms"],
                                 SMOKE["seed"]),
                     None, scen_cfg(SMOKE["deadline_ms"]))
    scenarios.append(([("trace", '"burst"'), ("base_rps", jnum(b["base"])),
                       ("burst_rps", jnum(b["burst"])),
                       ("period_ms", jnum(b["period_ms"])),
                       ("duty", jnum(b["duty"]))], rep))
    t = SMOKE["tight"]
    rep = run_events(poisson_trace(t["rate"], SMOKE["duration_ms"],
                                   SMOKE["seed"]),
                     None, scen_cfg(t["deadline_ms"]))
    scenarios.append(([("trace", '"poisson_tight"'),
                       ("rate_rps", jnum(t["rate"])),
                       ("deadline_ms", jnum(t["deadline_ms"]))], rep))
    for c in SMOKE["closed"]:
        dur = rust_round(SMOKE["duration_ms"] * 1e3)
        rng = Rng(SMOKE["seed"])
        pending = [(0, seq, rng.below(NUM_CLASSES)) for seq in range(c)]
        rep = run_events(pending, [dur, rng, c], scen_cfg(SMOKE["deadline_ms"]))
        scenarios.append(([("trace", '"closed"'), ("concurrency", jnum(c))],
                          rep))

    rows = []
    for head, rep in scenarios:
        rows.append(obj(head + report_fields(rep)))
        comps = rep.completions
        lateness = max((max(done - dl, 0)
                        for _, _, done, dl, _, _ in comps), default=0)
        diag.append(f"{dict(head)['trace']:>16} {dict(head).get('rate_rps', dict(head).get('concurrency', '-')):>6} "
                    f"offered={rep.offered:<5} done={len(comps):<5} "
                    f"shed={rep.shed:<4} deg={rep.degraded_served:<5} "
                    f"miss={sum(1 for _, _, d, dl, _, _ in comps if d > dl):<4} "
                    f"lateness_us={lateness:<6} qmax={rep.max_depth:<3} "
                    f"launches={rep.launches}")
    inner = obj([
        ("device", '"samsung_a71"'),
        ("family", '"srv"'),
        ("seed", jnum(SMOKE["seed"])),
        ("duration_ms", jnum(SMOKE["duration_ms"])),
        ("queue_cap", jnum(SMOKE["queue_cap"])),
        ("max_wait_ms", jnum(SMOKE["max_wait_ms"])),
        ("deadline_ms", jnum(SMOKE["deadline_ms"])),
        ("degrade", "true"),
        ("scenarios", "[" + ",".join(rows) + "]"),
    ])
    line = obj([("serve_bench", inner)])

    print("\n".join(diag), file=sys.stderr)
    for k, v in sorted(SERVICE_MS.items()):
        print(f"service {k} = {v!r} ms", file=sys.stderr)

    out_path = os.path.join(os.path.dirname(__file__), "..", "rust", "tests",
                            "golden", "serve_bench.json")
    out_path = os.path.normpath(out_path)
    if "--check" in sys.argv:
        want = open(out_path).read()
        if want != line + "\n":
            print("DRIFT: golden snapshot does not match oracle",
                  file=sys.stderr)
            return 1
        print("golden snapshot matches oracle", file=sys.stderr)
        return 0
    with open(out_path, "w") as f:
        f.write(line + "\n")
    print(f"wrote {out_path} ({len(line)} bytes)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
