"""AOT compile path: train -> transform -> lower every variant to HLO text.

Emits, per (family, transformation, batch):
  artifacts/<family>__<precision>__b<batch>.hlo.txt

plus ``artifacts/manifest.json`` describing every variant with the fields the
Rust Layer-3 consumes as the model tuple  m = <task, w, s_m, s_in, a, p>
(paper §III-B1): measured accuracy, computed FLOPs, parameter count and
serialized size, numerical precision, resolution and I/O shapes.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Weights are baked into the HLO as constants, so the Rust request path feeds
only the input image literal — python never runs at serving time.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import evaluate
from .layers import Ctx
from .models import FAMILIES, PRECISIONS, Family
from .train import get_trained_params
from .transform import apply_transform, precision_bits

# Batch sizes compiled per family. The flagship mobile model additionally
# gets batched executables for the Layer-3 dynamic-batching serving bench.
EXTRA_BATCHES = {"mobilenet_v2_100": (4, 8)}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # big literals as `constant({...})`, which the 0.5.1 HLO parser silently
    # turns into zero tensors -- the artifact would "run" with zero weights.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_variant(fam: Family, params, batch: int) -> str:
    """Lower the pallas-kernel forward pass for one variant to HLO text."""
    ctx = Ctx(impl="pallas")
    spec = jax.ShapeDtypeStruct((batch, fam.resolution, fam.resolution, 3),
                                jnp.float32)

    def fwd(x):
        return (fam.apply(params, x, ctx),)

    return to_hlo_text(jax.jit(fwd).lower(spec))


def model_costs(fam: Family, params) -> tuple[int, int, int]:
    """(flops at batch=1, param count, serialized weight bytes)."""
    costs: list = []
    ctx = Ctx(impl="ref", costs=costs)
    spec = jax.ShapeDtypeStruct((1, fam.resolution, fam.resolution, 3),
                                jnp.float32)
    jax.eval_shape(lambda x: fam.apply(params, x, ctx), spec)
    flops = sum(f for _, f, _ in costs)
    leaves = jax.tree.leaves(params)
    n_params = sum(l.size for l in leaves)
    size = sum(l.size * l.dtype.itemsize for l in leaves)
    return flops, n_params, size


def output_shape(fam: Family, params, batch: int) -> list[int]:
    ctx = Ctx(impl="ref")
    spec = jax.ShapeDtypeStruct((batch, fam.resolution, fam.resolution, 3),
                                jnp.float32)
    out = jax.eval_shape(lambda x: fam.apply(params, x, ctx), spec)
    return list(out.shape)


def build_family(fam: Family, out_dir: str, *, skip_existing: bool) -> list[dict]:
    params_ref = get_trained_params(fam)
    entries = []
    _, n_params, _ = model_costs(fam, params_ref)
    for prec in PRECISIONS:
        params_t = apply_transform(prec, params_ref)
        flops, _, size = model_costs(fam, params_t)
        acc = evaluate.evaluate(fam, params_t)
        batches = (1,) + EXTRA_BATCHES.get(fam.name, ())
        for batch in batches:
            fname = f"{fam.name}__{prec}__b{batch}.hlo.txt"
            path = os.path.join(out_dir, fname)
            if not (skip_existing and os.path.exists(path)):
                print(f"lowering {fname} ...", flush=True)
                text = lower_variant(fam, params_t, batch)
                with open(path, "w") as f:
                    f.write(text)
            entries.append({
                "name": f"{fam.name}__{prec}__b{batch}",
                "family": fam.name,
                "paper_name": fam.paper_name,
                "task": fam.task,
                "precision": prec,
                "bits": precision_bits(prec),
                "resolution": fam.resolution,
                "batch": batch,
                "input_shape": [batch, fam.resolution, fam.resolution, 3],
                "output_shape": output_shape(fam, params_t, batch),
                "params": int(n_params),
                "size_bytes": int(size),
                "flops": int(flops),
                "accuracy": float(acc),
                "accuracy_metric": "top1" if fam.task == "cls" else "miou",
                "hlo": fname,
            })
        print(f"  {fam.name} {prec}: acc={acc:.4f} flops={flops/1e6:.1f}M "
              f"size={size/1e6:.2f}MB", flush=True)
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--families", nargs="*", default=list(FAMILIES))
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the HLO file exists")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name in args.families:
        manifest.extend(build_family(FAMILIES[name], args.out_dir,
                                     skip_existing=not args.force))
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"version": 1, "models": manifest}, f, indent=1)
    print(f"wrote {len(manifest)} variants to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
