"""DeepLabV3 analogue (Chen et al.) — MobileNetV2 backbone + atrous conv head.

Keeps the family signature the paper uses: a mobile-friendly MobileNetV2
backbone (depth multiplier 0.5 in the paper; narrow IR blocks here), an
ASPP-lite head of parallel atrous (dilated) 3x3 convolutions at rates
{1, 2, 4} plus a 1x1 branch, channel concat, 1x1 classifier to the 5
segmentation classes, and bilinear upsampling back to input resolution.
Output is per-pixel logits [N, H, W, 5]; the reported metric is mIoU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from ..datasets import NUM_SEG_CLASSES

_ASPP_RATES = (1, 2, 4)
_ASPP_C = 32


def init(rng):
    k = jax.random.split(rng, 9)
    params = {"stem": L.init_conv(k[0], 3, 3, 3, 16)}
    params["blocks"] = [
        L.init_inverted_residual(k[1], 16, 16, expand=1, stride=1),
        L.init_inverted_residual(k[2], 16, 24, expand=4, stride=2),
        L.init_inverted_residual(k[3], 24, 32, expand=4, stride=1),
    ]
    params["aspp"] = [
        L.init_conv(k[4 + i], 3, 3, 32, _ASPP_C) for i in range(len(_ASPP_RATES))
    ]
    params["aspp1x1"] = L.init_conv(k[7], 1, 1, 32, _ASPP_C)
    cat = _ASPP_C * (len(_ASPP_RATES) + 1)
    params["classifier"] = L.init_conv(k[8], 1, 1, cat, NUM_SEG_CLASSES)
    return params


def apply(params, x: jnp.ndarray, ctx: L.Ctx) -> jnp.ndarray:
    n, h, w, _ = x.shape
    y = L.relu6(L.conv2d(ctx, params["stem"], x, stride=2))
    for blk in params["blocks"]:
        y = L.inverted_residual(ctx, blk, y)
    branches = [
        L.relu6(L.conv2d(ctx, p, y, dilation=r))
        for p, r in zip(params["aspp"], _ASPP_RATES)
    ]
    branches.append(L.relu6(L.conv2d(ctx, params["aspp1x1"], y, pad=0)))
    y = jnp.concatenate(branches, axis=-1)
    y = L.conv2d(ctx, params["classifier"], y, pad=0)
    return L.resize_bilinear(y, h, w)
