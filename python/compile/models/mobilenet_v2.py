"""MobileNetV2 analogue (Sandler et al., CVPR'18) — scaled for this testbed.

Preserves the architecture family's signature: a conv stem followed by
inverted-residual bottleneck blocks (1x1 expand -> 3x3 depthwise -> 1x1
project, residual when stride 1 and cin==cout), relu6, GAP + linear head.
The paper evaluates width multipliers 1.0 and 1.4; we do the same, with
channels rounded to multiples of 8 as in the original.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from ..datasets import NUM_CLASSES

# (cin, cout, expand, stride) before width scaling.
_BLOCKS = [
    (16, 16, 1, 1),
    (16, 24, 4, 2),
    (24, 24, 4, 1),
    (24, 48, 4, 2),
    (48, 48, 4, 1),
]
_STEM = 16
_HEAD = 96


def _scale(c: int, width: float) -> int:
    return max(8, int(round(c * width / 8)) * 8)


def init(rng, *, width: float = 1.0):
    ks = jax.random.split(rng, len(_BLOCKS) + 3)
    stem_c = _scale(_STEM, width)
    head_c = _scale(_HEAD, width)
    params = {"stem": L.init_conv(ks[0], 3, 3, 3, stem_c), "blocks": []}
    cin = stem_c
    for i, (bc_in, bc_out, t, s) in enumerate(_BLOCKS):
        cout = _scale(bc_out, width)
        params["blocks"].append(
            L.init_inverted_residual(ks[i + 1], cin, cout, expand=t, stride=s))
        cin = cout
    params["head"] = L.init_conv(ks[-2], 1, 1, cin, head_c)
    params["fc"] = L.init_dense(ks[-1], head_c, NUM_CLASSES)
    return params


def apply(params, x: jnp.ndarray, ctx: L.Ctx) -> jnp.ndarray:
    y = L.relu6(L.conv2d(ctx, params["stem"], x, stride=2))
    for blk in params["blocks"]:
        y = L.inverted_residual(ctx, blk, y)
    y = L.relu6(L.conv2d(ctx, params["head"], y, pad=0))
    y = L.global_avg_pool(y)
    return L.dense(ctx, params["fc"], y)
