"""ResNetV2 analogue (He et al., CVPR'16 / pre-activation variant) — scaled.

Keeps the family signature: pre-activation residual blocks of two dense 3x3
convolutions, stride-2 stage transitions with projection shortcuts.  This is
the heaviest model in the zoo (dense convs at high channel counts), mirroring
Table II where ResNetV2-101 has the largest parameter count and FLOPs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from ..datasets import NUM_CLASSES

# (channels, blocks, stride of first block).
_STAGES = [(32, 2, 1), (64, 2, 2), (128, 2, 2)]


def _init_block(rng, cin: int, cout: int, stride: int):
    k = jax.random.split(rng, 3)
    # Fixup-style residual-branch downscale: without normalisation layers
    # the residual sum doubles activation variance per block, saturating
    # relu6 and killing gradients; scaling the closing conv keeps each
    # block near-identity at init.
    c2 = L.init_conv(k[1], 3, 3, cout, cout)
    c2["w"] = c2["w"] * 0.1
    p = {
        "c1": L.init_conv(k[0], 3, 3, cin, cout),
        "c2": c2,
        "proj": None,
        "meta": L.Meta(stride=stride, cin=cin, cout=cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = L.init_conv(k[2], 1, 1, cin, cout)
    return p


def _block(ctx: L.Ctx, p, x: jnp.ndarray) -> jnp.ndarray:
    m = p["meta"]
    y = L.relu6(x)  # pre-activation
    shortcut = x
    if p["proj"] is not None:
        shortcut = L.conv2d(ctx, p["proj"], y, stride=m["stride"], pad=0)
    y = L.relu6(L.conv2d(ctx, p["c1"], y, stride=m["stride"]))
    y = L.conv2d(ctx, p["c2"], y)
    return shortcut + y


def init(rng):
    n_blocks = sum(b for _, b, _ in _STAGES)
    ks = jax.random.split(rng, n_blocks + 2)
    params = {"stem": L.init_conv(ks[0], 3, 3, 3, _STAGES[0][0]), "blocks": []}
    cin, ki = _STAGES[0][0], 1
    for cout, blocks, stride in _STAGES:
        for b in range(blocks):
            params["blocks"].append(
                _init_block(ks[ki], cin, cout, stride if b == 0 else 1))
            cin, ki = cout, ki + 1
    params["fc"] = L.init_dense(ks[-1], cin, NUM_CLASSES)
    return params


def apply(params, x: jnp.ndarray, ctx: L.Ctx) -> jnp.ndarray:
    y = L.conv2d(ctx, params["stem"], x)
    for blk in params["blocks"]:
        y = _block(ctx, blk, y)
    y = L.relu6(y)
    y = L.global_avg_pool(y)
    return L.dense(ctx, params["fc"], y)
