"""InceptionV3 analogue (Szegedy et al.) — scaled for this testbed.

Keeps the family signature: multi-branch inception modules (1x1 / 1x1->3x3 /
factorised 5x5 as two 3x3s / pool->1x1 projection, channel-concatenated),
with a stride-2 grid reduction between module groups.  Deliberately the
second-heaviest model in the zoo, mirroring Table II where InceptionV3 costs
an order of magnitude more FLOPs than the mobile-first families.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from ..datasets import NUM_CLASSES


def _init_module(rng, cin: int, b1: int, b3r: int, b3: int, b5r: int,
                 b5: int, bp: int):
    k = jax.random.split(rng, 7)
    return {
        "b1": L.init_conv(k[0], 1, 1, cin, b1),
        "b3_reduce": L.init_conv(k[1], 1, 1, cin, b3r),
        "b3": L.init_conv(k[2], 3, 3, b3r, b3),
        "b5_reduce": L.init_conv(k[3], 1, 1, cin, b5r),
        "b5a": L.init_conv(k[4], 3, 3, b5r, b5),
        "b5b": L.init_conv(k[5], 3, 3, b5, b5),
        "bpool": L.init_conv(k[6], 1, 1, cin, bp),
        "meta": L.Meta(cout=b1 + b3 + b5 + bp),
    }


def _module(ctx: L.Ctx, p, x: jnp.ndarray) -> jnp.ndarray:
    y1 = L.relu6(L.conv2d(ctx, p["b1"], x, pad=0))
    y3 = L.relu6(L.conv2d(ctx, p["b3_reduce"], x, pad=0))
    y3 = L.relu6(L.conv2d(ctx, p["b3"], y3))
    y5 = L.relu6(L.conv2d(ctx, p["b5_reduce"], x, pad=0))
    y5 = L.relu6(L.conv2d(ctx, p["b5a"], y5))
    y5 = L.relu6(L.conv2d(ctx, p["b5b"], y5))
    yp = L.relu6(L.conv2d(ctx, p["bpool"], L.avg_pool_3x3(x), pad=0))
    return jnp.concatenate([y1, y3, y5, yp], axis=-1)


def init(rng):
    k = jax.random.split(rng, 7)
    params = {"stem": L.init_conv(k[0], 3, 3, 3, 32)}
    params["m1"] = _init_module(k[1], 32, 24, 32, 48, 16, 24, 24)     # -> 120
    params["m2"] = _init_module(k[2], 120, 32, 48, 64, 16, 32, 32)    # -> 160
    params["reduce"] = L.init_conv(k[3], 3, 3, 160, 160)
    params["m3"] = _init_module(k[4], 160, 48, 64, 96, 24, 48, 48)    # -> 240
    params["head"] = L.init_conv(k[5], 1, 1, 240, 192)
    params["fc"] = L.init_dense(k[6], 192, NUM_CLASSES)
    return params


def apply(params, x: jnp.ndarray, ctx: L.Ctx) -> jnp.ndarray:
    y = L.relu6(L.conv2d(ctx, params["stem"], x, stride=2))
    y = _module(ctx, params["m1"], y)
    y = _module(ctx, params["m2"], y)
    y = L.relu6(L.conv2d(ctx, params["reduce"], y, stride=2))
    y = _module(ctx, params["m3"], y)
    y = L.relu6(L.conv2d(ctx, params["head"], y, pad=0))
    y = L.global_avg_pool(y)
    return L.dense(ctx, params["fc"], y)
