"""Model-zoo registry mirroring the paper's Table II.

Each family entry carries the paper model it stands in for, the task, the
testbed input resolution, and ``init``/``apply`` closures.  Resolutions scale
down the paper's 224/299/300/513 px inputs while preserving their ordering.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

from . import deeplab, efficientnet_lite, inception, mobilenet_v2, resnet


@dataclasses.dataclass(frozen=True)
class Family:
    name: str
    paper_name: str
    task: str               # "cls" | "seg"
    resolution: int
    init: Callable
    apply: Callable
    train_steps: int = 350
    lr: float = 2e-3


FAMILIES: dict[str, Family] = {
    f.name: f for f in [
        Family("mobilenet_v2_100", "MobileNetV2 1.0", "cls", 24,
               functools.partial(mobilenet_v2.init, width=1.0),
               mobilenet_v2.apply),
        Family("mobilenet_v2_140", "MobileNetV2 1.4", "cls", 24,
               functools.partial(mobilenet_v2.init, width=1.4),
               mobilenet_v2.apply),
        Family("efficientnet_lite0", "EfficientNetLite0", "cls", 24,
               functools.partial(efficientnet_lite.init, width=1.0, depth=1.0),
               efficientnet_lite.apply),
        # depth capped at 1.2: deeper stacks do not train without
        # normalisation layers (which the zoo omits for quantisation
        # simplicity); width carries the rest of the Lite0->Lite4 scaling.
        Family("efficientnet_lite4", "EfficientNetLite4", "cls", 32,
               functools.partial(efficientnet_lite.init, width=1.4, depth=1.2),
               efficientnet_lite.apply, train_steps=450),
        Family("inception_v3", "InceptionV3", "cls", 32,
               inception.init, inception.apply),
        # Fixup-style init still needs a gentler LR than the shallow nets.
        Family("resnet_v2", "ResNetV2 101", "cls", 32,
               resnet.init, resnet.apply, train_steps=300, lr=5e-4),
        Family("deeplab_v3", "DeepLabV3", "seg", 48,
               deeplab.init, deeplab.apply, train_steps=250),
    ]
}

PRECISIONS = ("fp32", "fp16", "int8")
