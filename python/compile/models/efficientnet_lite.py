"""EfficientNet-Lite analogue (Tan & Le, ICML'19) — scaled for this testbed.

Keeps the family signature: compound scaling of width/depth/resolution over
an MBConv (inverted-residual) backbone.  Lite0 is the small config; Lite4
scales width x1.4 and depth x1.8 and runs at a larger input resolution —
matching the paper's two evaluated variants (Table II).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from ..datasets import NUM_CLASSES

# Base stage config: (cout, expand, stride, repeats).
_STAGES = [
    (16, 1, 1, 1),
    (24, 4, 2, 2),
    (40, 4, 2, 2),
    (80, 4, 1, 1),
]
_STEM = 16
_HEAD = 128


def _scale_c(c: int, width: float) -> int:
    return max(8, int(round(c * width / 8)) * 8)


def _scale_d(r: int, depth: float) -> int:
    return max(1, int(round(r * depth)))


def init(rng, *, width: float = 1.0, depth: float = 1.0):
    n_blocks = sum(_scale_d(r, depth) for _, _, _, r in _STAGES)
    ks = jax.random.split(rng, n_blocks + 3)
    stem_c = _scale_c(_STEM, width)
    params = {"stem": L.init_conv(ks[0], 3, 3, 3, stem_c), "blocks": []}
    cin, ki = stem_c, 1
    for cout, t, s, reps in _STAGES:
        cout = _scale_c(cout, width)
        for r in range(_scale_d(reps, depth)):
            stride = s if r == 0 else 1
            params["blocks"].append(L.init_inverted_residual(
                ks[ki], cin, cout, expand=t, stride=stride))
            cin, ki = cout, ki + 1
    head_c = _scale_c(_HEAD, width)
    params["head"] = L.init_conv(ks[-2], 1, 1, cin, head_c)
    params["fc"] = L.init_dense(ks[-1], head_c, NUM_CLASSES)
    return params


def apply(params, x: jnp.ndarray, ctx: L.Ctx) -> jnp.ndarray:
    y = L.relu6(L.conv2d(ctx, params["stem"], x, stride=2))
    for blk in params["blocks"]:
        y = L.inverted_residual(ctx, blk, y)
    y = L.relu6(L.conv2d(ctx, params["head"], y, pad=0))
    y = L.global_avg_pool(y)
    return L.dense(ctx, params["fc"], y)
