"""Short *real* training of every model family on the synthetic datasets.

The paper measures accuracy of each transformed variant on a validation set;
for that to be a measurement rather than an assertion, the FP32 reference
models must actually fit their task.  Each family is trained with hand-rolled
Adam (no optax on this image) on the ``ref`` implementation path (fast XLA)
— pytest separately proves ref == pallas, so the trained weights are valid
for the kernel path that gets AOT-lowered.

Trained parameters are cached to ``artifacts/params/<family>.npz`` keyed by
flattened-leaf order, so ``make artifacts`` is a no-op when nothing changed.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from .layers import Ctx
from .models import FAMILIES, Family

BATCH = 64
LR = 2e-3


def _loss_cls(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _loss_seg(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)  # [N,H,W,C]
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def _adam_init(params):
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "t": jnp.zeros((), jnp.int32)}


def _adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                       params, mhat, vhat)
    return new, {"m": m, "v": v, "t": t}


def train_family(fam: Family, *, seed: int = 0, verbose: bool = True):
    """Train one family; returns (params, final_loss)."""
    rng = jax.random.PRNGKey(seed)
    params = fam.init(rng)
    xtr, ytr, _, _ = datasets.splits(fam.task, fam.resolution)
    xtr, ytr = jnp.asarray(xtr), jnp.asarray(ytr)
    loss_fn = _loss_cls if fam.task == "cls" else _loss_seg
    ctx = Ctx(impl="ref")

    @jax.jit
    def step(params, opt, x, y):
        def obj(p):
            return loss_fn(fam.apply(p, x, ctx), y)

        loss, grads = jax.value_and_grad(obj)(params)
        params, opt = _adam_update(params, grads, opt, fam.lr)
        return params, opt, loss

    opt = _adam_init(params)
    n = xtr.shape[0]
    perm_rng = np.random.default_rng(seed)
    loss = jnp.inf
    for i in range(fam.train_steps):
        idx = perm_rng.integers(0, n, size=BATCH)
        params, opt, loss = step(params, opt, xtr[idx], ytr[idx])
        if verbose and (i % 50 == 0 or i == fam.train_steps - 1):
            print(f"  [{fam.name}] step {i:4d} loss {float(loss):.4f}", flush=True)
    return params, float(loss)


# ---------------------------------------------------------------------------
# Parameter cache (npz of array leaves, in deterministic flatten order)
# ---------------------------------------------------------------------------

def save_params(path: str, params) -> None:
    leaves = [np.asarray(l) for l in jax.tree.leaves(params)]
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez_compressed(path, *leaves)


def load_params(path: str, fam: Family):
    """Rebuild the param pytree from cache using init's structure."""
    if not os.path.exists(path):
        return None
    loaded = np.load(path)
    arrays = [jnp.asarray(loaded[k]) for k in loaded.files]
    template = fam.init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree.flatten(template)
    if len(leaves) != len(arrays):
        return None  # stale cache (architecture changed)
    for a, b in zip(leaves, arrays):
        if a.shape != b.shape:
            return None
    return jax.tree.unflatten(treedef, arrays)


def get_trained_params(fam: Family, cache_dir: str = "../artifacts/params",
                       *, seed: int = 0):
    path = os.path.join(cache_dir, f"{fam.name}.npz")
    cached = load_params(path, fam)
    if cached is not None:
        return cached
    print(f"training {fam.name} ({fam.train_steps} steps)...", flush=True)
    params, _ = train_family(fam, seed=seed)
    save_params(path, params)
    return params


def main():
    for fam in FAMILIES.values():
        get_trained_params(fam)


if __name__ == "__main__":
    main()
