"""OODIn model Transformations (paper §III-B1): T = {FP32, FP16, INT8}.

A transformation maps the reference FP32 parameter pytree to a variant
pytree: ``m <-t- m_ref``.  FP16 casts weight tensors to float16 (biases stay
f32, activations stay f32 — TFLite float16 post-training quantisation).
INT8 replaces each weight with per-output-channel symmetric int8 + scale
(TFLite dynamic-range quantisation); dequantisation happens inside the L1
kernels.  The set is extensible (the paper calls out pruning / channel
skipping) — see ``register``.
"""

from __future__ import annotations

from typing import Any, Callable

import jax.numpy as jnp

from .kernels.conv import quantize_dw_weights
from .kernels.quantized import quantize_weights
from .layers import Meta


def _is_layer(node: Any) -> bool:
    return isinstance(node, dict) and "w" in node and "meta" in node


def _map_layers(params: Any, fn: Callable[[dict], dict]) -> Any:
    """Recursively rewrite every weighted-layer dict in the pytree."""
    if _is_layer(params):
        return fn(params)
    if isinstance(params, Meta):
        return params
    if isinstance(params, dict):
        return {k: _map_layers(v, fn) for k, v in params.items()}
    if isinstance(params, list):
        return [_map_layers(v, fn) for v in params]
    if isinstance(params, tuple):
        return tuple(_map_layers(v, fn) for v in params)
    return params


def fp32(params: Any) -> Any:
    """Identity transformation (the reference model)."""
    return params


def fp16(params: Any) -> Any:
    def cast(layer: dict) -> dict:
        out = dict(layer)
        out["w"] = layer["w"].astype(jnp.float16)
        return out

    return _map_layers(params, cast)


def int8(params: Any) -> Any:
    def quant(layer: dict) -> dict:
        out = {k: v for k, v in layer.items() if k != "w"}
        if layer["w"].ndim == 3:  # depthwise [k, k, C]
            out["w_q"], out["s"] = quantize_dw_weights(layer["w"])
        else:  # GEMM [K, N]
            out["w_q"], out["s"] = quantize_weights(layer["w"])
        return out

    return _map_layers(params, quant)


TRANSFORMS: dict[str, Callable[[Any], Any]] = {
    "fp32": fp32,
    "fp16": fp16,
    "int8": int8,
}


def register(name: str, fn: Callable[[Any], Any]) -> None:
    """Extend T with a new accuracy/complexity transformation."""
    TRANSFORMS[name] = fn


def apply_transform(name: str, params: Any) -> Any:
    return TRANSFORMS[name](params)


def precision_bits(name: str) -> int:
    return {"fp32": 32, "fp16": 16, "int8": 8}[name]
