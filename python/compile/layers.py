"""Layer-2 building blocks: conv / depthwise / dense / pooling on top of the
Layer-1 kernels, with a per-layer implementation switch and cost accounting.

Every weighted layer dispatches on two axes:

* ``Ctx.impl`` — ``"pallas"`` (L1 kernels; the path that is AOT-lowered into
  the shipped HLO artifacts) or ``"ref"`` (pure-jnp oracles; used for training
  and accuracy evaluation speed).  pytest asserts the two agree.
* parameter *kind* — the OODIn transformation ``t`` applied to the weights:
  ``{"w": f32}`` (FP32), ``{"w": f16}`` (FP16) or ``{"w_q": int8, "s": f32}``
  (INT8 dynamic-range).  Dispatch is on key presence / dtype, so it is static
  at trace time and each variant lowers to its own specialised HLO module.

``Ctx.costs`` accumulates (name, flops, weight_bytes) per layer; this is how
the Table II columns (FLOPs, size) are *computed* rather than asserted.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels import matmul as kmm
from .kernels import quantized as kq
from .kernels import ref as kref

Params = dict[str, Any]


class Meta(dict):
    """Static layer metadata (kernel size, channels, stride).

    Registered as a childless pytree node so its integer values stay python
    ints (usable in trace-time control flow) instead of becoming jit tracers.
    """


jax.tree_util.register_pytree_node(
    Meta,
    lambda m: ((), tuple(sorted(m.items()))),
    lambda aux, _: Meta(aux),
)


@dataclasses.dataclass
class Ctx:
    """Forward-pass context: implementation choice + cost accumulator."""

    impl: str = "ref"  # "ref" | "pallas"
    costs: list | None = None  # [(name, flops, weight_bytes)]

    def add(self, name: str, flops: int, wbytes: int) -> None:
        if self.costs is not None:
            self.costs.append((name, int(flops), int(wbytes)))


# ---------------------------------------------------------------------------
# Parameter initialisation (GEMM weight layout: [K, N] = [kh*kw*cin, cout])
# ---------------------------------------------------------------------------

def init_conv(rng, kh: int, kw: int, cin: int, cout: int) -> Params:
    fan_in = kh * kw * cin
    w = jax.random.normal(rng, (fan_in, cout), jnp.float32)
    w = w * jnp.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((cout,), jnp.float32),
            "meta": Meta(kh=kh, kw=kw, cin=cin, cout=cout)}


def init_dw(rng, k: int, c: int) -> Params:
    w = jax.random.normal(rng, (k, k, c), jnp.float32) * jnp.sqrt(2.0 / (k * k))
    return {"w": w, "b": jnp.zeros((c,), jnp.float32),
            "meta": Meta(k=k, c=c)}


def init_dense(rng, din: int, dout: int) -> Params:
    w = jax.random.normal(rng, (din, dout), jnp.float32) * jnp.sqrt(1.0 / din)
    return {"w": w, "b": jnp.zeros((dout,), jnp.float32),
            "meta": Meta(kh=1, kw=1, cin=din, cout=dout)}


# ---------------------------------------------------------------------------
# Weight-kind helpers
# ---------------------------------------------------------------------------

def weight_bytes(p: Params) -> int:
    """Bytes of the weight tensor under its current transformation."""
    if "w_q" in p:
        return p["w_q"].size * 1 + p["s"].size * 4
    return p["w"].size * p["w"].dtype.itemsize


def _gemm(ctx: Ctx, p: Params, x2d: jnp.ndarray) -> jnp.ndarray:
    """Dispatch a [M,K]@[K,N] GEMM on (impl, weight kind)."""
    if "w_q" in p:
        if ctx.impl == "pallas":
            return kq.qmatmul(x2d, p["w_q"], p["s"])
        return kref.qmatmul_ref(x2d, p["w_q"], p["s"])
    if ctx.impl == "pallas":
        return kmm.matmul(x2d, p["w"])
    return kref.matmul_ref(x2d, p["w"])


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def conv2d(ctx: Ctx, p: Params, x: jnp.ndarray, *, stride: int = 1,
           dilation: int = 1, pad: int | None = None) -> jnp.ndarray:
    """Dense conv (im2col + L1 GEMM). x [N,H,W,Cin] -> [N,Ho,Wo,Cout]."""
    m = p["meta"]
    kh, kw, cin, cout = m["kh"], m["kw"], m["cin"], m["cout"]
    if pad is None:
        pad = kconv.same_pad(kh, dilation)
    n, h, w_, _ = x.shape
    ho = kconv.out_size(h, kh, stride, dilation, pad)
    wo = kconv.out_size(w_, kw, stride, dilation, pad)
    ctx.add(f"conv{kh}x{kw}", 2 * n * ho * wo * kh * kw * cin * cout,
            weight_bytes(p))

    if kh == kw == 1 and stride == 1 and pad == 0:
        cols = x.reshape(n * h * w_, cin)
    else:
        cols = kconv.im2col(x, kh, kw, stride, dilation, pad)
        cols = cols.reshape(n * ho * wo, kh * kw * cin)
    y = _gemm(ctx, p, cols).reshape(n, ho, wo, cout)
    return y + p["b"]


def depthwise(ctx: Ctx, p: Params, x: jnp.ndarray, *, stride: int = 1) -> jnp.ndarray:
    """Depthwise conv (L1 VPU-shaped kernel). x [N,H,W,C] -> [N,Ho,Wo,C]."""
    m = p["meta"]
    k, c = m["k"], m["c"]
    n, h, w_, _ = x.shape
    pad = kconv.same_pad(k)
    ho = kconv.out_size(h, k, stride, 1, pad)
    wo = kconv.out_size(w_, k, stride, 1, pad)
    ctx.add(f"dw{k}x{k}", 2 * n * ho * wo * k * k * c, weight_bytes(p))

    if "w_q" in p:
        if ctx.impl == "pallas":
            y = kconv.qdepthwise(x, p["w_q"], p["s"], stride=stride)
        else:
            y = kref.qdepthwise_ref(x, p["w_q"], p["s"], stride=stride)
    elif ctx.impl == "pallas":
        y = kconv.depthwise(x, p["w"], stride=stride)
    else:
        y = kref.depthwise_ref(x, p["w"], stride=stride)
    return y + p["b"]


def dense(ctx: Ctx, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Fully-connected head. x [N, Din] -> [N, Dout]."""
    m = p["meta"]
    ctx.add("dense", 2 * x.shape[0] * m["cin"] * m["cout"], weight_bytes(p))
    return _gemm(ctx, p, x) + p["b"]


def relu6(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(x, 0.0, 6.0)


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    """[N,H,W,C] -> [N,C]."""
    return jnp.mean(x, axis=(1, 2))


def avg_pool_3x3(x: jnp.ndarray) -> jnp.ndarray:
    """3x3 stride-1 SAME average pool (Inception pool branch)."""
    s = jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 3, 3, 1), (1, 1, 1, 1),
                              "SAME")
    cnt = jax.lax.reduce_window(jnp.ones_like(x), 0.0, jax.lax.add,
                                (1, 3, 3, 1), (1, 1, 1, 1), "SAME")
    return s / cnt


def resize_bilinear(x: jnp.ndarray, h: int, w: int) -> jnp.ndarray:
    """[N,h0,w0,C] -> [N,h,w,C] (DeepLab upsampling head)."""
    n, _, _, c = x.shape
    return jax.image.resize(x, (n, h, w, c), method="bilinear")


# ---------------------------------------------------------------------------
# Composite blocks
# ---------------------------------------------------------------------------

def init_inverted_residual(rng, cin: int, cout: int, *, expand: int,
                           stride: int) -> Params:
    """MobileNetV2 inverted-residual block parameters."""
    r1, r2, r3 = jax.random.split(rng, 3)
    mid = cin * expand
    return {
        "expand": init_conv(r1, 1, 1, cin, mid) if expand != 1 else None,
        "dw": init_dw(r2, 3, mid),
        "project": init_conv(r3, 1, 1, mid, cout),
        "meta": Meta(cin=cin, cout=cout, stride=stride, expand=expand),
    }


def inverted_residual(ctx: Ctx, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    m = p["meta"]
    y = x
    if p["expand"] is not None:
        y = relu6(conv2d(ctx, p["expand"], y, pad=0))
    y = relu6(depthwise(ctx, p["dw"], y, stride=m["stride"]))
    y = conv2d(ctx, p["project"], y, pad=0)
    if m["stride"] == 1 and m["cin"] == m["cout"]:
        y = y + x
    return y
