"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal.

The inference path has two interchangeable implementations selected by
``layers.Ctx.impl``: ``"pallas"`` (the L1 kernels, used for AOT lowering) and
``"ref"`` (these oracles, used for training/eval speed).  pytest +
hypothesis assert they agree to float tolerance across shape/dtype sweeps,
which is what licenses training and accuracy evaluation to run on the ref
path while the shipped artifacts run the kernel path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x [M,K] @ w [K,N] (w possibly f16), f32 accumulate."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def qmatmul_ref(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Dequantise-then-matmul oracle for the INT8 GEMM."""
    return jnp.dot(x.astype(jnp.float32), w_q.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale[None, :]


def depthwise_ref(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1,
                  pad: int | None = None) -> jnp.ndarray:
    """Depthwise conv oracle via lax.conv with feature_group_count=C."""
    kh, kw, c = w.shape
    if pad is None:
        pad = (kh - 1) // 2
    # HWIO with I=1 per group.
    w4 = w.astype(jnp.float32).reshape(kh, kw, 1, c)
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w4,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def qdepthwise_ref(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray, *,
                   stride: int = 1, pad: int | None = None) -> jnp.ndarray:
    return depthwise_ref(x, w_q.astype(jnp.float32) * scale[None, None, :],
                         stride=stride, pad=pad)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, *, kh: int, kw: int,
               stride: int = 1, dilation: int = 1, pad: int = 0) -> jnp.ndarray:
    """Dense conv oracle. ``w`` is in GEMM layout [kh*kw*cin, cout]."""
    cin = x.shape[-1]
    cout = w.shape[-1]
    w4 = w.astype(jnp.float32).reshape(kh, kw, cin, cout)
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w4,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
