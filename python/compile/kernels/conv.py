"""Layer-1 convolution kernels: im2col lowering + Pallas depthwise conv.

Standard convolutions (dense, pointwise and atrous/dilated) are lowered to
im2col followed by the tiled Pallas GEMM of ``matmul.py`` / ``quantized.py``
— the MXU-shaped restatement of TFLite's NEON/OpenCL conv kernels (see
DESIGN.md §Hardware-Adaptation).

Depthwise convolution (the workhorse of MobileNetV2 / EfficientNet-Lite) has
no GEMM reuse, so it gets a dedicated VPU-shaped Pallas kernel: an unrolled
(kh x kw) shifted multiply-accumulate over the whole channel vector, which is
exactly the memory-bound elementwise-MAC structure it has on phones.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import INTERPRET


def out_size(size: int, k: int, stride: int, dilation: int, pad: int) -> int:
    eff = (k - 1) * dilation + 1
    return (size + 2 * pad - eff) // stride + 1


def same_pad(k: int, dilation: int = 1) -> int:
    """Padding that keeps spatial size at stride 1 ('SAME' for odd kernels)."""
    return ((k - 1) * dilation) // 2


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1,
           dilation: int = 1, pad: int = 0) -> jnp.ndarray:
    """Extract conv patches: [N, H, W, C] -> [N, Ho, Wo, kh*kw*C].

    Patch channel order is (dy, dx, c) — matching an HWIO weight reshaped to
    [kh*kw*C, Cout], so ``im2col(x) @ w.reshape(-1, cout)`` == conv(x, w).
    """
    n, h, w, c = x.shape
    ho = out_size(h, kh, stride, dilation, pad)
    wo = out_size(w, kw, stride, dilation, pad)
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            y0, x0 = dy * dilation, dx * dilation
            cols.append(x[:, y0:y0 + (ho - 1) * stride + 1:stride,
                          x0:x0 + (wo - 1) * stride + 1:stride, :])
    return jnp.concatenate(cols, axis=-1)


def _dw_kernel(kh: int, kw: int, stride: int, ho: int, wo: int,
               x_ref, w_ref, o_ref):
    """Depthwise conv over one image: unrolled shifted MAC (VPU-shaped)."""
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            window = x_ref[dy:dy + (ho - 1) * stride + 1:stride,
                           dx:dx + (wo - 1) * stride + 1:stride, :]
            acc += window * w_ref[dy, dx, :].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("stride", "pad"))
def depthwise(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1,
              pad: int | None = None) -> jnp.ndarray:
    """Depthwise conv, [N, H, W, C] * [kh, kw, C] -> [N, Ho, Wo, C].

    ``w`` may be f32 or f16 (converted at the MAC input).
    """
    n, h, width, c = x.shape
    kh, kw, c2 = w.shape
    assert c == c2
    if pad is None:
        pad = same_pad(kh)
    ho = out_size(h, kh, stride, 1, pad)
    wo = out_size(width, kw, stride, 1, pad)
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (pad, pad), (pad, pad), (0, 0)))

    call = pl.pallas_call(
        functools.partial(_dw_kernel, kh, kw, stride, ho, wo),
        out_shape=jax.ShapeDtypeStruct((ho, wo, c), jnp.float32),
        interpret=INTERPRET,
    )
    return jax.vmap(call, in_axes=(0, None))(xp, w)


def _qdw_kernel(kh: int, kw: int, stride: int, ho: int, wo: int,
                x_ref, w_ref, s_ref, o_ref):
    """INT8 depthwise: int8 taps dequantised per channel at the MAC input."""
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            window = x_ref[dy:dy + (ho - 1) * stride + 1:stride,
                           dx:dx + (wo - 1) * stride + 1:stride, :]
            acc += window * w_ref[dy, dx, :].astype(jnp.float32)
    o_ref[...] = acc * s_ref[...][None, None, :]


def quantize_dw_weights(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-channel symmetric int8 for a depthwise [kh, kw, C] weight."""
    amax = jnp.max(jnp.abs(w), axis=(0, 1))  # [C]
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    w_q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return w_q, scale


@functools.partial(jax.jit, static_argnames=("stride", "pad"))
def qdepthwise(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray, *,
               stride: int = 1, pad: int | None = None) -> jnp.ndarray:
    """INT8 depthwise conv (per-channel dequant in kernel)."""
    n, h, width, c = x.shape
    kh, kw, c2 = w_q.shape
    assert c == c2 and scale.shape == (c,)
    if pad is None:
        pad = same_pad(kh)
    ho = out_size(h, kh, stride, 1, pad)
    wo = out_size(width, kw, stride, 1, pad)
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (pad, pad), (pad, pad), (0, 0)))

    call = pl.pallas_call(
        functools.partial(_qdw_kernel, kh, kw, stride, ho, wo),
        out_shape=jax.ShapeDtypeStruct((ho, wo, c), jnp.float32),
        interpret=INTERPRET,
    )
    return jax.vmap(call, in_axes=(0, None, None))(xp, w_q, scale)
