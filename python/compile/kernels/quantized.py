"""Layer-1 Pallas INT8 dynamic-range-quantised GEMM.

This is OODIn's INT8 transformation (paper §III-B1, T = {FP32, FP16, INT8})
executed TFLite-dynamic-range style: weights are stored as per-output-channel
symmetric int8, activations stay float, and dequantisation happens inside the
kernel at the MXU input.  The int8 weight tile halves (vs f16) or quarters
(vs f32) the VMEM traffic of the weight operand — the same reason the paper's
INT8 variants win on memory-bound mobile engines.

Quantisation helpers (``quantize_weights``) live here too so python tests can
round-trip: ``qmatmul(x, *quantize_weights(w))  ≈  x @ w``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import INTERPRET, _ceil_to, _pad2, pick_blocks


def quantize_weights(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantisation of a [K, N] GEMM weight
    (classic TFLite dynamic-range).  The kernel interface is per-channel
    (scale [N]), so the per-tensor scale is broadcast — per-channel
    quantisation (``quantize_weights_per_channel``) drops in unchanged.

    Returns (w_q int8 [K, N], scale f32 [N]) with w ≈ w_q * scale.
    """
    amax = jnp.max(jnp.abs(w))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    w_q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return w_q, jnp.full((w.shape[1],), scale, jnp.float32)


def quantize_weights_per_channel(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-output-channel symmetric int8 (the higher-accuracy variant)."""
    amax = jnp.max(jnp.abs(w), axis=0)  # [N]
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    w_q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return w_q, scale


def _qmatmul_kernel(nk: int, x_ref, w_ref, s_ref, o_ref):
    """Accumulate raw (float x) @ (dequantised int8 w); scale on last K step.

    The scale is folded once per output tile rather than per K step: the
    accumulator holds x @ w_q (in f32) and is multiplied by the per-channel
    scale only when the final K tile has been folded in.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _scale():
        o_ref[...] = o_ref[...] * s_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n"))
def qmatmul(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray, *,
            block_m: int | None = None, block_k: int | None = None,
            block_n: int | None = None) -> jnp.ndarray:
    """``x @ (w_q * scale)`` without materialising the dequantised weight.

    Shapes: x [M, K] f32, w_q [K, N] int8, scale [N] f32 -> [M, N] f32.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2 and scale.shape == (n,)
    bm, bk, bn = pick_blocks(m, k, n)
    bm, bk, bn = block_m or bm, block_k or bk, block_n or bn
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad2(x.astype(jnp.float32), mp, kp)
    wp = _pad2(w_q, kp, np_)
    sp = jnp.pad(scale, (0, np_ - n))
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_qmatmul_kernel, nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(xp, wp, sp)
    return out[:m, :n]
