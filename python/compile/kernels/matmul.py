"""Layer-1 Pallas tiled GEMM kernels (FP32 and FP16-weight variants).

These are the inference hot-spot of every model in the zoo: all convolutions
are lowered to im2col + GEMM (see ``conv.py``), and the classifier head is a
GEMM.  The kernels are written TPU-style — a 3-D grid over (M, N, K) tiles
with the K dimension innermost so each (i, j) output tile is accumulated in
place across K steps — and BlockSpecs that express the HBM->VMEM staging
schedule.  On this testbed they are lowered with ``interpret=True`` so the
resulting HLO runs on the CPU PJRT client (see DESIGN.md §Hardware-Adaptation).

Block-size policy (``pick_blocks``): MXU-friendly tiles capped at 128 lanes /
64 sublanes, shrunk to the actual (padded) problem so tiny layers do not pay
for padding.  VMEM footprint per step is bm*bk + bk*bn + bm*bn floats, kept
well under the ~16 MB VMEM budget of a real TPU core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Interpret mode is mandatory on this image: real-TPU lowering emits a Mosaic
# custom-call that the CPU PJRT plugin cannot execute.
INTERPRET = True


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Choose (bm, bk, bn) tile sizes for an (M, K) x (K, N) GEMM.

    Tiles are MXU-shaped (sublane multiples of 8, lane multiples of 128) but
    sized GENEROUSLY: each interpret-mode grid step lowers to an XLA
    while-loop iteration (dynamic-slice + dot + update), so small tiles
    turn one GEMM into hundreds of loop iterations on the CPU path.
    (512, 576, 256) keeps the per-step VMEM footprint at ~2.3 MB — well
    under a TPU core's ~16 MB — while collapsing the zoo's conv GEMMs to
    single-digit grid sizes (EXPERIMENTS.md §Perf iteration 1).
    """
    bm = min(512, _ceil_to(m, 8))
    bk = min(576, _ceil_to(k, 8))
    bn = min(256, _ceil_to(n, 8))
    return bm, bk, bn


def _pad2(x: jnp.ndarray, r: int, c: int) -> jnp.ndarray:
    pr, pc = r - x.shape[0], c - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid (i, j, k); K innermost. Zero-init on k==0, accumulate after."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # FP16 weights are converted at the MXU input; accumulation stays f32.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n"))
def matmul(x: jnp.ndarray, w: jnp.ndarray, *, block_m: int | None = None,
           block_k: int | None = None, block_n: int | None = None) -> jnp.ndarray:
    """``x @ w`` with f32 accumulation. ``w`` may be f32 or f16.

    Shapes: x [M, K], w [K, N] -> [M, N] (f32).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bk, bn = pick_blocks(m, k, n)
    bm, bk, bn = block_m or bm, block_k or bk, block_n or bn
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad2(x.astype(jnp.float32), mp, kp)
    wp = _pad2(w, kp, np_)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(xp, wp)
    return out[:m, :n]


def vmem_bytes(bm: int, bk: int, bn: int, w_bytes: int = 4) -> int:
    """Per-grid-step VMEM footprint estimate for the GEMM kernel."""
    return bm * bk * 4 + bk * bn * w_bytes + bm * bn * 4
