"""Accuracy Evaluation (paper Fig 1, offline component).

Measures top-1 accuracy (classification) or mean IoU (segmentation) of every
(family, transformation) variant on the held-out synthetic validation split.
Runs on the ``ref`` implementation path for speed; ref == pallas is enforced
by pytest, so these numbers are the accuracy of the shipped artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from .layers import Ctx
from .models import Family

EVAL_BATCH = 100


def top1(fam: Family, params, x: np.ndarray, y: np.ndarray) -> float:
    ctx = Ctx(impl="ref")
    apply = jax.jit(lambda p, xb: jnp.argmax(fam.apply(p, xb, ctx), axis=-1))
    correct = 0
    for i in range(0, len(x), EVAL_BATCH):
        xb = jnp.asarray(x[i:i + EVAL_BATCH])
        pred = np.asarray(apply(params, xb))
        correct += int((pred == y[i:i + EVAL_BATCH]).sum())
    return correct / len(x)


def miou(fam: Family, params, x: np.ndarray, y: np.ndarray,
         n_classes: int = datasets.NUM_SEG_CLASSES) -> float:
    ctx = Ctx(impl="ref")
    apply = jax.jit(lambda p, xb: jnp.argmax(fam.apply(p, xb, ctx), axis=-1))
    inter = np.zeros(n_classes)
    union = np.zeros(n_classes)
    for i in range(0, len(x), EVAL_BATCH):
        xb = jnp.asarray(x[i:i + EVAL_BATCH])
        pred = np.asarray(apply(params, xb))
        gt = y[i:i + EVAL_BATCH]
        for c in range(n_classes):
            inter[c] += np.logical_and(pred == c, gt == c).sum()
            union[c] += np.logical_or(pred == c, gt == c).sum()
    ious = inter[union > 0] / union[union > 0]
    return float(ious.mean())


def evaluate(fam: Family, params) -> float:
    """Task-appropriate accuracy metric on the held-out split."""
    _, _, xte, yte = datasets.splits(fam.task, fam.resolution)
    if fam.task == "cls":
        return top1(fam, params, xte, yte)
    return miou(fam, params, xte, yte)
