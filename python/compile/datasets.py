"""Synthetic datasets standing in for ImageNet / PASCAL VOC (see DESIGN.md
§Substitutions).

Classification ("imagenet stand-in"): 10 classes.  Class ``k`` places a
Gaussian blob at angle 2πk/10 on a ring, with a class-dependent dominant
colour channel, plus distractor blobs and heavy additive noise — hard enough
that model capacity and weight precision measurably move top-1 accuracy.

Segmentation ("VOC stand-in"): 5 classes (background + 4 shape types:
square / disk / horizontal bar / vertical bar).  1–3 shapes per image; the
mask labels each shape's pixels with its class.

Both are generated deterministically from a seed so every build measures the
same accuracy numbers.
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 10
NUM_SEG_CLASSES = 5


def _blob(h: int, w: int, cy: float, cx: float, sigma: float) -> np.ndarray:
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    return np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2.0 * sigma**2)))


def make_classification(n: int, res: int, *, seed: int = 0,
                        noise: float = 0.95) -> tuple[np.ndarray, np.ndarray]:
    """Returns (x [n,res,res,3] f32, y [n] int32)."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, res, res, 3), np.float32)
    y = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    c0, r0 = res / 2.0, res * 0.30
    for i in range(n):
        k = int(y[i])
        ang = 2.0 * np.pi * k / NUM_CLASSES
        cy = c0 + r0 * np.sin(ang) + rng.normal(0, res * 0.03)
        cx = c0 + r0 * np.cos(ang) + rng.normal(0, res * 0.03)
        blob = _blob(res, res, cy, cx, res * 0.10)
        img = np.zeros((res, res, 3), np.float32)
        dom = k % 3
        img[:, :, dom] += 1.5 * blob
        img[:, :, (dom + 1) % 3] += 0.5 * blob
        # Distractor blobs at random positions with random colours — force
        # the model to use geometry (ring angle), not just colour energy.
        for _ in range(2):
            dy, dx = rng.uniform(0, res, size=2)
            col = rng.uniform(0.4, 1.2, size=3).astype(np.float32)
            img += _blob(res, res, dy, dx, res * 0.09)[:, :, None] * col
        img += rng.normal(0, noise, size=img.shape).astype(np.float32)
        x[i] = img
    return x, y


def make_segmentation(n: int, res: int, *, seed: int = 0,
                      noise: float = 0.35) -> tuple[np.ndarray, np.ndarray]:
    """Returns (x [n,res,res,3] f32, mask [n,res,res] int32 in [0,5))."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, noise, size=(n, res, res, 3)).astype(np.float32)
    masks = np.zeros((n, res, res), np.int32)
    yy, xx = np.mgrid[0:res, 0:res]
    for i in range(n):
        for _ in range(int(rng.integers(1, 4))):
            cls = int(rng.integers(1, NUM_SEG_CLASSES))
            cy, cx = rng.uniform(res * 0.2, res * 0.8, size=2)
            s = rng.uniform(res * 0.10, res * 0.22)
            if cls == 1:      # square
                region = (np.abs(yy - cy) < s) & (np.abs(xx - cx) < s)
            elif cls == 2:    # disk
                region = (yy - cy) ** 2 + (xx - cx) ** 2 < s**2
            elif cls == 3:    # horizontal bar
                region = (np.abs(yy - cy) < s * 0.35) & (np.abs(xx - cx) < s * 1.6)
            else:             # vertical bar
                region = (np.abs(xx - cx) < s * 0.35) & (np.abs(yy - cy) < s * 1.6)
            masks[i][region] = cls
            x[i][region] += np.array(
                [1.0 + 0.3 * cls, 2.0 - 0.3 * cls, 0.8], np.float32)
    return x, masks


def splits(task: str, res: int, *, n_train: int = 3000, n_test: int = 1000,
           seed: int = 7):
    """(x_train, y_train, x_test, y_test) for a task at a resolution."""
    gen = make_classification if task == "cls" else make_segmentation
    xtr, ytr = gen(n_train, res, seed=seed)
    xte, yte = gen(n_test, res, seed=seed + 1)
    return xtr, ytr, xte, yte
