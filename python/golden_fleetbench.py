#!/usr/bin/env python3
"""Independent oracle for the fleet-bench golden snapshot.

Re-implements, in plain Python, every deterministic component behind
``rust/tests/golden/fleetbench_smoke.json`` — the ``oodin fleet-bench
--smoke`` payload: SplitMix64 population sampling, archetype perturbation
along the five heterogeneity axes (+ hidden per-engine latent efficiency),
zero-noise Measurer LUTs, cross-device roofline-ratio LUT transfer with
confidence-gated probe fallback, cohort grouping with shared
frontier-cache accounting, the RuntimeManager decide() state machine under
the scripted condition storm, regret against the full-profile oracle, and
the JSON emission of ``util::json::to_string``.

Why this exists: the golden snapshot must be producible *without* running
the Rust binary (the authoring container has no Rust toolchain), and it
doubles as an N-version check — Rust and Python implementations of the
same spec must agree byte-for-byte (the same convention as
``golden_optbench.py`` and ``golden_serve_bench.py``).

Exactness notes: with measurement noise at 0 every latency is IEEE-754
double arithmetic mirrored here in the same operation order.  Storm loads
sit on conditions-bucket centres (exact powers of two), so bucketed and
exact conditions coincide.  Where the Rust side walks a cached Pareto
frontier this oracle runs the full enumerative search at the bucket's
representative conditions — the design-space layer's exactness theorem
(property-tested in `tests/designspace_props.rs`, re-asserted per event by
the Rust driver) guarantees both pick the same design.

Beyond the report JSON this oracle also regenerates the decision
flight-recorder trace (``rust/tests/golden/fleetbench_smoke_trace.jsonl``)
— the ``oodin fleet-bench --smoke --trace`` JSON-lines payload: cohort
transfer provenance and probe fallbacks at virtual t=0, one frontier
cache event per shared-cache lookup, one hold-or-switch event per
``decide()`` (switches with their ``explain`` records), the per-cohort
``frontier_delta`` + fleet ``correction`` aggregate for the post-storm
CPU correction, and the post-correction warm-hit round — in the same
emission order and with the same pinned key order as
``telemetry::trace``.

PR 8 extends both payloads with the fleet control-plane scenario
(``experiments::fleetbench::run_control_plane``): a pre-canary baseline
sweep, the mispredicted revision canaried and auto-rolled-back, the good
revision widened up the ladder to fleet-wide promotion, three residual
feedback rounds folded through the incremental delta path, threshold
re-anchoring, and a closing regret sweep — every frontier-cache lookup,
``rollout`` / ``residual`` / ``re_anchor`` transition and per-cohort
``frontier_delta`` mirrored in the same order on the storm's continued
virtual clock, plus the report's ``rollout`` and ``feedback`` blocks.

Usage:  python3 python/golden_fleetbench.py [--check]
  default: writes both golden files
  --check: compares against the existing files, exit 1 on drift
"""

import json
import math
import os
import sys

# --------------------------------------------------------------------------
# util::rng::Rng (SplitMix64)
# --------------------------------------------------------------------------

M64 = (1 << 64) - 1
GOLDEN_GAMMA = 0x9E3779B97F4A7C15


class Rng:
    def __init__(self, seed):
        self.state = (seed + GOLDEN_GAMMA) & M64

    def next_u64(self):
        self.state = (self.state + GOLDEN_GAMMA) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return z ^ (z >> 31)

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def range(self, lo, hi):
        return lo + self.f64() * (hi - lo)

    def below(self, n):
        return self.next_u64() % n


def device_seed(seed, index):
    """fleet::population::device_seed — FNV-1a over seed + index bytes."""
    h = 0xCBF29CE484222325
    data = seed.to_bytes(8, "little") + index.to_bytes(8, "little")
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & M64
    return h


def rust_round(x):
    """f64::round: half away from zero (positive inputs here)."""
    f = math.floor(x)
    return int(f) if x - f < 0.5 else int(f) + 1


def r3(x):
    return rust_round(x * 1000.0) / 1000.0


# --------------------------------------------------------------------------
# Device archetypes (device/profiles.rs) and model fixture
# (model::test_fixtures::fake_registry) — as in golden_optbench.py.
# --------------------------------------------------------------------------

GOV_ORDER = ["performance", "schedutil", "energy_step"]
FREQ_SCALE = {"performance": 1.0, "schedutil": 0.94, "energy_step": 0.78}
HEAT_FACTOR = {"performance": 1.0, "schedutil": 0.85, "energy_step": 0.58}
ENGINE_ORDER = ["cpu", "gpu", "nnapi"]
ARCHETYPES = ["sony_c5", "samsung_a71", "samsung_s20_fe"]


def engine(kind, peak, fp16, int8, bw, dispatch, parallel, heat):
    return dict(kind=kind, peak=peak, fp16=fp16, int8=int8, bw=bw,
                dispatch=dispatch, parallel=parallel, heat=heat)


BASE_DEVICES = {
    "sony_c5": dict(
        engines=[
            engine("cpu", 6.0, 0.85, 1.8, 2.5, 0.004, 0.80, 1.05),
            engine("gpu", 9.0, 1.7, 0.9, 3.5, 0.080, 0.0, 0.90),
        ],
        n_cores=8,
        mem_budget=4 * 1024 * 1024,
        governors=["performance", "schedutil"],
        max_deployable=8.0,
    ),
    "samsung_a71": dict(
        engines=[
            engine("cpu", 14.0, 0.95, 2.2, 8.0, 0.002, 0.85, 0.08),
            engine("gpu", 22.0, 1.9, 1.3, 11.0, 0.012, 0.0, 0.25),
            engine("nnapi", 16.0, 1.4, 4.0625, 9.0, 0.018, 0.0, 0.30),
        ],
        n_cores=8,
        mem_budget=12 * 1024 * 1024,
        governors=["energy_step", "performance", "schedutil"],
        max_deployable=25.0,
    ),
    "samsung_s20_fe": dict(
        engines=[
            engine("cpu", 30.0, 1.0, 2.5, 16.0, 0.0015, 0.85, 0.48),
            engine("gpu", 60.0, 1.9, 1.4, 22.0, 0.018, 0.0, 0.42),
            engine("nnapi", 20.0, 1.6, 7.5, 14.0, 0.030, 0.0, 0.66),
        ],
        n_cores=8,
        mem_budget=12 * 1024 * 1024,
        governors=["energy_step", "performance", "schedutil"],
        max_deployable=25.0,
    ),
}

NPU_PENALTY = {
    ("samsung_a71", "efficientnet_lite4"): 3.0,
    ("samsung_a71", "deeplab_v3"): 12.0,
    ("samsung_a71", "resnet_v2"): 1.8,
    ("samsung_s20_fe", "efficientnet_lite4"): 1.5,
    ("samsung_s20_fe", "deeplab_v3"): 110.0,
    ("samsung_s20_fe", "inception_v3"): 4.0,
    ("samsung_s20_fe", "resnet_v2"): 3.0,
}

FAMS = [
    ("mobilenet_v2_100", "cls", 24, 4_000_000),
    ("efficientnet_lite4", "cls", 32, 40_000_000),
    ("inception_v3", "cls", 32, 90_000_000),
    ("deeplab_v3", "seg", 48, 50_000_000),
]
PRECS = [("fp32", 32, 0.90), ("fp16", 16, 0.899), ("int8", 8, 0.885)]


def variants():
    out = {}
    for fam, task, res, flops in FAMS:
        for prec, bits, acc in PRECS:
            name = f"{fam}__{prec}__b1"
            in_elems = res * res * 3
            out_elems = 10 if task == "cls" else res * res * 5
            size = 400_000 * bits // 32
            io = max(in_elems, out_elems) * 4
            out[name] = dict(
                name=name, family=fam, prec=prec, flops=flops, size=size,
                acc=acc, in_elems=in_elems, out_elems=out_elems,
                mem=size + in_elems * 4 + io * 2,
            )
    return out


VARIANTS = variants()
# Registry order (manifest order): families × precisions.
VARIANT_ORDER = [f"{fam}__{prec}__b1" for fam, _, _, _ in FAMS
                 for prec, _, _ in PRECS]
A_REF = 0.90


# --------------------------------------------------------------------------
# Roofline latency (perf::latency_ms) parametrised over synthesized devices.
# --------------------------------------------------------------------------


def thread_speedup(parallel, threads):
    if threads <= 1:
        return 1.0
    return 1.0 / ((1.0 - parallel) + parallel / float(threads))


def spec_of(dev, kind):
    for s in dev["engines"]:
        if s["kind"] == kind:
            return s
    return None


def roofline_ms(dev, kind, vname, threads, governor):
    """perf::latency_ms at nominal (idle, cool) conditions."""
    spec = spec_of(dev, kind)
    if spec is None:
        return None
    v = VARIANTS[vname]
    threads = max(min(threads, dev["n_cores"]), 1)
    if spec["kind"] == "cpu":
        allc = thread_speedup(spec["parallel"], dev["n_cores"])
        base = spec["peak"] / allc * thread_speedup(spec["parallel"], threads)
    else:
        base = spec["peak"]
    penalty = (NPU_PENALTY.get((dev["archetype"], v["family"]), 1.0)
               if spec["kind"] == "nnapi" else 1.0)
    pm = {"fp32": 1.0, "fp16": spec["fp16"], "int8": spec["int8"]}[v["prec"]]
    gflops = base * pm * FREQ_SCALE[governor] * 1.0 / penalty
    compute = (float(v["flops"]) * 1.0) / (gflops * 1e6)
    act = (v["in_elems"] + v["out_elems"]) * 4
    memory = (float(v["size"]) + float(act)) / (spec["bw"] * 1e6)
    roof = max(compute, memory)
    return (spec["dispatch"] + roof) * 1.0  # contention(0) == 1.0


def avg_of_identical(base, runs):
    """LatencyStats::from_samples mean over `runs` identical samples."""
    total = 0.0
    for _ in range(runs):
        total += base
    return total / float(runs)


def thread_candidates(n_cores):
    t = [1]
    v = 2
    while v < n_cores:
        t.append(v)
        v *= 2
    if n_cores > 1:
        t.append(n_cores)
    return t


def lut_keys(dev):
    """Every (variant, engine, threads, governor) the Measurer sweeps."""
    keys = []
    for spec in dev["engines"]:
        threads = (thread_candidates(dev["n_cores"])
                   if spec["kind"] == "cpu" else [1])
        for vname in VARIANT_ORDER:
            for t in threads:
                for g in dev["governors"]:
                    keys.append((vname, spec["kind"], t, g))
    return keys


def key_sort(key):
    v, e, t, g = key
    return (v, ENGINE_ORDER.index(e), t, GOV_ORDER.index(g))


def build_lut(dev, runs):
    """Zero-noise Measurer sweep: (variant, engine, threads, gov) -> avg."""
    lut = {}
    for key in lut_keys(dev):
        vname, kind, t, g = key
        lut[key] = avg_of_identical(roofline_ms(dev, kind, vname, t, g), runs)
    return lut


# --------------------------------------------------------------------------
# fleet::population — sampling and cohorts.
# --------------------------------------------------------------------------

CFG = dict(
    size=200, seed=77,
    flops_log_spread=0.30, bw_log_spread=0.15, thermal_log_spread=0.20,
    mem_log_spread=0.15, latent_log_spread=0.10, npu_drop_prob=0.15,
    confidence_threshold=0.72, probe_runs=4, probes_per_engine=2,
    lut_runs=4, frontier_cache_cap=256,
    frontier_mem_budget_bytes=8 * 1024 * 1024,
    family="mobilenet_v2_100", eps=0.05,
    ticks=12, tick_ms=250.0, regret_ticks=[1, 4, 8, 11],
)
RATES = [1.0, 0.5, 0.25]
CAMERA_FPS = 30.0
BUCKET_LOG2_STEP = 0.5
# designspace::frontier resident-byte accounting constants.
FRONTIER_BASE_BYTES = 256
FRONTIER_POINT_BYTES = 192
# experiments::fleetbench post-storm correction + cost-model constants.
CORRECTION_ENGINE = "cpu"
CORRECTION_FACTOR = 1.25
SIM_NS_PER_EVAL = 150
# experiments::fleetbench control-plane scenario constants + the
# fleet::rollout / fleet::feedback default thresholds.
ROLLOUT_ENGINE = "cpu"
ROLLOUT_BAD_FACTOR = 0.25
ROLLOUT_GOOD_FACTOR = 0.8
ROLLOUT_SLO_MS = 1000.0 / 30.0
FEEDBACK_ROUNDS = 3
ROLLOUT_LADDER = [4, 7, 14]
ROLLOUT_MIN_SAMPLES = 2
MAX_REGRET_DELTA_PCT = 2.0
MAX_ABS_REGRET_PCT = 5.0
MAX_SLO_MISS_DELTA = 0.1
MAX_FAULT_DELTA = 0.0
FB_MIN_SAMPLES = 2
RE_ANCHOR_THRESHOLD = 0.15
# experiments::fleetbench storm burn-rate monitor constants
# (telemetry::SloBurnMonitor over the per-cohort `regret_pct` rollups).
BURN_SLO_REGRET_PCT = 5.0
BURN_BUDGET = 0.25
BURN_MIN_SAMPLES = 4
# telemetry::histogram::LogHistogram bucket grid (count_above's unit).
HIST_MIN_EXP = -20
HIST_MAX_EXP = 30
HIST_SUB = 16
HIST_BUCKETS = (HIST_MAX_EXP - HIST_MIN_EXP) * HIST_SUB + 2


def bucket_index(v):
    """telemetry::histogram::bucket_index — the log2 sub-bucket grid."""
    if not (v >= 2.0 ** HIST_MIN_EXP):
        return 0
    l2 = math.log2(v)
    if l2 >= HIST_MAX_EXP:
        return HIST_BUCKETS - 1
    grid = int((l2 - HIST_MIN_EXP) * HIST_SUB)
    return 1 + min(grid, HIST_BUCKETS - 3)


def scaled_device(archetype, axes, thermal_ln, mem_ln, latent):
    base = BASE_DEVICES[archetype]
    engines = []
    for kind, f, b, lat in axes:
        spec = dict(spec_of(base, kind))
        spec["peak"] = spec["peak"] * math.exp(f)
        spec["bw"] = spec["bw"] * math.exp(b)
        if latent:
            spec["peak"] = spec["peak"] * math.exp(lat)
            spec["bw"] = spec["bw"] * math.exp(lat)
        spec["heat"] = spec["heat"] * math.exp(-thermal_ln)
        engines.append(spec)
    return dict(
        archetype=archetype,
        engines=engines,
        n_cores=base["n_cores"],
        mem_budget=int(base["mem_budget"] * math.exp(mem_ln)),
        governors=base["governors"],
        max_deployable=base["max_deployable"],
    )


def sample_device(idx):
    rng = Rng(device_seed(CFG["seed"], idx))
    archetype = ARCHETYPES[rng.below(3)]
    base = BASE_DEVICES[archetype]
    drop = rng.f64() < CFG["npu_drop_prob"]
    axes = []
    dropped = False
    for spec in base["engines"]:
        f = rng.range(-CFG["flops_log_spread"], CFG["flops_log_spread"])
        b = rng.range(-CFG["bw_log_spread"], CFG["bw_log_spread"])
        lat = rng.range(-CFG["latent_log_spread"], CFG["latent_log_spread"])
        if spec["kind"] == "nnapi" and drop:
            dropped = True
            continue
        axes.append((spec["kind"], f, b, lat))
    thermal_ln = rng.range(-CFG["thermal_log_spread"],
                           CFG["thermal_log_spread"])
    mem_ln = rng.range(-CFG["mem_log_spread"], CFG["mem_log_spread"])
    return dict(
        idx=idx,
        archetype=archetype,
        axes=axes,
        dropped=dropped,
        nominal=scaled_device(archetype, axes, thermal_ln, mem_ln, False),
        true=scaled_device(archetype, axes, thermal_ln, mem_ln, True),
    )


def cohort_key(d):
    return (d["archetype"],
            tuple(ENGINE_ORDER.index(k) for k, _, _, _ in d["axes"]),
            tuple(f >= 0.0 for _, f, _, _ in d["axes"]))


def cohort_id(key):
    arch, engines, hi = key
    names = "+".join(ENGINE_ORDER[e] for e in engines)
    signs = "".join("+" if h else "-" for h in hi)
    return f"{arch}|{names}|f={signs}"


def cohort_representative(key):
    arch, engines, hi = key
    fs = CFG["flops_log_spread"]
    axes = [(ENGINE_ORDER[e], (fs / 2.0) if h else (-fs / 2.0), 0.0, 0.0)
            for e, h in zip(engines, hi)]
    return scaled_device(arch, axes, 0.0, -CFG["mem_log_spread"], False)


# --------------------------------------------------------------------------
# fleet::transfer — roofline-ratio prediction + probe fallback.
# --------------------------------------------------------------------------


def engine_distance(t, a):
    return (abs(math.log(t["peak"] / a["peak"]))
            + abs(math.log(t["bw"] / a["bw"]))
            + abs(math.log(t["dispatch"] / a["dispatch"])))


def anchors_by_distance(anchors, spec):
    ranked = []
    for i, a in enumerate(anchors):
        aspec = spec_of(a["profile"], spec["kind"])
        if aspec is not None:
            ranked.append((i, engine_distance(spec, aspec)))
    ranked.sort(key=lambda x: x[1])
    return ranked


def predict_lut(anchors, nominal):
    """TransferEngine::predict — entries + per-engine (anchor, distance)."""
    entries = {}
    engines = {}
    for spec in nominal["engines"]:
        ranked = anchors_by_distance(anchors, spec)
        nearest, distance = ranked[0]
        engines[spec["kind"]] = dict(
            anchor=anchors[nearest]["name"], distance=distance,
            confidence=math.exp(-distance), probed=False, probes=0,
            correction=1.0)
        threads = (thread_candidates(nominal["n_cores"])
                   if spec["kind"] == "cpu" else [1])
        for vname in VARIANT_ORDER:
            for t in threads:
                for g in nominal["governors"]:
                    key = (vname, spec["kind"], t, g)
                    hit = None
                    for i, _ in ranked:
                        if key in anchors[i]["lut"]:
                            hit = i
                            break
                    if hit is None:
                        continue
                    target_roof = roofline_ms(nominal, spec["kind"], vname,
                                              t, g)
                    anchor_roof = roofline_ms(anchors[hit]["profile"],
                                              spec["kind"], vname, t, g)
                    ratio = target_roof / anchor_roof
                    entries[key] = anchors[hit]["lut"][key] * ratio
    return entries, engines


def probe_engine(entries, engines, kind, true_profile):
    """TransferEngine::probe_engine — geometric-mean correction."""
    keys = sorted([k for k in entries if k[1] == kind], key=key_sort)
    p = CFG["probes_per_engine"]
    picks = []
    for j in range(p):
        idx = 0 if p == 1 else j * (len(keys) - 1) // (p - 1)
        if keys[idx] not in picks:
            picks.append(keys[idx])
    log_sum = 0.0
    for key in picks:
        vname, k, t, g = key
        measured = avg_of_identical(roofline_ms(true_profile, k, vname, t, g),
                                    CFG["probe_runs"])
        log_sum += math.log(measured / entries[key])
    correction = math.exp(log_sum / len(picks))
    for key in list(entries.keys()):
        if key[1] == kind:
            entries[key] = entries[key] * correction
    engines[kind]["probed"] = True
    engines[kind]["probes"] = len(picks)
    engines[kind]["correction"] = correction


# --------------------------------------------------------------------------
# designspace mirror: buckets, enumeration, canonical rank.
# --------------------------------------------------------------------------


def contention(load):
    return 2.0 ** max(load, 0.0)


def bucket_of(loads, thermals):
    steps = {}
    for e in ENGINE_ORDER:
        mult = contention(loads.get(e, 0.0)) / max(thermals.get(e, 1.0), 1e-3)
        step = rust_round(math.log2(mult) / BUCKET_LOG2_STEP)
        if step != 0:
            steps[e] = step
    return steps


def bucket_id(steps):
    if not steps:
        return "idle"
    return ",".join(f"{e}{steps[e]:+d}" for e in ENGINE_ORDER if e in steps)


def energy_proxy(spec, avg_ms, governor):
    f = FREQ_SCALE[governor]
    return avg_ms * spec["heat"] * f * f * HEAT_FACTOR[governor]


def adjusted(lut, design, loads, thermals):
    """manager::adjusted_latency at stat=avg."""
    key = design[:4]
    if key not in lut:
        return None
    e = design[1]
    return lut[key] * contention(loads.get(e, 0.0)) \
        / max(thermals.get(e, 1.0), 1e-3)


def enumerate_space(dev, lut, family, eps, loads, thermals):
    """DesignSpace::enumerate for MinLatency(avg) at given conditions."""
    out = []
    for key in sorted(lut.keys(), key=key_sort):
        vname, kind, threads, governor = key
        v = VARIANTS[vname]
        if v["family"] != family:
            continue
        spec = spec_of(dev, kind)
        if spec is None:
            continue
        raw_avg = lut[key]
        if not v["mem"] <= dev["mem_budget"]:
            continue
        if raw_avg > dev["max_deployable"]:
            continue
        if A_REF - v["acc"] > eps + 1e-12:
            continue
        energy = energy_proxy(spec, raw_avg, governor)
        adj = raw_avg * contention(loads.get(kind, 0.0)) \
            / max(thermals.get(kind, 1.0), 1e-3)
        for r in RATES:
            fps = min(CAMERA_FPS * r, 1000.0 / adj)
            out.append(dict(
                variant=vname, engine=kind, threads=threads,
                governor=governor, r=r, latency=adj, avg=adj, fps=fps,
                mem=v["mem"], acc=v["acc"], energy=energy,
            ))
    return out


def rank_key(c):
    return (-c["score"], c["energy"], c["latency"], -c["acc"], c["avg"],
            -c["r"], c["mem"], c["variant"],
            ENGINE_ORDER.index(c["engine"]), c["threads"],
            GOV_ORDER.index(c["governor"]))


def best_design(dev, lut, loads, thermals):
    """rank(enumerate)[0] as a design tuple (MinLatency: score=-latency)."""
    cands = enumerate_space(dev, lut, CFG["family"], CFG["eps"], loads,
                            thermals)
    for c in cands:
        c["score"] = -c["latency"]
    if not cands:
        return None
    best = min(cands, key=rank_key)
    return (best["variant"], best["engine"], best["threads"],
            best["governor"], best["r"])


def design_tuple(p):
    return (p["variant"], p["engine"], p["threads"], p["governor"], p["r"])


def dominates(p, q):
    """designspace::frontier::dominates (slice-local Pareto dominance)."""
    if (p["engine"] != q["engine"] or p["r"] != q["r"]
            or p["threads"] != q["threads"]):
        return False
    quality_no_worse = (p["acc"] > q["acc"]
                        or (p["acc"] == q["acc"] and p["mem"] <= q["mem"]))
    no_worse = (p["latency"] <= q["latency"] and p["avg"] <= q["avg"]
                and p["energy"] <= q["energy"] and quality_no_worse)
    strictly = (p["latency"] < q["latency"] or p["avg"] < q["avg"]
                or p["energy"] < q["energy"] or p["acc"] > q["acc"]
                or (p["acc"] == q["acc"] and p["mem"] < q["mem"]))
    return no_worse and strictly


def frontier_build(dev, lut, rep_loads):
    """ParetoFrontier::build at the bucket's representative conditions:
    ranked non-dominated points plus the enumerated-space size."""
    cands = enumerate_space(dev, lut, CFG["family"], CFG["eps"], rep_loads,
                            {})
    for c in cands:
        c["score"] = -c["latency"]
    pts = [q for q in cands if not any(dominates(p, q) for p in cands)]
    pts.sort(key=rank_key)
    return pts, len(cands)


def eval_key(dev, lut, key, r, rep_loads):
    """DesignSpace::eval_candidate for MinLatency(avg): re-score one
    (LUT key, rate) pair, None when the pre-filter now rejects it."""
    vname, kind, threads, governor = key
    v = VARIANTS[vname]
    spec = spec_of(dev, kind)
    raw = lut.get(key)
    if spec is None or raw is None:
        return None
    if not v["mem"] <= dev["mem_budget"]:
        return None
    if raw > dev["max_deployable"]:
        return None
    if A_REF - v["acc"] > CFG["eps"] + 1e-12:
        return None
    energy = energy_proxy(spec, raw, governor)
    adj = raw * contention(rep_loads.get(kind, 0.0)) / max(1.0, 1e-3)
    fps = min(CAMERA_FPS * r, 1000.0 / adj)
    return dict(variant=vname, engine=kind, threads=threads,
                governor=governor, r=r, latency=adj, avg=adj, fps=fps,
                mem=v["mem"], acc=v["acc"], energy=energy, score=-adj)


# --------------------------------------------------------------------------
# manager::RuntimeManager::decide — the adaptation state machine.
# --------------------------------------------------------------------------

POLICY = dict(load_delta=0.1, min_improvement=1.10, check_interval=250.0,
              confirmations=3, violation_ratio=1.25, cooldown=1000.0,
              thermal_alert=0.95)


class Manager:
    def __init__(self, current, scope=None, tr=None):
        self.current = current
        self.last_loads = {}
        self.last_check = -math.inf
        self.last_switch = -math.inf
        self.violations = 0
        self.degradation_start = None
        self.scope = scope
        self.tr = tr

    def hold(self, trigger, reason):
        if self.tr is not None:
            self.tr.emit("hold", [
                ("scope", f'"{self.scope}"'),
                ("trigger", f'"{trigger}"'),
                ("reason", f'"{reason}"'),
            ])
        return ("hold", reason)

    def decide(self, now, loads, thermals, select):
        if now - self.last_check < POLICY["check_interval"]:
            return self.hold("none", "not_due")
        self.last_check = now
        if now - self.last_switch < POLICY["cooldown"]:
            return self.hold("none", "cooldown")
        load_changed = any(
            abs(loads.get(k, 0.0) - self.last_loads.get(k, 0.0))
            >= POLICY["load_delta"] for k in ENGINE_ORDER)
        # No measured-latency window in the fleet driver: degradation is
        # the middleware-c thermal alert on the current engine only.
        degraded_now = (thermals.get(self.current[1], 1.0)
                        < POLICY["thermal_alert"])
        if degraded_now:
            if self.degradation_start is None:
                self.degradation_start = now
            self.violations += 1
        else:
            self.violations = 0
            self.degradation_start = None
        confirmed = self.violations >= POLICY["confirmations"]
        if not load_changed and not confirmed:
            return self.hold("none", "no_trigger")
        trigger = "degradation" if confirmed else "load"
        if load_changed:
            for k in ENGINE_ORDER:
                self.last_loads[k] = loads.get(k, 0.0)
        best, bid, npts = select(loads, thermals)
        if best is None:
            return self.hold(trigger, "no_alternative")
        if best == self.current:
            return self.hold(trigger, "current_still_best")
        cur_adj = adjusted(self.lut, self.current, loads, thermals)
        best_adj = adjusted(self.lut, best, loads, thermals)
        if cur_adj is None or best_adj is None:
            return self.hold(trigger, "no_alternative")
        if cur_adj / best_adj < POLICY["min_improvement"]:
            return self.hold(trigger, "below_hysteresis")
        detection = (now - self.degradation_start
                     if self.degradation_start is not None else 0.0)
        if self.tr is not None:
            self.tr.emit("switch", [
                ("scope", f'"{self.scope}"'),
                ("from", f'"{design_id(self.current)}"'),
                ("to", f'"{design_id(best)}"'),
                ("reason", f'"{trigger}"'),
                ("detection_ms", jnum(detection)),
            ])
            self.tr.emit("explain", [
                ("scope", f'"{self.scope}"'),
                ("bucket", f'"{bid}"'),
                ("chosen", f'"{design_id(best)}"'),
                ("score", jnum(r3(best_adj))),
                ("frontier", jnum(npts)),
                ("alternatives", jnum(max(npts - 1, 0))),
            ])
        self.current = best
        self.last_switch = now
        self.violations = 0
        self.degradation_start = None
        return ("switch", trigger)


# --------------------------------------------------------------------------
# The bench: cohorts, shared-cache accounting, storm, regret, JSON.
# --------------------------------------------------------------------------


def storm_phase(tick):
    if tick <= 2:
        return "calm"
    if tick <= 6:
        return "gpu_surge"
    if tick <= 9:
        return "npu_throttle"
    return "recovery"


def storm_conditions(tick, idx, has_npu):
    loads, thermals = {}, {}
    phase = storm_phase(tick)
    if phase == "gpu_surge":
        if idx % 2 == 0:
            loads["gpu"] = 1.0
    elif phase == "npu_throttle":
        if has_npu:
            thermals["nnapi"] = 0.5
        else:
            loads["cpu"] = 1.0
    return loads, thermals


def jnum(n):
    f = float(n)
    if f == int(f) and abs(f) < 9e15:
        return str(int(f))
    return repr(f)


def jobj(fields):
    return "{" + ",".join(f'"{k}":{v}' for k, v in fields) + "}"


def jbool(b):
    return "true" if b else "false"


def fmt_f64(x):
    """Rust f64 Display for the design-id rate (1 -> "1", 0.5 -> "0.5")."""
    return str(int(x)) if x == int(x) else repr(x)


def design_id(d):
    """manager::design_id — canonical design identity string."""
    return f"{d[0]}|{d[1]}|{d[2]}|{d[3]}|r={fmt_f64(d[4])}"


class Trace:
    """telemetry::trace::FlightRecorder mirror: JSON-lines emission with
    the pinned key order ``seq``, ``t_us``, ``ev``, then the payload.
    The smoke trace stays far below the 65 536-event ring capacity, so
    the oracle never models drops."""

    def __init__(self):
        self.lines = []
        self.seq = 0
        self.t_us = 0

    def set_now_us(self, t_us):
        self.t_us = t_us

    def emit(self, ev, fields):
        parts = [("seq", jnum(self.seq)), ("t_us", jnum(self.t_us)),
                 ("ev", f'"{ev}"')] + fields
        self.lines.append(jobj(parts))
        self.seq += 1

    def dump(self):
        return "".join(line + "\n" for line in self.lines)


# --------------------------------------------------------------------------
# telemetry::spans + telemetry::sampling mirror — the `oodin trace
# --summary` payload (rust/tests/golden/trace_summary.json) regenerated
# independently from the golden trace JSONL.
# --------------------------------------------------------------------------

SUMMARY_SAMPLE_RATE = 16
SUMMARY_SAMPLE_SEED = 7
PENDING_PER_KEY = 64
PENDING_KEYS = 512


def key_hash(seed, key):
    """telemetry::sampling::key_hash — seeded FNV-1a over seed LE bytes
    then the key bytes."""
    h = 0xCBF29CE484222325
    for b in seed.to_bytes(8, "little") + key.encode():
        h ^= b
        h = (h * 0x100000001B3) & M64
    return h


def head_keeps(rate, seed, key):
    return rate <= 1 or key_hash(seed, key) % rate == 0


def sample_key(e):
    """TraceEvent::sample_key on the parsed JSONL form."""
    ev = e["ev"]
    if ev in ("cohort_transfer", "probe_fallback", "residual", "re_anchor"):
        return e.get("cohort", "")
    if ev == "rollout":
        rev = float(e.get("revision", 0))
        return f"rev:{int(rev) if rev > 0.0 else 0}"
    if ev == "correction":
        return "fleet"
    return e.get("scope", "")


def is_anomalous(e):
    """TraceEvent::is_anomalous on the parsed JSONL form."""
    ev = e["ev"]
    if ev in ("shed", "slo_burn"):
        return True
    if ev == "rollout":
        return e.get("stage", "") == "rolled_back"
    if ev == "batch_complete":
        return int(e.get("slack_us", 0)) < 0
    return False


def analyze_trace(text):
    """telemetry::spans::Analysis::build over a pinned-schema JSONL
    trace: one deterministic pass reconstructing all four span families
    plus the cross-device causality chains."""
    events = [json.loads(ln) for ln in text.splitlines() if ln.strip()]
    a = dict(events=events, adaptation=[], abandoned=0, open_episodes=0,
             requests=[], batches=[], sheds=0, unclosed_requests=0,
             unclosed_batches=0, stray_completes=0, rollouts=[],
             rollout_holds=0, burn=[], chains=[], orphan_deltas=0,
             downstream=0, seq_gaps=0)
    episodes = {}       # scope -> [first_t_us, blocked_holds]
    queues = {}         # scope -> [enqueue_us, ...] FIFO
    open_batches = {}   # scope -> [(launch_us, [members]), ...] FIFO
    rollout_order = []
    rollouts = {}
    burn_order = []
    burns = {}
    pending = []        # (seq, t_us, scope) frontier_delta awaiting cause
    touch = set()       # (t_us, scope) instants touched by a chain

    def claim(cause, e):
        nonlocal pending
        cohorts = [p[2] for p in pending if p[1] == e["t_us"]]
        pending = [p for p in pending if p[1] != e["t_us"]]
        if cohorts:
            for c in cohorts:
                touch.add((e["t_us"], c))
            a["chains"].append(dict(cause=cause, cause_seq=e["seq"],
                                    t_us=e["t_us"], cohorts=cohorts))

    for idx, e in enumerate(events):
        if idx > 0 and e["seq"] != events[idx - 1]["seq"] + 1:
            a["seq_gaps"] += 1
        # Deltas from an earlier instant can no longer be claimed.
        keep = [p for p in pending if p[1] >= e["t_us"]]
        a["orphan_deltas"] += len(pending) - len(keep)
        pending = keep
        ev = e["ev"]
        if ev == "hold":
            scope = e.get("scope", "")
            if e.get("trigger", "") != "none":
                ep = episodes.setdefault(scope, [e["t_us"], 0])
                ep[1] += 1
            elif e.get("reason", "") == "no_trigger" and scope in episodes:
                del episodes[scope]
                a["abandoned"] += 1
        elif ev == "switch":
            scope = e.get("scope", "")
            det_us = int(math.floor(
                float(e.get("detection_ms", 0.0)) * 1000.0 + 0.5))
            onset = max(e["t_us"] - det_us, 0)
            if scope in episodes:
                first, blocked = episodes.pop(scope)
                start = min(first, onset)
            else:
                start, blocked = onset, 0
            prev_e = events[idx - 1] if idx > 0 else None
            if (prev_e is not None
                    and prev_e["ev"] in ("frontier_hit", "frontier_build")
                    and prev_e["t_us"] == e["t_us"]
                    and (e["t_us"], prev_e.get("scope", "")) in touch):
                a["downstream"] += 1
            a["adaptation"].append(dict(
                scope=scope, start_us=start, end_us=e["t_us"],
                detection_us=det_us, blocked_holds=blocked))
        elif ev == "enqueue":
            queues.setdefault(e.get("scope", ""), []).append(e["t_us"])
        elif ev == "shed":
            a["sheds"] += 1
        elif ev == "batch_launch":
            scope = e.get("scope", "")
            q = queues.setdefault(scope, [])
            n = min(int(e.get("size", 0)), len(q))
            members, queues[scope] = q[:n], q[n:]
            open_batches.setdefault(scope, []).append((e["t_us"], members))
        elif ev == "batch_complete":
            scope = e.get("scope", "")
            ob = open_batches.setdefault(scope, [])
            if ob:
                launch_us, members = ob.pop(0)
                for m in members:
                    a["requests"].append(dict(
                        scope=scope, enqueue_us=m, launch_us=launch_us,
                        complete_us=e["t_us"]))
                a["batches"].append(dict(
                    scope=scope, launch_us=launch_us,
                    complete_us=e["t_us"]))
            else:
                a["stray_completes"] += 1
        elif ev == "rollout":
            rev = int(float(e.get("revision", 0)))
            stage = e.get("stage", "")
            if stage == "held":
                a["rollout_holds"] += 1
            if rev not in rollouts:
                rollout_order.append(rev)
                rollouts[rev] = dict(revision=rev, start_us=e["t_us"],
                                     end_us=e["t_us"], stages=[],
                                     terminal="", has_canary=False)
            span = rollouts[rev]
            span["end_us"] = e["t_us"]
            if stage == "canary":
                span["has_canary"] = True
            if stage in ("promoted", "rolled_back"):
                span["terminal"] = stage
            span["stages"].append(stage)
            if stage != "held":
                claim("rollout", e)
        elif ev == "slo_burn":
            scope = e.get("scope", "")
            if scope not in burns:
                burn_order.append(scope)
                burns[scope] = dict(scope=scope, start_us=e["t_us"],
                                    end_us=e["t_us"], events=0,
                                    max_fast_burn=0.0)
            b = burns[scope]
            b["end_us"] = e["t_us"]
            b["events"] += 1
            fast = float(e.get("fast_burn", 0.0))
            if fast > b["max_fast_burn"]:
                b["max_fast_burn"] = fast
        elif ev == "frontier_delta":
            pending.append((e["seq"], e["t_us"], e.get("scope", "")))
        elif ev in ("correction", "residual", "re_anchor"):
            claim(ev, e)

    a["open_episodes"] = len(episodes)
    a["unclosed_requests"] = (sum(len(q) for q in queues.values())
                              + sum(len(m) for b in open_batches.values()
                                    for _, m in b))
    a["unclosed_batches"] = sum(len(b) for b in open_batches.values())
    a["orphan_deltas"] += len(pending)
    a["rollouts"] = [rollouts[r] for r in rollout_order]
    a["burn"] = [burns[s] for s in burn_order]
    return a


def simulate_sampling(events, policy, rate, seed):
    """telemetry::sampling::Sampler replay (payload: the anomaly flag);
    returns (retained, retained_anomalous) after the end-of-stream
    drain (drained events are rejected, not retained)."""
    pending = {}     # key -> [anom flags] bounded FIFO
    key_order = []
    retained = 0
    retained_anom = 0
    for e in events:
        key = sample_key(e)
        anom = is_anomalous(e)
        if policy == "head":
            if head_keeps(rate, seed, key):
                retained += 1
                if anom:
                    retained_anom += 1
            continue
        # tail
        if anom:
            flushed = pending.pop(key, [])
            if flushed or key in key_order:
                key_order.remove(key)
            retained += len(flushed) + 1
            retained_anom += sum(flushed) + 1
        elif head_keeps(rate, seed, key):
            retained += 1
        else:
            if key not in pending:
                if len(key_order) == PENDING_KEYS:
                    victim = key_order.pop(0)
                    del pending[victim]
                key_order.append(key)
                pending[key] = []
            q = pending[key]
            if len(q) == PENDING_PER_KEY:
                q.pop(0)
            q.append(1 if anom else 0)
    return retained, retained_anom


def trace_summary(text):
    """telemetry::spans::Analysis::summary_json + "\\n" — the byte form
    `oodin trace --summary` prints over the trace."""
    a = analyze_trace(text)
    events = a["events"]
    n = len(events)
    first_seq = events[0]["seq"] if events else 0
    last_seq = events[-1]["seq"] if events else 0
    t_first = events[0]["t_us"] if events else 0
    t_last = max((e["t_us"] for e in events), default=0)

    spans = len(a["adaptation"])
    blocked = sum(s["blocked_holds"] for s in a["adaptation"])
    det_sum = sum(s["detection_us"] for s in a["adaptation"])
    det_max = max((s["detection_us"] for s in a["adaptation"]), default=0)
    dur_sum = sum(s["end_us"] - s["start_us"] for s in a["adaptation"])
    mean_det_ms = r3(det_sum / spans / 1000.0) if spans else 0.0
    mean_dur_ms = r3(dur_sum / spans / 1000.0) if spans else 0.0

    reqs = len(a["requests"])
    wait_sum = sum(q["launch_us"] - q["enqueue_us"] for q in a["requests"])
    service_sum = sum(q["complete_us"] - q["launch_us"]
                      for q in a["requests"])
    mean_wait = r3(wait_sum / reqs) if reqs else 0.0
    mean_service = r3(service_sum / reqs) if reqs else 0.0

    promoted = sum(1 for r in a["rollouts"] if r["terminal"] == "promoted")
    rolled_back = sum(1 for r in a["rollouts"]
                      if r["terminal"] == "rolled_back")
    rollbacks_linked = all(r["has_canary"] for r in a["rollouts"]
                           if r["terminal"] == "rolled_back")

    burn_events = sum(b["events"] for b in a["burn"])
    burn_max = r3(max((b["max_fast_burn"] for b in a["burn"]), default=0.0))
    linked_deltas = sum(len(c["cohorts"]) for c in a["chains"])

    anomalous = sum(1 for e in events if is_anomalous(e))
    head_retained, _ = simulate_sampling(
        events, "head", SUMMARY_SAMPLE_RATE, SUMMARY_SAMPLE_SEED)
    tail_retained, tail_anom = simulate_sampling(
        events, "tail", SUMMARY_SAMPLE_RATE, SUMMARY_SAMPLE_SEED)
    reduction = n / tail_retained if tail_retained else 0.0
    anom_pct = (r3(100.0 * tail_anom / anomalous) if anomalous else 100.0)

    return jobj([
        ("events", jobj([
            ("count", jnum(n)),
            ("first_seq", jnum(first_seq)),
            ("last_seq", jnum(last_seq)),
            ("seq_gaps", jnum(a["seq_gaps"])),
            ("t_first_us", jnum(t_first)),
            ("t_last_us", jnum(t_last)),
        ])),
        ("adaptation", jobj([
            ("spans", jnum(spans)),
            ("switches", jnum(spans)),
            ("one_span_per_switch", jbool(True)),
            ("blocked_holds", jnum(blocked)),
            ("abandoned_episodes", jnum(a["abandoned"])),
            ("open_episodes", jnum(a["open_episodes"])),
            ("mean_detection_ms", jnum(mean_det_ms)),
            ("max_detection_ms", jnum(r3(det_max / 1000.0))),
            ("mean_duration_ms", jnum(mean_dur_ms)),
        ])),
        ("serving", jobj([
            ("requests", jnum(reqs)),
            ("batches", jnum(len(a["batches"]))),
            ("sheds", jnum(a["sheds"])),
            ("unclosed_requests", jnum(a["unclosed_requests"])),
            ("unclosed_batches", jnum(a["unclosed_batches"])),
            ("stray_completes", jnum(a["stray_completes"])),
            ("mean_queue_wait_us", jnum(mean_wait)),
            ("mean_service_us", jnum(mean_service)),
        ])),
        ("rollouts", jobj([
            ("spans", jnum(len(a["rollouts"]))),
            ("promoted", jnum(promoted)),
            ("rolled_back", jnum(rolled_back)),
            ("holds", jnum(a["rollout_holds"])),
            ("all_rollbacks_linked", jbool(rollbacks_linked)),
        ])),
        ("slo_burn", jobj([
            ("events", jnum(burn_events)),
            ("episodes", jnum(len(a["burn"]))),
            ("max_fast_burn", jnum(burn_max)),
        ])),
        ("causality", jobj([
            ("chains", jnum(len(a["chains"]))),
            ("linked_deltas", jnum(linked_deltas)),
            ("orphan_deltas", jnum(a["orphan_deltas"])),
            ("downstream_switches", jnum(a["downstream"])),
        ])),
        ("sampling", jobj([
            ("rate", jnum(SUMMARY_SAMPLE_RATE)),
            ("seed", jnum(SUMMARY_SAMPLE_SEED)),
            ("events", jnum(n)),
            ("head_retained", jnum(head_retained)),
            ("tail_retained", jnum(tail_retained)),
            ("tail_reduction_x", jnum(r3(reduction))),
            ("anomalous_events", jnum(anomalous)),
            ("anomalous_retained", jnum(tail_anom)),
            ("anomalous_retained_pct", jnum(anom_pct)),
            ("tail_reduction_ge_4x",
             jbool(tail_retained > 0 and reduction >= 4.0)),
        ])),
    ]) + "\n"


def run_fleetbench_smoke():
    # Anchors: every archetype, full zero-noise sweep.
    anchors = []
    for name in ARCHETYPES:
        profile = dict(BASE_DEVICES[name], archetype=name)
        anchors.append(dict(name=name, profile=profile,
                            lut=build_lut(profile, CFG["lut_runs"])))

    # Population.
    devices = [sample_device(i) for i in range(CFG["size"])]
    arch_counts = {a: 0 for a in ARCHETYPES}
    npu_dropped = 0
    for d in devices:
        arch_counts[d["archetype"]] += 1
        if d["dropped"]:
            npu_dropped += 1

    # Cohorts in canonical key order, with cohort-level confidence (worst
    # member) and probe fallback on the first member.
    groups = {}
    for d in devices:
        groups.setdefault(cohort_key(d), []).append(d["idx"])
    cohorts = []
    device_cohort = {}
    for ci, key in enumerate(sorted(groups.keys())):
        members = groups[key]
        rep = cohort_representative(key)
        entries, engines = predict_lut(anchors, rep)
        for kind in sorted(engines.keys(), key=ENGINE_ORDER.index):
            dist = engines[kind]["distance"]
            for m in members:
                mspec = spec_of(devices[m]["nominal"], kind)
                ranked = anchors_by_distance(anchors, mspec)
                dist = max(dist, ranked[0][1])
            engines[kind]["distance"] = dist
            engines[kind]["confidence"] = math.exp(-dist)
            if engines[kind]["confidence"] < CFG["confidence_threshold"]:
                probe_engine(entries, engines, kind,
                             devices[members[0]]["true"])
        for m in members:
            device_cohort[m] = ci
        cohorts.append(dict(
            key=key, id=cohort_id(key), rep=rep, lut=entries,
            engines=engines, members=members, cache={}, builds=0, hits=0,
            evals=0))

    # Flight recorder attach (Fleet::attach_recorder at virtual t=0):
    # transfer provenance per cohort in canonical order, probe fallbacks
    # per probed engine in EngineKind order.
    tr = Trace()
    tr.set_now_us(0)
    for c in cohorts:
        min_conf = min(e["confidence"] for e in c["engines"].values())
        tr.emit("cohort_transfer", [
            ("cohort", f'"{c["id"]}"'),
            ("members", jnum(len(c["members"]))),
            ("min_confidence", jnum(r3(min_conf))),
            ("probed", jbool(any(e["probed"]
                                 for e in c["engines"].values()))),
        ])
        for kind in ENGINE_ORDER:
            e = c["engines"].get(kind)
            if e is not None and e["probed"]:
                tr.emit("probe_fallback", [
                    ("cohort", f'"{c["id"]}"'),
                    ("engine", f'"{kind}"'),
                    ("probes", jnum(e["probes"])),
                    ("correction", jnum(r3(e["correction"]))),
                ])

    # Full-profile oracle LUTs + transfer prediction error on the family.
    oracle_luts = []
    err_sum = 0.0
    err_max = 0.0
    err_n = 0
    for d in devices:
        true_lut = build_lut(d["true"], CFG["lut_runs"])
        c = cohorts[device_cohort[d["idx"]]]
        for key in sorted(c["lut"].keys(), key=key_sort):
            if VARIANTS[key[0]]["family"] != CFG["family"]:
                continue
            err = abs(c["lut"][key] / true_lut[key] - 1.0)
            err_sum += err
            err_max = max(err_max, err)
            err_n += 1
        oracle_luts.append(true_lut)

    def cohort_select(ci, loads, thermals):
        """Shared-cache lookup; returns (best, bucket id, frontier len)
        and emits the cache-side trace event, exactly as
        FrontierCache::frontier does."""
        c = cohorts[ci]
        steps = bucket_of(loads, thermals)
        bid = bucket_id(steps)
        if bid in c["cache"]:
            c["hits"] += 1
            pts = c["cache"][bid]["points"]
            tr.emit("frontier_hit", [
                ("scope", f'"{c["id"]}"'),
                ("bucket", f'"{bid}"'),
                ("points", jnum(len(pts))),
            ])
            return (design_tuple(pts[0]) if pts else None, bid, len(pts))
        rep_loads = {e: s * BUCKET_LOG2_STEP for e, s in steps.items()}
        pts, n_cands = frontier_build(c["rep"], c["lut"], rep_loads)
        c["builds"] += 1
        c["evals"] += n_cands
        c["cache"][bid] = dict(points=pts, steps=steps)
        tr.emit("frontier_build", [
            ("scope", f'"{c["id"]}"'),
            ("bucket", f'"{bid}"'),
            ("points", jnum(len(pts))),
            ("candidates", jnum(n_cands)),
        ])
        best = design_tuple(pts[0]) if pts else None
        # The frontier-walk exactness theorem, re-asserted oracle-side.
        assert best == best_design(c["rep"], c["lut"], rep_loads, {})
        return (best, bid, len(pts))

    # Managers: initial design = idle-conditions cohort selection
    # (Fleet::manager_for), each scoped to its device id for the trace.
    managers = []
    for d in devices:
        ci = device_cohort[d["idx"]]
        init, _, _ = cohort_select(ci, {}, {})
        m = Manager(init, scope=f'd{d["idx"]:04d}', tr=tr)
        m.lut = cohorts[ci]["lut"]
        m.ci = ci
        managers.append(m)

    # The storm.  The burn-rate monitor watches every cohort's
    # `regret_pct` rollup at each regret tick (fast window = one regret
    # round, slow window = the storm so far); alerts land in the trace
    # as `slo_burn` events and never touch the report.
    thr_bucket = bucket_index(BURN_SLO_REGRET_PCT)
    burn_prev = {}  # cohort index -> (count, above, t_us)
    for c in cohorts:
        c["burn_count"] = 0
        c["burn_above"] = 0
    holds = dict(not_due=0, cooldown=0, no_trigger=0, no_alternative=0,
                 current_still_best=0, below_hysteresis=0)
    switches = switch_load = switch_degradation = 0
    per_device_switches = [0] * len(devices)
    regrets = []
    deploy_faults = 0
    for tick in range(CFG["ticks"]):
        now = tick * CFG["tick_ms"]
        tr.set_now_us(int(now * 1000.0))
        regret_tick = tick in CFG["regret_ticks"]
        for idx, d in enumerate(devices):
            has_npu = any(k == "nnapi" for k, _, _, _ in d["axes"])
            loads, thermals = storm_conditions(tick, idx, has_npu)
            ci = device_cohort[idx]
            outcome = managers[idx].decide(
                now, loads, thermals,
                lambda ld, th: cohort_select(ci, ld, th))
            if outcome[0] == "switch":
                switches += 1
                per_device_switches[idx] += 1
                if outcome[1] == "load":
                    switch_load += 1
                else:
                    switch_degradation += 1
            else:
                holds[outcome[1]] += 1
            if regret_tick:
                sel, _, _ = cohort_select(ci, loads, thermals)
                true_lut = oracle_luts[idx]
                oracle = best_design(d["true"], true_lut, loads, thermals)
                sel_adj = adjusted(true_lut, sel, loads, thermals)
                oracle_adj = adjusted(true_lut, oracle, loads, thermals)
                v = VARIANTS[sel[0]]
                admissible = (v["mem"] <= d["true"]["mem_budget"]
                              and true_lut[sel[:4]]
                              <= d["true"]["max_deployable"])
                r = sel_adj / oracle_adj - 1.0
                # Inadmissible picks can undercut the feasible-only oracle:
                # clamp their regret at 0 (the fault counter is their
                # signal) so the enforced mean is never flattered.
                if not admissible:
                    deploy_faults += 1
                    rv = max(r, 0.0)
                else:
                    rv = r
                regrets.append(rv)
                # Telemetry::record("regret_pct") into the cohort rollup:
                # only the bucketed above-threshold count matters here.
                cb = cohorts[ci]
                cb["burn_count"] += 1
                if bucket_index(100.0 * rv) > thr_bucket:
                    cb["burn_above"] += 1
        if regret_tick:
            # Fleet::check_burn after the device loop: cohorts in
            # canonical order, SloBurnMonitor::check_counts each.
            for ci2, c in enumerate(cohorts):
                count, above = c["burn_count"], c["burn_above"]
                pc, pa, pt = burn_prev.get(ci2, (0, 0, tr.t_us))
                burn_prev[ci2] = (count, above, tr.t_us)
                dc, da = count - pc, above - pa
                if count == 0 or dc < max(BURN_MIN_SAMPLES, 1):
                    continue
                fast = (da / dc) / BURN_BUDGET
                slow = (above / count) / BURN_BUDGET
                if fast > 1.0 and slow > 1.0:
                    tr.emit("slo_burn", [
                        ("scope", f'"{c["id"]}"'),
                        ("metric", '"regret_pct"'),
                        ("window_us", jnum(tr.t_us - pt)),
                        ("fast_burn", jnum(r3(fast))),
                        ("slow_burn", jnum(r3(slow))),
                        ("misses", jnum(da)),
                        ("samples", jnum(dc)),
                    ])

    regret_sum = 0.0
    for r in regrets:
        regret_sum += r
    regret_mean = regret_sum / max(len(regrets), 1)
    regret_max = 0.0
    for r in regrets:
        regret_max = max(regret_max, r)
    zero = sum(1 for r in regrets if r <= 1e-12)
    builds = sum(c["builds"] for c in cohorts)
    hits = sum(c["hits"] for c in cohorts)

    # Oracle-side acceptance checks (the Rust driver ensure!s the same).
    assert builds < CFG["size"], (builds, CFG["size"])
    assert 100.0 * regret_mean <= 5.0, regret_mean

    probed_cohorts = sum(
        1 for c in cohorts if any(e["probed"] for e in c["engines"].values()))
    probe_measurements = sum(e["probes"] for c in cohorts
                             for e in c["engines"].values())
    candidates_enumerated = sum(c["evals"] for c in cohorts)

    # -- post-storm per-engine correction via the incremental delta path --
    # Mirrors Fleet::apply_engine_correction: every cohort's CPU rows
    # × CORRECTION_FACTOR, each resident frontier carried in place
    # (ParetoFrontier::apply_delta with a pure engine-scale delta: resident
    # CPU points re-scored from the new LUT, dropped only past the
    # deployability bound; factor > 1 admits nothing new).
    mem_budget_per_cohort = max(
        CFG["frontier_mem_budget_bytes"] // len(cohorts), 1)
    delta_updated = 0
    delta_points_touched = 0
    delta_rebuild_points = 0
    tr.set_now_us(int(CFG["ticks"] * CFG["tick_ms"] * 1000.0))
    for c in cohorts:
        new_lut = {k: (v * CORRECTION_FACTOR if k[1] == CORRECTION_ENGINE
                       else v)
                   for k, v in c["lut"].items()}
        # Refreshed space_size: count_admitted over the new LUT
        # (conditions-independent), i.e. what a full rebuild would score.
        sz_new = len(enumerate_space(c["rep"], new_lut, CFG["family"],
                                     CFG["eps"], {}, {}))
        coh_updated = 0
        coh_touched = 0
        coh_rebuild = 0
        for entry in c["cache"].values():
            rep_loads = {e: s * BUCKET_LOG2_STEP
                         for e, s in entry["steps"].items()}
            touched = 0
            newpts = []
            for p in entry["points"]:
                if p["engine"] != CORRECTION_ENGINE:
                    newpts.append(p)
                    continue
                touched += 1
                key = (p["variant"], p["engine"], p["threads"],
                       p["governor"])
                rescored = eval_key(c["rep"], new_lut, key, p["r"],
                                    rep_loads)
                if rescored is not None:
                    newpts.append(rescored)
            newpts.sort(key=rank_key)
            entry["points"] = newpts
            coh_updated += 1
            coh_touched += touched
            coh_rebuild += sz_new
        # FrontierCache::apply_delta's per-cache summary event (no
        # entries are ever dropped by the factor-up correction).
        if coh_updated > 0:
            tr.emit("frontier_delta", [
                ("scope", f'"{c["id"]}"'),
                ("updated", jnum(coh_updated)),
                ("points_touched", jnum(coh_touched)),
                ("rebuild_points", jnum(coh_rebuild)),
            ])
        delta_updated += coh_updated
        delta_points_touched += coh_touched
        delta_rebuild_points += coh_rebuild
        c["lut"] = new_lut
        resident_c = sum(FRONTIER_BASE_BYTES
                         + FRONTIER_POINT_BYTES * len(e["points"])
                         for e in c["cache"].values())
        assert resident_c <= mem_budget_per_cohort, (c["id"], resident_c)
    # The Rust driver ensure!s the same invariants.
    assert delta_updated > 0
    assert delta_points_touched < delta_rebuild_points, (
        delta_points_touched, delta_rebuild_points)

    # Fleet::apply_engine_correction's fleet-level aggregate event.
    tr.emit("correction", [
        ("engine", f'"{CORRECTION_ENGINE}"'),
        ("factor", jnum(CORRECTION_FACTOR)),
        ("updated", jnum(delta_updated)),
        ("points_touched", jnum(delta_points_touched)),
    ])

    # Post-correction idle round: every cohort's idle frontier stays warm
    # (zero builds) and its walk still equals the full search.  The Rust
    # driver serves these 200 selections from the carried frontiers, so
    # each emits a frontier_hit (after the report's cache-stats snapshot,
    # hence emitted here without touching the counters above).
    for idx in range(CFG["size"]):
        c = cohorts[device_cohort[idx]]
        assert "idle" in c["cache"]
        pts = c["cache"]["idle"]["points"]
        tr.emit("frontier_hit", [
            ("scope", f'"{c["id"]}"'),
            ("bucket", '"idle"'),
            ("points", jnum(len(pts))),
        ])
        assert design_tuple(pts[0]) == best_design(c["rep"], c["lut"], {},
                                                   {})

    resident_bytes = sum(
        FRONTIER_BASE_BYTES + FRONTIER_POINT_BYTES * len(e["points"])
        for c in cohorts for e in c["cache"].values())

    # ===== fleet control plane ==========================================
    # Mirrors experiments::fleetbench::run_control_plane on the storm's
    # continued virtual clock.  Every report value above keeps its
    # pre-scenario computation point, exactly like the Rust driver; the
    # scenario's cache traffic runs on its own counters.

    def has_npu_of(d):
        return any(kk == "nnapi" for kk, _, _, _ in d["axes"])

    # The oracle's adjusted latency per (regret tick, device): true LUTs
    # never change, so every sweep reuses one full-search pass.
    oracle_adj = []
    for tick in CFG["regret_ticks"]:
        row = []
        for idx, d in enumerate(devices):
            loads, thermals = storm_conditions(tick, idx, has_npu_of(d))
            oracle = best_design(d["true"], oracle_luts[idx], loads,
                                 thermals)
            row.append(adjusted(oracle_luts[idx], oracle, loads, thermals))
        oracle_adj.append(row)

    for c in cohorts:
        for e in c["cache"].values():
            e["stale"] = False
    sc = dict(builds=0, hits=0)
    assigned = [0] * len(cohorts)  # RevisionRegistry: live revision/cohort

    def scenario_select(ci, loads, thermals):
        """Fleet::select after the report's cache-stats snapshot: same
        FrontierCache::frontier semantics (hit on a fresh entry; a stale
        scope fingerprint drops the entry silently and rebuilds)."""
        c = cohorts[ci]
        steps = bucket_of(loads, thermals)
        bid = bucket_id(steps)
        entry = c["cache"].get(bid)
        if entry is not None and not entry["stale"]:
            sc["hits"] += 1
            pts = entry["points"]
            tr.emit("frontier_hit", [
                ("scope", f'"{c["id"]}"'),
                ("bucket", f'"{bid}"'),
                ("points", jnum(len(pts))),
            ])
            return design_tuple(pts[0])
        if entry is not None:
            del c["cache"][bid]  # invalidation: counted, never emitted
        rep_loads = {e: s * BUCKET_LOG2_STEP for e, s in steps.items()}
        pts, n_cands = frontier_build(c["rep"], c["lut"], rep_loads)
        sc["builds"] += 1
        c["cache"][bid] = dict(points=pts, steps=steps, stale=False)
        tr.emit("frontier_build", [
            ("scope", f'"{c["id"]}"'),
            ("bucket", f'"{bid}"'),
            ("points", jnum(len(pts))),
            ("candidates", jnum(n_cands)),
        ])
        assert design_tuple(pts[0]) == best_design(c["rep"], c["lut"],
                                                   rep_loads, {})
        resident_c = sum(FRONTIER_BASE_BYTES
                         + FRONTIER_POINT_BYTES * len(e["points"])
                         for e in c["cache"].values())
        assert resident_c <= mem_budget_per_cohort, (c["id"], resident_c)
        return design_tuple(pts[0])

    def apply_cohort_delta(ci, eng, factor, new_lut):
        """Fleet::swap_cohort_lut under an engine-scale LutDelta: every
        resident frontier shares one family search scope, so either every
        entry observes the transition or none does (a bitwise no-op scale
        leaves the fingerprint — and the cache — untouched, with no
        event).  Per entry, ParetoFrontier::apply_delta: re-score resident
        points on the engine from the new LUT (drops past the
        deployability bound only); factor < 1 re-admits newly deployable
        keys with frontier-local dominance pruning."""
        c = cohorts[ci]
        old_lut = c["lut"]
        changed = any(new_lut[k] != old_lut[k] for k in old_lut
                      if VARIANTS[k[0]]["family"] == CFG["family"])
        if not changed:
            c["lut"] = new_lut
            return (0, 0, 0)
        sz_new = len(enumerate_space(c["rep"], new_lut, CFG["family"],
                                     CFG["eps"], {}, {}))
        updated = touched_total = rebuild = 0
        for entry in c["cache"].values():
            # Re-anchoring is the scenario's last mutation before the
            # closing sweep, so no delta ever lands on a stale entry.
            assert not entry["stale"]
            rep_loads = {e: s * BUCKET_LOG2_STEP
                         for e, s in entry["steps"].items()}
            touched = 0
            kept = []
            for p in entry["points"]:
                if p["engine"] != eng:
                    kept.append(p)
                    continue
                touched += 1
                key = (p["variant"], p["engine"], p["threads"],
                       p["governor"])
                rescored = eval_key(c["rep"], new_lut, key, p["r"],
                                    rep_loads)
                if rescored is not None:
                    kept.append(rescored)
            if factor < 1.0:
                news = [k for k in sorted(new_lut.keys(), key=key_sort)
                        if k[1] == eng
                        and (k not in old_lut
                             or old_lut[k] > c["rep"]["max_deployable"])
                        and eval_key(c["rep"], new_lut, k, 1.0, {})
                        is not None]
                cands = []
                for k in news:
                    for r in RATES:
                        q = eval_key(c["rep"], new_lut, k, r, rep_loads)
                        if q is not None:
                            cands.append(q)
                touched += len(cands)
                fresh = [q for q in cands
                         if not any(dominates(p, q) for p in cands)]
                fresh = [q for q in fresh
                         if not any(dominates(p, q) for p in kept)]
                kept = [p for p in kept
                        if not any(dominates(q, p) for q in fresh)]
                kept.extend(fresh)
            kept.sort(key=rank_key)
            entry["points"] = kept
            updated += 1
            touched_total += touched
            rebuild += sz_new
        c["lut"] = new_lut
        if updated > 0:
            tr.emit("frontier_delta", [
                ("scope", f'"{c["id"]}"'),
                ("updated", jnum(updated)),
                ("points_touched", jnum(touched_total)),
                ("rebuild_points", jnum(rebuild)),
            ])
        return (updated, touched_total, rebuild)

    def stats0():
        return dict(samples=0, regret=0.0, slo=0, faults=0)

    def fold_stats(tgt, s):
        tgt["samples"] += s["samples"]
        tgt["regret"] += s["regret"]
        tgt["slo"] += s["slo"]
        tgt["faults"] += s["faults"]

    def regret_mean_of(s):
        return s["regret"] / s["samples"] if s["samples"] else 0.0

    def slo_rate_of(s):
        return s["slo"] / s["samples"] if s["samples"] else 0.0

    def fault_rate_of(s):
        return s["faults"] / s["samples"] if s["samples"] else 0.0

    class RolloutSM:
        """fleet::rollout::Rollout — the canary stage machine with the
        diff-in-diff gates, over the shared `assigned` revision table."""

        def __init__(self, rev, eng, factor):
            self.rev = rev
            self.eng = eng
            self.factor = factor
            self.stage = "proposed"
            self.rung = 0
            self.treated = []
            self.snapshots = {}
            self.baseline = {}
            self.tstats = {}
            self.cstats = stats0()
            self.seen = set()
            self.dups = 0
            self.stale = 0

        def emit(self, stage, n, detail):
            tr.emit("rollout", [
                ("revision", jnum(self.rev)),
                ("stage", f'"{stage}"'),
                ("cohorts", jnum(n)),
                ("detail", f'"{detail}"'),
            ])

        def ingest(self, rep):
            if rep["cohort"] >= len(cohorts):
                return "unknown"
            dk = (rep["cohort"], rep["seq"])
            if dk in self.seen:
                self.dups += 1
                return "duplicate"
            self.seen.add(dk)
            if rep["revision"] != assigned[rep["cohort"]]:
                self.stale += 1
                return "stale"
            if self.stage == "proposed":
                tgt = self.baseline.setdefault(rep["cohort"], stats0())
            elif rep["cohort"] in self.treated:
                tgt = self.tstats.setdefault(rep["cohort"], stats0())
            else:
                tgt = self.cstats
            fold_stats(tgt, rep)
            return "accepted"

        def extend_to(self, n):
            for ci in range(n):
                if ci in self.snapshots:
                    continue
                assert assigned[ci] == 0
                self.snapshots[ci] = dict(cohorts[ci]["lut"])
                new_lut = {k: (v * self.factor if k[1] == self.eng else v)
                           for k, v in cohorts[ci]["lut"].items()}
                apply_cohort_delta(ci, self.eng, self.factor, new_lut)
                assigned[ci] = self.rev
                self.treated.append(ci)

        def begin_canary(self):
            assert self.stage == "proposed"
            n = min(max(ROLLOUT_LADDER[0], 1), len(cohorts))
            for ci in range(n):
                assert assigned[ci] == 0
            self.extend_to(n)
            self.stage = "canary"
            self.emit("canary", len(self.treated), "")

        def hold(self, reason):
            self.emit("held", len(self.treated), reason)
            return ("held", reason)

        def roll_back(self, reason):
            inv = 1.0 / self.factor
            for ci in self.treated:
                apply_cohort_delta(ci, self.eng, inv,
                                   dict(self.snapshots[ci]))
                assigned[ci] = 0
            self.stage = "rolled_back"
            self.emit("rolled_back", 0, reason)
            return ("rolled_back", reason)

        def evaluate(self):
            assert self.stage in ("canary", "widening")
            for ci in self.treated:
                s = self.tstats.get(ci)
                if s is None:
                    return self.hold(
                        f"missing_reports:{cohorts[ci]['id']}")
                if s["samples"] < ROLLOUT_MIN_SAMPLES:
                    return self.hold(
                        f"insufficient_samples:{cohorts[ci]['id']}")
            treated = stats0()
            for ci in sorted(self.tstats):
                fold_stats(treated, self.tstats[ci])
            control = self.cstats
            base = stats0()
            for ci in self.treated:
                if ci in self.baseline:
                    fold_stats(base, self.baseline[ci])
            breach = None
            if (control["samples"] > 0
                    and regret_mean_of(treated) - regret_mean_of(control)
                    > MAX_REGRET_DELTA_PCT):
                breach = (f"regret_delta:"
                          f"{regret_mean_of(treated) - regret_mean_of(control):.3f}")
            elif (control["samples"] == 0
                  and regret_mean_of(treated) > MAX_ABS_REGRET_PCT):
                breach = f"regret_abs:{regret_mean_of(treated):.3f}"
            elif (slo_rate_of(treated) - slo_rate_of(base)
                  > MAX_SLO_MISS_DELTA):
                breach = (f"slo_delta:"
                          f"{slo_rate_of(treated) - slo_rate_of(base):.3f}")
            elif (fault_rate_of(treated) - fault_rate_of(base)
                  > MAX_FAULT_DELTA):
                breach = (f"fault_delta:"
                          f"{fault_rate_of(treated) - fault_rate_of(base):.3f}")
            if breach is not None:
                return self.roll_back(breach)
            if len(self.treated) >= len(cohorts):
                self.stage = "promoted"
                self.snapshots = {}
                self.emit("promoted", len(cohorts), "")
                return ("promoted", None)
            next_rung = 1 if self.stage == "canary" else self.rung + 1
            target = (ROLLOUT_LADDER[next_rung]
                      if next_rung < len(ROLLOUT_LADDER) else len(cohorts))
            target = min(max(target, len(self.treated) + 1), len(cohorts))
            for ci in range(target):
                if ci not in self.snapshots and assigned[ci] != 0:
                    return self.hold(f"cohort_conflict:{cohorts[ci]['id']}")
            self.extend_to(target)
            self.stage = "widening"
            self.rung = next_rung
            self.tstats = {}
            self.cstats = stats0()
            self.emit("widening", len(self.treated), "")
            return ("advanced", None)

    def control_sweep(seq):
        """One telemetry round: every device re-selected at the storm's
        regret-tick snapshots, scored against the precomputed oracle."""
        reports = [dict(cohort=ci, revision=assigned[ci], seq=seq,
                        samples=0, regret=0.0, slo=0, faults=0)
                   for ci in range(len(cohorts))]
        sweep_regrets = []
        n_lookups = 0
        for ti, tick in enumerate(CFG["regret_ticks"]):
            for idx, d in enumerate(devices):
                loads, thermals = storm_conditions(tick, idx, has_npu_of(d))
                sel = scenario_select(device_cohort[idx], loads, thermals)
                n_lookups += 1
                true_lut = oracle_luts[idx]
                sel_adj = adjusted(true_lut, sel, loads, thermals)
                assert sel_adj is not None
                v = VARIANTS[sel[0]]
                admissible = (v["mem"] <= d["true"]["mem_budget"]
                              and true_lut[sel[:4]]
                              <= d["true"]["max_deployable"])
                r = sel_adj / oracle_adj[ti][idx] - 1.0
                rep = reports[device_cohort[idx]]
                if admissible:
                    rv = r
                else:
                    rep["faults"] += 1
                    rv = max(r, 0.0)
                sweep_regrets.append(rv)
                rep["samples"] += 1
                rep["regret"] += 100.0 * rv
                if sel_adj > ROLLOUT_SLO_MS:
                    rep["slo"] += 1
        return reports, sweep_regrets, n_lookups

    step_us = int(CFG["tick_ms"] * 1000.0)
    base_us = CFG["ticks"] * step_us
    clock = dict(k=0)

    def advance_clock():
        clock["k"] += 1
        tr.set_now_us(base_us + clock["k"] * step_us)

    cp_lookups = 0

    # Pre-canary baseline round: anchors the self-controlled SLO/fault
    # gates of both rollouts.
    advance_clock()
    baseline_reports, _, lk = control_sweep(0)
    cp_lookups += lk
    baseline_samples = sum(r["samples"] for r in baseline_reports)

    # -- the mispredicted revision: canary, gate breach, auto-rollback --
    bad = RolloutSM(1, ROLLOUT_ENGINE, ROLLOUT_BAD_FACTOR)
    for rep in baseline_reports:
        assert bad.ingest(rep) == "accepted"
    canary_n = min(ROLLOUT_LADDER[0], len(cohorts))
    pre_snap = [dict(cohorts[ci]["lut"]) for ci in range(canary_n)]
    advance_clock()
    bad.begin_canary()
    advance_clock()
    bad_reports, _, lk = control_sweep(1)
    cp_lookups += lk
    for rep in bad_reports:
        assert bad.ingest(rep) == "accepted"
    outcome, bad_reason = bad.evaluate()
    assert outcome == "rolled_back", (outcome, bad_reason)
    assert sum(1 for a in assigned if a == 1) == 0
    post_snap = [dict(cohorts[ci]["lut"]) for ci in range(canary_n)]
    fp_match = pre_snap == post_snap
    assert fp_match, "rollback failed to restore treated LUTs bit-identically"
    tsum = csum = 0.0
    tn = cn = 0
    for rep in bad_reports:
        if rep["cohort"] in bad.treated:
            tsum += rep["regret"]
            tn += rep["samples"]
        else:
            csum += rep["regret"]
            cn += rep["samples"]
    bad_canary_regret = tsum / max(tn, 1)
    bad_control_regret = csum / max(cn, 1)

    # -- the good revision: canary, widen up the ladder, promote --
    good = RolloutSM(2, ROLLOUT_ENGINE, ROLLOUT_GOOD_FACTOR)
    for rep in baseline_reports:
        assert good.ingest(rep) == "accepted"
    advance_clock()
    good.begin_canary()
    good_rounds = 0
    seq = 2
    while True:
        advance_clock()
        sweep_reports, _, lk = control_sweep(seq)
        cp_lookups += lk
        for rep in sweep_reports:
            assert good.ingest(rep) == "accepted"
        if good_rounds == 0:
            # A replayed (cohort, seq) report must be discarded.
            assert good.ingest(sweep_reports[0]) == "duplicate"
        good_rounds += 1
        seq += 1
        outcome, reason = good.evaluate()
        if outcome == "promoted":
            break
        assert outcome == "advanced", (outcome, reason)
        assert good_rounds <= len(cohorts), "rollout failed to terminate"
    assert good.stage == "promoted"
    assert sum(1 for a in assigned if a == 2) == len(cohorts)

    # -- residual feedback: observe, correct through the delta path --
    fb_cells = {}  # (cohort, engine idx) -> [sum_ln, sum_abs_ln, samples]
    fb_accumulated = {}
    residual_rounds = []
    fb_samples = 0
    fb_corrections = 0
    fb_delta = [0, 0, 0]
    for _ in range(FEEDBACK_ROUNDS):
        advance_clock()
        for tick in CFG["regret_ticks"]:
            for idx, d in enumerate(devices):
                loads, thermals = storm_conditions(tick, idx, has_npu_of(d))
                ci = device_cohort[idx]
                sel = scenario_select(ci, loads, thermals)
                cp_lookups += 1
                key = sel[:4]
                measured = oracle_luts[idx][key]
                predicted = cohorts[ci]["lut"][key]
                # RuntimeManager::record_latency is decision-inert (no
                # trace, no counters): not modelled.
                if (measured > 0.0 and predicted > 0.0
                        and math.isfinite(measured)
                        and math.isfinite(predicted)):
                    ln = math.log(measured / predicted)
                    cell = fb_cells.setdefault(
                        (ci, ENGINE_ORDER.index(sel[1])), [0.0, 0.0, 0])
                    cell[0] += ln
                    cell[1] += abs(ln)
                    cell[2] += 1
        # FeedbackLoop::apply_round: cells in (cohort, engine) order.
        cells = dict(fb_cells)
        fb_cells.clear()
        round_samples = 0
        sum_abs_total = 0.0
        for ck in sorted(cells):
            ci, ei = ck
            sum_ln, sum_abs, n = cells[ck]
            round_samples += n
            sum_abs_total += sum_abs
            if n < FB_MIN_SAMPLES:
                continue
            mean_ln = sum_ln / n
            factor = math.exp(mean_ln)
            eng = ENGINE_ORDER[ei]
            new_lut = {k: (v * factor if k[1] == eng else v)
                       for k, v in cohorts[ci]["lut"].items()}
            u, t, rb = apply_cohort_delta(ci, eng, factor, new_lut)
            fb_delta = [fb_delta[0] + u, fb_delta[1] + t, fb_delta[2] + rb]
            fb_corrections += 1
            fb_accumulated[ci] = fb_accumulated.get(ci, 0.0) + abs(mean_ln)
            tr.emit("residual", [
                ("cohort", f'"{cohorts[ci]["id"]}"'),
                ("engine", f'"{eng}"'),
                ("samples", jnum(n)),
                ("factor", jnum(r3(factor))),
            ])
        fb_samples += round_samples
        residual_rounds.append(
            sum_abs_total / round_samples if round_samples else 0.0)
    for prev, cur in zip(residual_rounds, residual_rounds[1:]):
        assert cur <= prev + 1e-9, residual_rounds

    # -- re-anchor drifted cohorts, then the closing regret round --
    advance_clock()
    re_anchored = []
    for ci, m in sorted(fb_accumulated.items()):
        if m <= RE_ANCHOR_THRESHOLD:
            continue
        member = cohorts[ci]["members"][0]
        anchor_lut = build_lut(devices[member]["true"], CFG["lut_runs"])
        cohorts[ci]["lut"] = anchor_lut
        for e in cohorts[ci]["cache"].values():
            e["stale"] = True  # lazy scope-fingerprint invalidation
        fb_accumulated[ci] = 0.0
        tr.emit("re_anchor", [
            ("cohort", f'"{cohorts[ci]["id"]}"'),
            ("device", f'"d{member:04d}"'),
            ("magnitude", jnum(r3(m))),
            ("entries", jnum(len(anchor_lut))),
        ])
        re_anchored.append(ci)
    assert re_anchored, "no cohort crossed the re-anchor threshold"
    assert len(re_anchored) < len(cohorts), re_anchored
    builds_before_post = sc["builds"]
    advance_clock()
    post_reports, post_regrets, lk = control_sweep(seq)
    cp_lookups += lk
    post_builds = sc["builds"] - builds_before_post
    post_sum = 0.0
    for rv in post_regrets:
        post_sum += rv
    post_mean = post_sum / max(len(post_regrets), 1)
    post_max = 0.0
    for rv in post_regrets:
        post_max = max(post_max, rv)
    post_faults = sum(rep["faults"] for rep in post_reports)
    improved = post_mean <= regret_mean
    assert improved, (post_mean, regret_mean)
    # Every control-plane lookup accounted by its own sweeps.
    assert sc["builds"] + sc["hits"] == cp_lookups
    for c in cohorts:
        resident_c = sum(FRONTIER_BASE_BYTES
                         + FRONTIER_POINT_BYTES * len(e["points"])
                         for e in c["cache"].values())
        assert resident_c <= mem_budget_per_cohort, (c["id"], resident_c)

    # -- JSON emission (mirrors experiments::fleetbench::report_json) -----
    config = jobj([
        ("devices", jnum(CFG["size"])),
        ("seed", jnum(CFG["seed"])),
        ("family", f'"{CFG["family"]}"'),
        ("objective", '"min_latency(avg,eps=0.05)"'),
        ("lut_runs", jnum(CFG["lut_runs"])),
        ("noise_sigma", jnum(0.0)),
        ("flops_log_spread", jnum(CFG["flops_log_spread"])),
        ("bw_log_spread", jnum(CFG["bw_log_spread"])),
        ("thermal_log_spread", jnum(CFG["thermal_log_spread"])),
        ("mem_log_spread", jnum(CFG["mem_log_spread"])),
        ("latent_log_spread", jnum(CFG["latent_log_spread"])),
        ("npu_drop_prob", jnum(CFG["npu_drop_prob"])),
        ("confidence_threshold", jnum(CFG["confidence_threshold"])),
        ("probes_per_engine", jnum(CFG["probes_per_engine"])),
        ("frontier_cache_cap", jnum(CFG["frontier_cache_cap"])),
        ("frontier_mem_budget_bytes",
         jnum(CFG["frontier_mem_budget_bytes"])),
        ("ticks", jnum(CFG["ticks"])),
        ("tick_ms", jnum(CFG["tick_ms"])),
    ])
    population = jobj([
        ("archetypes", jobj([(a, jnum(arch_counts[a])) for a in ARCHETYPES])),
        ("npu_dropped", jnum(npu_dropped)),
        ("cohorts", jnum(len(cohorts))),
    ])
    transfer = jobj([
        ("probed_cohorts", jnum(probed_cohorts)),
        ("probe_measurements", jnum(probe_measurements)),
        ("pred_err_mean_pct", jnum(r3(100.0 * err_sum / max(err_n, 1)))),
        ("pred_err_max_pct", jnum(r3(100.0 * err_max))),
    ])
    cohort_rows = []
    for c in cohorts:
        min_conf = min(e["confidence"] for e in c["engines"].values())
        cohort_rows.append(jobj([
            ("id", f'"{c["id"]}"'),
            ("members", jnum(len(c["members"]))),
            ("probed", jbool(any(e["probed"] for e in c["engines"].values()))),
            ("min_confidence", jnum(r3(min_conf))),
            ("builds", jnum(c["builds"])),
            ("hits", jnum(c["hits"])),
        ]))
    storm = jobj([
        ("ticks", jnum(CFG["ticks"])),
        ("decisions", jnum(CFG["ticks"] * CFG["size"])),
        ("switches", jnum(switches)),
        ("switch_load", jnum(switch_load)),
        ("switch_degradation", jnum(switch_degradation)),
        ("holds", jobj([
            ("not_due", jnum(holds["not_due"])),
            ("cooldown", jnum(holds["cooldown"])),
            ("no_trigger", jnum(holds["no_trigger"])),
            ("no_alternative", jnum(holds["no_alternative"])),
            ("current_still_best", jnum(holds["current_still_best"])),
            ("below_hysteresis", jnum(holds["below_hysteresis"])),
        ])),
        ("devices_switched",
         jnum(sum(1 for s in per_device_switches if s > 0))),
        ("max_switches_per_device", jnum(max(per_device_switches))),
    ])
    regret = jobj([
        ("events", jnum(len(regrets))),
        ("mean_pct", jnum(r3(100.0 * regret_mean))),
        ("max_pct", jnum(r3(100.0 * regret_max))),
        ("zero_share", jnum(r3(zero / max(len(regrets), 1)))),
        ("deploy_faults", jnum(deploy_faults)),
    ])
    delta = jobj([
        ("engine", f'"{CORRECTION_ENGINE}"'),
        ("factor", jnum(CORRECTION_FACTOR)),
        ("updated", jnum(delta_updated)),
        ("points_touched", jnum(delta_points_touched)),
        ("rebuild_points", jnum(delta_rebuild_points)),
        ("delta_lt_rebuild",
         jbool(delta_points_touched < delta_rebuild_points)),
        ("idempotent_reapply_updates", jnum(0)),
        ("post_correction_builds", jnum(0)),
    ])
    cache = jobj([
        ("builds", jnum(builds)),
        ("hits", jnum(hits)),
        ("bench_lookups", jnum(len(regrets))),
        ("evictions", jnum(0)),
        ("hit_rate", jnum(r3(hits / max(hits + builds, 1)))),
        ("builds_lt_devices", jbool(builds < CFG["size"])),
        ("resident_bytes", jnum(resident_bytes)),
        ("mem_budget_per_cohort", jnum(mem_budget_per_cohort)),
        ("under_budget",
         jbool(resident_bytes <= mem_budget_per_cohort * len(cohorts))),
        ("candidates_enumerated", jnum(candidates_enumerated)),
        ("decisions_per_sec_amortized",
         jnum(r3(float(CFG["ticks"] * CFG["size"]) * 1e9
                 / (float(SIM_NS_PER_EVAL)
                    * float(max(candidates_enumerated, 1)))))),
    ])
    rollout = jobj([
        ("engine", f'"{ROLLOUT_ENGINE}"'),
        ("ladder", "[" + ",".join(jnum(n) for n in ROLLOUT_LADDER) + "]"),
        ("min_samples", jnum(ROLLOUT_MIN_SAMPLES)),
        ("max_regret_delta_pct", jnum(MAX_REGRET_DELTA_PCT)),
        ("max_slo_miss_delta", jnum(MAX_SLO_MISS_DELTA)),
        ("max_fault_delta", jnum(MAX_FAULT_DELTA)),
        ("slo_ms", jnum(r3(ROLLOUT_SLO_MS))),
        ("baseline_samples", jnum(baseline_samples)),
        ("bad_revision", jnum(bad.rev)),
        ("bad_factor", jnum(ROLLOUT_BAD_FACTOR)),
        ("bad_stage", f'"{bad.stage}"'),
        ("bad_reason", f'"{bad_reason}"'),
        ("bad_canary_regret_pct", jnum(r3(bad_canary_regret))),
        ("bad_control_regret_pct", jnum(r3(bad_control_regret))),
        ("bad_live_cohorts",
         jnum(sum(1 for a in assigned if a == bad.rev))),
        ("rollback_fingerprints_match", jbool(fp_match)),
        ("good_revision", jnum(good.rev)),
        ("good_factor", jnum(ROLLOUT_GOOD_FACTOR)),
        ("good_stage", f'"{good.stage}"'),
        ("good_rounds", jnum(good_rounds)),
        ("good_live_cohorts",
         jnum(sum(1 for a in assigned if a == good.rev))),
        ("duplicates_rejected", jnum(good.dups)),
        ("lookups", jnum(cp_lookups)),
    ])
    feedback = jobj([
        ("rounds", jnum(FEEDBACK_ROUNDS)),
        ("samples", jnum(fb_samples)),
        ("corrections", jnum(fb_corrections)),
        ("mean_abs_ln",
         "[" + ",".join(jnum(r3(v)) for v in residual_rounds) + "]"),
        ("delta_updated", jnum(fb_delta[0])),
        ("delta_points_touched", jnum(fb_delta[1])),
        ("delta_rebuild_points", jnum(fb_delta[2])),
        ("re_anchor_threshold", jnum(RE_ANCHOR_THRESHOLD)),
        ("re_anchored_cohorts", jnum(len(re_anchored))),
        ("post_feedback_builds", jnum(post_builds)),
        ("pre_regret_mean_pct", jnum(r3(100.0 * regret_mean))),
        ("post_regret_mean_pct", jnum(r3(100.0 * post_mean))),
        ("post_regret_max_pct", jnum(r3(100.0 * post_max))),
        ("post_deploy_faults", jnum(post_faults)),
        ("regret_improved", jbool(improved)),
    ])
    inner = jobj([
        ("config", config),
        ("population", population),
        ("transfer", transfer),
        ("cohorts", "[" + ",".join(cohort_rows) + "]"),
        ("storm", storm),
        ("regret", regret),
        ("delta", delta),
        ("cache", cache),
        ("rollout", rollout),
        ("feedback", feedback),
    ])
    return jobj([("fleet_bench", inner)]) + "\n", tr.dump()


def main():
    gdir = os.path.normpath(os.path.join(
        os.path.dirname(__file__), "..", "rust", "tests", "golden"))
    golden = os.path.join(gdir, "fleetbench_smoke.json")
    golden_trace = os.path.join(gdir, "fleetbench_smoke_trace.jsonl")
    golden_summary = os.path.join(gdir, "trace_summary.json")
    content, trace = run_fleetbench_smoke()
    summary = trace_summary(trace)
    # Span-layer acceptance invariants, asserted on the oracle's own
    # reconstruction (the Rust property suite re-asserts them).
    s = json.loads(summary)
    n_switch = sum(1 for ln in trace.splitlines() if '"ev":"switch"' in ln)
    assert s["adaptation"]["spans"] == n_switch, (
        s["adaptation"]["spans"], n_switch)
    assert s["serving"]["unclosed_requests"] == 0
    assert s["serving"]["unclosed_batches"] == 0
    assert s["rollouts"]["all_rollbacks_linked"] is True
    assert s["sampling"]["tail_reduction_ge_4x"] is True, s["sampling"]
    assert s["sampling"]["anomalous_retained_pct"] == 100.0, s["sampling"]
    if "--check" in sys.argv:
        ok = True
        for path, want_content in [(golden, content), (golden_trace, trace),
                                   (golden_summary, summary)]:
            have = open(path).read()
            if have != want_content:
                print(f"DRIFT: {path} does not match oracle",
                      file=sys.stderr)
                ok = False
            else:
                print(f"{path} matches oracle", file=sys.stderr)
        return 0 if ok else 1
    with open(golden, "w") as f:
        f.write(content)
    print(f"wrote {golden} ({len(content)} bytes)", file=sys.stderr)
    with open(golden_trace, "w") as f:
        f.write(trace)
    print(f"wrote {golden_trace} ({len(trace)} bytes)", file=sys.stderr)
    with open(golden_summary, "w") as f:
        f.write(summary)
    print(f"wrote {golden_summary} ({len(summary)} bytes)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
