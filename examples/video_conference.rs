//! Video-conferencing AR segmentation — the paper's TargetLatency use-case
//! (Eq. 4): maximise accuracy subject to a latency budget, running the
//! DeepLabV3 analogue end-to-end with real PJRT numerics.
//!
//! The latency budget sweeps from loose to tight, showing the optimiser
//! descending the accuracy/latency Pareto front — tighter budgets force
//! cheaper precisions/engines or (ultimately) infeasibility.
//!
//! Run: `cargo run --release --example video_conference [device]`

use oodin::measurements::Measurer;
use oodin::optimizer::{Objective, Optimizer, SearchSpace};
use oodin::runtime::{default_backend, Backend};
use oodin::util::stats::Percentile;
use oodin::mdcl;

const FAMILY: &str = "deeplab_v3";

fn main() -> anyhow::Result<()> {
    let device_name = std::env::args().nth(1).unwrap_or("samsung_s20_fe".into());
    let registry = oodin::load_registry_or_synthetic()?;
    let device = mdcl::detect(&device_name)?;
    let lut = Measurer::new(&device, &registry).with_runs(100, 10).measure_all()?;
    let opt = Optimizer::new(&device, &registry, &lut).with_camera_fps(30.0);

    println!("VIDEO-CONFERENCE AR SEGMENTATION on {} ({FAMILY})", device.name);
    println!("TargetLatency (Eq. 4): max accuracy s.t. p90 latency <= budget\n");
    println!("{:>12} {:<26} {:<7} {:>9} {:>10} {:>8}",
             "budget ms", "variant", "engine", "p90 ms", "mIoU", "thr");

    let mut chosen = None;
    for budget in [5.0, 2.0, 1.0, 0.5, 0.2, 0.1, 0.05] {
        let r = opt.optimize(
            Objective::TargetLatency { t_target_ms: budget, stat: Percentile::P90 },
            &SearchSpace::family(FAMILY),
        );
        match r {
            Ok(best) => {
                println!("{:>12.2} {:<26} {:<7} {:>9.4} {:>9.2}% {:>8}",
                         budget, best.design.variant,
                         best.design.hw.engine.name(), best.latency_ms,
                         best.accuracy * 100.0, best.design.hw.threads);
                chosen.get_or_insert(best);
            }
            Err(_) => println!("{budget:>12.2} -- infeasible on this device --"),
        }
    }

    // Run the loosest-budget winner for real: full segmentation maps out of
    // the AOT artifact.
    let Some(best) = chosen else {
        println!("no feasible design at any budget");
        return Ok(());
    };
    let v = registry.get(&best.design.variant).unwrap();
    let rt = default_backend(&device, &registry)?;
    rt.load(&v.name, &registry.hlo_path(v))?;
    let mut cam = oodin::sil::SyntheticCamera::new(v.resolution, 30.0, 3);
    println!("\nreal segmentation through {} ({} -> {:?}):",
             v.name, v.resolution, v.output_shape);
    for i in 0..5 {
        let f = cam.capture(i as f64 * 33.3);
        let out = rt.execute(&v.name, f.data, &v.input_shape)?;
        // Per-pixel argmax over 5 classes; report foreground fraction.
        let hw = v.resolution * v.resolution;
        let mut fg = 0usize;
        for p in 0..hw {
            let logits = &out.values[p * 5..(p + 1) * 5];
            let cls = (0..5).max_by(|&a, &b| logits[a].total_cmp(&logits[b])).unwrap();
            if cls != 0 {
                fg += 1;
            }
        }
        println!("  frame {i}: {:.1}% foreground pixels, host {:.2} ms",
                 100.0 * fg as f64 / hw as f64, out.host_ms);
    }
    rt.shutdown();
    Ok(())
}
