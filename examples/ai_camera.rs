//! AI Camera — the paper's flagship use-case (Eq. 3, MaxFPS) and this
//! repo's END-TO-END VALIDATION driver.
//!
//! Full stack on a real workload: synthetic Camera2 stream → SIL → DLACL
//! input pipeline → real PJRT execution of the AOT artifact (real numerics,
//! measured online accuracy) → gallery persistence → middleware-c stats →
//! Runtime Manager.  For the OODIn-selected design *and* the three oSQ
//! baselines it reports throughput, latency (simulated-device and host
//! wall-clock) and online top-1 accuracy — demonstrating the headline
//! claim's shape end-to-end.  Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example ai_camera [device] [frames]`

use oodin::app::{AppConfig, Application};
use oodin::device::EngineKind;
use oodin::optimizer::{Objective, SearchSpace};
use oodin::util::stats::LatencyStats;

const FAMILY: &str = "mobilenet_v2_100";

struct RunSummary {
    label: String,
    fps: f64,
    sim_latency: LatencyStats,
    host_latency: Option<LatencyStats>,
    online_acc: f64,
    engine: String,
}

fn run_space(device: &str, frames: u64, label: &str, space: SearchSpace)
             -> anyhow::Result<Option<RunSummary>> {
    let registry = oodin::load_registry_or_synthetic()?;
    let mut cfg = AppConfig::new(device, Objective::MaxFps { epsilon: 0.015 }, space);
    cfg.real_exec = true;
    cfg.lut_runs = 100;
    let mut app = match Application::build(cfg, registry) {
        Ok(a) => a,
        Err(_) => return Ok(None), // space infeasible on this device
    };
    let t0 = app.sim.clock.now_ms();
    let recs = app.run(frames, &[])?;
    let elapsed_s = (app.sim.clock.now_ms() - t0) / 1e3;

    let sim: Vec<f64> = recs.iter().map(|r| r.latency_ms).collect();
    let host: Vec<f64> = recs.iter().filter_map(|r| r.host_ms).collect();
    let (mut ok, mut tot) = (0, 0);
    for r in &recs {
        if let Some(c) = r.correct {
            tot += 1;
            if c {
                ok += 1;
            }
        }
    }
    let summary = RunSummary {
        label: label.to_string(),
        fps: recs.len() as f64 / elapsed_s,
        sim_latency: LatencyStats::from_samples(&sim),
        host_latency: if host.is_empty() {
            None
        } else {
            Some(LatencyStats::from_samples(&host))
        },
        online_acc: ok as f64 / tot.max(1) as f64,
        engine: app.current_design().hw.engine.name().to_string(),
    };
    println!("  gallery entries: {}", app.gallery.len());
    app.shutdown();
    Ok(Some(summary))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let device = args.first().map(String::as_str).unwrap_or("samsung_a71");
    let frames: u64 = args.get(1).map_or(Ok(300), |s| s.parse())?;

    println!("AI CAMERA end-to-end on {device} ({frames} frames, {FAMILY})");
    println!("================================================================");

    let mut rows = Vec::new();
    let spaces: Vec<(&str, SearchSpace)> = vec![
        ("OODIn", SearchSpace::family(FAMILY)),
        ("oSQ-CPU", SearchSpace::family(FAMILY).with_engines(&[EngineKind::Cpu])),
        ("oSQ-GPU", SearchSpace::family(FAMILY).with_engines(&[EngineKind::Gpu])),
        ("oSQ-NNAPI", SearchSpace::family(FAMILY).with_engines(&[EngineKind::Npu])),
    ];
    for (label, space) in spaces {
        println!("[{label}]");
        if let Some(s) = run_space(device, frames, label, space)? {
            rows.push(s);
        } else {
            println!("  not available on this device");
        }
    }

    println!("\n{:<10} {:<7} {:>8} {:>12} {:>12} {:>12} {:>8}",
             "design", "engine", "fps", "sim avg ms", "sim p90 ms",
             "host avg ms", "top-1");
    for r in &rows {
        println!(
            "{:<10} {:<7} {:>8.1} {:>12.4} {:>12.4} {:>12} {:>7.1}%",
            r.label, r.engine, r.fps, r.sim_latency.avg, r.sim_latency.p90,
            r.host_latency.as_ref().map_or("n/a".into(),
                                           |h| format!("{:9.3}", h.avg)),
            r.online_acc * 100.0,
        );
    }
    if let Some(oodin) = rows.first() {
        for b in rows.iter().skip(1) {
            println!("OODIn speedup over {}: {:.2}x (sim avg)",
                     b.label, b.sim_latency.avg / oodin.sim_latency.avg);
        }
    }
    Ok(())
}
