//! Run-time adaptation live demo (paper §IV-C, Fig 7 + Fig 8): the full
//! Application with real PJRT numerics while the simulated device degrades.
//!
//! Phase 1 (device load): external load ramps on the active engine; the
//! Runtime Manager migrates engines to sustain latency.
//! Phase 2 (thermal): a continuous max-rate stream overheats the active
//! engine; throttling is detected and execution migrates again.
//!
//! Run: `cargo run --release --example adaptation [frames_per_phase]`

use oodin::app::{AppConfig, Application, ScenarioEvent};
use oodin::experiments::fig8;
use oodin::manager::Policy;
use oodin::optimizer::{Objective, SearchSpace};
use oodin::util::stats::Percentile;

fn main() -> anyhow::Result<()> {
    let frames: u64 = std::env::args().nth(1).map_or(Ok(240), |s| s.parse())?;
    let registry = oodin::load_registry_or_synthetic()?;
    let family = registry.family_or("mobilenet_v2_140", "mobilenet_v2_100");

    // ---- Phase 1: load-driven adaptation (Fig 7 conditions) -------------
    println!("PHASE 1 — device load ({family} on samsung_a71)");
    let mut cfg = AppConfig::new(
        "samsung_a71",
        Objective::MinLatency { stat: Percentile::P90, epsilon: 0.0 },
        SearchSpace::family(family),
    );
    cfg.real_exec = true;
    cfg.live_ui = true;
    cfg.lut_runs = 80;
    cfg.policy = Policy { check_interval_ms: 100.0, cooldown_ms: 400.0,
                          ..Policy::default() };
    let mut app = Application::build(cfg, registry.clone())?;
    let e0 = app.current_design().hw.engine;
    println!("initial engine: {}", e0.name());

    let scenario = vec![
        ScenarioEvent::SetLoad { at_frame: frames / 4, engine: e0, load: 1.0 },
        ScenarioEvent::SetLoad { at_frame: frames / 2, engine: e0, load: 2.0 },
    ];
    let recs = app.run(frames, &scenario)?;
    let switches: Vec<_> = recs.iter().filter(|r| r.switch.is_some()).collect();
    println!("processed {} frames, {} engine migrations", recs.len(),
             switches.len());
    let early: f64 = recs.iter().take(20).map(|r| r.latency_ms).sum::<f64>() / 20.0;
    let late: f64 = recs.iter().rev().take(20).map(|r| r.latency_ms).sum::<f64>() / 20.0;
    println!("avg latency: first 20 frames {early:.4} ms, last 20 {late:.4} ms");
    let acc = recs.iter().filter_map(|r| r.correct).filter(|&c| c).count() as f64
        / recs.iter().filter(|r| r.correct.is_some()).count().max(1) as f64;
    println!("online top-1 through all migrations: {:.1}%", acc * 100.0);
    app.shutdown();

    // ---- Phase 2: thermal-driven adaptation (Fig 8 conditions) ----------
    println!("\nPHASE 2 — thermal throttling (inception_v3 on samsung_a71)");
    let r = fig8::run(&registry, frames.max(600))?;
    println!("initial engine: {}", r.initial_engine.name());
    if let Some(t) = r.first_throttle_at {
        println!("first throttling at inference {t}");
    }
    for (i, sw) in &r.switches {
        println!("  migration at inference {i}: {} -> {} ({:?})",
                 sw.from.hw.engine.name(), sw.to.hw.engine.name(), sw.reason);
    }
    Ok(())
}
