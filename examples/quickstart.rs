//! Quickstart: the OODIn pipeline in ~40 effective lines.
//!
//! Loads the model zoo (AOT artifacts, or the synthetic registry when
//! they are absent), detects a device, runs Device Measurements, solves a
//! MaxFPS use-case (paper Eq. 3), and pushes a few frames through the
//! selected design on the default execution backend (PJRT or SimBackend).
//!
//! Run with: `cargo run --release --example quickstart`

use oodin::dlacl::decode_top1;
use oodin::measurements::Measurer;
use oodin::optimizer::{Objective, Optimizer, SearchSpace};
use oodin::runtime::{default_backend, Backend};
use oodin::sil::SyntheticCamera;
use oodin::mdcl;

fn main() -> anyhow::Result<()> {
    // 1. The model space M (built by `make artifacts`, or synthetic).
    let registry = oodin::load_registry_or_synthetic()?;
    println!("loaded {} model variants across {} families",
             registry.variants().len(), registry.families().len());

    // 2. MDCL resource detection: populate R for the target device.
    let device = mdcl::detect("samsung_a71")?;
    println!("R = {}", mdcl::format_resource_model(&device));

    // 3. Device Measurements: sweep <ce, threads, governor> per variant.
    let lut = Measurer::new(&device, &registry).with_runs(60, 6).measure_all()?;
    println!("measured {} configurations", lut.len());

    // 4. System Optimisation: MaxFPS with <=1.5% accuracy drop (Eq. 3).
    let opt = Optimizer::new(&device, &registry, &lut).with_camera_fps(30.0);
    let best = opt.optimize(
        Objective::MaxFps { epsilon: 0.015 },
        &SearchSpace::family("mobilenet_v2_100"),
    )?;
    println!(
        "σ = <{}, {}, threads={}, governor={}, r={}>  →  {:.1} fps @ {:.3} ms, acc {:.1}%",
        best.design.variant,
        best.design.hw.engine.name(),
        best.design.hw.threads,
        best.design.hw.governor.name(),
        best.design.hw.recognition_rate,
        best.fps,
        best.latency_ms,
        best.accuracy * 100.0,
    );

    // 5. Inference through the execution backend (python never runs here).
    let rt = default_backend(&device, &registry)?;
    let variant = registry.get(&best.design.variant).unwrap();
    rt.load(&variant.name, &registry.hlo_path(variant))?;
    let mut camera = SyntheticCamera::new(variant.resolution, 30.0, 1);
    let mut correct = 0;
    let n = 20;
    for i in 0..n {
        let frame = camera.capture(i as f64 * 33.3);
        let out = rt.execute(&variant.name, frame.data, &variant.input_shape)?;
        let (cls, conf) = decode_top1(&out.values, 10);
        if cls == frame.label {
            correct += 1;
        }
        if i < 3 {
            println!("frame {i}: predicted {cls} (label {}, logit {conf:.2}, host {:.2} ms)",
                     frame.label, out.host_ms);
        }
    }
    println!("online accuracy: {correct}/{n}");
    rt.shutdown();
    Ok(())
}
