//! Property tests for the fleet control plane's staged rollouts
//! (`fleet::rollout`): stage transitions are monotone and cannot skip
//! the canary, rollback restores every treated cohort's LUT
//! bit-identically (scoped fingerprints), no cohort ever carries two
//! live revisions, promotion requires every gate to pass with
//! sufficient samples from every treated cohort, and the whole
//! lifecycle is bit-deterministic per fleet seed.

use std::sync::Arc;

use oodin::designspace::scoped_fingerprint;
use oodin::device::EngineKind;
use oodin::fleet::{CohortReport, Fleet, FleetConfig, IngestOutcome,
                   PopulationConfig, RevisionRegistry, Rollout,
                   RolloutConfig, RolloutOutcome, RolloutStage,
                   BASELINE_REVISION};
use oodin::manager::Conditions;
use oodin::model::test_fixtures::fake_registry;
use oodin::optimizer::{Objective, SearchSpace};
use oodin::util::stats::Percentile;

fn obj() -> Objective {
    Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }
}

fn space() -> SearchSpace {
    SearchSpace::family("mobilenet_v2_100")
}

fn build_fleet() -> Fleet {
    let cfg = FleetConfig {
        population: PopulationConfig { size: 64, ..Default::default() },
        ..Default::default()
    };
    let fleet = Fleet::build(Arc::new(fake_registry()), cfg).unwrap();
    assert!(fleet.cohorts.len() >= 8,
            "need enough cohorts to stage over, got {}",
            fleet.cohorts.len());
    fleet
}

/// One synthetic telemetry report: `samples` decisions at a uniform
/// per-decision regret, no SLO misses, no deploy faults.
fn report(cohort: usize, revision: u64, seq: u64, samples: u64,
          regret_mean_pct: f64) -> CohortReport {
    CohortReport {
        cohort,
        revision,
        seq,
        samples,
        regret_pct_sum: regret_mean_pct * samples as f64,
        slo_misses: 0,
        deploy_faults: 0,
    }
}

/// Ingest one full-fleet telemetry round: treated cohorts at
/// `treated_pct` mean regret, the rest at `control_pct`, every report
/// tagged with its cohort's live revision.
fn ingest_round(rollout: &mut Rollout, reg: &RevisionRegistry,
                cohorts: usize, seq: u64, treated_pct: f64,
                control_pct: f64) {
    let treated: Vec<usize> = rollout.treated().to_vec();
    for ci in 0..cohorts {
        let pct = if treated.contains(&ci) {
            treated_pct
        } else {
            control_pct
        };
        let r = report(ci, reg.live(ci), seq, 4, pct);
        assert_eq!(rollout.ingest(r, reg), IngestOutcome::Accepted);
    }
}

fn fingerprints(fleet: &Fleet) -> Vec<u64> {
    let sspace = space();
    fleet
        .cohorts
        .iter()
        .map(|c| scoped_fingerprint(&c.lut, &fleet.registry, &sspace))
        .collect()
}

// ---------------------------------------------------------------------------
// Property 1: stage transitions are monotone — Proposed → Canary →
// Widening(1..) → Promoted, with strictly growing exposure, and the
// canary can never be skipped.
// ---------------------------------------------------------------------------

#[test]
fn stages_are_monotone_and_never_skip_canary() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.9);
    let mut ro = Rollout::new(rev, RolloutConfig::default());

    // Evaluating while still Proposed holds without side effects: the
    // only exit from Proposed is begin_canary.
    let fps0 = fingerprints(&fleet);
    match ro.evaluate(&mut fleet, &mut reg) {
        RolloutOutcome::Held { reason } => {
            assert_eq!(reason, "stage_proposed")
        }
        other => panic!("evaluate on Proposed must hold, got {other:?}"),
    }
    assert_eq!(ro.stage(), RolloutStage::Proposed);
    assert_eq!(fingerprints(&fleet), fps0);
    assert_eq!(reg.live_count(rev.id), 0);

    ro.begin_canary(&mut fleet, &mut reg).unwrap();
    assert_eq!(ro.stage(), RolloutStage::Canary);
    // A second begin_canary is a stage violation.
    assert!(ro.begin_canary(&mut fleet, &mut reg).is_err());

    let mut seq = 0u64;
    let mut exposures = vec![ro.treated().len()];
    let mut saw_widening_rung = 0usize;
    loop {
        ingest_round(&mut ro, &reg, n, seq, 1.0, 1.0);
        seq += 1;
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::Promoted => break,
            RolloutOutcome::Advanced { stage, treated } => {
                // Widening rungs are visited in order, never skipped.
                match stage {
                    RolloutStage::Widening(k) => {
                        assert_eq!(k, saw_widening_rung + 1,
                                   "rung order violated");
                        saw_widening_rung = k;
                    }
                    other => panic!("advance into {other:?}"),
                }
                assert_eq!(treated, ro.treated().len());
                exposures.push(treated);
            }
            other => panic!("clean rollout must advance, got {other:?}"),
        }
        assert!(exposures.len() <= n, "rollout failed to terminate");
    }
    assert_eq!(ro.stage(), RolloutStage::Promoted);
    // Exposure is strictly monotone and starts at the first ladder rung
    // (the canary) — never at a wider one.
    assert_eq!(exposures[0], RolloutConfig::default().ladder[0].min(n));
    assert!(exposures.windows(2).all(|w| w[0] < w[1]),
            "exposure not strictly monotone: {exposures:?}");
    assert_eq!(reg.live_count(rev.id), n);
    // A promoted rollout is terminal.
    match ro.evaluate(&mut fleet, &mut reg) {
        RolloutOutcome::Held { reason } => {
            assert_eq!(reason, "stage_promoted")
        }
        other => panic!("evaluate on Promoted must hold, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Property 2: rollback restores every treated cohort bit-identically —
// scoped fingerprints, live selections, and the warm caches all land
// exactly on the pre-canary state.
// ---------------------------------------------------------------------------

#[test]
fn rollback_restores_exact_pre_canary_state() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let sspace = space();
    // Warm every cohort's shared cache at two condition buckets so the
    // rollback has live frontiers to carry, not just LUTs.
    let mut loaded = Conditions::idle();
    loaded.loads.insert(EngineKind::Cpu, 1.0);
    let pre_selects: Vec<_> = (0..fleet.len())
        .map(|i| fleet.select(i, obj(), &sspace, &Conditions::idle())
            .unwrap())
        .collect();
    for i in 0..fleet.len() {
        fleet.select(i, obj(), &sspace, &loaded).unwrap();
    }
    let pre_fps = fingerprints(&fleet);
    let pre_builds = fleet.cache_stats().builds;

    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.25);
    let mut ro = Rollout::new(rev, RolloutConfig::default());
    ro.begin_canary(&mut fleet, &mut reg).unwrap();
    assert!(fingerprints(&fleet) != pre_fps,
            "canary must actually change the treated LUTs");

    // Treated cohorts report catastrophic regret against healthy
    // controls: the regret-delta gate must trip.
    ingest_round(&mut ro, &reg, n, 0, 60.0, 1.0);
    match ro.evaluate(&mut fleet, &mut reg) {
        RolloutOutcome::RolledBack { reason } => {
            assert!(reason.starts_with("regret_delta:"), "{reason}")
        }
        other => panic!("breach must roll back, got {other:?}"),
    }
    assert_eq!(ro.stage(), RolloutStage::RolledBack);
    assert_eq!(reg.live_count(rev.id), 0);
    assert!(reg.assigned().iter().all(|&a| a == BASELINE_REVISION));
    // Bit-identical restoration of every cohort (treated and not).
    assert_eq!(fingerprints(&fleet), pre_fps);
    // The carried caches still serve the exact pre-canary selections,
    // without a single rebuild.
    let builds_before_check = fleet.cache_stats().builds;
    for (i, want) in pre_selects.iter().enumerate() {
        let got =
            fleet.select(i, obj(), &sspace, &Conditions::idle()).unwrap();
        assert_eq!(&got, want, "device {i} selection changed by rollback");
    }
    assert_eq!(fleet.cache_stats().builds, builds_before_check,
               "rollback must carry warm frontiers, not rebuild them");
    assert_eq!(builds_before_check, pre_builds,
               "canary+rollback must cycle through the delta path, not \
                rebuilds");
}

// ---------------------------------------------------------------------------
// Property 3: a cohort carries exactly one live revision — a second
// rollout cannot claim claimed cohorts, and the failed claim has no
// side effects.
// ---------------------------------------------------------------------------

#[test]
fn no_cohort_ever_carries_two_live_revisions() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let mut reg = RevisionRegistry::new(n);
    let rev_a = reg.register(EngineKind::Cpu, 0.9);
    let rev_b = reg.register(EngineKind::Gpu, 0.9);
    let mut a = Rollout::new(rev_a, RolloutConfig::default());
    let mut b = Rollout::new(rev_b, RolloutConfig::default());

    a.begin_canary(&mut fleet, &mut reg).unwrap();
    let fps_after_a = fingerprints(&fleet);
    // B's canary would need cohorts A already claimed: refused, and the
    // refusal is side-effect free.
    assert!(b.begin_canary(&mut fleet, &mut reg).is_err());
    assert_eq!(b.stage(), RolloutStage::Proposed);
    assert_eq!(reg.live_count(rev_b.id), 0);
    assert_eq!(fingerprints(&fleet), fps_after_a);
    // At no point does any cohort carry more than one live revision:
    // the assignment table IS one revision per cohort, so it suffices
    // that A's claims and B's claims never overlap.
    assert_eq!(reg.live_count(rev_a.id), a.treated().len());

    // Roll A back; the cohorts become claimable and B's canary succeeds.
    ingest_round(&mut a, &reg, n, 0, 60.0, 1.0);
    match a.evaluate(&mut fleet, &mut reg) {
        RolloutOutcome::RolledBack { .. } => {}
        other => panic!("expected rollback, got {other:?}"),
    }
    b.begin_canary(&mut fleet, &mut reg).unwrap();
    assert_eq!(reg.live_count(rev_b.id), b.treated().len());
    assert_eq!(reg.live_count(rev_a.id), 0);
}

// ---------------------------------------------------------------------------
// Property 4: promotion requires every treated cohort to pass every
// gate with sufficient samples — missing or thin evidence holds the
// stage with zero side effects.
// ---------------------------------------------------------------------------

#[test]
fn promotion_requires_full_evidence_at_every_rung() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.9);
    let mut ro = Rollout::new(rev, RolloutConfig::default());
    let min_samples = RolloutConfig::default().min_samples;
    assert!(min_samples >= 2, "test needs a thin-evidence gap");
    ro.begin_canary(&mut fleet, &mut reg).unwrap();

    let mut seq = 0u64;
    let mut rounds = 0usize;
    loop {
        let treated: Vec<usize> = ro.treated().to_vec();
        let last = *treated.last().unwrap();
        let stage_before = ro.stage();
        let fps_before = fingerprints(&fleet);

        // Every treated cohort but the last reports; the silent cohort
        // holds the stage.
        for &ci in treated.iter().filter(|&&ci| ci != last) {
            let r = report(ci, reg.live(ci), seq, 4, 1.0);
            assert_eq!(ro.ingest(r, &reg), IngestOutcome::Accepted);
        }
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::Held { reason } => {
                assert!(reason.starts_with("missing_reports:"), "{reason}")
            }
            other => panic!("silent cohort must hold, got {other:?}"),
        }
        assert_eq!(ro.stage(), stage_before);
        assert_eq!(fingerprints(&fleet), fps_before);

        // One sample below the minimum still holds.
        let r = report(last, reg.live(last), seq, min_samples - 1, 1.0);
        assert_eq!(ro.ingest(r, &reg), IngestOutcome::Accepted);
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::Held { reason } => {
                assert!(reason.starts_with("insufficient_samples:"),
                        "{reason}")
            }
            other => panic!("thin evidence must hold, got {other:?}"),
        }
        assert_eq!(ro.stage(), stage_before);

        // The missing sample arrives; now the rung may advance.
        let r = report(last, reg.live(last), seq + 1, 1, 1.0);
        assert_eq!(ro.ingest(r, &reg), IngestOutcome::Accepted);
        seq += 2;
        rounds += 1;
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::Promoted => break,
            RolloutOutcome::Advanced { .. } => {}
            other => panic!("full evidence must advance, got {other:?}"),
        }
        assert!(rounds <= n, "rollout failed to terminate");
    }
    assert_eq!(ro.stage(), RolloutStage::Promoted);
    assert_eq!(reg.live_count(rev.id), n);
}

// ---------------------------------------------------------------------------
// Property 5: the regret-delta gate is exact at its boundary — a delta
// of exactly the threshold passes, the next representable step breaches.
// ---------------------------------------------------------------------------

#[test]
fn regret_gate_is_exact_at_the_boundary() {
    let cfg = RolloutConfig::default();
    for (delta, expect_rollback) in
        [(cfg.max_regret_delta_pct, false),
         (cfg.max_regret_delta_pct + 1e-9, true)]
    {
        let mut fleet = build_fleet();
        let n = fleet.cohorts.len();
        let mut reg = RevisionRegistry::new(n);
        let rev = reg.register(EngineKind::Cpu, 0.9);
        let mut ro = Rollout::new(rev, cfg.clone());
        ro.begin_canary(&mut fleet, &mut reg).unwrap();
        ingest_round(&mut ro, &reg, n, 0, 1.0 + delta, 1.0);
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::RolledBack { reason } => {
                assert!(expect_rollback, "delta {delta} breached: {reason}")
            }
            RolloutOutcome::Advanced { .. } => {
                assert!(!expect_rollback, "delta {delta} passed")
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Property 6: the opt-in p99 tail gate catches a revision the mean gates
// miss — treated cohorts keep a healthy mean but grow a heavy tail, and
// only a rollout configured with max_p99_ratio rolls back.
// ---------------------------------------------------------------------------

#[test]
fn p99_gate_catches_tail_regressions_the_mean_gates_miss() {
    // Post-canary cohort telemetry: 96% of decisions as fast as the
    // baseline, 4% fifty times slower — the mean barely moves, the p99
    // lands in the tail.
    let grow_tail = |fleet: &Fleet, treated: &[usize]| {
        for &ci in treated {
            let t = &fleet.cohorts[ci].telemetry;
            for _ in 0..96 {
                t.record("decision_ms", 1.0);
            }
            for _ in 0..4 {
                t.record("decision_ms", 50.0);
            }
        }
    };
    for (tail_gate, expect_rollback) in [(Some(2.0), true), (None, false)] {
        let mut fleet = build_fleet();
        let n = fleet.cohorts.len();
        // Pre-canary baseline: every cohort's histogram is tight at 1 ms.
        for c in &fleet.cohorts {
            for _ in 0..100 {
                c.telemetry.record("decision_ms", 1.0);
            }
        }
        let mut reg = RevisionRegistry::new(n);
        let rev = reg.register(EngineKind::Cpu, 0.9);
        let cfg = RolloutConfig {
            max_p99_ratio: tail_gate,
            p99_metric: "decision_ms".into(),
            ..RolloutConfig::default()
        };
        let mut ro = Rollout::new(rev, cfg);
        ro.begin_canary(&mut fleet, &mut reg).unwrap();
        grow_tail(&fleet, &ro.treated().to_vec());
        // Scalar reports are identical on both sides: every mean gate
        // (regret delta, SLO, faults) passes.
        ingest_round(&mut ro, &reg, n, 0, 1.0, 1.0);
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::RolledBack { reason } => {
                assert!(expect_rollback, "tail gate off yet rolled back: \
                                          {reason}");
                assert!(reason.starts_with("p99_ratio:"), "{reason}");
            }
            RolloutOutcome::Advanced { .. } => {
                assert!(!expect_rollback,
                        "tail regression must trip the p99 gate");
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Property 7: the whole lifecycle is bit-deterministic per fleet seed.
// ---------------------------------------------------------------------------

#[test]
fn rollout_lifecycle_is_bit_deterministic_per_seed() {
    let run = || {
        let mut fleet = build_fleet();
        let n = fleet.cohorts.len();
        let sspace = space();
        for i in 0..fleet.len() {
            fleet.select(i, obj(), &sspace, &Conditions::idle()).unwrap();
        }
        let mut reg = RevisionRegistry::new(n);
        let rev = reg.register(EngineKind::Cpu, 0.8);
        let mut ro = Rollout::new(rev, RolloutConfig::default());
        ro.begin_canary(&mut fleet, &mut reg).unwrap();
        let mut seq = 0u64;
        loop {
            ingest_round(&mut ro, &reg, n, seq, 1.0, 1.0);
            seq += 1;
            match ro.evaluate(&mut fleet, &mut reg) {
                RolloutOutcome::Promoted => break,
                RolloutOutcome::Advanced { .. } => {}
                other => panic!("expected advance, got {other:?}"),
            }
        }
        let selects: Vec<_> = (0..fleet.len())
            .map(|i| {
                let d = fleet
                    .select(i, obj(), &sspace, &Conditions::idle())
                    .unwrap();
                format!("{d:?}")
            })
            .collect();
        (fingerprints(&fleet), selects, fleet.cache_stats().builds,
         fleet.cache_stats().hits)
    };
    assert_eq!(run(), run());
}

// ---------------------------------------------------------------------------
// Property 8: the opt-in burn-rate gate rolls back on a treated cohort's
// SLO burn alert, fed from the fleet's multi-window monitor — and stays
// inert when disabled or when only control cohorts burn.
// ---------------------------------------------------------------------------

#[test]
fn burn_gate_rolls_back_on_treated_cohort_alerts() {
    use oodin::telemetry::{BurnConfig, SloBurnMonitor};

    for (burn_gate, expect_rollback) in [(Some(1.0), true), (None, false)] {
        let mut fleet = build_fleet();
        let n = fleet.cohorts.len();
        let mut reg = RevisionRegistry::new(n);
        let rev = reg.register(EngineKind::Cpu, 0.9);
        let cfg = RolloutConfig {
            max_fast_burn: burn_gate,
            ..RolloutConfig::default()
        };
        let mut ro = Rollout::new(rev, cfg);
        ro.begin_canary(&mut fleet, &mut reg).unwrap();

        // The treated cohort blows its error budget: every post-canary
        // sample misses the 5% SLO.  A control cohort burns too — it
        // must never trip the gate.
        let mut monitor = SloBurnMonitor::new(BurnConfig {
            threshold: 5.0,
            budget: 0.25,
            min_samples: 4,
        });
        let treated = ro.treated().to_vec();
        let control = (0..n).find(|ci| !treated.contains(ci)).unwrap();
        for &ci in &treated {
            for _ in 0..8 {
                fleet.cohorts[ci].telemetry.record("regret_pct", 40.0);
            }
        }
        for _ in 0..8 {
            fleet.cohorts[control].telemetry.record("regret_pct", 40.0);
        }
        let alerts = fleet.check_burn(&mut monitor, "regret_pct", 1_000);
        assert!(alerts.len() >= 2, "treated and control cohorts burn");
        for (cohort_id, sample) in &alerts {
            assert!(sample.burning);
            ro.observe_burn(cohort_id, sample.fast_burn);
        }

        // Scalar reports are clean on both sides: only the burn gate
        // can object.
        ingest_round(&mut ro, &reg, n, 0, 1.0, 1.0);
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::RolledBack { reason } => {
                assert!(expect_rollback,
                        "burn gate off yet rolled back: {reason}");
                assert!(reason.starts_with("burn_rate:"), "{reason}");
            }
            RolloutOutcome::Advanced { .. } => {
                assert!(!expect_rollback,
                        "a burning treated cohort must trip the gate");
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }
}

#[test]
fn control_only_burns_never_trip_the_gate() {
    use oodin::telemetry::{BurnConfig, SloBurnMonitor};

    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.9);
    let cfg = RolloutConfig {
        max_fast_burn: Some(1.0),
        ..RolloutConfig::default()
    };
    let mut ro = Rollout::new(rev, cfg);
    ro.begin_canary(&mut fleet, &mut reg).unwrap();

    let mut monitor = SloBurnMonitor::new(BurnConfig {
        threshold: 5.0,
        budget: 0.25,
        min_samples: 4,
    });
    let treated = ro.treated().to_vec();
    let control = (0..n).find(|ci| !treated.contains(ci)).unwrap();
    for _ in 0..8 {
        fleet.cohorts[control].telemetry.record("regret_pct", 40.0);
    }
    for (cohort_id, sample) in
        &fleet.check_burn(&mut monitor, "regret_pct", 1_000)
    {
        ro.observe_burn(cohort_id, sample.fast_burn);
    }
    ingest_round(&mut ro, &reg, n, 0, 1.0, 1.0);
    match ro.evaluate(&mut fleet, &mut reg) {
        RolloutOutcome::Advanced { .. } => {}
        other => panic!("control-only burn must not gate, got {other:?}"),
    }
}
