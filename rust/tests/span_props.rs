//! Property + golden tests for the trace-analytics layer
//! (`telemetry::spans` + `telemetry::sampling`).
//!
//! - every `switch` event closes exactly one adaptation span, with the
//!   episode's blocked holds and detection onset folded in;
//! - head sampling at the recorder never changes the spans of the keys
//!   it retains — the reconstruction is per-stream deterministic;
//! - tail sampling never drops an SLO-miss or rollback event, at any
//!   seed, while still rejecting the bulk of the healthy stream;
//! - the summary over the pinned fleet-bench smoke trace matches the
//!   byte-pinned `tests/golden/trace_summary.json`, generated
//!   INDEPENDENTLY by `python/golden_fleetbench.py` (regenerate both
//!   with UPDATE_GOLDEN=1 here, or by running the oracle).

use std::sync::Arc;

use oodin::telemetry::sampling::{head_keeps, SamplingPolicy};
use oodin::telemetry::spans::{
    Analysis, SUMMARY_SAMPLE_RATE, SUMMARY_SAMPLE_SEED,
};
use oodin::telemetry::trace::{FlightRecorder, TraceEvent};

/// Field-by-field projection of an adaptation span, for equality checks.
type SpanKey = (String, u64, u64, u64, u64, String, String, String);

fn span_key(s: &oodin::telemetry::spans::AdaptationSpan) -> SpanKey {
    (s.scope.clone(), s.start_us, s.end_us, s.detection_us,
     s.blocked_holds, s.from.clone(), s.to.clone(), s.trigger.clone())
}

fn hold(scope: &str, trigger: &str, reason: &str) -> TraceEvent {
    TraceEvent::Hold {
        scope: scope.to_string(),
        trigger: trigger.to_string(),
        reason: reason.to_string(),
    }
}

fn switch(scope: &str, detection_ms: f64) -> TraceEvent {
    TraceEvent::Switch {
        scope: scope.to_string(),
        from: "a".to_string(),
        to: "b".to_string(),
        reason: "degradation".to_string(),
        detection_ms,
    }
}

#[test]
fn every_switch_closes_exactly_one_span() {
    let rec = FlightRecorder::new();
    // dev-a: two blocked holds, then the switch closes the episode.
    rec.emit_at(1_000, hold("dev-a", "load", "below_hysteresis"));
    rec.emit_at(2_000, hold("dev-a", "load", "cooldown"));
    rec.emit_at(3_000, switch("dev-a", 2.0));
    // dev-b: a switch with no preceding episode — onset is the switch
    // time minus its detection latency.
    rec.emit_at(5_000, switch("dev-b", 0.5));
    // dev-c: an episode abandoned by a clean no-trigger hold.
    rec.emit_at(6_000, hold("dev-c", "degradation", "below_hysteresis"));
    rec.emit_at(7_000, hold("dev-c", "none", "no_trigger"));
    // dev-a again: an episode still pending at end of trace.
    rec.emit_at(8_000, hold("dev-a", "load", "not_due"));

    let a = Analysis::from_records(&rec.records());
    // One span per switch, independently counted from the raw events.
    let switch_events =
        a.events.iter().filter(|e| e.ev == "switch").count() as u64;
    assert_eq!(a.adaptation.len() as u64, switch_events);
    assert_eq!(a.switches(), 2);
    for scope in ["dev-a", "dev-b", "dev-c"] {
        let ev = a.events.iter()
            .filter(|e| {
                e.ev == "switch"
                    && e.body.get("scope")
                        .and_then(|v| v.as_str().ok())
                        == Some(scope)
            })
            .count();
        let spans =
            a.adaptation.iter().filter(|s| s.scope == scope).count();
        assert_eq!(spans, ev, "scope {scope}");
    }

    // dev-a's span folds in both blocked holds and starts at the first.
    let s0 = &a.adaptation[0];
    assert_eq!(s0.scope, "dev-a");
    assert_eq!((s0.start_us, s0.end_us), (1_000, 3_000));
    assert_eq!(s0.detection_us, 2_000);
    assert_eq!(s0.blocked_holds, 2);
    // dev-b's span starts at the detection onset (500 µs before).
    let s1 = &a.adaptation[1];
    assert_eq!(s1.scope, "dev-b");
    assert_eq!((s1.start_us, s1.end_us), (4_500, 5_000));
    assert_eq!(s1.blocked_holds, 0);

    assert_eq!(a.abandoned_episodes, 1);
    assert_eq!(a.open_episodes, 1);
}

#[test]
fn head_sampling_preserves_spans_of_retained_keys() {
    let rec = FlightRecorder::new();
    for i in 0..8u64 {
        let scope = format!("s{i}");
        rec.emit_at(i * 10_000 + 1_000,
                    hold(&scope, "load", "below_hysteresis"));
        rec.emit_at(i * 10_000 + 2_000, hold(&scope, "load", "cooldown"));
        rec.emit_at(i * 10_000 + 3_000, switch(&scope, 1.5));
    }
    let full_text = rec.to_jsonl();
    let full = Analysis::from_jsonl(&full_text).unwrap();
    assert_eq!(full.adaptation.len(), 8);

    let rate = 4u64;
    let (mut any_kept, mut any_dropped) = (false, false);
    for seed in 0..10u64 {
        // Head sampling at the recorder drops whole key streams; replay
        // that filter over the exported lines.
        let sampled: String = full_text
            .lines()
            .filter(|line| {
                let e = oodin::telemetry::spans::RawEvent::parse_line(line)
                    .unwrap();
                head_keeps(rate, seed, &e.sample_key())
            })
            .map(|l| format!("{l}\n"))
            .collect();
        let sub = Analysis::from_jsonl(&sampled).unwrap();

        let kept: Vec<SpanKey> = full
            .adaptation
            .iter()
            .filter(|s| head_keeps(rate, seed, &s.scope))
            .map(span_key)
            .collect();
        let got: Vec<SpanKey> = sub.adaptation.iter().map(span_key).collect();
        assert_eq!(got, kept, "seed {seed}");
        any_kept |= !kept.is_empty();
        any_dropped |= kept.len() < full.adaptation.len();
    }
    // The property must have been exercised from both sides.
    assert!(any_kept && any_dropped);
}

#[test]
fn tail_sampling_never_drops_anomalies() {
    let rec = FlightRecorder::new();
    // Bulk healthy traffic across many scopes...
    for i in 0..64u64 {
        for j in 0..8u64 {
            rec.emit_at(i * 1_000 + j, TraceEvent::Enqueue {
                scope: format!("dev-{j}"),
                class: "interactive".to_string(),
                depth: i % 4,
            });
        }
    }
    // ...with every anomaly class sprinkled in: sheds, SLO burns, a
    // rollback, and a deadline-missing batch completion.
    rec.emit_at(5_500, TraceEvent::Shed {
        scope: "dev-1".to_string(),
        class: "interactive".to_string(),
        depth: 9,
    });
    rec.emit_at(20_500, TraceEvent::SloBurn {
        scope: "dev-3".to_string(),
        metric: "deadline_miss".to_string(),
        window_us: 10_000,
        fast_burn: 2.5,
        slow_burn: 1.25,
        misses: 5,
        samples: 10,
    });
    rec.emit_at(30_500, TraceEvent::Rollout {
        revision: 7,
        stage: "rolled_back".to_string(),
        cohorts: 0,
        detail: "regret_delta:9.000".to_string(),
    });
    rec.emit_at(40_500, TraceEvent::BatchComplete {
        scope: "dev-5".to_string(),
        size: 4,
        slack_us: -250,
    });

    let a = Analysis::from_records(&rec.records());
    let anom = a.events.iter().filter(|e| e.is_anomalous()).count() as u64;
    assert_eq!(anom, 4);
    let total = a.events.len() as u64;

    for seed in 0..8u64 {
        let (retained, retained_anom) =
            a.simulate_sampling(SamplingPolicy::Tail { rate: 16, seed });
        assert_eq!(retained_anom, anom,
                   "tail sampling dropped an anomaly at seed {seed}");
        assert!(retained < total,
                "tail sampling must reject bulk traffic (seed {seed})");
    }
    // Head sampling alone has no such guarantee — the flush behaviour
    // is what tail adds on top.
    let (keep_all, keep_all_anom) =
        a.simulate_sampling(SamplingPolicy::KeepAll);
    assert_eq!((keep_all, keep_all_anom), (total, anom));
}

#[test]
fn golden_trace_summary_json() {
    let reg = oodin::model::test_fixtures::fake_registry();
    let cfg = oodin::experiments::fleetbench::FleetBenchConfig::smoke();
    let rec = Arc::new(FlightRecorder::new());
    oodin::experiments::fleetbench::run_traced(&reg, &cfg, Some(&rec))
        .unwrap();
    let a = Analysis::from_records(&rec.records());
    let got = a.summary_json() + "\n";
    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/tests/golden/trace_summary.json");
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(path, &got).unwrap();
    }
    let want = std::fs::read_to_string(path)
        .expect("golden summary missing — run with UPDATE_GOLDEN=1 or \
                 python3 python/golden_fleetbench.py");
    assert_eq!(got, want,
               "trace summary drifted from the golden snapshot \
                (UPDATE_GOLDEN=1 to accept, then re-run the Python oracle \
                to confirm both implementations still agree)");
}

#[test]
fn golden_trace_meets_acceptance_criteria() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/tests/golden/fleetbench_smoke_trace.jsonl");
    let text = std::fs::read_to_string(path).unwrap();
    let a = Analysis::from_jsonl(&text).unwrap();

    // Adaptation-span count equals the switch count, counted
    // independently from the raw event stream.
    let switch_events =
        a.events.iter().filter(|e| e.ev == "switch").count() as u64;
    assert!(switch_events > 0);
    assert_eq!(a.adaptation.len() as u64, switch_events);

    // Zero unclosed serving spans and a gap-free sequence.
    assert_eq!(a.unclosed_requests, 0);
    assert_eq!(a.unclosed_batches, 0);
    assert_eq!(a.stray_completes, 0);
    assert_eq!(a.seq_gaps, 0);

    // Every rollback is causally reachable from its canary claim.
    let rollbacks: Vec<_> = a.rollouts.iter()
        .filter(|r| r.terminal == "rolled_back")
        .collect();
    assert!(!rollbacks.is_empty());
    assert!(rollbacks.iter().all(|r| r.has_canary));
    // The smoke storm's fleet causes all fan out cleanly.
    assert!(!a.chains.is_empty());
    assert_eq!(a.orphan_deltas, 0);

    // The storm burns: the monitor fired and grouped into episodes.
    assert!(!a.burn.is_empty());

    // Tail sampling at the summary's pinned 1/16 head rate keeps every
    // anomaly while cutting retained events at least 4× on the storm.
    let anom = a.events.iter().filter(|e| e.is_anomalous()).count() as u64;
    assert!(anom > 0);
    let (retained, retained_anom) =
        a.simulate_sampling(SamplingPolicy::Tail {
            rate: SUMMARY_SAMPLE_RATE,
            seed: SUMMARY_SAMPLE_SEED,
        });
    assert_eq!(retained_anom, anom);
    assert!(retained > 0);
    let reduction = a.events.len() as f64 / retained as f64;
    assert!(reduction >= 4.0, "tail reduction {reduction:.3}x < 4x");
}
