//! Property tests for the multi-app `scheduler` subsystem, in the
//! `tests/properties.rs` style: `util::rng::Rng` generates seeded random
//! workloads and every assertion prints its case id.
//!
//! Invariants:
//! * joint search respects the global budget — Σ CPU threads, Σ model
//!   memory, and exclusive GPU/NNAPI ownership;
//! * arbitration windows never grant one engine to two apps in the same
//!   slice, and no admitted app starves (every app gets >= 1 inference per
//!   window);
//! * one pinned joint-search result stays byte-stable (golden snapshot).

use std::collections::BTreeMap;
use std::sync::Arc;

use oodin::device::profiles::samsung_a71;
use oodin::device::EngineKind;
use oodin::devicesim::DeviceSim;
use oodin::dvfs::Governor;
use oodin::manager::Conditions;
use oodin::measurements::{ExecPlan, Lut, LutEntry, LutKey, Measurer};
use oodin::model::test_fixtures::fake_registry;
use oodin::model::Registry;
use oodin::optimizer::Objective;
use oodin::scheduler::{Admission, GlobalBudget, JointSearch, Scheduler,
                       WorkloadDescriptor};
use oodin::util::clock::Clock;
use oodin::util::rng::Rng;
use oodin::util::stats::{LatencyStats, Percentile};

const FAMILIES: [&str; 4] = ["mobilenet_v2_100", "efficientnet_lite4",
                             "inception_v3", "deeplab_v3"];

fn desc(id: &str, family: &str, fps: f64, slo_ms: f64) -> WorkloadDescriptor {
    WorkloadDescriptor {
        app_id: id.to_string(),
        family: family.to_string(),
        arrival_fps: fps,
        objective: Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 },
        slo_latency_ms: slo_ms,
    }
}

fn random_descs(rng: &mut Rng) -> Vec<WorkloadDescriptor> {
    let n = 1 + rng.below(4);
    (0..n)
        .map(|i| {
            desc(&format!("app{i}"), FAMILIES[rng.below(FAMILIES.len())],
                 5.0 + rng.range(0.0, 115.0), rng.range(0.05, 10.0))
        })
        .collect()
}

#[test]
fn prop_joint_search_respects_global_budget() {
    let dev = samsung_a71();
    let reg = fake_registry();
    let lut = Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap();
    for case in 0..12u64 {
        let mut rng = Rng::new(21_000 + case);
        let descs = random_descs(&mut rng);
        let budget = GlobalBudget {
            cpu_threads: 1 + rng.below(8),
            mem_bytes: 150_000 + rng.below(2_000_000) as u64,
            util_cap: 1.0,
        };
        let search = JointSearch::new(&dev, &reg, &lut, budget.clone());
        let Ok(assignment) = search.search(&descs, &Conditions::idle()) else {
            continue; // infeasible under this budget: admission rejects
        };
        let mut cpu_threads = 0usize;
        let mut mem = 0u64;
        let mut owners: BTreeMap<EngineKind, usize> = BTreeMap::new();
        for p in &assignment.apps {
            let e = p.design.hw.engine;
            *owners.entry(e).or_insert(0) += 1;
            if e == EngineKind::Cpu {
                cpu_threads += p.design.hw.threads;
            }
            mem += p.mem_bytes;
        }
        assert!(cpu_threads <= budget.cpu_threads,
                "case {case}: CPU budget exceeded ({cpu_threads})");
        assert!(mem <= budget.mem_bytes,
                "case {case}: memory cap exceeded ({mem})");
        assert!(owners.get(&EngineKind::Gpu).copied().unwrap_or(0) <= 1,
                "case {case}: GPU shared");
        assert!(owners.get(&EngineKind::Npu).copied().unwrap_or(0) <= 1,
                "case {case}: NNAPI shared");
        // Violation accounting is consistent with the predictions.
        let predicted = assignment.apps.iter().filter(|p| !p.slo_ok).count();
        assert_eq!(predicted, assignment.violations, "case {case}");
    }
}

#[test]
fn prop_no_admitted_app_starves_and_engines_exclusive() {
    let dev = samsung_a71();
    let reg = fake_registry();
    let lut = Arc::new(
        Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap(),
    );
    for case in 0..6u64 {
        let mut rng = Rng::new(23_000 + case);
        let descs = random_descs(&mut rng);
        let mut sched = Scheduler::new(Arc::new(dev.clone()),
                                       Arc::new(reg.clone()),
                                       Arc::clone(&lut));
        let mut sim = DeviceSim::new(dev.clone(), Clock::sim());
        let mut admitted = Vec::new();
        for d in &descs {
            match sched
                .register(d.clone(), sim.clock.now_ms(), &sim.conditions())
                .unwrap()
            {
                Admission::Admitted { .. } => admitted.push(d.app_id.clone()),
                Admission::Rejected { .. } => {}
            }
        }
        if admitted.is_empty() {
            continue;
        }
        // The planned window grants each engine at most once per slice —
        // in particular GPU/NNAPI are never held by two apps in one slice.
        let plan_input: Vec<(String, EngineKind, f64)> = sched
            .designs()
            .into_iter()
            .map(|(id, d)| (id, d.hw.engine, 1.0))
            .collect();
        let window = sched.arbiter.plan(&plan_input);
        for (si, slice) in window.slices.iter().enumerate() {
            let mut seen = Vec::new();
            for g in &slice.grants {
                assert!(!seen.contains(&g.engine),
                        "case {case}: slice {si} grants {:?} twice", g.engine);
                seen.push(g.engine);
            }
        }
        // Every admitted app is actually served in every window.
        for w in 0..2 {
            let report = sched.run_window(&mut sim).unwrap();
            for id in &admitted {
                let served = report
                    .apps
                    .iter()
                    .find(|a| &a.app_id == id)
                    .map_or(0, |a| a.inferences);
                assert!(served >= 1,
                        "case {case}: app {id} starved in window {w}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Golden snapshot: one pinned multi-app joint-search result over a fixed,
// hand-written LUT (regenerate with UPDATE_GOLDEN=1).
// ---------------------------------------------------------------------------

fn fixed_lut(reg: &Registry) -> Lut {
    let mut entries = BTreeMap::new();
    let mut put = |variant: &str, engine, threads, ms: f64| {
        let v = reg.get(variant).expect(variant);
        entries.insert(
            LutKey {
                variant: variant.to_string(),
                engine,
                threads,
                governor: Governor::Performance,
                plan: ExecPlan::Mono,
            },
            LutEntry {
                latency: LatencyStats::from_samples(&[ms]),
                mem_bytes: v.mem_bytes(),
                accuracy: v.accuracy,
                stages: Vec::new(),
            },
        );
    };

    use EngineKind::{Cpu, Gpu, Npu};
    put("mobilenet_v2_100__int8__b1", Npu, 1, 1.0);
    put("mobilenet_v2_100__int8__b1", Gpu, 1, 2.2);
    put("mobilenet_v2_100__int8__b1", Cpu, 4, 2.5);
    put("mobilenet_v2_100__fp32__b1", Gpu, 1, 3.0);
    put("mobilenet_v2_100__fp32__b1", Cpu, 4, 4.0);
    put("mobilenet_v2_100__fp32__b1", Npu, 1, 6.0);
    put("mobilenet_v2_100__fp32__b1", Cpu, 1, 8.0);

    put("inception_v3__int8__b1", Npu, 1, 2.0);
    put("inception_v3__int8__b1", Cpu, 4, 6.0);
    put("inception_v3__int8__b1", Gpu, 1, 6.5);
    put("inception_v3__fp32__b1", Gpu, 1, 9.0);
    put("inception_v3__fp32__b1", Cpu, 4, 12.0);
    put("inception_v3__fp32__b1", Npu, 1, 20.0);

    Lut { device: "samsung_a71".to_string(), entries }
}

#[test]
fn golden_joint_search_is_byte_stable() {
    let reg = fake_registry();
    let lut = fixed_lut(&reg);
    let dev = samsung_a71();
    let descs = vec![
        desc("ai_camera", "mobilenet_v2_100", 60.0, 2.5),
        desc("gallery_tagger", "inception_v3", 15.0, 4.5),
    ];
    let search = JointSearch::new(&dev, &reg, &lut, GlobalBudget::of(&dev));
    let assignment = search.search(&descs, &Conditions::idle()).unwrap();

    let mut lines: Vec<String> = assignment
        .apps
        .iter()
        .map(|p| {
            format!(
                "{}: {}|{}|{}|{}|r={}|T={:.4}ms|slo_ok={}|degraded={}",
                p.app_id,
                p.design.variant,
                p.design.hw.engine.name(),
                p.design.hw.threads,
                p.design.hw.governor.name(),
                p.design.hw.recognition_rate,
                p.latency_ms,
                p.slo_ok,
                p.degraded,
            )
        })
        .collect();
    lines.push(format!("violations={} pressure={:.4}",
                       assignment.violations, assignment.pressure));
    let got = lines.join("\n") + "\n";

    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/tests/golden/multiapp_designs.txt");
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(path, &got).unwrap();
    }
    let want = std::fs::read_to_string(path)
        .expect("golden snapshot missing — run with UPDATE_GOLDEN=1");
    assert_eq!(got, want,
               "joint-search assignment drifted from the golden snapshot \
                (UPDATE_GOLDEN=1 to accept)");
}
