//! Property suite for the bounded telemetry substrate: a metric's
//! memory is `O(buckets)` no matter how many samples it absorbs (10^6
//! here), quantiles stay within the documented relative error bound
//! (`2^(1/SUBBUCKETS_PER_OCTAVE) - 1` ≈ 4.43 %) against exact order
//! statistics across sample distributions, and sharded sinks merge to
//! the same state as one pooled sink.

use oodin::telemetry::histogram::{exact_quantile, LogHistogram,
                                  SUBBUCKETS_PER_OCTAVE};
use oodin::telemetry::Telemetry;
use oodin::util::rng::Rng;

const QS: [f64; 8] = [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999];

fn error_bound() -> f64 {
    f64::exp2(1.0 / SUBBUCKETS_PER_OCTAVE as f64) - 1.0
}

#[test]
fn million_samples_use_constant_memory() {
    let mut h = LogHistogram::new();
    let before = h.resident_bytes();
    let mut rng = Rng::new(7);
    for _ in 0..1_000_000 {
        h.record(rng.range(0.01, 5_000.0));
    }
    assert_eq!(h.count(), 1_000_000);
    assert_eq!(h.resident_bytes(), before,
               "histogram memory must not grow with sample count");

    // Same property through the Telemetry sink front-end.
    let t = Telemetry::new();
    t.record("lat", 1.0);
    let footprint = t.resident_bytes();
    for i in 0..1_000_000u64 {
        t.record("lat", 0.01 + (i % 997) as f64 * 0.013);
    }
    assert_eq!(t.resident_bytes(), footprint);
    assert_eq!(t.stats("lat").unwrap().n, 1_000_001);
}

#[test]
fn quantiles_hold_documented_bound_across_distributions() {
    let bound = error_bound();
    assert!(bound <= 0.045, "documented bound is ≤ 4.5 %");
    // Uniform, log-uniform (12 octaves), and lognormal heavy-tail —
    // the shapes latency metrics actually take.
    for (name, seed) in [("uniform", 11u64), ("loguniform", 23),
                         ("lognormal", 47)] {
        let mut rng = Rng::new(seed);
        let mut h = LogHistogram::new();
        let mut raw: Vec<f64> = Vec::with_capacity(20_000);
        for _ in 0..20_000 {
            let v = match name {
                "uniform" => rng.range(0.5, 400.0),
                "loguniform" => f64::exp2(rng.range(-4.0, 8.0)),
                _ => 5.0 * rng.lognormal(0.8),
            };
            h.record(v);
            raw.push(v);
        }
        raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in QS {
            let exact = exact_quantile(&raw, q);
            let approx = h.quantile(q).unwrap();
            let err = (approx / exact - 1.0).abs();
            assert!(err <= bound,
                    "{name} q={q}: approx {approx} vs exact {exact} \
                     (err {err:.5} > bound {bound:.5})");
        }
        // Exact moments survive bucketing exactly.
        let s = h.stats().unwrap();
        assert_eq!(s.n, raw.len());
        assert_eq!(s.min, raw[0]);
        assert_eq!(s.max, raw[raw.len() - 1]);
        let sum: f64 = raw.iter().sum();
        assert_eq!(s.avg, sum / raw.len() as f64);
    }
}

#[test]
fn sharded_sinks_merge_to_the_pooled_state() {
    // 8 shards vs one pooled histogram over the same sample stream —
    // the cohort → fleet rollup must lose nothing.
    let mut rng = Rng::new(99);
    let mut shards: Vec<LogHistogram> =
        (0..8).map(|_| LogHistogram::new()).collect();
    let mut pooled = LogHistogram::new();
    for i in 0..80_000usize {
        let v = f64::exp2(rng.range(-2.0, 6.0));
        shards[i % 8].record(v);
        pooled.record(v);
    }
    let mut merged = LogHistogram::new();
    for s in &shards {
        merged.merge(s);
    }
    assert_eq!(merged.count(), pooled.count());
    // Sums accumulate in different orders across shards: equal up to
    // float associativity only.
    assert!((merged.sum() / pooled.sum() - 1.0).abs() < 1e-12);
    let (ms, ps) = (merged.stats().unwrap(), pooled.stats().unwrap());
    assert_eq!(ms.min, ps.min);
    assert_eq!(ms.max, ps.max);
    for q in QS {
        assert_eq!(merged.quantile(q), pooled.quantile(q),
                   "merge order must not change reported quantiles");
    }
}
