//! Property tests for the fleet layer: cross-device LUT transfer
//! (zero regret on anchors, monotone scaling along every perturbation
//! axis, confidence-gated probe fallback) and cohort-shared frontier
//! caches (builds amortise across the population).

use std::path::PathBuf;
use std::sync::Arc;

use oodin::designspace::{rank, DesignSpace};
use oodin::device::EngineKind;
use oodin::dvfs::Governor;
use oodin::fleet::population::{archetype_profile, sample_fleet, EngineAxes,
                               PopulationConfig};
use oodin::fleet::{Fleet, FleetConfig, TransferConfig, TransferEngine};
use oodin::fleet::{population, transfer};
use oodin::manager::Conditions;
use oodin::measurements::{ExecPlan, LutKey};
use oodin::model::test_fixtures::{fake_manifest, fake_registry};
use oodin::model::Registry;
use oodin::optimizer::{Objective, SearchSpace};
use oodin::util::stats::Percentile;

fn obj() -> Objective {
    Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }
}

fn anchors(reg: &Registry) -> TransferEngine<'_> {
    TransferEngine::from_archetypes(reg, TransferConfig::default(), 8, 1, 0.0)
        .unwrap()
}

/// Axes covering every archetype engine, flops-perturbed on one engine.
fn axes_with(base: &oodin::device::DeviceProfile, kind: EngineKind,
             flops_ln: f64, bw_ln: f64) -> Vec<EngineAxes> {
    base.engines
        .iter()
        .map(|e| EngineAxes {
            kind: e.kind,
            flops_ln: if e.kind == kind { flops_ln } else { 0.0 },
            bw_ln: if e.kind == kind { bw_ln } else { 0.0 },
            latent_ln: 0.0,
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Satellite property 1: zero regret when the target device IS an anchor.
// ---------------------------------------------------------------------------

#[test]
fn anchor_target_has_exactly_zero_regret() {
    let reg = fake_registry();
    let te = anchors(&reg);
    let space = SearchSpace::family("mobilenet_v2_100");
    let mut loaded = Conditions::idle();
    loaded.loads.insert(EngineKind::Cpu, 2.0);
    for anchor in &te.anchors {
        let predicted = te.predict(&anchor.profile).unwrap().lut;
        for conds in [Conditions::idle(), loaded.clone()] {
            let ds_pred = DesignSpace::new(&anchor.profile, &reg, &predicted);
            let ds_true = DesignSpace::new(&anchor.profile, &reg, &anchor.lut);
            let p = rank(ds_pred.enumerate(obj(), &space, &conds), obj());
            let t = rank(ds_true.enumerate(obj(), &space, &conds), obj());
            assert_eq!(p.len(), t.len());
            // Same selection AND bit-identical true latency: regret == 0.
            assert_eq!(p[0].design, t[0].design, "{}", anchor.name);
            assert_eq!(p[0].latency_ms, t[0].latency_ms, "{}", anchor.name);
        }
    }
}

// ---------------------------------------------------------------------------
// Satellite property 2: monotone latency scaling along each axis.
// ---------------------------------------------------------------------------

#[test]
fn predicted_latency_monotone_in_flops_axis() {
    let reg = fake_registry();
    let te = anchors(&reg);
    let base = archetype_profile("samsung_a71");
    // inception fp32 on the CPU is strongly compute-bound: more peak FLOPS
    // must strictly reduce the predicted latency.
    let key = LutKey {
        variant: "inception_v3__fp32__b1".into(),
        engine: EngineKind::Cpu,
        threads: 8,
        governor: Governor::Performance,
        plan: ExecPlan::Mono,
    };
    let mut prev = f64::INFINITY;
    for f in [-0.3, -0.1, 0.0, 0.1, 0.3] {
        let axes = axes_with(&base, EngineKind::Cpu, f, 0.0);
        let nominal = population::scaled_profile(&base, &axes, 0.0, 0.0, false);
        let t = te.predict(&nominal).unwrap();
        let avg = t.lut.get(&key).unwrap().latency.avg;
        assert!(avg < prev, "flops_ln={f}: {avg} !< {prev}");
        prev = avg;
    }
}

#[test]
fn predicted_latency_monotone_in_bandwidth_axis() {
    // Make inception fp32 memory-bound so the bandwidth axis bites.
    let manifest = fake_manifest().replace(
        r#""size_bytes":400000,"flops":90000000"#,
        r#""size_bytes":200000000,"flops":90000000"#,
    );
    let reg = Registry::from_manifest_json(&manifest,
                                           PathBuf::from("/tmp/fake"))
        .unwrap();
    let te = anchors(&reg);
    let base = archetype_profile("samsung_a71");
    let key = LutKey {
        variant: "inception_v3__fp32__b1".into(),
        engine: EngineKind::Cpu,
        threads: 8,
        governor: Governor::Performance,
        plan: ExecPlan::Mono,
    };
    let mut prev = f64::INFINITY;
    for b in [-0.15, -0.05, 0.0, 0.05, 0.15] {
        let axes = axes_with(&base, EngineKind::Cpu, 0.0, b);
        let nominal = population::scaled_profile(&base, &axes, 0.0, 0.0, false);
        let t = te.predict(&nominal).unwrap();
        let avg = t.lut.get(&key).unwrap().latency.avg;
        assert!(avg < prev, "bw_ln={b}: {avg} !< {prev}");
        prev = avg;
    }
}

#[test]
fn thermal_and_memory_axes_scale_their_targets() {
    let base = archetype_profile("samsung_a71");
    let axes = axes_with(&base, EngineKind::Cpu, 0.0, 0.0);
    let mut prev_heat = f64::INFINITY;
    let mut prev_mem = 0u64;
    for x in [-0.2, 0.0, 0.2] {
        let p = population::scaled_profile(&base, &axes, x, x, false);
        // More thermal capacity → strictly lower heat accumulation.
        let heat = p.engine(EngineKind::Cpu).unwrap().thermal.heat_per_ms;
        assert!(heat < prev_heat, "thermal_ln={x}");
        prev_heat = heat;
        // Memory axis monotone in the budget.
        assert!(p.mem_budget_bytes > prev_mem, "mem_ln={x}");
        prev_mem = p.mem_budget_bytes;
    }
}

#[test]
fn memory_axis_gates_deployability() {
    // With an oversized mobilenet FP32 (fast enough to stay deployable on
    // the sony latency bound, but near its memory budget) the memory axis
    // decides how much of the family's ladder is admitted: a roomier
    // sampled device must never admit fewer designs.
    let manifest = fake_manifest().replace(
        r#""size_bytes":400000,"flops":4000000"#,
        r#""size_bytes":3600000,"flops":4000000"#,
    );
    let reg = Registry::from_manifest_json(&manifest,
                                           PathBuf::from("/tmp/fake"))
        .unwrap();
    let te = anchors(&reg);
    let base = archetype_profile("sony_c5");
    let axes = axes_with(&base, EngineKind::Cpu, 0.0, 0.0);
    let space = SearchSpace::family("mobilenet_v2_100");
    let mut prev = 0usize;
    let mut grew = false;
    for m in [-0.15, 0.0, 0.15] {
        let nominal = population::scaled_profile(&base, &axes, 0.0, m, false);
        let t = te.predict(&nominal).unwrap();
        let ds = DesignSpace::new(&nominal, &reg, &t.lut);
        let n = ds.enumerate(obj(), &space, &Conditions::idle()).len();
        assert!(n >= prev, "mem_ln={m}: {n} admitted < {prev}");
        if n > prev && prev > 0 {
            grew = true;
        }
        prev = n;
    }
    assert!(grew, "memory spread never changed admission");
}

#[test]
fn engine_availability_axis_removes_lut_entries() {
    let reg = fake_registry();
    let te = anchors(&reg);
    let cfg = PopulationConfig { size: 128, ..Default::default() };
    let fleet = sample_fleet(&cfg);
    let dropped = fleet.iter().find(|d| d.dropped_npu).expect("some drop");
    let t = te.predict(&dropped.nominal).unwrap();
    assert!(t.lut.entries.keys().all(|k| k.engine != EngineKind::Npu));
    assert!(!t.engines.contains_key(&EngineKind::Npu));
}

// ---------------------------------------------------------------------------
// Satellite property 3: probe fallback triggers exactly under low
// confidence.
// ---------------------------------------------------------------------------

#[test]
fn probe_fallback_triggers_iff_confidence_low() {
    let reg = fake_registry();
    let te = anchors(&reg);
    let base = archetype_profile("samsung_a71");
    for delta in [0.0, 0.2, 0.5, 0.9] {
        let axes = axes_with(&base, EngineKind::Cpu, delta, 0.0);
        let nominal = population::scaled_profile(&base, &axes, 0.0, 0.0, false);
        let t = te.predict_with_probes(&nominal, &nominal).unwrap();
        let cpu = &t.engines[&EngineKind::Cpu];
        let expect_probe =
            transfer::confidence(delta) < te.cfg.confidence_threshold;
        assert_eq!(cpu.probed, expect_probe,
                   "delta={delta}: confidence {}", cpu.confidence);
        if cpu.probed {
            // True profile == nominal here, so the probes must confirm the
            // prediction (correction ≈ 1).
            assert!((cpu.correction - 1.0).abs() < 1e-9,
                    "correction {}", cpu.correction);
        }
    }
}

// ---------------------------------------------------------------------------
// Fleet-level amortisation.
// ---------------------------------------------------------------------------

#[test]
fn fleet_cohort_builds_stay_below_devices_under_churn() {
    // 128 devices quantise into ~26 cohorts (seed 77): three visited
    // buckets per cohort keep builds far below the device count.
    let cfg = FleetConfig {
        population: PopulationConfig { size: 128, ..Default::default() },
        ..Default::default()
    };
    let fleet = Fleet::build(Arc::new(fake_registry()), cfg).unwrap();
    let space = SearchSpace::family("mobilenet_v2_100");
    // Every device visits three condition buckets.
    let mut gpu = Conditions::idle();
    gpu.loads.insert(EngineKind::Gpu, 1.0);
    let mut hot = Conditions::idle();
    hot.thermal.insert(EngineKind::Npu, 0.5);
    for idx in 0..fleet.len() {
        for conds in [&Conditions::idle(), &gpu, &hot] {
            fleet.select(idx, obj(), &space, conds).unwrap();
        }
    }
    let stats = fleet.cache_stats();
    assert!(stats.builds < fleet.len() as u64,
            "{} builds for {} devices", stats.builds, fleet.len());
    assert_eq!(stats.builds + stats.hits, 3 * fleet.len() as u64);
    assert!(stats.hits > stats.builds, "sharing must dominate: {stats:?}");
}
