//! Golden test for the System Optimisation module: a fixed, hand-written
//! LUT must make the complete enumerative search return a byte-stable
//! `Design` (and metrics) for each `Objective`.  The expected output is
//! pinned in `tests/golden/optimizer_designs.txt`; regenerate it after an
//! intentional behaviour change with
//!
//!     UPDATE_GOLDEN=1 cargo test --test golden_optimizer
//!
//! The LUT entries are single-sample (all latency statistics collapse to
//! the written value), so the expected winners are hand-derivable:
//!
//! * min-latency winners follow the raw minima (int8/NNAPI at 1.0 ms, or
//!   FP32/GPU at 3.0 ms once ε = 0 excludes lossy precisions);
//! * target-latency maximises accuracy inside the 3 ms budget (FP32/GPU);
//! * the weighted accuracy+fps sum saturates fps at the camera rate, so
//!   every FP32 r=1 design ties at score 2.0 and the design-space layer's
//!   canonical tie chain breaks toward the lowest-energy design: CPU
//!   4-thread schedutil at 5 ms (energy ∝ T·heat·f²·gov_heat = 5.0 × 0.08
//!   × 0.94² × 0.85 ≈ 0.300, below the 4-thread performance entry's 0.320
//!   and every GPU/NNAPI entry).

use std::collections::BTreeMap;

use oodin::device::profiles::samsung_a71;
use oodin::device::EngineKind;
use oodin::dvfs::Governor;
use oodin::measurements::{ExecPlan, Lut, LutEntry, LutKey};
use oodin::model::test_fixtures::fake_registry;
use oodin::model::Registry;
use oodin::optimizer::{Objective, Optimizer, SearchSpace};
use oodin::util::stats::{LatencyStats, Percentile};

fn fixed_lut(reg: &Registry) -> Lut {
    let mut entries = BTreeMap::new();
    let mut put = |variant: &str, engine, threads, governor, ms: f64| {
        let v = reg.get(variant).expect(variant);
        entries.insert(
            LutKey { variant: variant.to_string(), engine, threads, governor,
                     plan: ExecPlan::Mono },
            LutEntry {
                latency: LatencyStats::from_samples(&[ms]),
                mem_bytes: v.mem_bytes(),
                accuracy: v.accuracy,
                stages: Vec::new(),
            },
        );
    };

    use EngineKind::{Cpu, Gpu, Npu};
    use Governor::{Performance as P, Schedutil as S};
    let fp32 = "mobilenet_v2_100__fp32__b1";
    let fp16 = "mobilenet_v2_100__fp16__b1";
    let int8 = "mobilenet_v2_100__int8__b1";

    put(fp32, Cpu, 1, P, 8.0);
    put(fp32, Cpu, 4, P, 4.0);
    put(fp32, Gpu, 1, P, 3.0);
    put(fp32, Npu, 1, P, 6.0);
    put(fp32, Cpu, 1, S, 10.0);
    put(fp32, Cpu, 4, S, 5.0);
    put(fp32, Gpu, 1, S, 3.75);
    put(fp32, Npu, 1, S, 7.5);

    put(fp16, Cpu, 4, P, 3.5);
    put(fp16, Gpu, 1, P, 2.0);
    put(fp16, Npu, 1, P, 4.0);
    put(fp16, Cpu, 4, S, 4.375);
    put(fp16, Gpu, 1, S, 2.5);
    put(fp16, Npu, 1, S, 5.0);

    put(int8, Cpu, 4, P, 2.5);
    put(int8, Gpu, 1, P, 2.2);
    put(int8, Npu, 1, P, 1.0);
    put(int8, Cpu, 4, S, 3.125);
    put(int8, Gpu, 1, S, 2.75);
    put(int8, Npu, 1, S, 1.25);

    Lut { device: "samsung_a71".to_string(), entries }
}

#[test]
fn search_is_byte_stable_per_objective() {
    let reg = fake_registry();
    let lut = fixed_lut(&reg);
    let dev = samsung_a71();
    let opt = Optimizer::new(&dev, &reg, &lut).with_camera_fps(30.0);

    let objectives: Vec<(&str, Objective)> = vec![
        ("min_latency_avg_eps02",
         Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.02 }),
        ("min_latency_avg_eps0",
         Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.0 }),
        ("max_fps_eps02", Objective::MaxFps { epsilon: 0.02 }),
        ("target_latency_3ms",
         Objective::TargetLatency { t_target_ms: 3.0, stat: Percentile::Avg }),
        ("max_acc_max_fps_w1", Objective::MaxAccMaxFps { w_fps: 1.0 }),
    ];

    let mut lines = Vec::new();
    for (tag, obj) in objectives {
        let best = opt.optimize(obj, &SearchSpace::default()).unwrap();
        lines.push(format!(
            "{tag}: {}|{}|{}|{}|r={}|T={:.4}ms|acc={:.4}",
            best.design.variant,
            best.design.hw.engine.name(),
            best.design.hw.threads,
            best.design.hw.governor.name(),
            best.design.hw.recognition_rate,
            best.latency_ms,
            best.accuracy,
        ));
    }
    let got = lines.join("\n") + "\n";

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/optimizer_designs.txt");
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(path, &got).unwrap();
    }
    let want = std::fs::read_to_string(path)
        .expect("golden snapshot missing — run with UPDATE_GOLDEN=1");
    assert_eq!(got, want,
               "optimizer designs drifted from the golden snapshot \
                (UPDATE_GOLDEN=1 to accept)");
}
