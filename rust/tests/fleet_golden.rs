//! Golden + acceptance tests for `oodin fleet-bench --smoke`.
//!
//! The smoke payload is pinned byte-for-byte in
//! `tests/golden/fleetbench_smoke.json`, generated INDEPENDENTLY by
//! `python/golden_fleetbench.py` (an N-version Python oracle of the whole
//! smoke path: SplitMix64 population sampling, roofline LUTs, roofline-
//! ratio transfer + probe fallback, cohort cache accounting, the manager
//! decide() state machine under the storm, and the JSON formatting).
//! Regenerate with
//!
//!     python3 python/golden_fleetbench.py
//!
//! and the issue's acceptance criteria are asserted here explicitly:
//! transferred-LUT selections reach ≤ 5% mean latency regret vs the
//! full-profile oracle on a ≥ 200-device fleet, with cohort frontier
//! builds strictly fewer than devices; and the control-plane scenario
//! rolls the bad revision back (bit-identical fingerprints, zero live
//! cohorts), promotes the good one fleet-wide, and closes the residual
//! feedback loop with regret no worse than the pre-feedback baseline.

use std::sync::Arc;

use oodin::experiments::fleetbench::{self, FleetBenchConfig};
use oodin::model::test_fixtures::fake_registry;
use oodin::telemetry::trace::FlightRecorder;
use oodin::util::json;

#[test]
fn golden_fleetbench_smoke_json() {
    let reg = fake_registry();
    let cfg = FleetBenchConfig::smoke();
    let report = fleetbench::run(&reg, &cfg).unwrap();
    let got = json::to_string(&fleetbench::report_json(&report)) + "\n";
    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/tests/golden/fleetbench_smoke.json");
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(path, &got).unwrap();
    }
    let want = std::fs::read_to_string(path)
        .expect("golden snapshot missing — run with UPDATE_GOLDEN=1 or \
                 python3 python/golden_fleetbench.py");
    assert_eq!(got, want,
               "fleet-bench smoke JSON drifted from the golden snapshot \
                (UPDATE_GOLDEN=1 to accept, then re-run the Python oracle \
                to confirm both implementations still agree)");
}

#[test]
fn golden_fleetbench_smoke_trace_jsonl() {
    let reg = fake_registry();
    let cfg = FleetBenchConfig::smoke();
    let rec = Arc::new(FlightRecorder::new());
    fleetbench::run_traced(&reg, &cfg, Some(&rec)).unwrap();
    assert_eq!(rec.dropped(), 0, "smoke trace must fit the default ring");
    let got = rec.to_jsonl();
    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/tests/golden/fleetbench_smoke_trace.jsonl");
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(path, &got).unwrap();
    }
    let want = std::fs::read_to_string(path)
        .expect("golden trace missing — run with UPDATE_GOLDEN=1 or \
                 python3 python/golden_fleetbench.py");
    assert_eq!(got, want,
               "fleet-bench smoke trace drifted from the golden snapshot \
                (UPDATE_GOLDEN=1 to accept, then re-run the Python oracle \
                to confirm both implementations still agree)");
}

#[test]
fn smoke_meets_acceptance_criteria() {
    let reg = fake_registry();
    let cfg = FleetBenchConfig::smoke();
    let report = fleetbench::run(&reg, &cfg).unwrap();
    // ≥ 200-device fleet.
    assert!(cfg.fleet.population.size >= 200);
    assert_eq!(report.decisions,
               (cfg.ticks * cfg.fleet.population.size) as u64);
    // Transferred-LUT selections: ≤ 5% mean latency regret vs the
    // full-profile oracle.
    assert!(report.regret_mean_pct <= 5.0,
            "mean regret {}%", report.regret_mean_pct);
    assert!(report.regret_events
            >= cfg.regret_ticks.len() * cfg.fleet.population.size);
    // Cohort sharing demonstrably amortises: strictly fewer frontier
    // builds than devices, and hits dominate.
    assert!(report.cache_builds < cfg.fleet.population.size as u64,
            "{} builds for {} devices", report.cache_builds,
            cfg.fleet.population.size);
    assert!(report.cache_hits > report.cache_builds);
    // The storm actually exercises adaptation on a meaningful share of
    // the fleet.
    assert!(report.switches > 0 && report.devices_switched > 0);
}

#[test]
fn smoke_control_plane_meets_acceptance_criteria() {
    let reg = fake_registry();
    let cfg = FleetBenchConfig::smoke();
    let report = fleetbench::run(&reg, &cfg).unwrap();
    let cp = &report.control_plane;
    let cohorts = report.cohorts.len();
    // The deliberately mispredicted revision is caught at the canary rung
    // by the regret gate and rolled back to bit-identical pre-canary
    // LUTs, leaving no cohort on it.
    assert_eq!(cp.bad_stage, "rolled_back");
    assert!(cp.bad_reason.starts_with("regret_delta:"),
            "bad reason {:?}", cp.bad_reason);
    assert!(cp.bad_canary_regret_pct > cp.bad_control_regret_pct,
            "canary {}% vs control {}%", cp.bad_canary_regret_pct,
            cp.bad_control_regret_pct);
    assert_eq!(cp.bad_live_cohorts, 0);
    assert!(cp.rollback_fingerprints_match);
    // The good revision widens up the ladder and promotes fleet-wide.
    assert_eq!(cp.good_stage, "promoted");
    assert!(cp.good_rounds > 0);
    assert_eq!(cp.good_live_cohorts, cohorts);
    // Ingestion faults were exercised: the replayed canary report was
    // rejected exactly once.
    assert_eq!(cp.duplicates_rejected, 1);
    // Residual feedback shrinks the prediction error round over round
    // and closes the loop with regret no worse than the pre-feedback
    // storm baseline, without introducing deploy faults.
    assert!(cp.feedback_rounds > 0 && cp.feedback_corrections > 0);
    assert_eq!(cp.residual_mean_abs_ln.len(), cp.feedback_rounds);
    for w in cp.residual_mean_abs_ln.windows(2) {
        assert!(w[1] <= w[0] + 1e-9,
                "residuals must not grow: {} -> {}", w[0], w[1]);
    }
    assert!(cp.feedback_delta_updated > 0,
            "corrections must ride the frontier delta path");
    assert!(cp.regret_improved);
    assert!(cp.post_regret_mean_pct <= report.regret_mean_pct,
            "post-feedback mean {}% vs pre {}%", cp.post_regret_mean_pct,
            report.regret_mean_pct);
    assert_eq!(cp.post_deploy_faults, 0);
    // Sustained drift promotes some — but not every — cohort to a
    // measured anchor, and their rebuilds are lazy (paid by the closing
    // sweep, bounded by the re-anchored population).
    assert!(cp.re_anchored_cohorts > 0 && cp.re_anchored_cohorts < cohorts,
            "{} of {} cohorts re-anchored", cp.re_anchored_cohorts,
            cohorts);
    assert!(cp.post_feedback_builds > 0);
    assert!(cp.lookups > 0, "scenario sweeps must be cache-accounted");
}

#[test]
fn smoke_is_deterministic() {
    let reg = fake_registry();
    let cfg = FleetBenchConfig::smoke();
    let a = fleetbench::run(&reg, &cfg).unwrap();
    let b = fleetbench::run(&reg, &cfg).unwrap();
    assert_eq!(json::to_string(&fleetbench::report_json(&a)),
               json::to_string(&fleetbench::report_json(&b)));
}
