//! Property tests for the fleet control plane's residual feedback loop
//! (`fleet::feedback`): corrections monotonically shrink the residual
//! under seeded drift, the delta-carried frontiers match a from-scratch
//! rebuild after every correction, re-anchoring fires iff the
//! accumulated correction magnitude crosses the threshold, and a second
//! apply without fresh evidence (or with a bit-exact no-op correction)
//! is idempotent.

use std::sync::Arc;

use oodin::designspace::{rank, scoped_fingerprint, DesignSpace};
use oodin::device::EngineKind;
use oodin::fleet::{FeedbackConfig, FeedbackLoop, Fleet, FleetConfig,
                   PopulationConfig};
use oodin::manager::Conditions;
use oodin::measurements::LutKey;
use oodin::model::test_fixtures::fake_registry;
use oodin::optimizer::{Objective, SearchSpace};
use oodin::util::stats::Percentile;

fn obj() -> Objective {
    Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }
}

fn space() -> SearchSpace {
    SearchSpace::family("mobilenet_v2_100")
}

fn build_fleet() -> Fleet {
    let cfg = FleetConfig {
        population: PopulationConfig { size: 64, ..Default::default() },
        ..Default::default()
    };
    let fleet = Fleet::build(Arc::new(fake_registry()), cfg).unwrap();
    assert!(fleet.cohorts.len() >= 4,
            "need cohorts to correct, got {}", fleet.cohorts.len());
    fleet
}

fn warm_idle(fleet: &Fleet) {
    let sspace = space();
    for i in 0..fleet.len() {
        fleet.select(i, obj(), &sspace, &Conditions::idle()).unwrap();
    }
}

/// Every cohort's ground truth under seeded drift: the CPU rows of the
/// original LUT scaled by `drift` — what the devices "actually" run at
/// while the cohort still predicts from the unscaled LUT.
fn drift_targets(fleet: &Fleet, drift: f64) -> Vec<Vec<(LutKey, f64)>> {
    fleet
        .cohorts
        .iter()
        .map(|c| {
            c.lut
                .entries
                .iter()
                .filter(|(k, _)| k.engine == EngineKind::Cpu)
                .map(|(k, e)| (k.clone(), e.latency.avg * drift))
                .collect()
        })
        .collect()
}

/// One observation round: every CPU row's "measured" truth against the
/// cohort's current prediction for it.
fn observe_round(fb: &mut FeedbackLoop, fleet: &Fleet,
                 targets: &[Vec<(LutKey, f64)>]) {
    for (ci, rows) in targets.iter().enumerate() {
        for (key, measured) in rows {
            let predicted =
                fleet.cohorts[ci].lut.get(key).unwrap().latency.avg;
            fb.observe(ci, EngineKind::Cpu, *measured, predicted);
        }
    }
}

// ---------------------------------------------------------------------------
// Property 1: residual corrections monotonically shrink under seeded
// drift — after one round the predictions carry the geometric mean of
// the truth, so later rounds see (near-)zero residual.
// ---------------------------------------------------------------------------

#[test]
fn residuals_shrink_monotonically_under_seeded_drift() {
    let mut fleet = build_fleet();
    warm_idle(&fleet);
    let targets = drift_targets(&fleet, 1.3);
    let mut fb = FeedbackLoop::new(FeedbackConfig::default());

    let mut rounds = Vec::new();
    for _ in 0..3 {
        observe_round(&mut fb, &fleet, &targets);
        rounds.push(fb.apply_round(&mut fleet));
    }
    // Round 1 sees the full ln(1.3) drift; round 2 sees rounding noise.
    assert!(rounds[0].mean_abs_ln > 0.2, "{}", rounds[0].mean_abs_ln);
    assert!(rounds[1].mean_abs_ln < 1e-9, "{}", rounds[1].mean_abs_ln);
    for w in rounds.windows(2) {
        assert!(w[1].mean_abs_ln <= w[0].mean_abs_ln + 1e-9,
                "residuals must shrink: {} -> {}", w[0].mean_abs_ln,
                w[1].mean_abs_ln);
    }
    // The first round corrected every cohort through the delta path.
    assert_eq!(rounds[0].corrections, fleet.cohorts.len() as u64);
    assert!(rounds[0].delta.updated > 0,
            "warm frontiers must be carried, not dropped");
    // Accumulated magnitude records the drift that was corrected.
    for ci in 0..fleet.cohorts.len() {
        assert!(fb.accumulated(ci) > 0.2, "cohort {ci} accumulated \
                 {}", fb.accumulated(ci));
    }
}

// ---------------------------------------------------------------------------
// Property 2: after every correction the carried frontier matches a
// from-scratch rebuild — selections equal the fresh full search, with
// zero builds spent.
// ---------------------------------------------------------------------------

#[test]
fn corrected_frontiers_match_scratch_rebuild() {
    let mut fleet = build_fleet();
    let sspace = space();
    warm_idle(&fleet);
    let targets = drift_targets(&fleet, 0.7);
    let mut fb = FeedbackLoop::new(FeedbackConfig::default());
    observe_round(&mut fb, &fleet, &targets);
    let round = fb.apply_round(&mut fleet);
    assert!(round.corrections > 0);

    let builds_before = fleet.cache_stats().builds;
    for i in 0..fleet.len() {
        let got =
            fleet.select(i, obj(), &sspace, &Conditions::idle()).unwrap();
        let c = &fleet.cohorts[fleet.device_cohort[i]];
        let ds = DesignSpace::new(&c.rep, &fleet.registry, &c.lut);
        let fresh = rank(ds.enumerate(obj(), &sspace, &Conditions::idle()),
                         obj());
        assert_eq!(got, fresh[0].design,
                   "device {i}: carried frontier diverged from rebuild");
    }
    assert_eq!(fleet.cache_stats().builds, builds_before,
               "corrections must carry warm frontiers, not rebuild them");
}

// ---------------------------------------------------------------------------
// Property 3: re-anchoring fires iff the accumulated correction
// magnitude crosses the threshold, resets the magnitude, and lazily
// invalidates the cohort's cached frontiers.
// ---------------------------------------------------------------------------

#[test]
fn re_anchor_fires_iff_threshold_crossed() {
    let mut fleet = build_fleet();
    let sspace = space();
    warm_idle(&fleet);
    let threshold = FeedbackConfig::default().re_anchor_threshold;
    let mut fb = FeedbackLoop::new(FeedbackConfig::default());

    // Cohort 0 drifts far past the threshold, cohort 1 barely at all.
    let targets = drift_targets(&fleet, 1.0);
    let big: Vec<(LutKey, f64)> = targets[0]
        .iter()
        .map(|(k, v)| (k.clone(), v * (2.0 * threshold).exp()))
        .collect();
    let small: Vec<(LutKey, f64)> = targets[1]
        .iter()
        .map(|(k, v)| (k.clone(), v * (0.1 * threshold).exp()))
        .collect();
    observe_round(&mut fb, &fleet,
                  &[big, small, Vec::new(), Vec::new()]);
    fb.apply_round(&mut fleet);
    assert!(fb.accumulated(0) > threshold);
    assert!(fb.accumulated(1) > 0.0 && fb.accumulated(1) < threshold);

    let outcomes = fb.re_anchor(&mut fleet).unwrap();
    assert_eq!(outcomes.len(), 1, "exactly cohort 0 crossed");
    assert_eq!(outcomes[0].cohort, 0);
    assert_eq!(outcomes[0].device,
               fleet.devices[fleet.cohorts[0].members[0]].id);
    assert!(outcomes[0].magnitude > threshold);
    assert_eq!(outcomes[0].entries, fleet.cohorts[0].lut.len());
    // The magnitude resets; the untripped cohort's keeps accumulating.
    assert_eq!(fb.accumulated(0), 0.0);
    assert!(fb.accumulated(1) > 0.0);
    assert_eq!(fb.re_anchored(), vec![0]);
    // Nothing left above the threshold: a second pass is a no-op.
    assert!(fb.re_anchor(&mut fleet).unwrap().is_empty());

    // The re-anchored LUT is an undescribed change: the warm idle
    // frontier invalidates lazily and rebuilds on the next lookup,
    // landing on the fresh full search of the measured LUT.
    let stats_before = fleet.cache_stats();
    let dev = fleet.cohorts[0].members[0];
    let got =
        fleet.select(dev, obj(), &sspace, &Conditions::idle()).unwrap();
    let stats_after = fleet.cache_stats();
    assert_eq!(stats_after.builds, stats_before.builds + 1);
    assert_eq!(stats_after.invalidations, stats_before.invalidations + 1);
    let c = &fleet.cohorts[0];
    let ds = DesignSpace::new(&c.rep, &fleet.registry, &c.lut);
    let fresh =
        rank(ds.enumerate(obj(), &sspace, &Conditions::idle()), obj());
    assert_eq!(got, fresh[0].design);
}

// ---------------------------------------------------------------------------
// Property 4: applying twice is idempotent — a drained loop corrects
// nothing, and a bit-exact no-op correction (factor exactly 1.0) leaves
// every fingerprint untouched.
// ---------------------------------------------------------------------------

#[test]
fn second_apply_is_idempotent() {
    let mut fleet = build_fleet();
    let sspace = space();
    warm_idle(&fleet);
    let mut fb = FeedbackLoop::new(FeedbackConfig::default());
    let targets = drift_targets(&fleet, 1.2);
    observe_round(&mut fb, &fleet, &targets);
    let first = fb.apply_round(&mut fleet);
    assert!(first.corrections > 0);
    let fps: Vec<u64> = fleet
        .cohorts
        .iter()
        .map(|c| scoped_fingerprint(&c.lut, &fleet.registry, &sspace))
        .collect();

    // The cells drained: a second apply without fresh evidence does
    // nothing at all.
    let second = fb.apply_round(&mut fleet);
    assert_eq!(second.samples, 0);
    assert_eq!(second.corrections, 0);
    assert_eq!(second.delta.updated, 0);
    assert_eq!(second.mean_abs_ln, 0.0);
    let fps2: Vec<u64> = fleet
        .cohorts
        .iter()
        .map(|c| scoped_fingerprint(&c.lut, &fleet.registry, &sspace))
        .collect();
    assert_eq!(fps, fps2);

    // measured == predicted distils factor exactly 1.0: the correction
    // is applied (and counted) but every value is bit-identical, so the
    // scope fingerprints — and therefore the caches — are untouched.
    let v = 10.0;
    fb.observe(0, EngineKind::Cpu, v, v);
    fb.observe(0, EngineKind::Cpu, v, v);
    let noop = fb.apply_round(&mut fleet);
    assert_eq!(noop.corrections, 1);
    assert_eq!(noop.delta.updated, 0);
    assert!(noop.delta.untouched > 0,
            "warm entries must be recognised as untouched");
    let fps3: Vec<u64> = fleet
        .cohorts
        .iter()
        .map(|c| scoped_fingerprint(&c.lut, &fleet.registry, &sspace))
        .collect();
    assert_eq!(fps, fps3);
}

// ---------------------------------------------------------------------------
// Property 5: observe() discards meaningless inputs.
// ---------------------------------------------------------------------------

#[test]
fn observe_rejects_non_positive_and_non_finite_inputs() {
    let mut fb = FeedbackLoop::new(FeedbackConfig::default());
    fb.observe(0, EngineKind::Cpu, -1.0, 5.0);
    fb.observe(0, EngineKind::Cpu, 5.0, 0.0);
    fb.observe(0, EngineKind::Cpu, f64::NAN, 5.0);
    fb.observe(0, EngineKind::Cpu, 5.0, f64::INFINITY);
    assert_eq!(fb.pending_samples(), 0);
}
