//! Property-based tests for the serving front-end's batching coordinator,
//! driven through the deterministic `SimBackend` (no artifacts needed).
//! Hand-rolled in the `rust/tests/properties.rs` style: `util::rng::Rng`
//! generates seeded random cases and every assertion prints its case id.
//!
//! Invariants:
//! * no accepted request is ever dropped — every reply channel resolves;
//! * responses map to their own requests (no cross-wiring inside a batch,
//!   across chunked batches, or under queue pressure);
//! * `try_submit` backpressure triggers at the configured queue bound and
//!   accepted requests still complete;
//! * the bounded queue never exceeds its capacity (queue-depth telemetry),
//!   refused requests are counted in `shed_requests`, and sustained
//!   pressure engages the degraded (INT8) ladder until the backlog drains.

use std::sync::Arc;

use oodin::device::profiles::samsung_a71;
use oodin::model::test_fixtures::{bench_registry, serving_registry};
use oodin::model::{Precision, Registry};
use oodin::runtime::{Backend, SimBackend};
use oodin::serving::{Server, ServerConfig};
use oodin::sil::camera::class_frame;
use oodin::util::rng::Rng;

const RES: usize = 16;

fn backend(reg: &Registry, wall_delay_ms: f64) -> Arc<dyn Backend> {
    Arc::new(
        SimBackend::new(samsung_a71(), reg.clone()).with_wall_delay_ms(wall_delay_ms),
    )
}

fn config(reg: &Registry) -> ServerConfig {
    ServerConfig::for_family(reg, "cls", Precision::Fp32).unwrap()
}

#[test]
fn prop_no_request_dropped_and_responses_map_to_requests() {
    for case in 0..6u64 {
        let mut rng = Rng::new(9000 + case);
        let reg = serving_registry(RES);
        let mut cfg = config(&reg);
        cfg.max_batch_delay_ms = rng.range(0.0, 3.0);
        cfg.queue_cap = 8 + rng.below(56);
        let srv = Server::start(backend(&reg, 0.0), &reg, cfg).unwrap();

        let n = 20 + rng.below(60);
        let labels: Vec<usize> = (0..n).map(|_| rng.below(10)).collect();
        let rxs: Vec<_> = labels
            .iter()
            .map(|&c| srv.submit(class_frame(RES, c), RES, RES).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx
                .recv()
                .unwrap_or_else(|_| panic!("case {case}: request {i} dropped"))
                .unwrap_or_else(|e| panic!("case {case}: request {i} failed: {e}"));
            assert_eq!(resp.class, labels[i],
                       "case {case}: response {i} mapped to wrong request");
            assert!(resp.batch >= 1 && resp.queue_ms >= 0.0, "case {case}");
        }
        // Accounting: every accepted request rode exactly one batch, and
        // every executed slot is either a real request or counted padding.
        assert_eq!(srv.telemetry.counter("batched_requests"), n as u64,
                   "case {case}");
        let executed = srv.telemetry.counter("executed_slots");
        let padded = srv.telemetry.counter("padded_slots");
        assert_eq!(executed, n as u64 + padded, "case {case}");
        // Pad-up never wastes more than the configured per-batch bound.
        assert!(srv.wasted_compute_ratio() <= 0.25 + 1e-12,
                "case {case}: wasted {}", srv.wasted_compute_ratio());
        srv.stop();
    }
}

#[test]
fn prop_padded_tail_is_counted_not_invisible() {
    // Three requests against a {1,4} batch ladder: the flushed tail rounds
    // up to b4 with exactly one replicated slot, and that slot must show up
    // in telemetry as wasted compute.
    let reg = serving_registry(RES);
    let mut cfg = config(&reg);
    cfg.max_batch_delay_ms = 60.0;
    let srv = Server::start(backend(&reg, 0.0), &reg, cfg).unwrap();
    let rxs: Vec<_> = (0..3)
        .map(|c| srv.submit(class_frame(RES, c), RES, RES).unwrap())
        .collect();
    for (c, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.class, c, "padding corrupted a real response");
        assert_eq!(resp.batch, 4);
        assert_eq!(resp.variant, "cls__fp32__b4");
    }
    assert_eq!(srv.telemetry.counter("padded_slots"), 1);
    assert_eq!(srv.telemetry.counter("executed_slots"), 4);
    assert!((srv.wasted_compute_ratio() - 0.25).abs() < 1e-12,
            "wasted {}", srv.wasted_compute_ratio());
    srv.stop();
}

#[test]
fn prop_try_submit_backpressure_at_queue_bound() {
    for case in 0..4u64 {
        let mut rng = Rng::new(11_000 + case);
        let reg = serving_registry(RES);
        let mut cfg = config(&reg);
        cfg.queue_cap = 1 + rng.below(3);
        cfg.max_batch_delay_ms = 1.0;
        // A real per-execution delay makes the queue fill deterministically.
        let srv = Server::start(backend(&reg, 4.0), &reg, cfg).unwrap();

        let mut accepted = Vec::new();
        let mut refused = 0usize;
        for i in 0..64usize {
            let label = i % 10;
            match srv.try_submit(class_frame(RES, label), RES, RES).unwrap() {
                Some(rx) => accepted.push((label, rx)),
                None => refused += 1,
            }
        }
        assert!(refused > 0,
                "case {case}: 64 instant submits against a <=4-deep queue \
                 must hit backpressure");
        // Everything accepted still completes, correctly mapped.
        for (label, rx) in accepted {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.class, label, "case {case}");
        }
        srv.stop();
    }
}

#[test]
fn prop_queue_depth_telemetry_never_exceeds_capacity() {
    for case in 0..3u64 {
        let mut rng = Rng::new(15_000 + case);
        let reg = serving_registry(RES);
        let mut cfg = config(&reg);
        cfg.queue_cap = 2 + rng.below(6);
        cfg.max_batch_delay_ms = 1.0;
        let srv = Server::start(backend(&reg, 2.0), &reg, cfg.clone()).unwrap();
        let mut rxs = Vec::new();
        let mut refused = 0u64;
        for i in 0..48usize {
            if i % 2 == 0 {
                rxs.push(srv.submit(class_frame(RES, i % 10), RES, RES).unwrap());
            } else {
                match srv.try_submit(class_frame(RES, i % 10), RES, RES).unwrap() {
                    Some(rx) => rxs.push(rx),
                    None => refused += 1,
                }
            }
        }
        for rx in rxs {
            let _ = rx.recv();
        }
        // The queue-depth gauge (sampled at every admission) must respect
        // the bound, and every refusal must be counted.
        let depth = srv.telemetry.stats("queue_depth").unwrap();
        assert!(depth.max <= cfg.queue_cap as f64,
                "case {case}: depth {} > cap {}", depth.max, cfg.queue_cap);
        assert_eq!(srv.telemetry.counter("shed_requests"), refused,
                   "case {case}: sheds not counted");
        srv.stop();
    }
}

#[test]
fn prop_sustained_pressure_engages_degraded_ladder() {
    // The `srv` bench family carries an FP32 primary ladder and an INT8
    // degraded ladder.  A slow backend plus blocking submits keeps the
    // queue at its bound, which must flip the pipeline into degraded mode
    // (responses flagged, telemetry counted) — and the flagged responses
    // must still decode their own class exactly.
    let reg = bench_registry(RES);
    let be: Arc<dyn Backend> = Arc::new(
        SimBackend::new(samsung_a71(), reg.clone()).with_wall_delay_ms(3.0),
    );
    let mut cfg = ServerConfig::for_family(&reg, "srv", Precision::Fp32)
        .unwrap()
        .with_degraded(&reg, "srv", Precision::Int8, 8, 2);
    cfg.queue_cap = 16;
    cfg.max_batch_delay_ms = 1.0;
    let srv = Server::start(be, &reg, cfg).unwrap();
    let rxs: Vec<_> = (0..64)
        .map(|i| srv.submit(class_frame(RES, i % 10), RES, RES).unwrap())
        .collect();
    let mut degraded = 0u64;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.class, i % 10, "degradation corrupted request {i}");
        if resp.degraded {
            assert!(resp.variant.contains("int8"),
                    "degraded response served by {}", resp.variant);
            degraded += 1;
        }
    }
    assert!(degraded > 0, "16-deep queue at the bound never degraded");
    assert_eq!(srv.telemetry.counter("degraded_requests"), degraded);
    assert_eq!(srv.telemetry.counter("batched_requests"), 64);
    srv.stop();
}

#[test]
fn prop_mixed_submit_try_submit_consistent() {
    // Interleave blocking and non-blocking submission under load; every
    // delivered reply must still carry its own request's class.
    for case in 0..4u64 {
        let mut rng = Rng::new(13_000 + case);
        let reg = serving_registry(RES);
        let mut cfg = config(&reg);
        cfg.queue_cap = 4;
        cfg.max_batch_delay_ms = rng.range(0.5, 2.0);
        let srv = Server::start(backend(&reg, 1.0), &reg, cfg).unwrap();

        let mut pending = Vec::new();
        for i in 0..40usize {
            let label = rng.below(10);
            if i % 2 == 0 {
                pending.push((label, srv.submit(class_frame(RES, label), RES, RES).unwrap()));
            } else if let Some(rx) =
                srv.try_submit(class_frame(RES, label), RES, RES).unwrap()
            {
                pending.push((label, rx));
            }
        }
        assert!(!pending.is_empty(), "case {case}");
        for (label, rx) in pending {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.class, label, "case {case}");
        }
        srv.stop();
    }
}
