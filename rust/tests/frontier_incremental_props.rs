//! Differential property suite for incremental frontier maintenance
//! (`designspace::frontier`): on random LUTs and random change-sets —
//! single-entry edits, per-engine scale corrections (slowdowns *and*
//! speedups, so the deployability bound is crossed in both directions),
//! and entry removals — the delta-updated frontier must be set-identical
//! to a from-scratch rebuild (the reference implementation), and
//! `RuntimeManager::best_under` picks must be equal at idle and at random
//! condition buckets.  The delta path is provably equivalent, not just
//! plausible: every comparison below is bit-exact on the metric vector.

use std::collections::BTreeMap;
use std::sync::Arc;

use oodin::designspace::{rank, ConditionsBucket, DesignSpace, FrontierCache,
                         LutDelta, ParetoFrontier};
use oodin::device::profiles::samsung_a71;
use oodin::device::EngineKind;
use oodin::manager::Conditions;
use oodin::measurements::{ExecPlan, Lut, LutEntry, LutKey};
use oodin::model::test_fixtures::fake_registry;
use oodin::optimizer::{Objective, SearchSpace};
use oodin::util::rng::Rng;
use oodin::util::stats::{LatencyStats, Percentile};

/// A random-but-valid LUT for the A71 (same recipe as
/// `tests/designspace_props.rs`) with base latencies wide enough to
/// straddle the 25 ms sustained-deployability bound — scale corrections
/// must be able to push designs across it in both directions.
fn random_lut(rng: &mut Rng) -> Lut {
    let reg = fake_registry();
    let dev = samsung_a71();
    let mut entries = BTreeMap::new();
    for v in reg.variants() {
        for spec in &dev.engines {
            let threads: Vec<usize> = if spec.kind == EngineKind::Cpu {
                dev.thread_candidates()
            } else {
                vec![1]
            };
            for t in threads {
                for g in &dev.governors {
                    let base = rng.range(0.05, 60.0);
                    let samples: Vec<f64> =
                        (0..30).map(|_| base * rng.lognormal(0.05)).collect();
                    entries.insert(
                        LutKey { variant: v.name.clone(), engine: spec.kind,
                                 threads: t, governor: *g,
                                 plan: ExecPlan::Mono },
                        LutEntry {
                            latency: LatencyStats::from_samples(&samples),
                            mem_bytes: v.mem_bytes(),
                            accuracy: v.accuracy,
                            stages: Vec::new(),
                        },
                    );
                }
            }
        }
    }
    Lut { device: "samsung_a71".to_string(), entries }
}

fn objectives() -> Vec<Objective> {
    vec![
        Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 },
        Objective::MinLatency { stat: Percentile::P90, epsilon: 0.0 },
        Objective::MaxFps { epsilon: 0.05 },
        Objective::TargetLatency { t_target_ms: 20.0, stat: Percentile::Avg },
        Objective::MaxAccMaxFps { w_fps: 1.0 },
    ]
}

fn spaces() -> Vec<SearchSpace> {
    vec![
        SearchSpace::default(),
        SearchSpace::family("mobilenet_v2_100"),
        SearchSpace::family("deeplab_v3"),
    ]
}

fn random_conditions(rng: &mut Rng) -> Conditions {
    let mut conds = Conditions::idle();
    for e in EngineKind::ALL {
        if rng.below(2) == 1 {
            conds.loads.insert(e, rng.range(0.0, 3.0));
        }
        if rng.below(4) == 0 {
            conds.thermal.insert(e, rng.range(0.3, 1.0));
        }
    }
    conds
}

/// A random change-set over `lut`: the mutated LUT plus the delta
/// describing it.  `kind` cycles through single-entry edits, removals,
/// per-engine scales (slowdown and speedup) and a mixed set.
fn random_delta(rng: &mut Rng, lut: &Lut, kind: u64) -> (Lut, LutDelta) {
    let keys: Vec<LutKey> = lut.entries.keys().cloned().collect();
    let mut new = lut.clone();
    match kind % 5 {
        0 => {
            // Single-entry edits: latency rescale, occasionally an
            // accuracy bump (crosses the ε-constraint).
            for _ in 0..=rng.below(3) {
                let k = &keys[rng.below(keys.len())];
                let e = new.entries.get_mut(k).unwrap();
                e.latency = e.latency.scaled(rng.range(0.3, 3.0));
                if rng.below(3) == 0 {
                    e.accuracy = (e.accuracy - 0.03).max(0.0);
                }
            }
            (new.clone(), LutDelta::between(lut, &new))
        }
        1 => {
            // Entry removals.
            for _ in 0..=rng.below(3) {
                let k = &keys[rng.below(keys.len())];
                new.entries.remove(k);
            }
            (new.clone(), LutDelta::between(lut, &new))
        }
        2 => {
            // Per-engine slowdown (can push designs past deployability).
            let e = EngineKind::ALL[rng.below(EngineKind::ALL.len())];
            let f = rng.range(1.05, 1.9);
            (lut.scaled_engine(e, f), LutDelta::engine_scale(e, f))
        }
        3 => {
            // Per-engine speedup (can newly admit undeployable designs).
            let e = EngineKind::ALL[rng.below(EngineKind::ALL.len())];
            let f = rng.range(0.4, 0.95);
            (lut.scaled_engine(e, f), LutDelta::engine_scale(e, f))
        }
        _ => {
            // Mixed: a scale plus entry edits and a removal on top.
            let e = EngineKind::ALL[rng.below(EngineKind::ALL.len())];
            let f = rng.range(0.5, 1.5);
            let mut new = lut.scaled_engine(e, f);
            let k = &keys[rng.below(keys.len())];
            new.entries
                .get_mut(k)
                .unwrap()
                .latency = lut.entries[k].latency.scaled(rng.range(0.3, 3.0));
            let r = &keys[rng.below(keys.len())];
            new.entries.remove(r);
            let mut delta = LutDelta::between(lut, &new);
            // Re-express the uniform part as a scale: drop the scaled
            // engine's keys from `changed` unless individually edited.
            delta.changed.retain(|c| {
                c.engine != e || c == k || !lut.entries.contains_key(c)
            });
            delta.engine_scales.insert(e, f);
            (new, delta)
        }
    }
}

fn assert_frontiers_identical(got: &ParetoFrontier, want: &ParetoFrontier,
                              ctx: &str) {
    assert_eq!(got.space_size, want.space_size, "{ctx}: space_size");
    assert_eq!(got.len(), want.len(), "{ctx}: point count");
    for (a, b) in got.points().iter().zip(want.points()) {
        assert_eq!(a.design, b.design, "{ctx}: design order");
        assert_eq!(a.latency_ms.to_bits(), b.latency_ms.to_bits(), "{ctx}");
        assert_eq!(a.avg_latency_ms.to_bits(), b.avg_latency_ms.to_bits(),
                   "{ctx}");
        assert_eq!(a.fps.to_bits(), b.fps.to_bits(), "{ctx}");
        assert_eq!(a.energy_mj.to_bits(), b.energy_mj.to_bits(), "{ctx}");
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}");
        assert_eq!(a.mem_bytes, b.mem_bytes, "{ctx}");
        assert_eq!(a.score.to_bits(), b.score.to_bits(), "{ctx}");
    }
}

#[test]
fn prop_delta_update_is_set_identical_to_rebuild() {
    let dev = samsung_a71();
    let reg = fake_registry();
    for case in 0..40u64 {
        let mut rng = Rng::new(71_000 + case);
        let lut = random_lut(&mut rng);
        let (lut2, delta) = random_delta(&mut rng, &lut, case);
        let obj = objectives()[rng.below(objectives().len())];
        let sspace = spaces()[rng.below(spaces().len())].clone();
        let old_ds = DesignSpace::new(&dev, &reg, &lut);
        let new_ds = DesignSpace::new(&dev, &reg, &lut2);
        // Warm the idle bucket plus three random buckets, then carry all
        // resident frontiers across the transition in one call.
        let mut cache = FrontierCache::new();
        let mut buckets = vec![ConditionsBucket::of(&Conditions::idle())];
        for _ in 0..3 {
            buckets.push(ConditionsBucket::of(&random_conditions(&mut rng)));
        }
        for b in &buckets {
            cache.frontier(&old_ds, obj, &sspace, b);
        }
        let builds_before = cache.stats.builds;
        let out = cache.apply_delta(&old_ds, &new_ds, &delta);
        assert_eq!(out.dropped, 0, "case {case}: no fallback expected");
        for b in &buckets {
            let got = cache.frontier(&new_ds, obj, &sspace, b);
            let want = ParetoFrontier::build(&new_ds, obj, &sspace, b);
            assert_frontiers_identical(
                &got, &want,
                &format!("case {case} kind {} bucket {}", case % 5, b.id()));
        }
        assert_eq!(cache.stats.builds, builds_before,
                   "case {case}: lookups after the delta must all hit");
        // Idempotency: re-applying the same transition is a no-op.
        let again = cache.apply_delta(&old_ds, &new_ds, &delta);
        assert_eq!(again.updated, 0, "case {case}: re-apply must not touch");
        assert_eq!(again.points_touched, 0);
    }
}

#[test]
fn prop_delta_touches_fewer_points_than_rebuild() {
    // The perf gate's property: on every change-set the delta path must
    // re-evaluate strictly fewer candidates than the rebuild it replaces
    // (rebuild cost = the enumerated space, per updated frontier).
    let dev = samsung_a71();
    let reg = fake_registry();
    for case in 0..20u64 {
        let mut rng = Rng::new(72_000 + case);
        let lut = random_lut(&mut rng);
        let (lut2, delta) = random_delta(&mut rng, &lut, case);
        // Unrestricted space: every change-set intersects the scope.
        let obj = Objective::MinLatency { stat: Percentile::Avg,
                                          epsilon: 0.05 };
        let sspace = SearchSpace::default();
        let old_ds = DesignSpace::new(&dev, &reg, &lut);
        let new_ds = DesignSpace::new(&dev, &reg, &lut2);
        let mut cache = FrontierCache::new();
        cache.frontier(&old_ds, obj, &sspace,
                       &ConditionsBucket::of(&Conditions::idle()));
        let out = cache.apply_delta(&old_ds, &new_ds, &delta);
        if out.updated > 0 {
            assert!(out.points_touched < out.rebuild_points,
                    "case {case}: delta touched {} !< rebuild {}",
                    out.points_touched, out.rebuild_points);
        }
    }
}

#[test]
fn prop_best_under_picks_equal_after_delta() {
    // Thread the delta through the RuntimeManager: after
    // `apply_lut_delta`, `best_under` must equal a full enumerate+rank
    // over the new LUT at idle and at random buckets.
    let dev = samsung_a71();
    let reg = fake_registry();
    for case in 0..15u64 {
        let mut rng = Rng::new(73_000 + case);
        let lut = random_lut(&mut rng);
        let (lut2, delta) = random_delta(&mut rng, &lut, case);
        let obj = objectives()[rng.below(objectives().len())];
        let sspace = SearchSpace::family("mobilenet_v2_100");
        let old_ds = DesignSpace::new(&dev, &reg, &lut);
        let init = {
            let full = rank(old_ds.enumerate(obj, &sspace,
                                             &Conditions::idle()), obj);
            match full.first() {
                Some(c) => c.design.clone(),
                None => continue, // infeasible under this random LUT
            }
        };
        let mut mgr = oodin::manager::RuntimeManager::new(
            Arc::new(dev.clone()), Arc::new(reg.clone()),
            Arc::new(lut.clone()), obj, sspace.clone(), init);
        // Warm idle + two random buckets before the correction lands.
        let mut probes = vec![Conditions::idle()];
        for _ in 0..2 {
            probes.push(random_conditions(&mut rng));
        }
        for c in &probes {
            let _ = mgr.best_under(c);
        }
        mgr.apply_lut_delta(Arc::new(lut2.clone()), &delta);
        let new_ds = DesignSpace::new(&dev, &reg, &lut2);
        for (pi, conds) in probes.iter().enumerate() {
            let bucket = ConditionsBucket::of(conds);
            let full = rank(new_ds.enumerate(obj, &sspace,
                                             &bucket.representative()), obj);
            match mgr.best_under(conds) {
                Ok(pick) => {
                    // TargetLatency re-checks at exact conditions; compare
                    // against the frontier reference semantics instead of
                    // blind rank[0] there.
                    if matches!(obj, Objective::TargetLatency { .. }) {
                        let f = ParetoFrontier::build(&new_ds, obj, &sspace,
                                                      &bucket);
                        let want = oodin::designspace::select_from_frontier(
                            &f, &lut2, obj, conds).unwrap();
                        assert_eq!(pick, want.design,
                                   "case {case} probe {pi}");
                    } else {
                        assert_eq!(pick, full[0].design,
                                   "case {case} probe {pi}");
                    }
                }
                Err(_) => {
                    if !matches!(obj, Objective::TargetLatency { .. }) {
                        assert!(full.is_empty(), "case {case} probe {pi}");
                    }
                }
            }
        }
    }
}

#[test]
fn delta_fallback_drops_entries_that_predate_the_transition() {
    // A cache built under LUT₀ asked to carry (LUT₁ → LUT₂) must fall
    // back to rebuild-on-demand, never serve a stale frontier.
    let dev = samsung_a71();
    let reg = fake_registry();
    let mut rng = Rng::new(74_000);
    let lut0 = random_lut(&mut rng);
    let lut1 = lut0.scaled_engine(EngineKind::Cpu, 1.3);
    let lut2 = lut1.scaled_engine(EngineKind::Gpu, 1.3);
    let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 };
    let sspace = SearchSpace::default();
    let b = ConditionsBucket::of(&Conditions::idle());
    let mut cache = FrontierCache::new();
    let ds0 = DesignSpace::new(&dev, &reg, &lut0);
    cache.frontier(&ds0, obj, &sspace, &b);
    let ds1 = DesignSpace::new(&dev, &reg, &lut1);
    let ds2 = DesignSpace::new(&dev, &reg, &lut2);
    let out = cache.apply_delta(&ds1, &ds2,
                                &LutDelta::engine_scale(EngineKind::Gpu, 1.3));
    assert_eq!((out.updated, out.dropped), (0, 1));
    assert_eq!(cache.stats.invalidations, 1);
    let got = cache.frontier(&ds2, obj, &sspace, &b);
    let want = ParetoFrontier::build(&ds2, obj, &sspace, &b);
    assert_frontiers_identical(&got, &want, "fallback rebuild");
}
