//! Property-based tests on coordinator invariants (hand-rolled: the
//! offline image has no proptest; `oodin::util::rng::Rng` drives seeded
//! random-case generation with the same spirit — every case prints its
//! seed on failure).

use std::collections::BTreeMap;

use oodin::device::profiles::{profiles, samsung_a71};
use oodin::device::EngineKind;
use oodin::dvfs::Governor;
use oodin::measurements::{ExecPlan, Lut, LutEntry, LutKey, Measurer};
use oodin::model::test_fixtures::fake_registry;
use oodin::model::Precision;
use oodin::optimizer::{Design, HwConfig, Objective, Optimizer, SearchSpace};
use oodin::util::json;
use oodin::util::rng::Rng;
use oodin::util::stats::{LatencyStats, Percentile};

const CASES: usize = 60;

/// Generate a random-but-valid LUT for a device from random base latencies.
fn random_lut(rng: &mut Rng, device: &str) -> (Lut, Vec<String>) {
    let reg = fake_registry();
    let dev = profiles().into_iter().find(|d| d.name == device).unwrap();
    let mut entries = BTreeMap::new();
    let mut variants = Vec::new();
    for v in reg.variants() {
        variants.push(v.name.clone());
        for spec in &dev.engines {
            let threads: Vec<usize> = if spec.kind == EngineKind::Cpu {
                dev.thread_candidates()
            } else {
                vec![1]
            };
            for t in threads {
                for g in &dev.governors {
                    let base = rng.range(0.01, 5.0);
                    let samples: Vec<f64> =
                        (0..30).map(|_| base * rng.lognormal(0.05)).collect();
                    entries.insert(
                        LutKey { variant: v.name.clone(), engine: spec.kind,
                                 threads: t, governor: *g,
                                 plan: ExecPlan::Mono },
                        LutEntry {
                            latency: LatencyStats::from_samples(&samples),
                            mem_bytes: v.mem_bytes(),
                            accuracy: v.accuracy,
                            stages: Vec::new(),
                        },
                    );
                }
            }
        }
    }
    (Lut { device: device.to_string(), entries }, variants)
}

#[test]
fn prop_optimizer_result_is_global_minimum() {
    // For MinLatency the returned design must be the argmin over every
    // feasible LUT entry — on *randomised* LUTs, not just the perf model's.
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case as u64);
        let (lut, _) = random_lut(&mut rng, "samsung_a71");
        let dev = samsung_a71();
        let reg = fake_registry();
        let opt = Optimizer::new(&dev, &reg, &lut);
        let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: 1.0 };
        let Ok(best) = opt.optimize(obj, &SearchSpace::default()) else {
            continue;
        };
        for (k, e) in &lut.entries {
            let v = reg.get(&k.variant).unwrap();
            if !oodin::perf::fits_memory(&dev, v)
                || e.latency.avg > dev.max_deployable_latency_ms
            {
                continue;
            }
            assert!(
                best.latency_ms <= e.latency.avg + 1e-9,
                "seed {case}: {k:?} beats the returned optimum"
            );
        }
    }
}

#[test]
fn prop_epsilon_constraint_always_respected() {
    for case in 0..CASES {
        let mut rng = Rng::new(2000 + case as u64);
        let (lut, _) = random_lut(&mut rng, "samsung_a71");
        let dev = samsung_a71();
        let reg = fake_registry();
        let opt = Optimizer::new(&dev, &reg, &lut);
        let eps = rng.range(0.0, 0.03);
        let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: eps };
        if let Ok(all) = opt.search(obj, &SearchSpace::default()) {
            for cand in all {
                let v = reg.get(&cand.design.variant).unwrap();
                let a_ref = opt.reference_accuracy(&v.family).unwrap();
                assert!(
                    a_ref - cand.accuracy <= eps + 1e-9,
                    "seed {case}: ε violated ({} vs ref {a_ref})", cand.accuracy
                );
            }
        }
    }
}

#[test]
fn prop_target_latency_never_exceeds_budget() {
    for case in 0..CASES {
        let mut rng = Rng::new(3000 + case as u64);
        let (lut, _) = random_lut(&mut rng, "samsung_s20_fe");
        let dev = profiles().into_iter().find(|d| d.name == "samsung_s20_fe").unwrap();
        let reg = fake_registry();
        let opt = Optimizer::new(&dev, &reg, &lut);
        let budget = rng.range(0.05, 3.0);
        let obj = Objective::TargetLatency { t_target_ms: budget, stat: Percentile::P90 };
        if let Ok(best) = opt.optimize(obj, &SearchSpace::default()) {
            assert!(best.latency_ms <= budget + 1e-9,
                    "seed {case}: budget {budget} exceeded: {}", best.latency_ms);
        }
    }
}

#[test]
fn prop_search_space_restrictions_are_honoured() {
    for case in 0..CASES {
        let mut rng = Rng::new(4000 + case as u64);
        let (lut, _) = random_lut(&mut rng, "samsung_a71");
        let dev = samsung_a71();
        let reg = fake_registry();
        let opt = Optimizer::new(&dev, &reg, &lut);
        let engine = *rng.choose(&[EngineKind::Cpu, EngineKind::Gpu, EngineKind::Npu]);
        let prec = *rng.choose(&[Precision::Fp32, Precision::Fp16, Precision::Int8]);
        let space = SearchSpace::default()
            .with_engines(&[engine])
            .with_precisions(&[prec]);
        let obj = Objective::MaxFps { epsilon: 1.0 };
        if let Ok(all) = opt.search(obj, &space) {
            for cand in all {
                assert_eq!(cand.design.hw.engine, engine, "seed {case}");
                let v = reg.get(&cand.design.variant).unwrap();
                assert_eq!(v.precision, prec, "seed {case}");
            }
        }
    }
}

#[test]
fn prop_lut_json_roundtrip_random() {
    for case in 0..CASES {
        let mut rng = Rng::new(5000 + case as u64);
        let (lut, _) = random_lut(&mut rng, "sony_c5");
        let back = Lut::from_json(&lut.to_json()).unwrap();
        assert_eq!(back.len(), lut.len(), "seed {case}");
        for (k, e) in &lut.entries {
            let b = back.get(k).expect("key survives");
            assert_eq!(b.latency, e.latency, "seed {case}");
        }
    }
}

#[test]
fn prop_json_number_roundtrip() {
    let mut rng = Rng::new(99);
    for case in 0..500 {
        let x = (rng.f64() - 0.5) * 10f64.powi((rng.below(12) as i32) - 3);
        let text = json::to_string(&json::Value::Num(x));
        let back = json::parse(&text).unwrap();
        let y = back.as_f64().unwrap();
        assert!((x - y).abs() <= x.abs() * 1e-12 + 1e-15, "case {case}: {x} vs {y}");
    }
}

#[test]
fn prop_json_string_roundtrip() {
    let mut rng = Rng::new(7);
    let alphabet: Vec<char> =
        "abc\"\\\n\t é😀{}[]:,0".chars().collect();
    for case in 0..300 {
        let len = rng.below(20);
        let s: String = (0..len).map(|_| *rng.choose(&alphabet)).collect();
        let text = json::to_string(&json::Value::Str(s.clone()));
        let back = json::parse(&text).unwrap();
        assert_eq!(back.as_str().unwrap(), s, "case {case}");
    }
}

#[test]
fn prop_measurer_deterministic_across_runs() {
    // Same (device, key) always reproduces identical stats — the LUTs the
    // Runtime Manager holds must match what the optimiser saw.
    let reg = fake_registry();
    for case in 0..10 {
        let dev = samsung_a71();
        let m1 = Measurer::new(&dev, &reg).with_runs(25, 2);
        let m2 = Measurer::new(&dev, &reg).with_runs(25, 2);
        let mut rng = Rng::new(6000 + case);
        let v = reg.variants()[rng.below(reg.variants().len())].name.clone();
        let key = LutKey {
            variant: v,
            engine: EngineKind::Cpu,
            threads: *rng.choose(&[1usize, 2, 4, 8]),
            governor: *rng.choose(&Governor::ALL),
            plan: ExecPlan::Mono,
        };
        assert_eq!(m1.measure_one(&key).unwrap().latency,
                   m2.measure_one(&key).unwrap().latency, "case {case}");
    }
}

#[test]
fn prop_manager_switches_only_improve_adjusted_latency() {
    use oodin::manager::{Conditions, RuntimeManager};
    use std::sync::Arc;
    for case in 0..25 {
        let mut rng = Rng::new(7000 + case as u64);
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap();
        let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 };
        let space = SearchSpace::family("mobilenet_v2_100");
        let opt = Optimizer::new(&dev, &reg, &lut);
        let init = opt.optimize(obj, &space).unwrap().design;
        let mut mgr = RuntimeManager::new(
            Arc::new(dev.clone()), Arc::new(reg.clone()), Arc::new(lut),
            obj, space, init,
        );
        // Random load trajectory; every emitted switch must strictly improve
        // the adjusted latency at its decision point.
        let mut conds = Conditions::idle();
        let mut t = 0.0;
        for _ in 0..60 {
            t += 260.0;
            let e = *rng.choose(&EngineKind::ALL);
            conds.loads.insert(e, rng.range(0.0, 3.0));
            let before = mgr.current().clone();
            if let Some(sw) = mgr.observe(t, &conds) {
                let cur = mgr.adjusted_latency(&before, &conds).unwrap();
                let new = mgr.adjusted_latency(&sw.to, &conds).unwrap();
                assert!(new < cur, "case {case}: switch worsened latency");
            }
        }
    }
}

#[test]
fn prop_stage_input_preserves_range() {
    use oodin::dlacl::stage_input;
    let mut rng = Rng::new(11);
    for case in 0..60 {
        let h = 2 + rng.below(30);
        let w = 2 + rng.below(30);
        let res = 2 + rng.below(30);
        let frame: Vec<f32> =
            (0..h * w * 3).map(|_| rng.range(-2.0, 2.0) as f32).collect();
        let mut dst = vec![0.0f32; res * res * 3];
        stage_input(&frame, h, w, &mut dst, res);
        let (fmin, fmax) = frame.iter().fold((f32::MAX, f32::MIN),
                                             |(a, b), &x| (a.min(x), b.max(x)));
        for &d in &dst {
            assert!(d >= fmin && d <= fmax,
                    "case {case}: nearest-neighbour invented value {d}");
        }
    }
}

#[test]
fn prop_evaluate_matches_search_entry() {
    // evaluate(design) must agree with what search() reported for the same
    // design (no double-counting of conditions).
    for case in 0..20 {
        let mut rng = Rng::new(8000 + case as u64);
        let (lut, _) = random_lut(&mut rng, "samsung_a71");
        let dev = samsung_a71();
        let reg = fake_registry();
        let opt = Optimizer::new(&dev, &reg, &lut);
        let obj = Objective::MinLatency { stat: Percentile::P90, epsilon: 1.0 };
        if let Ok(all) = opt.search(obj, &SearchSpace::default()) {
            for cand in all.iter().take(5) {
                let re = opt.evaluate(&cand.design, Percentile::P90).unwrap();
                assert!((re.latency_ms - cand.latency_ms).abs() < 1e-12,
                        "case {case}");
            }
        }
    }
}

#[test]
fn prop_design_lut_key_roundtrip() {
    let mut rng = Rng::new(13);
    let reg = fake_registry();
    for _ in 0..100 {
        let v = &reg.variants()[rng.below(reg.variants().len())];
        let d = Design {
            variant: v.name.clone(),
            hw: HwConfig {
                engine: *rng.choose(&EngineKind::ALL),
                threads: 1 + rng.below(8),
                governor: *rng.choose(&Governor::ALL),
                recognition_rate: *rng.choose(&[1.0, 0.5, 0.25]),
                plan: Default::default(),
            },
        };
        let key = d.lut_key();
        let parsed = LutKey::parse(&key.id()).unwrap();
        assert_eq!(parsed, key);
    }
}
