//! Fault-injection tests for the rollout control plane's telemetry
//! ingestion (`fleet::rollout`): dropped reports hold a stage forever,
//! duplicated reports are rejected and never double-count towards the
//! gates, reports tagged with a non-live revision are discarded as
//! stale, unknown cohorts bounce, and a silent cohort blocks promotion
//! until it affirmatively reports.

use std::sync::Arc;

use oodin::designspace::scoped_fingerprint;
use oodin::device::EngineKind;
use oodin::fleet::{CohortReport, Fleet, FleetConfig, IngestOutcome,
                   PopulationConfig, RevisionRegistry, Rollout,
                   RolloutConfig, RolloutOutcome, RolloutStage,
                   BASELINE_REVISION};
use oodin::model::test_fixtures::fake_registry;
use oodin::optimizer::SearchSpace;

fn build_fleet() -> Fleet {
    let cfg = FleetConfig {
        population: PopulationConfig { size: 64, ..Default::default() },
        ..Default::default()
    };
    let fleet = Fleet::build(Arc::new(fake_registry()), cfg).unwrap();
    assert!(fleet.cohorts.len() >= 8,
            "need enough cohorts to stage over, got {}",
            fleet.cohorts.len());
    fleet
}

fn report(cohort: usize, revision: u64, seq: u64, samples: u64,
          regret_mean_pct: f64) -> CohortReport {
    CohortReport {
        cohort,
        revision,
        seq,
        samples,
        regret_pct_sum: regret_mean_pct * samples as f64,
        slo_misses: 0,
        deploy_faults: 0,
    }
}

fn fingerprints(fleet: &Fleet) -> Vec<u64> {
    let sspace = SearchSpace::family("mobilenet_v2_100");
    fleet
        .cohorts
        .iter()
        .map(|c| scoped_fingerprint(&c.lut, &fleet.registry, &sspace))
        .collect()
}

// ---------------------------------------------------------------------------
// Fault 1: dropped telemetry — a cohort whose reports never arrive
// holds the stage forever; repeated evaluation never advances and never
// mutates fleet state.
// ---------------------------------------------------------------------------

#[test]
fn dropped_reports_hold_the_stage_forever() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.9);
    let mut ro = Rollout::new(rev, RolloutConfig::default());
    ro.begin_canary(&mut fleet, &mut reg).unwrap();
    let treated = ro.treated().to_vec();
    let fps = fingerprints(&fleet);

    // Only the first treated cohort ever reports.
    for seq in 0..5u64 {
        let r = report(treated[0], rev.id, seq, 4, 1.0);
        assert_eq!(ro.ingest(r, &reg), IngestOutcome::Accepted);
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::Held { reason } => {
                assert!(reason.starts_with("missing_reports:"), "{reason}")
            }
            other => panic!("dropped telemetry must hold, got {other:?}"),
        }
        assert_eq!(ro.stage(), RolloutStage::Canary);
        assert_eq!(ro.treated(), &treated[..]);
        assert_eq!(reg.live_count(rev.id), treated.len());
        assert_eq!(fingerprints(&fleet), fps);
    }
}

// ---------------------------------------------------------------------------
// Fault 2: duplicated telemetry — a replayed (cohort, seq) report is
// rejected and its samples are never double-counted.  With exactly
// min_samples-1 distinct samples per cohort, a double-count would let
// the stage advance; the dedup keeps it held on insufficient evidence.
// ---------------------------------------------------------------------------

#[test]
fn duplicate_reports_never_double_count() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let cfg = RolloutConfig::default();
    assert!(cfg.min_samples >= 2, "test needs a thin-evidence gap");
    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.9);
    let mut ro = Rollout::new(rev, cfg.clone());
    ro.begin_canary(&mut fleet, &mut reg).unwrap();
    let treated = ro.treated().to_vec();

    // One report per treated cohort, one sample short of the minimum —
    // then replay every single one of them.
    for &ci in &treated {
        let r = report(ci, rev.id, 0, cfg.min_samples - 1, 1.0);
        assert_eq!(ro.ingest(r, &reg), IngestOutcome::Accepted);
        assert_eq!(ro.ingest(r, &reg), IngestOutcome::Duplicate);
    }
    assert_eq!(ro.duplicates(), treated.len() as u64);
    // If the replays had been counted, every cohort would now sit at
    // 2×(min_samples−1) ≥ min_samples and the canary would widen.
    match ro.evaluate(&mut fleet, &mut reg) {
        RolloutOutcome::Held { reason } => {
            assert!(reason.starts_with("insufficient_samples:"), "{reason}")
        }
        other => panic!("duplicates were double-counted: {other:?}"),
    }
    assert_eq!(ro.stage(), RolloutStage::Canary);
}

// ---------------------------------------------------------------------------
// Fault 3: stale telemetry — reports tagged with a revision that is not
// live on their cohort are discarded, whichever side they claim to be
// from, and contribute nothing to the gates.
// ---------------------------------------------------------------------------

#[test]
fn stale_revision_reports_are_rejected() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.9);
    let mut ro = Rollout::new(rev, RolloutConfig::default());
    ro.begin_canary(&mut fleet, &mut reg).unwrap();
    let treated = ro.treated().to_vec();
    let control = (0..n).find(|ci| !treated.contains(ci)).unwrap();

    // A treated cohort still reporting the baseline revision is stale…
    let r = report(treated[0], BASELINE_REVISION, 0, 4, 1.0);
    assert_eq!(ro.ingest(r, &reg), IngestOutcome::Stale);
    // …as is a control cohort claiming the canary revision.
    let r = report(control, rev.id, 0, 4, 1.0);
    assert_eq!(ro.ingest(r, &reg), IngestOutcome::Stale);
    assert_eq!(ro.stale_reports(), 2);
    // Neither leaked into the evidence: every treated cohort still reads
    // as unreported.
    match ro.evaluate(&mut fleet, &mut reg) {
        RolloutOutcome::Held { reason } => {
            assert!(reason.starts_with("missing_reports:"), "{reason}")
        }
        other => panic!("stale telemetry leaked into the gates: {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Fault 4: unknown cohort indices bounce without polluting any state —
// not even the dedup set.
// ---------------------------------------------------------------------------

#[test]
fn unknown_cohorts_bounce_cleanly() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.9);
    let mut ro = Rollout::new(rev, RolloutConfig::default());
    ro.begin_canary(&mut fleet, &mut reg).unwrap();

    let r = report(n + 100, rev.id, 0, 4, 1.0);
    assert_eq!(ro.ingest(r, &reg), IngestOutcome::UnknownCohort);
    assert_eq!(ro.duplicates(), 0);
    assert_eq!(ro.stale_reports(), 0);
    // The bounced report did not claim its (cohort, seq) slot: a valid
    // cohort reusing seq 0 is accepted, not deduplicated.
    let r = report(0, reg.live(0), 0, 4, 1.0);
    assert_eq!(ro.ingest(r, &reg), IngestOutcome::Accepted);
}

// ---------------------------------------------------------------------------
// Fault 5: a silent cohort blocks promotion at the final rung; the
// moment it affirmatively reports, the fleet promotes.
// ---------------------------------------------------------------------------

#[test]
fn silent_cohort_blocks_promotion_until_it_reports() {
    let mut fleet = build_fleet();
    let n = fleet.cohorts.len();
    let mut reg = RevisionRegistry::new(n);
    let rev = reg.register(EngineKind::Cpu, 0.9);
    let mut ro = Rollout::new(rev, RolloutConfig::default());
    ro.begin_canary(&mut fleet, &mut reg).unwrap();

    let mut seq = 0u64;
    let mut rounds = 0usize;
    loop {
        let treated = ro.treated().to_vec();
        let at_final_rung = treated.len() == n;
        let silent = *treated.last().unwrap();
        for ci in 0..n {
            if at_final_rung && ci == silent {
                continue;
            }
            let r = report(ci, reg.live(ci), seq, 4, 1.0);
            assert_eq!(ro.ingest(r, &reg), IngestOutcome::Accepted);
        }
        if at_final_rung {
            // Everyone but one cohort reported: promotion must wait.
            match ro.evaluate(&mut fleet, &mut reg) {
                RolloutOutcome::Held { reason } => {
                    assert!(reason.starts_with("missing_reports:"),
                            "{reason}")
                }
                other => {
                    panic!("silent cohort failed to block: {other:?}")
                }
            }
            assert_eq!(ro.stage(), RolloutStage::Widening(3));
            // The cohort comes back online; the fleet promotes.
            let r = report(silent, reg.live(silent), seq, 4, 1.0);
            assert_eq!(ro.ingest(r, &reg), IngestOutcome::Accepted);
            match ro.evaluate(&mut fleet, &mut reg) {
                RolloutOutcome::Promoted => break,
                other => panic!("expected promotion, got {other:?}"),
            }
        }
        match ro.evaluate(&mut fleet, &mut reg) {
            RolloutOutcome::Advanced { .. } => {}
            other => panic!("expected advance, got {other:?}"),
        }
        seq += 1;
        rounds += 1;
        assert!(rounds <= n, "rollout failed to terminate");
    }
    assert_eq!(ro.stage(), RolloutStage::Promoted);
    assert_eq!(reg.live_count(rev.id), n);
}
