//! Property suite for the decision flight recorder: trace determinism
//! (same seed ⇒ byte-identical bytes), ring boundedness under the full
//! smoke storm, and the decide()/trace correspondence — every manager
//! decision leaves exactly one `hold` or `switch` event, and every
//! switch carries its `explain` record.

use std::sync::Arc;

use oodin::experiments::fleetbench::{self, FleetBenchConfig,
                                     FleetBenchReport};
use oodin::model::test_fixtures::fake_registry;
use oodin::telemetry::trace::{FlightRecorder, TraceEvent};

fn traced_smoke(rec: &Arc<FlightRecorder>) -> FleetBenchReport {
    let reg = fake_registry();
    let cfg = FleetBenchConfig::smoke();
    fleetbench::run_traced(&reg, &cfg, Some(rec)).unwrap()
}

#[test]
fn same_seed_yields_byte_identical_trace() {
    let a = Arc::new(FlightRecorder::new());
    let b = Arc::new(FlightRecorder::new());
    traced_smoke(&a);
    traced_smoke(&b);
    assert_eq!(a.dropped(), 0, "smoke trace must fit the default ring");
    assert!(!a.is_empty());
    assert_eq!(a.to_jsonl(), b.to_jsonl(),
               "virtual-clock traces must be reproducible byte-for-byte");
    assert_eq!(a.to_chrome_trace(), b.to_chrome_trace());
}

#[test]
fn ring_stays_bounded_under_storm() {
    let rec = Arc::new(FlightRecorder::with_capacity(64));
    traced_smoke(&rec);
    assert!(rec.emitted() > 64, "storm must overflow the tiny ring");
    assert_eq!(rec.capacity(), 64);
    assert_eq!(rec.len(), 64, "ring must never exceed its capacity");
    assert_eq!(rec.dropped(), rec.emitted() - 64);
    assert_eq!(rec.to_jsonl().lines().count(), 64);
    // The survivors are the newest events, with sequence numbers that
    // still count every emission (drops included).
    let records = rec.records();
    assert_eq!(records.last().unwrap().seq, rec.emitted() - 1);
    assert_eq!(records.first().unwrap().seq, rec.emitted() - 64);
    for w in records.windows(2) {
        assert_eq!(w[1].seq, w[0].seq + 1, "seq must stay contiguous");
        assert!(w[1].t_us >= w[0].t_us, "virtual time must be monotone");
    }
}

#[test]
fn every_decide_emits_exactly_one_adaptation_event() {
    let rec = Arc::new(FlightRecorder::new());
    let report = traced_smoke(&rec);
    let records = rec.records();
    let holds = records
        .iter()
        .filter(|r| matches!(r.event, TraceEvent::Hold { .. }))
        .count() as u64;
    let switches = records
        .iter()
        .filter(|r| matches!(r.event, TraceEvent::Switch { .. }))
        .count() as u64;
    let explains = records
        .iter()
        .filter(|r| matches!(r.event, TraceEvent::Explain { .. }))
        .count() as u64;
    assert_eq!(holds + switches, report.decisions,
               "each decide() must leave exactly one hold-or-switch event");
    assert_eq!(switches, report.switches);
    assert_eq!(explains, switches,
               "every switch must carry its explain record");
    // Recording never perturbs the run: the traced report matches an
    // untraced one bit-for-bit through the JSON emission.
    let reg = fake_registry();
    let cfg = FleetBenchConfig::smoke();
    let untraced = fleetbench::run(&reg, &cfg).unwrap();
    assert_eq!(
        oodin::util::json::to_string(&fleetbench::report_json(&report)),
        oodin::util::json::to_string(&fleetbench::report_json(&untraced)),
    );
}
