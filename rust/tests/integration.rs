//! Integration tests over the REAL AOT artifacts: runtime + DLACL + app +
//! experiments composing end-to-end.  Skipped (with a message) when
//! `make artifacts` has not been run.

use oodin::app::{AppConfig, Application};
use oodin::device::EngineKind;
use oodin::dlacl::{decode_top1, ModelSlot};
use oodin::model::{Precision, Registry, Task};
use oodin::optimizer::{Objective, SearchSpace};
use oodin::runtime::RuntimeHandle;
use oodin::sil::SyntheticCamera;
use oodin::util::stats::Percentile;

fn real_registry() -> Option<Registry> {
    match oodin::load_registry() {
        Ok(r) => Some(r),
        Err(_) => {
            eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn every_artifact_loads_and_executes() {
    let Some(reg) = real_registry() else { return };
    let rt = RuntimeHandle::cpu().unwrap();
    for v in reg.variants() {
        rt.load(&v.name, reg.hlo_path(v))
            .unwrap_or_else(|e| panic!("loading {}: {e}", v.name));
        let input = vec![0.1f32; v.input_elems()];
        let out = rt.execute(&v.name, input, &v.input_shape)
            .unwrap_or_else(|e| panic!("executing {}: {e}", v.name));
        assert_eq!(out.values.len(), v.output_elems(), "{}", v.name);
        assert!(out.values.iter().all(|x| x.is_finite()),
                "{} produced non-finite output", v.name);
        rt.evict(&v.name).unwrap();
    }
    rt.shutdown();
}

#[test]
fn precisions_agree_on_predictions() {
    // The three transformations of one family must mostly agree on real
    // frames (the accuracy gap in the manifest is small).
    let Some(reg) = real_registry() else { return };
    let rt = RuntimeHandle::cpu().unwrap();
    for family in ["mobilenet_v2_100", "efficientnet_lite0"] {
        let variants: Vec<_> = Precision::ALL
            .iter()
            .filter_map(|&p| reg.find(family, p, 1))
            .collect();
        assert_eq!(variants.len(), 3, "{family} missing precisions");
        for v in &variants {
            rt.load(&v.name, reg.hlo_path(v)).unwrap();
        }
        let mut cam = SyntheticCamera::new(variants[0].resolution, 30.0, 17);
        let mut agree = 0;
        let n = 12;
        for i in 0..n {
            let f = cam.capture(i as f64);
            let preds: Vec<usize> = variants
                .iter()
                .map(|v| {
                    let out = rt
                        .execute(&v.name, f.data.clone(), &v.input_shape)
                        .unwrap();
                    decode_top1(&out.values, 10).0
                })
                .collect();
            if preds.iter().all(|&p| p == preds[0]) {
                agree += 1;
            }
        }
        assert!(agree * 10 >= n * 7,
                "{family}: precisions agree on only {agree}/{n} frames");
        for v in &variants {
            rt.evict(&v.name).unwrap();
        }
    }
    rt.shutdown();
}

#[test]
fn online_accuracy_matches_offline_manifest() {
    // Camera frames come from the same generator family as the python
    // validation set: online top-1 through the full stack should be within
    // a loose band of the manifest accuracy.
    let Some(reg) = real_registry() else { return };
    let rt = RuntimeHandle::cpu().unwrap();
    let v = reg.find("mobilenet_v2_140", Precision::Fp32, 1).unwrap();
    rt.load(&v.name, reg.hlo_path(v)).unwrap();
    let mut cam = SyntheticCamera::new(v.resolution, 30.0, 23);
    let n = 150;
    let mut ok = 0;
    for i in 0..n {
        let f = cam.capture(i as f64);
        let out = rt.execute(&v.name, f.data, &v.input_shape).unwrap();
        if decode_top1(&out.values, 10).0 == f.label {
            ok += 1;
        }
    }
    let online = ok as f64 / n as f64;
    assert!((online - v.accuracy).abs() < 0.15,
            "online {online:.3} vs manifest {:.3}", v.accuracy);
    rt.shutdown();
}

#[test]
fn dlacl_swap_cycles_through_variants() {
    let Some(reg) = real_registry() else { return };
    let rt = RuntimeHandle::cpu().unwrap();
    let mut slot = ModelSlot::new(rt.clone(), u64::MAX);
    let names: Vec<String> = Precision::ALL
        .iter()
        .map(|&p| reg.find("mobilenet_v2_100", p, 1).unwrap().name.clone())
        .collect();
    let res = reg.get(&names[0]).unwrap().resolution;
    let frame = vec![0.2f32; res * res * 3];
    for round in 0..2 {
        for name in &names {
            slot.swap_to(&reg, name).unwrap();
            let out = slot.infer(&frame, res, res).unwrap();
            assert!(out.values.iter().all(|x| x.is_finite()), "round {round}");
            // Exactly one executable resident at a time.
            assert_eq!(rt.loaded().unwrap().len(), 1);
        }
    }
    assert_eq!(slot.swaps, 6);
    rt.shutdown();
}

#[test]
fn full_app_runs_real_exec_with_adaptation() {
    let Some(reg) = real_registry() else { return };
    let mut cfg = AppConfig::new(
        "samsung_a71",
        Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.015 },
        SearchSpace::family("mobilenet_v2_100"),
    );
    cfg.real_exec = true;
    cfg.lut_runs = 30;
    let mut app = Application::build(cfg, reg).unwrap();
    let e0 = app.current_design().hw.engine;
    let recs = app
        .run(120, &[oodin::app::ScenarioEvent::SetLoad {
            at_frame: 30,
            engine: e0,
            load: 3.0,
        }])
        .unwrap();
    assert_eq!(recs.len() as u64, 120 / (1.0 / app.current_design().hw.recognition_rate) as u64);
    assert!(recs.iter().any(|r| r.switch.is_some()),
            "no adaptation under 8x load");
    assert!(recs.iter().all(|r| r.host_ms.is_some()), "real exec missing");
    let acc = recs.iter().filter_map(|r| r.correct).filter(|&c| c).count() as f64
        / recs.iter().filter(|r| r.correct.is_some()).count() as f64;
    assert!(acc > 0.5, "online accuracy collapsed: {acc}");
    assert!(app.gallery.len() > 0);
    app.shutdown();
}

#[test]
fn segmentation_task_end_to_end() {
    let Some(reg) = real_registry() else { return };
    let rt = RuntimeHandle::cpu().unwrap();
    let v = reg.find("deeplab_v3", Precision::Int8, 1).unwrap();
    assert_eq!(v.task, Task::Segmentation);
    rt.load(&v.name, reg.hlo_path(v)).unwrap();
    let input = vec![0.3f32; v.input_elems()];
    let out = rt.execute(&v.name, input, &v.input_shape).unwrap();
    assert_eq!(out.values.len(),
               v.resolution * v.resolution * 5, "per-pixel logits");
    rt.shutdown();
}

#[test]
fn experiments_compose_on_real_registry() {
    let Some(reg) = real_registry() else { return };
    // Fig 3 invariant on real data: OODIn >= every baseline.
    let (rows, summaries) = oodin::experiments::fig3::run(&reg).unwrap();
    assert!(rows.len() >= 15, "rows: {}", rows.len());
    for r in &rows {
        for b in [r.osq_cpu_ms, r.osq_gpu_ms, r.osq_nnapi_ms].into_iter().flatten() {
            assert!(r.oodin_ms <= b + 1e-9, "{r:?}");
        }
    }
    // Geo-mean speedups in a plausible band (paper: 1.73 / 1.74 / 5.9).
    for s in &summaries {
        assert!(s.vs_cpu.0 >= 1.0 && s.vs_cpu.0 < 50.0);
        if let Some((geo, max)) = s.vs_nnapi {
            assert!(geo >= 1.0);
            assert!(max < 1000.0);
        }
    }
    // NNAPI tail (S20 + deeplab) is catastrophic, as in the paper.
    let s20_deeplab = rows.iter()
        .find(|r| r.device == "samsung_s20_fe" && r.family == "deeplab_v3");
    if let Some(r) = s20_deeplab {
        if let Some(sp) = r.speedup(r.osq_nnapi_ms) {
            assert!(sp > 10.0, "expected catastrophic NNAPI tail, got {sp}");
        }
    }
}

#[test]
fn engine_choice_varies_on_real_zoo() {
    let Some(reg) = real_registry() else { return };
    let m = oodin::experiments::fig3::engine_matrix(&reg).unwrap();
    let engines: std::collections::BTreeSet<EngineKind> =
        m.iter().map(|(_, _, e)| *e).collect();
    assert!(engines.len() >= 2,
            "best engine should vary across (model, device): {m:?}");
}
