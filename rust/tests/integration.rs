//! End-to-end integration tests: backend + DLACL + app + experiments
//! composing through the full stack.  These tests NEVER skip: when
//! `make artifacts` has been run (and the `pjrt` feature is enabled) they
//! exercise the real AOT artifacts; otherwise the same assertions run
//! hermetically against `SimBackend` + the synthetic fixture registry —
//! no Python, no XLA, no artifacts directory.

use std::sync::Arc;

use oodin::app::{AppConfig, Application};
use oodin::device::profiles::samsung_a71;
use oodin::device::EngineKind;
use oodin::dlacl::{decode_top1, ModelSlot};
use oodin::model::{Precision, Registry, Task};
use oodin::optimizer::{Objective, SearchSpace};
use oodin::runtime::{default_backend, Backend};
use oodin::sil::SyntheticCamera;
use oodin::util::stats::Percentile;

/// Real registry when artifacts exist, the synthetic fixture otherwise.
fn test_registry() -> Registry {
    oodin::load_registry_or_synthetic().unwrap()
}

fn backend_for(reg: &Registry) -> Arc<dyn Backend> {
    default_backend(&samsung_a71(), reg).unwrap()
}

/// First classification family carrying all three precision
/// transformations — present in both the real zoo and the fixture.
fn cls_family(reg: &Registry) -> String {
    for f in reg.families() {
        let full = Precision::ALL.iter().all(|&p| {
            reg.find(f, p, 1).map_or(false, |v| v.task == Task::Classification)
        });
        if full {
            return f.to_string();
        }
    }
    panic!("no classification family with all precisions");
}

#[test]
fn every_variant_loads_and_executes() {
    let reg = test_registry();
    let rt = backend_for(&reg);
    for v in reg.variants() {
        rt.load(&v.name, &reg.hlo_path(v))
            .unwrap_or_else(|e| panic!("loading {}: {e}", v.name));
        let input = vec![0.1f32; v.input_elems()];
        let out = rt.execute(&v.name, input, &v.input_shape)
            .unwrap_or_else(|e| panic!("executing {}: {e}", v.name));
        assert_eq!(out.values.len(), v.output_elems(), "{}", v.name);
        assert!(out.values.iter().all(|x| x.is_finite()),
                "{} produced non-finite output", v.name);
        rt.evict(&v.name).unwrap();
    }
    rt.shutdown();
}

#[test]
fn precisions_agree_on_predictions() {
    // The three transformations of one family must mostly agree on frames
    // (the accuracy gap between them is small on both backends).
    let reg = test_registry();
    let rt = backend_for(&reg);
    let family = cls_family(&reg);
    let variants: Vec<_> = Precision::ALL
        .iter()
        .filter_map(|&p| reg.find(&family, p, 1))
        .collect();
    assert_eq!(variants.len(), 3, "{family} missing precisions");
    for v in &variants {
        rt.load(&v.name, &reg.hlo_path(v)).unwrap();
    }
    let mut cam = SyntheticCamera::new(variants[0].resolution, 30.0, 17);
    let mut agree = 0;
    let n = 12;
    for i in 0..n {
        let f = cam.capture(i as f64);
        let preds: Vec<usize> = variants
            .iter()
            .map(|v| {
                let out = rt
                    .execute(&v.name, f.data.clone(), &v.input_shape)
                    .unwrap();
                decode_top1(&out.values, 10).0
            })
            .collect();
        if preds.iter().all(|&p| p == preds[0]) {
            agree += 1;
        }
    }
    assert!(agree * 10 >= n * 7,
            "{family}: precisions agree on only {agree}/{n} frames");
    for v in &variants {
        rt.evict(&v.name).unwrap();
    }
    rt.shutdown();
}

#[test]
fn online_accuracy_matches_offline_manifest() {
    // Camera frames come from the same generator family as the validation
    // set: online top-1 through the backend should sit within a loose band
    // of the manifest accuracy on both execution paths.
    let reg = test_registry();
    let rt = backend_for(&reg);
    let family = cls_family(&reg);
    let v = reg.find(&family, Precision::Fp32, 1).unwrap();
    rt.load(&v.name, &reg.hlo_path(v)).unwrap();
    let mut cam = SyntheticCamera::new(v.resolution, 30.0, 23);
    let n = 150;
    let mut ok = 0;
    for i in 0..n {
        let f = cam.capture(i as f64);
        let out = rt.execute(&v.name, f.data, &v.input_shape).unwrap();
        if decode_top1(&out.values, 10).0 == f.label {
            ok += 1;
        }
    }
    let online = ok as f64 / n as f64;
    assert!((online - v.accuracy).abs() < 0.15,
            "online {online:.3} vs manifest {:.3}", v.accuracy);
    rt.shutdown();
}

#[test]
fn dlacl_swap_cycles_through_variants() {
    let reg = test_registry();
    let rt = backend_for(&reg);
    let mut slot = ModelSlot::new(Arc::clone(&rt), u64::MAX);
    let family = cls_family(&reg);
    let names: Vec<String> = Precision::ALL
        .iter()
        .map(|&p| reg.find(&family, p, 1).unwrap().name.clone())
        .collect();
    let res = reg.get(&names[0]).unwrap().resolution;
    let frame = vec![0.2f32; res * res * 3];
    for round in 0..2 {
        for name in &names {
            slot.swap_to(&reg, name).unwrap();
            let out = slot.infer(&frame, res, res).unwrap();
            assert!(out.values.iter().all(|x| x.is_finite()), "round {round}");
            // Exactly one executable resident at a time.
            assert_eq!(rt.loaded().unwrap().len(), 1);
        }
    }
    assert_eq!(slot.swaps, 6);
    rt.shutdown();
}

#[test]
fn full_app_runs_backend_numerics_with_adaptation() {
    let reg = test_registry();
    let family = cls_family(&reg);
    let mut cfg = AppConfig::new(
        "samsung_a71",
        Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.015 },
        SearchSpace::family(&family),
    );
    cfg.real_exec = true;
    cfg.lut_runs = 30;
    let mut app = Application::build(cfg, reg).unwrap();
    let e0 = app.current_design().hw.engine;
    let recs = app
        .run(120, &[oodin::app::ScenarioEvent::SetLoad {
            at_frame: 30,
            engine: e0,
            load: 3.0,
        }])
        .unwrap();
    assert_eq!(recs.len() as u64,
               120 / (1.0 / app.current_design().hw.recognition_rate) as u64);
    assert!(recs.iter().any(|r| r.switch.is_some()),
            "no adaptation under 8x load");
    assert!(recs.iter().all(|r| r.host_ms.is_some()), "backend numerics missing");
    let acc = recs.iter().filter_map(|r| r.correct).filter(|&c| c).count() as f64
        / recs.iter().filter(|r| r.correct.is_some()).count() as f64;
    assert!(acc > 0.5, "online accuracy collapsed: {acc}");
    assert!(app.gallery.len() > 0);
    app.shutdown();
}

#[test]
fn segmentation_task_end_to_end() {
    let reg = test_registry();
    let rt = backend_for(&reg);
    let v = reg.find("deeplab_v3", Precision::Int8, 1).unwrap();
    assert_eq!(v.task, Task::Segmentation);
    rt.load(&v.name, &reg.hlo_path(v)).unwrap();
    let input = vec![0.3f32; v.input_elems()];
    let out = rt.execute(&v.name, input, &v.input_shape).unwrap();
    assert_eq!(out.values.len(),
               v.resolution * v.resolution * 5, "per-pixel logits");
    rt.shutdown();
}

#[test]
fn experiments_compose_on_registry() {
    let reg = test_registry();
    // Fig 3 invariant: OODIn >= every baseline.
    let (rows, summaries) = oodin::experiments::fig3::run(&reg).unwrap();
    assert!(rows.len() >= 8, "rows: {}", rows.len());
    for r in &rows {
        for b in [r.osq_cpu_ms, r.osq_gpu_ms, r.osq_nnapi_ms].into_iter().flatten() {
            assert!(r.oodin_ms <= b + 1e-9, "{r:?}");
        }
    }
    // Geo-mean speedups in a plausible band (paper: 1.73 / 1.74 / 5.9).
    for s in &summaries {
        assert!(s.vs_cpu.0 >= 1.0 && s.vs_cpu.0 < 50.0);
        if let Some((geo, max)) = s.vs_nnapi {
            assert!(geo >= 1.0);
            assert!(max < 1000.0);
        }
    }
    // NNAPI tail (S20 + deeplab) is catastrophic, as in the paper.
    let s20_deeplab = rows.iter()
        .find(|r| r.device == "samsung_s20_fe" && r.family == "deeplab_v3");
    if let Some(r) = s20_deeplab {
        if let Some(sp) = r.speedup(r.osq_nnapi_ms) {
            assert!(sp > 10.0, "expected catastrophic NNAPI tail, got {sp}");
        }
    }
}

#[test]
fn engine_choice_varies_across_zoo() {
    let reg = test_registry();
    let m = oodin::experiments::fig3::engine_matrix(&reg).unwrap();
    let engines: std::collections::BTreeSet<EngineKind> =
        m.iter().map(|(_, _, e)| *e).collect();
    assert!(engines.len() >= 2,
            "best engine should vary across (model, device): {m:?}");
}
