//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Search strategy** — OODIn's complete enumerative LUT search vs a
//!    random configuration pick vs a greedy engine-first heuristic:
//!    solution quality (latency of the chosen design) and search time.
//! 2. **Adaptation hysteresis** — sweep the Runtime Manager's
//!    `min_improvement` threshold and report switch counts + average
//!    latency under the Fig 7 load ramp (too low = flapping, too high =
//!    stuck on a degraded engine).
//! 3. **Recognition rate r** — effective fps/latency trade-off per r.


use oodin::app::{AppConfig, Application};
use oodin::device::profiles::samsung_a71;
use oodin::experiments::{build_lut, EVAL_EPSILON};
use oodin::manager::Policy;
use oodin::measurements::LutKey;
use oodin::model::Registry;
use oodin::optimizer::{Objective, Optimizer, SearchSpace};
use oodin::util::bench::{bench, black_box};
use oodin::util::rng::Rng;
use oodin::util::stats::Percentile;

const OBJ: Objective = Objective::MinLatency {
    stat: Percentile::Avg,
    epsilon: EVAL_EPSILON,
};

fn main() {
    let registry = oodin::load_registry_or_synthetic().unwrap();
    search_quality(&registry);
    hysteresis_sweep(&registry);
    recognition_rate_sweep(&registry);
}

fn search_quality(registry: &Registry) {
    println!("== ablation 1: search strategy (samsung_a71, all families) ==");
    let device = samsung_a71();
    let lut = build_lut(&device, registry).unwrap();
    let opt = Optimizer::new(&device, registry, &lut);

    println!("{:<22} {:>14} {:>12}", "strategy", "geo latency", "vs OODIn");
    let mut oodin_geo = 1.0f64;
    for strategy in ["oodin-enumerative", "greedy-engine-first", "random-pick"] {
        let mut lats = Vec::new();
        for family in registry.families() {
            let lat = match strategy {
                "oodin-enumerative" => opt
                    .optimize(OBJ, &SearchSpace::family(family))
                    .ok()
                    .map(|e| e.latency_ms),
                "greedy-engine-first" => greedy(&opt, registry, family),
                _ => random_pick(&opt, registry, &lut.entries, family),
            };
            if let Some(l) = lat {
                lats.push(l);
            }
        }
        let geo = oodin::util::stats::geomean(&lats);
        if strategy == "oodin-enumerative" {
            oodin_geo = geo;
        }
        println!("{:<22} {:>11.4} ms {:>11.2}x", strategy, geo, geo / oodin_geo);
    }

    bench("search/oodin_enumerative", 5, 100, || {
        black_box(opt.optimize(OBJ, &SearchSpace::family("inception_v3")).unwrap());
    });
}

/// Greedy: pick the engine with the best single default config, then tune
/// threads/governor only on that engine (what a hand-tuned port does).
fn greedy(opt: &Optimizer, registry: &Registry, family: &str) -> Option<f64> {
    use oodin::device::EngineKind;
    let mut best_engine = None;
    for e in EngineKind::ALL {
        let space = SearchSpace::family(family)
            .with_engines(&[e])
            .with_precisions(&[oodin::model::Precision::Fp32]);
        if let Ok(r) = opt.optimize(OBJ, &space) {
            if best_engine
                .as_ref()
                .map_or(true, |(_, l)| r.latency_ms < *l)
            {
                best_engine = Some((e, r.latency_ms));
            }
        }
    }
    let (engine, _) = best_engine?;
    let _ = registry;
    opt.optimize(OBJ, &SearchSpace::family(family).with_engines(&[engine]))
        .ok()
        .map(|e| e.latency_ms)
}

/// Random feasible configuration (averaged over 20 draws).
fn random_pick(opt: &Optimizer, registry: &Registry,
               entries: &std::collections::BTreeMap<LutKey, oodin::measurements::LutEntry>,
               family: &str) -> Option<f64> {
    let keys: Vec<&LutKey> = entries
        .keys()
        .filter(|k| registry.get(&k.variant).map_or(false, |v| v.family == family))
        .collect();
    if keys.is_empty() {
        return None;
    }
    let mut rng = Rng::new(7);
    let mut acc = Vec::new();
    for _ in 0..20 {
        let k = keys[rng.below(keys.len())];
        let d = oodin::optimizer::Design {
            variant: k.variant.clone(),
            hw: oodin::optimizer::HwConfig {
                engine: k.engine,
                threads: k.threads,
                governor: k.governor,
                recognition_rate: 1.0,
                plan: k.plan.clone(),
            },
        };
        if let Ok(e) = opt.evaluate(&d, Percentile::Avg) {
            acc.push(e.latency_ms);
        }
    }
    Some(acc.iter().sum::<f64>() / acc.len() as f64)
}

fn hysteresis_sweep(registry: &Registry) {
    println!("\n== ablation 2: adaptation hysteresis (Fig 7 conditions) ==");
    println!("{:>12} {:>10} {:>14}", "threshold", "switches", "avg latency");
    let family = registry.family_or("mobilenet_v2_140", "mobilenet_v2_100");
    for min_improvement in [1.0, 1.05, 1.10, 1.25, 1.5, 2.0, 4.0] {
        let mut cfg = AppConfig::new(
            "samsung_a71",
            Objective::MinLatency { stat: Percentile::P90, epsilon: 0.0 },
            SearchSpace::family(family),
        );
        cfg.real_exec = false;
        cfg.lut_runs = 40;
        cfg.policy = Policy {
            min_improvement,
            check_interval_ms: 100.0,
            cooldown_ms: 200.0,
            ..Policy::default()
        };
        let Ok(mut app) = Application::build(cfg, registry.clone()) else {
            continue;
        };
        let e0 = app.current_design().hw.engine;
        let mut recs = Vec::new();
        for load in [0.0, 1.0, 2.0] {
            app.sim.set_load(e0, load);
            recs.extend(app.run(60, &[]).unwrap());
        }
        let switches = recs.iter().filter(|r| r.switch.is_some()).count();
        let avg = recs.iter().map(|r| r.latency_ms).sum::<f64>() / recs.len() as f64;
        println!("{:>12.2} {:>10} {:>11.4} ms", min_improvement, switches, avg);
    }
}

fn recognition_rate_sweep(registry: &Registry) {
    println!("\n== ablation 3: recognition rate r (Eq. system params) ==");
    let device = samsung_a71();
    let lut = build_lut(&device, registry).unwrap();
    let opt = Optimizer::new(&device, registry, &lut)
        .with_camera_fps(30.0);
    println!("{:>6} {:>10} {:>14}", "r", "eff fps", "per-frame ms");
    for r in [1.0, 0.5, 0.25] {
        let mut space = SearchSpace::family("inception_v3");
        space.recognition_rate = Some(r);
        if let Ok(best) = opt.optimize(Objective::MaxFps { epsilon: EVAL_EPSILON },
                                       &space) {
            println!("{:>6.2} {:>10.2} {:>11.4} ms", r, best.fps, best.avg_latency_ms);
        }
    }
}
