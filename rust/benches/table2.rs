//! Bench: regenerate Table II and time the registry/report machinery.

use oodin::experiments::tables;
use oodin::util::bench::{bench, black_box};

fn main() {
    let registry = oodin::load_registry_or_synthetic().unwrap();
    println!("== TABLE II reproduction ==");
    tables::print_table1();
    println!();
    tables::print_table2(&registry);

    println!("\n== harness timings ==");
    bench("registry/load_manifest", 3, 30, || {
        black_box(oodin::load_registry_or_synthetic().unwrap());
    });
    bench("table2/regenerate", 3, 100, || {
        black_box(tables::table2(&registry));
    });
}
