//! Hot-path benchmark: execution through the configured backend — the
//! anchor for the §Perf optimisation pass (EXPERIMENTS.md).
//!
//! Runs against the real PJRT artifacts when `make artifacts` + the `pjrt`
//! feature are available, and against the hermetic SimBackend otherwise
//! (useful for measuring the coordinator/batching overhead in isolation).
//!
//! Measures per-variant latency, batch-amortisation on the batched
//! executables when the zoo has them, backend round-trip overhead, and the
//! serving front-end's end-to-end throughput.

use std::sync::Arc;

use oodin::designspace::{ConditionsBucket, DesignSpace, LutDelta,
                         ParetoFrontier};
use oodin::device::profiles::samsung_a71;
use oodin::device::EngineKind;
use oodin::manager::Conditions;
use oodin::measurements::Measurer;
use oodin::model::Precision;
use oodin::optimizer::{Objective, SearchSpace};
use oodin::runtime::{default_backend, Backend};
use oodin::serving::{Server, ServerConfig};
use oodin::util::bench::{bench, black_box};
use oodin::util::stats::Percentile;

fn main() {
    let registry = oodin::load_registry_or_synthetic().unwrap();
    let rt = default_backend(&samsung_a71(), &registry).unwrap();
    println!("backend: {}", rt.kind());

    // Backend round-trip floor: the cheapest variant in the zoo.
    let smallest = registry
        .variants()
        .iter()
        .filter(|v| v.batch == 1)
        .min_by_key(|v| v.flops)
        .expect("empty registry")
        .clone();
    rt.load(&smallest.name, &registry.hlo_path(&smallest)).unwrap();
    let tiny_input = vec![0.1f32; smallest.input_elems()];
    bench("runtime/roundtrip_floor", 20, 200, || {
        black_box(
            rt.execute(&smallest.name, tiny_input.clone(), &smallest.input_shape)
                .unwrap(),
        );
    });
    rt.evict(&smallest.name).unwrap();

    // Per-variant latency (batch-1, all families, fp32+int8).
    println!("\n== per-variant latency through the backend ==");
    for v in registry.variants() {
        if v.batch != 1 || v.precision == Precision::Fp16 {
            continue;
        }
        if rt.load(&v.name, &registry.hlo_path(v)).is_err() {
            println!("{:<40} load failed", v.name);
            continue;
        }
        let input = vec![0.1f32; v.input_elems()];
        let shape = v.input_shape.clone();
        let name = v.name.clone();
        bench(&format!("exec/{name}"), 5, 60, || {
            black_box(rt.execute(&name, input.clone(), &shape).unwrap());
        });
        rt.evict(&name).unwrap();
    }

    // Batch amortisation on the flagship model (real zoo only — the
    // synthetic registry carries batch-1 variants).
    println!("\n== batching (mobilenet_v2_100 fp32) ==");
    for b in [1usize, 4, 8] {
        let Some(v) = registry.find("mobilenet_v2_100", Precision::Fp32, b) else {
            println!("  (no b={b} variant in this registry)");
            continue;
        };
        rt.load(&v.name, &registry.hlo_path(v)).unwrap();
        let input = vec![0.1f32; v.input_elems()];
        let shape = v.input_shape.clone();
        let name = v.name.clone();
        let r = bench(&format!("exec/batch{b}"), 5, 60, || {
            black_box(rt.execute(&name, input.clone(), &shape).unwrap());
        });
        println!("{:<44} {:>10.4} ms/sample", format!("  -> per-sample (b={b})"),
                 r.stats.avg / b as f64);
    }

    // Serving front-end throughput.
    println!("\n== serving front-end (dynamic batcher) ==");
    for delay_ms in [0.0, 2.0] {
        let mut cfg =
            ServerConfig::for_family(&registry, "mobilenet_v2_100", Precision::Fp32)
                .unwrap();
        cfg.max_batch_delay_ms = delay_ms;
        let srv = Server::start(Arc::clone(&rt), &registry, cfg).unwrap();
        let res = registry
            .find("mobilenet_v2_100", Precision::Fp32, 1)
            .unwrap()
            .resolution;
        let frame = vec![0.1f32; res * res * 3];
        let n = 256;
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|_| srv.submit(frame.clone(), res, res).unwrap())
            .collect();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv().unwrap().is_ok() {
                ok += 1;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "serve/delay={delay_ms}ms: {ok}/{n} ok, {:>8.1} req/s  (batches: {})",
            n as f64 / secs,
            oodin::util::json::to_string(
                &srv.telemetry.snapshot().get("counters").unwrap().clone()
            ),
        );
        srv.stop();
    }

    // Decision hot path: full frontier rebuild vs the incremental delta
    // path across a per-engine LUT correction (the fleet probe-fallback
    // shape).  `opt-bench` / `fleet-bench` golden-pin the same comparison
    // under the simulated cost model; this is the wall-clock view.
    println!("\n== frontier maintenance: full rebuild vs incremental delta ==");
    let device = samsung_a71();
    let lut = Measurer::new(&device, &registry).measure_all().unwrap();
    let objective =
        Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 };
    let sspace = SearchSpace::family("mobilenet_v2_100");
    let bucket = ConditionsBucket::of(&Conditions::idle());
    let old_space = DesignSpace::new(&device, &registry, &lut);
    let frontier =
        ParetoFrontier::build(&old_space, objective, &sspace, &bucket);
    let new_lut = lut.scaled_engine(EngineKind::Gpu, 1.25);
    let new_space = DesignSpace::new(&device, &registry, &new_lut);
    let delta = LutDelta::engine_scale(EngineKind::Gpu, 1.25);
    let (carried, touched) = frontier.apply_delta(&old_space, &new_space,
                                                  objective, &sspace, &delta);
    let rebuilt = ParetoFrontier::build(&new_space, objective, &sspace,
                                        &bucket);
    assert_eq!(carried.best().map(|c| &c.design),
               rebuilt.best().map(|c| &c.design),
               "delta path must stay exact");
    let full = bench("frontier/full_rebuild", 20, 400, || {
        black_box(ParetoFrontier::build(&new_space, objective, &sspace,
                                        &bucket));
    });
    let inc = bench("frontier/apply_delta", 20, 400, || {
        black_box(frontier.apply_delta(&old_space, &new_space, objective,
                                       &sspace, &delta));
    });
    let walk = bench("frontier/walk_decision", 20, 400, || {
        black_box(carried.best());
    });
    let ratio = full.stats.avg / inc.stats.avg.max(1e-9);
    println!(
        "frontier/delta: {touched} points touched vs {} rebuild candidates \
         ({} frontier points); delta {:.0}/s vs rebuild {:.0}/s \
         ({:.1}x cheaper); decisions {:.0}/s on the warm frontier",
        rebuilt.space_size,
        carried.len(),
        1e3 / inc.stats.avg.max(1e-9),
        1e3 / full.stats.avg.max(1e-9),
        ratio,
        1e3 / walk.stats.avg.max(1e-9),
    );
    // Wall-clock regression gate: apply_delta must beat a full rebuild by a
    // comfortable margin.  The work-count gap (points_touched vs space_size)
    // is typically >10x, so a 2x wall-clock floor leaves generous headroom
    // for shared-runner timing noise while still catching an accidental
    // rebuild-in-disguise.  Skipped when the rebuild itself is too fast to
    // time reliably (sub-50µs averages are mostly harness overhead).
    const MIN_DELTA_SPEEDUP: f64 = 2.0;
    if full.stats.avg > 0.05 {
        assert!(
            ratio >= MIN_DELTA_SPEEDUP,
            "frontier/apply_delta regressed: only {ratio:.2}x faster than \
             full_rebuild (floor {MIN_DELTA_SPEEDUP}x; rebuild avg \
             {:.4} ms, delta avg {:.4} ms)",
            full.stats.avg, inc.stats.avg,
        );
        println!("frontier/delta wall-clock gate: {ratio:.1}x >= \
                  {MIN_DELTA_SPEEDUP}x floor — ok");
    } else {
        println!("frontier/delta wall-clock gate: rebuild avg {:.4} ms too \
                  small to time reliably — gate skipped", full.stats.avg);
    }

    // Co-execution decision scenario: the partitioned σ-space widens
    // enumeration (every admitted 2–3-stage plan is an extra candidate per
    // batch-1 variant), so the decision hot path must stay the same order
    // of work.  At the default partition grid ({250,500,750} per-mille
    // cuts, ≤3 stages) the widened space is bounded by 3x the monolithic
    // candidate count — gate it so a grid change can't silently blow up
    // every frontier build in the fleet.
    println!("\n== co-execution: partitioned vs monolithic decision ==");
    let wide_lut = Measurer::new(&device, &registry)
        .measure_with_partitions()
        .unwrap();
    let wide_space = DesignSpace::new(&device, &registry, &wide_lut);
    let all = SearchSpace::default();
    let idle = Conditions::idle();
    let n_full = wide_space.enumerate(objective, &all, &idle).len();
    let n_mono = old_space.enumerate(objective, &all, &idle).len();
    println!("coexec/space: {n_full} widened candidates vs {n_mono} \
              monolithic");
    assert!(n_full <= 3 * n_mono,
            "partitioned enumeration blew past 3x the monolithic space: \
             {n_full} vs {n_mono} candidates — did the partition grid grow?");
    println!("coexec/space gate: {n_full} <= 3 * {n_mono} — ok");
    let mono_enum = bench("coexec/enumerate_mono", 10, 100, || {
        black_box(old_space.enumerate(objective, &all, &idle));
    });
    let wide_enum = bench("coexec/enumerate_partitioned", 10, 100, || {
        black_box(wide_space.enumerate(objective, &all, &idle));
    });
    let wide_frontier =
        ParetoFrontier::build(&wide_space, objective, &all, &bucket);
    let pick = wide_frontier.best().expect("non-empty widened frontier");
    println!(
        "coexec/decision: widened enumerate {:.0}/s vs mono {:.0}/s \
         ({:.2}x work); pick {} ({:.3} ms avg, {})",
        1e3 / wide_enum.stats.avg.max(1e-9),
        1e3 / mono_enum.stats.avg.max(1e-9),
        wide_enum.stats.avg / mono_enum.stats.avg.max(1e-9),
        pick.design.variant,
        pick.avg_latency_ms,
        if pick.design.hw.plan.is_split() { "partitioned" } else { "monolithic" },
    );
    rt.shutdown();
}
