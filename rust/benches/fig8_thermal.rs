//! Bench: regenerate Fig 8 (Runtime Manager under thermal throttling).

use oodin::experiments::fig8;
use oodin::util::bench::time_once;

fn main() {
    let registry = oodin::load_registry_or_synthetic().unwrap();
    let (_, ms) = time_once("fig8/full_experiment", || {
        fig8::print(&registry, 1200).unwrap();
    });
    println!("(fig8 end-to-end: {ms:.0} ms)");
}
