//! Bench: regenerate Fig 8 (Runtime Manager under thermal throttling).

use oodin::experiments::fig8;
use oodin::load_registry;
use oodin::util::bench::time_once;

fn main() {
    let registry = load_registry().expect("run `make artifacts` first");
    let (_, ms) = time_once("fig8/full_experiment", || {
        fig8::print(&registry, 1200).unwrap();
    });
    println!("(fig8 end-to-end: {ms:.0} ms)");
}
