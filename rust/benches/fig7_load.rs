//! Bench: regenerate Fig 7 (Runtime Manager under ramped device load) and
//! run the hysteresis-threshold ablation the adaptation policy calls out.

use oodin::experiments::fig7;
use oodin::util::bench::time_once;

fn main() {
    let registry = oodin::load_registry_or_synthetic().unwrap();
    let (_, ms) = time_once("fig7/full_experiment", || {
        fig7::print(&registry, false).unwrap();
    });
    println!("(fig7 end-to-end: {ms:.0} ms)");
}
