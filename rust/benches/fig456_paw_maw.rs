//! Bench: regenerate Fig 4/5/6 (OODIn vs PAW-D / MAW-D per device).

use oodin::experiments::fig456;
use oodin::load_registry;
use oodin::util::bench::time_once;

fn main() {
    let registry = load_registry().expect("run `make artifacts` first");
    let (_, ms) = time_once("fig456/full_experiment", || {
        fig456::print(&registry, None).unwrap();
    });
    println!("(fig4/5/6 end-to-end: {ms:.0} ms)");
}
