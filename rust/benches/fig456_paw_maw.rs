//! Bench: regenerate Fig 4/5/6 (OODIn vs PAW-D / MAW-D per device).

use oodin::experiments::fig456;
use oodin::util::bench::time_once;

fn main() {
    let registry = oodin::load_registry_or_synthetic().unwrap();
    let (_, ms) = time_once("fig456/full_experiment", || {
        fig456::print(&registry, None).unwrap();
    });
    println!("(fig4/5/6 end-to-end: {ms:.0} ms)");
}
