//! Bench: regenerate Fig 3 (OODIn vs oSQ-CPU/-GPU/-NNAPI across the three
//! devices and all model families) and time its components.

use oodin::device::profiles::samsung_a71;
use oodin::experiments::{build_lut, fig3, EVAL_EPSILON};
use oodin::optimizer::{Objective, Optimizer, SearchSpace};
use oodin::util::bench::{bench, black_box, time_once};
use oodin::util::stats::Percentile;

fn main() {
    let registry = oodin::load_registry_or_synthetic().unwrap();

    println!("== FIG 3 reproduction ==");
    let (_, ms) = time_once("fig3/full_experiment", || {
        fig3::print(&registry).unwrap();
    });
    println!("(fig3 end-to-end: {ms:.0} ms)");

    println!("\n== component timings ==");
    let device = samsung_a71();
    let lut = build_lut(&device, &registry).unwrap();
    bench("measurements/full_sweep_200runs", 1, 5, || {
        black_box(build_lut(&device, &registry).unwrap());
    });
    let opt = Optimizer::new(&device, &registry, &lut);
    let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: EVAL_EPSILON };
    bench("optimizer/enumerative_search_one_family", 5, 200, || {
        black_box(opt.optimize(obj, &SearchSpace::family("mobilenet_v2_100")).unwrap());
    });
    bench("optimizer/enumerative_search_full_space", 5, 100, || {
        black_box(opt.optimize(obj, &SearchSpace::default()).unwrap());
    });
}
