//! Device resource model (paper §III-B1, Eq. 2):
//! `R = <CE, N_cores, C, DVFS, b, v_os, v_camera>`
//! plus the per-engine calibration constants that drive the performance
//! model (see `perf/`).  The three profiles in `profiles()` encode Table I
//! verbatim on the resource side; the engine throughput/overhead constants
//! are calibration values chosen so the *relative* engine behaviour of each
//! device class matches the phenomena the paper reports (see DESIGN.md
//! §Substitutions — dispatch overheads are scaled with the scaled-down
//! model workloads).

pub mod profiles;


/// A compute engine kind: CE = {CPU, GPU, NPU} (NPU ≡ the NNAPI target).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EngineKind {
    /// The multi-core CPU (threaded, XNNPACK-style execution).
    Cpu,
    /// The GPU delegate.
    Gpu,
    /// The NPU, reached through the NNAPI delegate.
    Npu,
}

impl EngineKind {
    /// Every engine kind, in declaration order.
    pub const ALL: [EngineKind; 3] = [EngineKind::Cpu, EngineKind::Gpu, EngineKind::Npu];

    /// Canonical identifier (`nnapi` for the NPU), as used in LUT keys.
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Cpu => "cpu",
            EngineKind::Gpu => "gpu",
            EngineKind::Npu => "nnapi",
        }
    }

    /// Parse an identifier (`npu` and `nnapi` both name the NPU).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "cpu" => EngineKind::Cpu,
            "gpu" => EngineKind::Gpu,
            "npu" | "nnapi" => EngineKind::Npu,
            other => anyhow::bail!("unknown engine `{other}`"),
        })
    }
}

/// Calibration constants of one compute engine on one device.
#[derive(Debug, Clone)]
pub struct EngineSpec {
    /// Which engine these constants describe.
    pub kind: EngineKind,
    /// Effective FP32 throughput with all resources engaged (GFLOP/s).
    pub peak_gflops_fp32: f64,
    /// Multiplier on peak when running FP16 models.
    pub fp16_mult: f64,
    /// Multiplier on peak when running INT8 models.
    pub int8_mult: f64,
    /// Memory bandwidth seen by this engine (GB/s).
    pub mem_bw_gbps: f64,
    /// Fixed per-dispatch overhead (ms): driver, queue, DMA setup.
    pub dispatch_ms: f64,
    /// Amdahl parallel fraction (CPU only; 0 for offload engines).
    pub parallel_frac: f64,
    /// Thermal behaviour of this engine.
    pub thermal: ThermalSpec,
}

/// First-order thermal RC constants (see `dvfs::ThermalModel`).
#[derive(Debug, Clone)]
pub struct ThermalSpec {
    /// Degrees added per ms of full-utilisation compute.
    pub heat_per_ms: f64,
    /// Fractional leak towards ambient per ms.
    pub cool_rate: f64,
    /// Throttling onset temperature (deg C).
    pub throttle_temp: f64,
    /// Frequency floor once fully throttled (fraction of nominal).
    pub min_freq_scale: f64,
}

/// Camera capabilities (v_camera in Eq. 2).
#[derive(Debug, Clone)]
pub struct CameraSpec {
    /// Camera2 hardware level: LEGACY | LIMITED | FULL | LEVEL_3.
    pub api_level: &'static str,
    /// Maximum capture rate (frames/s).
    pub max_fps: f64,
    /// Sensor resolution (width, height).
    pub resolution: (u32, u32),
}

/// The full per-device resource representation R.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    /// Profile identifier (`sony_c5`, `samsung_a71`, `samsung_s20_fe`).
    pub name: &'static str,
    /// SoC marketing name.
    pub chipset: &'static str,
    /// Device release year.
    pub year: u32,
    /// CE: available compute engines.
    pub engines: Vec<EngineSpec>,
    /// N_cores.
    pub n_cores: usize,
    /// C: memory capacity (bytes, scaled units — see DESIGN.md).
    pub mem_budget_bytes: u64,
    /// Physical RAM (GB, Table I).
    pub ram_gb: f64,
    /// DVFS: available governors.
    pub governors: Vec<crate::dvfs::Governor>,
    /// b: battery capacity (mAh).
    pub battery_mah: u32,
    /// v_os: Android version / API level.
    pub os_version: u32,
    /// Android API level.
    pub api_level: u32,
    /// v_camera: camera capabilities.
    pub camera: CameraSpec,
    /// A deployment is rejected when even the best sustained latency
    /// exceeds this (the paper drops DNNs causing >=5 s lag on Sony C5).
    pub max_deployable_latency_ms: f64,
}

impl DeviceProfile {
    /// The spec of one engine kind, when the device has it.
    pub fn engine(&self, kind: EngineKind) -> Option<&EngineSpec> {
        self.engines.iter().find(|e| e.kind == kind)
    }

    /// True when the device exposes this engine.
    pub fn has_engine(&self, kind: EngineKind) -> bool {
        self.engine(kind).is_some()
    }

    /// Valid thread counts to sweep: 1..=N_cores, powers of two + N_cores.
    pub fn thread_candidates(&self) -> Vec<usize> {
        let mut t = vec![1usize];
        let mut v = 2;
        while v < self.n_cores {
            t.push(v);
            v *= 2;
        }
        if self.n_cores > 1 {
            t.push(self.n_cores);
        }
        t.dedup();
        t
    }

    /// NNAPI op-support penalty for a model family on this device: >1 means
    /// partial acceleration with CPU fallbacks (the paper's "NNAPI remains
    /// in its infancy" effect).  1.0 for non-NPU engines.
    pub fn npu_family_penalty(&self, family: &str) -> f64 {
        profiles::npu_family_penalty(self.name, family)
    }
}

#[cfg(test)]
mod tests {
    use super::profiles::*;
    use super::*;

    #[test]
    fn three_devices_match_table1() {
        let all = profiles();
        assert_eq!(all.len(), 3);
        let sony = &all[0];
        assert_eq!(sony.name, "sony_c5");
        assert_eq!(sony.n_cores, 8);
        assert!(!sony.has_engine(EngineKind::Npu)); // Table I: NPU = x
        assert_eq!(sony.api_level, 23);
        assert_eq!(sony.battery_mah, 2930);

        let a71 = &all[1];
        assert!(a71.has_engine(EngineKind::Npu));
        assert_eq!(a71.n_cores, 8);
        assert_eq!(a71.api_level, 29);

        let s20 = &all[2];
        assert!(s20.has_engine(EngineKind::Npu));
        assert_eq!(s20.battery_mah, 4500);
        assert_eq!(s20.os_version, 11);
    }

    #[test]
    fn performance_ordering_low_to_high_end() {
        let all = profiles();
        let cpu = |d: &DeviceProfile| d.engine(EngineKind::Cpu).unwrap().peak_gflops_fp32;
        assert!(cpu(&all[0]) < cpu(&all[1]));
        assert!(cpu(&all[1]) < cpu(&all[2]));
    }

    #[test]
    fn thread_candidates_cover_cores() {
        let d = by_name("samsung_a71").unwrap();
        let t = d.thread_candidates();
        assert_eq!(t, vec![1, 2, 4, 8]);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("samsung_s20_fe").is_some());
        assert!(by_name("pixel_9").is_none());
    }

    #[test]
    fn npu_penalty_only_meaningful_families() {
        let _ = by_name("samsung_s20_fe").unwrap();
        assert!(npu_family_penalty("samsung_s20_fe", "deeplab_v3") > 5.0);
        assert_eq!(npu_family_penalty("samsung_s20_fe", "mobilenet_v2_100"), 1.0);
    }

    #[test]
    fn camera_api_levels() {
        let all = profiles();
        assert_eq!(all[0].camera.api_level, "LEGACY");
        assert_eq!(all[2].camera.api_level, "FULL");
    }
}
