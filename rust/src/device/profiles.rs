//! The three target platforms of Table I, as resource models + calibration
//! constants.
//!
//! Resource-side fields (cores, clocks, RAM, battery, OS/API level, camera
//! API, engine availability, governor sets) are Table I verbatim.  Engine
//! throughput/overhead/thermal constants are *calibration values*: they are
//! not measured from the physical phones (unavailable on this testbed) but
//! are chosen so each device class exhibits the qualitative behaviour the
//! paper reports and attributes to real hardware:
//!
//! * Sony Xperia C5 (2015 low-end): weak CPU (8x A53), old GPU driver with
//!   large dispatch overheads, no NPU, tight memory, aggressive thermal
//!   envelope -> some large FP32 models are simply not deployable (Fig 4).
//! * Samsung A71 (mid-tier): NNAPI NPU is excellent on quantised
//!   convnets (OODIn picks NNAPI for MobileNetV2 INT8 -> 3.5x over the
//!   CPU choice that an S20-optimised MAW design makes, §IV-B).
//! * Samsung S20 FE (flagship): very fast CPU with tiny dispatch cost --
//!   "on S20 the CPU is often the highest performing engine" (§IV-B) --
//!   while its NNAPI path is catastrophic on unsupported ops
//!   (up to ~93x, Fig 3).
//!
//! Dispatch overheads are scaled down ~100x together with the model
//! workloads (DESIGN.md §Substitutions), keeping overhead:compute ratios in
//! the regime that produces the paper's engine-selection landscape.
//! Thermal heat rates are scaled *up* by the same reasoning: sustained
//! streams of scaled-down inferences must reach throttling after a
//! comparable number of processed images (Fig 8's ~85), so degrees-per-
//! busy-ms is ~30x a physical SoC's.

use super::{CameraSpec, DeviceProfile, EngineKind, EngineSpec, ThermalSpec};
use crate::dvfs::Governor;

fn thermal(heat_per_ms: f64, cool_rate: f64, throttle_temp: f64,
           min_freq_scale: f64) -> ThermalSpec {
    ThermalSpec { heat_per_ms, cool_rate, throttle_temp, min_freq_scale }
}

/// Sony Xperia C5 Ultra — MediaTek MT6752, 8x Cortex-A53 @ 1.69 GHz,
/// Mali-T760 MP2, no NPU, 2 GB RAM, Android 6 (API 23), LEGACY camera.
pub fn sony_c5() -> DeviceProfile {
    DeviceProfile {
        name: "sony_c5",
        chipset: "MediaTek MT6752",
        year: 2015,
        engines: vec![
            EngineSpec {
                kind: EngineKind::Cpu,
                peak_gflops_fp32: 6.0,
                fp16_mult: 0.85, // no native fp16 pipe on A53: emulation cost
                int8_mult: 1.8,
                mem_bw_gbps: 2.5,
                dispatch_ms: 0.004,
                parallel_frac: 0.80,
                thermal: thermal(1.05, 0.002, 55.0, 0.45),
            },
            EngineSpec {
                kind: EngineKind::Gpu,
                peak_gflops_fp32: 9.0,
                fp16_mult: 1.7,
                int8_mult: 0.9, // old driver dequantises on the fly
                mem_bw_gbps: 3.5,
                dispatch_ms: 0.080, // 2015-era GL driver: heavy dispatch
                parallel_frac: 0.0,
                thermal: thermal(0.90, 0.002, 52.0, 0.40),
            },
        ],
        n_cores: 8,
        mem_budget_bytes: 4 * 1024 * 1024, // scaled from 2 GB (see DESIGN.md)
        ram_gb: 2.0,
        governors: vec![Governor::Performance, Governor::Schedutil],
        battery_mah: 2930,
        os_version: 6,
        api_level: 23,
        camera: CameraSpec { api_level: "LEGACY", max_fps: 30.0, resolution: (1080, 1920) },
        max_deployable_latency_ms: 8.0, // scaled "5 s AI-camera lag" bound
    }
}

/// Samsung A71 — Snapdragon 730 (2x Kryo 470 Gold @2.2 + 6x Silver @1.8),
/// Adreno 618, NPU, 6 GB RAM, Android 10 (API 29), LEVEL_3 camera.
pub fn samsung_a71() -> DeviceProfile {
    DeviceProfile {
        name: "samsung_a71",
        chipset: "Snapdragon 730",
        year: 2020,
        engines: vec![
            EngineSpec {
                kind: EngineKind::Cpu,
                peak_gflops_fp32: 14.0,
                fp16_mult: 0.95,
                int8_mult: 2.2, // XNNPACK dot-product kernels
                mem_bw_gbps: 8.0,
                dispatch_ms: 0.002,
                parallel_frac: 0.85,
                thermal: thermal(0.08, 0.003, 62.0, 0.55),
            },
            EngineSpec {
                kind: EngineKind::Gpu,
                peak_gflops_fp32: 22.0,
                fp16_mult: 1.9,
                int8_mult: 1.3,
                mem_bw_gbps: 11.0,
                dispatch_ms: 0.012,
                parallel_frac: 0.0,
                thermal: thermal(0.25, 0.001, 60.0, 0.50),
            },
            EngineSpec {
                kind: EngineKind::Npu,
                // NNAPI executes fp32 graphs in relaxed-fp16 on the DSP:
                // decent, but behind the GPU's native fp32 pipe.
                peak_gflops_fp32: 16.0,
                fp16_mult: 1.4,
                int8_mult: 4.0625, // 65 GFLOP/s effective on int8 convnets
                mem_bw_gbps: 9.0,
                dispatch_ms: 0.018,
                parallel_frac: 0.0,
                thermal: thermal(0.30, 0.0003, 58.0, 0.35),
            },
        ],
        n_cores: 8,
        mem_budget_bytes: 12 * 1024 * 1024, // scaled from 6 GB
        ram_gb: 6.0,
        governors: vec![Governor::EnergyStep, Governor::Performance, Governor::Schedutil],
        battery_mah: 4500,
        os_version: 10,
        api_level: 29,
        camera: CameraSpec { api_level: "LEVEL_3", max_fps: 30.0, resolution: (1080, 2400) },
        max_deployable_latency_ms: 25.0,
    }
}

/// Samsung S20 FE — Exynos 990 (2x M5 @2.73 + 2x A76 @2.5 + 4x A55 @2.0),
/// Mali-G77 MP11, NPU, 6 GB RAM, Android 11 (API 30), FULL camera.
pub fn samsung_s20_fe() -> DeviceProfile {
    DeviceProfile {
        name: "samsung_s20_fe",
        chipset: "Exynos 990",
        year: 2020,
        engines: vec![
            EngineSpec {
                kind: EngineKind::Cpu,
                peak_gflops_fp32: 30.0,
                fp16_mult: 1.0,
                int8_mult: 2.5,
                mem_bw_gbps: 16.0,
                dispatch_ms: 0.0015,
                parallel_frac: 0.85,
                thermal: thermal(0.48, 0.0035, 65.0, 0.55),
            },
            EngineSpec {
                kind: EngineKind::Gpu,
                peak_gflops_fp32: 60.0,
                fp16_mult: 1.9,
                int8_mult: 1.4,
                mem_bw_gbps: 22.0,
                dispatch_ms: 0.018,
                parallel_frac: 0.0,
                thermal: thermal(0.42, 0.0035, 63.0, 0.50),
            },
            EngineSpec {
                kind: EngineKind::Npu,
                // Relaxed-fp16 execution of fp32 graphs, as on the A71.
                peak_gflops_fp32: 20.0,
                fp16_mult: 1.6,
                int8_mult: 7.5, // 150 GFLOP/s on supported int8 graphs
                mem_bw_gbps: 14.0,
                dispatch_ms: 0.030, // Exynos NNAPI HAL: heavy session setup
                parallel_frac: 0.0,
                thermal: thermal(0.66, 0.003, 60.0, 0.35),
            },
        ],
        n_cores: 8,
        mem_budget_bytes: 12 * 1024 * 1024,
        ram_gb: 6.0,
        governors: vec![Governor::EnergyStep, Governor::Performance, Governor::Schedutil],
        battery_mah: 4500,
        os_version: 11,
        api_level: 30,
        camera: CameraSpec { api_level: "FULL", max_fps: 60.0, resolution: (1080, 2400) },
        max_deployable_latency_ms: 25.0,
    }
}

/// All Table I devices, low- to high-end.
pub fn profiles() -> Vec<DeviceProfile> {
    vec![sony_c5(), samsung_a71(), samsung_s20_fe()]
}

/// Look up a Table I profile by its `name` field.
pub fn by_name(name: &str) -> Option<DeviceProfile> {
    profiles().into_iter().find(|d| d.name == name)
}

/// NNAPI op-support penalty (multiplier >= 1 on NPU latency) per
/// (device, model family).  Families with ops outside the NNAPI-delegate
/// fast path (bilinear resize + atrous conv in DeepLab, the branchy
/// inception concat pattern, large dense ResNet convs on some HALs) incur
/// partial CPU fallback.  These produce Fig 3's "NNAPI is up to 93x worse"
/// tail and the A71-vs-S20 engine flips in §IV-B.
pub fn npu_family_penalty(device: &str, family: &str) -> f64 {
    match (device, family) {
        // Snapdragon 730 NNAPI: good on convnets, weak on seg heads.
        ("samsung_a71", "efficientnet_lite4") => 3.0,
        ("samsung_a71", "deeplab_v3") => 12.0,
        ("samsung_a71", "resnet_v2") => 1.8,
        // Exynos 990 NNAPI HAL: catastrophic on unsupported graphs.
        ("samsung_s20_fe", "efficientnet_lite4") => 1.5,
        ("samsung_s20_fe", "deeplab_v3") => 110.0,
        ("samsung_s20_fe", "inception_v3") => 4.0,
        ("samsung_s20_fe", "resnet_v2") => 3.0,
        _ => 1.0,
    }
}
