//! DLACL — Deep Learning Architecture Convergence Layer (paper §III-C2).
//!
//! The DNN-aware sublayer: it owns every model-dependent buffer (input
//! samples, the model itself, intermediate results), sized statically from
//! the variant tuple fields `s_in`, `s_m`, `p` known a priori — so a model
//! swap allocates exactly what the incoming variant needs and releases the
//! outgoing variant's buffers without starving memory.  It also implements
//! the input pipeline (resolution adaptation from the camera stream) and
//! executes the online model selection orders issued by the Runtime
//! Manager.
//!
//! DLACL is execution-engine-agnostic: it drives whichever [`Backend`] the
//! application wired in (PJRT artifacts or the hermetic simulator).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::model::{ModelVariant, Registry};
use crate::runtime::{Backend, ExecOutput};

/// Model-dependent buffer set for one resident variant.
#[derive(Debug)]
pub struct BufferSet {
    /// Flat f32 input staging buffer (reused across frames — the request
    /// path does not allocate).
    pub input: Vec<f32>,
    /// Logical input shape of the resident variant.
    pub input_shape: Vec<usize>,
    /// Bytes attributed to this variant: weights + input + intermediates.
    pub total_bytes: u64,
}

impl BufferSet {
    /// Allocate the statically-sized buffers for one variant.
    pub fn for_variant(v: &ModelVariant) -> Self {
        BufferSet {
            input: vec![0.0; v.input_elems()],
            input_shape: v.input_shape.clone(),
            total_bytes: v.mem_bytes(),
        }
    }
}

/// The DLACL model slot: at most one resident variant per slot, swapped on
/// Runtime Manager orders.
pub struct ModelSlot {
    runtime: Arc<dyn Backend>,
    resident: Option<(ModelVariant, BufferSet)>,
    /// Device memory budget DLACL may use (from the MDCL resource model).
    budget_bytes: u64,
    /// Swap count (telemetry).
    pub swaps: u64,
}

impl ModelSlot {
    /// An empty slot over `runtime` with a memory budget.
    pub fn new(runtime: Arc<dyn Backend>, budget_bytes: u64) -> Self {
        ModelSlot { runtime, resident: None, budget_bytes, swaps: 0 }
    }

    /// The currently resident variant, if any.
    pub fn resident(&self) -> Option<&ModelVariant> {
        self.resident.as_ref().map(|(v, _)| v)
    }

    /// Bytes attributed to the resident variant (0 when empty).
    pub fn resident_bytes(&self) -> u64 {
        self.resident.as_ref().map_or(0, |(_, b)| b.total_bytes)
    }

    /// Swap in `variant`: budget check, compile+cache via the backend,
    /// allocate the statically-sized buffers, release the old set.
    pub fn swap_to(&mut self, registry: &Registry, variant: &str) -> Result<()> {
        if self.resident().map(|v| v.name.as_str()) == Some(variant) {
            return Ok(()); // already resident
        }
        let v = registry
            .get(variant)
            .with_context(|| format!("unknown variant `{variant}`"))?
            .clone();
        if v.mem_bytes() > self.budget_bytes {
            bail!(
                "variant `{}` needs {} B, budget is {} B",
                v.name, v.mem_bytes(), self.budget_bytes
            );
        }
        let path = registry.hlo_path(&v);
        self.runtime
            .load(&v.name, &path)
            .with_context(|| format!("loading artifact for `{}`", v.name))?;
        // Old buffers drop here; the executable cache entry is evicted so
        // compiled code does not accumulate across many swaps.
        if let Some((old, _)) = self.resident.take() {
            let _ = self.runtime.evict(&old.name);
        }
        let bufs = BufferSet::for_variant(&v);
        self.resident = Some((v, bufs));
        self.swaps += 1;
        Ok(())
    }

    /// Stage a frame into the input buffer (nearest-neighbour resample from
    /// the camera resolution) and execute.  Returns the raw output plus the
    /// host wall-clock.
    pub fn infer(&mut self, frame: &[f32], frame_h: usize, frame_w: usize)
                 -> Result<ExecOutput> {
        let Some((v, bufs)) = self.resident.as_mut() else {
            bail!("no model resident in DLACL slot");
        };
        stage_input(frame, frame_h, frame_w, &mut bufs.input, v.resolution);
        self.runtime
            .execute(&v.name, bufs.input.clone(), &bufs.input_shape)
    }
}

/// Nearest-neighbour RGB resample from (h, w) to (res, res) into `dst`
/// (layout NHWC with N=1..batch; the frame is replicated across batch).
pub fn stage_input(frame: &[f32], h: usize, w: usize, dst: &mut [f32], res: usize) {
    assert_eq!(frame.len(), h * w * 3, "frame buffer size");
    let per_image = res * res * 3;
    assert!(dst.len() % per_image == 0, "dst not a whole batch");
    for oy in 0..res {
        let sy = oy * h / res;
        for ox in 0..res {
            let sx = ox * w / res;
            let s = (sy * w + sx) * 3;
            let d = (oy * res + ox) * 3;
            dst[d..d + 3].copy_from_slice(&frame[s..s + 3]);
        }
    }
    // Replicate to remaining batch entries.
    let (first, rest) = dst.split_at_mut(per_image);
    for chunk in rest.chunks_mut(per_image) {
        chunk.copy_from_slice(first);
    }
}

/// Classification head decode: arg-max + score over the logits of sample 0.
pub fn decode_top1(output: &[f32], n_classes: usize) -> (usize, f32) {
    let logits = &output[..n_classes];
    let mut best = 0;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    (best, logits[best])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::model::test_fixtures::fake_registry;
    use crate::runtime::SimBackend;
    use crate::sil::camera::class_frame;

    fn backend() -> Arc<dyn Backend> {
        Arc::new(SimBackend::new(samsung_a71(), fake_registry()))
    }

    #[test]
    fn stage_input_identity_when_same_size() {
        let frame: Vec<f32> = (0..4 * 4 * 3).map(|i| i as f32).collect();
        let mut dst = vec![0.0; 4 * 4 * 3];
        stage_input(&frame, 4, 4, &mut dst, 4);
        assert_eq!(dst, frame);
    }

    #[test]
    fn stage_input_downsamples() {
        // 4x4 -> 2x2 nearest: picks pixels (0,0),(0,2),(2,0),(2,2)
        let mut frame = vec![0.0; 4 * 4 * 3];
        for y in 0..4 {
            for x in 0..4 {
                frame[(y * 4 + x) * 3] = (y * 10 + x) as f32;
            }
        }
        let mut dst = vec![0.0; 2 * 2 * 3];
        stage_input(&frame, 4, 4, &mut dst, 2);
        assert_eq!([dst[0], dst[3], dst[6], dst[9]], [0.0, 2.0, 20.0, 22.0]);
    }

    #[test]
    fn stage_input_replicates_batch() {
        let frame = vec![1.5f32; 2 * 2 * 3];
        let mut dst = vec![0.0; 3 * (2 * 2 * 3)]; // batch of 3
        stage_input(&frame, 2, 2, &mut dst, 2);
        assert!(dst.iter().all(|&x| x == 1.5));
    }

    #[test]
    fn decode_top1_picks_argmax() {
        let out = [0.1, 3.0, -1.0, 2.0];
        assert_eq!(decode_top1(&out, 4), (1, 3.0));
    }

    #[test]
    fn swap_rejects_over_budget() {
        let reg = fake_registry();
        let mut slot = ModelSlot::new(backend(), 10); // 10-byte budget
        let err = slot.swap_to(&reg, "mobilenet_v2_100__fp32__b1").unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
    }

    #[test]
    fn swap_unknown_variant_fails() {
        let reg = fake_registry();
        let mut slot = ModelSlot::new(backend(), u64::MAX);
        assert!(slot.swap_to(&reg, "ghost__fp32__b1").is_err());
    }

    #[test]
    fn infer_without_model_fails() {
        let mut slot = ModelSlot::new(backend(), u64::MAX);
        assert!(slot.infer(&[0.0; 12], 2, 2).is_err());
    }

    #[test]
    fn swap_is_idempotent_and_counts() {
        let reg = fake_registry();
        let mut slot = ModelSlot::new(backend(), u64::MAX);
        slot.swap_to(&reg, "mobilenet_v2_100__fp32__b1").unwrap();
        slot.swap_to(&reg, "mobilenet_v2_100__fp32__b1").unwrap();
        assert_eq!(slot.swaps, 1);
        assert!(slot.resident_bytes() > 0);
    }

    #[test]
    fn swap_evicts_outgoing_variant() {
        let be = backend();
        let reg = fake_registry();
        let mut slot = ModelSlot::new(Arc::clone(&be), u64::MAX);
        slot.swap_to(&reg, "mobilenet_v2_100__fp32__b1").unwrap();
        slot.swap_to(&reg, "mobilenet_v2_100__int8__b1").unwrap();
        assert_eq!(be.loaded().unwrap(),
                   vec!["mobilenet_v2_100__int8__b1".to_string()]);
        assert_eq!(slot.swaps, 2);
    }

    #[test]
    fn infer_stages_and_decodes_through_backend() {
        let reg = fake_registry();
        let v = reg.get("mobilenet_v2_100__fp32__b1").unwrap().clone();
        let mut slot = ModelSlot::new(backend(), u64::MAX);
        slot.swap_to(&reg, &v.name).unwrap();
        let frame = class_frame(v.resolution, 3);
        let out = slot.infer(&frame, v.resolution, v.resolution).unwrap();
        assert_eq!(out.values.len(), v.output_elems());
        assert_eq!(decode_top1(&out.values, 10).0, 3);
        assert!(out.host_ms > 0.0);
    }
}
