//! Deterministic SplitMix64 RNG (no `rand` crate on this offline image).
//!
//! Used by the measurement noise model, the synthetic camera, and the
//! property-test harness.  Deterministic seeding keeps every experiment
//! reproducible run-to-run.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator; equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal multiplicative noise with the given sigma (latency jitter).
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Uniformly pick one element (panics on an empty slice).
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_positive_and_centred() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..10_000).map(|_| r.lognormal(0.05)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
