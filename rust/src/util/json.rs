//! Minimal JSON parser/serializer (no serde on this offline image).
//!
//! Supports the full JSON grammar minus exotic escapes (\u surrogate pairs
//! are decoded), preserves object insertion order via `Vec<(String, Value)>`,
//! and round-trips the artifact manifest and LUT files byte-faithfully
//! enough for our needs. Numbers are stored as f64 (adequate: manifest
//! integers are < 2^53).

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (stored as f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object, insertion order preserved.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup; `None` on non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing field `{key}`"))
    }

    /// The number, or an error on any other kind.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The number as an unsigned integer (rejects negatives/fractions).
    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected unsigned integer, got {f}");
        }
        Ok(f as u64)
    }

    /// [`Value::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    /// The string, or an error on any other kind.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The boolean, or an error on any other kind.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// The array items, or an error on any other kind.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// The object's (key, value) pairs, or an error on any other kind.
    pub fn as_obj(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

/// Parse a complete JSON document (trailing garbage is an error).
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, found `{}`",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(kv));
                }
                c => bail!("expected `,` or `}}`, found `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected `,` or `]`, found `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                s.push(char::from_u32(c)
                                    .ok_or_else(|| anyhow!("bad surrogate"))?);
                            } else {
                                s.push(char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        _ => bail!("bad escape `\\{}`", e as char),
                    }
                }
                _ => {
                    // Re-scan as UTF-8: step back and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| anyhow!("invalid utf8: {e}"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek()?;
            self.i += 1;
            v = v * 16
                + (c as char).to_digit(16)
                    .ok_or_else(|| anyhow!("bad hex digit"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| anyhow!("invalid number `{s}` at byte {start}"))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

/// Serialise compactly (no whitespace; integers without a fraction).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v);
    out
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_str(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, item);
            }
            out.push(']');
        }
        Value::Obj(kv) => {
            out.push('{');
            for (i, (k, val)) in kv.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(out, k);
                out.push(':');
                write_value(out, val);
            }
            out.push('}');
        }
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by the LUT / report writers.
pub fn obj(kv: Vec<(&str, Value)>) -> Value {
    Value::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// A [`Value::Num`].
pub fn num(n: f64) -> Value {
    Value::Num(n)
}

/// A [`Value::Str`].
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"m1","flops":123456789,"acc":0.8125,"arr":[1,2,3],"nested":{"x":null,"y":false}}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(to_string(&Value::Num(3.0)), "3");
        assert_eq!(to_string(&Value::Num(3.5)), "3.5");
    }

    #[test]
    fn req_reports_missing_field() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("a").is_ok());
        let err = v.req("b").unwrap_err().to_string();
        assert!(err.contains("`b`"), "{err}");
    }
}
