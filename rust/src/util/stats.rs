//! Latency statistics: the paper's Device Measurements collect min, max,
//! average, median and n-th percentile of latency/throughput plus peak
//! memory (§III-D). `LatencyStats` is that summary; `Summary` keeps the raw
//! samples for percentile queries at arbitrary n.

use crate::util::json::{self, Value};

/// Summary statistics over a set of latency samples (milliseconds).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyStats {
    /// Fastest sample (ms).
    pub min: f64,
    /// Slowest sample (ms).
    pub max: f64,
    /// Arithmetic mean (ms).
    pub avg: f64,
    /// 50th percentile (ms).
    pub median: f64,
    /// 90th percentile (ms).
    pub p90: f64,
    /// 99th percentile (ms).
    pub p99: f64,
    /// Sample count.
    pub n: usize,
}

impl LatencyStats {
    /// Summarise a non-empty sample set (panics on empty input).
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "no samples");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencyStats {
            min: s[0],
            max: *s.last().unwrap(),
            avg: s.iter().sum::<f64>() / s.len() as f64,
            median: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
            n: s.len(),
        }
    }

    /// Every statistic multiplied by `factor` (sample count unchanged) —
    /// the shape of a uniform latency correction, e.g. the fleet probe
    /// fallback's per-engine scale.
    pub fn scaled(&self, factor: f64) -> Self {
        LatencyStats {
            min: self.min * factor,
            max: self.max * factor,
            avg: self.avg * factor,
            median: self.median * factor,
            p90: self.p90 * factor,
            p99: self.p99 * factor,
            n: self.n,
        }
    }

    /// Pick the statistic named by the objective (`avg`, `median`, `p90`...).
    pub fn metric(&self, which: Percentile) -> f64 {
        match which {
            Percentile::Min => self.min,
            Percentile::Max => self.max,
            Percentile::Avg => self.avg,
            Percentile::Median => self.median,
            Percentile::P90 => self.p90,
            Percentile::P99 => self.p99,
        }
    }

    /// Serialise for LUT files / telemetry snapshots.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("min", json::num(self.min)),
            ("max", json::num(self.max)),
            ("avg", json::num(self.avg)),
            ("median", json::num(self.median)),
            ("p90", json::num(self.p90)),
            ("p99", json::num(self.p99)),
            ("n", json::num(self.n as f64)),
        ])
    }

    /// Parse the [`LatencyStats::to_json`] representation.
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(LatencyStats {
            min: v.req("min")?.as_f64()?,
            max: v.req("max")?.as_f64()?,
            avg: v.req("avg")?.as_f64()?,
            median: v.req("median")?.as_f64()?,
            p90: v.req("p90")?.as_f64()?,
            p99: v.req("p99")?.as_f64()?,
            n: v.req("n")?.as_usize()?,
        })
    }
}

/// Which summary statistic an objective targets (paper: avg / median / n-th
/// percentile).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Percentile {
    /// Fastest sample.
    Min,
    /// Slowest sample.
    Max,
    /// Arithmetic mean.
    Avg,
    /// 50th percentile.
    Median,
    /// 90th percentile.
    P90,
    /// 99th percentile.
    P99,
}

impl Percentile {
    /// Parse a statistic name (`avg`, `p50`, `p90`, ...).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "min" => Percentile::Min,
            "max" => Percentile::Max,
            "avg" | "average" | "mean" => Percentile::Avg,
            "median" | "p50" => Percentile::Median,
            "p90" => Percentile::P90,
            "p99" => Percentile::P99,
            other => anyhow::bail!("unknown statistic `{other}`"),
        })
    }

    /// Canonical lower-case name.
    pub fn name(&self) -> &'static str {
        match self {
            Percentile::Min => "min",
            Percentile::Max => "max",
            Percentile::Avg => "avg",
            Percentile::Median => "median",
            Percentile::P90 => "p90",
            Percentile::P99 => "p99",
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean — the paper reports geo-mean speedups across models.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// A rolling window of recent latency samples (Runtime Manager's view).
#[derive(Debug, Clone)]
pub struct RollingWindow {
    cap: usize,
    buf: Vec<f64>,
    next: usize,
    full: bool,
}

impl RollingWindow {
    /// An empty window keeping the most recent `cap` samples.
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        RollingWindow { cap, buf: Vec::with_capacity(cap), next: 0, full: false }
    }

    /// Append a sample, evicting the oldest once full.
    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
            if self.buf.len() == self.cap {
                self.full = true;
            }
        } else {
            self.buf[self.next] = x;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Samples currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True before the first sample.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// True once `cap` samples have been seen.
    pub fn is_full(&self) -> bool {
        self.full
    }

    /// Mean of the held samples; `None` when empty.
    pub fn mean(&self) -> Option<f64> {
        if self.buf.is_empty() {
            None
        } else {
            Some(self.buf.iter().sum::<f64>() / self.buf.len() as f64)
        }
    }

    /// Percentile of the held samples; `None` when empty.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.buf.is_empty() {
            return None;
        }
        let mut s = self.buf.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(percentile_sorted(&s, p))
    }

    /// Drop every held sample.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.next = 0;
        self.full = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = LatencyStats::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.avg, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&s, 0.0), 10.0);
        assert_eq!(percentile_sorted(&s, 100.0), 40.0);
        assert!((percentile_sorted(&s, 50.0) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn p90_on_uniform() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencyStats::from_samples(&xs);
        assert!((s.p90 - 90.1).abs() < 0.2, "{}", s.p90);
    }

    #[test]
    fn json_roundtrip() {
        let s = LatencyStats::from_samples(&[3.0, 1.0, 2.0]);
        let back = LatencyStats::from_json(&s.to_json()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn geomean_matches_paper_style() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_parse_names() {
        assert_eq!(Percentile::parse("p90").unwrap(), Percentile::P90);
        assert_eq!(Percentile::parse("avg").unwrap(), Percentile::Avg);
        assert!(Percentile::parse("p42").is_err());
    }

    #[test]
    fn rolling_window_wraps() {
        let mut w = RollingWindow::new(3);
        assert!(w.mean().is_none());
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        // window now holds {4, 2, 3}
        assert!(w.is_full());
        assert!((w.mean().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn stats_empty_panics() {
        LatencyStats::from_samples(&[]);
    }
}
