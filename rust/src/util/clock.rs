//! Simulated/real time abstraction.
//!
//! The adaptation experiments (Fig 7/8) reason about *device* time —
//! "throttling detected within ~800 ms" — while numerics run as real PJRT
//! executions on the host.  `Clock` lets the Application, Runtime Manager
//! and thermal model share one monotonically advancing timeline that is
//! either wall-clock (`Real`) or advanced explicitly by simulated latencies
//! (`Sim`), so experiments are deterministic and fast.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A monotone timeline: wall clock or explicitly-advanced simulated time.
#[derive(Clone)]
pub enum Clock {
    /// Wall clock anchored at construction.
    Real(Instant),
    /// Microsecond counter advanced by [`Clock::advance_ms`].
    Sim(Arc<AtomicU64>),
}

impl Clock {
    /// A wall clock starting now.
    pub fn real() -> Self {
        Clock::Real(Instant::now())
    }

    /// A simulated clock starting at 0; clones share the counter.
    pub fn sim() -> Self {
        Clock::Sim(Arc::new(AtomicU64::new(0)))
    }

    /// Milliseconds since the clock's origin.
    pub fn now_ms(&self) -> f64 {
        match self {
            Clock::Real(t0) => t0.elapsed().as_secs_f64() * 1e3,
            Clock::Sim(us) => us.load(Ordering::SeqCst) as f64 / 1e3,
        }
    }

    /// Advance a simulated clock; no-op (with a debug assert) on real clocks.
    pub fn advance_ms(&self, ms: f64) {
        match self {
            Clock::Real(_) => debug_assert!(false, "advance_ms on real clock"),
            Clock::Sim(us) => {
                us.fetch_add((ms * 1e3).round() as u64, Ordering::SeqCst);
            }
        }
    }

    /// True for simulated clocks.
    pub fn is_sim(&self) -> bool {
        matches!(self, Clock::Sim(_))
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Clock::Real(_) => write!(f, "Clock::Real"),
            Clock::Sim(_) => write!(f, "Clock::Sim({:.3} ms)", self.now_ms()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances() {
        let c = Clock::sim();
        assert_eq!(c.now_ms(), 0.0);
        c.advance_ms(12.5);
        assert!((c.now_ms() - 12.5).abs() < 1e-9);
    }

    #[test]
    fn sim_clock_shared_between_clones() {
        let c = Clock::sim();
        let c2 = c.clone();
        c.advance_ms(5.0);
        assert!((c2.now_ms() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn real_clock_monotone() {
        let c = Clock::real();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
    }
}
