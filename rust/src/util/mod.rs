//! In-tree utility substrate (this offline image has no serde / rand /
//! tokio; see Cargo.toml).

pub mod bench;
pub mod clock;
pub mod json;
pub mod rng;
pub mod stats;
