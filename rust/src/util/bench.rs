//! Minimal benchmark harness (no criterion on this offline image).
//!
//! Measures wall-clock over warm-up + measured iterations and prints
//! criterion-style summary lines; used by every `rust/benches/*.rs` target
//! (declared with `harness = false`).

use std::time::Instant;

use crate::util::stats::LatencyStats;

/// Prevent the optimiser from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Per-iteration latency summary (ms).
    pub stats: LatencyStats,
    /// Measured iterations.
    pub iters: usize,
}

impl BenchResult {
    /// Print the criterion-style summary line.
    pub fn print(&self) {
        println!(
            "{:<44} {:>10.4} ms/iter  (median {:.4}, p90 {:.4}, min {:.4}, n={})",
            self.name, self.stats.avg, self.stats.median, self.stats.p90,
            self.stats.min, self.iters
        );
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F)
                         -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let r = BenchResult {
        name: name.to_string(),
        stats: LatencyStats::from_samples(&samples),
        iters,
    };
    r.print();
    r
}

/// Time a single long-running operation.
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("{name:<44} {ms:>10.1} ms (single run)");
    (out, ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || {
            n += 1;
            black_box(n);
        });
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + iters
        assert!(r.stats.min >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, ms) = time_once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(ms >= 0.0);
    }
}
