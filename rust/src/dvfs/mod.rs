//! DVFS governors and the first-order thermal model.
//!
//! The paper's system parameter `g ∈ DVFS` selects the frequency-scaling
//! policy; its run-time experiments (Fig 8) hinge on thermal throttling:
//! sustained inference heats the active engine, the governor cuts the
//! clock, latency rises, and the Runtime Manager migrates engines.  We model
//! each engine's temperature with a leaky integrator ("thermal RC"):
//!
//! `T += heat_per_ms * busy_ms * freq^2 * gov_heat  -  cool_rate * (T - ambient) * dt`
//!
//! and map temperature to a frequency scale with a linear ramp below
//! `min_freq_scale`-floored saturation — the classic step-down governor
//! shape.

use crate::device::ThermalSpec;

/// Ambient temperature every engine cools towards (deg C).
pub const AMBIENT_C: f64 = 25.0;

/// DVFS governor policies available on the target devices (Table I: S20 FE
/// exposes energy_step / performance / schedutil).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Governor {
    /// Pin to maximum frequency; fastest, heats fastest.
    Performance,
    /// Utilisation-driven; slight average clock loss, cooler.
    Schedutil,
    /// Step-wise energy saver; large clock loss, coolest.
    EnergyStep,
}

impl Governor {
    /// Every governor, in declaration order.
    pub const ALL: [Governor; 3] =
        [Governor::Performance, Governor::Schedutil, Governor::EnergyStep];

    /// Canonical identifier, as used in LUT keys.
    pub fn name(&self) -> &'static str {
        match self {
            Governor::Performance => "performance",
            Governor::Schedutil => "schedutil",
            Governor::EnergyStep => "energy_step",
        }
    }

    /// Parse a [`Governor::name`] identifier.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "performance" => Governor::Performance,
            "schedutil" => Governor::Schedutil,
            "energy_step" => Governor::EnergyStep,
            other => anyhow::bail!("unknown governor `{other}`"),
        })
    }

    /// Nominal frequency scale this governor sustains under inference load.
    pub fn freq_scale(&self) -> f64 {
        match self {
            Governor::Performance => 1.0,
            Governor::Schedutil => 0.94,
            Governor::EnergyStep => 0.78,
        }
    }

    /// Multiplier on heat generation (lower clocks burn less).
    pub fn heat_factor(&self) -> f64 {
        match self {
            Governor::Performance => 1.0,
            Governor::Schedutil => 0.85,
            Governor::EnergyStep => 0.58,
        }
    }
}

/// Per-engine thermal state evolved on the shared (sim or real) timeline.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    spec: ThermalSpec,
    temp_c: f64,
    last_update_ms: f64,
}

impl ThermalModel {
    /// A cool engine (ambient temperature) with the given constants.
    pub fn new(spec: ThermalSpec) -> Self {
        ThermalModel { spec, temp_c: AMBIENT_C, last_update_ms: 0.0 }
    }

    /// Current engine temperature (deg C).
    pub fn temp_c(&self) -> f64 {
        self.temp_c
    }

    /// Account `busy_ms` of compute ending at `now_ms` under `gov`.
    /// Cooling applies across the whole elapsed wall since the last call.
    pub fn record_work(&mut self, now_ms: f64, busy_ms: f64, gov: Governor) {
        let dt = (now_ms - self.last_update_ms).max(0.0);
        self.last_update_ms = now_ms;
        let f = gov.freq_scale();
        self.temp_c += self.spec.heat_per_ms * busy_ms * f * f * gov.heat_factor();
        self.cool(dt);
    }

    /// Pure cooling over `dt_ms` of idleness.
    pub fn idle_until(&mut self, now_ms: f64) {
        let dt = (now_ms - self.last_update_ms).max(0.0);
        self.last_update_ms = now_ms;
        self.cool(dt);
    }

    fn cool(&mut self, dt_ms: f64) {
        // Exponential decay towards ambient (exact integration, so large
        // simulated steps remain stable).
        let k = (-self.spec.cool_rate * dt_ms).exp();
        self.temp_c = AMBIENT_C + (self.temp_c - AMBIENT_C) * k;
    }

    /// Current frequency scale from throttling: 1.0 below the throttle
    /// temperature, then a linear ramp down to `min_freq_scale` over 12 C.
    pub fn freq_scale(&self) -> f64 {
        let over = self.temp_c - self.spec.throttle_temp;
        if over <= 0.0 {
            1.0
        } else {
            let ramp = 1.0 - over / 12.0 * (1.0 - self.spec.min_freq_scale);
            ramp.max(self.spec.min_freq_scale)
        }
    }

    /// True above the throttle-onset temperature.
    pub fn is_throttling(&self) -> bool {
        self.temp_c > self.spec.throttle_temp
    }

    #[cfg(test)]
    pub fn set_temp_for_test(&mut self, t: f64) {
        self.temp_c = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ThermalSpec {
        // equilibrium dT = heat/cool = 45 C over ambient -> crosses 55 C
        ThermalSpec { heat_per_ms: 0.09, cool_rate: 0.002, throttle_temp: 55.0,
                      min_freq_scale: 0.4 }
    }

    #[test]
    fn starts_at_ambient_unthrottled() {
        let m = ThermalModel::new(spec());
        assert_eq!(m.temp_c(), AMBIENT_C);
        assert_eq!(m.freq_scale(), 1.0);
        assert!(!m.is_throttling());
    }

    #[test]
    fn sustained_work_throttles() {
        let mut m = ThermalModel::new(spec());
        let mut t = 0.0;
        for _ in 0..2000 {
            t += 1.0;
            m.record_work(t, 1.0, Governor::Performance);
        }
        assert!(m.is_throttling(), "temp {}", m.temp_c());
        assert!(m.freq_scale() < 1.0);
        assert!(m.freq_scale() >= 0.4);
    }

    #[test]
    fn idle_cools_back_down() {
        let mut m = ThermalModel::new(spec());
        let mut t = 0.0;
        for _ in 0..2000 {
            t += 1.0;
            m.record_work(t, 1.0, Governor::Performance);
        }
        let hot = m.temp_c();
        m.idle_until(t + 5000.0);
        assert!(m.temp_c() < hot);
        m.idle_until(t + 100_000.0);
        assert!((m.temp_c() - AMBIENT_C).abs() < 0.5);
    }

    #[test]
    fn energy_step_heats_less() {
        let mut perf = ThermalModel::new(spec());
        let mut eco = ThermalModel::new(spec());
        let mut t = 0.0;
        for _ in 0..500 {
            t += 1.0;
            perf.record_work(t, 1.0, Governor::Performance);
            eco.record_work(t, 1.0, Governor::EnergyStep);
        }
        assert!(eco.temp_c() < perf.temp_c());
    }

    #[test]
    fn freq_scale_floors_at_min() {
        let mut m = ThermalModel::new(spec());
        m.set_temp_for_test(200.0);
        assert_eq!(m.freq_scale(), 0.4);
    }

    #[test]
    fn governor_names_roundtrip() {
        for g in Governor::ALL {
            assert_eq!(Governor::parse(g.name()).unwrap(), g);
        }
        assert!(Governor::parse("ondemand").is_err());
    }

    #[test]
    fn governor_scale_ordering() {
        assert!(Governor::Performance.freq_scale()
                > Governor::Schedutil.freq_scale());
        assert!(Governor::Schedutil.freq_scale()
                > Governor::EnergyStep.freq_scale());
    }
}
