//! Cross-device LUT transfer: predict an unseen device's per-design
//! latencies from measured *anchor* devices, without running the full
//! per-device measurement sweep.
//!
//! The registry cold-start problem at fleet scale: OODIn's offline Device
//! Measurements sweep every `<variant, engine, threads, governor>`
//! configuration per device (§III-D, 200 runs each) — affordable for three
//! phones, impossible for thousands of SoC variants.  This module
//! amortises it:
//!
//! * **Roofline-ratio scaling.**  For each LUT key, the predicted latency
//!   is the nearest anchor's *measured* entry scaled by the ratio of the
//!   closed-form roofline predictions ([`crate::perf::latency_ms`]) on the
//!   target's spec-sheet profile vs the anchor's.  The anchor measurement
//!   carries everything the analytical model got right about reality
//!   (noise floor, warm-up-trimmed statistics); the ratio carries the
//!   *observable* hardware delta (peak FLOPS, bandwidth, dispatch).  When
//!   the target *is* an anchor the ratio is exactly 1 and the prediction
//!   is the anchor entry bit-for-bit — transfer is anchored, not fitted.
//!
//! * **Confidence bounds.**  Per engine, confidence decays exponentially
//!   with the log-space distance between the target's engine spec and its
//!   nearest anchor's: far extrapolations are flagged rather than trusted.
//!
//! * **Probe fallback.**  Below the confidence threshold the engine is
//!   micro-profiled: a small probe set of designs (default 2 per engine)
//!   is measured on the *true* device through the simulator-backed
//!   [`crate::measurements::Measurer`], and the geometric-mean
//!   measured/predicted ratio becomes a per-engine correction applied to
//!   every predicted entry.  This is what recovers the hidden latent
//!   efficiency of [`super::population`] devices — the component no
//!   spec-sheet model can see — at probe-set cost instead of
//!   full-sweep cost.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::device::{DeviceProfile, EngineKind, EngineSpec};
use crate::measurements::{ExecPlan, Lut, LutKey, Measurer};
use crate::model::Registry;
use crate::perf::{self, ExecConditions};
use crate::util::stats::LatencyStats;

/// Transfer tuning knobs.
#[derive(Debug, Clone)]
pub struct TransferConfig {
    /// Probe an engine when its transfer confidence falls below this.
    pub confidence_threshold: f64,
    /// Measured runs per probe configuration.
    pub probe_runs: usize,
    /// Discarded warm-up runs per probe configuration.
    pub probe_warmup: usize,
    /// Probe designs per low-confidence engine.
    pub probes_per_engine: usize,
    /// Log-normal measurement noise of the probes (0 = closed-form).
    pub noise_sigma: f64,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            confidence_threshold: 0.72,
            probe_runs: 4,
            probe_warmup: 1,
            probes_per_engine: 2,
            noise_sigma: 0.0,
        }
    }
}

/// A fully measured reference device the transfer extrapolates from.
#[derive(Debug, Clone)]
pub struct Anchor {
    /// Anchor name (its archetype profile name).
    pub name: String,
    /// The anchor's spec-sheet profile.
    pub profile: DeviceProfile,
    /// The anchor's measured LUT (full sweep).
    pub lut: Lut,
}

/// Per-engine transfer provenance, reported by `oodin fleet-bench`.
#[derive(Debug, Clone)]
pub struct EngineTransfer {
    /// Nearest anchor this engine extrapolates from.
    pub anchor: String,
    /// Log-space spec distance to that anchor.
    pub distance: f64,
    /// `exp(-distance)` — the transfer confidence.
    pub confidence: f64,
    /// True when the probe fallback ran for this engine.
    pub probed: bool,
    /// Probe configurations measured (0 when not probed).
    pub probes: usize,
    /// Multiplicative correction applied to every predicted entry on this
    /// engine (1.0 when not probed).
    pub correction: f64,
}

/// A transferred LUT plus its per-engine provenance.
#[derive(Debug, Clone)]
pub struct TransferredLut {
    /// The predicted LUT for the target device.
    pub lut: Lut,
    /// Per-engine anchor choice, confidence and probe outcome.
    pub engines: BTreeMap<EngineKind, EngineTransfer>,
}

/// Log-space distance between two engine specs: the observable axes the
/// roofline ratio extrapolates along (peak throughput, bandwidth,
/// dispatch overhead).
pub fn engine_distance(t: &EngineSpec, a: &EngineSpec) -> f64 {
    (t.peak_gflops_fp32 / a.peak_gflops_fp32).ln().abs()
        + (t.mem_bw_gbps / a.mem_bw_gbps).ln().abs()
        + (t.dispatch_ms / a.dispatch_ms).ln().abs()
}

/// Transfer confidence for a spec distance: `exp(-d)` ∈ (0, 1].
pub fn confidence(distance: f64) -> f64 {
    (-distance).exp()
}

/// Closed-form roofline latency of a LUT configuration on a profile, at
/// nominal (idle, cool) conditions — the analytical half of the transfer
/// ratio.
pub fn roofline_ms(profile: &DeviceProfile, registry: &Registry, key: &LutKey)
                   -> Option<f64> {
    let v = registry.get(&key.variant)?;
    let cond = ExecConditions {
        governor: key.governor,
        threads: key.threads,
        load_factor: 0.0,
        thermal_freq_scale: 1.0,
    };
    perf::latency_ms(profile, key.engine, v, &cond)
}

fn scale_stats(s: &LatencyStats, r: f64) -> LatencyStats {
    LatencyStats {
        min: s.min * r,
        max: s.max * r,
        avg: s.avg * r,
        median: s.median * r,
        p90: s.p90 * r,
        p99: s.p99 * r,
        n: s.n,
    }
}

/// The cross-device LUT transfer engine.
pub struct TransferEngine<'a> {
    /// Measured anchors, in preference order on distance ties.
    pub anchors: Vec<Anchor>,
    /// Model space shared by every device.
    pub registry: &'a Registry,
    /// Tuning knobs.
    pub cfg: TransferConfig,
}

impl<'a> TransferEngine<'a> {
    /// A transfer engine over measured anchors.
    pub fn new(anchors: Vec<Anchor>, registry: &'a Registry,
               cfg: TransferConfig) -> Self {
        TransferEngine { anchors, registry, cfg }
    }

    /// Measure the standard anchors (every archetype, full sweep) with the
    /// given depth/noise and build a transfer engine over them.
    pub fn from_archetypes(registry: &'a Registry, cfg: TransferConfig,
                           lut_runs: usize, lut_warmup: usize,
                           noise_sigma: f64) -> Result<Self> {
        let mut anchors = Vec::new();
        for name in super::population::ARCHETYPES {
            let profile = super::population::archetype_profile(name);
            let lut = Measurer::new(&profile, registry)
                .with_runs(lut_runs, lut_warmup)
                .with_noise_sigma(noise_sigma)
                .measure_all()?;
            anchors.push(Anchor { name: name.to_string(), profile, lut });
        }
        Ok(TransferEngine::new(anchors, registry, cfg))
    }

    /// Anchor indices ordered by spec distance to `spec` (anchors lacking
    /// the engine excluded); ties keep anchor order.
    fn anchors_by_distance(&self, spec: &EngineSpec) -> Vec<(usize, f64)> {
        let mut out: Vec<(usize, f64)> = self
            .anchors
            .iter()
            .enumerate()
            .filter_map(|(i, a)| {
                a.profile
                    .engine(spec.kind)
                    .map(|aspec| (i, engine_distance(spec, aspec)))
            })
            .collect();
        out.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
        out
    }

    /// The distance from a profile's engine to its nearest anchor (`None`
    /// when the profile lacks the engine or no anchor has it).
    pub fn nearest_distance(&self, nominal: &DeviceProfile, kind: EngineKind)
                            -> Option<f64> {
        let spec = nominal.engine(kind)?;
        self.anchors_by_distance(spec).first().map(|&(_, d)| d)
    }

    /// Predict the target's full LUT from its spec-sheet profile: for each
    /// valid configuration, the nearest anchor's measured entry scaled by
    /// the target/anchor roofline ratio.  Keys fall back to the
    /// next-nearest anchor when the nearest lacks them (e.g. a governor
    /// outside the anchor's set).
    pub fn predict(&self, nominal: &DeviceProfile) -> Result<TransferredLut> {
        let mut entries = BTreeMap::new();
        let mut engines = BTreeMap::new();
        for spec in &nominal.engines {
            let ranked = self.anchors_by_distance(spec);
            let &(nearest, distance) = ranked.first().ok_or_else(|| {
                anyhow!("no anchor exposes engine {}", spec.kind.name())
            })?;
            engines.insert(spec.kind, EngineTransfer {
                anchor: self.anchors[nearest].name.clone(),
                distance,
                confidence: confidence(distance),
                probed: false,
                probes: 0,
                correction: 1.0,
            });
            let threads: Vec<usize> = match spec.kind {
                EngineKind::Cpu => nominal.thread_candidates(),
                _ => vec![1],
            };
            for v in self.registry.variants().iter().filter(|v| v.batch == 1) {
                for &t in &threads {
                    for &g in &nominal.governors {
                        let key = LutKey {
                            variant: v.name.clone(),
                            engine: spec.kind,
                            threads: t,
                            governor: g,
                            plan: ExecPlan::Mono,
                        };
                        let Some((anchor, entry)) = ranked
                            .iter()
                            .find_map(|&(i, _)| {
                                self.anchors[i]
                                    .lut
                                    .get(&key)
                                    .map(|e| (&self.anchors[i], e))
                            })
                        else {
                            continue;
                        };
                        let target_roof = roofline_ms(nominal, self.registry,
                                                      &key)
                            .ok_or_else(|| anyhow!("roofline for {}",
                                                   key.id()))?;
                        let anchor_roof = roofline_ms(&anchor.profile,
                                                      self.registry, &key)
                            .ok_or_else(|| anyhow!("anchor roofline for {}",
                                                   key.id()))?;
                        let ratio = target_roof / anchor_roof;
                        let mut e = entry.clone();
                        e.latency = scale_stats(&entry.latency, ratio);
                        e.mem_bytes = v.mem_bytes();
                        e.accuracy = v.accuracy;
                        entries.insert(key, e);
                    }
                }
            }
        }
        Ok(TransferredLut {
            lut: Lut { device: nominal.name.to_string(), entries },
            engines,
        })
    }

    /// Evenly spaced probe keys for one engine of a predicted LUT.
    pub fn probe_keys(&self, tlut: &TransferredLut, kind: EngineKind)
                      -> Vec<LutKey> {
        let keys: Vec<&LutKey> = tlut
            .lut
            .entries
            .keys()
            .filter(|k| k.engine == kind)
            .collect();
        if keys.is_empty() {
            return Vec::new();
        }
        let p = self.cfg.probes_per_engine.max(1);
        let mut picks = Vec::new();
        for j in 0..p {
            let idx = if p == 1 { 0 } else { j * (keys.len() - 1) / (p - 1) };
            let k = keys[idx].clone();
            if !picks.contains(&k) {
                picks.push(k);
            }
        }
        picks
    }

    /// Probe fallback for one engine: micro-profile the probe set on the
    /// *true* device profile (simulator-backed measurement), fold the
    /// geometric-mean measured/predicted ratio into every predicted entry
    /// on the engine, and record the outcome.
    pub fn probe_engine(&self, true_profile: &DeviceProfile,
                        tlut: &mut TransferredLut, kind: EngineKind)
                        -> Result<()> {
        let picks = self.probe_keys(tlut, kind);
        if picks.is_empty() {
            return Err(anyhow!("no predicted entries to probe on {}",
                               kind.name()));
        }
        let measurer = Measurer::new(true_profile, self.registry)
            .with_runs(self.cfg.probe_runs, self.cfg.probe_warmup)
            .with_noise_sigma(self.cfg.noise_sigma);
        let mut log_sum = 0.0;
        for key in &picks {
            let measured = measurer.measure_one(key)?;
            let predicted = tlut
                .lut
                .get(key)
                .ok_or_else(|| anyhow!("probe key {} unpredicted", key.id()))?;
            log_sum += (measured.latency.avg / predicted.latency.avg).ln();
        }
        let correction = (log_sum / picks.len() as f64).exp();
        for (k, e) in tlut.lut.entries.iter_mut() {
            if k.engine == kind {
                e.latency = scale_stats(&e.latency, correction);
            }
        }
        let rec = tlut
            .engines
            .get_mut(&kind)
            .ok_or_else(|| anyhow!("no transfer record for {}", kind.name()))?;
        rec.probed = true;
        rec.probes = picks.len();
        rec.correction = correction;
        Ok(())
    }

    /// Predict and, for every engine whose confidence falls below the
    /// threshold, run the probe fallback against the true profile.
    pub fn predict_with_probes(&self, nominal: &DeviceProfile,
                               true_profile: &DeviceProfile)
                               -> Result<TransferredLut> {
        let mut tlut = self.predict(nominal)?;
        let kinds: Vec<EngineKind> = tlut.engines.keys().copied().collect();
        for kind in kinds {
            if tlut.engines[&kind].confidence < self.cfg.confidence_threshold {
                self.probe_engine(true_profile, &mut tlut, kind)?;
            }
        }
        Ok(tlut)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::population::{archetype_profile, sample_device,
                                   PopulationConfig};
    use crate::model::test_fixtures::fake_registry;

    fn engine_over(reg: &Registry) -> TransferEngine<'_> {
        TransferEngine::from_archetypes(reg, TransferConfig::default(), 8, 1,
                                        0.0)
            .unwrap()
    }

    #[test]
    fn anchor_predicts_itself_exactly() {
        let reg = fake_registry();
        let te = engine_over(&reg);
        for anchor in &te.anchors {
            let t = te.predict(&anchor.profile).unwrap();
            assert_eq!(t.lut.len(), anchor.lut.len());
            for (k, e) in &anchor.lut.entries {
                let p = t.lut.get(k).unwrap();
                assert_eq!(p.latency.avg, e.latency.avg, "{}", k.id());
                assert_eq!(p.latency.p90, e.latency.p90, "{}", k.id());
            }
            for rec in t.engines.values() {
                assert_eq!(rec.distance, 0.0);
                assert_eq!(rec.confidence, 1.0);
                assert!(!rec.probed);
            }
        }
    }

    #[test]
    fn prediction_covers_the_target_key_space() {
        let reg = fake_registry();
        let te = engine_over(&reg);
        let d = sample_device(&PopulationConfig::default(), 3);
        let t = te.predict(&d.nominal).unwrap();
        // Every key measurable on the true device is predicted.
        let full = Measurer::new(&d.profile, &reg)
            .with_runs(4, 1)
            .with_noise_sigma(0.0)
            .measure_all()
            .unwrap();
        assert_eq!(t.lut.len(), full.len());
        for k in full.entries.keys() {
            assert!(t.lut.get(k).is_some(), "missing {}", k.id());
        }
    }

    #[test]
    fn low_confidence_triggers_probe_and_correction_recovers_latent() {
        let reg = fake_registry();
        let te = engine_over(&reg);
        // A target far from every anchor on the CPU axis, with a strong
        // hidden latent inefficiency the spec sheet cannot see.
        let base = archetype_profile("samsung_a71");
        let mut nominal = base.clone();
        nominal.engines[0].peak_gflops_fp32 *= (0.9f64).exp();
        let mut true_profile = nominal.clone();
        true_profile.engines[0].peak_gflops_fp32 *= 0.8;
        true_profile.engines[0].mem_bw_gbps *= 0.8;

        let t = te.predict_with_probes(&nominal, &true_profile).unwrap();
        let cpu = &t.engines[&EngineKind::Cpu];
        assert!(cpu.confidence < te.cfg.confidence_threshold,
                "confidence {} not low", cpu.confidence);
        assert!(cpu.probed && cpu.probes >= 2);
        // The latent factor slows the device ~1/0.8: the correction must
        // recover most of it (dispatch overhead keeps it from being exact).
        assert!(cpu.correction > 1.15 && cpu.correction < 1.30,
                "correction {}", cpu.correction);
        // Post-correction predictions sit close to true measurements.
        let full = Measurer::new(&true_profile, &reg)
            .with_runs(4, 1)
            .with_noise_sigma(0.0)
            .measure_all()
            .unwrap();
        for (k, e) in &full.entries {
            if k.engine != EngineKind::Cpu {
                continue;
            }
            let p = t.lut.get(k).unwrap();
            let err = (p.latency.avg / e.latency.avg - 1.0).abs();
            assert!(err < 0.06, "{}: err {err}", k.id());
        }
    }

    #[test]
    fn high_confidence_skips_probes() {
        let reg = fake_registry();
        let te = engine_over(&reg);
        let d = sample_device(&PopulationConfig::default(), 11);
        let t = te.predict_with_probes(&d.nominal, &d.profile).unwrap();
        for (kind, rec) in &t.engines {
            if rec.confidence >= te.cfg.confidence_threshold {
                assert!(!rec.probed, "{} probed at confidence {}",
                        kind.name(), rec.confidence);
            }
        }
    }
}
