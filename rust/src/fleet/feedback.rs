//! Online residual feedback: measured-vs-predicted latency corrections
//! and automatic anchor promotion.
//!
//! Devices already observe their true latencies — every executed design
//! lands in [`crate::manager::RuntimeManager::record_latency`] — while
//! their cohort decides from a *transferred* LUT.  This module closes
//! that loop:
//!
//! * [`FeedbackLoop::observe`] folds one execution's
//!   `ln(measured / predicted)` residual into a per-(cohort, engine)
//!   accumulator.
//! * [`FeedbackLoop::apply_round`] distils each accumulator with enough
//!   samples into a multiplicative correction
//!   `exp(mean ln residual)` — the geometric mean of the observed
//!   ratios, exactly the probe fallback's correction shape — and applies
//!   it through the incremental frontier delta path
//!   ([`Fleet::apply_cohort_scale`]), so every shared cache carries its
//!   warm frontiers across the corrected LUT.  Each applied correction
//!   is recorded as a [`TraceEvent::Residual`].
//! * [`FeedbackLoop::re_anchor`] watches the per-cohort accumulated
//!   `|ln correction|` magnitude: when it crosses the configured
//!   threshold the cohort's first member is promoted to a measured
//!   anchor ([`Fleet::re_anchor_cohort`]) — the continuous version of
//!   the probe fallback — bounding worst-case transfer distance as the
//!   population drifts.  Recorded as [`TraceEvent::ReAnchor`].
//!
//! Because corrections are uniform per-engine rescales, repeated rounds
//! converge: after a correction the cohort's predicted latencies carry
//! the geometric-mean of the observed truth, so the next round's
//! residuals shrink towards the irreducible intra-cohort spread.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::Result;

use crate::designspace::DeltaOutcome;
use crate::device::EngineKind;
use crate::telemetry::trace::{round3, TraceEvent};

use super::Fleet;

/// Feedback-loop thresholds.
#[derive(Debug, Clone)]
pub struct FeedbackConfig {
    /// Minimum residual samples a (cohort, engine) cell needs before a
    /// correction is distilled from it.
    pub min_samples: u64,
    /// Accumulated per-cohort `|ln correction|` above which the cohort
    /// representative is promoted to a measured anchor.
    pub re_anchor_threshold: f64,
}

impl Default for FeedbackConfig {
    fn default() -> Self {
        FeedbackConfig { min_samples: 2, re_anchor_threshold: 0.15 }
    }
}

/// One (cohort, engine) residual accumulator cell.
#[derive(Debug, Clone, Copy, Default)]
struct Cell {
    sum_ln: f64,
    sum_abs_ln: f64,
    samples: u64,
}

/// Aggregate outcome of one [`FeedbackLoop::apply_round`] call.
#[derive(Debug, Clone, Default)]
pub struct FeedbackRound {
    /// Residual observations folded this round.
    pub samples: u64,
    /// Mean `|ln(measured / predicted)|` over the round's observations.
    pub mean_abs_ln: f64,
    /// (cohort, engine) corrections applied.
    pub corrections: u64,
    /// Aggregate frontier-delta outcome of the applied corrections.
    pub delta: DeltaOutcome,
}

/// One anchor promotion performed by [`FeedbackLoop::re_anchor`].
#[derive(Debug, Clone)]
pub struct ReAnchorOutcome {
    /// Cohort index promoted (canonical order).
    pub cohort: usize,
    /// Device measured as the new anchor.
    pub device: String,
    /// Accumulated `|ln correction|` that tripped the threshold.
    pub magnitude: f64,
    /// Entries in the freshly measured LUT.
    pub entries: usize,
}

/// The per-fleet online feedback loop.
#[derive(Debug, Default)]
pub struct FeedbackLoop {
    cfg: FeedbackConfig,
    cells: BTreeMap<(usize, EngineKind), Cell>,
    accumulated: BTreeMap<usize, f64>,
    re_anchored: BTreeSet<usize>,
}

impl FeedbackLoop {
    /// A loop with the given thresholds.
    pub fn new(cfg: FeedbackConfig) -> FeedbackLoop {
        FeedbackLoop { cfg, ..Default::default() }
    }

    /// The active thresholds.
    pub fn cfg(&self) -> &FeedbackConfig {
        &self.cfg
    }

    /// Fold one executed design's measured latency against the cohort
    /// LUT's prediction for it.  Non-positive inputs are discarded
    /// (nothing meaningful can be logged about them).
    pub fn observe(&mut self, cohort: usize, engine: EngineKind,
                   measured_ms: f64, predicted_ms: f64) {
        if measured_ms <= 0.0
            || predicted_ms <= 0.0
            || !measured_ms.is_finite()
            || !predicted_ms.is_finite()
        {
            return;
        }
        let ln = (measured_ms / predicted_ms).ln();
        let cell = self.cells.entry((cohort, engine)).or_default();
        cell.sum_ln += ln;
        cell.sum_abs_ln += ln.abs();
        cell.samples += 1;
    }

    /// Residual observations awaiting the next round.
    pub fn pending_samples(&self) -> u64 {
        self.cells.values().map(|c| c.samples).sum()
    }

    /// A cohort's accumulated `|ln correction|` magnitude (reset to 0 by
    /// a re-anchor).
    pub fn accumulated(&self, cohort: usize) -> f64 {
        self.accumulated.get(&cohort).copied().unwrap_or(0.0)
    }

    /// Cohorts promoted to measured anchors so far, ascending.
    pub fn re_anchored(&self) -> Vec<usize> {
        self.re_anchored.iter().copied().collect()
    }

    /// Distil every cell with at least `min_samples` observations into a
    /// geometric-mean correction, apply it through the delta path, and
    /// drain the accumulators.  Cells are visited in (cohort, engine)
    /// order, so the correction stream is deterministic.
    pub fn apply_round(&mut self, fleet: &mut Fleet) -> FeedbackRound {
        let cells = std::mem::take(&mut self.cells);
        let mut round = FeedbackRound::default();
        let mut sum_abs_ln = 0.0;
        for ((ci, engine), cell) in cells {
            round.samples += cell.samples;
            sum_abs_ln += cell.sum_abs_ln;
            if cell.samples < self.cfg.min_samples {
                continue;
            }
            let mean_ln = cell.sum_ln / cell.samples as f64;
            let factor = mean_ln.exp();
            round.delta.absorb(fleet.apply_cohort_scale(ci, engine, factor));
            round.corrections += 1;
            *self.accumulated.entry(ci).or_insert(0.0) += mean_ln.abs();
            if let Some(rec) = &fleet.recorder {
                rec.emit(TraceEvent::Residual {
                    cohort: fleet.cohorts[ci].id.clone(),
                    engine: engine.name().to_string(),
                    samples: cell.samples,
                    factor: round3(factor),
                });
            }
        }
        round.mean_abs_ln = if round.samples == 0 {
            0.0
        } else {
            sum_abs_ln / round.samples as f64
        };
        round
    }

    /// Promote every cohort whose accumulated correction magnitude
    /// crossed the threshold to a measured anchor, resetting its
    /// magnitude.  Visits cohorts in ascending order.
    pub fn re_anchor(&mut self, fleet: &mut Fleet)
                     -> Result<Vec<ReAnchorOutcome>> {
        let tripped: Vec<(usize, f64)> = self
            .accumulated
            .iter()
            .filter(|&(_, &m)| m > self.cfg.re_anchor_threshold)
            .map(|(&ci, &m)| (ci, m))
            .collect();
        let mut outcomes = Vec::new();
        for (ci, magnitude) in tripped {
            let (device, entries) = fleet.re_anchor_cohort(ci)?;
            self.accumulated.insert(ci, 0.0);
            self.re_anchored.insert(ci);
            if let Some(rec) = &fleet.recorder {
                rec.emit(TraceEvent::ReAnchor {
                    cohort: fleet.cohorts[ci].id.clone(),
                    device: device.clone(),
                    magnitude: round3(magnitude),
                    entries: entries as u64,
                });
            }
            outcomes.push(ReAnchorOutcome { cohort: ci, device, magnitude,
                                            entries });
        }
        Ok(outcomes)
    }
}
