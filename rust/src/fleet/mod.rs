//! Fleet layer: population-scale device simulation with cross-device LUT
//! transfer and cohort-shared frontier caches.
//!
//! OODIn's premise is that DL deployment must adapt to *vast* system
//! heterogeneity (§I/§II) — but per-device offline profiling (§III-D)
//! and per-device Pareto-frontier builds ([`crate::designspace`]) do not
//! scale from three calibrated phones to the thousands of SoC / thermal /
//! memory configurations a real deployment faces.  This subsystem scales
//! the existing stack to a population:
//!
//! * [`population`] — a seeded sampler perturbing the Table I archetypes
//!   along peak-FLOPS / bandwidth / thermal-capacity / memory-capacity /
//!   engine-availability axes into reproducible fleets, each device with
//!   a hidden per-engine latent efficiency no spec sheet shows.
//! * [`transfer`] — cross-device LUT transfer: an unseen device's
//!   per-design latencies predicted from its nearest measured anchors by
//!   roofline-ratio scaling, with confidence bounds and a probe-set
//!   micro-profiling fallback — solving LUT cold-start without the full
//!   per-device sweep.
//! * [`Fleet`] — quantises devices into [`Cohort`]s (archetype × engine
//!   set × coarse performance bin), transfers **one LUT per cohort**
//!   (predicted at the cohort representative, probe-corrected when
//!   confidence is low) and shares **one LRU-bounded
//!   [`FrontierCache`] per cohort**, so the Pareto-frontier builds that
//!   power O(frontier) re-adaptation amortise across the population:
//!   frontier builds scale with (cohorts × visited buckets), not with
//!   devices.  Per-device adaptation then runs through the *existing*
//!   [`crate::manager::RuntimeManager`] path, each manager pointed at its
//!   cohort's representative device, LUT and shared cache.
//!
//! * [`rollout`] / [`feedback`] — the fleet **control plane**: staged
//!   canary rollouts of versioned LUT revisions gated on live cohort
//!   telemetry, and an online residual feedback loop that folds
//!   measured-vs-predicted latencies into per-cohort per-engine LUT
//!   corrections, promoting drifted cohorts to measured anchors.
//!
//! `oodin fleet-bench` ([`crate::experiments::fleetbench`]) drives a
//! scripted condition storm across the fleet and reports transferred-LUT
//! decision regret against a full-profile oracle, cohort cache hit rates,
//! and per-device adaptation decision counts — then a rollout scenario:
//! a deliberately mispredicted revision must auto-roll-back off its
//! canary cohorts while a good one promotes fleet-wide, followed by
//! residual-feedback rounds that must not worsen mean decision regret.

pub mod feedback;
pub mod population;
pub mod rollout;
pub mod transfer;

pub use feedback::{FeedbackConfig, FeedbackLoop, FeedbackRound,
                   ReAnchorOutcome};
pub use population::{CohortKey, PopulationConfig, SampledDevice};
pub use rollout::{CohortReport, IngestOutcome, Revision, RevisionRegistry,
                  Rollout, RolloutConfig, RolloutOutcome, RolloutStage,
                  BASELINE_REVISION};
pub use transfer::{Anchor, EngineTransfer, TransferConfig, TransferEngine,
                   TransferredLut};

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::designspace::{CacheStats, ConditionsBucket, DeltaOutcome,
                         DesignSpace, FrontierCache, LutDelta};
use crate::device::{DeviceProfile, EngineKind};
use crate::manager::{Conditions, RuntimeManager};
use crate::measurements::{Lut, Measurer};
use crate::model::Registry;
use crate::optimizer::{Design, Objective, SearchSpace};
use crate::telemetry::trace::{round3, FlightRecorder, TraceEvent};
use crate::telemetry::Telemetry;

/// Fleet construction parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Population sampling parameters.
    pub population: PopulationConfig,
    /// Cross-device transfer parameters.
    pub transfer: TransferConfig,
    /// Measured runs for anchor LUTs and full-profile oracle LUTs.
    pub lut_runs: usize,
    /// Discarded warm-up runs for those sweeps.
    pub lut_warmup: usize,
    /// Log-normal measurement noise of those sweeps (0 = closed-form).
    pub noise_sigma: f64,
    /// LRU capacity of each cohort's shared frontier cache.
    pub frontier_cache_cap: usize,
    /// Fleet-wide frontier memory budget in accounted bytes
    /// ([`FrontierCache::resident_bytes`]); split evenly across cohorts so
    /// each shared cache's LRU bound is data-driven (0 = unbounded).
    pub frontier_mem_budget_bytes: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            population: PopulationConfig::default(),
            transfer: TransferConfig::default(),
            lut_runs: 4,
            lut_warmup: 1,
            noise_sigma: 0.0,
            frontier_cache_cap: 256,
            frontier_mem_budget_bytes: 8 * 1024 * 1024,
        }
    }
}

/// One device cohort: the sharing unit for the transferred LUT and the
/// frontier cache.
pub struct Cohort {
    /// The quantisation cell.
    pub key: CohortKey,
    /// Canonical cohort id ([`CohortKey::id`]).
    pub id: String,
    /// Representative nominal profile every member's manager runs against.
    pub rep: Arc<DeviceProfile>,
    /// The cohort's transferred (and possibly probe-corrected) LUT.
    pub lut: Arc<Lut>,
    /// The cohort-shared, LRU-bounded frontier cache.
    pub cache: Arc<Mutex<FrontierCache>>,
    /// Member device indices, ascending.
    pub members: Vec<usize>,
    /// Per-engine transfer provenance at cohort level (distance /
    /// confidence are the worst member's).
    pub transfer: BTreeMap<EngineKind, EngineTransfer>,
    /// Cohort-local metrics sink (bounded histograms); the fleet-wide
    /// view is the merge of every cohort's — see [`Fleet::rollup`].
    pub telemetry: Arc<Telemetry>,
}

impl Cohort {
    /// True when any engine ran the probe fallback.
    pub fn probed(&self) -> bool {
        self.transfer.values().any(|t| t.probed)
    }

    /// Lowest per-engine transfer confidence.
    pub fn min_confidence(&self) -> f64 {
        self.transfer
            .values()
            .map(|t| t.confidence)
            .fold(f64::INFINITY, f64::min)
    }

    /// This cohort's cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.lock().unwrap().stats
    }

    /// Accounted bytes of this cohort's resident frontiers.
    pub fn resident_bytes(&self) -> u64 {
        self.cache.lock().unwrap().resident_bytes()
    }

    /// The per-cohort share of the fleet memory budget this cache enforces.
    pub fn mem_budget(&self) -> u64 {
        self.cache.lock().unwrap().mem_budget()
    }
}

/// A sampled device population organised into cohorts with shared
/// transferred LUTs and frontier caches.
pub struct Fleet {
    /// Construction parameters.
    pub cfg: FleetConfig,
    /// The sampled devices, by index.
    pub devices: Vec<SampledDevice>,
    /// Cohorts in canonical ([`CohortKey`]) order.
    pub cohorts: Vec<Cohort>,
    /// Device index → cohort index.
    pub device_cohort: Vec<usize>,
    /// Shared model registry.
    pub registry: Arc<Registry>,
    /// Attached flight recorder ([`Fleet::attach_recorder`]); fleet-level
    /// events (engine corrections) are emitted here when set.
    recorder: Option<Arc<FlightRecorder>>,
}

impl Fleet {
    /// Sample the population, measure the anchors, group cohorts, and
    /// transfer one LUT per cohort (probing low-confidence engines on the
    /// cohort's first member).
    pub fn build(registry: Arc<Registry>, cfg: FleetConfig) -> Result<Fleet> {
        let devices = population::sample_fleet(&cfg.population);
        let te = TransferEngine::from_archetypes(
            &registry, cfg.transfer.clone(), cfg.lut_runs, cfg.lut_warmup,
            cfg.noise_sigma)?;

        let mut groups: BTreeMap<CohortKey, Vec<usize>> = BTreeMap::new();
        for d in &devices {
            groups.entry(d.cohort_key()).or_default().push(d.index);
        }

        let mut cohorts = Vec::new();
        let mut device_cohort = vec![0usize; devices.len()];
        // Even split of the fleet-wide frontier memory budget: the LRU
        // byte bound every cohort-shared cache enforces.
        let per_cohort_budget = if cfg.frontier_mem_budget_bytes == 0 {
            0
        } else {
            (cfg.frontier_mem_budget_bytes / groups.len().max(1) as u64).max(1)
        };
        for (ci, (key, members)) in groups.into_iter().enumerate() {
            let rep = key.representative(&cfg.population);
            let mut tlut = te.predict(&rep)?;
            // Cohort confidence is the worst member's: the transfer must
            // hold for every device the shared LUT will decide for.
            let kinds: Vec<EngineKind> = tlut.engines.keys().copied().collect();
            for kind in kinds {
                let mut dist = tlut.engines[&kind].distance;
                for &m in &members {
                    let d = te
                        .nearest_distance(&devices[m].nominal, kind)
                        .ok_or_else(|| anyhow!("member {m} lacks {}",
                                               kind.name()))?;
                    dist = dist.max(d);
                }
                let conf = transfer::confidence(dist);
                {
                    let rec = tlut.engines.get_mut(&kind).unwrap();
                    rec.distance = dist;
                    rec.confidence = conf;
                }
                if conf < cfg.transfer.confidence_threshold {
                    let probe_on = members[0];
                    te.probe_engine(&devices[probe_on].profile, &mut tlut,
                                    kind)?;
                }
            }
            for &m in &members {
                device_cohort[m] = ci;
            }
            cohorts.push(Cohort {
                id: key.id(),
                rep: Arc::new(rep),
                lut: Arc::new(tlut.lut),
                cache: Arc::new(Mutex::new(
                    FrontierCache::new()
                        .with_cap(cfg.frontier_cache_cap)
                        .with_mem_budget(per_cohort_budget))),
                members,
                transfer: tlut.engines,
                telemetry: Arc::new(Telemetry::new()),
                key,
            });
        }
        Ok(Fleet { cfg, devices, cohorts, device_cohort, registry,
                   recorder: None })
    }

    /// Attach a flight recorder to every cohort's shared frontier cache
    /// (scope = cohort id) and emit each cohort's transfer provenance —
    /// a [`TraceEvent::CohortTransfer`] per cohort in canonical order,
    /// followed by a [`TraceEvent::ProbeFallback`] per probed engine.
    /// Recording never changes selections or cache behaviour.
    pub fn attach_recorder(&mut self, recorder: &Arc<FlightRecorder>) {
        self.recorder = Some(Arc::clone(recorder));
        for cohort in &self.cohorts {
            cohort
                .cache
                .lock()
                .unwrap()
                .set_recorder(Arc::clone(recorder), &cohort.id);
            recorder.emit(TraceEvent::CohortTransfer {
                cohort: cohort.id.clone(),
                members: cohort.members.len() as u64,
                min_confidence: round3(cohort.min_confidence()),
                probed: cohort.probed(),
            });
            for (kind, t) in &cohort.transfer {
                if t.probed {
                    recorder.emit(TraceEvent::ProbeFallback {
                        cohort: cohort.id.clone(),
                        engine: kind.name().to_string(),
                        probes: t.probes as u64,
                        correction: round3(t.correction),
                    });
                }
            }
        }
    }

    /// The fleet-wide telemetry rollup: every cohort's sink merged into
    /// one (counters add, latency histograms merge bucket-wise) — the
    /// population view stays `O(metrics × buckets)` no matter how many
    /// devices or samples fed the cohort sinks.
    pub fn rollup(&self) -> Telemetry {
        let total = Telemetry::new();
        for c in &self.cohorts {
            total.merge_from(&c.telemetry);
        }
        total
    }

    /// Run one SLO burn-rate check of `metric` over every cohort's
    /// telemetry rollup, in canonical cohort order.  Burning cohorts
    /// emit an [`TraceEvent::SloBurn`] through the attached recorder
    /// (burn rates rounded to the trace's 3-decimal precision) and are
    /// returned so callers can feed rollout gates
    /// ([`rollout::Rollout::observe_burn`]).  Abstentions and healthy
    /// cohorts stay silent — alerts, not heartbeats.
    pub fn check_burn(&self, monitor: &mut crate::telemetry::SloBurnMonitor,
                      metric: &str, now_us: u64)
                      -> Vec<(String, crate::telemetry::BurnSample)> {
        let mut burning = Vec::new();
        for c in &self.cohorts {
            let Some(s) = monitor.check(&c.id, &c.telemetry, metric, now_us)
            else {
                continue;
            };
            if !s.burning {
                continue;
            }
            if let Some(rec) = &self.recorder {
                rec.emit_at(now_us, TraceEvent::SloBurn {
                    scope: c.id.clone(),
                    metric: metric.to_string(),
                    window_us: s.window_us,
                    fast_burn: round3(s.fast_burn),
                    slow_burn: round3(s.slow_burn),
                    misses: s.misses,
                    samples: s.samples,
                });
            }
            burning.push((c.id.clone(), s));
        }
        burning
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True for an empty fleet.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// The cohort a device belongs to.
    pub fn cohort_of(&self, device_idx: usize) -> &Cohort {
        &self.cohorts[self.device_cohort[device_idx]]
    }

    /// The transferred-LUT selection for one device at the given
    /// conditions: a frontier walk over the device's cohort cache, exactly
    /// the [`RuntimeManager::best_under`] semantics (bucketed frontier;
    /// hard latency targets re-checked at the exact conditions).
    pub fn select(&self, device_idx: usize, objective: Objective,
                  space: &SearchSpace, conds: &Conditions) -> Result<Design> {
        let cohort = self.cohort_of(device_idx);
        let bucket = ConditionsBucket::of(conds);
        let ds = DesignSpace::new(&cohort.rep, &self.registry, &cohort.lut);
        let frontier = cohort.cache.lock().unwrap().frontier(
            &ds, objective, space, &bucket);
        crate::designspace::select_from_frontier(&frontier, &cohort.lut,
                                                 objective, conds)
            .map(|c| c.design.clone())
            .ok_or_else(|| {
                anyhow!("{}: no feasible design in cohort {}",
                        self.devices[device_idx].id, cohort.id)
            })
    }

    /// A [`RuntimeManager`] for one device, running against its cohort's
    /// representative profile, transferred LUT and shared frontier cache;
    /// the initial design is the cohort's idle-conditions selection.
    pub fn manager_for(&self, device_idx: usize, objective: Objective,
                       space: &SearchSpace) -> Result<RuntimeManager> {
        let initial =
            self.select(device_idx, objective, space, &Conditions::idle())?;
        let cohort = self.cohort_of(device_idx);
        Ok(RuntimeManager::new(
            Arc::clone(&cohort.rep),
            Arc::clone(&self.registry),
            Arc::clone(&cohort.lut),
            objective,
            space.clone(),
            initial,
        )
        .with_frontier_cache(Arc::clone(&cohort.cache)))
    }

    /// Full-profile oracle LUT of one device: the complete measurement
    /// sweep on the *true* profile — what per-device offline profiling
    /// would have produced, and what transferred selections are judged
    /// against.
    pub fn oracle_lut(&self, device_idx: usize) -> Result<Lut> {
        Measurer::new(&self.devices[device_idx].profile, &self.registry)
            .with_runs(self.cfg.lut_runs, self.cfg.lut_warmup)
            .with_noise_sigma(self.cfg.noise_sigma)
            .measure_all()
    }

    /// Apply a uniform per-engine latency correction (the probe
    /// fallback's shape: every latency statistic on `engine` × `factor`)
    /// to **every cohort's LUT**, carrying each cohort's shared frontier
    /// cache across the transition incrementally
    /// ([`FrontierCache::apply_delta`]) instead of cold-starting all of
    /// them.  Returns the aggregate delta outcome.  Member managers built
    /// before the correction still hold the old LUT `Arc`; push the new
    /// one with [`RuntimeManager::apply_lut_delta`] (idempotent on the
    /// shared caches).
    pub fn apply_engine_correction(&mut self, engine: EngineKind,
                                   factor: f64) -> DeltaOutcome {
        let mut total = DeltaOutcome::default();
        for ci in 0..self.cohorts.len() {
            total.absorb(self.apply_cohort_scale(ci, engine, factor));
        }
        // The per-cohort `FrontierDelta` events above come from the
        // caches themselves; this is the fleet-level aggregate.
        if let Some(rec) = &self.recorder {
            rec.emit(TraceEvent::Correction {
                engine: engine.name().to_string(),
                factor,
                updated: total.updated,
                points_touched: total.points_touched,
            });
        }
        total
    }

    /// Scale one cohort's LUT on `engine` by `factor` (the probe
    /// fallback's correction shape), carrying that cohort's shared
    /// frontier cache across the transition in place.  The per-cohort
    /// primitive behind [`Fleet::apply_engine_correction`], staged
    /// rollouts ([`rollout::Rollout`]) and residual feedback
    /// ([`feedback::FeedbackLoop`]).
    pub fn apply_cohort_scale(&mut self, cohort_idx: usize,
                              engine: EngineKind, factor: f64)
                              -> DeltaOutcome {
        let new_lut = Arc::new(
            self.cohorts[cohort_idx].lut.scaled_engine(engine, factor));
        let delta = LutDelta::engine_scale(engine, factor);
        self.swap_cohort_lut(cohort_idx, new_lut, &delta)
    }

    /// Replace one cohort's LUT with `new_lut`, carrying the cohort's
    /// shared frontier cache across the transition described by `delta`.
    /// Exact whenever `delta` covers every difference between the LUTs
    /// (rollback restores a snapshot this way: re-scoring reads the
    /// restored LUT directly, so carried frontiers and their scope
    /// fingerprints land bit-identical to the pre-transition state).
    pub fn swap_cohort_lut(&mut self, cohort_idx: usize, new_lut: Arc<Lut>,
                           delta: &LutDelta) -> DeltaOutcome {
        let cohort = &mut self.cohorts[cohort_idx];
        let outcome = {
            let old_ds = DesignSpace::new(&cohort.rep, &self.registry,
                                          &cohort.lut);
            let new_ds = DesignSpace::new(&cohort.rep, &self.registry,
                                          &new_lut);
            cohort.cache.lock().unwrap().apply_delta(&old_ds, &new_ds, delta)
        };
        cohort.lut = new_lut;
        outcome
    }

    /// Promote a cohort's first member to a measured anchor: replace the
    /// transferred LUT with a full measurement sweep of that device's
    /// *true* profile.  This is an undescribed LUT change, so the
    /// cohort's cached frontiers invalidate lazily on their next lookup
    /// (scope-fingerprint mismatch) and rebuild on demand.  Returns the
    /// measured device's id and the fresh LUT's entry count.
    pub fn re_anchor_cohort(&mut self, cohort_idx: usize)
                            -> Result<(String, usize)> {
        let member = self.cohorts[cohort_idx].members[0];
        let lut = self.oracle_lut(member)?;
        let entries = lut.len();
        self.cohorts[cohort_idx].lut = Arc::new(lut);
        Ok((self.devices[member].id.clone(), entries))
    }

    /// Accounted resident frontier bytes summed over every cohort cache.
    pub fn resident_bytes(&self) -> u64 {
        self.cohorts.iter().map(|c| c.resident_bytes()).sum()
    }

    /// Aggregate cache counters over every cohort.
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for c in &self.cohorts {
            let s = c.cache_stats();
            total.builds += s.builds;
            total.hits += s.hits;
            total.invalidations += s.invalidations;
            total.candidates_enumerated += s.candidates_enumerated;
            total.evictions += s.evictions;
            total.delta_updates += s.delta_updates;
            total.delta_points_touched += s.delta_points_touched;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;
    use crate::util::stats::Percentile;

    fn small_fleet(size: usize) -> Fleet {
        let cfg = FleetConfig {
            population: PopulationConfig { size, ..Default::default() },
            ..Default::default()
        };
        Fleet::build(Arc::new(fake_registry()), cfg).unwrap()
    }

    fn obj() -> Objective {
        Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }
    }

    #[test]
    fn build_partitions_every_device_into_one_cohort() {
        let fleet = small_fleet(48);
        assert_eq!(fleet.len(), 48);
        let covered: usize = fleet.cohorts.iter().map(|c| c.members.len()).sum();
        assert_eq!(covered, 48);
        assert!(fleet.cohorts.len() < 48);
        for (i, c) in fleet.cohorts.iter().enumerate() {
            for &m in &c.members {
                assert_eq!(fleet.device_cohort[m], i);
                assert_eq!(fleet.devices[m].cohort_key(), c.key);
            }
        }
    }

    #[test]
    fn cohort_sharing_amortises_frontier_builds() {
        let fleet = small_fleet(32);
        let space = SearchSpace::family("mobilenet_v2_100");
        for idx in 0..fleet.len() {
            fleet.select(idx, obj(), &space, &Conditions::idle()).unwrap();
        }
        let stats = fleet.cache_stats();
        assert_eq!(stats.builds, fleet.cohorts.len() as u64,
                   "one idle frontier per cohort");
        assert_eq!(stats.hits + stats.builds, fleet.len() as u64);
        assert!(stats.builds < fleet.len() as u64);
    }

    #[test]
    fn managers_share_their_cohort_cache() {
        // 64 devices quantise into ~21 cohorts (seed 77): even after the
        // idle bucket and one loaded bucket build per cohort, builds stay
        // well below the device count.
        let fleet = small_fleet(64);
        let space = SearchSpace::family("mobilenet_v2_100");
        let mut managers: Vec<RuntimeManager> = (0..fleet.len())
            .map(|i| fleet.manager_for(i, obj(), &space).unwrap())
            .collect();
        let builds_after_init = fleet.cache_stats().builds;
        // A shared load shift: every manager re-searches, but each cohort
        // builds the loaded bucket's frontier at most once.
        let mut conds = Conditions::idle();
        conds.loads.insert(EngineKind::Cpu, 2.0);
        for m in managers.iter_mut() {
            m.decide(10_000.0, &conds);
        }
        let stats = fleet.cache_stats();
        assert!(stats.builds <= builds_after_init + fleet.cohorts.len() as u64);
        assert!(stats.builds < fleet.len() as u64);
    }

    #[test]
    fn engine_correction_keeps_cohort_caches_warm() {
        let mut fleet = small_fleet(32);
        let space = SearchSpace::family("mobilenet_v2_100");
        for idx in 0..fleet.len() {
            fleet.select(idx, obj(), &space, &Conditions::idle()).unwrap();
        }
        let builds_before = fleet.cache_stats().builds;
        let out = fleet.apply_engine_correction(EngineKind::Cpu, 1.25);
        assert_eq!(out.updated, fleet.cohorts.len() as u64,
                   "every cohort's idle frontier carried in place");
        assert_eq!(out.dropped, 0);
        assert!(out.points_touched < out.rebuild_points,
                "delta {} !< rebuild {}", out.points_touched,
                out.rebuild_points);
        // Post-correction selections hit the carried frontiers — zero
        // rebuilds — and still equal a full search over the corrected LUT.
        for idx in 0..fleet.len() {
            let pick =
                fleet.select(idx, obj(), &space, &Conditions::idle()).unwrap();
            let cohort = fleet.cohort_of(idx);
            let ds = DesignSpace::new(&cohort.rep, &fleet.registry,
                                      &cohort.lut);
            let full = crate::designspace::rank(
                ds.enumerate(obj(), &space, &Conditions::idle()), obj());
            assert_eq!(pick, full[0].design);
        }
        assert_eq!(fleet.cache_stats().builds, builds_before,
                   "no cold start after the correction");
        assert!(fleet.resident_bytes() > 0);
    }

    #[test]
    fn recorder_captures_transfer_and_correction_events() {
        let mut fleet = small_fleet(32);
        let rec = Arc::new(FlightRecorder::new());
        fleet.attach_recorder(&rec);
        let transfers = rec
            .records()
            .iter()
            .filter(|r| matches!(r.event, TraceEvent::CohortTransfer { .. }))
            .count();
        assert_eq!(transfers, fleet.cohorts.len());
        let space = SearchSpace::family("mobilenet_v2_100");
        for idx in 0..fleet.len() {
            fleet.select(idx, obj(), &space, &Conditions::idle()).unwrap();
        }
        // Build/hit events mirror the cache counters exactly.
        let events = rec.records();
        let builds = events
            .iter()
            .filter(|r| matches!(r.event, TraceEvent::FrontierBuild { .. }))
            .count();
        let hits = events
            .iter()
            .filter(|r| matches!(r.event, TraceEvent::FrontierHit { .. }))
            .count();
        let stats = fleet.cache_stats();
        assert_eq!(builds as u64, stats.builds);
        assert_eq!(hits as u64, stats.hits);
        fleet.apply_engine_correction(EngineKind::Cpu, 1.25);
        assert!(rec
            .records()
            .iter()
            .any(|r| matches!(r.event, TraceEvent::Correction { .. })));
    }

    #[test]
    fn cohort_rollup_merges_sinks() {
        let fleet = small_fleet(16);
        for (i, c) in fleet.cohorts.iter().enumerate() {
            c.telemetry.incr("decisions");
            c.telemetry.record("regret_pct", 1.0 + i as f64);
        }
        let total = fleet.rollup();
        assert_eq!(total.counter("decisions"), fleet.cohorts.len() as u64);
        assert_eq!(total.stats("regret_pct").unwrap().n, fleet.cohorts.len());
    }

    #[test]
    fn oracle_lut_reflects_the_true_profile() {
        let fleet = small_fleet(8);
        let lut = fleet.oracle_lut(0).unwrap();
        let d = &fleet.devices[0];
        // Engine set matches the true device (e.g. no NNAPI entries after
        // an NPU drop).
        for k in lut.entries.keys() {
            assert!(d.profile.has_engine(k.engine));
        }
        assert!(!lut.is_empty());
    }
}
