//! Staged cohort rollouts: a versioned LUT-revision registry and a
//! canary state machine over [`Fleet`].
//!
//! The fleet layer makes the population *decide* like production, but a
//! control plane that can push a bad LUT revision to every cohort at
//! once is worse than no control plane.  This module gates revision
//! exposure the way real fleets do:
//!
//! * [`RevisionRegistry`] — monotone revision ids with a per-cohort
//!   live-assignment table; a cohort carries exactly one live revision
//!   (id 0 is the transferred baseline), and a second rollout cannot
//!   claim a cohort that already carries one.
//! * [`Rollout`] — the stage machine
//!   `Proposed → Canary → Widening(rung)* → Promoted | RolledBack`.
//!   Each stage applies the revision to a prefix of the canonical cohort
//!   order through the incremental frontier delta path
//!   ([`Fleet::apply_cohort_scale`]), snapshotting every treated
//!   cohort's LUT first.  Stage transitions are driven exclusively by
//!   [`CohortReport`] telemetry: observed decision regret on treated
//!   cohorts versus the untreated controls, and SLO-miss / deploy-fault
//!   rates versus the *same* cohorts' pre-canary baseline (reports
//!   ingested while still `Proposed`) — a difference-in-differences
//!   gate, because absolute miss rates are cohort-structural and the
//!   canary prefix is not a representative sample.  An opt-in tail gate
//!   ([`RolloutConfig::max_p99_ratio`]) additionally compares each
//!   treated cohort's live p99 — read from its bounded latency-histogram
//!   rollups — against the same cohort's pre-canary p99, catching
//!   revisions that keep the mean flat while growing a heavy tail.
//!   Minimum-sample guards and per-stage fresh-evidence resets apply
//!   throughout.
//!   Any gate breach rolls every treated cohort back onto its exact
//!   snapshot (bit-identical scoped fingerprints), carried through the
//!   same delta path so the shared frontier caches stay warm.
//!
//! Telemetry ingestion is defensive: duplicate `(cohort, seq)` reports
//! never double-count, reports tagged with a revision that is no longer
//! live on their cohort are rejected as stale, and a silent cohort holds
//! the stage forever — promotion requires affirmative evidence from
//! *every* treated cohort.
//!
//! Every transition is recorded as a [`TraceEvent::Rollout`] through the
//! fleet's attached flight recorder, so rollout causality is replayable
//! next to the adaptation and frontier events it perturbs.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::designspace::{DeltaOutcome, LutDelta};
use crate::device::EngineKind;
use crate::measurements::Lut;
use crate::telemetry::trace::TraceEvent;

use super::Fleet;

/// The revision id every cohort starts on: the transferred baseline LUT.
pub const BASELINE_REVISION: u64 = 0;

/// One versioned LUT revision: a uniform per-engine latency rescale of
/// whatever LUT a cohort currently carries (the same shape the probe
/// fallback and the residual feedback loop produce).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Revision {
    /// Monotone id issued by the [`RevisionRegistry`] (0 = baseline).
    pub id: u64,
    /// Engine the revision rescales.
    pub engine: EngineKind,
    /// Multiplicative latency factor the revision applies.
    pub factor: f64,
}

/// Monotone revision ids plus the per-cohort live-assignment table.
#[derive(Debug, Clone)]
pub struct RevisionRegistry {
    next: u64,
    revisions: BTreeMap<u64, Revision>,
    assigned: Vec<u64>,
}

impl RevisionRegistry {
    /// A registry for `cohorts` cohorts, all on [`BASELINE_REVISION`].
    pub fn new(cohorts: usize) -> Self {
        RevisionRegistry {
            next: 1,
            revisions: BTreeMap::new(),
            assigned: vec![BASELINE_REVISION; cohorts],
        }
    }

    /// Mint the next revision id for an engine-scale revision.
    pub fn register(&mut self, engine: EngineKind, factor: f64) -> Revision {
        let rev = Revision { id: self.next, engine, factor };
        self.next += 1;
        self.revisions.insert(rev.id, rev);
        rev
    }

    /// Look up a registered revision.
    pub fn get(&self, id: u64) -> Option<Revision> {
        self.revisions.get(&id).copied()
    }

    /// The revision currently live on a cohort (0 = baseline).
    pub fn live(&self, cohort: usize) -> u64 {
        self.assigned[cohort]
    }

    /// The full per-cohort assignment table.
    pub fn assigned(&self) -> &[u64] {
        &self.assigned
    }

    /// Cohorts currently carrying `id`.
    pub fn live_count(&self, id: u64) -> usize {
        self.assigned.iter().filter(|&&a| a == id).count()
    }

    fn assign(&mut self, cohort: usize, id: u64) {
        self.assigned[cohort] = id;
    }
}

/// Rollout stage machine states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolloutStage {
    /// Registered, nothing applied yet.
    Proposed,
    /// Live on the first ladder rung of cohorts.
    Canary,
    /// Live on rung `n` of the widening ladder (1-based).
    Widening(usize),
    /// Live fleet-wide; every gate passed at every rung.
    Promoted,
    /// Reverted; every treated cohort restored to its exact snapshot.
    RolledBack,
}

impl RolloutStage {
    /// Stable snake_case name (the trace `stage` field).
    pub fn name(&self) -> &'static str {
        match self {
            RolloutStage::Proposed => "proposed",
            RolloutStage::Canary => "canary",
            RolloutStage::Widening(_) => "widening",
            RolloutStage::Promoted => "promoted",
            RolloutStage::RolledBack => "rolled_back",
        }
    }
}

/// Gate thresholds and the widening ladder.
#[derive(Debug, Clone)]
pub struct RolloutConfig {
    /// Cumulative treated-cohort counts per rung, in canonical cohort
    /// order; past the last rung the next advance treats every cohort.
    pub ladder: Vec<usize>,
    /// Max tolerated (treated − control) mean decision regret, in pct
    /// points.
    pub max_regret_delta_pct: f64,
    /// Absolute mean-regret bound used when no control cohort remains
    /// (the final fleet-wide rung).
    pub max_abs_regret_pct: f64,
    /// Max tolerated SLO-miss rate increase of the treated cohorts over
    /// their own pre-canary baseline.
    pub max_slo_miss_delta: f64,
    /// Max tolerated deploy-fault rate increase of the treated cohorts
    /// over their own pre-canary baseline.
    pub max_fault_delta: f64,
    /// Minimum accepted samples per treated cohort per stage before the
    /// gates may be evaluated at all.
    pub min_samples: u64,
    /// Optional tail gate: max tolerated ratio of a treated cohort's
    /// current p99 over its own pre-canary p99, read under
    /// [`Self::p99_metric`] from the cohort's bounded latency histograms
    /// ([`crate::telemetry::Telemetry::stats`]) — the mean gates above
    /// cannot see a revision that keeps the average flat while growing a
    /// heavy tail.  `None` (the default) disables the gate.
    pub max_p99_ratio: Option<f64>,
    /// Telemetry metric the p99 gate reads.
    pub p99_metric: String,
    /// Optional burn-rate gate: max tolerated fast-window SLO burn rate
    /// ([`crate::telemetry::SloBurnMonitor`]) observed on a treated
    /// cohort since the last stage transition, fed via
    /// [`Rollout::observe_burn`] from the fleet's `slo_burn` alerts.
    /// The scalar gates compare means per evaluation round; this gate
    /// reacts to the alerting pipeline itself, so a cohort burning its
    /// error budget rolls the revision back even when round means stay
    /// inside the deltas.  `None` (the default) disables the gate.
    pub max_fast_burn: Option<f64>,
}

impl Default for RolloutConfig {
    fn default() -> Self {
        RolloutConfig {
            ladder: vec![4, 7, 14],
            max_regret_delta_pct: 2.0,
            max_abs_regret_pct: 5.0,
            max_slo_miss_delta: 0.1,
            max_fault_delta: 0.0,
            min_samples: 2,
            max_p99_ratio: None,
            p99_metric: "regret_pct".to_string(),
            max_fast_burn: None,
        }
    }
}

/// One cohort's telemetry report for one evaluation round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CohortReport {
    /// Reporting cohort index (canonical order).
    pub cohort: usize,
    /// Revision the cohort believes it is running.
    pub revision: u64,
    /// Per-cohort monotone report sequence number (the dedup key).
    pub seq: u64,
    /// Decision samples aggregated into this report.
    pub samples: u64,
    /// Sum of per-decision regret percentages over those samples.
    pub regret_pct_sum: f64,
    /// Decisions whose observed latency missed the SLO.
    pub slo_misses: u64,
    /// Decisions whose selected design was undeployable on the device.
    pub deploy_faults: u64,
}

/// What [`Rollout::ingest`] did with a report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IngestOutcome {
    /// Counted towards the gates.
    Accepted,
    /// `(cohort, seq)` already seen — discarded, never double-counted.
    Duplicate,
    /// Tagged with a revision that is not live on the cohort — discarded.
    Stale,
    /// Cohort index out of range — discarded.
    UnknownCohort,
}

/// What one [`Rollout::evaluate`] call decided.
#[derive(Debug, Clone, PartialEq)]
pub enum RolloutOutcome {
    /// Gates could not be evaluated (not live, or missing evidence).
    Held {
        /// Why the stage was held.
        reason: String,
    },
    /// Every gate passed; the revision widened to the next rung.
    Advanced {
        /// Stage entered.
        stage: RolloutStage,
        /// Treated cohorts after widening.
        treated: usize,
    },
    /// Every gate passed fleet-wide; the revision is the new baseline.
    Promoted,
    /// A gate breached; every treated cohort restored to its snapshot.
    RolledBack {
        /// The breached gate.
        reason: String,
    },
}

/// Accumulated gate evidence for one side (treated cohort or controls).
#[derive(Debug, Clone, Copy, Default)]
struct GateStats {
    samples: u64,
    regret_pct_sum: f64,
    slo_misses: u64,
    deploy_faults: u64,
}

impl GateStats {
    fn fold(&mut self, r: &CohortReport) {
        self.samples += r.samples;
        self.regret_pct_sum += r.regret_pct_sum;
        self.slo_misses += r.slo_misses;
        self.deploy_faults += r.deploy_faults;
    }

    fn regret_mean(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.regret_pct_sum / self.samples as f64
        }
    }

    fn slo_rate(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.slo_misses as f64 / self.samples as f64
        }
    }

    fn fault_rate(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.deploy_faults as f64 / self.samples as f64
        }
    }
}

/// The staged-rollout state machine shepherding one [`Revision`] across
/// a [`Fleet`].
#[derive(Debug)]
pub struct Rollout {
    cfg: RolloutConfig,
    revision: Revision,
    stage: RolloutStage,
    treated: Vec<usize>,
    snapshots: BTreeMap<usize, Arc<Lut>>,
    baseline: BTreeMap<usize, GateStats>,
    p99_baseline: BTreeMap<usize, f64>,
    treated_stats: BTreeMap<usize, GateStats>,
    control_stats: GateStats,
    /// Worst observed fast-window burn per cohort id since the last
    /// stage transition ([`Rollout::observe_burn`]).
    burn: BTreeMap<String, f64>,
    seen: BTreeSet<(usize, u64)>,
    duplicates: u64,
    stale: u64,
}

impl Rollout {
    /// A rollout for `revision` in stage [`RolloutStage::Proposed`].
    pub fn new(revision: Revision, cfg: RolloutConfig) -> Rollout {
        Rollout {
            cfg,
            revision,
            stage: RolloutStage::Proposed,
            treated: Vec::new(),
            snapshots: BTreeMap::new(),
            baseline: BTreeMap::new(),
            p99_baseline: BTreeMap::new(),
            treated_stats: BTreeMap::new(),
            control_stats: GateStats::default(),
            burn: BTreeMap::new(),
            seen: BTreeSet::new(),
            duplicates: 0,
            stale: 0,
        }
    }

    /// Current stage.
    pub fn stage(&self) -> RolloutStage {
        self.stage
    }

    /// The revision under rollout.
    pub fn revision(&self) -> Revision {
        self.revision
    }

    /// Cohorts currently (or, after a rollback, formerly) treated, in
    /// claim order (ascending canonical cohort index).
    pub fn treated(&self) -> &[usize] {
        &self.treated
    }

    /// Duplicate reports rejected so far.
    pub fn duplicates(&self) -> u64 {
        self.duplicates
    }

    /// Stale reports rejected so far.
    pub fn stale_reports(&self) -> u64 {
        self.stale
    }

    /// Apply the revision to the first ladder rung of cohorts (snapshot,
    /// scale through the delta path, assign) and enter
    /// [`RolloutStage::Canary`].  Fails without side effects if the
    /// rollout already left `Proposed` or any target cohort carries
    /// another live revision.
    pub fn begin_canary(&mut self, fleet: &mut Fleet,
                        reg: &mut RevisionRegistry)
                        -> Result<DeltaOutcome> {
        if self.stage != RolloutStage::Proposed {
            bail!("revision {} rollout already {}", self.revision.id,
                  self.stage.name());
        }
        let n = self
            .cfg
            .ladder
            .first()
            .copied()
            .unwrap_or(fleet.cohorts.len())
            .clamp(1, fleet.cohorts.len());
        for ci in 0..n {
            if reg.live(ci) != BASELINE_REVISION {
                bail!("cohort {} already carries live revision {}",
                      fleet.cohorts[ci].id, reg.live(ci));
            }
        }
        let out = self.extend_to(fleet, reg, n);
        self.stage = RolloutStage::Canary;
        self.emit_stage(fleet, self.treated.len() as u64, "");
        Ok(out)
    }

    /// Fold one telemetry report into the gate evidence.  Reports
    /// ingested while still [`RolloutStage::Proposed`] become the
    /// per-cohort pre-canary baseline the SLO and fault gates compare
    /// against.
    pub fn ingest(&mut self, report: CohortReport, reg: &RevisionRegistry)
                  -> IngestOutcome {
        if report.cohort >= reg.assigned().len() {
            return IngestOutcome::UnknownCohort;
        }
        if !self.seen.insert((report.cohort, report.seq)) {
            self.duplicates += 1;
            return IngestOutcome::Duplicate;
        }
        if report.revision != reg.live(report.cohort) {
            self.stale += 1;
            return IngestOutcome::Stale;
        }
        if self.stage == RolloutStage::Proposed {
            self.baseline.entry(report.cohort).or_default().fold(&report);
        } else if self.treated.contains(&report.cohort) {
            self.treated_stats
                .entry(report.cohort)
                .or_default()
                .fold(&report);
        } else {
            self.control_stats.fold(&report);
        }
        IngestOutcome::Accepted
    }

    /// Evaluate the gates on the evidence accepted since the last stage
    /// transition: hold on missing or thin evidence, roll back on any
    /// breach, otherwise widen one rung (or promote fleet-wide).
    pub fn evaluate(&mut self, fleet: &mut Fleet,
                    reg: &mut RevisionRegistry) -> RolloutOutcome {
        match self.stage {
            RolloutStage::Canary | RolloutStage::Widening(_) => {}
            _ => {
                return RolloutOutcome::Held {
                    reason: format!("stage_{}", self.stage.name()),
                }
            }
        }
        // Gate 0: affirmative fresh evidence from every treated cohort.
        for &ci in &self.treated {
            match self.treated_stats.get(&ci) {
                None => {
                    return self.hold(fleet,
                                     format!("missing_reports:{}",
                                             fleet.cohorts[ci].id));
                }
                Some(s) if s.samples < self.cfg.min_samples => {
                    return self.hold(fleet,
                                     format!("insufficient_samples:{}",
                                             fleet.cohorts[ci].id));
                }
                Some(_) => {}
            }
        }
        let mut treated = GateStats::default();
        for s in self.treated_stats.values() {
            treated.samples += s.samples;
            treated.regret_pct_sum += s.regret_pct_sum;
            treated.slo_misses += s.slo_misses;
            treated.deploy_faults += s.deploy_faults;
        }
        let control = self.control_stats;
        // SLO and fault gates are difference-in-differences: the treated
        // cohorts' current rates against the same cohorts' pre-canary
        // baseline.  With no baseline evidence the rates compare against
        // zero, which degrades to the conservative absolute gate.
        let mut base = GateStats::default();
        for &ci in &self.treated {
            if let Some(s) = self.baseline.get(&ci) {
                base.samples += s.samples;
                base.regret_pct_sum += s.regret_pct_sum;
                base.slo_misses += s.slo_misses;
                base.deploy_faults += s.deploy_faults;
            }
        }
        let breach = if control.samples > 0
            && treated.regret_mean() - control.regret_mean()
                > self.cfg.max_regret_delta_pct
        {
            Some(format!("regret_delta:{:.3}",
                         treated.regret_mean() - control.regret_mean()))
        } else if control.samples == 0
            && treated.regret_mean() > self.cfg.max_abs_regret_pct
        {
            Some(format!("regret_abs:{:.3}", treated.regret_mean()))
        } else if treated.slo_rate() - base.slo_rate()
            > self.cfg.max_slo_miss_delta
        {
            Some(format!("slo_delta:{:.3}",
                         treated.slo_rate() - base.slo_rate()))
        } else if treated.fault_rate() - base.fault_rate()
            > self.cfg.max_fault_delta
        {
            Some(format!("fault_delta:{:.3}",
                         treated.fault_rate() - base.fault_rate()))
        } else if let Some(reason) = self.p99_breach(fleet) {
            Some(reason)
        } else if let Some(reason) = self.burn_breach(fleet) {
            Some(reason)
        } else {
            None
        };
        if let Some(reason) = breach {
            return self.roll_back(fleet, reg, reason);
        }
        let all = fleet.cohorts.len();
        if self.treated.len() >= all {
            self.stage = RolloutStage::Promoted;
            self.snapshots.clear();
            self.emit_stage(fleet, all as u64, "");
            return RolloutOutcome::Promoted;
        }
        let next_rung = match self.stage {
            RolloutStage::Canary => 1,
            RolloutStage::Widening(k) => k + 1,
            _ => unreachable!("evaluate gated on live stages"),
        };
        let target = self
            .cfg
            .ladder
            .get(next_rung)
            .copied()
            .unwrap_or(all)
            .max(self.treated.len() + 1)
            .min(all);
        // A cohort can carry exactly one live revision: a conflicting
        // claim holds the widening instead of stacking revisions.
        for ci in 0..target {
            if !self.snapshots.contains_key(&ci)
                && reg.live(ci) != BASELINE_REVISION
            {
                return self.hold(fleet,
                                 format!("cohort_conflict:{}",
                                         fleet.cohorts[ci].id));
            }
        }
        self.extend_to(fleet, reg, target);
        self.stage = RolloutStage::Widening(next_rung);
        // Each stage requires fresh evidence at the new exposure.
        self.treated_stats.clear();
        self.control_stats = GateStats::default();
        self.burn.clear();
        self.emit_stage(fleet, self.treated.len() as u64, "");
        RolloutOutcome::Advanced {
            stage: self.stage,
            treated: self.treated.len(),
        }
    }

    /// The tail gate: the worst treated cohort's current p99 over its
    /// own pre-canary p99, from the per-cohort histogram rollups.  `None`
    /// when disabled, when no treated cohort has both sides sampled, or
    /// when every ratio is within the bound — cohorts without baseline
    /// samples are guarded by the scalar gates alone.
    fn p99_breach(&self, fleet: &Fleet) -> Option<String> {
        let limit = self.cfg.max_p99_ratio?;
        let mut worst: Option<f64> = None;
        for &ci in &self.treated {
            let Some(&base) = self.p99_baseline.get(&ci) else { continue };
            let Some(cur) =
                fleet.cohorts[ci].telemetry.stats(&self.cfg.p99_metric)
            else {
                continue;
            };
            if base <= 0.0 {
                continue;
            }
            let ratio = cur.p99 / base;
            if worst.map_or(true, |w| ratio > w) {
                worst = Some(ratio);
            }
        }
        let w = worst?;
        (w > limit).then(|| format!("p99_ratio:{w:.3}"))
    }

    /// Record one fast-window burn observation for a cohort (from the
    /// fleet's [`crate::fleet::Fleet::check_burn`] alerts); the gate
    /// keeps the worst value per cohort per stage.
    pub fn observe_burn(&mut self, cohort_id: &str, fast_burn: f64) {
        let e = self.burn.entry(cohort_id.to_string()).or_insert(0.0);
        if fast_burn > *e {
            *e = fast_burn;
        }
    }

    /// The burn gate: the worst fast-window burn observed on a treated
    /// cohort this stage.  `None` when disabled or when no treated
    /// cohort reported a burn alert.
    fn burn_breach(&self, fleet: &Fleet) -> Option<String> {
        let limit = self.cfg.max_fast_burn?;
        let mut worst: Option<f64> = None;
        for &ci in &self.treated {
            let Some(&b) = self.burn.get(&fleet.cohorts[ci].id) else {
                continue;
            };
            if worst.map_or(true, |w| b > w) {
                worst = Some(b);
            }
        }
        let w = worst?;
        (w > limit).then(|| format!("burn_rate:{w:.3}"))
    }

    fn extend_to(&mut self, fleet: &mut Fleet, reg: &mut RevisionRegistry,
                 n: usize) -> DeltaOutcome {
        let mut total = DeltaOutcome::default();
        for ci in 0..n {
            if self.snapshots.contains_key(&ci) {
                continue;
            }
            debug_assert_eq!(reg.live(ci), BASELINE_REVISION);
            self.snapshots.insert(ci, Arc::clone(&fleet.cohorts[ci].lut));
            // Pre-treatment p99 of the tail-gate metric, snapshotted the
            // moment the cohort is claimed.
            if let Some(s) =
                fleet.cohorts[ci].telemetry.stats(&self.cfg.p99_metric)
            {
                self.p99_baseline.insert(ci, s.p99);
            }
            total.absorb(fleet.apply_cohort_scale(ci, self.revision.engine,
                                                  self.revision.factor));
            reg.assign(ci, self.revision.id);
            self.treated.push(ci);
        }
        total
    }

    fn hold(&self, fleet: &Fleet, reason: String) -> RolloutOutcome {
        self.emit(fleet, "held", self.treated.len() as u64, &reason);
        RolloutOutcome::Held { reason }
    }

    fn roll_back(&mut self, fleet: &mut Fleet, reg: &mut RevisionRegistry,
                 reason: String) -> RolloutOutcome {
        // Restore each snapshot LUT verbatim, carrying the shared caches
        // across with the inverse engine-scale delta: re-scoring reads the
        // restored LUT directly, so the carried frontiers (and their scope
        // fingerprints) land bit-identical to the pre-canary state.
        let inverse = 1.0 / self.revision.factor;
        for &ci in &self.treated {
            let snap = Arc::clone(&self.snapshots[&ci]);
            let delta = LutDelta::engine_scale(self.revision.engine, inverse);
            fleet.swap_cohort_lut(ci, snap, &delta);
            reg.assign(ci, BASELINE_REVISION);
        }
        self.stage = RolloutStage::RolledBack;
        self.emit(fleet, "rolled_back", 0, &reason);
        RolloutOutcome::RolledBack { reason }
    }

    fn emit_stage(&self, fleet: &Fleet, cohorts: u64, detail: &str) {
        self.emit(fleet, self.stage.name(), cohorts, detail);
    }

    fn emit(&self, fleet: &Fleet, stage: &str, cohorts: u64, detail: &str) {
        if let Some(rec) = &fleet.recorder {
            rec.emit(TraceEvent::Rollout {
                revision: self.revision.id,
                stage: stage.to_string(),
                cohorts,
                detail: detail.to_string(),
            });
        }
    }
}
