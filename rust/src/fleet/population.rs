//! Seeded device-population sampling: reproducible fleets of thousands of
//! simulated handsets grown from the Table I archetypes.
//!
//! Real deployments face *vast* system heterogeneity (paper §I/§II;
//! Almeida et al. 2021 count thousands of distinct SoC/thermal/memory
//! configurations in the wild).  This module models that spread as
//! deterministic perturbations of the three calibrated archetype profiles
//! along five axes:
//!
//! * **peak FLOPS** — per-engine silicon/bin spread (log-uniform),
//! * **memory bandwidth** — per-engine DRAM/bus spread (log-uniform),
//! * **thermal capacity** — device-wide heat-dissipation spread applied to
//!   every engine's `heat_per_ms` (a roomier chassis heats slower),
//! * **memory capacity** — device-wide budget spread,
//! * **engine availability** — a fraction of mid/high-tier units ship
//!   without a usable NNAPI path (vendor HAL missing or blocklisted).
//!
//! On top of the *observable* (spec-sheet) spread, every engine carries a
//! hidden **latent efficiency** factor — driver quality, firmware, memory
//! timings — that perturbs its true throughput but is invisible to any
//! analytical model.  Cross-device LUT transfer ([`super::transfer`]) can
//! scale away the spec-sheet spread exactly; the latent factor is exactly
//! what its probe fallback exists to recover.
//!
//! Sampling is bit-reproducible: each device draws from its own
//! [`crate::util::rng::Rng`] stream seeded from `(fleet seed, index)`, so
//! fleets are stable across runs, platforms and the independent Python
//! oracle (`python/golden_fleetbench.py`).

use crate::device::profiles::{samsung_a71, samsung_s20_fe, sony_c5};
use crate::device::{DeviceProfile, EngineKind};
use crate::util::rng::Rng;

/// The archetype names a population is grown from, in sampling order.
pub const ARCHETYPES: [&str; 3] = ["sony_c5", "samsung_a71", "samsung_s20_fe"];

/// The archetype profile for a [`ARCHETYPES`] name.
pub fn archetype_profile(name: &str) -> DeviceProfile {
    match name {
        "sony_c5" => sony_c5(),
        "samsung_a71" => samsung_a71(),
        _ => samsung_s20_fe(),
    }
}

/// Log-spread population parameters.  Every factor is sampled log-uniform:
/// `exp(U(-spread, spread))`.
#[derive(Debug, Clone)]
pub struct PopulationConfig {
    /// Fleet size.
    pub size: usize,
    /// Fleet seed; equal seeds give bit-identical fleets.
    pub seed: u64,
    /// Per-engine peak-FLOPS log-spread (observable).
    pub flops_log_spread: f64,
    /// Per-engine memory-bandwidth log-spread (observable).
    pub bw_log_spread: f64,
    /// Device-wide thermal-capacity log-spread (observable; divides
    /// `heat_per_ms`).
    pub thermal_log_spread: f64,
    /// Device-wide memory-budget log-spread (observable).
    pub mem_log_spread: f64,
    /// Per-engine *latent* efficiency log-spread (hidden from transfer).
    pub latent_log_spread: f64,
    /// Probability that a unit with an NPU archetype ships without a
    /// usable NNAPI path.
    pub npu_drop_prob: f64,
}

impl Default for PopulationConfig {
    fn default() -> Self {
        PopulationConfig {
            size: 200,
            seed: 77,
            flops_log_spread: 0.30,
            bw_log_spread: 0.15,
            thermal_log_spread: 0.20,
            mem_log_spread: 0.15,
            latent_log_spread: 0.10,
            npu_drop_prob: 0.15,
        }
    }
}

/// The sampled axis values of one engine on one device.
#[derive(Debug, Clone, Copy)]
pub struct EngineAxes {
    /// Which engine the axes perturb.
    pub kind: EngineKind,
    /// Observable log peak-FLOPS factor.
    pub flops_ln: f64,
    /// Observable log memory-bandwidth factor.
    pub bw_ln: f64,
    /// Hidden log efficiency factor (true profile only).
    pub latent_ln: f64,
}

/// One device of a sampled fleet.
///
/// `nominal` is the *spec-sheet* view — what a cross-device latency
/// predictor is allowed to know.  `profile` is the *true* device — the
/// nominal specs with the hidden latent efficiency folded into every
/// engine's throughput and bandwidth; measurements (oracle LUTs, probe
/// micro-profiles) run against it.  Both keep the archetype's `name`, so
/// family-level NNAPI op-support penalties keep applying.
#[derive(Debug, Clone)]
pub struct SampledDevice {
    /// Stable fleet-local id, `d0000`…
    pub id: String,
    /// Index in the fleet (drives the per-device RNG stream).
    pub index: usize,
    /// Archetype the device was grown from.
    pub archetype: &'static str,
    /// Spec-sheet profile (no latent factors).
    pub nominal: DeviceProfile,
    /// True profile (latent factors folded in); the measurable device.
    pub profile: DeviceProfile,
    /// Per-engine sampled axes, in the archetype's engine order (dropped
    /// engines excluded).
    pub axes: Vec<EngineAxes>,
    /// Device-wide log thermal-capacity factor (divides `heat_per_ms`).
    pub thermal_ln: f64,
    /// Device-wide log memory-budget factor.
    pub mem_ln: f64,
    /// True when the archetype's NPU was dropped (engine-availability
    /// axis).
    pub dropped_npu: bool,
}

impl SampledDevice {
    /// True when the device exposes an NNAPI path.
    pub fn has_npu(&self) -> bool {
        self.profile.has_engine(EngineKind::Npu)
    }

    /// The cohort this device quantises into.
    pub fn cohort_key(&self) -> CohortKey {
        CohortKey {
            archetype: self.archetype,
            engines: self.axes.iter().map(|a| a.kind).collect(),
            flops_hi: self.axes.iter().map(|a| a.flops_ln >= 0.0).collect(),
        }
    }
}

/// FNV-1a over the fleet seed and device index: each device gets its own
/// deterministic RNG stream, independent of fleet size.
pub fn device_seed(seed: u64, index: usize) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in seed.to_le_bytes().into_iter().chain((index as u64).to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Scale an archetype profile along sampled axes into a device profile.
/// `latent` selects whether each engine's hidden efficiency is folded in
/// (the true profile) or not (the nominal spec-sheet view).
pub fn scaled_profile(archetype: &DeviceProfile, axes: &[EngineAxes],
                      thermal_ln: f64, mem_ln: f64, latent: bool)
                      -> DeviceProfile {
    let mut p = archetype.clone();
    p.engines = axes
        .iter()
        .map(|a| {
            let mut e = archetype
                .engine(a.kind)
                .expect("axis for an engine the archetype lacks")
                .clone();
            e.peak_gflops_fp32 *= a.flops_ln.exp();
            e.mem_bw_gbps *= a.bw_ln.exp();
            if latent {
                e.peak_gflops_fp32 *= a.latent_ln.exp();
                e.mem_bw_gbps *= a.latent_ln.exp();
            }
            e.thermal.heat_per_ms *= (-thermal_ln).exp();
            e
        })
        .collect();
    p.mem_budget_bytes =
        (archetype.mem_budget_bytes as f64 * mem_ln.exp()) as u64;
    p
}

/// Sample one device of the fleet.  The RNG draw order is part of the
/// format (mirrored by the Python oracle): archetype, NPU-drop, then per
/// archetype engine (flops, bandwidth, latent), then thermal, then memory.
pub fn sample_device(cfg: &PopulationConfig, index: usize) -> SampledDevice {
    let mut rng = Rng::new(device_seed(cfg.seed, index));
    let archetype = ARCHETYPES[rng.below(ARCHETYPES.len())];
    let base = archetype_profile(archetype);
    let drop_npu = rng.f64() < cfg.npu_drop_prob;
    let mut axes = Vec::new();
    let mut dropped = false;
    for spec in &base.engines {
        let a = EngineAxes {
            kind: spec.kind,
            flops_ln: rng.range(-cfg.flops_log_spread, cfg.flops_log_spread),
            bw_ln: rng.range(-cfg.bw_log_spread, cfg.bw_log_spread),
            latent_ln: rng.range(-cfg.latent_log_spread,
                                 cfg.latent_log_spread),
        };
        if spec.kind == EngineKind::Npu && drop_npu {
            dropped = true;
            continue;
        }
        axes.push(a);
    }
    let thermal_ln = rng.range(-cfg.thermal_log_spread, cfg.thermal_log_spread);
    let mem_ln = rng.range(-cfg.mem_log_spread, cfg.mem_log_spread);
    SampledDevice {
        id: format!("d{index:04}"),
        index,
        archetype,
        nominal: scaled_profile(&base, &axes, thermal_ln, mem_ln, false),
        profile: scaled_profile(&base, &axes, thermal_ln, mem_ln, true),
        axes,
        thermal_ln,
        mem_ln,
        dropped_npu: dropped,
    }
}

/// Sample the whole fleet.
pub fn sample_fleet(cfg: &PopulationConfig) -> Vec<SampledDevice> {
    (0..cfg.size).map(|i| sample_device(cfg, i)).collect()
}

/// A device cohort: the quantisation cell the fleet layer shares one
/// transferred LUT and one frontier cache across.
///
/// Cohorts quantise the *observable* axes only — archetype, surviving
/// engine set, and the sign of each engine's log peak-FLOPS factor (a
/// two-level half-spread quantisation).  Bandwidth/thermal sit at the
/// archetype centre of the representative and memory is represented at
/// the *floor* of its spread, so a variant the representative admits fits
/// every member (conservative memory admission).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CohortKey {
    /// Archetype of every member.
    pub archetype: &'static str,
    /// Surviving engines, in archetype order.
    pub engines: Vec<EngineKind>,
    /// Per engine: log peak-FLOPS factor ≥ 0 (upper half of the spread).
    pub flops_hi: Vec<bool>,
}

impl CohortKey {
    /// Canonical id, e.g. `samsung_a71|cpu+gpu+nnapi|f=+-+`.
    pub fn id(&self) -> String {
        let engines: Vec<&str> = self.engines.iter().map(|e| e.name()).collect();
        let signs: String = self
            .flops_hi
            .iter()
            .map(|&h| if h { '+' } else { '-' })
            .collect();
        format!("{}|{}|f={}", self.archetype, engines.join("+"), signs)
    }

    /// The cohort's representative *nominal* profile: each engine's peak
    /// FLOPS at the centre of its half-spread (`exp(±spread/2)`),
    /// bandwidth and thermal at the archetype centre, memory at the floor
    /// of the spread (conservative admission).
    pub fn representative(&self, cfg: &PopulationConfig) -> DeviceProfile {
        let base = archetype_profile(self.archetype);
        let axes: Vec<EngineAxes> = self
            .engines
            .iter()
            .zip(&self.flops_hi)
            .map(|(&kind, &hi)| EngineAxes {
                kind,
                flops_ln: if hi {
                    cfg.flops_log_spread / 2.0
                } else {
                    -cfg.flops_log_spread / 2.0
                },
                bw_ln: 0.0,
                latent_ln: 0.0,
            })
            .collect();
        scaled_profile(&base, &axes, 0.0, -cfg.mem_log_spread, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic_and_seed_sensitive() {
        let cfg = PopulationConfig { size: 16, ..Default::default() };
        let a = sample_fleet(&cfg);
        let b = sample_fleet(&cfg);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.archetype, y.archetype);
            assert_eq!(x.cohort_key(), y.cohort_key());
            assert_eq!(x.profile.mem_budget_bytes, y.profile.mem_budget_bytes);
        }
        let other = sample_fleet(&PopulationConfig { seed: 78, ..cfg });
        assert!(a.iter().zip(&other).any(|(x, y)| {
            x.archetype != y.archetype
                || x.profile.mem_budget_bytes != y.profile.mem_budget_bytes
        }));
    }

    #[test]
    fn perturbations_stay_within_spread() {
        let cfg = PopulationConfig { size: 64, ..Default::default() };
        for d in sample_fleet(&cfg) {
            let base = archetype_profile(d.archetype);
            for a in &d.axes {
                assert!(a.flops_ln.abs() <= cfg.flops_log_spread);
                let nom = d.nominal.engine(a.kind).unwrap().peak_gflops_fp32;
                let arch = base.engine(a.kind).unwrap().peak_gflops_fp32;
                let lo = arch * (-cfg.flops_log_spread).exp();
                let hi = arch * cfg.flops_log_spread.exp();
                assert!(nom >= lo * (1.0 - 1e-12) && nom <= hi * (1.0 + 1e-12));
            }
        }
    }

    #[test]
    fn latent_folds_only_into_true_profile() {
        let cfg = PopulationConfig { size: 64, ..Default::default() };
        for d in sample_fleet(&cfg) {
            for a in &d.axes {
                let nom = d.nominal.engine(a.kind).unwrap();
                let tru = d.profile.engine(a.kind).unwrap();
                let expect = nom.peak_gflops_fp32 * a.latent_ln.exp();
                assert!((tru.peak_gflops_fp32 - expect).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn npu_drop_and_cohort_engines_agree() {
        let cfg = PopulationConfig { size: 128, ..Default::default() };
        let fleet = sample_fleet(&cfg);
        assert!(fleet.iter().any(|d| d.dropped_npu), "expect some NPU drops");
        for d in &fleet {
            let key = d.cohort_key();
            assert_eq!(key.engines.contains(&EngineKind::Npu), d.has_npu());
            if d.archetype == "sony_c5" {
                assert!(!d.has_npu());
            }
            // The representative exposes exactly the member engine set and
            // never admits more memory than the member has.
            let rep = key.representative(&cfg);
            assert_eq!(rep.engines.len(), d.profile.engines.len());
            assert!(rep.mem_budget_bytes <= d.profile.mem_budget_bytes);
        }
    }

    #[test]
    fn cohorts_far_fewer_than_devices() {
        let cfg = PopulationConfig { size: 200, ..Default::default() };
        let fleet = sample_fleet(&cfg);
        let cohorts: std::collections::BTreeSet<CohortKey> =
            fleet.iter().map(|d| d.cohort_key()).collect();
        assert!(cohorts.len() < fleet.len() / 4,
                "{} cohorts for {} devices", cohorts.len(), fleet.len());
    }
}
