//! Use-case configuration: the user-facing spec of performance objectives
//! (paper §III-D, `o_i = <P, max/min/val(stat)>`), parsed from JSON files or
//! CLI shorthands into the optimizer's `Objective` + `SearchSpace`.

use anyhow::{bail, Context, Result};

use crate::device::EngineKind;
use crate::model::Precision;
use crate::optimizer::{Objective, SearchSpace};
use crate::util::json::{self, Value};
use crate::util::stats::Percentile;

/// A fully-specified use case.
#[derive(Debug, Clone)]
pub struct UseCase {
    /// Human-readable use-case name.
    pub name: String,
    /// Target device profile name.
    pub device: String,
    /// The performance objective o_i.
    pub objective: Objective,
    /// Candidate-space restrictions.
    pub space: SearchSpace,
    /// Camera/source frame rate (frames/s).
    pub camera_fps: f64,
}

impl UseCase {
    /// Parse from JSON, e.g.
    /// `{"name":"ai_camera","device":"samsung_a71",
    ///   "objective":{"kind":"max_fps","epsilon":0.015},
    ///   "family":"mobilenet_v2_100","camera_fps":30}`
    pub fn from_json(v: &Value) -> Result<Self> {
        let objective = parse_objective(v.req("objective")?)?;
        let mut space = SearchSpace::default();
        if let Some(f) = v.get("family") {
            space.family = Some(f.as_str()?.to_string());
        }
        if let Some(es) = v.get("engines") {
            let mut engines = Vec::new();
            for e in es.as_arr()? {
                engines.push(EngineKind::parse(e.as_str()?)?);
            }
            space.engines = Some(engines);
        }
        if let Some(ps) = v.get("precisions") {
            let mut precisions = Vec::new();
            for p in ps.as_arr()? {
                precisions.push(Precision::parse(p.as_str()?)?);
            }
            space.precisions = Some(precisions);
        }
        if let Some(r) = v.get("recognition_rate") {
            space.recognition_rate = Some(r.as_f64()?);
        }
        Ok(UseCase {
            name: v.get("name").and_then(|x| x.as_str().ok().map(String::from))
                .unwrap_or_else(|| "unnamed".into()),
            device: v.req("device")?.as_str()?.to_string(),
            objective,
            space,
            camera_fps: v.get("camera_fps").map_or(Ok(30.0), |x| x.as_f64())?,
        })
    }

    /// Parse a use-case from JSON text.
    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&json::parse(text).context("parsing use-case JSON")?)
    }

    /// Parse a use-case from a JSON file.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Self::from_json_str(&text)
    }
}

fn parse_objective(v: &Value) -> Result<Objective> {
    let kind = v.req("kind")?.as_str()?;
    let stat = match v.get("stat") {
        Some(s) => Percentile::parse(s.as_str()?)?,
        None => Percentile::Avg,
    };
    Ok(match kind {
        // Eq. 3
        "max_fps" => Objective::MaxFps {
            epsilon: v.get("epsilon").map_or(Ok(0.015), |x| x.as_f64())?,
        },
        // Eq. 4
        "target_latency" => Objective::TargetLatency {
            t_target_ms: v.req("t_target_ms")?.as_f64()?,
            stat,
        },
        // Eq. 5
        "max_acc_max_fps" => Objective::MaxAccMaxFps {
            w_fps: v.get("w_fps").map_or(Ok(1.0), |x| x.as_f64())?,
        },
        // Fig 3-6 evaluation objective
        "min_latency" => Objective::MinLatency {
            stat,
            epsilon: v.get("epsilon").map_or(Ok(0.015), |x| x.as_f64())?,
        },
        other => bail!("unknown objective kind `{other}`"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_max_fps_use_case() {
        let uc = UseCase::from_json_str(
            r#"{"name":"cam","device":"samsung_a71",
                "objective":{"kind":"max_fps","epsilon":0.02},
                "family":"mobilenet_v2_100","camera_fps":24}"#,
        ).unwrap();
        assert_eq!(uc.name, "cam");
        assert_eq!(uc.camera_fps, 24.0);
        assert!(matches!(uc.objective, Objective::MaxFps { epsilon } if epsilon == 0.02));
        assert_eq!(uc.space.family.as_deref(), Some("mobilenet_v2_100"));
    }

    #[test]
    fn parses_target_latency_with_stat() {
        let uc = UseCase::from_json_str(
            r#"{"device":"sony_c5",
                "objective":{"kind":"target_latency","t_target_ms":5,"stat":"p90"}}"#,
        ).unwrap();
        assert!(matches!(
            uc.objective,
            Objective::TargetLatency { t_target_ms, stat: Percentile::P90 }
                if t_target_ms == 5.0
        ));
        assert_eq!(uc.name, "unnamed");
    }

    #[test]
    fn parses_engine_and_precision_restrictions() {
        let uc = UseCase::from_json_str(
            r#"{"device":"samsung_s20_fe",
                "objective":{"kind":"min_latency"},
                "engines":["cpu","gpu"],"precisions":["int8"],
                "recognition_rate":0.5}"#,
        ).unwrap();
        assert_eq!(uc.space.engines.as_ref().unwrap().len(), 2);
        assert_eq!(uc.space.precisions.as_ref().unwrap(),
                   &[Precision::Int8]);
        assert_eq!(uc.space.recognition_rate, Some(0.5));
    }

    #[test]
    fn rejects_unknown_objective() {
        let r = UseCase::from_json_str(
            r#"{"device":"x","objective":{"kind":"max_energy"}}"#);
        assert!(r.is_err());
    }

    #[test]
    fn missing_target_latency_value_errors() {
        let r = UseCase::from_json_str(
            r#"{"device":"x","objective":{"kind":"target_latency"}}"#);
        assert!(r.is_err());
    }
}
