//! The unified design-space layer (CARIn-style, Panopoulos et al. 2024):
//! one home for every σ-space search in the system.
//!
//! Three layers used to re-enumerate the full design space with their own
//! near-copies of the scoring loop on every adaptation event —
//! [`crate::optimizer`] (offline System Optimisation),
//! [`crate::scheduler::joint`] (the multi-app σ-vector search) and
//! [`crate::manager`] (`best_under` re-search).  This module factors the
//! common machinery out:
//!
//! * [`DesignSpace`] — lazily enumerates [`Candidate`]s from
//!   `Registry × DeviceProfile × Lut` with constraint *pre-filtering*
//!   (memory budget, engine availability, deployable-latency bound, the
//!   objective's ε-accuracy constraint), scoring latencies through the one
//!   canonical scorer [`crate::manager::adjusted_latency`].
//! * [`rank`] — the shared selection order: objective score first, then a
//!   canonical tie chain (energy ↑, latency ↑, accuracy ↓, recognition
//!   rate ↓, memory ↑, LUT key).  Every search layer selects with this
//!   exact total order, which is what makes frontier-walk selection
//!   *provably* equal to full-search selection (see [`frontier`]).
//! * [`frontier`] — Pareto frontiers over (latency, accuracy, energy),
//!   sliced by the resource dimensions (engine, recognition rate,
//!   threads), cached per (objective + space, conditions-bucket) and
//!   invalidated when the LUT or registry changes, so runtime
//!   re-adaptation walks O(frontier) points instead of re-scoring the
//!   O(space) enumeration per event.

pub mod frontier;

pub use frontier::{dominates, scoped_fingerprint, CacheStats,
                   ConditionsBucket, DeltaOutcome, FrontierCache, LutDelta,
                   ParetoFrontier, FRONTIER_CACHE_DEFAULT_CAP,
                   FRONTIER_BASE_BYTES, FRONTIER_POINT_BYTES};

use std::cmp::Ordering;

use crate::device::DeviceProfile;
use crate::manager::{adjusted_latency, Conditions};
use crate::measurements::{entry_energy_mj, Lut, LutKey};
use crate::model::{Precision, Registry};
use crate::optimizer::{Design, HwConfig, Objective, SearchSpace, RECOGNITION_RATES};
use crate::util::stats::Percentile;

/// One evaluated design σ with the metric vector every search layer reads.
/// `latency_ms`/`avg_latency_ms`/`fps` are condition-adjusted (through
/// [`crate::manager::adjusted_latency`]); `energy_mj` and `mem_bytes` are
/// static per-design properties.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// The design these metrics describe.
    pub design: Design,
    /// T: latency statistic targeted by the objective (ms), adjusted for
    /// the enumeration conditions.
    pub latency_ms: f64,
    /// Condition-adjusted average latency (drives fps regardless of the
    /// targeted statistic).
    pub avg_latency_ms: f64,
    /// fps: effective processed frames/s at recognition rate r.
    pub fps: f64,
    /// mem: working-set bytes.
    pub mem_bytes: u64,
    /// a: accuracy of the variant.
    pub accuracy: f64,
    /// First-order per-inference energy estimate at idle conditions
    /// ([`crate::perf::energy_proxy_mj`], summed per stage for
    /// partitioned plans); a static design property used as a Pareto
    /// dimension and as the leading tie-breaker.
    pub energy_mj: f64,
    /// Objective score (higher is better, across all objectives); 0 until
    /// [`rank`] assigns it.
    pub score: f64,
}

/// Normalisation constants for the weighted-sum objective (Eq. 5): the
/// maxima observed over the candidate set.  Dominance preserves both
/// maxima, so norms computed over a Pareto frontier equal norms computed
/// over the full enumerated space — weighted-sum selection from the
/// frontier stays exact.
#[derive(Debug, Clone, Copy)]
pub struct Norms {
    /// Max effective fps over the candidates.
    pub fps_max: f64,
    /// Max accuracy over the candidates.
    pub a_max: f64,
}

impl Norms {
    /// The maxima over a candidate set.
    pub fn of(cands: &[Candidate]) -> Self {
        Norms {
            fps_max: cands.iter().map(|c| c.fps).fold(f64::MIN, f64::max),
            a_max: cands.iter().map(|c| c.accuracy).fold(f64::MIN, f64::max),
        }
    }
}

/// The unified design space: every valid σ = <m_ref, t, hw> the measured
/// LUT supports on this device.
pub struct DesignSpace<'a> {
    /// Target device.
    pub device: &'a DeviceProfile,
    /// Model space M.
    pub registry: &'a Registry,
    /// Device measurements driving every score.
    pub lut: &'a Lut,
    /// Camera/source frame rate bounding effective fps.
    pub camera_fps: f64,
}

impl<'a> DesignSpace<'a> {
    /// A design space over (device, registry, LUT) at the default 30 fps
    /// camera rate (matching [`crate::optimizer::Optimizer::new`]).
    pub fn new(device: &'a DeviceProfile, registry: &'a Registry, lut: &'a Lut)
               -> Self {
        DesignSpace { device, registry, lut, camera_fps: 30.0 }
    }

    /// Override the camera/source frame rate.
    pub fn with_camera_fps(mut self, fps: f64) -> Self {
        self.camera_fps = fps;
        self
    }

    /// Reference accuracy a_ref for a family: its FP32 (identity-
    /// transformation) variant.
    pub fn reference_accuracy(&self, family: &str) -> Option<f64> {
        self.registry
            .find(family, Precision::Fp32, 1)
            .map(|v| v.accuracy)
    }

    /// Enumerate every candidate admitted by the constraint pre-filter:
    /// the restriction `space`, the device memory budget, engine
    /// availability, the sustained-deployability latency bound (paper
    /// Fig 4) and the objective's ε-accuracy constraint where it carries
    /// one.  Latencies are condition-adjusted through the single scorer
    /// [`adjusted_latency`]; `Conditions::idle()` reproduces the offline
    /// enumeration exactly.
    pub fn enumerate(&self, objective: Objective, space: &SearchSpace,
                     conds: &Conditions) -> Vec<Candidate> {
        self.enumerate_where(objective, space, conds, |_| true)
    }

    /// [`Self::enumerate`] restricted to LUT keys satisfying `pred` — the
    /// incremental frontier maintenance path re-enumerates only the
    /// (engine, threads) slices a LUT delta touched.  With `|_| true` this
    /// is exactly `enumerate` (same key order, same filters, same
    /// arithmetic), which is what keeps the delta path bit-identical to a
    /// full rebuild.
    pub fn enumerate_where<F>(&self, objective: Objective, space: &SearchSpace,
                              conds: &Conditions, pred: F) -> Vec<Candidate>
    where
        F: Fn(&LutKey) -> bool,
    {
        let fixed_rate = [space.recognition_rate.unwrap_or(0.0)];
        let rates: &[f64] = if space.recognition_rate.is_some() {
            &fixed_rate
        } else {
            &RECOGNITION_RATES
        };
        let mut out = Vec::new();
        for key in self.lut.entries.keys() {
            if !pred(key) || !self.entry_admitted(objective, space, key) {
                continue;
            }
            for &r in rates {
                if let Some(c) = self.eval_candidate(objective, space, conds,
                                                     key, r) {
                    out.push(c);
                }
            }
        }
        out
    }

    /// The constraint pre-filter for one LUT key: the restriction `space`,
    /// engine availability, the device memory budget, the sustained-
    /// deployability latency bound (paper Fig 4) and the objective's
    /// ε-accuracy constraint where it carries one.
    fn entry_admitted(&self, objective: Objective, space: &SearchSpace,
                      key: &LutKey) -> bool {
        let Some(entry) = self.lut.get(key) else {
            return false;
        };
        if !space.admits(self.registry, key) {
            return false;
        }
        // Engine availability: a LUT loaded from disk may carry entries
        // for engines this device does not expose.  A partitioned key
        // occupies every engine of its pipeline, so all must exist.
        if key.plan
              .engines(key.engine)
              .iter()
              .any(|e| self.device.engine(*e).is_none()) {
            return false;
        }
        let v = self.registry.get(&key.variant).unwrap();
        // Deployability (paper Fig 4: overheating / >=5 s lag models are
        // not deployable): memory budget + sustained-latency bound.  The
        // entry's own footprint covers the plan's boundary-activation
        // buffers on top of the variant working set (equal to
        // `perf::fits_memory` for monolithic entries).
        if entry.mem_bytes > self.device.mem_budget_bytes {
            return false;
        }
        if entry.latency.avg > self.device.max_deployable_latency_ms {
            return false;
        }
        // ε-constraint on accuracy where the objective carries one.
        let eps = match objective {
            Objective::MaxFps { epsilon } => Some(epsilon),
            Objective::MinLatency { epsilon, .. } => Some(epsilon),
            _ => None,
        };
        if let Some(eps) = eps {
            let a_ref = self.reference_accuracy(&v.family).unwrap_or(v.accuracy);
            if a_ref - entry.accuracy > eps + 1e-12 {
                return false;
            }
        }
        true
    }

    /// Evaluate one (LUT key, recognition rate) pair into a [`Candidate`],
    /// or `None` when the pre-filter rejects the key.  Single-candidate
    /// form of the [`Self::enumerate`] loop body (identical filters and
    /// arithmetic), used by the delta path to re-score resident frontier
    /// points in place.
    pub fn eval_candidate(&self, objective: Objective, space: &SearchSpace,
                          conds: &Conditions, key: &LutKey, r: f64)
                          -> Option<Candidate> {
        if !self.entry_admitted(objective, space, key) {
            return None;
        }
        let entry = self.lut.get(key).unwrap();
        let energy_mj =
            entry_energy_mj(self.device, key.engine, entry, key.governor)?;
        let design = Design {
            variant: key.variant.clone(),
            hw: HwConfig {
                engine: key.engine,
                threads: key.threads,
                governor: key.governor,
                recognition_rate: r,
                plan: key.plan.clone(),
            },
        };
        let latency_ms =
            adjusted_latency(self.lut, &design, objective.stat(), conds)?;
        let avg_latency_ms =
            adjusted_latency(self.lut, &design, Percentile::Avg, conds)?;
        let fps = (self.camera_fps * r).min(1000.0 / avg_latency_ms);
        Some(Candidate {
            design,
            latency_ms,
            avg_latency_ms,
            fps,
            mem_bytes: entry.mem_bytes,
            accuracy: entry.accuracy,
            energy_mj,
            score: 0.0,
        })
    }

    /// `enumerate(objective, space, _).len()` without building candidates:
    /// the pre-filter is conditions-independent and every admitted key
    /// yields exactly one candidate per recognition rate, so the count is
    /// admitted keys × rates.  The delta path uses this to refresh a
    /// frontier's `space_size` (the cost a full rebuild would have paid)
    /// without paying that cost.
    pub fn count_admitted(&self, objective: Objective, space: &SearchSpace)
                          -> usize {
        let rates = if space.recognition_rate.is_some() {
            1
        } else {
            RECOGNITION_RATES.len()
        };
        self.lut
            .entries
            .keys()
            .filter(|k| self.entry_admitted(objective, space, k))
            .count()
            * rates
    }
}

/// Objective score of one candidate (higher is better); `None` when the
/// candidate fails the objective's own feasibility constraint (the
/// target-latency budget).  The formulas are the paper's Eq. 3–5 scores,
/// unchanged — this function exists so every layer scores identically.
pub fn objective_score(objective: Objective, c: &Candidate, norms: &Norms)
                       -> Option<f64> {
    match objective {
        Objective::MaxFps { .. } => {
            // fps saturates at the camera rate; break ties toward the
            // lowest-latency (headroom) design.
            Some(c.fps - 1e-6 * c.avg_latency_ms)
        }
        Objective::TargetLatency { t_target_ms, .. } => {
            if c.latency_ms > t_target_ms {
                return None;
            }
            // Accuracy first; fps breaks ties.
            Some(c.accuracy + 1e-6 * c.fps)
        }
        Objective::MaxAccMaxFps { w_fps } => {
            Some(c.accuracy / norms.a_max + w_fps * c.fps / norms.fps_max)
        }
        Objective::MinLatency { .. } => Some(-c.latency_ms),
    }
}

/// The canonical selection order: score (descending) first, then the
/// deterministic tie chain — energy ↑, targeted latency ↑, accuracy ↓,
/// average latency ↑, recognition rate ↓, memory ↑, then the LUT key for
/// total stability.  The chain walks every Pareto-dominance dimension
/// (energy, latency, accuracy, average latency, then memory within equal
/// accuracy) in the dominating direction before any neutral tie-breaker,
/// so a dominated candidate can never be selected ahead of its dominator —
/// the invariant the frontier's exactness proof rests on.
pub fn cmp_ranked(a: &Candidate, b: &Candidate) -> Ordering {
    b.score
        .partial_cmp(&a.score)
        .unwrap()
        .then_with(|| a.energy_mj.partial_cmp(&b.energy_mj).unwrap())
        .then_with(|| a.latency_ms.partial_cmp(&b.latency_ms).unwrap())
        .then_with(|| b.accuracy.partial_cmp(&a.accuracy).unwrap())
        .then_with(|| a.avg_latency_ms.partial_cmp(&b.avg_latency_ms).unwrap())
        .then_with(|| {
            b.design
                .hw
                .recognition_rate
                .partial_cmp(&a.design.hw.recognition_rate)
                .unwrap()
        })
        .then_with(|| a.mem_bytes.cmp(&b.mem_bytes))
        .then_with(|| a.design.lut_key().cmp(&b.design.lut_key()))
}

/// The canonical frontier-walk selection: the best feasible frontier
/// point, with hard latency targets re-checked at the *exact* observed
/// conditions (the frontier is built and ranked at its bucket's
/// representative conditions, which can sit up to half a quantisation
/// step away).  `manager::best_under` and the fleet layer's per-device
/// selection both walk frontiers through this one function, so the
/// population bench provably mirrors the manager's semantics.
pub fn select_from_frontier<'f>(frontier: &'f ParetoFrontier, lut: &Lut,
                                objective: Objective, conds: &Conditions)
                                -> Option<&'f Candidate> {
    match objective {
        Objective::TargetLatency { t_target_ms, .. } => {
            frontier.points().iter().find(|c| {
                adjusted_latency(lut, &c.design, objective.stat(), conds)
                    .map_or(false, |adj| adj <= t_target_ms)
            })
        }
        _ => frontier.best(),
    }
}

/// Score and sort candidates best-first under the canonical selection
/// order, dropping candidates infeasible for the objective.  This is the
/// selection semantics of `optimizer::search`, `manager::best_under`, the
/// joint search's per-app rankings and the frontier walk — one
/// implementation for all four.
pub fn rank(cands: Vec<Candidate>, objective: Objective) -> Vec<Candidate> {
    let norms = Norms::of(&cands);
    let mut scored: Vec<Candidate> = cands
        .into_iter()
        .filter_map(|mut c| {
            c.score = objective_score(objective, &c, &norms)?;
            Some(c)
        })
        .collect();
    scored.sort_by(cmp_ranked);
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::measurements::Measurer;
    use crate::model::test_fixtures::fake_registry;
    use crate::optimizer::{Objective, Optimizer, SearchSpace};
    use crate::util::stats::Percentile;

    #[test]
    fn idle_enumeration_matches_optimizer_search() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap();
        let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 };
        let space = SearchSpace::family("mobilenet_v2_100");
        let ds = DesignSpace::new(&dev, &reg, &lut);
        let ranked = rank(ds.enumerate(obj, &space, &Conditions::idle()), obj);
        let opt = Optimizer::new(&dev, &reg, &lut);
        let searched = opt.search(obj, &space).unwrap();
        assert_eq!(ranked.len(), searched.len());
        for (a, b) in ranked.iter().zip(&searched) {
            assert_eq!(a.design, b.design);
            assert!((a.latency_ms - b.latency_ms).abs() < 1e-12);
        }
    }

    #[test]
    fn enumeration_respects_fixed_recognition_rate() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 };
        let mut space = SearchSpace::family("mobilenet_v2_100");
        space.recognition_rate = Some(0.5);
        let ds = DesignSpace::new(&dev, &reg, &lut);
        let cands = ds.enumerate(obj, &space, &Conditions::idle());
        assert!(!cands.is_empty());
        assert!(cands.iter().all(|c| c.design.hw.recognition_rate == 0.5));
    }

    #[test]
    fn conditions_scale_enumerated_latencies() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 };
        let space = SearchSpace::family("mobilenet_v2_100");
        let ds = DesignSpace::new(&dev, &reg, &lut);
        let idle = ds.enumerate(obj, &space, &Conditions::idle());
        let mut conds = Conditions::idle();
        conds.loads.insert(crate::device::EngineKind::Gpu, 1.0);
        let loaded = ds.enumerate(obj, &space, &conds);
        assert_eq!(idle.len(), loaded.len());
        for (a, b) in idle.iter().zip(&loaded) {
            if a.design.hw.engine == crate::device::EngineKind::Gpu {
                assert!((b.latency_ms - 2.0 * a.latency_ms).abs() < 1e-9);
            } else {
                assert!((b.latency_ms - a.latency_ms).abs() < 1e-12);
            }
        }
    }
}
