//! Cached Pareto frontiers: precompute the multi-objective front once per
//! (task, conditions-bucket), then make every adaptation decision an
//! O(frontier) walk (CARIn's fix for OODIn's per-event full re-search).
//!
//! **Dominance.**  Candidate p dominates q when both spend the *same
//! resources* — equal engine, recognition rate r and thread count — and p
//! is no worse on every objective dimension, strictly better on at least
//! one.  The objective dimensions are the targeted latency statistic, the
//! average latency (it drives every fps term, so it must be protected even
//! when the objective targets a tail statistic), energy, and *quality* —
//! accuracy ordered lexicographically with memory (strictly higher
//! accuracy wins; at exactly equal accuracy, not-larger memory wins).
//! Quality is lexicographic rather than two independent dimensions on
//! purpose: an ordered memory dimension would protect every
//! lower-precision variant from pruning (smaller weights), gutting the
//! frontier, while the lexicographic form prunes them through their
//! accuracy gap yet still keeps a smaller-memory variant whose accuracy
//! exactly ties.  Known trade-off: a variant that is strictly less
//! accurate *and* slower *and* hotter survives only through its accuracy
//! gap being real — if such a variant's sole advantage is memory, it is
//! pruned, so under extreme memory pressure the joint packer can reject
//! an app the raw (unpruned) ranking could still have degraded onto it.
//! The precision ladders that carry the practical memory fallbacks all
//! have genuine accuracy gaps and therefore survive.
//!
//! The resource triple is an equality *slice* rather than a set of
//! ordered dimensions because its "better" direction is
//! consumer-dependent: the GPU/NNAPI engines are exclusively owned in a
//! joint assignment, higher r means more fps for a solo app but more
//! engine time charged against the scheduler's utilisation budget, and
//! more threads mean lower latency but a bigger bite of the shared
//! CPU-core budget.  Slicing keeps every fallback ladder (alternative
//! engines, lower r, fewer threads, smaller variants) that engine
//! arbitration and admission control rely on, so one frontier serves both
//! the single-app selectors and the joint packer.  Slice-local dominance
//! also makes the *membership* of the frontier conditions-invariant:
//! external load and throttling scale every candidate on an engine by the
//! same multiplier, which can never flip a within-engine dominance — only
//! the scored ranking changes across buckets.
//!
//! **Exactness.**  The selection order ([`super::cmp_ranked`]) scores with
//! formulas that are monotone along every dominance dimension at fixed
//! (engine, r, threads), and its tie chain walks those same dimensions in
//! the dominating direction before any neutral tie-breaker.  Hence the
//! full-search arg-best is never dominated — it is always *on* the
//! frontier — and walking the frontier with the same order returns exactly
//! the full-search result (property-tested per objective, including
//! tail-statistic targets, in `tests/designspace_props.rs`).
//!
//! **Conditions buckets.**  Adjusted latency scales each engine by
//! `2^load / thermal`; the bucket quantises that per-engine multiplier in
//! half-doubling steps so one cached frontier serves every condition
//! vector in its bucket.  Both the frontier build and the subsequent walk
//! evaluate at the bucket's representative conditions, so the cached
//! selection equals a full search at those representative conditions.
//!
//! **Invalidation.**  Each cache entry carries a [`scoped_fingerprint`] of
//! the slice of (LUT, registry) its search space can observe — the entries
//! its (family, engine, precision) restriction admits plus the registry
//! variants of its family.  A lookup whose scope fingerprint drifted drops
//! and rebuilds *that entry only*: re-measuring one app's family no longer
//! cold-starts every other app's warm frontiers.
//!
//! **Incremental maintenance.**  When the caller can *describe* a LUT
//! change as a [`LutDelta`] (entry edits/additions, entry removals, or a
//! uniform per-engine latency scale like the fleet probe fallback's
//! correction), [`FrontierCache::apply_delta`] updates resident frontiers
//! in place instead of dropping them.  The delta path is exact, not
//! approximate, resting on two invariants:
//!
//! * Dominance is slice-local (equal engine, rate, threads) and
//!   transitive, so a changed/removed key perturbs only its own
//!   (engine, threads) slices — those slices are re-enumerated from the
//!   new LUT and re-pruned while every other slice is kept verbatim, and
//!   any candidate dominated by a non-frontier point is also dominated by
//!   some frontier point (dominator chains end on the frontier).
//! * A uniform per-engine latency scale multiplies every latency statistic
//!   *and* the energy proxy of a slice's candidates by the same factor
//!   while leaving accuracy and memory untouched, so within-slice
//!   dominance membership is invariant — resident points on the scaled
//!   engine are re-scored in place.  The deployability bound is the one
//!   filter a scale can cross: a slowdown can only drop a dominator
//!   together with everything it dominated (no resurrection), while a
//!   speedup may newly admit previously-undeployable keys, which are
//!   enumerated and inserted with frontier-local dominance checks.
//!
//! The delta path *falls back to a full rebuild* (the entry is dropped and
//! rebuilt on demand, counted in [`CacheStats::invalidations`]) whenever a
//! resident entry's fingerprint matches neither side of the declared
//! (old LUT → new LUT) transition — e.g. the entry predates an undescribed
//! change — so correctness never depends on delta bookkeeping being
//! complete.  The full rebuild ([`ParetoFrontier::build`]) remains the
//! reference implementation; `tests/frontier_incremental_props.rs`
//! asserts set-identity between both paths on randomized change-sets.
//!
//! **Capacity.**  The cache is LRU-bounded two ways: by resident frontier
//! *count* ([`FRONTIER_CACHE_DEFAULT_CAP`], overridable via
//! [`FrontierCache::with_cap`]) and — data-driven — by resident *bytes*
//! ([`FrontierCache::with_mem_budget`], accounted as
//! [`FRONTIER_BASE_BYTES`] + points × [`FRONTIER_POINT_BYTES`] per
//! frontier).  Once one cache is shared across a whole cohort of fleet
//! devices ([`crate::fleet`]), the set of (task, bucket) pairs its members
//! visit can grow with the population, so the least-recently-used frontier
//! is evicted (counted in [`CacheStats::evictions`]) whenever either bound
//! is exceeded.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::device::EngineKind;
use crate::manager::Conditions;
use crate::measurements::{Lut, LutEntry, LutKey};
use crate::model::Registry;
use crate::optimizer::{Objective, SearchSpace};
use crate::perf;
use crate::telemetry::trace::{FlightRecorder, TraceEvent};

use super::{cmp_ranked, rank, Candidate, DesignSpace};

/// Log2 width of one conditions-bucket step (half-doubling granularity:
/// multipliers within ~19% land in the same bucket).
pub const BUCKET_LOG2_STEP: f64 = 0.5;

/// A quantised per-engine condition vector: the cache key dimension that
/// lets one frontier serve a whole neighbourhood of condition vectors.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct ConditionsBucket {
    /// Quantised steps of log2(latency multiplier) per engine; engines at
    /// nominal conditions (step 0) are omitted.
    steps: BTreeMap<EngineKind, i32>,
}

impl ConditionsBucket {
    /// The bucket containing `conds`: per engine, the latency multiplier
    /// `2^load / thermal` quantised to [`BUCKET_LOG2_STEP`]-wide steps.
    pub fn of(conds: &Conditions) -> Self {
        let mut steps = BTreeMap::new();
        for e in EngineKind::ALL {
            let mult = perf::contention(conds.load(e))
                / conds.thermal_scale(e).max(1e-3);
            let step = (mult.log2() / BUCKET_LOG2_STEP).round() as i32;
            if step != 0 {
                steps.insert(e, step);
            }
        }
        ConditionsBucket { steps }
    }

    /// The bucket's representative conditions: each engine's multiplier is
    /// re-expressed as a pure load factor (`2^load`, cool thermal state) at
    /// the bucket's centre.
    pub fn representative(&self) -> Conditions {
        let mut conds = Conditions::idle();
        for (&e, &step) in &self.steps {
            conds.loads.insert(e, step as f64 * BUCKET_LOG2_STEP);
        }
        conds
    }

    /// True at nominal conditions on every engine.
    pub fn is_idle(&self) -> bool {
        self.steps.is_empty()
    }

    /// Canonical id, e.g. `cpu+2,nnapi+3` (`idle` when empty) — used in
    /// cache keys and experiment reports.
    pub fn id(&self) -> String {
        if self.steps.is_empty() {
            return "idle".to_string();
        }
        self.steps
            .iter()
            .map(|(e, s)| format!("{}{:+}", e.name(), s))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// True when `p` Pareto-dominates `q`: equal resource slice (engine,
/// recognition rate, thread count), no worse on every objective dimension
/// — targeted-statistic latency, average latency, energy, and quality
/// (accuracy, then memory at exactly equal accuracy) — strictly better on
/// at least one.
pub fn dominates(p: &Candidate, q: &Candidate) -> bool {
    if p.design.hw.engine != q.design.hw.engine
        || p.design.hw.recognition_rate != q.design.hw.recognition_rate
        || p.design.hw.threads != q.design.hw.threads
        || p.design.hw.plan != q.design.hw.plan
    {
        return false;
    }
    let quality_no_worse = p.accuracy > q.accuracy
        || (p.accuracy == q.accuracy && p.mem_bytes <= q.mem_bytes);
    let no_worse = p.latency_ms <= q.latency_ms
        && p.avg_latency_ms <= q.avg_latency_ms
        && p.energy_mj <= q.energy_mj
        && quality_no_worse;
    let strictly_better = p.latency_ms < q.latency_ms
        || p.avg_latency_ms < q.avg_latency_ms
        || p.energy_mj < q.energy_mj
        || p.accuracy > q.accuracy
        || (p.accuracy == q.accuracy && p.mem_bytes < q.mem_bytes);
    no_worse && strictly_better
}

/// A structured description of one LUT transition — the delta path's
/// input.  Three change families cover every online-correction source the
/// system produces: entry edits/additions (`changed`), entry removals
/// (`removed`), and uniform per-engine latency scale corrections
/// (`engine_scales`, the shape of the fleet probe fallback's
/// geometric-mean factor).  A delta passed to
/// [`FrontierCache::apply_delta`] must cover *every* difference between
/// the old and new LUT ([`LutDelta::between`] computes exactly that);
/// entries outside the declared delta are assumed byte-identical.
#[derive(Debug, Clone, Default)]
pub struct LutDelta {
    /// Keys whose entries changed in, or were added to, the new LUT.
    pub changed: BTreeSet<LutKey>,
    /// Keys absent from the new LUT.
    pub removed: BTreeSet<LutKey>,
    /// Uniform per-engine latency scale factors: every latency statistic
    /// of every entry on the engine is multiplied by the factor (accuracy
    /// and memory untouched), as produced by
    /// [`crate::measurements::Lut::scaled_engine`].
    pub engine_scales: BTreeMap<EngineKind, f64>,
}

/// True when two LUT entries are byte-identical on every field a frontier
/// can observe.
fn same_entry(a: &LutEntry, b: &LutEntry) -> bool {
    let l = &a.latency;
    let r = &b.latency;
    [l.min, l.max, l.avg, l.median, l.p90, l.p99]
        .iter()
        .zip([r.min, r.max, r.avg, r.median, r.p90, r.p99].iter())
        .all(|(x, y)| x.to_bits() == y.to_bits())
        && a.mem_bytes == b.mem_bytes
        && a.accuracy.to_bits() == b.accuracy.to_bits()
        && a.stages.len() == b.stages.len()
        && a.stages.iter().zip(b.stages.iter()).all(|(x, y)| {
            x.engine == y.engine
                && x.stage_ms.to_bits() == y.stage_ms.to_bits()
                && x.xfer_ms.to_bits() == y.xfer_ms.to_bits()
        })
}

impl LutDelta {
    /// A delta describing edited or added entries.
    pub fn entries(keys: impl IntoIterator<Item = LutKey>) -> Self {
        LutDelta { changed: keys.into_iter().collect(), ..Default::default() }
    }

    /// A delta describing removed entries.
    pub fn removal(keys: impl IntoIterator<Item = LutKey>) -> Self {
        LutDelta { removed: keys.into_iter().collect(), ..Default::default() }
    }

    /// A delta describing a uniform latency scale on one engine.
    pub fn engine_scale(engine: EngineKind, factor: f64) -> Self {
        let mut engine_scales = BTreeMap::new();
        engine_scales.insert(engine, factor);
        LutDelta { engine_scales, ..Default::default() }
    }

    /// The exact diff between two LUTs as an entry-level delta (no scale
    /// inference): keys edited or added end up in `changed`, keys dropped
    /// in `removed`.
    pub fn between(old: &Lut, new: &Lut) -> Self {
        let mut delta = LutDelta::default();
        for (k, e) in &new.entries {
            match old.entries.get(k) {
                Some(o) if same_entry(o, e) => {}
                _ => {
                    delta.changed.insert(k.clone());
                }
            }
        }
        for k in old.entries.keys() {
            if !new.entries.contains_key(k) {
                delta.removed.insert(k.clone());
            }
        }
        delta
    }

    /// True when the delta describes no change at all.
    pub fn is_empty(&self) -> bool {
        self.changed.is_empty()
            && self.removed.is_empty()
            && self.engine_scales.is_empty()
    }
}

/// A dominance-pruned design front for one (objective, search space) at
/// one conditions bucket, stored in canonical selection order.
#[derive(Debug, Clone)]
pub struct ParetoFrontier {
    /// The bucket this frontier was built at.
    pub bucket: ConditionsBucket,
    /// Non-dominated, objective-feasible candidates, best-first under
    /// [`cmp_ranked`] (scored at the bucket's representative conditions).
    points: Vec<Candidate>,
    /// Enumerated-space size after constraint pre-filtering — the
    /// per-event cost a full search would pay.
    pub space_size: usize,
}

impl ParetoFrontier {
    /// Enumerate the space at the bucket's representative conditions,
    /// prune dominated candidates, and rank the survivors.
    pub fn build(space: &DesignSpace, objective: Objective,
                 sspace: &SearchSpace, bucket: &ConditionsBucket) -> Self {
        let conds = bucket.representative();
        let cands = space.enumerate(objective, sspace, &conds);
        let space_size = cands.len();
        let survivors: Vec<Candidate> = cands
            .iter()
            .filter(|q| !cands.iter().any(|p| dominates(p, q)))
            .cloned()
            .collect();
        ParetoFrontier {
            bucket: bucket.clone(),
            points: rank(survivors, objective),
            space_size,
        }
    }

    /// The frontier points, best-first under the canonical selection
    /// order.
    pub fn points(&self) -> &[Candidate] {
        &self.points
    }

    /// Number of frontier points — the per-event cost of a frontier walk.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when no feasible design survives (e.g. an unknown family or an
    /// unreachable latency target).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The frontier-walk selection: the best feasible candidate, already
    /// front-of-list by construction.
    pub fn best(&self) -> Option<&Candidate> {
        self.points.first()
    }

    /// Incrementally carry this frontier (built over `old`'s LUT) across
    /// the LUT transition described by `delta`, returning the updated
    /// frontier plus the number of points/candidates the delta path
    /// touched (the cost a caller compares against the `space_size` a full
    /// rebuild would enumerate).  Exact — set-identical to
    /// `ParetoFrontier::build` over `new` — provided `delta` covers every
    /// difference between `old.lut` and `new.lut` (see [`LutDelta`] and
    /// the module docs for the invariants this rests on).
    pub fn apply_delta(&self, old: &DesignSpace, new: &DesignSpace,
                       objective: Objective, sspace: &SearchSpace,
                       delta: &LutDelta) -> (ParetoFrontier, u64) {
        let conds = self.bucket.representative();
        let mut touched: u64 = 0;

        // Entry-level changes perturb only their own (engine, threads)
        // slices: those slices are rebuilt from the new LUT wholesale.
        let mut slices: BTreeSet<(EngineKind, usize)> = BTreeSet::new();
        for k in delta.changed.iter().chain(delta.removed.iter()) {
            if sspace.admits(new.registry, k) {
                slices.insert((k.engine, k.threads));
            }
        }

        // Points outside the rebuilt slices survive verbatim (their LUT
        // entries are byte-identical across the transition).
        let mut kept: Vec<Candidate> = self
            .points
            .iter()
            .filter(|c| {
                !slices.contains(&(c.design.hw.engine, c.design.hw.threads))
            })
            .cloned()
            .collect();

        // Slice rebuild: re-enumerate only keys inside affected slices and
        // prune within them — dominance never crosses a slice boundary, so
        // slice-local pruning is exact.
        let mut incoming: Vec<Candidate> = Vec::new();
        if !slices.is_empty() {
            let cands = new.enumerate_where(objective, sspace, &conds, |k| {
                slices.contains(&(k.engine, k.threads))
            });
            touched += cands.len() as u64;
            incoming.extend(
                cands
                    .iter()
                    .filter(|q| !cands.iter().any(|p| dominates(p, q)))
                    .cloned(),
            );
        }

        // Per-engine scale: within-slice dominance membership is invariant
        // under a uniform latency scale, so surviving points on the engine
        // are re-scored in place from the new LUT.
        for (&engine, &factor) in &delta.engine_scales {
            if let Some(engines) = &sspace.engines {
                if !engines.contains(&engine) {
                    continue;
                }
            }
            let mut next = Vec::with_capacity(kept.len());
            for c in kept {
                if c.design.hw.engine != engine {
                    next.push(c);
                    continue;
                }
                touched += 1;
                if let Some(rescored) = new.eval_candidate(
                    objective, sspace, &conds, &c.design.lut_key(),
                    c.design.hw.recognition_rate)
                {
                    next.push(rescored);
                }
                // else: scaled past the deployability bound — safe to drop
                // without re-checking the slice, because a uniform scale
                // can only push a dominator out together with everything
                // it dominated.
            }
            kept = next;
            if factor < 1.0 {
                // A speedup may pull previously-undeployable keys under
                // the sustained-latency bound: enumerate and insert them
                // with frontier-local dominance checks (exact, because any
                // dominator chain over them ends on the frontier).
                let news: Vec<&LutKey> = new
                    .lut
                    .entries
                    .keys()
                    .filter(|k| {
                        k.engine == engine
                            && !slices.contains(&(k.engine, k.threads))
                            && old.lut.get(k).map_or(true, |e| {
                                e.latency.avg
                                    > old.device.max_deployable_latency_ms
                            })
                            && new.entry_admitted(objective, sspace, k)
                    })
                    .collect();
                if !news.is_empty() {
                    let cands =
                        new.enumerate_where(objective, sspace, &conds, |k| {
                            news.contains(&k)
                        });
                    touched += cands.len() as u64;
                    let mut fresh: Vec<Candidate> = cands
                        .iter()
                        .filter(|q| !cands.iter().any(|p| dominates(p, q)))
                        .cloned()
                        .collect();
                    fresh.retain(|q| {
                        !kept
                            .iter()
                            .chain(incoming.iter())
                            .any(|p| dominates(p, q))
                    });
                    kept.retain(|q| !fresh.iter().any(|p| dominates(p, q)));
                    incoming
                        .retain(|q| !fresh.iter().any(|p| dominates(p, q)));
                    incoming.extend(fresh);
                }
            }
        }

        kept.extend(incoming);
        (
            ParetoFrontier {
                bucket: self.bucket.clone(),
                points: rank(kept, objective),
                space_size: new.count_admitted(objective, sspace),
            },
            touched,
        )
    }
}

/// Cache effectiveness counters, reported by `oodin opt-bench` and
/// `oodin fleet-bench`.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Frontier builds (cache misses).
    pub builds: u64,
    /// Cache hits (adaptation events served without a build).
    pub hits: u64,
    /// Cached frontiers dropped because their scope fingerprint drifted
    /// (an undescribed LUT / registry change, or the delta fallback).
    pub invalidations: u64,
    /// Candidates enumerated across all builds (the amortised build cost).
    pub candidates_enumerated: u64,
    /// Frontiers dropped by the LRU capacity or memory-budget bound.
    pub evictions: u64,
    /// Frontiers carried across a LUT transition in place by the delta
    /// path ([`FrontierCache::apply_delta`]).
    pub delta_updates: u64,
    /// Points/candidates the delta path re-evaluated — compare against
    /// `candidates_enumerated` growth to see the avoided rebuild cost.
    pub delta_points_touched: u64,
}

/// Aggregate outcome of one [`FrontierCache::apply_delta`] call (or of
/// several absorbed together, e.g. across a fleet's cohorts).
#[derive(Debug, Clone, Copy, Default)]
pub struct DeltaOutcome {
    /// Resident frontiers delta-updated in place.
    pub updated: u64,
    /// Resident frontiers left untouched: their scope cannot observe the
    /// delta, or they already sit at the new fingerprint (idempotent
    /// re-apply on a shared cache).
    pub untouched: u64,
    /// Resident frontiers dropped to rebuild-on-demand — the fallback when
    /// a fingerprint matches neither side of the declared transition.
    pub dropped: u64,
    /// Points/candidates the delta path re-evaluated.
    pub points_touched: u64,
    /// Candidates a from-scratch rebuild of the *updated* frontiers would
    /// have enumerated — the cost the delta path avoided.
    pub rebuild_points: u64,
}

impl DeltaOutcome {
    /// Fold another outcome into this one (fleet-level aggregation).
    pub fn absorb(&mut self, other: DeltaOutcome) {
        self.updated += other.updated;
        self.untouched += other.untouched;
        self.dropped += other.dropped;
        self.points_touched += other.points_touched;
        self.rebuild_points += other.rebuild_points;
    }
}

/// Default LRU capacity of a [`FrontierCache`]: generous enough that the
/// single-device paths (a handful of tasks × conditions buckets) never
/// evict, while bounding memory when one cache is shared across a whole
/// cohort of fleet devices.
pub const FRONTIER_CACHE_DEFAULT_CAP: usize = 1024;

/// Nominal accounted bytes per resident frontier point.  A fixed
/// accounting constant rather than `size_of::<Candidate>()` so that
/// budget arithmetic is identical across platforms and reproducible by
/// the Python golden oracles; 192 B covers the metric vector, the design
/// (short strings included) and `Vec` slack on 64-bit targets.
pub const FRONTIER_POINT_BYTES: u64 = 192;

/// Nominal accounted fixed overhead per resident frontier (cache key
/// strings, bucket, fingerprint, ticks).
pub const FRONTIER_BASE_BYTES: u64 = 256;

/// One resident frontier plus everything needed to validate and
/// delta-update it without the original caller.
#[derive(Debug)]
struct CacheEntry {
    frontier: Arc<ParetoFrontier>,
    /// Last-use tick; drives LRU eviction.
    used: u64,
    /// [`scoped_fingerprint`] of the (LUT, registry) slice this entry's
    /// search space observes, as of the build or last delta update.
    scope_fp: u64,
    objective: Objective,
    sspace: SearchSpace,
    camera_fps: f64,
}

/// The frontier cache: one [`ParetoFrontier`] per (task, bucket), keyed by
/// a canonical task tag, scope-fingerprint-invalidated per entry when the
/// LUT or registry drifts, delta-updatable in place via
/// [`FrontierCache::apply_delta`], and LRU-bounded both by entry count
/// (`cap`) and by accounted resident bytes (`mem_budget`).
#[derive(Debug)]
pub struct FrontierCache {
    /// (task, bucket) -> resident entry.
    map: BTreeMap<(String, String), CacheEntry>,
    tick: u64,
    cap: usize,
    mem_budget: u64,
    /// Attached flight recorder (with its scope label) — every cache
    /// transition (build / hit / evict / delta-apply) is emitted when set.
    recorder: Option<(Arc<FlightRecorder>, String)>,
    /// Effectiveness counters since construction.
    pub stats: CacheStats,
}

impl Default for FrontierCache {
    fn default() -> Self {
        FrontierCache {
            map: BTreeMap::new(),
            tick: 0,
            cap: FRONTIER_CACHE_DEFAULT_CAP,
            mem_budget: 0,
            recorder: None,
            stats: CacheStats::default(),
        }
    }
}

/// Canonical cache tag of one search task (objective + space restriction +
/// camera rate — the last caps every fps score, so spaces differing only
/// in camera rate must not share frontiers).  `Objective` and
/// `SearchSpace` carry floats, so a formatted tag stands in for
/// `Ord`/`Hash` keys.
pub fn task_tag(objective: Objective, space: &SearchSpace, camera_fps: f64)
                -> String {
    format!(
        "{objective:?}|fam={:?}|eng={:?}|prec={:?}|r={:?}|cam={camera_fps}",
        space.family, space.engines, space.precisions, space.recognition_rate
    )
}

/// FNV-1a fingerprint of the slice of the (LUT, registry) pair that
/// `space`'s restriction can observe: the device name, every LUT entry the
/// (family, engine, precision) restriction admits, and the registry
/// variants of the restricted family.  Invalidation is therefore scoped —
/// a change to one app's family leaves other apps' cached frontiers warm.
/// Allocation-free and a plain linear read (~ns per entry), so recomputing
/// it per lookup stays far below the enumeration + scoring + sorting cost
/// the cache exists to avoid.
pub fn scoped_fingerprint(lut: &Lut, registry: &Registry,
                          space: &SearchSpace) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    eat(lut.device.as_bytes());
    for (k, e) in &lut.entries {
        if !space.admits(registry, k) {
            continue;
        }
        eat(k.variant.as_bytes());
        eat(&[k.engine as u8, k.governor as u8]);
        eat(&(k.threads as u64).to_le_bytes());
        // Partitioned keys additionally pin their plan and per-stage
        // costs; monolithic keys eat nothing extra, keeping every
        // pre-partitioning fingerprint stable.
        if let crate::measurements::ExecPlan::Split(p) = &k.plan {
            eat(&[0x70]); // 'p' marker separating plan bytes
            for se in &p.engines {
                eat(&[*se as u8]);
            }
            for c in &p.cuts_pm {
                eat(&c.to_le_bytes());
            }
            for st in &e.stages {
                eat(&[st.engine as u8]);
                eat(&st.stage_ms.to_bits().to_le_bytes());
                eat(&st.xfer_ms.to_bits().to_le_bytes());
            }
        }
        eat(&e.latency.avg.to_bits().to_le_bytes());
        eat(&e.latency.p90.to_bits().to_le_bytes());
        eat(&e.latency.p99.to_bits().to_le_bytes());
        eat(&e.accuracy.to_bits().to_le_bytes());
        eat(&e.mem_bytes.to_le_bytes());
    }
    for v in registry.variants() {
        if let Some(fam) = &space.family {
            if &v.family != fam {
                continue;
            }
        }
        eat(v.name.as_bytes());
        eat(&v.accuracy.to_bits().to_le_bytes());
        eat(&v.size_bytes.to_le_bytes());
    }
    h
}

impl FrontierCache {
    /// An empty cache at the default LRU capacity.
    pub fn new() -> Self {
        FrontierCache::default()
    }

    /// Override the LRU capacity (0 disables the bound).  Evicting the
    /// least-recently-used frontier keeps a cohort-shared cache's memory
    /// proportional to its working set of (task, bucket) pairs rather than
    /// to everything any member ever visited.
    pub fn with_cap(mut self, cap: usize) -> Self {
        self.cap = cap;
        self
    }

    /// The active LRU capacity (0 = unbounded).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Bound accounted resident bytes ([`Self::resident_bytes`]) instead
    /// of — well, alongside — the entry-count cap: whenever the gauge
    /// exceeds `bytes`, least-recently-used frontiers are evicted until it
    /// fits (the most-recently-used frontier always stays resident, so the
    /// active decision path cannot thrash).  0 disables the bound.
    pub fn with_mem_budget(mut self, bytes: u64) -> Self {
        self.mem_budget = bytes;
        self
    }

    /// The active memory budget in accounted bytes (0 = unbounded).
    pub fn mem_budget(&self) -> u64 {
        self.mem_budget
    }

    /// Attach a flight recorder: every subsequent build / hit / evict /
    /// delta-apply emits a [`TraceEvent`] scoped to `scope` (the cache
    /// owner — a cohort id or an app id).  Recording never changes cache
    /// behaviour or statistics.
    pub fn set_recorder(&mut self, recorder: Arc<FlightRecorder>,
                        scope: &str) {
        self.recorder = Some((recorder, scope.to_string()));
    }

    fn emit(&self, event: TraceEvent) {
        if let Some((rec, _)) = &self.recorder {
            rec.emit(event);
        }
    }

    fn scope(&self) -> String {
        self.recorder
            .as_ref()
            .map(|(_, s)| s.clone())
            .unwrap_or_default()
    }

    /// Accounted bytes of all resident frontiers:
    /// [`FRONTIER_BASE_BYTES`] + points × [`FRONTIER_POINT_BYTES`] each.
    pub fn resident_bytes(&self) -> u64 {
        self.map
            .values()
            .map(|e| {
                FRONTIER_BASE_BYTES
                    + FRONTIER_POINT_BYTES * e.frontier.len() as u64
            })
            .sum()
    }

    /// Evict least-recently-used frontiers (linear scan: the map is small
    /// and eviction is the rare path) until the memory budget holds.
    fn enforce_mem_budget(&mut self) {
        if self.mem_budget == 0 {
            return;
        }
        while self.map.len() > 1 && self.resident_bytes() > self.mem_budget {
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.used)
                .map(|(k, _)| k.clone())
            {
                if let Some(e) = self.map.remove(&lru) {
                    self.emit(TraceEvent::FrontierEvict {
                        scope: self.scope(),
                        bucket: lru.1.clone(),
                        points: e.frontier.len() as u64,
                    });
                }
                self.stats.evictions += 1;
            }
        }
    }

    /// The cached frontier for (objective, space restriction, camera rate,
    /// bucket), building it on first use and whenever the entry's scope
    /// fingerprint drifted since it was built ([`scoped_fingerprint`] — a
    /// linear allocation-free read, orders of magnitude cheaper than the
    /// enumeration + scoring + sort a miss would pay, but not free; the
    /// `opt-bench` cost model counts scored candidates only and excludes
    /// this guard).  Only the stale entry itself is dropped: frontiers
    /// whose scope did not drift stay warm.
    pub fn frontier(&mut self, space: &DesignSpace, objective: Objective,
                    sspace: &SearchSpace, bucket: &ConditionsBucket)
                    -> Arc<ParetoFrontier> {
        let fp = scoped_fingerprint(space.lut, space.registry, sspace);
        let key = (task_tag(objective, sspace, space.camera_fps), bucket.id());
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&key) {
            Some(e) if e.scope_fp == fp => {
                e.used = tick;
                self.stats.hits += 1;
                let f = Arc::clone(&e.frontier);
                self.emit(TraceEvent::FrontierHit {
                    scope: self.scope(),
                    bucket: bucket.id(),
                    points: f.len() as u64,
                });
                return f;
            }
            Some(_) => {
                self.map.remove(&key);
                self.stats.invalidations += 1;
            }
            None => {}
        }
        if self.cap > 0 && self.map.len() >= self.cap {
            // Evict the least-recently-used frontier (linear scan: the map
            // is at most `cap` entries and eviction is the rare path).
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.used)
                .map(|(k, _)| k.clone())
            {
                if let Some(e) = self.map.remove(&lru) {
                    self.emit(TraceEvent::FrontierEvict {
                        scope: self.scope(),
                        bucket: lru.1.clone(),
                        points: e.frontier.len() as u64,
                    });
                }
                self.stats.evictions += 1;
            }
        }
        let f = Arc::new(ParetoFrontier::build(space, objective, sspace, bucket));
        self.stats.builds += 1;
        self.stats.candidates_enumerated += f.space_size as u64;
        self.emit(TraceEvent::FrontierBuild {
            scope: self.scope(),
            bucket: bucket.id(),
            points: f.len() as u64,
            candidates: f.space_size as u64,
        });
        self.map.insert(
            key,
            CacheEntry {
                frontier: Arc::clone(&f),
                used: tick,
                scope_fp: fp,
                objective,
                sspace: sspace.clone(),
                camera_fps: space.camera_fps,
            },
        );
        self.enforce_mem_budget();
        f
    }

    /// Carry every resident frontier across the (`old` → `new`) LUT
    /// transition described by `delta`, in place, instead of dropping the
    /// cache.  Per entry: if its scope fingerprint already matches the new
    /// LUT it is untouched (the delta cannot be observed by its search
    /// space, or was already applied — re-applying on a cohort-shared
    /// cache is idempotent); if it matches the *old* LUT it is
    /// delta-updated exactly ([`ParetoFrontier::apply_delta`]); otherwise
    /// it predates an undescribed change and falls back to
    /// rebuild-on-demand (dropped, counted as an invalidation).
    pub fn apply_delta(&mut self, old: &DesignSpace, new: &DesignSpace,
                       delta: &LutDelta) -> DeltaOutcome {
        let mut out = DeltaOutcome::default();
        let keys: Vec<(String, String)> = self.map.keys().cloned().collect();
        for key in keys {
            let (objective, sspace, camera_fps, scope_fp, frontier) = {
                let e = self.map.get(&key).unwrap();
                (e.objective, e.sspace.clone(), e.camera_fps, e.scope_fp,
                 Arc::clone(&e.frontier))
            };
            let fp_new = scoped_fingerprint(new.lut, new.registry, &sspace);
            if scope_fp == fp_new {
                out.untouched += 1;
                continue;
            }
            let fp_old = scoped_fingerprint(old.lut, old.registry, &sspace);
            if scope_fp != fp_old {
                // Fallback to full rebuild on next lookup.
                self.map.remove(&key);
                self.stats.invalidations += 1;
                out.dropped += 1;
                continue;
            }
            let old_ds = DesignSpace {
                device: old.device,
                registry: old.registry,
                lut: old.lut,
                camera_fps,
            };
            let new_ds = DesignSpace {
                device: new.device,
                registry: new.registry,
                lut: new.lut,
                camera_fps,
            };
            let (updated, touched) =
                frontier.apply_delta(&old_ds, &new_ds, objective, &sspace,
                                     delta);
            out.updated += 1;
            out.points_touched += touched;
            out.rebuild_points += updated.space_size as u64;
            self.stats.delta_updates += 1;
            self.stats.delta_points_touched += touched;
            let e = self.map.get_mut(&key).unwrap();
            e.frontier = Arc::new(updated);
            e.scope_fp = fp_new;
        }
        self.enforce_mem_budget();
        // One event per effective application; idempotent re-applies on a
        // shared cache (everything untouched) stay silent.
        if out.updated + out.dropped > 0 {
            self.emit(TraceEvent::FrontierDelta {
                scope: self.scope(),
                updated: out.updated,
                points_touched: out.points_touched,
                rebuild_points: out.rebuild_points,
            });
        }
        out
    }

    /// Cached frontiers currently resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True before the first build (or right after an invalidation).
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::manager::Conditions;
    use crate::measurements::Measurer;
    use crate::model::test_fixtures::fake_registry;
    use crate::util::stats::Percentile;

    fn obj() -> Objective {
        Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }
    }

    #[test]
    fn bucket_quantises_and_represents() {
        let mut conds = Conditions::idle();
        conds.loads.insert(EngineKind::Gpu, 1.0);
        let b = ConditionsBucket::of(&conds);
        assert!(!b.is_idle());
        assert_eq!(b.id(), "gpu+2");
        let rep = b.representative();
        assert!((rep.load(EngineKind::Gpu) - 1.0).abs() < 1e-12);
        assert_eq!(ConditionsBucket::of(&rep), b, "representative re-buckets");
        assert!(ConditionsBucket::of(&Conditions::idle()).is_idle());
    }

    #[test]
    fn thermal_throttle_lands_in_load_bucket() {
        // thermal 0.5 halves the clock: multiplier 2 == load 1.0.
        let mut hot = Conditions::idle();
        hot.thermal.insert(EngineKind::Npu, 0.5);
        let mut loaded = Conditions::idle();
        loaded.loads.insert(EngineKind::Npu, 1.0);
        assert_eq!(ConditionsBucket::of(&hot), ConditionsBucket::of(&loaded));
    }

    #[test]
    fn frontier_smaller_than_space_and_selects_best() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap();
        let ds = DesignSpace::new(&dev, &reg, &lut);
        let space = SearchSpace::family("mobilenet_v2_100");
        let b = ConditionsBucket::of(&Conditions::idle());
        let f = ParetoFrontier::build(&ds, obj(), &space, &b);
        assert!(!f.is_empty());
        assert!(f.len() < f.space_size,
                "frontier {} !< space {}", f.len(), f.space_size);
        let full = rank(ds.enumerate(obj(), &space, &Conditions::idle()), obj());
        assert_eq!(f.best().unwrap().design, full[0].design);
    }

    #[test]
    fn camera_rate_gets_its_own_frontier() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let b = ConditionsBucket::of(&Conditions::idle());
        let mut cache = FrontierCache::new();
        let obj = Objective::MaxFps { epsilon: 0.05 };
        let ds30 = DesignSpace::new(&dev, &reg, &lut);
        let ds60 = DesignSpace::new(&dev, &reg, &lut).with_camera_fps(60.0);
        let f30 = cache.frontier(&ds30, obj, &space, &b);
        let f60 = cache.frontier(&ds60, obj, &space, &b);
        assert_eq!(cache.stats.builds, 2, "camera rates must not share");
        assert!(f30.best().unwrap().fps <= 30.0 + 1e-9);
        assert!(f60.best().unwrap().fps > 30.0);
    }

    #[test]
    fn lru_cap_bounds_residency_and_counts_evictions() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let ds = DesignSpace::new(&dev, &reg, &lut);
        let mut cache = FrontierCache::new().with_cap(2);
        assert_eq!(cache.cap(), 2);
        // Visit three distinct buckets: the third build must evict the
        // least-recently-used (the first) while staying at cap residency.
        let buckets: Vec<ConditionsBucket> = [0.0, 1.0, 2.0]
            .iter()
            .map(|&l| {
                let mut c = Conditions::idle();
                c.loads.insert(EngineKind::Cpu, l);
                ConditionsBucket::of(&c)
            })
            .collect();
        for b in &buckets {
            cache.frontier(&ds, obj(), &space, b);
        }
        assert_eq!(cache.len(), 2, "residency must not exceed the cap");
        assert_eq!(cache.stats.builds, 3);
        assert_eq!(cache.stats.evictions, 1);
        // The survivors still hit; the evicted bucket rebuilds.
        cache.frontier(&ds, obj(), &space, &buckets[2]);
        assert_eq!(cache.stats.hits, 1);
        cache.frontier(&ds, obj(), &space, &buckets[0]);
        assert_eq!(cache.stats.builds, 4, "evicted frontier must rebuild");
        assert_eq!(cache.stats.evictions, 2);
    }

    #[test]
    fn cache_hits_and_scoped_invalidation() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let b = ConditionsBucket::of(&Conditions::idle());
        let mut cache = FrontierCache::new();
        {
            let ds = DesignSpace::new(&dev, &reg, &lut);
            cache.frontier(&ds, obj(), &space, &b);
            cache.frontier(&ds, obj(), &space, &b);
        }
        assert_eq!(cache.stats.builds, 1);
        assert_eq!(cache.stats.hits, 1);
        // Perturbing another family's entry is outside this space's scope:
        // the warm frontier must survive (a hit, not an invalidation).
        let mut lut2 = lut.clone();
        let other = lut2
            .entries
            .keys()
            .find(|k| k.variant.starts_with("deeplab_v3"))
            .unwrap()
            .clone();
        lut2.entries.get_mut(&other).unwrap().accuracy += 0.001;
        let ds2 = DesignSpace::new(&dev, &reg, &lut2);
        cache.frontier(&ds2, obj(), &space, &b);
        assert_eq!(cache.stats.invalidations, 0, "out-of-scope change");
        assert_eq!((cache.stats.builds, cache.stats.hits), (1, 2));
        // Perturbing an in-scope entry must drop exactly that entry.
        let mut lut3 = lut2.clone();
        let own = lut3
            .entries
            .keys()
            .find(|k| k.variant.starts_with("mobilenet_v2_100"))
            .unwrap()
            .clone();
        lut3.entries.get_mut(&own).unwrap().accuracy += 0.001;
        let ds3 = DesignSpace::new(&dev, &reg, &lut3);
        cache.frontier(&ds3, obj(), &space, &b);
        assert_eq!(cache.stats.invalidations, 1);
        assert_eq!(cache.stats.builds, 2);
        assert_eq!(cache.len(), 1, "stale frontier dropped and rebuilt");
    }

    #[test]
    fn delta_engine_scale_matches_rebuild_and_is_idempotent() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let b = ConditionsBucket::of(&Conditions::idle());
        let mut cache = FrontierCache::new();
        let ds = DesignSpace::new(&dev, &reg, &lut);
        cache.frontier(&ds, obj(), &space, &b);
        let lut2 = std::sync::Arc::new(lut.scaled_engine(EngineKind::Cpu, 1.25));
        let delta = LutDelta::engine_scale(EngineKind::Cpu, 1.25);
        let ds2 = DesignSpace::new(&dev, &reg, &lut2);
        let out = cache.apply_delta(&ds, &ds2, &delta);
        assert_eq!((out.updated, out.dropped), (1, 0));
        assert!(out.points_touched < out.rebuild_points,
                "delta touched {} !< rebuild {}", out.points_touched,
                out.rebuild_points);
        // The updated frontier must serve a lookup against the new LUT as
        // a hit and equal a from-scratch rebuild exactly.
        let cached = cache.frontier(&ds2, obj(), &space, &b);
        assert_eq!(cache.stats.builds, 1, "no rebuild after delta");
        let rebuilt = ParetoFrontier::build(&ds2, obj(), &space, &b);
        assert_eq!(cached.len(), rebuilt.len());
        assert_eq!(cached.space_size, rebuilt.space_size);
        for (a, c) in cached.points().iter().zip(rebuilt.points()) {
            assert_eq!(a.design, c.design);
            assert_eq!(a.latency_ms.to_bits(), c.latency_ms.to_bits());
            assert_eq!(a.energy_mj.to_bits(), c.energy_mj.to_bits());
        }
        // Re-applying the same transition (second manager on a shared
        // cohort cache) must be a no-op.
        let again = cache.apply_delta(&ds, &ds2, &delta);
        assert_eq!((again.updated, again.untouched), (0, 1));
        assert_eq!(again.points_touched, 0);
    }

    #[test]
    fn delta_entry_edit_and_removal_match_rebuild() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let b = ConditionsBucket::of(&Conditions::idle());
        let mut cache = FrontierCache::new();
        let ds = DesignSpace::new(&dev, &reg, &lut);
        cache.frontier(&ds, obj(), &space, &b);
        // Edit one entry and remove another (both in scope).
        let mut lut2 = lut.clone();
        let keys: Vec<LutKey> = lut2
            .entries
            .keys()
            .filter(|k| k.variant.starts_with("mobilenet_v2_100"))
            .cloned()
            .collect();
        lut2.entries.get_mut(&keys[0]).unwrap().latency.avg *= 1.4;
        lut2.entries.remove(&keys[1]);
        let delta = LutDelta::between(&lut, &lut2);
        assert_eq!(delta.changed.len(), 1);
        assert_eq!(delta.removed.len(), 1);
        let ds2 = DesignSpace::new(&dev, &reg, &lut2);
        let out = cache.apply_delta(&ds, &ds2, &delta);
        assert_eq!(out.updated, 1);
        assert!(out.points_touched < out.rebuild_points);
        let cached = cache.frontier(&ds2, obj(), &space, &b);
        assert_eq!(cache.stats.builds, 1, "no rebuild after delta");
        let rebuilt = ParetoFrontier::build(&ds2, obj(), &space, &b);
        assert_eq!(cached.len(), rebuilt.len());
        for (a, c) in cached.points().iter().zip(rebuilt.points()) {
            assert_eq!(a.design, c.design);
            assert_eq!(a.latency_ms.to_bits(), c.latency_ms.to_bits());
        }
    }

    #[test]
    fn mem_budget_bounds_resident_bytes() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let ds = DesignSpace::new(&dev, &reg, &lut);
        // First find one frontier's accounted footprint, then budget for
        // barely more than one frontier: a second bucket must evict the
        // first while the newest stays resident.
        let mut probe = FrontierCache::new();
        let b0 = ConditionsBucket::of(&Conditions::idle());
        probe.frontier(&ds, obj(), &space, &b0);
        let one = probe.resident_bytes();
        assert!(one > FRONTIER_BASE_BYTES);
        let mut cache = FrontierCache::new().with_mem_budget(one + 1);
        assert_eq!(cache.mem_budget(), one + 1);
        cache.frontier(&ds, obj(), &space, &b0);
        let mut loaded = Conditions::idle();
        loaded.loads.insert(EngineKind::Cpu, 1.0);
        let b1 = ConditionsBucket::of(&loaded);
        cache.frontier(&ds, obj(), &space, &b1);
        assert_eq!(cache.len(), 1, "budget must evict down to one frontier");
        assert_eq!(cache.stats.evictions, 1);
        assert!(cache.resident_bytes() <= cache.mem_budget()
                || cache.len() == 1);
    }
}
