//! Cached Pareto frontiers: precompute the multi-objective front once per
//! (task, conditions-bucket), then make every adaptation decision an
//! O(frontier) walk (CARIn's fix for OODIn's per-event full re-search).
//!
//! **Dominance.**  Candidate p dominates q when both spend the *same
//! resources* — equal engine, recognition rate r and thread count — and p
//! is no worse on every objective dimension, strictly better on at least
//! one.  The objective dimensions are the targeted latency statistic, the
//! average latency (it drives every fps term, so it must be protected even
//! when the objective targets a tail statistic), energy, and *quality* —
//! accuracy ordered lexicographically with memory (strictly higher
//! accuracy wins; at exactly equal accuracy, not-larger memory wins).
//! Quality is lexicographic rather than two independent dimensions on
//! purpose: an ordered memory dimension would protect every
//! lower-precision variant from pruning (smaller weights), gutting the
//! frontier, while the lexicographic form prunes them through their
//! accuracy gap yet still keeps a smaller-memory variant whose accuracy
//! exactly ties.  Known trade-off: a variant that is strictly less
//! accurate *and* slower *and* hotter survives only through its accuracy
//! gap being real — if such a variant's sole advantage is memory, it is
//! pruned, so under extreme memory pressure the joint packer can reject
//! an app the raw (unpruned) ranking could still have degraded onto it.
//! The precision ladders that carry the practical memory fallbacks all
//! have genuine accuracy gaps and therefore survive.
//!
//! The resource triple is an equality *slice* rather than a set of
//! ordered dimensions because its "better" direction is
//! consumer-dependent: the GPU/NNAPI engines are exclusively owned in a
//! joint assignment, higher r means more fps for a solo app but more
//! engine time charged against the scheduler's utilisation budget, and
//! more threads mean lower latency but a bigger bite of the shared
//! CPU-core budget.  Slicing keeps every fallback ladder (alternative
//! engines, lower r, fewer threads, smaller variants) that engine
//! arbitration and admission control rely on, so one frontier serves both
//! the single-app selectors and the joint packer.  Slice-local dominance
//! also makes the *membership* of the frontier conditions-invariant:
//! external load and throttling scale every candidate on an engine by the
//! same multiplier, which can never flip a within-engine dominance — only
//! the scored ranking changes across buckets.
//!
//! **Exactness.**  The selection order ([`super::cmp_ranked`]) scores with
//! formulas that are monotone along every dominance dimension at fixed
//! (engine, r, threads), and its tie chain walks those same dimensions in
//! the dominating direction before any neutral tie-breaker.  Hence the
//! full-search arg-best is never dominated — it is always *on* the
//! frontier — and walking the frontier with the same order returns exactly
//! the full-search result (property-tested per objective, including
//! tail-statistic targets, in `tests/designspace_props.rs`).
//!
//! **Conditions buckets.**  Adjusted latency scales each engine by
//! `2^load / thermal`; the bucket quantises that per-engine multiplier in
//! half-doubling steps so one cached frontier serves every condition
//! vector in its bucket.  Both the frontier build and the subsequent walk
//! evaluate at the bucket's representative conditions, so the cached
//! selection equals a full search at those representative conditions.
//!
//! **Invalidation.**  The cache fingerprints the LUT and the registry;
//! when either changes (re-measurement, model-zoo update) every cached
//! frontier is dropped and rebuilt on demand.
//!
//! **Capacity.**  The cache is LRU-bounded
//! ([`FRONTIER_CACHE_DEFAULT_CAP`], overridable via
//! [`FrontierCache::with_cap`]): once one cache is shared across a whole
//! cohort of fleet devices ([`crate::fleet`]), the set of (task, bucket)
//! pairs its members visit can grow with the population, so resident
//! frontiers are capped and the least-recently-used one is evicted
//! (counted in [`CacheStats::evictions`]).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::device::EngineKind;
use crate::manager::Conditions;
use crate::measurements::Lut;
use crate::model::Registry;
use crate::optimizer::{Objective, SearchSpace};
use crate::perf;

use super::{cmp_ranked, rank, Candidate, DesignSpace};

/// Log2 width of one conditions-bucket step (half-doubling granularity:
/// multipliers within ~19% land in the same bucket).
pub const BUCKET_LOG2_STEP: f64 = 0.5;

/// A quantised per-engine condition vector: the cache key dimension that
/// lets one frontier serve a whole neighbourhood of condition vectors.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct ConditionsBucket {
    /// Quantised steps of log2(latency multiplier) per engine; engines at
    /// nominal conditions (step 0) are omitted.
    steps: BTreeMap<EngineKind, i32>,
}

impl ConditionsBucket {
    /// The bucket containing `conds`: per engine, the latency multiplier
    /// `2^load / thermal` quantised to [`BUCKET_LOG2_STEP`]-wide steps.
    pub fn of(conds: &Conditions) -> Self {
        let mut steps = BTreeMap::new();
        for e in EngineKind::ALL {
            let mult = perf::contention(conds.load(e))
                / conds.thermal_scale(e).max(1e-3);
            let step = (mult.log2() / BUCKET_LOG2_STEP).round() as i32;
            if step != 0 {
                steps.insert(e, step);
            }
        }
        ConditionsBucket { steps }
    }

    /// The bucket's representative conditions: each engine's multiplier is
    /// re-expressed as a pure load factor (`2^load`, cool thermal state) at
    /// the bucket's centre.
    pub fn representative(&self) -> Conditions {
        let mut conds = Conditions::idle();
        for (&e, &step) in &self.steps {
            conds.loads.insert(e, step as f64 * BUCKET_LOG2_STEP);
        }
        conds
    }

    /// True at nominal conditions on every engine.
    pub fn is_idle(&self) -> bool {
        self.steps.is_empty()
    }

    /// Canonical id, e.g. `cpu+2,nnapi+3` (`idle` when empty) — used in
    /// cache keys and experiment reports.
    pub fn id(&self) -> String {
        if self.steps.is_empty() {
            return "idle".to_string();
        }
        self.steps
            .iter()
            .map(|(e, s)| format!("{}{:+}", e.name(), s))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// True when `p` Pareto-dominates `q`: equal resource slice (engine,
/// recognition rate, thread count), no worse on every objective dimension
/// — targeted-statistic latency, average latency, energy, and quality
/// (accuracy, then memory at exactly equal accuracy) — strictly better on
/// at least one.
pub fn dominates(p: &Candidate, q: &Candidate) -> bool {
    if p.design.hw.engine != q.design.hw.engine
        || p.design.hw.recognition_rate != q.design.hw.recognition_rate
        || p.design.hw.threads != q.design.hw.threads
    {
        return false;
    }
    let quality_no_worse = p.accuracy > q.accuracy
        || (p.accuracy == q.accuracy && p.mem_bytes <= q.mem_bytes);
    let no_worse = p.latency_ms <= q.latency_ms
        && p.avg_latency_ms <= q.avg_latency_ms
        && p.energy_mj <= q.energy_mj
        && quality_no_worse;
    let strictly_better = p.latency_ms < q.latency_ms
        || p.avg_latency_ms < q.avg_latency_ms
        || p.energy_mj < q.energy_mj
        || p.accuracy > q.accuracy
        || (p.accuracy == q.accuracy && p.mem_bytes < q.mem_bytes);
    no_worse && strictly_better
}

/// A dominance-pruned design front for one (objective, search space) at
/// one conditions bucket, stored in canonical selection order.
#[derive(Debug, Clone)]
pub struct ParetoFrontier {
    /// The bucket this frontier was built at.
    pub bucket: ConditionsBucket,
    /// Non-dominated, objective-feasible candidates, best-first under
    /// [`cmp_ranked`] (scored at the bucket's representative conditions).
    points: Vec<Candidate>,
    /// Enumerated-space size after constraint pre-filtering — the
    /// per-event cost a full search would pay.
    pub space_size: usize,
}

impl ParetoFrontier {
    /// Enumerate the space at the bucket's representative conditions,
    /// prune dominated candidates, and rank the survivors.
    pub fn build(space: &DesignSpace, objective: Objective,
                 sspace: &SearchSpace, bucket: &ConditionsBucket) -> Self {
        let conds = bucket.representative();
        let cands = space.enumerate(objective, sspace, &conds);
        let space_size = cands.len();
        let survivors: Vec<Candidate> = cands
            .iter()
            .filter(|q| !cands.iter().any(|p| dominates(p, q)))
            .cloned()
            .collect();
        ParetoFrontier {
            bucket: bucket.clone(),
            points: rank(survivors, objective),
            space_size,
        }
    }

    /// The frontier points, best-first under the canonical selection
    /// order.
    pub fn points(&self) -> &[Candidate] {
        &self.points
    }

    /// Number of frontier points — the per-event cost of a frontier walk.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when no feasible design survives (e.g. an unknown family or an
    /// unreachable latency target).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The frontier-walk selection: the best feasible candidate, already
    /// front-of-list by construction.
    pub fn best(&self) -> Option<&Candidate> {
        self.points.first()
    }
}

/// Cache effectiveness counters, reported by `oodin opt-bench` and
/// `oodin fleet-bench`.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Frontier builds (cache misses).
    pub builds: u64,
    /// Cache hits (adaptation events served without a build).
    pub hits: u64,
    /// Whole-cache invalidations from a LUT / registry change.
    pub invalidations: u64,
    /// Candidates enumerated across all builds (the amortised build cost).
    pub candidates_enumerated: u64,
    /// Frontiers dropped by the LRU capacity bound.
    pub evictions: u64,
}

/// Default LRU capacity of a [`FrontierCache`]: generous enough that the
/// single-device paths (a handful of tasks × conditions buckets) never
/// evict, while bounding memory when one cache is shared across a whole
/// cohort of fleet devices.
pub const FRONTIER_CACHE_DEFAULT_CAP: usize = 1024;

/// The frontier cache: one [`ParetoFrontier`] per (task, bucket), keyed by
/// a canonical task tag, fingerprint-invalidated when the LUT or registry
/// changes, and LRU-bounded to `cap` resident frontiers.
#[derive(Debug)]
pub struct FrontierCache {
    fingerprint: u64,
    /// (task, bucket) -> (frontier, last-use tick) — the tick drives LRU
    /// eviction once `cap` is reached.
    map: BTreeMap<(String, String), (Arc<ParetoFrontier>, u64)>,
    tick: u64,
    cap: usize,
    /// Effectiveness counters since construction.
    pub stats: CacheStats,
}

impl Default for FrontierCache {
    fn default() -> Self {
        FrontierCache {
            fingerprint: 0,
            map: BTreeMap::new(),
            tick: 0,
            cap: FRONTIER_CACHE_DEFAULT_CAP,
            stats: CacheStats::default(),
        }
    }
}

/// Canonical cache tag of one search task (objective + space restriction +
/// camera rate — the last caps every fps score, so spaces differing only
/// in camera rate must not share frontiers).  `Objective` and
/// `SearchSpace` carry floats, so a formatted tag stands in for
/// `Ord`/`Hash` keys.
pub fn task_tag(objective: Objective, space: &SearchSpace, camera_fps: f64)
                -> String {
    format!(
        "{objective:?}|fam={:?}|eng={:?}|prec={:?}|r={:?}|cam={camera_fps}",
        space.family, space.engines, space.precisions, space.recognition_rate
    )
}

/// FNV-1a fingerprint of the (LUT, registry) pair driving every frontier;
/// any drift in either invalidates the whole cache.  Allocation-free and
/// a plain linear read (~ns per entry), so recomputing it per lookup
/// stays far below the enumeration + scoring + sorting cost the cache
/// exists to avoid.
pub fn fingerprint(lut: &Lut, registry: &Registry) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    eat(lut.device.as_bytes());
    for (k, e) in &lut.entries {
        eat(k.variant.as_bytes());
        eat(&[k.engine as u8, k.governor as u8]);
        eat(&(k.threads as u64).to_le_bytes());
        eat(&e.latency.avg.to_bits().to_le_bytes());
        eat(&e.latency.p90.to_bits().to_le_bytes());
        eat(&e.latency.p99.to_bits().to_le_bytes());
        eat(&e.accuracy.to_bits().to_le_bytes());
        eat(&e.mem_bytes.to_le_bytes());
    }
    for v in registry.variants() {
        eat(v.name.as_bytes());
        eat(&v.accuracy.to_bits().to_le_bytes());
        eat(&v.size_bytes.to_le_bytes());
    }
    h
}

impl FrontierCache {
    /// An empty cache at the default LRU capacity.
    pub fn new() -> Self {
        FrontierCache::default()
    }

    /// Override the LRU capacity (0 disables the bound).  Evicting the
    /// least-recently-used frontier keeps a cohort-shared cache's memory
    /// proportional to its working set of (task, bucket) pairs rather than
    /// to everything any member ever visited.
    pub fn with_cap(mut self, cap: usize) -> Self {
        self.cap = cap;
        self
    }

    /// The active LRU capacity (0 = unbounded).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// The cached frontier for (objective, space restriction, camera rate,
    /// bucket), building it on first use and whenever the LUT or registry
    /// changed since the last call.  Every lookup re-runs the
    /// [`fingerprint`] guard — an O(LUT + registry) branch-free linear
    /// read (no allocation), orders of magnitude cheaper than the
    /// enumeration + scoring + sort a miss would pay, but not free; the
    /// `opt-bench` cost model counts scored candidates only and excludes
    /// this guard.
    pub fn frontier(&mut self, space: &DesignSpace, objective: Objective,
                    sspace: &SearchSpace, bucket: &ConditionsBucket)
                    -> Arc<ParetoFrontier> {
        let fp = fingerprint(space.lut, space.registry);
        if fp != self.fingerprint {
            if self.fingerprint != 0 && !self.map.is_empty() {
                self.stats.invalidations += 1;
            }
            self.map.clear();
            self.fingerprint = fp;
        }
        let key = (task_tag(objective, sspace, space.camera_fps), bucket.id());
        self.tick += 1;
        let tick = self.tick;
        if let Some((f, used)) = self.map.get_mut(&key) {
            *used = tick;
            self.stats.hits += 1;
            return Arc::clone(f);
        }
        if self.cap > 0 && self.map.len() >= self.cap {
            // Evict the least-recently-used frontier (linear scan: the map
            // is at most `cap` entries and eviction is the rare path).
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&lru);
                self.stats.evictions += 1;
            }
        }
        let f = Arc::new(ParetoFrontier::build(space, objective, sspace, bucket));
        self.stats.builds += 1;
        self.stats.candidates_enumerated += f.space_size as u64;
        self.map.insert(key, (Arc::clone(&f), tick));
        f
    }

    /// Cached frontiers currently resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True before the first build (or right after an invalidation).
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::manager::Conditions;
    use crate::measurements::Measurer;
    use crate::model::test_fixtures::fake_registry;
    use crate::util::stats::Percentile;

    fn obj() -> Objective {
        Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }
    }

    #[test]
    fn bucket_quantises_and_represents() {
        let mut conds = Conditions::idle();
        conds.loads.insert(EngineKind::Gpu, 1.0);
        let b = ConditionsBucket::of(&conds);
        assert!(!b.is_idle());
        assert_eq!(b.id(), "gpu+2");
        let rep = b.representative();
        assert!((rep.load(EngineKind::Gpu) - 1.0).abs() < 1e-12);
        assert_eq!(ConditionsBucket::of(&rep), b, "representative re-buckets");
        assert!(ConditionsBucket::of(&Conditions::idle()).is_idle());
    }

    #[test]
    fn thermal_throttle_lands_in_load_bucket() {
        // thermal 0.5 halves the clock: multiplier 2 == load 1.0.
        let mut hot = Conditions::idle();
        hot.thermal.insert(EngineKind::Npu, 0.5);
        let mut loaded = Conditions::idle();
        loaded.loads.insert(EngineKind::Npu, 1.0);
        assert_eq!(ConditionsBucket::of(&hot), ConditionsBucket::of(&loaded));
    }

    #[test]
    fn frontier_smaller_than_space_and_selects_best() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap();
        let ds = DesignSpace::new(&dev, &reg, &lut);
        let space = SearchSpace::family("mobilenet_v2_100");
        let b = ConditionsBucket::of(&Conditions::idle());
        let f = ParetoFrontier::build(&ds, obj(), &space, &b);
        assert!(!f.is_empty());
        assert!(f.len() < f.space_size,
                "frontier {} !< space {}", f.len(), f.space_size);
        let full = rank(ds.enumerate(obj(), &space, &Conditions::idle()), obj());
        assert_eq!(f.best().unwrap().design, full[0].design);
    }

    #[test]
    fn camera_rate_gets_its_own_frontier() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let b = ConditionsBucket::of(&Conditions::idle());
        let mut cache = FrontierCache::new();
        let obj = Objective::MaxFps { epsilon: 0.05 };
        let ds30 = DesignSpace::new(&dev, &reg, &lut);
        let ds60 = DesignSpace::new(&dev, &reg, &lut).with_camera_fps(60.0);
        let f30 = cache.frontier(&ds30, obj, &space, &b);
        let f60 = cache.frontier(&ds60, obj, &space, &b);
        assert_eq!(cache.stats.builds, 2, "camera rates must not share");
        assert!(f30.best().unwrap().fps <= 30.0 + 1e-9);
        assert!(f60.best().unwrap().fps > 30.0);
    }

    #[test]
    fn lru_cap_bounds_residency_and_counts_evictions() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let ds = DesignSpace::new(&dev, &reg, &lut);
        let mut cache = FrontierCache::new().with_cap(2);
        assert_eq!(cache.cap(), 2);
        // Visit three distinct buckets: the third build must evict the
        // least-recently-used (the first) while staying at cap residency.
        let buckets: Vec<ConditionsBucket> = [0.0, 1.0, 2.0]
            .iter()
            .map(|&l| {
                let mut c = Conditions::idle();
                c.loads.insert(EngineKind::Cpu, l);
                ConditionsBucket::of(&c)
            })
            .collect();
        for b in &buckets {
            cache.frontier(&ds, obj(), &space, b);
        }
        assert_eq!(cache.len(), 2, "residency must not exceed the cap");
        assert_eq!(cache.stats.builds, 3);
        assert_eq!(cache.stats.evictions, 1);
        // The survivors still hit; the evicted bucket rebuilds.
        cache.frontier(&ds, obj(), &space, &buckets[2]);
        assert_eq!(cache.stats.hits, 1);
        cache.frontier(&ds, obj(), &space, &buckets[0]);
        assert_eq!(cache.stats.builds, 4, "evicted frontier must rebuild");
        assert_eq!(cache.stats.evictions, 2);
    }

    #[test]
    fn cache_hits_and_fingerprint_invalidation() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let space = SearchSpace::family("mobilenet_v2_100");
        let b = ConditionsBucket::of(&Conditions::idle());
        let mut cache = FrontierCache::new();
        {
            let ds = DesignSpace::new(&dev, &reg, &lut);
            cache.frontier(&ds, obj(), &space, &b);
            cache.frontier(&ds, obj(), &space, &b);
        }
        assert_eq!(cache.stats.builds, 1);
        assert_eq!(cache.stats.hits, 1);
        // Perturb one LUT entry: the whole cache must invalidate.
        let mut lut2 = lut.clone();
        let k = lut2.entries.keys().next().unwrap().clone();
        lut2.entries.get_mut(&k).unwrap().accuracy += 0.001;
        let ds2 = DesignSpace::new(&dev, &reg, &lut2);
        cache.frontier(&ds2, obj(), &space, &b);
        assert_eq!(cache.stats.invalidations, 1);
        assert_eq!(cache.stats.builds, 2);
        assert_eq!(cache.len(), 1, "stale frontiers dropped");
    }
}
