//! The OODIn mobile Application (paper §III-B2, online component).
//!
//! Wires the layered architecture end-to-end: SIL blocks (camera, gallery,
//! UI) on top, DLACL in the middle (input pipeline, buffers, model swaps),
//! MDCL at the bottom (resource detection, middlewares a/b/c), with the
//! Runtime Manager observing middleware-c statistics and issuing
//! reconfigurations.
//!
//! Numerics flow through the configured execution [`Backend`]
//! (`real_exec`): the PJRT artifacts when available, the deterministic
//! `SimBackend` otherwise — while device latency, thermal state and
//! contention evolve on the simulated device timeline (DESIGN.md
//! §Substitutions).  Scenario events inject the Fig 7/8 conditions (engine
//! load ramps; thermal stress emerges by itself from sustained work).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::device::{DeviceProfile, EngineKind};
use crate::devicesim::DeviceSim;
use crate::dlacl::{decode_top1, ModelSlot};
use crate::manager::{Policy, RuntimeManager, Switch};
use crate::mdcl;
use crate::measurements::{Lut, Measurer};
use crate::model::{Registry, Task};
use crate::optimizer::{Design, Objective, Optimizer, SearchSpace};
use crate::runtime::{self, Backend};
use crate::sil::{Gallery, SyntheticCamera, UiStub};
use crate::util::clock::Clock;

/// Application configuration (what the developer ships + OODIn's chosen σ).
#[derive(Clone)]
pub struct AppConfig {
    /// Target device profile name.
    pub device: String,
    /// The app's performance objective.
    pub objective: Objective,
    /// Candidate-space restrictions (usually the app's model family).
    pub space: SearchSpace,
    /// Camera capture rate (frames/s).
    pub camera_fps: f64,
    /// Execute backend numerics per processed frame (PJRT when artifacts
    /// exist, SimBackend otherwise).
    pub real_exec: bool,
    /// Echo UI events to stdout.
    pub live_ui: bool,
    /// Measurement runs when building the LUT (paper default 200).
    pub lut_runs: usize,
    /// Runtime Manager adaptation policy.
    pub policy: Policy,
    /// Synthetic camera RNG seed.
    pub camera_seed: u64,
}

impl AppConfig {
    /// Defaults: 30 fps camera, real execution, 60-run LUT, seed 42.
    pub fn new(device: &str, objective: Objective, space: SearchSpace) -> Self {
        AppConfig {
            device: device.to_string(),
            objective,
            space,
            camera_fps: 30.0,
            real_exec: true,
            live_ui: false,
            lut_runs: 60,
            policy: Policy::default(),
            camera_seed: 42,
        }
    }
}

/// A scheduled condition change (Fig 7's load ramp).
#[derive(Debug, Clone)]
pub enum ScenarioEvent {
    /// Inject external engine load before a given frame.
    SetLoad {
        /// Frame index the load appears at.
        at_frame: u64,
        /// Loaded engine.
        engine: EngineKind,
        /// Load factor (latency multiplier 2^load).
        load: f64,
    },
}

/// The canonical multi-app workload mix (the `multi` CLI scenario): up to
/// four co-resident apps drawn from the paper's use-cases — an AI camera
/// (latency-critical), a video conference (throughput), a gallery tagger
/// and a scene segmenter.  Each app's SLO latency bound is set relative to
/// its *solo-optimal* latency on this (device, LUT): `slo_factor` × solo —
/// tight enough that naive co-location violates it under engine
/// contention.  Families that are not in the registry or not deployable on
/// the device are skipped, so the mix degrades gracefully on low-end
/// profiles.
pub fn multi_scenario(n: usize, device: &DeviceProfile, registry: &Registry,
                      lut: &Lut, slo_factor: f64)
                      -> Vec<crate::scheduler::WorkloadDescriptor> {
    use crate::util::stats::Percentile;
    let mix: [(&str, &str, f64, Objective); 4] = [
        ("ai_camera", "mobilenet_v2_100", 60.0,
         Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }),
        ("video_conference", "efficientnet_lite4", 30.0,
         Objective::MaxFps { epsilon: 0.05 }),
        ("gallery_tagger", "inception_v3", 15.0,
         Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }),
        ("scene_segmenter", "deeplab_v3", 10.0,
         Objective::MinLatency { stat: Percentile::P90, epsilon: 0.05 }),
    ];
    let opt = Optimizer::new(device, registry, lut);
    let mut out = Vec::new();
    for (app_id, family, arrival_fps, objective) in mix.into_iter().take(n) {
        let Ok(solo) = opt.optimize(objective, &SearchSpace::family(family))
        else {
            continue; // family absent or undeployable on this device
        };
        out.push(crate::scheduler::WorkloadDescriptor {
            app_id: app_id.to_string(),
            family: family.to_string(),
            arrival_fps,
            objective,
            slo_latency_ms: solo.latency_ms * slo_factor,
        });
    }
    out
}

/// Per-frame record emitted by the application loop.
#[derive(Debug, Clone)]
pub struct FrameRecord {
    /// Camera sequence number.
    pub seq: u64,
    /// Capture timestamp on the device timeline (ms).
    pub ts_ms: f64,
    /// Simulated device latency of this inference (ms).
    pub latency_ms: f64,
    /// Real host PJRT latency, when real_exec is on.
    pub host_ms: Option<f64>,
    /// Engine the inference ran on.
    pub engine: EngineKind,
    /// Variant that served the frame.
    pub variant: String,
    /// Decoded top-1 class (None without real execution).
    pub predicted: Option<usize>,
    /// Ground-truth class of the synthetic frame.
    pub label: usize,
    /// Whether predicted == label (None without real execution).
    pub correct: Option<bool>,
    /// A reconfiguration decided right after this frame.
    pub switch: Option<Switch>,
    /// Active-engine temperature after the frame (deg C).
    pub temp_c: f64,
}

/// The assembled application.
pub struct Application {
    /// The configuration the app was built from.
    pub cfg: AppConfig,
    /// Detected resource model R.
    pub profile: Arc<DeviceProfile>,
    /// The model space M.
    pub registry: Arc<Registry>,
    /// Device Measurements output.
    pub lut: Arc<Lut>,
    /// The simulated device timeline.
    pub sim: DeviceSim,
    /// The adaptation state machine.
    pub manager: RuntimeManager,
    /// SIL camera block.
    pub camera: SyntheticCamera,
    /// SIL gallery block.
    pub gallery: Gallery,
    /// SIL UI block.
    pub ui: UiStub,
    backend: Option<Arc<dyn Backend>>,
    slot: Option<ModelSlot>,
    frames_seen: u64,
    frames_processed: u64,
}

impl Application {
    /// Build the app: detect resources (MDCL), run Device Measurements,
    /// System Optimisation, then initialise SIL + DLACL around the selected
    /// design σ.
    pub fn build(cfg: AppConfig, registry: Registry) -> Result<Self> {
        let profile = Arc::new(mdcl::detect(&cfg.device)?);
        let registry = Arc::new(registry);

        // Offline component: measurements + optimisation.
        let lut = Arc::new(
            Measurer::new(&profile, &registry)
                .with_runs(cfg.lut_runs, (cfg.lut_runs / 10).max(1))
                .measure_all()?,
        );
        let opt = Optimizer::new(&profile, &registry, &lut)
            .with_camera_fps(cfg.camera_fps);
        let initial = opt.optimize(cfg.objective, &cfg.space)?.design;

        // Online component.
        let hw_info = mdcl::middleware_a(&profile);
        let variant = registry.get(&initial.variant).unwrap();
        let mut camera = SyntheticCamera::new(
            variant.resolution.max(16),
            cfg.camera_fps.min(hw_info.camera.max_fps),
            cfg.camera_seed,
        );
        camera.fps = cfg.camera_fps.min(hw_info.camera.max_fps);

        let (backend, slot) = if cfg.real_exec {
            let be = runtime::default_backend(&profile, &registry)?;
            let mut slot = ModelSlot::new(Arc::clone(&be), profile.mem_budget_bytes);
            slot.swap_to(&registry, &initial.variant)
                .context("loading initial model")?;
            (Some(be), Some(slot))
        } else {
            (None, None)
        };

        let manager = RuntimeManager::new(
            Arc::clone(&profile),
            Arc::clone(&registry),
            Arc::clone(&lut),
            cfg.objective,
            cfg.space.clone(),
            initial.clone(),
        )
        .with_policy(cfg.policy.clone());

        let mut ui = UiStub::new(cfg.live_ui);
        ui.set_banner(format!(
            "{} | {} | {} thr={} gov={} r={}",
            profile.name,
            initial.variant,
            initial.hw.engine.name(),
            initial.hw.threads,
            initial.hw.governor.name(),
            initial.hw.recognition_rate,
        ));

        Ok(Application {
            gallery: Gallery::temp(&format!("app_{}", cfg.device))?,
            sim: DeviceSim::new((*profile).clone(), Clock::sim()),
            cfg,
            profile,
            registry,
            lut,
            manager,
            camera,
            ui,
            backend,
            slot,
            frames_seen: 0,
            frames_processed: 0,
        })
    }

    /// The design currently resident in DLACL.
    pub fn current_design(&self) -> &Design {
        self.manager.current()
    }

    /// Apply a reconfiguration: DLACL swaps the model if it changed.
    fn apply_switch(&mut self, sw: &Switch) -> Result<()> {
        if sw.from.variant != sw.to.variant {
            if let Some(slot) = self.slot.as_mut() {
                slot.swap_to(&self.registry, &sw.to.variant)?;
            }
        }
        self.ui.set_banner(format!(
            "{} | {} | {} thr={} gov={} ({:?}, detected in {:.0} ms)",
            self.profile.name,
            sw.to.variant,
            sw.to.hw.engine.name(),
            sw.to.hw.threads,
            sw.to.hw.governor.name(),
            sw.reason,
            sw.detection_ms,
        ));
        Ok(())
    }

    /// Process `n_frames` camera frames, applying scenario events.  Returns
    /// one record per *processed* frame (recognition rate subsamples).
    pub fn run(&mut self, n_frames: u64, scenario: &[ScenarioEvent])
               -> Result<Vec<FrameRecord>> {
        let mut records = Vec::new();
        let interval = self.camera.frame_interval_ms();

        for i in 0..n_frames {
            // Scenario injections scheduled for this frame index.
            for ev in scenario {
                match ev {
                    ScenarioEvent::SetLoad { at_frame, engine, load }
                        if *at_frame == i =>
                    {
                        self.sim.set_load(*engine, *load);
                        self.ui.event(format!(
                            "scenario: load({})={:.2} at frame {}",
                            engine.name(), load, i
                        ));
                    }
                    _ => {}
                }
            }

            let ts = self.sim.clock.now_ms();
            let frame = self.camera.capture(ts);
            self.frames_seen += 1;

            // Recognition rate r: process every (1/r)-th frame.
            let design = self.manager.current().clone();
            let stride = (1.0 / design.hw.recognition_rate).round().max(1.0) as u64;
            if (self.frames_seen - 1) % stride != 0 {
                self.sim.idle(interval);
                continue;
            }

            let v = self
                .registry
                .get(&design.variant)
                .context("current design variant not in registry")?
                .clone();
            let exec = self.sim.run_inference(
                &v,
                design.hw.engine,
                design.hw.threads,
                design.hw.governor,
            )?;
            self.frames_processed += 1;

            // Real numerics through the AOT artifact.
            let (host_ms, predicted, correct) = if let Some(slot) = self.slot.as_mut() {
                let out = slot.infer(&frame.data, frame.height, frame.width)?;
                let (cls, conf) = match v.task {
                    Task::Classification => decode_top1(&out.values, 10),
                    Task::Segmentation => (0, 0.0),
                };
                if v.task == Task::Classification {
                    self.gallery.add(&crate::sil::GalleryEntry {
                        ts_ms: ts,
                        seq: frame.seq,
                        predicted_class: cls,
                        confidence: conf as f64,
                        model: v.name.clone(),
                        engine: design.hw.engine.name().to_string(),
                    })?;
                    // Middleware b: DNN-output-driven feature tuning.
                    if let Some(adj) = mdcl::middleware_b(cls, conf) {
                        self.camera.exposure = adj.camera_exposure;
                    }
                    (Some(out.host_ms), Some(cls), Some(cls == frame.label))
                } else {
                    (Some(out.host_ms), None, None)
                }
            } else {
                (None, None, None)
            };

            // Middleware c -> Runtime Manager.
            let report = mdcl::middleware_c(
                &self.sim,
                self.slot.as_ref().map_or(0, |s| s.resident_bytes()),
            );
            self.manager.record_latency(exec.latency_ms);
            let sw = self.manager.observe(report.at_ms, &report.conditions);
            if let Some(sw) = &sw {
                self.apply_switch(sw)?;
                self.ui.event(format!(
                    "switch @{:.0}ms: {} -> {} ({:?})",
                    sw.at_ms,
                    sw.from.hw.engine.name(),
                    sw.to.hw.engine.name(),
                    sw.reason
                ));
            }

            records.push(FrameRecord {
                seq: frame.seq,
                ts_ms: ts,
                latency_ms: exec.latency_ms,
                host_ms,
                engine: design.hw.engine,
                variant: design.variant.clone(),
                predicted,
                label: frame.label,
                correct,
                switch: sw,
                temp_c: exec.temp_c,
            });

            // Idle out the rest of the frame slot, if any.
            let spare = interval - exec.latency_ms;
            if spare > 0.0 {
                self.sim.idle(spare);
            }
        }
        Ok(records)
    }

    /// Release the execution backend.
    pub fn shutdown(self) {
        if let Some(be) = self.backend {
            be.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;
    use crate::util::stats::Percentile;

    fn cfg(device: &str) -> AppConfig {
        let mut c = AppConfig::new(
            device,
            Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 },
            SearchSpace::family("mobilenet_v2_100"),
        );
        c.real_exec = false; // latency-only runs keep these tests fast
        c.lut_runs = 20;
        c
    }

    #[test]
    fn build_selects_a_design_and_runs() {
        let mut app = Application::build(cfg("samsung_a71"), fake_registry()).unwrap();
        let recs = app.run(30, &[]).unwrap();
        assert_eq!(recs.len(), 30); // r=1 on a fast pair
        assert!(recs.iter().all(|r| r.latency_ms > 0.0));
        assert!(app.frames_processed > 0);
    }

    #[test]
    fn load_scenario_triggers_engine_migration() {
        let mut app = Application::build(cfg("samsung_a71"), fake_registry()).unwrap();
        let e0 = app.current_design().hw.engine;
        let scenario = vec![ScenarioEvent::SetLoad {
            at_frame: 10,
            engine: e0,
            load: 3.0,
        }];
        let recs = app.run(200, &scenario).unwrap();
        let switched: Vec<_> = recs.iter().filter(|r| r.switch.is_some()).collect();
        assert!(!switched.is_empty(), "no switch under 8x load");
        assert_ne!(app.current_design().hw.engine, e0);
    }

    #[test]
    fn recognition_rate_subsamples_frames() {
        let mut c = cfg("sony_c5");
        // Force r < 1 by fixing it in the search space.
        c.space.recognition_rate = Some(0.5);
        let mut app = Application::build(c, fake_registry()).unwrap();
        let recs = app.run(40, &[]).unwrap();
        assert_eq!(recs.len(), 20);
    }

    #[test]
    fn sim_clock_advances_through_run() {
        let mut app = Application::build(cfg("samsung_s20_fe"), fake_registry()).unwrap();
        app.run(15, &[]).unwrap();
        // >= 15 frame intervals at 30 fps
        assert!(app.sim.clock.now_ms() >= 14.0 * 33.0);
    }

    #[test]
    fn multi_scenario_sets_slos_from_solo_latency() {
        let reg = fake_registry();
        let dev = crate::device::profiles::samsung_a71();
        let lut = crate::measurements::Measurer::new(&dev, &reg)
            .with_runs(20, 2)
            .measure_all()
            .unwrap();
        let descs = multi_scenario(4, &dev, &reg, &lut, 2.0);
        assert_eq!(descs.len(), 4);
        let opt = Optimizer::new(&dev, &reg, &lut);
        for d in &descs {
            let solo = opt
                .optimize(d.objective, &SearchSpace::family(&d.family))
                .unwrap();
            assert!((d.slo_latency_ms - 2.0 * solo.latency_ms).abs() < 1e-9,
                    "{}", d.app_id);
            assert!(d.arrival_fps > 0.0);
        }
        // Requesting fewer apps trims the mix from the front.
        assert_eq!(multi_scenario(2, &dev, &reg, &lut, 2.0).len(), 2);
    }

    #[test]
    fn hermetic_real_exec_runs_backend_numerics() {
        // real_exec with no artifacts: the app must wire in SimBackend and
        // produce per-frame numerics with plausible online accuracy.
        let mut c = cfg("samsung_a71");
        c.real_exec = true;
        let mut app = Application::build(c, fake_registry()).unwrap();
        let recs = app.run(60, &[]).unwrap();
        assert!(!recs.is_empty());
        assert!(recs.iter().all(|r| r.host_ms.is_some()), "backend numerics missing");
        let scored: Vec<bool> = recs.iter().filter_map(|r| r.correct).collect();
        assert!(!scored.is_empty());
        let acc = scored.iter().filter(|&&c| c).count() as f64 / scored.len() as f64;
        assert!(acc > 0.5, "online accuracy collapsed: {acc}");
        assert!(app.gallery.len() > 0);
        app.shutdown();
    }
}
