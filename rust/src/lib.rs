//! # OODIn — Optimised On-Device Inference for Heterogeneous Mobile Devices
//!
//! A Rust + JAX + Pallas reproduction of *OODIn* (Venieris, Panopoulos,
//! Venieris, 2021).  Python authors and AOT-compiles the model zoo once
//! (`make artifacts`); this crate is the entire online system.
//!
//! ## Execution backends
//!
//! Every layer above [`runtime`] talks to the execution engine through the
//! [`runtime::Backend`] trait — the swappable-engine seam the paper's
//! multi-layer architecture is built around:
//!
//! * **`SimBackend`** (default): deterministic and hermetic.  Outputs are
//!   synthesised from the synthetic scene model at manifest-accurate top-1
//!   rates; latencies come from the `perf` roofline + `devicesim`
//!   contention/thermal + `dvfs` governor state.  `cargo test` passes with
//!   no Python, no XLA and no `artifacts/` directory.
//! * **PJRT** (`--features pjrt`): the real executor thread compiling and
//!   running the AOT HLO-text artifacts on the host CPU client.
//!
//! See `rust/README.md` for the hermetic vs. artifact-backed test matrix.
//!
//! ## Layers
//!
//! * [`model`] — the model tuple `m = <task, w, s_m, s_in, a, p>` and the
//!   variant registry loaded from `artifacts/manifest.json` (or the
//!   synthetic fixture registry in hermetic mode).
//! * [`device`] — the resource model `R = <CE, N_cores, C, DVFS, b, v_os,
//!   v_camera>` with the three Table I phone profiles.
//! * [`perf`] / [`dvfs`] / [`devicesim`] — the heterogeneous-hardware
//!   substrate: roofline engine model, governors, thermal RC, contention.
//! * [`runtime`] — the [`runtime::Backend`] trait + its PJRT and simulator
//!   implementations.
//! * [`measurements`] — Device Measurements sweeps -> look-up tables.
//! * [`designspace`] — the unified design-space layer: one σ-space
//!   enumeration with constraint pre-filtering, the canonical selection
//!   order, and cached Pareto frontiers per (task, conditions-bucket) —
//!   the O(frontier) re-adaptation substrate every search layer shares.
//! * [`optimizer`] — System Optimisation: the MOO formulations of Eq. 3-5
//!   and the enumerative LUT search (over the design-space layer).
//! * [`manager`] — the Runtime Manager's adaptation state machine
//!   (frontier-walk re-search).
//! * [`scheduler`] — the multi-app layer: N concurrent DL apps with
//!   per-app SLOs, joint (σ₁…σ_N) optimisation under global resource
//!   constraints, time-sliced engine arbitration with admission control,
//!   and coordinated joint re-adaptation.
//! * [`fleet`] — the population layer: seeded device-population sampling
//!   from the Table I archetypes, cross-device LUT transfer (roofline-
//!   ratio scaling + confidence-gated probe fallback), and device cohorts
//!   sharing one transferred LUT and one LRU-bounded frontier cache each,
//!   so profiling and Pareto builds amortise across thousands of devices.
//! * [`sil`] / [`dlacl`] / [`mdcl`] — the multi-layer mobile software
//!   architecture (Fig 2).
//! * [`app`] — the assembled Application; [`serving`] — the async serving
//!   pipeline (bounded deadline queue → dynamic batcher → per-engine
//!   worker lanes, single- and multi-app, with load shedding and a
//!   degraded-ladder brownout mode); [`experiments`] — drivers
//!   regenerating every table/figure of the paper's evaluation plus the
//!   multi-app contention table and the serve-bench latency/throughput
//!   curves.
//!
//! `docs/ARCHITECTURE.md` has the full layer diagram and the paper-to-code
//! mapping table.

#![warn(missing_docs)]

pub mod app;
pub mod config;
pub mod designspace;
pub mod device;
pub mod devicesim;
pub mod dlacl;
pub mod dvfs;
pub mod experiments;
pub mod fleet;
pub mod manager;
pub mod mdcl;
pub mod measurements;
pub mod model;
pub mod optimizer;
pub mod perf;
pub mod runtime;
pub mod scheduler;
pub mod serving;
pub mod sil;
pub mod telemetry;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory by walking up from the current directory
/// (so examples/benches work from any workspace subdirectory); `None` when
/// no `artifacts/manifest.json` exists anywhere up the tree.
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let candidate = dir.join(ARTIFACTS_DIR);
        if candidate.join("manifest.json").exists() {
            return Some(candidate);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Load the model registry from the conventional artifacts location.
pub fn load_registry() -> anyhow::Result<model::Registry> {
    match find_artifacts_dir() {
        Some(dir) => model::Registry::load(dir),
        None => anyhow::bail!(
            "artifacts/manifest.json not found; run `make artifacts` first"
        ),
    }
}

/// Load the real registry when `make artifacts` has been run, the synthetic
/// fixture registry otherwise — the entry point the CLI, benches and
/// integration tests use so the whole stack runs hermetically on
/// `SimBackend` when no artifacts exist.  A manifest that exists but fails
/// to load is a real error, not a reason to silently switch to the
/// synthetic zoo.
pub fn load_registry_or_synthetic() -> anyhow::Result<model::Registry> {
    match find_artifacts_dir() {
        Some(dir) => model::Registry::load(dir),
        None => {
            eprintln!(
                "note: artifacts/manifest.json not found — running hermetically \
                 on the synthetic registry + SimBackend"
            );
            Ok(model::test_fixtures::fake_registry())
        }
    }
}
