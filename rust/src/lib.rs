//! # OODIn — Optimised On-Device Inference for Heterogeneous Mobile Devices
//!
//! A Rust + JAX + Pallas reproduction of *OODIn* (Venieris, Panopoulos,
//! Venieris, 2021).  Python authors and AOT-compiles the model zoo once
//! (`make artifacts`); this crate is the entire online system:
//!
//! * [`model`] — the model tuple `m = <task, w, s_m, s_in, a, p>` and the
//!   variant registry loaded from `artifacts/manifest.json`.
//! * [`device`] — the resource model `R = <CE, N_cores, C, DVFS, b, v_os,
//!   v_camera>` with the three Table I phone profiles.
//! * [`perf`] / [`dvfs`] / [`devicesim`] — the heterogeneous-hardware
//!   substrate: roofline engine model, governors, thermal RC, contention.
//! * [`runtime`] — the PJRT executor (HLO-text artifacts, CPU client).
//! * [`measurements`] — Device Measurements sweeps -> look-up tables.
//! * [`optimizer`] — System Optimisation: the MOO formulations of Eq. 3-5
//!   and the enumerative LUT search.
//! * [`manager`] — the Runtime Manager's adaptation state machine.
//! * [`sil`] / [`dlacl`] / [`mdcl`] — the multi-layer mobile software
//!   architecture (Fig 2).
//! * [`app`] — the assembled Application; [`serving`] — the batched
//!   request front-end; [`experiments`] — drivers regenerating every
//!   table/figure of the paper's evaluation.

pub mod app;
pub mod config;
pub mod device;
pub mod devicesim;
pub mod dlacl;
pub mod dvfs;
pub mod experiments;
pub mod manager;
pub mod mdcl;
pub mod measurements;
pub mod model;
pub mod optimizer;
pub mod perf;
pub mod runtime;
pub mod serving;
pub mod sil;
pub mod telemetry;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Load the model registry from the conventional artifacts location,
/// walking up from the current directory so examples/benches work from any
/// workspace subdirectory.
pub fn load_registry() -> anyhow::Result<model::Registry> {
    let mut dir = std::env::current_dir()?;
    loop {
        let candidate = dir.join(ARTIFACTS_DIR).join("manifest.json");
        if candidate.exists() {
            return model::Registry::load(dir.join(ARTIFACTS_DIR));
        }
        if !dir.pop() {
            anyhow::bail!(
                "artifacts/manifest.json not found; run `make artifacts` first"
            );
        }
    }
}
