//! OODIn command-line launcher.
//!
//! Subcommands (hand-rolled parser — no clap on this offline image):
//!
//! ```text
//! oodin report  --table1 | --table2          Regenerate the paper's tables
//! oodin exp     fig3|fig4|fig5|fig6|fig7|fig8 [--real]   Regenerate a figure
//! oodin measure --device <name> [--out lut.json] [--host-calibrated]
//! oodin optimize --use-case <file.json>      Run System Optimisation
//! oodin resources                            Print the detected R per device
//! oodin serve   --family <f> [--precision p] [--requests n] [--device d]
//! oodin serve-bench [--smoke] [--device d] [--rate r] [--duration ms] [--json f] [--trace f]
//! oodin multi   [--smoke] [--device d] [--apps n] [--windows w] [--json f]
//! oodin opt-bench [--smoke|--coexec] [--device d] [--apps n] [--json f] [--trace f]
//! oodin fleet-bench [--smoke] [--devices n] [--seed s] [--family f] [--json f] [--trace f]
//! oodin trace   <file.jsonl> [--summary] [--chrome <out>]   Span analytics over a trace
//! ```
//!
//! `--trace <path>` (the three benches above) writes the decision flight
//! recorder as JSON-lines to `<path>` and a Perfetto/Chrome-loadable
//! trace to `<path>.chrome.json`.
//!
//! Every command runs hermetically when `artifacts/` is absent: the
//! synthetic registry + SimBackend stand in for the AOT zoo + PJRT.

use anyhow::{bail, Context, Result};

use oodin::config::UseCase;
use oodin::experiments::{coexec, fig3, fig456, fig7, fig8, fleetbench,
                         loadgen, multiapp, optbench, tables};
use oodin::measurements::Measurer;
use oodin::model::Precision;
use oodin::optimizer::Optimizer;
use oodin::runtime::{default_backend, Backend};
use oodin::serving::{Server, ServerConfig};
use oodin::telemetry::spans::Analysis;
use oodin::util::json;
use oodin::{load_registry_or_synthetic, mdcl};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` and bare `--switch` flags.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let takes_value = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if takes_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "report" => cmd_report(&args),
        "exp" => cmd_exp(&args),
        "measure" => cmd_measure(&args),
        "optimize" => cmd_optimize(&args),
        "resources" => cmd_resources(),
        "serve" => cmd_serve(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "multi" => cmd_multi(&args),
        "opt-bench" => cmd_opt_bench(&args),
        "fleet-bench" => cmd_fleet_bench(&args),
        "trace" => cmd_trace(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `oodin help`)"),
    }
}

fn print_usage() {
    println!(
        "OODIn — optimised on-device inference (paper reproduction)\n\
         \n\
         usage: oodin <command> [flags]\n\
         \n\
         commands:\n\
         \x20 report   --table1 | --table2       regenerate the paper's tables\n\
         \x20 exp      fig3|fig4|fig5|fig6|fig7|fig8 [--real]  regenerate a figure\n\
         \x20 measure  --device <name> [--out f] [--host-calibrated]  device sweep\n\
         \x20 optimize --use-case <file.json>    run System Optimisation\n\
         \x20 resources                           print resource model R per device\n\
         \x20 serve    --family <f> [--precision p] [--requests n] [--device d]  serving demo\n\
         \x20 serve-bench [--smoke] [--device d] [--rate r] [--duration ms] [--json f] [--trace f]  pipeline load bench\n\
         \x20 multi    [--smoke] [--device d] [--apps n] [--windows w] [--json f]  multi-app contention table\n\
         \x20 opt-bench [--smoke] [--device d] [--apps n] [--json f] [--trace f]  full-search vs frontier-walk adaptation cost\n\
         \x20 opt-bench --coexec [--json f] [--trace f]  pipelined multi-engine partitioning vs best monolithic\n\
         \x20 fleet-bench [--smoke] [--devices n] [--seed s] [--family f] [--json f] [--trace f]  population-scale LUT transfer + cohort caches + staged-rollout control plane\n\
         \x20 trace    <file.jsonl> [--summary] [--chrome f]  span/causality analytics over a recorded trace\n\
         \n\
         --trace <path> (benches) writes a decision flight-recorder trace as\n\
         JSON-lines plus a Perfetto-loadable <path>.chrome.json\n\
         (no artifacts/?  everything runs on the hermetic SimBackend)"
    );
}

fn cmd_report(args: &Args) -> Result<()> {
    if args.has("table1") {
        tables::print_table1();
    }
    if args.has("table2") || !args.has("table1") {
        let registry = load_registry_or_synthetic()?;
        tables::print_table2(&registry);
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .context("exp needs a figure id (fig3..fig8)")?;
    let registry = load_registry_or_synthetic()?;
    match which.as_str() {
        "fig3" => fig3::print(&registry)?,
        "fig4" => fig456::print(&registry, Some("sony_c5"))?,
        "fig5" => fig456::print(&registry, Some("samsung_a71"))?,
        "fig6" => fig456::print(&registry, Some("samsung_s20_fe"))?,
        "fig456" | "all456" => fig456::print(&registry, None)?,
        "fig7" => fig7::print(&registry, args.has("real"))?,
        "fig8" => {
            let n = args.flag("inferences").map_or(Ok(1200), |s| s.parse())?;
            fig8::print(&registry, n)?
        }
        other => bail!("unknown experiment `{other}`"),
    }
    Ok(())
}

fn cmd_measure(args: &Args) -> Result<()> {
    let device = mdcl::detect(args.flag("device").context("--device required")?)?;
    let registry = load_registry_or_synthetic()?;
    let backend;
    let mut measurer = Measurer::new(&device, &registry);
    if args.has("host-calibrated") {
        backend = default_backend(&device, &registry)?;
        measurer = measurer.host_calibrated(backend.as_ref());
    }
    let lut = measurer.measure_all()?;
    println!("measured {} configurations on {}", lut.len(), device.name);
    if let Some(out) = args.flag("out") {
        lut.save(out)?;
        println!("LUT written to {out}");
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let uc = UseCase::from_file(args.flag("use-case").context("--use-case required")?)?;
    let device = mdcl::detect(&uc.device)?;
    let registry = load_registry_or_synthetic()?;
    let lut = Measurer::new(&device, &registry).measure_all()?;
    let opt = Optimizer::new(&device, &registry, &lut).with_camera_fps(uc.camera_fps);
    let best = opt.optimize(uc.objective, &uc.space)?;
    println!("use-case `{}` on {}:", uc.name, device.name);
    println!("  σ = <{}, engine={}, threads={}, governor={}, r={}>",
             best.design.variant,
             best.design.hw.engine.name(),
             best.design.hw.threads,
             best.design.hw.governor.name(),
             best.design.hw.recognition_rate);
    println!("  T={:.4} ms  fps={:.1}  mem={:.2} MB  accuracy={:.2}%",
             best.latency_ms, best.fps,
             best.mem_bytes as f64 / 1e6, best.accuracy * 100.0);
    Ok(())
}

fn cmd_resources() -> Result<()> {
    for d in oodin::device::profiles::profiles() {
        println!("{}", mdcl::format_resource_model(&d));
    }
    Ok(())
}

fn cmd_multi(args: &Args) -> Result<()> {
    let registry = load_registry_or_synthetic()?;
    let mut cfg = if args.has("smoke") {
        multiapp::MultiAppConfig::smoke()
    } else {
        multiapp::MultiAppConfig::full()
    };
    if let Some(d) = args.flag("device") {
        cfg.devices = vec![d.to_string()];
    }
    if let Some(n) = args.flag("apps") {
        cfg.app_counts = vec![n.parse().context("--apps")?];
    }
    if let Some(w) = args.flag("windows") {
        cfg.windows = w.parse().context("--windows")?;
    }
    multiapp::print(&registry, &cfg, args.flag("json"))
}

fn cmd_opt_bench(args: &Args) -> Result<()> {
    let registry = load_registry_or_synthetic()?;
    if args.has("coexec") {
        // The co-execution smoke: widened (partitioned) σ-space on the
        // golden-pinned device, ignoring --device/--apps depth flags.
        return coexec::print(&registry, args.flag("json"),
                             args.flag("trace"));
    }
    let mut cfg = if args.has("smoke") {
        optbench::OptBenchConfig::smoke()
    } else {
        optbench::OptBenchConfig::full()
    };
    if let Some(d) = args.flag("device") {
        cfg.devices = vec![d.to_string()];
    }
    if let Some(n) = args.flag("apps") {
        cfg.n_apps = n.parse().context("--apps")?;
    }
    optbench::print(&registry, &cfg, args.flag("json"), args.flag("trace"))
}

fn cmd_fleet_bench(args: &Args) -> Result<()> {
    let registry = load_registry_or_synthetic()?;
    let mut cfg = if args.has("smoke") {
        fleetbench::FleetBenchConfig::smoke()
    } else {
        fleetbench::FleetBenchConfig::full()
    };
    if let Some(n) = args.flag("devices") {
        cfg.fleet.population.size = n.parse().context("--devices")?;
    }
    if let Some(s) = args.flag("seed") {
        cfg.fleet.population.seed = s.parse().context("--seed")?;
    }
    if let Some(f) = args.flag("family") {
        cfg.family = f.to_string();
    }
    // The smoke acceptance bounds (mean regret ≤ 5%, builds < devices) are
    // pinned to the standard smoke population; any override makes this an
    // exploration run — report the metrics instead of aborting on them.
    if args.has("devices") || args.has("seed") || args.has("family") {
        cfg.enforce_regret_pct = None;
    }
    fleetbench::print(&registry, &cfg, args.flag("json"), args.flag("trace"))
}

fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .context("usage: oodin trace <file.jsonl> [--summary] [--chrome <out>]")?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path}"))?;
    let analysis = Analysis::from_jsonl(&text)
        .with_context(|| format!("parsing trace {path}"))?;
    if let Some(out) = args.flag("chrome") {
        let chrome = json::to_string(&json::obj(vec![(
            "traceEvents",
            json::Value::Arr(analysis.chrome_spans()),
        )]));
        std::fs::write(out, chrome)
            .with_context(|| format!("writing chrome trace {out}"))?;
        // Stderr so `--summary --chrome f` keeps stdout byte-pinnable.
        eprintln!("wrote reconstructed-span chrome trace to {out}");
    }
    if args.has("summary") {
        // One machine-readable line; CI diffs this against the golden.
        println!("{}", analysis.summary_json());
        return Ok(());
    }
    let (t0, t1) = analysis
        .events
        .iter()
        .fold((u64::MAX, 0u64), |(a, b), e| (a.min(e.t_us), b.max(e.t_us)));
    println!("trace: {} events, {} seq gaps, span {}..{} us",
             analysis.events.len(),
             analysis.seq_gaps,
             if t0 == u64::MAX { 0 } else { t0 },
             t1);
    println!("adaptation: {} spans / {} switches ({} abandoned, {} open)",
             analysis.adaptation.len(),
             analysis.switches(),
             analysis.abandoned_episodes,
             analysis.open_episodes);
    println!("serving: {} requests in {} batches, {} sheds, {} unclosed requests, {} unclosed batches",
             analysis.requests.len(),
             analysis.batches.len(),
             analysis.sheds,
             analysis.unclosed_requests,
             analysis.unclosed_batches);
    let promoted = analysis.rollouts.iter()
        .filter(|r| r.terminal == "promoted").count();
    let rolled_back = analysis.rollouts.iter()
        .filter(|r| r.terminal == "rolled_back").count();
    println!("rollouts: {} spans ({promoted} promoted, {rolled_back} rolled back, {} holds)",
             analysis.rollouts.len(),
             analysis.rollout_holds);
    let burn_events: u64 = analysis.burn.iter().map(|b| b.events).sum();
    println!("slo_burn: {} events in {} episodes",
             burn_events,
             analysis.burn.len());
    println!("causality: {} chains ({} orphan deltas, {} downstream switches)",
             analysis.chains.len(),
             analysis.orphan_deltas,
             analysis.downstream_switches);
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let mut cfg = if args.has("smoke") {
        loadgen::LoadgenConfig::smoke()
    } else {
        loadgen::LoadgenConfig::full()
    };
    if let Some(d) = args.flag("device") {
        cfg.device = d.to_string();
    }
    if let Some(r) = args.flag("rate") {
        cfg.open_rates_rps = vec![r.parse().context("--rate")?];
        cfg.burst = None;
        cfg.tight = None;
        cfg.closed_concurrency.clear();
    }
    if let Some(ms) = args.flag("duration") {
        cfg.duration_ms = ms.parse().context("--duration")?;
    }
    if let Some(s) = args.flag("seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    loadgen::print(&cfg, args.flag("json"), args.flag("trace"))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let family = args.flag("family").unwrap_or("mobilenet_v2_100");
    let precision = Precision::parse(args.flag("precision").unwrap_or("fp32"))?;
    let n: usize = args.flag("requests").map_or(Ok(64), |s| s.parse())?;
    let device = mdcl::detect(args.flag("device").unwrap_or("samsung_a71"))?;
    let registry = load_registry_or_synthetic()?;
    let rt = default_backend(&device, &registry)?;
    let cfg = ServerConfig::for_family(&registry, family, precision)?;
    println!("serving {family} ({}) on the {} backend with batch sizes {:?}",
             precision.name(),
             rt.kind(),
             cfg.variants.iter().map(|(b, _)| *b).collect::<Vec<_>>());
    let srv = Server::start(std::sync::Arc::clone(&rt), &registry, cfg)?;

    let res = registry
        .find(family, precision, 1)
        .context("variant missing")?
        .resolution;
    let mut cam = oodin::sil::SyntheticCamera::new(res, 30.0, 7);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let f = cam.capture(i as f64);
            srv.submit(f.data, f.height, f.width).unwrap()
        })
        .collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!("{ok}/{n} ok in {secs:.3}s  ({:.1} req/s)", n as f64 / secs);
    println!("telemetry: {}",
             oodin::util::json::to_string(&srv.telemetry.snapshot()));
    srv.stop();
    rt.shutdown();
    Ok(())
}
