//! Engine performance model: roofline latency + Amdahl thread scaling +
//! DVFS/thermal frequency scaling + external-load contention.
//!
//! `latency_ms` is the single source of truth for *simulated device*
//! latency.  The CPU path of the real system also executes the artifact on
//! the host PJRT client (for numerics and host wall-clock), but every LUT,
//! objective and adaptation decision is driven by this model so the three
//! Table I device classes can coexist on one testbed (DESIGN.md
//! §Substitutions).
//!
//!   latency = dispatch + max(compute, memory) · contention(load)
//!   compute = flops·batch / (peak·prec_mult·threads(Amdahl)·freq)
//!   memory  = (weights + activations) / bandwidth
//!
//! `freq = governor_scale · thermal_scale`; `contention = 2^load` — the
//! paper's own Fig 7 load model ("exponentially scaling the inference
//! latency by a load factor").

use crate::device::{DeviceProfile, EngineKind, EngineSpec};
use crate::dvfs::Governor;
use crate::model::{ModelVariant, Precision};

/// Instantaneous execution conditions seen by one engine.
#[derive(Debug, Clone, Copy)]
pub struct ExecConditions {
    /// Active DVFS governor.
    pub governor: Governor,
    /// CPU threads (ignored by offload engines).
    pub threads: usize,
    /// External contention l: latency multiplier 2^l (0 = idle).
    pub load_factor: f64,
    /// Thermal throttling scale from `dvfs::ThermalModel` (1.0 = cool).
    pub thermal_freq_scale: f64,
}

impl ExecConditions {
    /// Idle, cool, performance governor — the offline-measurement baseline.
    pub fn nominal(threads: usize) -> Self {
        ExecConditions {
            governor: Governor::Performance,
            threads,
            load_factor: 0.0,
            thermal_freq_scale: 1.0,
        }
    }
}

/// Amdahl's-law thread speedup for the CPU engine.
pub fn thread_speedup(spec: &EngineSpec, threads: usize) -> f64 {
    if spec.kind != EngineKind::Cpu || threads <= 1 {
        return 1.0;
    }
    let p = spec.parallel_frac;
    1.0 / ((1.0 - p) + p / threads as f64)
}

/// Precision multiplier on engine peak throughput.
pub fn precision_mult(spec: &EngineSpec, p: Precision) -> f64 {
    match p {
        Precision::Fp32 => 1.0,
        Precision::Fp16 => spec.fp16_mult,
        Precision::Int8 => spec.int8_mult,
    }
}

/// Effective GFLOP/s under the given conditions (before contention).
pub fn effective_gflops(dev: &DeviceProfile, spec: &EngineSpec,
                        v: &ModelVariant, cond: &ExecConditions) -> f64 {
    let threads = cond.threads.min(dev.n_cores).max(1);
    // A CPU engine's stated peak assumes all cores: scale to 1 thread first.
    let base = if spec.kind == EngineKind::Cpu {
        let all = thread_speedup(spec, dev.n_cores);
        spec.peak_gflops_fp32 / all * thread_speedup(spec, threads)
    } else {
        spec.peak_gflops_fp32
    };
    let penalty = if spec.kind == EngineKind::Npu {
        dev.npu_family_penalty(&v.family)
    } else {
        1.0
    };
    base * precision_mult(spec, v.precision)
        * cond.governor.freq_scale()
        * cond.thermal_freq_scale
        / penalty
}

/// Compute-bound time (ms) for one execution (whole batch).
pub fn compute_ms(dev: &DeviceProfile, spec: &EngineSpec, v: &ModelVariant,
                  cond: &ExecConditions) -> f64 {
    let gflops = effective_gflops(dev, spec, v, cond);
    (v.flops as f64 * v.batch as f64) / (gflops * 1e6)
}

/// Memory-bound time (ms): weights streamed once + activations per batch.
pub fn memory_ms(spec: &EngineSpec, v: &ModelVariant) -> f64 {
    let act = (v.input_elems() + v.output_elems()) * 4;
    let bytes = v.size_bytes as f64 + act as f64;
    bytes / (spec.mem_bw_gbps * 1e6)
}

/// Contention multiplier for an external load factor l (paper Fig 7).
pub fn contention(load_factor: f64) -> f64 {
    2f64.powf(load_factor.max(0.0))
}

/// Roofline latency (ms) of one inference execution.
pub fn latency_ms(dev: &DeviceProfile, kind: EngineKind, v: &ModelVariant,
                  cond: &ExecConditions) -> Option<f64> {
    let spec = dev.engine(kind)?;
    let roof = compute_ms(dev, spec, v, cond).max(memory_ms(spec, v));
    Some((spec.dispatch_ms + roof) * contention(cond.load_factor))
}

/// Throughput (frames/s) of back-to-back executions at this latency.
pub fn fps_from_latency(latency_ms: f64, batch: usize) -> f64 {
    batch as f64 * 1000.0 / latency_ms
}

/// True when the variant fits the device memory budget (DLACL buffers
/// included) — the paper's undeployable-model filter, part 1.
pub fn fits_memory(dev: &DeviceProfile, v: &ModelVariant) -> bool {
    v.mem_bytes() <= dev.mem_budget_bytes
}

/// First-order per-inference energy estimate (arbitrary units ∝ mJ) of one
/// execution measured at `avg_latency_ms` on `spec` under `governor`:
///
///   energy ∝ latency · heat_per_ms · freq² · gov_heat
///
/// i.e. run time × the engine's dissipation rate at the governor's
/// sustained clock — the same `freq² · gov_heat` power term the thermal RC
/// model integrates (`dvfs::ThermalModel::record_work`).  It is a *static*
/// per-design property (evaluated at idle, cool conditions), giving the
/// design-space layer its third Pareto dimension without any new
/// calibration constants.
pub fn energy_proxy_mj(spec: &EngineSpec, avg_latency_ms: f64,
                       governor: Governor) -> f64 {
    let f = governor.freq_scale();
    avg_latency_ms * spec.thermal.heat_per_ms * f * f * governor.heat_factor()
}

/// Busy time the engine accrues for thermal accounting (compute only:
/// dispatch is host-side).
pub fn busy_ms(dev: &DeviceProfile, kind: EngineKind, v: &ModelVariant,
               cond: &ExecConditions) -> Option<f64> {
    let spec = dev.engine(kind)?;
    Some(compute_ms(dev, spec, v, cond).max(memory_ms(spec, v)))
}

// ---------------------------------------------------------------------------
// Intra-model co-execution: a partitioned plan splits one variant into
// 2–3 layer-group segments pinned to distinct engines and runs them as a
// pipeline.  Steady-state latency is the bottleneck stage (stage roofline
// + inter-engine transfer), not the sum of stages.
// ---------------------------------------------------------------------------

/// Fixed overhead (ms) of one inter-engine handoff: queue submission +
/// synchronisation, paid per transfer on top of the activation-bytes /
/// bandwidth term.
pub const HANDOFF_MS: f64 = 0.05;

/// Per-stage cost breakdown of a partitioned execution plan at nominal
/// (idle, cool) conditions.  Stored in the LUT next to the sampled
/// latency statistics so condition adjustment can re-find the bottleneck
/// stage under per-engine load/thermal state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageCost {
    /// Engine this segment runs on.
    pub engine: EngineKind,
    /// Segment roofline time (ms): dispatch + max(compute, memory).
    pub stage_ms: f64,
    /// Inter-engine transfer into this segment (ms); 0 for the first.
    pub xfer_ms: f64,
}

/// Activation elements crossing a per-mille cut point: geometric
/// interpolation between the variant's input and output widths.  The grid
/// cuts {250, 500, 750} use a sqrt-only chain (IEEE sqrt is correctly
/// rounded, so the Rust and Python oracles agree bit-for-bit); other cut
/// points fall back to `powf` and must not appear on golden-pinned paths.
pub fn boundary_elems(v: &ModelVariant, cut_pm: u32) -> f64 {
    let i = v.input_elems() as f64;
    let o = v.output_elems() as f64;
    match cut_pm {
        0 => i,
        1000 => o,
        500 => (i * o).sqrt(),
        250 => {
            let mid = (i * o).sqrt();
            (i * mid).sqrt()
        }
        750 => {
            let mid = (i * o).sqrt();
            (mid * o).sqrt()
        }
        _ => {
            let t = cut_pm as f64 / 1000.0;
            i.powf(1.0 - t) * o.powf(t)
        }
    }
}

/// Threads a partitioned plan runs with: all cores when any segment is on
/// the CPU (the CPU stage gets the full thread budget while offload
/// stages run concurrently), 1 otherwise.
pub fn plan_threads(dev: &DeviceProfile, engines: &[EngineKind]) -> usize {
    if engines.contains(&EngineKind::Cpu) {
        dev.n_cores
    } else {
        1
    }
}

/// Per-stage roofline costs of a partitioned plan at idle, cool
/// conditions under `governor`.  `cuts_pm` are the interior cut points
/// (per-mille of the variant's FLOPs/bytes); segment i covers
/// `(bounds[i], bounds[i+1]]` with its weights-fraction streamed plus the
/// fp32 activations at both segment boundaries.  `None` when any engine
/// the plan touches is absent on the device.
pub fn plan_stage_costs(dev: &DeviceProfile, v: &ModelVariant,
                        engines: &[EngineKind], cuts_pm: &[u32],
                        governor: Governor) -> Option<Vec<StageCost>> {
    let cond = ExecConditions {
        governor,
        threads: plan_threads(dev, engines),
        load_factor: 0.0,
        thermal_freq_scale: 1.0,
    };
    let mut bounds = Vec::with_capacity(engines.len() + 1);
    bounds.push(0u32);
    bounds.extend_from_slice(cuts_pm);
    bounds.push(1000);
    let mut stages = Vec::with_capacity(engines.len());
    for (i, &kind) in engines.iter().enumerate() {
        let spec = dev.engine(kind)?;
        let (lo, hi) = (bounds[i], bounds[i + 1]);
        let frac = (hi - lo) as f64 / 1000.0;
        let flops = v.flops as f64 * frac;
        let size = v.size_bytes as f64 * frac;
        let b_in = boundary_elems(v, lo);
        let b_out = boundary_elems(v, hi);
        let gflops = effective_gflops(dev, spec, v, &cond);
        let compute = flops / (gflops * 1e6);
        let act = (b_in + b_out) * 4.0;
        let memory = (size + act) / (spec.mem_bw_gbps * 1e6);
        let stage_ms = spec.dispatch_ms + compute.max(memory);
        let xfer_ms = if i == 0 {
            0.0
        } else {
            let prev = dev.engine(engines[i - 1])?;
            let bw = prev.mem_bw_gbps.min(spec.mem_bw_gbps);
            (b_in * 4.0) / (bw * 1e6) + HANDOFF_MS
        };
        stages.push(StageCost { engine: kind, stage_ms, xfer_ms });
    }
    Some(stages)
}

/// Steady-state latency (ms) of a pipelined plan: the bottleneck
/// max(transfer-in + stage) over all stages.
pub fn pipelined_latency_ms(stages: &[StageCost]) -> f64 {
    let mut bn = 0.0f64;
    for st in stages {
        bn = bn.max(st.xfer_ms + st.stage_ms);
    }
    bn
}

/// Condition-adjustment factor of a partitioned plan: the ratio of the
/// pipeline bottleneck under per-engine contention/thermal state to the
/// nominal bottleneck.  Stage compute scales by `2^load / thermal` on its
/// own engine; transfers are bus-side and stay fixed — so load on one
/// engine can move the bottleneck to a different stage.
pub fn plan_condition_factor(stages: &[StageCost],
                             load: impl Fn(EngineKind) -> f64,
                             thermal: impl Fn(EngineKind) -> f64) -> f64 {
    let mut base = 0.0f64;
    let mut cond = 0.0f64;
    for st in stages {
        base = base.max(st.xfer_ms + st.stage_ms);
        cond = cond.max(st.xfer_ms
            + st.stage_ms * contention(load(st.engine))
                / thermal(st.engine).max(1e-3));
    }
    cond / base
}

/// Working set of a partitioned plan: the variant's memory plus
/// double-buffered fp32 activations at every interior segment boundary.
pub fn plan_mem_bytes(v: &ModelVariant, cuts_pm: &[u32]) -> u64 {
    let mut extra = 0u64;
    for &c in cuts_pm {
        extra += (boundary_elems(v, c).ceil() as u64) * 8;
    }
    v.mem_bytes() + extra
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::{by_name, samsung_a71, samsung_s20_fe, sony_c5};
    use crate::model::test_fixtures::fake_registry;

    fn mk(name: &str) -> ModelVariant {
        fake_registry().get(name).unwrap().clone()
    }

    #[test]
    fn amdahl_monotone_and_bounded() {
        let d = samsung_a71();
        let cpu = d.engine(EngineKind::Cpu).unwrap();
        let mut prev = 0.0;
        for t in [1, 2, 4, 8] {
            let s = thread_speedup(cpu, t);
            assert!(s > prev);
            prev = s;
        }
        assert!(prev < 8.0); // sub-linear
        let gpu = d.engine(EngineKind::Gpu).unwrap();
        assert_eq!(thread_speedup(gpu, 8), 1.0); // offload engines ignore threads
    }

    #[test]
    fn more_threads_lower_cpu_latency() {
        let d = sony_c5();
        let v = mk("inception_v3__fp32__b1");
        let l1 = latency_ms(&d, EngineKind::Cpu, &v, &ExecConditions::nominal(1)).unwrap();
        let l8 = latency_ms(&d, EngineKind::Cpu, &v, &ExecConditions::nominal(8)).unwrap();
        assert!(l8 < l1);
    }

    #[test]
    fn int8_faster_than_fp32_on_cpu() {
        let d = samsung_a71();
        let f = mk("mobilenet_v2_100__fp32__b1");
        let q = mk("mobilenet_v2_100__int8__b1");
        let c = ExecConditions::nominal(4);
        assert!(latency_ms(&d, EngineKind::Cpu, &q, &c).unwrap()
                < latency_ms(&d, EngineKind::Cpu, &f, &c).unwrap());
    }

    #[test]
    fn missing_engine_returns_none() {
        let d = sony_c5();
        let v = mk("mobilenet_v2_100__fp32__b1");
        assert!(latency_ms(&d, EngineKind::Npu, &v, &ExecConditions::nominal(1)).is_none());
    }

    #[test]
    fn paper_phenomenon_a71_npu_wins_mobilenet_int8() {
        // §IV-B: OODIn selects NNAPI for MobileNetV2 1.0 INT8 on A71.
        let d = samsung_a71();
        let v = mk("mobilenet_v2_100__int8__b1");
        let c = ExecConditions::nominal(d.n_cores);
        let npu = latency_ms(&d, EngineKind::Npu, &v, &c).unwrap();
        let cpu = latency_ms(&d, EngineKind::Cpu, &v, &c).unwrap();
        let gpu = latency_ms(&d, EngineKind::Gpu, &v, &c).unwrap();
        assert!(npu < cpu && npu < gpu, "npu {npu} cpu {cpu} gpu {gpu}");
    }

    #[test]
    fn paper_phenomenon_s20_cpu_wins_small_int8() {
        // §IV-B: "On S20, the CPU is often the highest performing engine."
        let d = samsung_s20_fe();
        let v = mk("mobilenet_v2_100__int8__b1");
        let c = ExecConditions::nominal(d.n_cores);
        let cpu = latency_ms(&d, EngineKind::Cpu, &v, &c).unwrap();
        let npu = latency_ms(&d, EngineKind::Npu, &v, &c).unwrap();
        assert!(cpu < npu, "cpu {cpu} npu {npu}");
    }

    #[test]
    fn paper_phenomenon_nnapi_catastrophic_on_deeplab_s20() {
        // Fig 3: up to ~93x speedup over oSQ-NNAPI on a pathological pair.
        let d = samsung_s20_fe();
        let v = mk("deeplab_v3__fp32__b1");
        let c = ExecConditions::nominal(d.n_cores);
        let npu = latency_ms(&d, EngineKind::Npu, &v, &c).unwrap();
        let best = EngineKind::ALL
            .iter()
            .filter_map(|&k| latency_ms(&d, k, &v, &c))
            .fold(f64::INFINITY, f64::min);
        assert!(npu / best > 20.0, "ratio {}", npu / best);
    }

    #[test]
    fn gpu_wins_big_fp32_models() {
        let d = samsung_s20_fe();
        let v = mk("inception_v3__fp32__b1");
        let c = ExecConditions::nominal(d.n_cores);
        let gpu = latency_ms(&d, EngineKind::Gpu, &v, &c).unwrap();
        let cpu = latency_ms(&d, EngineKind::Cpu, &v, &c).unwrap();
        assert!(gpu < cpu, "gpu {gpu} cpu {cpu}");
    }

    #[test]
    fn contention_doubles_per_unit_load() {
        assert_eq!(contention(0.0), 1.0);
        assert_eq!(contention(1.0), 2.0);
        assert_eq!(contention(2.0), 4.0);
        assert_eq!(contention(-3.0), 1.0); // clamped
    }

    #[test]
    fn governor_slows_execution() {
        let d = samsung_a71();
        let v = mk("inception_v3__fp32__b1");
        let mut c = ExecConditions::nominal(8);
        let perf = latency_ms(&d, EngineKind::Cpu, &v, &c).unwrap();
        c.governor = Governor::EnergyStep;
        let eco = latency_ms(&d, EngineKind::Cpu, &v, &c).unwrap();
        assert!(eco > perf * 1.2);
    }

    #[test]
    fn thermal_scale_slows_execution() {
        let d = samsung_a71();
        let v = mk("inception_v3__fp32__b1");
        let mut c = ExecConditions::nominal(8);
        let cool = latency_ms(&d, EngineKind::Npu, &v, &c).unwrap();
        c.thermal_freq_scale = 0.5;
        let hot = latency_ms(&d, EngineKind::Npu, &v, &c).unwrap();
        assert!(hot > cool * 1.5);
    }

    #[test]
    fn energy_proxy_orders_governors_and_scales_with_latency() {
        let d = samsung_a71();
        let cpu = d.engine(EngineKind::Cpu).unwrap();
        // At equal measured latency, lower clocks burn strictly less.
        let perf = energy_proxy_mj(cpu, 4.0, Governor::Performance);
        let sched = energy_proxy_mj(cpu, 4.0, Governor::Schedutil);
        let eco = energy_proxy_mj(cpu, 4.0, Governor::EnergyStep);
        assert!(perf > sched && sched > eco, "{perf} {sched} {eco}");
        // Linear in run time.
        assert!((energy_proxy_mj(cpu, 8.0, Governor::Performance)
                 - 2.0 * perf).abs() < 1e-12);
    }

    #[test]
    fn memory_budget_filter() {
        let sony = by_name("sony_c5").unwrap();
        let small = mk("mobilenet_v2_100__int8__b1");
        assert!(fits_memory(&sony, &small));
        let mut big = mk("inception_v3__fp32__b1");
        big.size_bytes = 100 * 1024 * 1024;
        assert!(!fits_memory(&sony, &big));
    }

    #[test]
    fn fps_inverse_of_latency() {
        assert_eq!(fps_from_latency(10.0, 1), 100.0);
        assert_eq!(fps_from_latency(10.0, 8), 800.0);
    }
}
