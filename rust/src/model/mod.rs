//! Model representation and registry (paper §III-B1).
//!
//! OODIn represents a model as the tuple `m = <task, w, s_m, s_in, a, p>` —
//! task, workload in FLOPs, model size, input resolution, accuracy and
//! numerical precision.  `ModelVariant` is that tuple plus the artifact
//! bookkeeping (HLO path, I/O shapes, batch) the runtime needs.  The
//! registry is loaded from `artifacts/manifest.json`, which the Python
//! compile path emits with *measured* accuracy and *computed* FLOPs/size.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// The inference task of a model (classification / segmentation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Image classification (top-1 decoded logits).
    Classification,
    /// Semantic segmentation (per-pixel logit map).
    Segmentation,
}

impl Task {
    /// Parse a manifest task id (`cls` / `seg`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "cls" => Task::Classification,
            "seg" => Task::Segmentation,
            other => bail!("unknown task `{other}`"),
        })
    }

    /// Canonical manifest id.
    pub fn name(&self) -> &'static str {
        match self {
            Task::Classification => "cls",
            Task::Segmentation => "seg",
        }
    }
}

/// The transformation t ∈ T = {FP32, FP16, INT8} applied to the reference
/// model (paper Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    /// The untransformed reference model.
    Fp32,
    /// Half-precision weights/activations.
    Fp16,
    /// Post-training 8-bit quantisation.
    Int8,
}

impl Precision {
    /// Every transformation, in decreasing precision order.
    pub const ALL: [Precision; 3] = [Precision::Fp32, Precision::Fp16, Precision::Int8];

    /// Parse a manifest precision id (`fp32` / `fp16` / `int8`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fp32" => Precision::Fp32,
            "fp16" => Precision::Fp16,
            "int8" => Precision::Int8,
            other => bail!("unknown precision `{other}`"),
        })
    }

    /// Canonical manifest id.
    pub fn name(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
        }
    }

    /// Storage bits per weight.
    pub fn bits(&self) -> u32 {
        match self {
            Precision::Fp32 => 32,
            Precision::Fp16 => 16,
            Precision::Int8 => 8,
        }
    }
}

/// One deployable model variant: the paper's tuple `m` + artifact metadata.
#[derive(Debug, Clone)]
pub struct ModelVariant {
    /// `<family>__<precision>__b<batch>` — unique within a manifest.
    pub name: String,
    /// Architecture family (`mobilenet_v2_100`, ...).
    pub family: String,
    /// The Table II model this family stands in for.
    pub paper_name: String,
    /// The inference task this variant serves.
    pub task: Task,
    /// t: the transformation that produced this variant.
    pub precision: Precision,
    /// s_in: input resolution (square).
    pub resolution: usize,
    /// Compiled batch size.
    pub batch: usize,
    /// Logical input tensor shape `[batch, res, res, 3]`.
    pub input_shape: Vec<usize>,
    /// Logical output tensor shape.
    pub output_shape: Vec<usize>,
    /// Number of trained parameters.
    pub params: u64,
    /// s_m: serialized weight bytes under this transformation.
    pub size_bytes: u64,
    /// w: FLOPs per batch-1 inference.
    pub flops: u64,
    /// a: measured accuracy (top-1 or mIoU) on the held-out split.
    pub accuracy: f64,
    /// Which metric `accuracy` reports (`top1` / `miou`).
    pub accuracy_metric: String,
    /// HLO text artifact, relative to the artifacts dir.
    pub hlo: String,
}

impl ModelVariant {
    /// Parse one manifest `models[]` entry.
    pub fn from_json(v: &Value) -> Result<Self> {
        let shape = |key: &str| -> Result<Vec<usize>> {
            v.req(key)?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect()
        };
        Ok(ModelVariant {
            name: v.req("name")?.as_str()?.to_string(),
            family: v.req("family")?.as_str()?.to_string(),
            paper_name: v.req("paper_name")?.as_str()?.to_string(),
            task: Task::parse(v.req("task")?.as_str()?)?,
            precision: Precision::parse(v.req("precision")?.as_str()?)?,
            resolution: v.req("resolution")?.as_usize()?,
            batch: v.req("batch")?.as_usize()?,
            input_shape: shape("input_shape")?,
            output_shape: shape("output_shape")?,
            params: v.req("params")?.as_u64()?,
            size_bytes: v.req("size_bytes")?.as_u64()?,
            flops: v.req("flops")?.as_u64()?,
            accuracy: v.req("accuracy")?.as_f64()?,
            accuracy_metric: v.req("accuracy_metric")?.as_str()?.to_string(),
            hlo: v.req("hlo")?.as_str()?.to_string(),
        })
    }

    /// Input elements per inference (batch * H * W * C).
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Output elements per inference.
    pub fn output_elems(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Estimated peak working-set bytes: weights + input + output + the
    /// DLACL intermediate-buffer allowance (2x the larger of in/out, f32).
    pub fn mem_bytes(&self) -> u64 {
        let io = (self.input_elems().max(self.output_elems()) * 4) as u64;
        self.size_bytes + (self.input_elems() * 4) as u64 + io * 2
    }
}

/// The model space M: every variant generated from the reference models.
#[derive(Debug, Clone)]
pub struct Registry {
    /// Directory the manifest's relative artifact paths resolve against.
    pub artifacts_dir: PathBuf,
    variants: Vec<ModelVariant>,
    by_name: BTreeMap<String, usize>,
}

impl Registry {
    /// Load `<artifacts_dir>/manifest.json`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_manifest_json(&text, dir)
    }

    /// Parse a manifest document (rejects duplicate variant names).
    pub fn from_manifest_json(text: &str, artifacts_dir: PathBuf) -> Result<Self> {
        let root = json::parse(text).context("parsing manifest.json")?;
        let models = root.req("models")?.as_arr()?;
        let mut variants = Vec::with_capacity(models.len());
        for m in models {
            variants.push(ModelVariant::from_json(m)?);
        }
        let mut by_name = BTreeMap::new();
        for (i, v) in variants.iter().enumerate() {
            if by_name.insert(v.name.clone(), i).is_some() {
                bail!("duplicate variant `{}` in manifest", v.name);
            }
        }
        Ok(Registry { artifacts_dir, variants, by_name })
    }

    /// Every variant, in manifest order.
    pub fn variants(&self) -> &[ModelVariant] {
        &self.variants
    }

    /// Look up a variant by its unique name.
    pub fn get(&self, name: &str) -> Option<&ModelVariant> {
        self.by_name.get(name).map(|&i| &self.variants[i])
    }

    /// All batch-1 variants of a family (the optimizer's model dimension).
    pub fn family_variants(&self, family: &str) -> Vec<&ModelVariant> {
        self.variants
            .iter()
            .filter(|v| v.family == family && v.batch == 1)
            .collect()
    }

    /// Distinct family names, in manifest order.
    pub fn families(&self) -> Vec<&str> {
        let mut seen = Vec::new();
        for v in &self.variants {
            if !seen.contains(&v.family.as_str()) {
                seen.push(v.family.as_str());
            }
        }
        seen
    }

    /// Absolute path of a variant's HLO artifact.
    pub fn hlo_path(&self, v: &ModelVariant) -> PathBuf {
        self.artifacts_dir.join(&v.hlo)
    }

    /// Look up a specific (family, precision, batch) variant.
    pub fn find(&self, family: &str, precision: Precision, batch: usize)
                -> Option<&ModelVariant> {
        self.get(&format!("{family}__{}__b{batch}", precision.name()))
    }

    /// `preferred` when its FP32 batch-1 variant exists here, `fallback`
    /// otherwise — experiment drivers target the real zoo's flagship but
    /// must run hermetically on the synthetic registry.
    pub fn family_or<'s>(&self, preferred: &'s str, fallback: &'s str) -> &'s str {
        if self.find(preferred, Precision::Fp32, 1).is_some() {
            preferred
        } else {
            fallback
        }
    }
}

/// Synthetic-manifest fixtures shared by unit tests, integration tests and
/// benches (compiled unconditionally: it has no test-only deps).
pub mod test_fixtures {
    use super::*;

    /// A synthetic manifest used across the Rust test suite (no artifacts
    /// needed).  Mirrors the real manifest's schema exactly.
    pub fn fake_manifest() -> String {
        let mut models = Vec::new();
        let fams: [(&str, &str, &str, usize, u64); 4] = [
            ("mobilenet_v2_100", "MobileNetV2 1.0", "cls", 24, 4_000_000),
            ("efficientnet_lite4", "EfficientNetLite4", "cls", 32, 40_000_000),
            ("inception_v3", "InceptionV3", "cls", 32, 90_000_000),
            ("deeplab_v3", "DeepLabV3", "seg", 48, 50_000_000),
        ];
        for (fam, paper, task, res, flops) in fams {
            for (prec, bits, acc) in
                [("fp32", 32, 0.90), ("fp16", 16, 0.899), ("int8", 8, 0.885)]
            {
                let out = if task == "cls" {
                    "[1,10]".to_string()
                } else {
                    format!("[1,{res},{res},5]")
                };
                models.push(format!(
                    r#"{{"name":"{fam}__{prec}__b1","family":"{fam}","paper_name":"{paper}","task":"{task}","precision":"{prec}","bits":{bits},"resolution":{res},"batch":1,"input_shape":[1,{res},{res},3],"output_shape":{out},"params":100000,"size_bytes":{size},"flops":{flops},"accuracy":{acc},"accuracy_metric":"top1","hlo":"{fam}__{prec}__b1.hlo.txt"}}"#,
                    size = 400_000 * bits as u64 / 32,
                ));
            }
        }
        format!(r#"{{"version":1,"models":[{}]}}"#, models.join(","))
    }

    /// [`fake_manifest`] parsed into a registry.
    pub fn fake_registry() -> Registry {
        Registry::from_manifest_json(&fake_manifest(), PathBuf::from("/tmp/fake"))
            .unwrap()
    }

    /// The serve-bench registry: one classification family (`srv`) with a
    /// full batch ladder (b = 1/4/8) in FP32 plus an INT8 sibling ladder
    /// (the pipeline's degraded mode under queue pressure).
    ///
    /// Calibrated for *hand-derivable* golden latencies on the Samsung A71
    /// CPU path (peak 14 GFLOP/s, INT8 ×2.2): per-sample FLOPs shrink with
    /// batch (28M/21M/17.5M — batched kernels are more efficient per
    /// sample), so on the zero-noise simulator the FP32 ladder costs
    /// exactly 2/6/10 ms of roofline compute per execution and batching has
    /// a real throughput payoff.  Accuracy is 1.0 on both ladders so
    /// predictions are never hash-corrupted — the oracle
    /// (`python/golden_serve_bench.py`) reproduces every serve-bench number
    /// without replicating frame synthesis.
    pub fn bench_registry(res: usize) -> Registry {
        let mut models = Vec::new();
        let batches: [(usize, u64); 3] =
            [(1, 28_000_000), (4, 21_000_000), (8, 17_500_000)];
        for (prec, bits, size) in [("fp32", 32u64, 400_000u64),
                                   ("int8", 8, 100_000)] {
            for (b, flops) in batches {
                models.push(format!(
                    r#"{{"name":"srv__{prec}__b{b}","family":"srv","paper_name":"ServeBench","task":"cls","precision":"{prec}","bits":{bits},"resolution":{res},"batch":{b},"input_shape":[{b},{res},{res},3],"output_shape":[{b},10],"params":1000,"size_bytes":{size},"flops":{flops},"accuracy":1.0,"accuracy_metric":"top1","hlo":"srv_{prec}_b{b}.hlo.txt"}}"#
                ));
            }
        }
        let manifest = format!(r#"{{"version":1,"models":[{}]}}"#, models.join(","));
        Registry::from_manifest_json(&manifest, PathBuf::from("/tmp/oodin_bench"))
            .unwrap()
    }

    /// A tiny serving-oriented manifest: one classification family compiled
    /// at batch sizes 1 and 4 (the dynamic batcher's inputs), accuracy 1.0
    /// so the SimBackend never corrupts predictions.
    pub fn serving_registry(res: usize) -> Registry {
        let mut models = Vec::new();
        for b in [1usize, 4] {
            models.push(format!(
                r#"{{"name":"cls__fp32__b{b}","family":"cls","paper_name":"Tiny","task":"cls","precision":"fp32","bits":32,"resolution":{res},"batch":{b},"input_shape":[{b},{res},{res},3],"output_shape":[{b},10],"params":1000,"size_bytes":4000,"flops":100000,"accuracy":1.0,"accuracy_metric":"top1","hlo":"cls_b{b}.hlo.txt"}}"#
            ));
        }
        let manifest = format!(r#"{{"version":1,"models":[{}]}}"#, models.join(","));
        Registry::from_manifest_json(&manifest, PathBuf::from("/tmp/oodin_sim_srv"))
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::test_fixtures::*;
    use super::*;

    #[test]
    fn loads_fake_manifest() {
        let r = fake_registry();
        assert_eq!(r.variants().len(), 12);
        assert_eq!(r.families().len(), 4);
    }

    #[test]
    fn lookup_by_name_and_find() {
        let r = fake_registry();
        let v = r.get("mobilenet_v2_100__int8__b1").unwrap();
        assert_eq!(v.precision, Precision::Int8);
        assert_eq!(v.task, Task::Classification);
        let same = r.find("mobilenet_v2_100", Precision::Int8, 1).unwrap();
        assert_eq!(same.name, v.name);
    }

    #[test]
    fn family_variants_are_batch1_only() {
        let r = fake_registry();
        let vs = r.family_variants("inception_v3");
        assert_eq!(vs.len(), 3);
        assert!(vs.iter().all(|v| v.batch == 1));
    }

    #[test]
    fn precision_ordering_by_size() {
        let r = fake_registry();
        let f32v = r.find("deeplab_v3", Precision::Fp32, 1).unwrap();
        let i8v = r.find("deeplab_v3", Precision::Int8, 1).unwrap();
        assert!(i8v.size_bytes < f32v.size_bytes);
        assert!(i8v.accuracy <= f32v.accuracy);
    }

    #[test]
    fn mem_bytes_exceeds_weights() {
        let r = fake_registry();
        for v in r.variants() {
            assert!(v.mem_bytes() > v.size_bytes);
        }
    }

    #[test]
    fn duplicate_names_rejected() {
        let m = fake_manifest();
        let dup = m.replace(
            r#""models":["#,
            &format!(
                r#""models":[{},"#,
                json::parse(&m).unwrap().req("models").unwrap().as_arr().unwrap()[0]
                    .clone_to_string()
            ),
        );
        // helper: rebuild string of first model
        assert!(Registry::from_manifest_json(&dup, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn bench_registry_has_two_full_ladders() {
        let r = bench_registry(16);
        assert_eq!(r.variants().len(), 6);
        for prec in [Precision::Fp32, Precision::Int8] {
            for b in [1usize, 4, 8] {
                let v = r.find("srv", prec, b).unwrap();
                assert_eq!(v.batch, b);
                assert_eq!(v.accuracy, 1.0, "bench predictions must be exact");
            }
        }
        // Per-sample FLOPs shrink with batch: batching pays off.
        let f = |b: usize| r.find("srv", Precision::Fp32, b).unwrap().flops;
        assert!(f(8) < f(4) && f(4) < f(1));
    }

    #[test]
    fn rejects_bad_task() {
        assert!(Task::parse("detection").is_err());
        assert!(Precision::parse("int4").is_err());
    }

    impl Value {
        fn clone_to_string(&self) -> String {
            json::to_string(self)
        }
    }
    use crate::util::json::Value;
}
