//! Decision flight recorder: a bounded ring buffer of typed, virtually
//! timestamped trace events covering every adaptive layer of the stack.
//!
//! The runtime layer is driven entirely by monitored conditions —
//! re-adaptation triggers, hold reasons, frontier walks, admission and
//! shed decisions — yet counters alone cannot answer *why* a device
//! switched (or held) after the fact.  A [`FlightRecorder`] captures
//! that causality as a stream of [`TraceEvent`]s:
//!
//! * **adaptation** — decide/hold with trigger + hold-reason, switches,
//!   and a per-decision `explain` record (chosen design, objective
//!   score, frontier slice walked, alternatives considered);
//! * **frontier** — cache build/hit/evict and in-place delta
//!   application with points touched;
//! * **serving** — enqueue/shed/batch-launch/complete with deadline
//!   slack;
//! * **fleet** — cohort transfer provenance, probe fallbacks,
//!   engine-scale corrections, rollout stage transitions, residual
//!   feedback corrections, and anchor promotions;
//! * **scheduler** — multi-app admission and arbitration windows.
//!
//! Payloads are plain strings and numbers, so every layer can emit
//! without depending on a higher layer's types, and events are stamped
//! from the recorder's own **virtual clock** (`set_now_us`), driven by
//! the same deterministic simulated time the benches use — traces are
//! bit-reproducible and golden-pinnable.  The ring is bounded: past
//! `capacity`, the oldest event is dropped and counted, never the
//! process's memory.
//!
//! Export is dual-format: JSON-lines (one event per line, fixed key
//! order — the golden-diffable form) and the Chrome trace-event JSON
//! that Perfetto (<https://ui.perfetto.dev>) loads directly.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::{self, Value};

use super::sampling::{SampleOutcome, Sampler, SamplingPolicy};

/// Default ring capacity — comfortably above the ~5 k events a smoke
/// bench emits, small enough (a few MB) to embed per device.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// Round to 3 decimals (half away from zero) — the float precision the
/// trace schema pins, matching the experiment reports and the Python
/// oracles' `r3`.
pub fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

/// One structured flight-recorder event.  Every variant's payload is
/// plain data; `scope` identifies the emitting entity (device id, app
/// name, or cohort id).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Adaptation held the current design.
    Hold {
        /// Device or app the decision belongs to.
        scope: String,
        /// What fired (`load`, `degradation`) or `none` when the check
        /// never reached trigger evaluation.
        trigger: String,
        /// Hold reason (`not_due`, `cooldown`, `no_trigger`,
        /// `no_alternative`, `current_still_best`, `below_hysteresis`).
        reason: String,
    },
    /// Adaptation switched designs.
    Switch {
        /// Device or app the decision belongs to.
        scope: String,
        /// Design id switched away from.
        from: String,
        /// Design id switched to.
        to: String,
        /// Trigger that caused the switch (`load`, `degradation`).
        reason: String,
        /// Milliseconds from first violation to the switch (0 for pure
        /// load triggers).
        detection_ms: f64,
    },
    /// Per-decision explanation emitted alongside a switch.
    Explain {
        /// Device or app the decision belongs to.
        scope: String,
        /// Conditions-bucket id of the frontier slice walked.
        bucket: String,
        /// Chosen design id.
        chosen: String,
        /// Objective score of the chosen design at the exact observed
        /// conditions (rounded to 3 decimals).
        score: f64,
        /// Pareto-frontier points walked for this decision.
        frontier: u64,
        /// Alternatives considered and rejected (`frontier - 1`).
        alternatives: u64,
    },
    /// Frontier cache built a frontier for a bucket (cold miss).
    FrontierBuild {
        /// Cache owner (cohort id or app id).
        scope: String,
        /// Conditions-bucket id.
        bucket: String,
        /// Points on the built frontier.
        points: u64,
        /// Candidates enumerated to build it.
        candidates: u64,
    },
    /// Frontier cache served a warm frontier.
    FrontierHit {
        /// Cache owner (cohort id or app id).
        scope: String,
        /// Conditions-bucket id.
        bucket: String,
        /// Points on the served frontier.
        points: u64,
    },
    /// Frontier cache evicted an entry (capacity or memory budget).
    FrontierEvict {
        /// Cache owner (cohort id or app id).
        scope: String,
        /// Conditions-bucket id evicted.
        bucket: String,
        /// Points the evicted frontier held.
        points: u64,
    },
    /// A described LUT delta was applied across a cache's entries.
    FrontierDelta {
        /// Cache owner (cohort id or app id).
        scope: String,
        /// Entries updated in place.
        updated: u64,
        /// Frontier points touched by the in-place pass.
        points_touched: u64,
        /// Points a full rebuild of the updated entries would have
        /// re-enumerated (the work the delta path avoided).
        rebuild_points: u64,
    },
    /// Serving: a request was admitted to the deadline queue.
    Enqueue {
        /// Pipeline scope (scenario or device id).
        scope: String,
        /// Request class name.
        class: String,
        /// Queue depth after admission.
        depth: u64,
    },
    /// Serving: a request was shed at admission.
    Shed {
        /// Pipeline scope (scenario or device id).
        scope: String,
        /// Request class name.
        class: String,
        /// Queue depth at the shed decision.
        depth: u64,
    },
    /// Serving: a batch launched.
    BatchLaunch {
        /// Pipeline scope (scenario or device id).
        scope: String,
        /// Launch reason (`full`, `max_wait`, `deadline_risk`).
        reason: String,
        /// Requests in the batch.
        size: u64,
        /// Padding slots added to reach the executable batch shape.
        padded: u64,
    },
    /// Serving: a batch completed.
    BatchComplete {
        /// Pipeline scope (scenario or device id).
        scope: String,
        /// Requests in the batch.
        size: u64,
        /// Deadline slack of the tightest request in µs (negative =
        /// missed).
        slack_us: i64,
    },
    /// Fleet: one cohort's LUT-transfer summary at build time.
    CohortTransfer {
        /// Cohort id.
        cohort: String,
        /// Member devices.
        members: u64,
        /// Worst per-engine transfer confidence across members
        /// (rounded to 3 decimals).
        min_confidence: f64,
        /// True when any engine fell back to probing.
        probed: bool,
    },
    /// Fleet: the probe fallback ran for one engine of a cohort.
    ProbeFallback {
        /// Cohort id.
        cohort: String,
        /// Engine probed.
        engine: String,
        /// Probe configurations measured.
        probes: u64,
        /// Multiplicative correction folded into the engine's
        /// predictions (rounded to 3 decimals).
        correction: f64,
    },
    /// Fleet: an engine-scale LUT correction swept the cohort caches.
    Correction {
        /// Engine corrected.
        engine: String,
        /// Multiplicative latency factor applied.
        factor: f64,
        /// Cache entries updated in place across all cohorts.
        updated: u64,
        /// Frontier points touched across all cohorts.
        points_touched: u64,
    },
    /// Fleet control plane: a rollout stage transition (or hold).
    Rollout {
        /// Monotone revision id the rollout is shepherding.
        revision: u64,
        /// Stage entered (`canary`, `widening`, `promoted`,
        /// `rolled_back`) or `held` when gates lacked data.
        stage: String,
        /// Cohorts carrying the revision after this transition.
        cohorts: u64,
        /// Gate verdict or hold/rollback reason (empty when clean).
        detail: String,
    },
    /// Fleet control plane: a per-cohort per-engine residual correction
    /// distilled from measured-vs-predicted latency reports.
    Residual {
        /// Cohort id.
        cohort: String,
        /// Engine corrected.
        engine: String,
        /// Measured samples folded into the correction.
        samples: u64,
        /// Multiplicative latency factor applied to the cohort LUT
        /// (rounded to 3 decimals).
        factor: f64,
    },
    /// Fleet control plane: a cohort representative was promoted to a
    /// measured anchor after accumulated corrections crossed threshold.
    ReAnchor {
        /// Cohort id.
        cohort: String,
        /// Device re-measured as the new anchor.
        device: String,
        /// Accumulated |ln correction| that tripped the threshold
        /// (rounded to 3 decimals).
        magnitude: f64,
        /// LUT entries in the freshly measured table.
        entries: u64,
    },
    /// Co-execution: a partitioned (pipelined multi-engine) design was
    /// selected for an app, with its predicted edge over the best
    /// monolithic alternative.
    Partition {
        /// App or scenario the selection belongs to.
        scope: String,
        /// Chosen design id (plan id in the engine slot).
        design: String,
        /// Pipeline stages in the plan.
        stages: u64,
        /// Predicted steady-state latency (ms, rounded to 3 decimals).
        latency_ms: f64,
        /// Speedup over the best monolithic design (rounded to 3
        /// decimals).
        speedup: f64,
    },
    /// Scheduler: a multi-app admission decision.
    Admission {
        /// App admitted or rejected.
        scope: String,
        /// `admitted`, `admitted_degraded`, or `rejected`.
        outcome: String,
        /// Chosen design id, or the rejection reason.
        detail: String,
    },
    /// Scheduler: an arbitration window was planned.
    Arbitration {
        /// Scheduler scope label.
        scope: String,
        /// Window length (ms).
        window_ms: f64,
        /// Slice grants issued in the window.
        grants: u64,
    },
    /// SLO burn-rate alert: a scope's fast *and* slow error-budget burn
    /// both exceeded 1× over its histogram rollups
    /// ([`crate::telemetry::SloBurnMonitor`]).
    SloBurn {
        /// Burning entity (cohort id, device id, or pipeline scope).
        scope: String,
        /// Telemetry metric the monitor watched.
        metric: String,
        /// Fast (since-last-check) window length in virtual µs.
        window_us: u64,
        /// Fast-window burn rate: miss-rate ÷ error budget (rounded to
        /// 3 decimals; > 1 = burning).
        fast_burn: f64,
        /// Slow (cumulative) window burn rate (rounded to 3 decimals).
        slow_burn: f64,
        /// SLO misses inside the fast window.
        misses: u64,
        /// Samples inside the fast window.
        samples: u64,
    },
}

impl TraceEvent {
    /// Canonical event name (the JSON `ev` field).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Hold { .. } => "hold",
            TraceEvent::Switch { .. } => "switch",
            TraceEvent::Explain { .. } => "explain",
            TraceEvent::FrontierBuild { .. } => "frontier_build",
            TraceEvent::FrontierHit { .. } => "frontier_hit",
            TraceEvent::FrontierEvict { .. } => "frontier_evict",
            TraceEvent::FrontierDelta { .. } => "frontier_delta",
            TraceEvent::Enqueue { .. } => "enqueue",
            TraceEvent::Shed { .. } => "shed",
            TraceEvent::BatchLaunch { .. } => "batch_launch",
            TraceEvent::BatchComplete { .. } => "batch_complete",
            TraceEvent::CohortTransfer { .. } => "cohort_transfer",
            TraceEvent::ProbeFallback { .. } => "probe_fallback",
            TraceEvent::Correction { .. } => "correction",
            TraceEvent::Rollout { .. } => "rollout",
            TraceEvent::Residual { .. } => "residual",
            TraceEvent::ReAnchor { .. } => "re_anchor",
            TraceEvent::Partition { .. } => "partition",
            TraceEvent::Admission { .. } => "admission",
            TraceEvent::Arbitration { .. } => "arbitration",
            TraceEvent::SloBurn { .. } => "slo_burn",
        }
    }

    /// Layer category (the Chrome trace `cat` field).
    pub fn category(&self) -> &'static str {
        match self {
            TraceEvent::Hold { .. }
            | TraceEvent::Switch { .. }
            | TraceEvent::Explain { .. }
            | TraceEvent::Partition { .. } => "adaptation",
            TraceEvent::FrontierBuild { .. }
            | TraceEvent::FrontierHit { .. }
            | TraceEvent::FrontierEvict { .. }
            | TraceEvent::FrontierDelta { .. } => "frontier",
            TraceEvent::Enqueue { .. }
            | TraceEvent::Shed { .. }
            | TraceEvent::BatchLaunch { .. }
            | TraceEvent::BatchComplete { .. } => "serving",
            TraceEvent::CohortTransfer { .. }
            | TraceEvent::ProbeFallback { .. }
            | TraceEvent::Correction { .. }
            | TraceEvent::Rollout { .. }
            | TraceEvent::Residual { .. }
            | TraceEvent::ReAnchor { .. }
            | TraceEvent::SloBurn { .. } => "fleet",
            TraceEvent::Admission { .. } | TraceEvent::Arbitration { .. } => {
                "scheduler"
            }
        }
    }

    /// Payload fields in pinned order (the JSON keys after `ev`).
    fn fields(&self) -> Vec<(&'static str, Value)> {
        match self {
            TraceEvent::Hold { scope, trigger, reason } => vec![
                ("scope", json::s(scope)),
                ("trigger", json::s(trigger)),
                ("reason", json::s(reason)),
            ],
            TraceEvent::Switch { scope, from, to, reason, detection_ms } => {
                vec![
                    ("scope", json::s(scope)),
                    ("from", json::s(from)),
                    ("to", json::s(to)),
                    ("reason", json::s(reason)),
                    ("detection_ms", json::num(*detection_ms)),
                ]
            }
            TraceEvent::Explain {
                scope,
                bucket,
                chosen,
                score,
                frontier,
                alternatives,
            } => vec![
                ("scope", json::s(scope)),
                ("bucket", json::s(bucket)),
                ("chosen", json::s(chosen)),
                ("score", json::num(*score)),
                ("frontier", json::num(*frontier as f64)),
                ("alternatives", json::num(*alternatives as f64)),
            ],
            TraceEvent::FrontierBuild { scope, bucket, points, candidates } => {
                vec![
                    ("scope", json::s(scope)),
                    ("bucket", json::s(bucket)),
                    ("points", json::num(*points as f64)),
                    ("candidates", json::num(*candidates as f64)),
                ]
            }
            TraceEvent::FrontierHit { scope, bucket, points } => vec![
                ("scope", json::s(scope)),
                ("bucket", json::s(bucket)),
                ("points", json::num(*points as f64)),
            ],
            TraceEvent::FrontierEvict { scope, bucket, points } => vec![
                ("scope", json::s(scope)),
                ("bucket", json::s(bucket)),
                ("points", json::num(*points as f64)),
            ],
            TraceEvent::FrontierDelta {
                scope,
                updated,
                points_touched,
                rebuild_points,
            } => vec![
                ("scope", json::s(scope)),
                ("updated", json::num(*updated as f64)),
                ("points_touched", json::num(*points_touched as f64)),
                ("rebuild_points", json::num(*rebuild_points as f64)),
            ],
            TraceEvent::Enqueue { scope, class, depth } => vec![
                ("scope", json::s(scope)),
                ("class", json::s(class)),
                ("depth", json::num(*depth as f64)),
            ],
            TraceEvent::Shed { scope, class, depth } => vec![
                ("scope", json::s(scope)),
                ("class", json::s(class)),
                ("depth", json::num(*depth as f64)),
            ],
            TraceEvent::BatchLaunch { scope, reason, size, padded } => vec![
                ("scope", json::s(scope)),
                ("reason", json::s(reason)),
                ("size", json::num(*size as f64)),
                ("padded", json::num(*padded as f64)),
            ],
            TraceEvent::BatchComplete { scope, size, slack_us } => vec![
                ("scope", json::s(scope)),
                ("size", json::num(*size as f64)),
                ("slack_us", json::num(*slack_us as f64)),
            ],
            TraceEvent::CohortTransfer {
                cohort,
                members,
                min_confidence,
                probed,
            } => vec![
                ("cohort", json::s(cohort)),
                ("members", json::num(*members as f64)),
                ("min_confidence", json::num(*min_confidence)),
                ("probed", Value::Bool(*probed)),
            ],
            TraceEvent::ProbeFallback { cohort, engine, probes, correction } => {
                vec![
                    ("cohort", json::s(cohort)),
                    ("engine", json::s(engine)),
                    ("probes", json::num(*probes as f64)),
                    ("correction", json::num(*correction)),
                ]
            }
            TraceEvent::Correction {
                engine,
                factor,
                updated,
                points_touched,
            } => vec![
                ("engine", json::s(engine)),
                ("factor", json::num(*factor)),
                ("updated", json::num(*updated as f64)),
                ("points_touched", json::num(*points_touched as f64)),
            ],
            TraceEvent::Rollout { revision, stage, cohorts, detail } => vec![
                ("revision", json::num(*revision as f64)),
                ("stage", json::s(stage)),
                ("cohorts", json::num(*cohorts as f64)),
                ("detail", json::s(detail)),
            ],
            TraceEvent::Residual { cohort, engine, samples, factor } => vec![
                ("cohort", json::s(cohort)),
                ("engine", json::s(engine)),
                ("samples", json::num(*samples as f64)),
                ("factor", json::num(*factor)),
            ],
            TraceEvent::ReAnchor { cohort, device, magnitude, entries } => {
                vec![
                    ("cohort", json::s(cohort)),
                    ("device", json::s(device)),
                    ("magnitude", json::num(*magnitude)),
                    ("entries", json::num(*entries as f64)),
                ]
            }
            TraceEvent::Partition {
                scope,
                design,
                stages,
                latency_ms,
                speedup,
            } => vec![
                ("scope", json::s(scope)),
                ("design", json::s(design)),
                ("stages", json::num(*stages as f64)),
                ("latency_ms", json::num(*latency_ms)),
                ("speedup", json::num(*speedup)),
            ],
            TraceEvent::Admission { scope, outcome, detail } => vec![
                ("scope", json::s(scope)),
                ("outcome", json::s(outcome)),
                ("detail", json::s(detail)),
            ],
            TraceEvent::Arbitration { scope, window_ms, grants } => vec![
                ("scope", json::s(scope)),
                ("window_ms", json::num(*window_ms)),
                ("grants", json::num(*grants as f64)),
            ],
            TraceEvent::SloBurn {
                scope,
                metric,
                window_us,
                fast_burn,
                slow_burn,
                misses,
                samples,
            } => vec![
                ("scope", json::s(scope)),
                ("metric", json::s(metric)),
                ("window_us", json::num(*window_us as f64)),
                ("fast_burn", json::num(*fast_burn)),
                ("slow_burn", json::num(*slow_burn)),
                ("misses", json::num(*misses as f64)),
                ("samples", json::num(*samples as f64)),
            ],
        }
    }

    /// The event's *stream key* for sampling decisions
    /// ([`crate::telemetry::sampling`]): the finest-grained entity whose
    /// events form one causal stream — device/app scope for adaptation
    /// and serving, cohort id for cohort-level fleet events,
    /// `rev:<id>` for rollout lifecycles, `fleet` for fleet-wide
    /// aggregates.  Keeping or dropping a whole key keeps or drops whole
    /// spans, never fragments of one.
    pub fn sample_key(&self) -> String {
        match self {
            TraceEvent::Hold { scope, .. }
            | TraceEvent::Switch { scope, .. }
            | TraceEvent::Explain { scope, .. }
            | TraceEvent::FrontierBuild { scope, .. }
            | TraceEvent::FrontierHit { scope, .. }
            | TraceEvent::FrontierEvict { scope, .. }
            | TraceEvent::FrontierDelta { scope, .. }
            | TraceEvent::Enqueue { scope, .. }
            | TraceEvent::Shed { scope, .. }
            | TraceEvent::BatchLaunch { scope, .. }
            | TraceEvent::BatchComplete { scope, .. }
            | TraceEvent::Partition { scope, .. }
            | TraceEvent::Admission { scope, .. }
            | TraceEvent::Arbitration { scope, .. }
            | TraceEvent::SloBurn { scope, .. } => scope.clone(),
            TraceEvent::CohortTransfer { cohort, .. }
            | TraceEvent::ProbeFallback { cohort, .. }
            | TraceEvent::Residual { cohort, .. }
            | TraceEvent::ReAnchor { cohort, .. } => cohort.clone(),
            TraceEvent::Rollout { revision, .. } => format!("rev:{revision}"),
            TraceEvent::Correction { .. } => "fleet".to_string(),
        }
    }

    /// True for the anomaly classes tail sampling must always retain: a
    /// shed request, an SLO burn alert, a rollout rollback, and a batch
    /// that missed its deadline (negative slack).  Every class terminates
    /// the span it belongs to, so flushing the key's buffered history at
    /// the anomaly reconstructs the whole anomalous span.
    pub fn is_anomalous(&self) -> bool {
        match self {
            TraceEvent::Shed { .. } | TraceEvent::SloBurn { .. } => true,
            TraceEvent::Rollout { stage, .. } => stage == "rolled_back",
            TraceEvent::BatchComplete { slack_us, .. } => *slack_us < 0,
            _ => false,
        }
    }
}

/// A recorded event: sequence number, virtual timestamp, payload.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Monotone per-recorder sequence number (0-based, counts drops).
    pub seq: u64,
    /// Virtual timestamp (µs) the event was stamped with.
    pub t_us: u64,
    /// The event payload.
    pub event: TraceEvent,
}

impl TraceRecord {
    /// The pinned JSON-lines form of this record.
    pub fn to_json_line(&self) -> String {
        let mut fields: Vec<(String, Value)> = vec![
            ("seq".to_string(), json::num(self.seq as f64)),
            ("t_us".to_string(), json::num(self.t_us as f64)),
            ("ev".to_string(), json::s(self.event.name())),
        ];
        for (k, v) in self.event.fields() {
            fields.push((k.to_string(), v));
        }
        json::to_string(&Value::Obj(fields))
    }
}

#[derive(Debug)]
struct Ring {
    events: VecDeque<TraceRecord>,
    capacity: usize,
    seq: u64,
    dropped: u64,
    emitted: u64,
    sampler: Option<Sampler<(u64, TraceEvent)>>,
}

/// Bounded, thread-safe ring buffer of [`TraceRecord`]s with a
/// driver-advanced virtual clock.
#[derive(Debug)]
pub struct FlightRecorder {
    ring: Mutex<Ring>,
    now_us: AtomicU64,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::new()
    }
}

impl FlightRecorder {
    /// A recorder with the default capacity.
    pub fn new() -> Self {
        FlightRecorder::with_capacity(DEFAULT_TRACE_CAPACITY)
    }

    /// A recorder holding at most `capacity` events (≥ 1).
    pub fn with_capacity(capacity: usize) -> Self {
        FlightRecorder {
            ring: Mutex::new(Ring {
                events: VecDeque::with_capacity(capacity.max(1).min(4096)),
                capacity: capacity.max(1),
                seq: 0,
                dropped: 0,
                emitted: 0,
                sampler: None,
            }),
            now_us: AtomicU64::new(0),
        }
    }

    /// Advance the virtual clock; subsequent [`emit`](Self::emit) calls
    /// stamp this time.
    pub fn set_now_us(&self, t_us: u64) {
        self.now_us.store(t_us, Ordering::Relaxed);
    }

    /// The current virtual time (µs).
    pub fn now_us(&self) -> u64 {
        self.now_us.load(Ordering::Relaxed)
    }

    /// Record an event at the current virtual time.
    pub fn emit(&self, event: TraceEvent) {
        self.emit_at(self.now_us(), event);
    }

    /// Record an event at an explicit virtual time (used by layers that
    /// carry their own clock, e.g. the serving pipeline's event loop).
    ///
    /// With a sampling policy installed ([`Self::set_sampling`]) the
    /// event is first routed through the policy: sequence numbers are
    /// assigned **only to retained events**, so `seq` stays contiguous
    /// per retention class (0, 1, 2, … over the retained stream) while
    /// [`Self::sampled_out`] / [`Self::pending`] account for the rest —
    /// `emitted == seq_assigned + sampled_out + pending` always holds,
    /// and ring-overflow drops ([`Self::dropped`]) stay a separate
    /// counter.  A tail-sampling flush re-stamps the flushed history
    /// with its original timestamps under freshly assigned sequence
    /// numbers, so `t_us` may step backwards across a flush boundary
    /// (`seq` never does).
    pub fn emit_at(&self, t_us: u64, event: TraceEvent) {
        let mut g = self.ring.lock().unwrap();
        g.emitted += 1;
        let retained = match g.sampler.as_mut() {
            None => vec![(t_us, event)],
            Some(s) => {
                let key = event.sample_key();
                let anomalous = event.is_anomalous();
                match s.observe(&key, anomalous, (t_us, event)) {
                    SampleOutcome::Retain(v) => v,
                    SampleOutcome::Dropped | SampleOutcome::Buffered => {
                        Vec::new()
                    }
                }
            }
        };
        for (at, ev) in retained {
            let seq = g.seq;
            g.seq += 1;
            if g.events.len() == g.capacity {
                g.events.pop_front();
                g.dropped += 1;
            }
            g.events.push_back(TraceRecord { seq, t_us: at, event: ev });
        }
    }

    /// Install a sampling policy from a clean sampler state (replacing
    /// any previous policy; previously pending events are discarded
    /// without accounting — install before emitting).  Retention starts
    /// with the next emit; already-retained records stay.
    pub fn set_sampling(&self, policy: SamplingPolicy) {
        self.ring.lock().unwrap().sampler = Some(Sampler::new(policy));
    }

    /// Events rejected by the sampling policy (never 'dropped': ring
    /// overflow is counted separately by [`Self::dropped`]).  0 without
    /// a policy.
    pub fn sampled_out(&self) -> u64 {
        self.ring
            .lock()
            .unwrap()
            .sampler
            .as_ref()
            .map_or(0, |s| s.rejected())
    }

    /// Events parked in the tail sampler's bounded pending buffers.
    pub fn pending(&self) -> usize {
        self.ring
            .lock()
            .unwrap()
            .sampler
            .as_ref()
            .map_or(0, |s| s.pending())
    }

    /// Discard the tail sampler's pending buffers, folding them into
    /// [`Self::sampled_out`]; returns how many events were discarded.
    /// Call at end of stream to close the accounting identity.
    pub fn drain_pending(&self) -> u64 {
        self.ring
            .lock()
            .unwrap()
            .sampler
            .as_mut()
            .map_or(0, |s| s.drain())
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().events.len()
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.ring.lock().unwrap().capacity
    }

    /// Events evicted to bound the ring.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().unwrap().dropped
    }

    /// Total events ever emitted, before any sampling decision
    /// (`emitted == seq_assigned + sampled_out + pending`; without a
    /// policy this equals the sequence counter).
    pub fn emitted(&self) -> u64 {
        self.ring.lock().unwrap().emitted
    }

    /// Sequence numbers assigned so far (== retained events; the next
    /// retained event gets this value).
    pub fn seq_assigned(&self) -> u64 {
        self.ring.lock().unwrap().seq
    }

    /// Snapshot the retained records in emission order.
    pub fn records(&self) -> Vec<TraceRecord> {
        self.ring.lock().unwrap().events.iter().cloned().collect()
    }

    /// Drop every retained record (sequence numbers keep counting).
    pub fn clear(&self) {
        self.ring.lock().unwrap().events.clear();
    }

    /// JSON-lines export: one pinned-key-order object per line, trailing
    /// newline — the golden-diffable format.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in self.records() {
            out.push_str(&r.to_json_line());
            out.push('\n');
        }
        out
    }

    /// Chrome trace-event export (Perfetto-loadable): every record as an
    /// instant event with its payload under `args`, followed by the
    /// reconstructed spans ([`crate::telemetry::spans`]) as async
    /// `b`/`e` pairs — Perfetto shows adaptation episodes, serving
    /// batches, rollout lifecycles and burn episodes as bars, not just
    /// ticks — and, when a sampling policy is installed, one
    /// `sampling_policy` metadata instant carrying the retention
    /// counters.
    pub fn to_chrome_trace(&self) -> String {
        let records = self.records();
        let mut events: Vec<Value> = records
            .iter()
            .map(|r| {
                let args: Vec<(String, Value)> = r
                    .event
                    .fields()
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .chain(std::iter::once((
                        "seq".to_string(),
                        json::num(r.seq as f64),
                    )))
                    .collect();
                json::obj(vec![
                    ("name", json::s(r.event.name())),
                    ("cat", json::s(r.event.category())),
                    ("ph", json::s("i")),
                    ("ts", json::num(r.t_us as f64)),
                    ("pid", json::num(1.0)),
                    ("tid", json::num(1.0)),
                    ("s", json::s("t")),
                    ("args", Value::Obj(args)),
                ])
            })
            .collect();
        events.extend(super::spans::chrome_span_events(&records));
        {
            let g = self.ring.lock().unwrap();
            if let Some(s) = &g.sampler {
                let ts = records.last().map_or(0, |r| r.t_us);
                events.push(json::obj(vec![
                    ("name", json::s("sampling_policy")),
                    ("cat", json::s("sampling")),
                    ("ph", json::s("i")),
                    ("ts", json::num(ts as f64)),
                    ("pid", json::num(1.0)),
                    ("tid", json::num(1.0)),
                    ("s", json::s("g")),
                    (
                        "args",
                        json::obj(vec![
                            ("policy", json::s(s.policy().name())),
                            ("retained", json::num(g.seq as f64)),
                            ("sampled_out", json::num(s.rejected() as f64)),
                            ("pending", json::num(s.pending() as f64)),
                        ]),
                    ),
                ]));
            }
        }
        json::to_string(&json::obj(vec![(
            "traceEvents",
            Value::Arr(events),
        )]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hold(scope: &str) -> TraceEvent {
        TraceEvent::Hold {
            scope: scope.to_string(),
            trigger: "none".to_string(),
            reason: "no_trigger".to_string(),
        }
    }

    #[test]
    fn stamps_virtual_time_and_sequence() {
        let rec = FlightRecorder::new();
        rec.emit(hold("d0"));
        rec.set_now_us(250_000);
        rec.emit(hold("d1"));
        let rs = rec.records();
        assert_eq!(rs[0].seq, 0);
        assert_eq!(rs[0].t_us, 0);
        assert_eq!(rs[1].seq, 1);
        assert_eq!(rs[1].t_us, 250_000);
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let rec = FlightRecorder::with_capacity(4);
        for _ in 0..10 {
            rec.emit(hold("d"));
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 6);
        assert_eq!(rec.emitted(), 10);
        // Oldest evicted: the survivors are the last four.
        assert_eq!(rec.records()[0].seq, 6);
    }

    #[test]
    fn jsonl_key_order_is_pinned() {
        let rec = FlightRecorder::new();
        rec.emit(hold("d0007"));
        let line = rec.to_jsonl();
        assert_eq!(
            line,
            "{\"seq\":0,\"t_us\":0,\"ev\":\"hold\",\"scope\":\"d0007\",\
             \"trigger\":\"none\",\"reason\":\"no_trigger\"}\n"
        );
    }

    #[test]
    fn chrome_trace_wraps_trace_events() {
        let rec = FlightRecorder::new();
        rec.emit(hold("d0"));
        let chrome = rec.to_chrome_trace();
        assert!(chrome.starts_with("{\"traceEvents\":["));
        assert!(chrome.contains("\"ph\":\"i\""));
        assert!(chrome.contains("\"cat\":\"adaptation\""));
    }

    #[test]
    fn sampling_keeps_seq_contiguous_per_retention_class() {
        let rec = FlightRecorder::new();
        rec.set_sampling(SamplingPolicy::Head { rate: 4, seed: 7 });
        for i in 0..64u64 {
            rec.set_now_us(i * 10);
            rec.emit(hold(&format!("d{i:04}")));
        }
        let rs = rec.records();
        assert!(!rs.is_empty());
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.seq, i as u64, "retained seqs are 0,1,2,…");
        }
        assert!(rec.sampled_out() > 0);
        assert_eq!(rec.dropped(), 0, "policy rejections are not ring drops");
        assert_eq!(rec.emitted(), 64);
        assert_eq!(
            rec.emitted(),
            rec.seq_assigned() + rec.sampled_out() + rec.pending() as u64
        );
    }

    #[test]
    fn overflow_drops_and_sampled_out_are_distinct_counters() {
        let rec = FlightRecorder::with_capacity(4);
        rec.set_sampling(SamplingPolicy::KeepAll);
        for _ in 0..10 {
            rec.emit(hold("d"));
        }
        assert_eq!(rec.dropped(), 6, "ring overflow");
        assert_eq!(rec.sampled_out(), 0, "no policy rejections");
        assert_eq!(rec.emitted(), 10);
        assert_eq!(rec.records()[0].seq, 6);
    }

    #[test]
    fn tail_sampling_flushes_anomalous_history() {
        let rec = FlightRecorder::new();
        // Rate high enough that nothing head-passes.
        rec.set_sampling(SamplingPolicy::Tail { rate: 1 << 30, seed: 1 });
        for i in 0..3u64 {
            rec.set_now_us(i * 100);
            rec.emit(TraceEvent::Enqueue {
                scope: "p".to_string(),
                class: "cam".to_string(),
                depth: i,
            });
        }
        assert_eq!(rec.len(), 0);
        assert_eq!(rec.pending(), 3);
        rec.set_now_us(400);
        rec.emit(TraceEvent::Shed {
            scope: "p".to_string(),
            class: "cam".to_string(),
            depth: 9,
        });
        let rs = rec.records();
        assert_eq!(rs.len(), 4, "flushed history + the anomaly");
        assert_eq!(rs[0].t_us, 0, "history keeps original timestamps");
        assert_eq!(rs[3].t_us, 400);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
        }
        assert_eq!(rec.drain_pending(), 0);
        assert_eq!(rec.emitted(), rec.seq_assigned() + rec.sampled_out());
    }

    #[test]
    fn round3_matches_report_precision() {
        assert_eq!(round3(2.2414), 2.241);
        assert_eq!(round3(2.0 / 3.0), 0.667);
        assert_eq!(round3(3.0), 3.0);
    }
}
