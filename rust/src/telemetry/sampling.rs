//! Pluggable trace-sampling policies for production-rate flight
//! recording.
//!
//! A fleet emitting every [`super::trace::TraceEvent`] at production
//! rate overruns any bounded ring; "Smart at what cost?" (arXiv
//! 2109.13963)-style heavy-tailed populations make *uniform* downsampling
//! dishonest — the anomalies carry the signal.  This module provides the
//! two standard remedies, both deterministic in virtual time:
//!
//! * **Head sampling** ([`SamplingPolicy::Head`]) decides at emission
//!   from a seeded FNV-1a hash of the event's *stream key* (device
//!   scope, cohort id, revision id — [`super::trace::TraceEvent::sample_key`]),
//!   so a retained key keeps its **entire** event stream and span
//!   reconstruction ([`super::spans`]) over the sample is exact for the
//!   keys it kept.
//! * **Tail sampling** ([`SamplingPolicy::Tail`]) buffers non-retained
//!   keys' recent events in bounded pending buffers and, the moment an
//!   *anomalous* event arrives (shed, SLO-burn, rollout rollback,
//!   deadline-missing batch — [`super::trace::TraceEvent::is_anomalous`]),
//!   flushes that key's buffered history ahead of the anomalous event —
//!   anomalous spans survive at full fidelity while steady-state streams
//!   are cut by the head rate.  Every anomaly class terminates its span,
//!   so flushed history + the anomalous event is the complete span.
//!
//! The [`Sampler`] is generic over the buffered payload so the
//! [`super::trace::FlightRecorder`] (payload: stamped events) and the
//! offline analyzer in [`super::spans`] (payload: event indices) share
//! one decision procedure — the byte-pinned `oodin trace --summary`
//! sampling block is the same code path the live ring runs.
//!
//! Accounting is exact: every observed event is retained, rejected, or
//! pending, and buffer evictions fold into the rejected count, so
//! `observed == retained + rejected + pending` always holds
//! (`FlightRecorder` pins the same identity as
//! `emitted == seq + sampled_out + pending`).

use std::collections::{BTreeMap, VecDeque};

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Max buffered events per pending key under tail sampling; the oldest
/// event of the key is evicted (and counted rejected) past this.
pub const PENDING_PER_KEY: usize = 64;

/// Max distinct pending keys under tail sampling; the oldest key's whole
/// buffer is evicted (and counted rejected) past this.
pub const PENDING_KEYS: usize = 512;

/// Seeded FNV-1a over `seed` (little-endian bytes) then the key bytes —
/// the deterministic, platform-independent hash behind head sampling
/// (mirrored bit-exactly by the Python oracles).
pub fn key_hash(seed: u64, key: &str) -> u64 {
    let mut h = FNV_OFFSET;
    for b in seed.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for b in key.as_bytes() {
        h = (h ^ *b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// True when head sampling at `1/rate` keeps `key` (rates ≤ 1 keep
/// everything).  Key-level, not event-level: a kept key keeps its whole
/// stream.
pub fn head_keeps(rate: u64, seed: u64, key: &str) -> bool {
    rate <= 1 || key_hash(seed, key) % rate == 0
}

/// A trace-sampling policy, applied per event stream key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplingPolicy {
    /// Retain everything (the default recorder behaviour).
    KeepAll,
    /// Keep the streams of keys whose seeded hash lands on `0 mod rate`;
    /// reject every other event at emission.
    Head {
        /// Inverse sampling rate (`16` = keep ~1/16 of keys).
        rate: u64,
        /// Hash seed; different seeds retain different key subsets.
        seed: u64,
    },
    /// Head sampling plus bounded per-key pending buffers: an anomalous
    /// event flushes its key's buffered history and is always retained.
    Tail {
        /// Inverse head rate for non-anomalous streams.
        rate: u64,
        /// Hash seed shared with the head decision.
        seed: u64,
    },
}

impl SamplingPolicy {
    /// Stable snake_case policy name (for export metadata).
    pub fn name(&self) -> &'static str {
        match self {
            SamplingPolicy::KeepAll => "keep_all",
            SamplingPolicy::Head { .. } => "head",
            SamplingPolicy::Tail { .. } => "tail",
        }
    }
}

/// What [`Sampler::observe`] decided for one event.
#[derive(Debug, PartialEq, Eq)]
pub enum SampleOutcome<T> {
    /// Retain these payloads now, in order (the observed event alone, or
    /// a flushed pending history ending with the observed event).
    Retain(Vec<T>),
    /// Rejected by the policy (already counted in
    /// [`Sampler::rejected`]).
    Dropped,
    /// Parked in the key's bounded pending buffer (tail sampling only).
    Buffered,
}

/// Stateful sampling decision engine: one per recorder or offline
/// analysis pass.  Generic over the payload carried per event.
#[derive(Debug)]
pub struct Sampler<T> {
    policy: SamplingPolicy,
    pending: BTreeMap<String, VecDeque<T>>,
    key_order: VecDeque<String>,
    pending_total: usize,
    rejected: u64,
}

impl<T> Sampler<T> {
    /// A sampler applying `policy` from a clean state.
    pub fn new(policy: SamplingPolicy) -> Self {
        Sampler {
            policy,
            pending: BTreeMap::new(),
            key_order: VecDeque::new(),
            pending_total: 0,
            rejected: 0,
        }
    }

    /// The policy in force.
    pub fn policy(&self) -> SamplingPolicy {
        self.policy
    }

    /// Events rejected so far (explicit drops plus buffer evictions).
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Events currently parked in pending buffers.
    pub fn pending(&self) -> usize {
        self.pending_total
    }

    /// Decide one event: `key` is its stream key, `anomalous` marks the
    /// span-terminating anomaly classes.  The caller computes both from
    /// the event so this engine stays payload-agnostic.
    pub fn observe(&mut self, key: &str, anomalous: bool, payload: T)
                   -> SampleOutcome<T> {
        match self.policy {
            SamplingPolicy::KeepAll => SampleOutcome::Retain(vec![payload]),
            SamplingPolicy::Head { rate, seed } => {
                if head_keeps(rate, seed, key) {
                    SampleOutcome::Retain(vec![payload])
                } else {
                    self.rejected += 1;
                    SampleOutcome::Dropped
                }
            }
            SamplingPolicy::Tail { rate, seed } => {
                if anomalous {
                    let mut flushed = self.take_pending(key);
                    flushed.push(payload);
                    SampleOutcome::Retain(flushed)
                } else if head_keeps(rate, seed, key) {
                    SampleOutcome::Retain(vec![payload])
                } else {
                    self.buffer(key, payload);
                    SampleOutcome::Buffered
                }
            }
        }
    }

    /// Discard every pending buffer, folding the parked events into the
    /// rejected count; returns how many were discarded.  Call at end of
    /// stream so the accounting identity closes with `pending == 0`.
    pub fn drain(&mut self) -> u64 {
        let n = self.pending_total as u64;
        self.rejected += n;
        self.pending.clear();
        self.key_order.clear();
        self.pending_total = 0;
        n
    }

    fn take_pending(&mut self, key: &str) -> Vec<T> {
        match self.pending.remove(key) {
            Some(q) => {
                self.pending_total -= q.len();
                self.key_order.retain(|k| k != key);
                q.into()
            }
            None => Vec::new(),
        }
    }

    fn buffer(&mut self, key: &str, payload: T) {
        if !self.pending.contains_key(key) {
            if self.key_order.len() == PENDING_KEYS {
                // Evict the longest-pending key wholesale.
                let victim = self.key_order.pop_front().unwrap();
                let q = self.pending.remove(&victim).unwrap();
                self.pending_total -= q.len();
                self.rejected += q.len() as u64;
            }
            self.key_order.push_back(key.to_string());
            self.pending.insert(key.to_string(), VecDeque::new());
        }
        let q = self.pending.get_mut(key).unwrap();
        if q.len() == PENDING_PER_KEY {
            q.pop_front();
            self.pending_total -= 1;
            self.rejected += 1;
        }
        q.push_back(payload);
        self.pending_total += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_is_key_level_and_seeded() {
        // Rates ≤ 1 keep everything.
        assert!(head_keeps(0, 7, "x"));
        assert!(head_keeps(1, 7, "x"));
        // Same (rate, seed, key) always agrees; some seed must differ in
        // verdict across a key set (hash actually depends on the seed).
        let keys: Vec<String> = (0..64).map(|i| format!("d{i:04}")).collect();
        let a: Vec<bool> =
            keys.iter().map(|k| head_keeps(4, 7, k)).collect();
        let b: Vec<bool> =
            keys.iter().map(|k| head_keeps(4, 7, k)).collect();
        assert_eq!(a, b);
        let c: Vec<bool> =
            keys.iter().map(|k| head_keeps(4, 8, k)).collect();
        assert_ne!(a, c, "seed must perturb the retained key set");
        // Roughly 1/rate of keys survive (loose sanity bound).
        let kept = a.iter().filter(|&&x| x).count();
        assert!(kept > 0 && kept < keys.len());
    }

    #[test]
    fn accounting_identity_holds() {
        let mut s: Sampler<u64> =
            Sampler::new(SamplingPolicy::Tail { rate: 1 << 30, seed: 1 });
        let mut observed = 0u64;
        let mut retained = 0u64;
        for i in 0..200 {
            let key = format!("k{}", i % 3);
            observed += 1;
            match s.observe(&key, false, i) {
                SampleOutcome::Retain(v) => retained += v.len() as u64,
                SampleOutcome::Dropped | SampleOutcome::Buffered => {}
            }
        }
        assert_eq!(observed, retained + s.rejected() + s.pending() as u64);
        // Per-key buffers are bounded.
        assert!(s.pending() <= 3 * PENDING_PER_KEY);
        s.drain();
        assert_eq!(s.pending(), 0);
        assert_eq!(observed, retained + s.rejected());
    }

    #[test]
    fn anomaly_flushes_the_pending_history_in_order() {
        // Astronomically high rate: nothing head-passes.
        let mut s: Sampler<u64> =
            Sampler::new(SamplingPolicy::Tail { rate: 1 << 30, seed: 9 });
        for i in 0..5u64 {
            assert_eq!(s.observe("k", false, i), SampleOutcome::Buffered);
        }
        match s.observe("k", true, 99) {
            SampleOutcome::Retain(v) => {
                assert_eq!(v, vec![0, 1, 2, 3, 4, 99]);
            }
            other => panic!("expected flush, got {other:?}"),
        }
        assert_eq!(s.pending(), 0);
        assert_eq!(s.rejected(), 0);
        // The key is not permanently retained: new steady-state events
        // buffer again.
        assert_eq!(s.observe("k", false, 100), SampleOutcome::Buffered);
    }

    #[test]
    fn tail_buffers_are_bounded_per_key() {
        let mut s: Sampler<u64> =
            Sampler::new(SamplingPolicy::Tail { rate: 1 << 30, seed: 9 });
        for i in 0..(PENDING_PER_KEY as u64 + 10) {
            s.observe("k", false, i);
        }
        assert_eq!(s.pending(), PENDING_PER_KEY);
        assert_eq!(s.rejected(), 10);
        // The flush returns the most recent window.
        match s.observe("k", true, 1000) {
            SampleOutcome::Retain(v) => {
                assert_eq!(v.len(), PENDING_PER_KEY + 1);
                assert_eq!(v[0], 10);
                assert_eq!(*v.last().unwrap(), 1000);
            }
            other => panic!("expected flush, got {other:?}"),
        }
    }

    #[test]
    fn key_table_is_bounded() {
        let mut s: Sampler<u64> =
            Sampler::new(SamplingPolicy::Tail { rate: 1 << 30, seed: 9 });
        for i in 0..(PENDING_KEYS as u64 + 8) {
            s.observe(&format!("key{i:05}"), false, i);
        }
        assert_eq!(s.pending(), PENDING_KEYS);
        assert_eq!(s.rejected(), 8, "evicted whole oldest-key buffers");
    }
}
