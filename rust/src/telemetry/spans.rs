//! Trace analytics: reconstruct typed causal **spans** from the flat
//! [`super::trace::TraceEvent`] stream.
//!
//! The flight recorder answers "what happened"; this module answers
//! "how long did the episode take end-to-end, and what caused it".  A
//! single deterministic pass over a trace (a live ring snapshot or a
//! `--trace` JSONL file — both normalise to the same pinned JSON-lines
//! schema first, so the online and offline paths cannot diverge)
//! rebuilds four span families:
//!
//! * **Adaptation episodes** — degradation/load onset (first blocked
//!   `hold` whose trigger fired) through the closing `switch`, with the
//!   switch's own detection latency widening the span start when the
//!   violation predates the first recorded hold.  Every `switch` closes
//!   exactly one span; a clean `no_trigger` hold abandons a pending
//!   episode (the condition resolved itself).
//! * **Serving request/batch spans** — `enqueue → batch_launch →
//!   batch_complete` joined per pipeline scope by FIFO order (the
//!   pipeline's own dispatch order), splitting each request's latency
//!   into queue-wait vs service time; leftovers at end-of-trace are
//!   *unclosed* and pinned to zero in the goldens.
//! * **Rollout lifecycles** — the `Proposed → Canary → Widening* →
//!   Promoted | RolledBack` stage machine per revision id; a rollback is
//!   *linked* when its span contains the canary claim that caused it.
//! * **SLO-burn episodes** — `slo_burn` alerts grouped per scope.
//!
//! Cross-device **causality chains** link fleet-level causes
//! (`correction`, `rollout` stage applications, `residual`,
//! `re_anchor`) to the per-cohort `frontier_delta` events they fan out
//! at the same virtual timestamp; deltas no cause claims are *orphans*,
//! and storm `switch`es whose frontier came from a cohort touched by a
//! chain at the same instant count as *downstream switches*.
//!
//! [`Analysis::summary`] distils everything into one pinned-key-order
//! JSON object — the `oodin trace --summary` output, byte-pinned over
//! the golden fleet trace in `rust/tests/golden/trace_summary.json` and
//! regenerated independently by `python/golden_fleetbench.py`.  The
//! summary's `sampling` block replays the trace through
//! [`super::sampling`] head and tail policies at a pinned rate/seed,
//! asserting the tail policy's contract: anomalous spans survive at
//! 100 % while total retention shrinks by the pinned factor.

use anyhow::{anyhow, Result};

use std::collections::BTreeMap;
use std::collections::VecDeque;

use crate::util::json::{self, Value};

use super::sampling::{SampleOutcome, Sampler, SamplingPolicy};
use super::trace::{round3, TraceRecord};

/// Inverse sampling rate the summary's sampling block replays at.
pub const SUMMARY_SAMPLE_RATE: u64 = 16;

/// Hash seed the summary's sampling block replays with.
pub const SUMMARY_SAMPLE_SEED: u64 = 7;

/// One trace event in its normalised JSON-lines form: the pinned `seq` /
/// `t_us` / `ev` header plus the payload object.
#[derive(Debug, Clone)]
pub struct RawEvent {
    /// Sequence number (contiguous per retention class).
    pub seq: u64,
    /// Virtual timestamp (µs).
    pub t_us: u64,
    /// Event name (the `ev` field).
    pub ev: String,
    /// The full parsed line (header fields included).
    pub body: Value,
}

impl RawEvent {
    /// Parse one JSON line of the pinned trace schema.
    pub fn parse_line(line: &str) -> Result<RawEvent> {
        let body = json::parse(line)?;
        let seq = body.req("seq")?.as_u64()?;
        let t_us = body.req("t_us")?.as_u64()?;
        let ev = body.req("ev")?.as_str()?.to_string();
        Ok(RawEvent { seq, t_us, ev, body })
    }

    /// Normalise a live [`TraceRecord`] through the same pinned schema
    /// the JSONL export uses, so ring and file analyses are one path.
    pub fn from_record(r: &TraceRecord) -> RawEvent {
        RawEvent::parse_line(&r.to_json_line())
            .expect("a serialised record always re-parses")
    }

    fn s(&self, key: &str) -> &str {
        self.body.get(key).and_then(|v| v.as_str().ok()).unwrap_or("")
    }

    fn f(&self, key: &str) -> f64 {
        self.body.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
    }

    fn u(&self, key: &str) -> u64 {
        let f = self.f(key);
        if f > 0.0 { f as u64 } else { 0 }
    }

    fn i(&self, key: &str) -> i64 {
        self.f(key) as i64
    }

    /// The sampling stream key — mirrors
    /// [`super::trace::TraceEvent::sample_key`] on the parsed form.
    pub fn sample_key(&self) -> String {
        match self.ev.as_str() {
            "cohort_transfer" | "probe_fallback" | "residual"
            | "re_anchor" => self.s("cohort").to_string(),
            "rollout" => format!("rev:{}", self.u("revision")),
            "correction" => "fleet".to_string(),
            _ => self.s("scope").to_string(),
        }
    }

    /// The anomaly classes — mirrors
    /// [`super::trace::TraceEvent::is_anomalous`] on the parsed form.
    pub fn is_anomalous(&self) -> bool {
        match self.ev.as_str() {
            "shed" | "slo_burn" => true,
            "rollout" => self.s("stage") == "rolled_back",
            "batch_complete" => self.i("slack_us") < 0,
            _ => false,
        }
    }
}

/// One reconstructed adaptation episode, closed by its `switch`.
#[derive(Debug, Clone)]
pub struct AdaptationSpan {
    /// Device or app scope.
    pub scope: String,
    /// Episode start: the earlier of the first blocked hold and the
    /// switch time minus its detection latency.
    pub start_us: u64,
    /// The closing switch's timestamp.
    pub end_us: u64,
    /// The switch's detection latency in µs (0 for pure load triggers).
    pub detection_us: u64,
    /// Holds with a fired trigger inside the episode (reaction latency
    /// in decide-rounds).
    pub blocked_holds: u64,
    /// Design switched away from.
    pub from: String,
    /// Design switched to.
    pub to: String,
    /// The closing trigger (`load`, `degradation`).
    pub trigger: String,
}

/// One served request's queue-wait / service breakdown.
#[derive(Debug, Clone)]
pub struct RequestSpan {
    /// Pipeline scope.
    pub scope: String,
    /// Admission time.
    pub enqueue_us: u64,
    /// Batch launch time (queue wait ends).
    pub launch_us: u64,
    /// Batch completion time (service ends).
    pub complete_us: u64,
}

/// One launched batch from launch to completion.
#[derive(Debug, Clone)]
pub struct BatchSpan {
    /// Pipeline scope.
    pub scope: String,
    /// Launch time.
    pub launch_us: u64,
    /// Completion time.
    pub complete_us: u64,
    /// Requests in the batch (at completion).
    pub size: u64,
    /// Tightest deadline slack at completion (negative = miss).
    pub slack_us: i64,
}

/// One revision's rollout lifecycle.
#[derive(Debug, Clone)]
pub struct RolloutSpan {
    /// Revision id.
    pub revision: u64,
    /// First stage event's timestamp.
    pub start_us: u64,
    /// Last stage event's timestamp.
    pub end_us: u64,
    /// Stage names in order (including `held`).
    pub stages: Vec<String>,
    /// Terminal stage (`promoted` / `rolled_back`), empty while live.
    pub terminal: String,
    /// True when the span contains the canary claim — a terminal
    /// rollback is causally *linked* to its origin iff this holds.
    pub has_canary: bool,
}

/// `slo_burn` alerts grouped per emitting scope.
#[derive(Debug, Clone)]
pub struct BurnEpisode {
    /// Burning scope.
    pub scope: String,
    /// First alert time.
    pub start_us: u64,
    /// Last alert time.
    pub end_us: u64,
    /// Alerts in the episode.
    pub events: u64,
    /// Worst fast-window burn rate seen.
    pub max_fast_burn: f64,
}

/// One fleet cause and the per-cohort deltas it fanned out.
#[derive(Debug, Clone)]
pub struct CausalChain {
    /// Cause event name (`correction`, `rollout`, `residual`,
    /// `re_anchor`).
    pub cause: String,
    /// Cause event's sequence number.
    pub cause_seq: u64,
    /// Shared virtual timestamp of cause and deltas.
    pub t_us: u64,
    /// Cohort scopes of the attached `frontier_delta` events.
    pub cohorts: Vec<String>,
}

/// The full deterministic reconstruction over one trace.
#[derive(Debug, Default)]
pub struct Analysis {
    /// The normalised events, in input order.
    pub events: Vec<RawEvent>,
    /// Closed adaptation episodes, in switch order.
    pub adaptation: Vec<AdaptationSpan>,
    /// Pending episodes abandoned by a clean `no_trigger` hold.
    pub abandoned_episodes: u64,
    /// Episodes still pending at end of trace.
    pub open_episodes: u64,
    /// Completed request spans.
    pub requests: Vec<RequestSpan>,
    /// Completed batch spans.
    pub batches: Vec<BatchSpan>,
    /// Requests shed at admission.
    pub sheds: u64,
    /// Requests enqueued or launched but never completed.
    pub unclosed_requests: u64,
    /// Batches launched but never completed.
    pub unclosed_batches: u64,
    /// `batch_complete` events with no open batch to close.
    pub stray_completes: u64,
    /// Rollout lifecycles, in first-appearance order.
    pub rollouts: Vec<RolloutSpan>,
    /// Rollout `held` events across all revisions.
    pub rollout_holds: u64,
    /// Burn episodes, in first-appearance order.
    pub burn: Vec<BurnEpisode>,
    /// Causality chains with at least one attached delta.
    pub chains: Vec<CausalChain>,
    /// `frontier_delta` events no cause claimed.
    pub orphan_deltas: u64,
    /// Switches whose frontier came from a cohort a chain touched at
    /// the same instant.
    pub downstream_switches: u64,
    /// Sequence gaps observed (adjacent events whose seqs differ by
    /// more than one — ring truncation or mixed retention classes).
    pub seq_gaps: u64,
}

#[derive(Default)]
struct PendingEpisode {
    first_t_us: u64,
    blocked_holds: u64,
}

impl Analysis {
    /// Analyse a pinned-schema JSON-lines trace (blank lines ignored).
    pub fn from_jsonl(text: &str) -> Result<Analysis> {
        let mut events = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            events.push(RawEvent::parse_line(line).map_err(|e| {
                anyhow!("trace line {}: {e}", i + 1)
            })?);
        }
        Ok(Analysis::build(events))
    }

    /// Analyse a live ring snapshot (normalised through the JSONL
    /// schema, so this is exactly [`Analysis::from_jsonl`] semantics).
    pub fn from_records(records: &[TraceRecord]) -> Analysis {
        Analysis::build(records.iter().map(RawEvent::from_record).collect())
    }

    fn build(events: Vec<RawEvent>) -> Analysis {
        let mut a = Analysis { events, ..Analysis::default() };

        let mut episodes: BTreeMap<String, PendingEpisode> = BTreeMap::new();
        let mut queues: BTreeMap<String, VecDeque<u64>> = BTreeMap::new();
        let mut open_batches: BTreeMap<String, VecDeque<(u64, Vec<u64>)>> =
            BTreeMap::new();
        let mut rollout_order: Vec<u64> = Vec::new();
        let mut rollouts: BTreeMap<u64, RolloutSpan> = BTreeMap::new();
        let mut burn_order: Vec<String> = Vec::new();
        let mut burns: BTreeMap<String, BurnEpisode> = BTreeMap::new();
        // (seq, t_us, cohort) of frontier_delta events awaiting a cause.
        let mut pending_deltas: Vec<(u64, u64, String)> = Vec::new();
        // (t_us, cohort) instants touched by a chain.
        let mut chain_touch: Vec<(u64, String)> = Vec::new();

        for idx in 0..a.events.len() {
            let e = a.events[idx].clone();
            if idx > 0 && e.seq != a.events[idx - 1].seq + 1 {
                a.seq_gaps += 1;
            }
            // Deltas from an earlier instant can no longer be claimed:
            // causes attach same-timestamp deltas only.
            let before = pending_deltas.len();
            pending_deltas.retain(|(_, t, _)| *t >= e.t_us);
            a.orphan_deltas += (before - pending_deltas.len()) as u64;

            match e.ev.as_str() {
                "hold" => {
                    let scope = e.s("scope").to_string();
                    if e.s("trigger") != "none" {
                        let ep =
                            episodes.entry(scope).or_insert_with(|| {
                                PendingEpisode {
                                    first_t_us: e.t_us,
                                    blocked_holds: 0,
                                }
                            });
                        ep.blocked_holds += 1;
                    } else if e.s("reason") == "no_trigger"
                        && episodes.remove(&scope).is_some()
                    {
                        a.abandoned_episodes += 1;
                    }
                }
                "switch" => {
                    let scope = e.s("scope").to_string();
                    let detection_us =
                        (e.f("detection_ms") * 1000.0 + 0.5).floor() as u64;
                    let onset = e.t_us.saturating_sub(detection_us);
                    let (start_us, blocked_holds) =
                        match episodes.remove(&scope) {
                            Some(ep) => {
                                (ep.first_t_us.min(onset), ep.blocked_holds)
                            }
                            None => (onset, 0),
                        };
                    if chain_touch.iter().any(|(t, c)| {
                        *t == e.t_us
                            && idx > 0
                            && matches!(a.events[idx - 1].ev.as_str(),
                                        "frontier_hit" | "frontier_build")
                            && a.events[idx - 1].t_us == e.t_us
                            && a.events[idx - 1].s("scope") == c
                    }) {
                        a.downstream_switches += 1;
                    }
                    a.adaptation.push(AdaptationSpan {
                        scope,
                        start_us,
                        end_us: e.t_us,
                        detection_us,
                        blocked_holds,
                        from: e.s("from").to_string(),
                        to: e.s("to").to_string(),
                        trigger: e.s("reason").to_string(),
                    });
                }
                "enqueue" => {
                    queues
                        .entry(e.s("scope").to_string())
                        .or_default()
                        .push_back(e.t_us);
                }
                "shed" => {
                    a.sheds += 1;
                }
                "batch_launch" => {
                    let scope = e.s("scope").to_string();
                    let q = queues.entry(scope.clone()).or_default();
                    let n = (e.u("size") as usize).min(q.len());
                    let members: Vec<u64> = q.drain(..n).collect();
                    open_batches
                        .entry(scope)
                        .or_default()
                        .push_back((e.t_us, members));
                }
                "batch_complete" => {
                    let scope = e.s("scope").to_string();
                    match open_batches
                        .entry(scope.clone())
                        .or_default()
                        .pop_front()
                    {
                        Some((launch_us, members)) => {
                            for m in members {
                                a.requests.push(RequestSpan {
                                    scope: scope.clone(),
                                    enqueue_us: m,
                                    launch_us,
                                    complete_us: e.t_us,
                                });
                            }
                            a.batches.push(BatchSpan {
                                scope,
                                launch_us,
                                complete_us: e.t_us,
                                size: e.u("size"),
                                slack_us: e.i("slack_us"),
                            });
                        }
                        None => a.stray_completes += 1,
                    }
                }
                "rollout" => {
                    let rev = e.u("revision");
                    let stage = e.s("stage").to_string();
                    if stage == "held" {
                        a.rollout_holds += 1;
                    }
                    let span =
                        rollouts.entry(rev).or_insert_with(|| {
                            rollout_order.push(rev);
                            RolloutSpan {
                                revision: rev,
                                start_us: e.t_us,
                                end_us: e.t_us,
                                stages: Vec::new(),
                                terminal: String::new(),
                                has_canary: false,
                            }
                        });
                    span.end_us = e.t_us;
                    if stage == "canary" {
                        span.has_canary = true;
                    }
                    if stage == "promoted" || stage == "rolled_back" {
                        span.terminal = stage.clone();
                    }
                    span.stages.push(stage.clone());
                    if stage != "held" {
                        Analysis::claim_deltas(
                            &mut pending_deltas,
                            &mut chain_touch,
                            &mut a.chains,
                            "rollout",
                            &e,
                        );
                    }
                }
                "slo_burn" => {
                    let scope = e.s("scope").to_string();
                    let fast = e.f("fast_burn");
                    let ep = burns.entry(scope.clone()).or_insert_with(|| {
                        burn_order.push(scope.clone());
                        BurnEpisode {
                            scope,
                            start_us: e.t_us,
                            end_us: e.t_us,
                            events: 0,
                            max_fast_burn: 0.0,
                        }
                    });
                    ep.end_us = e.t_us;
                    ep.events += 1;
                    if fast > ep.max_fast_burn {
                        ep.max_fast_burn = fast;
                    }
                }
                "frontier_delta" => {
                    pending_deltas.push((
                        e.seq,
                        e.t_us,
                        e.s("scope").to_string(),
                    ));
                }
                "correction" | "residual" | "re_anchor" => {
                    Analysis::claim_deltas(
                        &mut pending_deltas,
                        &mut chain_touch,
                        &mut a.chains,
                        &e.ev,
                        &e,
                    );
                }
                _ => {}
            }
        }

        a.open_episodes = episodes.len() as u64;
        a.unclosed_requests = queues.values().map(|q| q.len() as u64).sum::<u64>()
            + open_batches
                .values()
                .flat_map(|b| b.iter())
                .map(|(_, m)| m.len() as u64)
                .sum::<u64>();
        a.unclosed_batches =
            open_batches.values().map(|b| b.len() as u64).sum();
        a.orphan_deltas += pending_deltas.len() as u64;
        a.rollouts = rollout_order
            .into_iter()
            .map(|r| rollouts.remove(&r).unwrap())
            .collect();
        a.burn = burn_order
            .into_iter()
            .map(|s| burns.remove(&s).unwrap())
            .collect();
        a
    }

    fn claim_deltas(pending: &mut Vec<(u64, u64, String)>,
                    touch: &mut Vec<(u64, String)>,
                    chains: &mut Vec<CausalChain>, cause: &str,
                    e: &RawEvent) {
        let mut cohorts = Vec::new();
        pending.retain(|(_, t, scope)| {
            if *t == e.t_us {
                cohorts.push(scope.clone());
                false
            } else {
                true
            }
        });
        if !cohorts.is_empty() {
            for c in &cohorts {
                touch.push((e.t_us, c.clone()));
            }
            chains.push(CausalChain {
                cause: cause.to_string(),
                cause_seq: e.seq,
                t_us: e.t_us,
                cohorts,
            });
        }
    }

    /// Count of `switch` events (each closes exactly one span).
    pub fn switches(&self) -> u64 {
        self.adaptation.len() as u64
    }

    /// Replay the events through a sampling policy; returns
    /// `(retained, retained_anomalous)` after the end-of-stream drain.
    pub fn simulate_sampling(&self, policy: SamplingPolicy) -> (u64, u64) {
        let mut s: Sampler<bool> = Sampler::new(policy);
        let mut retained = 0u64;
        let mut retained_anom = 0u64;
        for e in &self.events {
            let anom = e.is_anomalous();
            if let SampleOutcome::Retain(v) =
                s.observe(&e.sample_key(), anom, anom)
            {
                retained += v.len() as u64;
                retained_anom += v.iter().filter(|a| **a).count() as u64;
            }
        }
        s.drain();
        (retained, retained_anom)
    }

    /// The pinned-key-order summary object (`oodin trace --summary`).
    pub fn summary(&self) -> Value {
        let n = self.events.len() as u64;
        let first_seq = self.events.first().map_or(0, |e| e.seq);
        let last_seq = self.events.last().map_or(0, |e| e.seq);
        let t_first = self.events.first().map_or(0, |e| e.t_us);
        let t_last = self.events.iter().map(|e| e.t_us).max().unwrap_or(0);

        let spans = self.adaptation.len() as u64;
        let blocked: u64 =
            self.adaptation.iter().map(|s| s.blocked_holds).sum();
        let det_sum: u64 =
            self.adaptation.iter().map(|s| s.detection_us).sum();
        let det_max: u64 =
            self.adaptation.iter().map(|s| s.detection_us).max().unwrap_or(0);
        let dur_sum: u64 = self
            .adaptation
            .iter()
            .map(|s| s.end_us - s.start_us)
            .sum();
        let mean_det_ms = if spans == 0 {
            0.0
        } else {
            round3(det_sum as f64 / spans as f64 / 1000.0)
        };
        let mean_dur_ms = if spans == 0 {
            0.0
        } else {
            round3(dur_sum as f64 / spans as f64 / 1000.0)
        };

        let reqs = self.requests.len() as u64;
        let wait_sum: u64 = self
            .requests
            .iter()
            .map(|r| r.launch_us - r.enqueue_us)
            .sum();
        let service_sum: u64 = self
            .requests
            .iter()
            .map(|r| r.complete_us - r.launch_us)
            .sum();
        let mean_wait = if reqs == 0 {
            0.0
        } else {
            round3(wait_sum as f64 / reqs as f64)
        };
        let mean_service = if reqs == 0 {
            0.0
        } else {
            round3(service_sum as f64 / reqs as f64)
        };

        let promoted = self
            .rollouts
            .iter()
            .filter(|r| r.terminal == "promoted")
            .count() as u64;
        let rolled_back = self
            .rollouts
            .iter()
            .filter(|r| r.terminal == "rolled_back")
            .count() as u64;
        let rollbacks_linked = self
            .rollouts
            .iter()
            .filter(|r| r.terminal == "rolled_back")
            .all(|r| r.has_canary);

        let burn_events: u64 = self.burn.iter().map(|b| b.events).sum();
        let burn_max = round3(
            self.burn
                .iter()
                .map(|b| b.max_fast_burn)
                .fold(0.0, f64::max),
        );

        let linked_deltas: u64 =
            self.chains.iter().map(|c| c.cohorts.len() as u64).sum();

        let anomalous: u64 =
            self.events.iter().filter(|e| e.is_anomalous()).count() as u64;
        let (head_retained, _) = self.simulate_sampling(SamplingPolicy::Head {
            rate: SUMMARY_SAMPLE_RATE,
            seed: SUMMARY_SAMPLE_SEED,
        });
        let (tail_retained, tail_anom) =
            self.simulate_sampling(SamplingPolicy::Tail {
                rate: SUMMARY_SAMPLE_RATE,
                seed: SUMMARY_SAMPLE_SEED,
            });
        let reduction = if tail_retained == 0 {
            0.0
        } else {
            n as f64 / tail_retained as f64
        };
        let anom_pct = if anomalous == 0 {
            100.0
        } else {
            round3(100.0 * tail_anom as f64 / anomalous as f64)
        };

        json::obj(vec![
            (
                "events",
                json::obj(vec![
                    ("count", json::num(n as f64)),
                    ("first_seq", json::num(first_seq as f64)),
                    ("last_seq", json::num(last_seq as f64)),
                    ("seq_gaps", json::num(self.seq_gaps as f64)),
                    ("t_first_us", json::num(t_first as f64)),
                    ("t_last_us", json::num(t_last as f64)),
                ]),
            ),
            (
                "adaptation",
                json::obj(vec![
                    ("spans", json::num(spans as f64)),
                    ("switches", json::num(self.switches() as f64)),
                    (
                        "one_span_per_switch",
                        Value::Bool(spans == self.switches()),
                    ),
                    ("blocked_holds", json::num(blocked as f64)),
                    (
                        "abandoned_episodes",
                        json::num(self.abandoned_episodes as f64),
                    ),
                    ("open_episodes", json::num(self.open_episodes as f64)),
                    ("mean_detection_ms", json::num(mean_det_ms)),
                    (
                        "max_detection_ms",
                        json::num(round3(det_max as f64 / 1000.0)),
                    ),
                    ("mean_duration_ms", json::num(mean_dur_ms)),
                ]),
            ),
            (
                "serving",
                json::obj(vec![
                    ("requests", json::num(reqs as f64)),
                    ("batches", json::num(self.batches.len() as f64)),
                    ("sheds", json::num(self.sheds as f64)),
                    (
                        "unclosed_requests",
                        json::num(self.unclosed_requests as f64),
                    ),
                    (
                        "unclosed_batches",
                        json::num(self.unclosed_batches as f64),
                    ),
                    (
                        "stray_completes",
                        json::num(self.stray_completes as f64),
                    ),
                    ("mean_queue_wait_us", json::num(mean_wait)),
                    ("mean_service_us", json::num(mean_service)),
                ]),
            ),
            (
                "rollouts",
                json::obj(vec![
                    ("spans", json::num(self.rollouts.len() as f64)),
                    ("promoted", json::num(promoted as f64)),
                    ("rolled_back", json::num(rolled_back as f64)),
                    ("holds", json::num(self.rollout_holds as f64)),
                    ("all_rollbacks_linked", Value::Bool(rollbacks_linked)),
                ]),
            ),
            (
                "slo_burn",
                json::obj(vec![
                    ("events", json::num(burn_events as f64)),
                    ("episodes", json::num(self.burn.len() as f64)),
                    ("max_fast_burn", json::num(burn_max)),
                ]),
            ),
            (
                "causality",
                json::obj(vec![
                    ("chains", json::num(self.chains.len() as f64)),
                    ("linked_deltas", json::num(linked_deltas as f64)),
                    ("orphan_deltas", json::num(self.orphan_deltas as f64)),
                    (
                        "downstream_switches",
                        json::num(self.downstream_switches as f64),
                    ),
                ]),
            ),
            (
                "sampling",
                json::obj(vec![
                    ("rate", json::num(SUMMARY_SAMPLE_RATE as f64)),
                    ("seed", json::num(SUMMARY_SAMPLE_SEED as f64)),
                    ("events", json::num(n as f64)),
                    ("head_retained", json::num(head_retained as f64)),
                    ("tail_retained", json::num(tail_retained as f64)),
                    ("tail_reduction_x", json::num(round3(reduction))),
                    ("anomalous_events", json::num(anomalous as f64)),
                    ("anomalous_retained", json::num(tail_anom as f64)),
                    ("anomalous_retained_pct", json::num(anom_pct)),
                    (
                        "tail_reduction_ge_4x",
                        Value::Bool(tail_retained > 0 && reduction >= 4.0),
                    ),
                ]),
            ),
        ])
    }

    /// The summary as its pinned byte form (no trailing newline).
    pub fn summary_json(&self) -> String {
        json::to_string(&self.summary())
    }

    /// The reconstructed spans as Chrome trace async `b`/`e` event
    /// pairs (ids are assigned in span order within each family).
    pub fn chrome_spans(&self) -> Vec<Value> {
        fn pair(name: String, cat: &str, id: u64, start: u64, end: u64,
                args: Vec<(&str, Value)>) -> [Value; 2] {
            let base = |ph: &str, ts: u64, args: Vec<(&str, Value)>| {
                json::obj(vec![
                    ("name", json::s(&name)),
                    ("cat", json::s(cat)),
                    ("ph", json::s(ph)),
                    ("id", json::num(id as f64)),
                    ("ts", json::num(ts as f64)),
                    ("pid", json::num(1.0)),
                    ("tid", json::num(1.0)),
                    ("args", json::obj(args)),
                ])
            };
            [base("b", start, args), base("e", end, vec![])]
        }
        let mut out = Vec::new();
        let mut id = 0u64;
        for s in &self.adaptation {
            out.extend(pair(
                format!("adapt:{}", s.scope),
                "span",
                id,
                s.start_us,
                s.end_us,
                vec![
                    ("from", json::s(&s.from)),
                    ("to", json::s(&s.to)),
                    ("trigger", json::s(&s.trigger)),
                    ("blocked_holds", json::num(s.blocked_holds as f64)),
                ],
            ));
            id += 1;
        }
        for b in &self.batches {
            out.extend(pair(
                format!("batch:{}", b.scope),
                "span",
                id,
                b.launch_us,
                b.complete_us,
                vec![
                    ("size", json::num(b.size as f64)),
                    ("slack_us", json::num(b.slack_us as f64)),
                ],
            ));
            id += 1;
        }
        for r in &self.rollouts {
            out.extend(pair(
                format!("rollout:rev{}", r.revision),
                "span",
                id,
                r.start_us,
                r.end_us,
                vec![
                    ("stages", json::num(r.stages.len() as f64)),
                    ("terminal", json::s(&r.terminal)),
                ],
            ));
            id += 1;
        }
        for b in &self.burn {
            out.extend(pair(
                format!("burn:{}", b.scope),
                "span",
                id,
                b.start_us,
                b.end_us,
                vec![
                    ("events", json::num(b.events as f64)),
                    ("max_fast_burn", json::num(round3(b.max_fast_burn))),
                ],
            ));
            id += 1;
        }
        out
    }
}

/// Chrome span events for a live ring snapshot — the hook
/// [`super::trace::FlightRecorder::to_chrome_trace`] appends.
pub fn chrome_span_events(records: &[TraceRecord]) -> Vec<Value> {
    Analysis::from_records(records).chrome_spans()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::trace::{FlightRecorder, TraceEvent};

    fn jsonl(rec: &FlightRecorder) -> String {
        rec.to_jsonl()
    }

    #[test]
    fn switch_closes_exactly_one_span_with_onset_widening() {
        let rec = FlightRecorder::new();
        rec.set_now_us(1000);
        rec.emit(TraceEvent::Hold {
            scope: "d0".to_string(),
            trigger: "degradation".to_string(),
            reason: "cooldown".to_string(),
        });
        rec.set_now_us(3000);
        rec.emit(TraceEvent::Switch {
            scope: "d0".to_string(),
            from: "a".to_string(),
            to: "b".to_string(),
            reason: "degradation".to_string(),
            detection_ms: 5.0,
        });
        let a = Analysis::from_jsonl(&jsonl(&rec)).unwrap();
        assert_eq!(a.adaptation.len(), 1);
        let s = &a.adaptation[0];
        // Detection latency (5 ms = 5000 µs) predates the first hold.
        assert_eq!(s.start_us, 0, "3000 - 5000 saturates at 0");
        assert_eq!(s.end_us, 3000);
        assert_eq!(s.blocked_holds, 1);
        assert_eq!(a.open_episodes, 0);
    }

    #[test]
    fn clean_hold_abandons_a_pending_episode() {
        let rec = FlightRecorder::new();
        rec.emit(TraceEvent::Hold {
            scope: "d0".to_string(),
            trigger: "load".to_string(),
            reason: "below_hysteresis".to_string(),
        });
        rec.set_now_us(500);
        rec.emit(TraceEvent::Hold {
            scope: "d0".to_string(),
            trigger: "none".to_string(),
            reason: "no_trigger".to_string(),
        });
        let a = Analysis::from_jsonl(&jsonl(&rec)).unwrap();
        assert_eq!(a.adaptation.len(), 0);
        assert_eq!(a.abandoned_episodes, 1);
        assert_eq!(a.open_episodes, 0);
    }

    #[test]
    fn serving_spans_split_queue_wait_and_service() {
        let rec = FlightRecorder::new();
        let scope = "pipe".to_string();
        rec.emit_at(100, TraceEvent::Enqueue {
            scope: scope.clone(),
            class: "cam".to_string(),
            depth: 1,
        });
        rec.emit_at(200, TraceEvent::Enqueue {
            scope: scope.clone(),
            class: "cam".to_string(),
            depth: 2,
        });
        rec.emit_at(300, TraceEvent::BatchLaunch {
            scope: scope.clone(),
            reason: "full".to_string(),
            size: 2,
            padded: 0,
        });
        rec.emit_at(900, TraceEvent::BatchComplete {
            scope: scope.clone(),
            size: 2,
            slack_us: 50,
        });
        let a = Analysis::from_jsonl(&jsonl(&rec)).unwrap();
        assert_eq!(a.requests.len(), 2);
        assert_eq!(a.batches.len(), 1);
        assert_eq!(a.unclosed_requests, 0);
        assert_eq!(a.unclosed_batches, 0);
        assert_eq!(a.requests[0].launch_us - a.requests[0].enqueue_us, 200);
        assert_eq!(a.requests[0].complete_us - a.requests[0].launch_us, 600);
        // Summary means: waits (200, 100) → 150; service 600.
        let v = a.summary();
        let serving = v.get("serving").unwrap();
        assert_eq!(
            serving.get("mean_queue_wait_us").unwrap().as_f64().unwrap(),
            150.0
        );
        assert_eq!(
            serving.get("mean_service_us").unwrap().as_f64().unwrap(),
            600.0
        );
    }

    #[test]
    fn unclosed_serving_work_is_counted() {
        let rec = FlightRecorder::new();
        rec.emit_at(1, TraceEvent::Enqueue {
            scope: "p".to_string(),
            class: "c".to_string(),
            depth: 1,
        });
        rec.emit_at(2, TraceEvent::Enqueue {
            scope: "p".to_string(),
            class: "c".to_string(),
            depth: 2,
        });
        rec.emit_at(3, TraceEvent::BatchLaunch {
            scope: "p".to_string(),
            reason: "max_wait".to_string(),
            size: 1,
            padded: 0,
        });
        let a = Analysis::from_jsonl(&jsonl(&rec)).unwrap();
        assert_eq!(a.requests.len(), 0);
        assert_eq!(a.unclosed_requests, 2, "1 queued + 1 in-flight");
        assert_eq!(a.unclosed_batches, 1);
    }

    #[test]
    fn rollback_links_to_its_canary_claim() {
        let rec = FlightRecorder::new();
        let stage = |stage: &str, t: u64| {
            rec.emit_at(t, TraceEvent::Rollout {
                revision: 3,
                stage: stage.to_string(),
                cohorts: 4,
                detail: String::new(),
            });
        };
        stage("canary", 100);
        stage("held", 200);
        stage("rolled_back", 300);
        let a = Analysis::from_jsonl(&jsonl(&rec)).unwrap();
        assert_eq!(a.rollouts.len(), 1);
        assert!(a.rollouts[0].has_canary);
        assert_eq!(a.rollouts[0].terminal, "rolled_back");
        assert_eq!(a.rollout_holds, 1);
        let v = a.summary();
        assert!(v
            .get("rollouts")
            .unwrap()
            .get("all_rollbacks_linked")
            .unwrap()
            .as_bool()
            .unwrap());
    }

    #[test]
    fn causality_chains_claim_same_instant_deltas() {
        let rec = FlightRecorder::new();
        rec.set_now_us(5000);
        for c in ["c-a", "c-b"] {
            rec.emit(TraceEvent::FrontierDelta {
                scope: c.to_string(),
                updated: 1,
                points_touched: 10,
                rebuild_points: 40,
            });
        }
        rec.emit(TraceEvent::Correction {
            engine: "gpu".to_string(),
            factor: 1.1,
            updated: 2,
            points_touched: 20,
        });
        // A later orphan delta no cause ever claims.
        rec.set_now_us(6000);
        rec.emit(TraceEvent::FrontierDelta {
            scope: "c-z".to_string(),
            updated: 1,
            points_touched: 1,
            rebuild_points: 2,
        });
        let a = Analysis::from_jsonl(&jsonl(&rec)).unwrap();
        assert_eq!(a.chains.len(), 1);
        assert_eq!(a.chains[0].cohorts, vec!["c-a", "c-b"]);
        assert_eq!(a.orphan_deltas, 1);
    }

    #[test]
    fn tail_sampling_never_drops_anomalies_in_summary() {
        let rec = FlightRecorder::new();
        // A steady stream on many keys plus a few anomalies.
        for i in 0..200u64 {
            rec.set_now_us(i * 10);
            rec.emit(TraceEvent::FrontierHit {
                scope: format!("c{:03}", i % 40),
                bucket: "b".to_string(),
                points: 5,
            });
        }
        rec.set_now_us(3000);
        rec.emit(TraceEvent::Shed {
            scope: "p".to_string(),
            class: "cam".to_string(),
            depth: 7,
        });
        let a = Analysis::from_jsonl(&jsonl(&rec)).unwrap();
        let (_, tail_anom) =
            a.simulate_sampling(SamplingPolicy::Tail {
                rate: SUMMARY_SAMPLE_RATE,
                seed: SUMMARY_SAMPLE_SEED,
            });
        assert_eq!(tail_anom, 1, "the shed always survives");
        let v = a.summary();
        let smp = v.get("sampling").unwrap();
        assert_eq!(
            smp.get("anomalous_retained_pct").unwrap().as_f64().unwrap(),
            100.0
        );
    }

    #[test]
    fn head_sampled_subset_reconstructs_identical_spans_for_kept_keys() {
        use crate::telemetry::sampling::head_keeps;
        let rec = FlightRecorder::new();
        for d in 0..32u64 {
            let scope = format!("d{d:04}");
            rec.set_now_us(d * 100);
            rec.emit(TraceEvent::Hold {
                scope: scope.clone(),
                trigger: "load".to_string(),
                reason: "cooldown".to_string(),
            });
            rec.set_now_us(d * 100 + 50);
            rec.emit(TraceEvent::Switch {
                scope,
                from: "a".to_string(),
                to: "b".to_string(),
                reason: "load".to_string(),
                detection_ms: 0.0,
            });
        }
        let full = Analysis::from_jsonl(&jsonl(&rec)).unwrap();
        for seed in [1u64, 7, 23] {
            let filtered: String = jsonl(&rec)
                .lines()
                .filter(|l| {
                    let e = RawEvent::parse_line(l).unwrap();
                    head_keeps(4, seed, &e.sample_key())
                })
                .map(|l| format!("{l}\n"))
                .collect();
            let sampled = Analysis::from_jsonl(&filtered).unwrap();
            for s in &sampled.adaptation {
                let orig = full
                    .adaptation
                    .iter()
                    .find(|o| o.scope == s.scope)
                    .expect("kept scope exists in full analysis");
                assert_eq!(s.start_us, orig.start_us);
                assert_eq!(s.end_us, orig.end_us);
                assert_eq!(s.blocked_holds, orig.blocked_holds);
            }
            // Every kept key's span is present.
            let kept = full
                .adaptation
                .iter()
                .filter(|s| head_keeps(4, seed, &s.scope))
                .count();
            assert_eq!(sampled.adaptation.len(), kept);
        }
    }

    #[test]
    fn chrome_spans_pair_b_and_e() {
        let rec = FlightRecorder::new();
        rec.emit(TraceEvent::Switch {
            scope: "d0".to_string(),
            from: "a".to_string(),
            to: "b".to_string(),
            reason: "load".to_string(),
            detection_ms: 0.0,
        });
        let chrome = rec.to_chrome_trace();
        assert!(chrome.contains("\"ph\":\"b\""));
        assert!(chrome.contains("\"ph\":\"e\""));
        assert!(chrome.contains("\"name\":\"adapt:d0\""));
    }
}
