//! Observability layer: bounded metrics plus the decision flight
//! recorder.
//!
//! * [`Telemetry`] — thread-safe counters and latency metrics.  Latency
//!   samples land in fixed-bucket log-scaled [`histogram::LogHistogram`]s,
//!   so a metric's memory is `O(buckets)` no matter how many samples a
//!   long-running device records, and sinks merge — the substrate for
//!   per-cohort fleet rollups.
//! * [`trace::FlightRecorder`] — a bounded ring of typed, virtually
//!   timestamped [`trace::TraceEvent`]s explaining every adaptation
//!   decision, frontier-cache transition, serving action and fleet
//!   correction after the fact.

pub mod histogram;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::{self, Value};
use crate::util::stats::LatencyStats;

use histogram::LogHistogram;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    samples: BTreeMap<String, LogHistogram>,
}

/// Shared metrics sink with bounded per-metric memory.
#[derive(Default)]
pub struct Telemetry {
    inner: Mutex<Inner>,
}

impl Telemetry {
    /// An empty sink.
    pub fn new() -> Self {
        Telemetry::default()
    }

    /// Increment counter `name` by one.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `by` to counter `name` (created at zero).
    pub fn add(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current counter value (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Record one latency sample (ms) under `name`.  O(1) memory per
    /// metric: the sample folds into a bounded log-scaled histogram.
    pub fn record(&self, name: &str, ms: f64) {
        let mut g = self.inner.lock().unwrap();
        g.samples.entry(name.to_string()).or_default().record(ms);
    }

    /// Summary of the samples recorded under `name`; `None` when empty.
    /// `min`/`max`/`avg`/`n` are exact; quantiles carry the histogram's
    /// documented bucket error (≤ 4.5 % relative).
    pub fn stats(&self, name: &str) -> Option<LatencyStats> {
        let g = self.inner.lock().unwrap();
        g.samples.get(name).and_then(|h| h.stats())
    }

    /// Bytes resident in the latency histograms — proportional to the
    /// number of *metrics*, never to the number of samples.
    pub fn resident_bytes(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.samples.values().map(|h| h.resident_bytes()).sum()
    }

    /// Fold another sink into this one: counters add, histograms merge
    /// bucket-wise.  The cohort → fleet rollup primitive.
    pub fn merge_from(&self, other: &Telemetry) {
        let o = other.inner.lock().unwrap();
        let mut g = self.inner.lock().unwrap();
        for (k, v) in &o.counters {
            *g.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &o.samples {
            g.samples.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Everything as JSON: counters verbatim, samples summarised.
    pub fn snapshot(&self) -> Value {
        let g = self.inner.lock().unwrap();
        let counters: Vec<(String, Value)> = g
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), json::num(*v as f64)))
            .collect();
        let stats: Vec<(String, Value)> = g
            .samples
            .iter()
            .filter_map(|(k, h)| h.stats().map(|s| (k.clone(), s.to_json())))
            .collect();
        Value::Obj(vec![
            ("counters".to_string(), Value::Obj(counters)),
            ("latency".to_string(), Value::Obj(stats)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let t = Telemetry::new();
        t.incr("req");
        t.add("req", 4);
        assert_eq!(t.counter("req"), 5);
        assert_eq!(t.counter("missing"), 0);
    }

    #[test]
    fn samples_summarise() {
        let t = Telemetry::new();
        for x in [1.0, 2.0, 3.0] {
            t.record("lat", x);
        }
        let s = t.stats("lat").unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.avg, 2.0);
        assert!(t.stats("none").is_none());
    }

    #[test]
    fn concurrent_updates() {
        let t = std::sync::Arc::new(Telemetry::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        t.incr("n");
                        t.record("x", 1.0);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.counter("n"), 800);
        assert_eq!(t.stats("x").unwrap().n, 800);
    }

    #[test]
    fn snapshot_is_json() {
        let t = Telemetry::new();
        t.incr("a");
        t.record("l", 5.0);
        let v = t.snapshot();
        assert!(v.get("counters").unwrap().get("a").is_some());
        assert!(v.get("latency").unwrap().get("l").is_some());
    }

    #[test]
    fn memory_stays_bounded_and_sinks_merge() {
        let a = Telemetry::new();
        a.record("lat", 1.0);
        let footprint = a.resident_bytes();
        for i in 0..50_000 {
            a.record("lat", 0.5 + (i % 100) as f64);
        }
        assert_eq!(a.resident_bytes(), footprint);

        let b = Telemetry::new();
        b.incr("req");
        b.record("lat", 1000.0);
        a.merge_from(&b);
        assert_eq!(a.counter("req"), 1);
        let s = a.stats("lat").unwrap();
        assert_eq!(s.n, 50_002);
        assert_eq!(s.max, 1000.0);
    }
}
