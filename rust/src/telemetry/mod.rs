//! Lightweight metrics registry: counters + latency samples, thread-safe,
//! serialisable to JSON for the experiment reports.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::{self, Value};
use crate::util::stats::LatencyStats;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    samples: BTreeMap<String, Vec<f64>>,
}

/// Shared metrics sink.
#[derive(Default)]
pub struct Telemetry {
    inner: Mutex<Inner>,
}

impl Telemetry {
    /// An empty sink.
    pub fn new() -> Self {
        Telemetry::default()
    }

    /// Increment counter `name` by one.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `by` to counter `name` (created at zero).
    pub fn add(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current counter value (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Record one latency sample (ms) under `name`.
    pub fn record(&self, name: &str, ms: f64) {
        let mut g = self.inner.lock().unwrap();
        g.samples.entry(name.to_string()).or_default().push(ms);
    }

    /// Summary of the samples recorded under `name`; `None` when empty.
    pub fn stats(&self, name: &str) -> Option<LatencyStats> {
        let g = self.inner.lock().unwrap();
        g.samples.get(name).filter(|s| !s.is_empty())
            .map(|s| LatencyStats::from_samples(s))
    }

    /// Everything as JSON: counters verbatim, samples summarised.
    pub fn snapshot(&self) -> Value {
        let g = self.inner.lock().unwrap();
        let counters: Vec<(String, Value)> = g
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), json::num(*v as f64)))
            .collect();
        let stats: Vec<(String, Value)> = g
            .samples
            .iter()
            .filter(|(_, s)| !s.is_empty())
            .map(|(k, s)| (k.clone(), LatencyStats::from_samples(s).to_json()))
            .collect();
        Value::Obj(vec![
            ("counters".to_string(), Value::Obj(counters)),
            ("latency".to_string(), Value::Obj(stats)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let t = Telemetry::new();
        t.incr("req");
        t.add("req", 4);
        assert_eq!(t.counter("req"), 5);
        assert_eq!(t.counter("missing"), 0);
    }

    #[test]
    fn samples_summarise() {
        let t = Telemetry::new();
        for x in [1.0, 2.0, 3.0] {
            t.record("lat", x);
        }
        let s = t.stats("lat").unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.avg, 2.0);
        assert!(t.stats("none").is_none());
    }

    #[test]
    fn concurrent_updates() {
        let t = std::sync::Arc::new(Telemetry::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        t.incr("n");
                        t.record("x", 1.0);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.counter("n"), 800);
        assert_eq!(t.stats("x").unwrap().n, 800);
    }

    #[test]
    fn snapshot_is_json() {
        let t = Telemetry::new();
        t.incr("a");
        t.record("l", 5.0);
        let v = t.snapshot();
        assert!(v.get("counters").unwrap().get("a").is_some());
        assert!(v.get("latency").unwrap().get("l").is_some());
    }
}
