//! Observability layer: bounded metrics plus the decision flight
//! recorder.
//!
//! * [`Telemetry`] — thread-safe counters and latency metrics.  Latency
//!   samples land in fixed-bucket log-scaled [`histogram::LogHistogram`]s,
//!   so a metric's memory is `O(buckets)` no matter how many samples a
//!   long-running device records, and sinks merge — the substrate for
//!   per-cohort fleet rollups.
//! * [`trace::FlightRecorder`] — a bounded ring of typed, virtually
//!   timestamped [`trace::TraceEvent`]s explaining every adaptation
//!   decision, frontier-cache transition, serving action and fleet
//!   correction after the fact.
//! * [`sampling`] — head/tail sampling policies that keep the ring
//!   honest at production event rates with bounded memory.
//! * [`spans`] — deterministic trace analytics: typed causal spans,
//!   cross-device causality chains and the `oodin trace` summary.
//! * [`SloBurnMonitor`] — fast/slow-window error-budget burn rates over
//!   the histogram rollups, emitting `slo_burn` trace events.

pub mod histogram;
pub mod sampling;
pub mod spans;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::{self, Value};
use crate::util::stats::LatencyStats;

use histogram::LogHistogram;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    samples: BTreeMap<String, LogHistogram>,
}

/// Shared metrics sink with bounded per-metric memory.
#[derive(Default)]
pub struct Telemetry {
    inner: Mutex<Inner>,
}

impl Telemetry {
    /// An empty sink.
    pub fn new() -> Self {
        Telemetry::default()
    }

    /// Increment counter `name` by one.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `by` to counter `name` (created at zero).
    pub fn add(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current counter value (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Record one latency sample (ms) under `name`.  O(1) memory per
    /// metric: the sample folds into a bounded log-scaled histogram.
    pub fn record(&self, name: &str, ms: f64) {
        let mut g = self.inner.lock().unwrap();
        g.samples.entry(name.to_string()).or_default().record(ms);
    }

    /// Summary of the samples recorded under `name`; `None` when empty.
    /// `min`/`max`/`avg`/`n` are exact; quantiles carry the histogram's
    /// documented bucket error (≤ 4.5 % relative).
    pub fn stats(&self, name: &str) -> Option<LatencyStats> {
        let g = self.inner.lock().unwrap();
        g.samples.get(name).and_then(|h| h.stats())
    }

    /// `(total samples, samples above threshold)` for metric `name` —
    /// the cumulative counters a [`SloBurnMonitor`] differences into
    /// fast-window burn rates.  `None` when the metric was never
    /// recorded.  Miss counting is bucket-exact
    /// ([`histogram::LogHistogram::count_above`]), so it survives
    /// cohort merges and is mirrored by the Python oracles.
    pub fn burn_counts(&self, name: &str, threshold: f64) -> Option<(u64, u64)> {
        let g = self.inner.lock().unwrap();
        g.samples
            .get(name)
            .map(|h| (h.count(), h.count_above(threshold)))
    }

    /// Bytes resident in the latency histograms — proportional to the
    /// number of *metrics*, never to the number of samples.
    pub fn resident_bytes(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.samples.values().map(|h| h.resident_bytes()).sum()
    }

    /// Fold another sink into this one: counters add, histograms merge
    /// bucket-wise.  The cohort → fleet rollup primitive.
    pub fn merge_from(&self, other: &Telemetry) {
        let o = other.inner.lock().unwrap();
        let mut g = self.inner.lock().unwrap();
        for (k, v) in &o.counters {
            *g.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &o.samples {
            g.samples.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Everything as JSON: counters verbatim, samples summarised.
    pub fn snapshot(&self) -> Value {
        let g = self.inner.lock().unwrap();
        let counters: Vec<(String, Value)> = g
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), json::num(*v as f64)))
            .collect();
        let stats: Vec<(String, Value)> = g
            .samples
            .iter()
            .filter_map(|(k, h)| h.stats().map(|s| (k.clone(), s.to_json())))
            .collect();
        Value::Obj(vec![
            ("counters".to_string(), Value::Obj(counters)),
            ("latency".to_string(), Value::Obj(stats)),
        ])
    }
}

/// Configuration of an [`SloBurnMonitor`].
#[derive(Debug, Clone)]
pub struct BurnConfig {
    /// SLO threshold on the watched metric: a sample is a *miss* when
    /// it lands strictly above the threshold's histogram bucket.
    pub threshold: f64,
    /// Error budget: the tolerated miss *fraction* (e.g. `0.25` = one
    /// in four samples may miss).  Burn rate = miss-rate ÷ budget.
    pub budget: f64,
    /// Minimum new samples in the fast window for a verdict — fewer
    /// and the check abstains (no alert from noise).
    pub min_samples: u64,
}

/// One burn-rate verdict from [`SloBurnMonitor::check_counts`].
#[derive(Debug, Clone, PartialEq)]
pub struct BurnSample {
    /// Fast-window length in virtual µs (time since the previous
    /// check of this scope).
    pub window_us: u64,
    /// Fast-window burn rate: new-miss-rate ÷ budget (> 1 = burning).
    pub fast_burn: f64,
    /// Slow-window burn rate: cumulative miss-rate ÷ budget.
    pub slow_burn: f64,
    /// Misses inside the fast window.
    pub misses: u64,
    /// Samples inside the fast window.
    pub samples: u64,
    /// True when *both* windows burn above 1× — the multi-window
    /// alert condition (fast alone is noisy, slow alone is stale).
    pub burning: bool,
}

/// Multi-window SLO burn-rate monitor over cumulative histogram
/// counters.
///
/// Classic burn-rate alerting compares the error-budget consumption
/// rate over a *fast* window (recent behaviour, quick detection) and a
/// *slow* window (sustained behaviour, de-noising); an alert needs
/// both above 1×.  Here both windows live in virtual time: the fast
/// window is everything since the scope's previous check (the caller's
/// own cadence — fleet-bench checks once per regret tick), the slow
/// window is the metric's full history.  State per scope is three
/// integers — bounded regardless of sample rate — and every verdict is
/// a pure function of bucket counts, so the Python oracles reproduce
/// alerts bit-for-bit.
#[derive(Debug)]
pub struct SloBurnMonitor {
    cfg: BurnConfig,
    /// Per-scope `(count, above, t_us)` at the previous check.
    prev: BTreeMap<String, (u64, u64, u64)>,
}

impl SloBurnMonitor {
    /// A monitor with the given thresholds.
    pub fn new(cfg: BurnConfig) -> Self {
        SloBurnMonitor { cfg, prev: BTreeMap::new() }
    }

    /// The configured thresholds.
    pub fn config(&self) -> &BurnConfig {
        &self.cfg
    }

    /// Advance `scope`'s window against cumulative `(count, above)`
    /// counters at virtual time `now_us`.  The window *always*
    /// advances; the verdict is `None` when fewer than `min_samples`
    /// new samples arrived (abstain, not "healthy").
    pub fn check_counts(&mut self, scope: &str, now_us: u64, count: u64,
                        above: u64) -> Option<BurnSample> {
        let cfg = self.cfg.clone();
        let (pc, pa, pt) = self
            .prev
            .insert(scope.to_string(), (count, above, now_us))
            .unwrap_or((0, 0, now_us));
        let dc = count.saturating_sub(pc);
        let da = above.saturating_sub(pa);
        if count == 0 || dc < cfg.min_samples.max(1) {
            return None;
        }
        let fast_burn = (da as f64 / dc as f64) / cfg.budget;
        let slow_burn = (above as f64 / count as f64) / cfg.budget;
        Some(BurnSample {
            window_us: now_us.saturating_sub(pt),
            fast_burn,
            slow_burn,
            misses: da,
            samples: dc,
            burning: fast_burn > 1.0 && slow_burn > 1.0,
        })
    }

    /// [`SloBurnMonitor::check_counts`] against a live sink's metric
    /// (`None` also when the metric was never recorded — the window
    /// still advances to `now_us`).
    pub fn check(&mut self, scope: &str, sink: &Telemetry, metric: &str,
                 now_us: u64) -> Option<BurnSample> {
        let (count, above) = sink
            .burn_counts(metric, self.config().threshold)
            .unwrap_or((0, 0));
        self.check_counts(scope, now_us, count, above)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let t = Telemetry::new();
        t.incr("req");
        t.add("req", 4);
        assert_eq!(t.counter("req"), 5);
        assert_eq!(t.counter("missing"), 0);
    }

    #[test]
    fn samples_summarise() {
        let t = Telemetry::new();
        for x in [1.0, 2.0, 3.0] {
            t.record("lat", x);
        }
        let s = t.stats("lat").unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.avg, 2.0);
        assert!(t.stats("none").is_none());
    }

    #[test]
    fn concurrent_updates() {
        let t = std::sync::Arc::new(Telemetry::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        t.incr("n");
                        t.record("x", 1.0);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.counter("n"), 800);
        assert_eq!(t.stats("x").unwrap().n, 800);
    }

    #[test]
    fn snapshot_is_json() {
        let t = Telemetry::new();
        t.incr("a");
        t.record("l", 5.0);
        let v = t.snapshot();
        assert!(v.get("counters").unwrap().get("a").is_some());
        assert!(v.get("latency").unwrap().get("l").is_some());
    }

    #[test]
    fn burn_monitor_needs_both_windows_hot() {
        let mut m = SloBurnMonitor::new(BurnConfig {
            threshold: 5.0,
            budget: 0.25,
            min_samples: 4,
        });
        let t = Telemetry::new();
        // Healthy history: 8 samples, 0 misses.
        for _ in 0..8 {
            t.record("lat", 1.0);
        }
        let s = m.check("d0", &t, "lat", 1000).unwrap();
        assert!(!s.burning);
        assert_eq!(s.samples, 8);
        // A hot fast window: 8 new samples, all misses.  Fast burn is
        // 4×; slow is (8/16)/0.25 = 2× — both above 1 → alert.
        for _ in 0..8 {
            t.record("lat", 50.0);
        }
        let s = m.check("d0", &t, "lat", 2000).unwrap();
        assert!(s.burning);
        assert_eq!(s.window_us, 1000);
        assert_eq!(s.misses, 8);
        assert_eq!(s.fast_burn, 4.0);
        assert_eq!(s.slow_burn, 2.0);
    }

    #[test]
    fn burn_monitor_abstains_below_min_samples_but_advances() {
        let mut m = SloBurnMonitor::new(BurnConfig {
            threshold: 5.0,
            budget: 0.25,
            min_samples: 4,
        });
        let t = Telemetry::new();
        for _ in 0..3 {
            t.record("lat", 50.0);
        }
        // 3 < min_samples → abstain; the window still advances, so the
        // same 3 samples never accumulate into a later fast window.
        assert!(m.check("d0", &t, "lat", 100).is_none());
        for _ in 0..3 {
            t.record("lat", 50.0);
        }
        assert!(m.check("d0", &t, "lat", 200).is_none());
        // Unknown metric: abstains, never panics.
        assert!(m.check("d0", &t, "nope", 300).is_none());
    }

    #[test]
    fn memory_stays_bounded_and_sinks_merge() {
        let a = Telemetry::new();
        a.record("lat", 1.0);
        let footprint = a.resident_bytes();
        for i in 0..50_000 {
            a.record("lat", 0.5 + (i % 100) as f64);
        }
        assert_eq!(a.resident_bytes(), footprint);

        let b = Telemetry::new();
        b.incr("req");
        b.record("lat", 1000.0);
        a.merge_from(&b);
        assert_eq!(a.counter("req"), 1);
        let s = a.stats("lat").unwrap();
        assert_eq!(s.n, 50_002);
        assert_eq!(s.max, 1000.0);
    }
}
