//! Fixed-bucket log-scaled latency histograms: the bounded-memory,
//! mergeable substrate behind [`super::Telemetry`] and the per-cohort
//! fleet rollups.
//!
//! Motivation: the old telemetry sink kept every raw sample in a
//! `Vec<f64>` per metric — a long-running device recording one latency
//! per inference grows without bound.  A `LogHistogram` instead buckets
//! samples on a logarithmic grid with [`SUBBUCKETS_PER_OCTAVE`] buckets
//! per power of two, so memory is `O(BUCKETS)` regardless of sample
//! count, and two histograms merge by adding counts — exactly what
//! population-scale rollups (fleet cohorts → fleet) need.
//!
//! **Accuracy contract.** `count`, `sum` (hence the mean), `min` and
//! `max` are exact.  Quantiles are approximate: a reported quantile is
//! the geometric midpoint of the sub-bucket holding the ranked sample,
//! clamped to `[min, max]`, so its relative error versus the exact
//! sample value is at most `2^(1/SUBBUCKETS_PER_OCTAVE) - 1` — with 16
//! sub-buckets per octave, **≤ 4.5 %**.  The property suite in
//! `tests/telemetry_props.rs` enforces this bound against exact
//! order statistics.

use crate::util::stats::LatencyStats;

/// Log-grid resolution: sub-buckets per power of two.  The documented
/// quantile error bound is `2^(1/SUBBUCKETS_PER_OCTAVE) - 1` (≈ 4.43 %).
pub const SUBBUCKETS_PER_OCTAVE: usize = 16;

/// Smallest finite-bucket exponent: values below `2^MIN_EXP` (≈ 1 µs when
/// samples are milliseconds) land in the underflow bucket.
pub const MIN_EXP: i32 = -20;

/// Largest finite-bucket exponent: values at or above `2^MAX_EXP`
/// (≈ 12 days in milliseconds) land in the overflow bucket.
pub const MAX_EXP: i32 = 30;

/// Total bucket count: the finite log grid plus one underflow and one
/// overflow bucket.  Memory per histogram is `BUCKETS * 8` bytes of
/// counts plus a constant header — independent of how many samples are
/// recorded.
pub const BUCKETS: usize =
    (MAX_EXP - MIN_EXP) as usize * SUBBUCKETS_PER_OCTAVE + 2;

/// A bounded, mergeable latency histogram (samples in milliseconds).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

/// The sub-bucket a value falls into.  Non-positive (and NaN) values
/// share the underflow bucket: latencies are positive by construction
/// and the recorder must stay total.
fn bucket_index(v: f64) -> usize {
    if !(v >= f64::exp2(MIN_EXP as f64)) {
        return 0; // underflow (also v <= 0 and NaN)
    }
    let l = v.log2();
    if l >= MAX_EXP as f64 {
        return BUCKETS - 1; // overflow
    }
    let grid = ((l - MIN_EXP as f64) * SUBBUCKETS_PER_OCTAVE as f64) as usize;
    1 + grid.min(BUCKETS - 3)
}

/// Geometric midpoint of a finite sub-bucket — the value a quantile
/// query reports for samples in that bucket.
fn bucket_mid(i: usize) -> f64 {
    debug_assert!(i >= 1 && i <= BUCKETS - 2);
    let step = (i - 1) as f64 + 0.5;
    f64::exp2(MIN_EXP as f64 + step / SUBBUCKETS_PER_OCTAVE as f64)
}

impl LogHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample.  O(1); never allocates after construction.
    /// NaN is recorded as zero (underflow) so the sink stays total.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_nan() { 0.0 } else { v };
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Samples recorded (exact).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples (exact).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Fold another histogram into this one: counts add bucket-wise,
    /// `sum`/`count`/`min`/`max` combine exactly.  This is the cohort →
    /// fleet rollup primitive.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Approximate quantile `q ∈ [0, 1]`: the geometric midpoint of the
    /// sub-bucket holding the sample of rank `ceil(q·count)`, clamped to
    /// the exact `[min, max]`.  `None` when empty.  Relative error vs
    /// the exact order statistic is ≤ `2^(1/SUBBUCKETS_PER_OCTAVE) - 1`.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let target = ((q * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                let mid = if i == 0 {
                    self.min
                } else if i == BUCKETS - 1 {
                    self.max
                } else {
                    bucket_mid(i)
                };
                return Some(mid.clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }

    /// Samples recorded in buckets strictly above the bucket holding
    /// `threshold` — the deterministic SLO-miss counter behind
    /// [`super::SloBurnMonitor`].  Resolution is the bucket grid:
    /// samples sharing `threshold`'s sub-bucket (within the documented
    /// ≤ 4.5 % grid width) are *not* counted, so the count depends only
    /// on bucket contents and survives [`LogHistogram::merge`]
    /// bucket-wise — the N-version oracles mirror it from the same grid
    /// arithmetic.
    pub fn count_above(&self, threshold: f64) -> u64 {
        let b = bucket_index(threshold);
        self.counts[b + 1..].iter().sum()
    }

    /// Summarise as [`LatencyStats`]: `min`/`max`/`avg`/`n` exact,
    /// `median`/`p90`/`p99` within the documented bucket error.  `None`
    /// when empty.
    pub fn stats(&self) -> Option<LatencyStats> {
        if self.count == 0 {
            return None;
        }
        Some(LatencyStats {
            min: self.min,
            max: self.max,
            avg: self.sum / self.count as f64,
            median: self.quantile(0.5).unwrap(),
            p90: self.quantile(0.9).unwrap(),
            p99: self.quantile(0.99).unwrap(),
            n: self.count as usize,
        })
    }

    /// Bytes resident in this histogram — a constant (`BUCKETS` counts
    /// plus the header), independent of samples recorded.
    pub fn resident_bytes(&self) -> usize {
        self.counts.capacity() * std::mem::size_of::<u64>()
            + std::mem::size_of::<Self>()
    }
}

/// Exact quantile of a raw sample set — the reference the property suite
/// compares [`LogHistogram::quantile`] against (rank semantics match:
/// the sample of rank `ceil(q·n)`).
pub fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let target = ((q * sorted.len() as f64).ceil() as usize)
        .clamp(1, sorted.len());
    sorted[target - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_moments_survive_bucketing() {
        let mut h = LogHistogram::new();
        for x in [1.0, 2.0, 3.0] {
            h.record(x);
        }
        let s = h.stats().unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.avg, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn quantiles_within_documented_bound() {
        let bound = f64::exp2(1.0 / SUBBUCKETS_PER_OCTAVE as f64) - 1.0;
        let mut h = LogHistogram::new();
        let mut raw: Vec<f64> = (1..=500).map(|i| 0.37 * i as f64).collect();
        for &x in &raw {
            h.record(x);
        }
        raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.1, 0.5, 0.9, 0.99] {
            let exact = exact_quantile(&raw, q);
            let approx = h.quantile(q).unwrap();
            let err = (approx / exact - 1.0).abs();
            assert!(err <= bound, "q={q}: {approx} vs {exact} (err {err})");
        }
    }

    #[test]
    fn merge_is_count_exact() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for i in 0..100 {
            a.record(1.0 + i as f64);
            b.record(500.0 + i as f64);
        }
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.count(), 200);
        assert_eq!(m.sum(), a.sum() + b.sum());
        let s = m.stats().unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 599.0);
    }

    #[test]
    fn degenerate_values_land_in_underflow() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        assert_eq!(h.count(), 3);
        // The underflow bucket reports `min` for every quantile.
        assert_eq!(h.quantile(0.5).unwrap(), h.stats().unwrap().min);
    }

    #[test]
    fn count_above_is_bucket_exact_and_merges() {
        let mut h = LogHistogram::new();
        for v in [1.0, 2.0, 4.0, 20.0, 40.0] {
            h.record(v);
        }
        // Well-separated values: everything above 5.0's bucket is the
        // {20, 40} pair; the threshold's own bucket never counts.
        assert_eq!(h.count_above(5.0), 2);
        assert_eq!(h.count_above(0.5), 5);
        assert_eq!(h.count_above(100.0), 0);
        let mut other = LogHistogram::new();
        other.record(30.0);
        h.merge(&other);
        assert_eq!(h.count_above(5.0), 3);
        // Overflow threshold: nothing can sit strictly above it.
        assert_eq!(h.count_above(f64::exp2(MAX_EXP as f64 + 1.0)), 0);
    }

    #[test]
    fn memory_is_constant_in_sample_count() {
        let mut h = LogHistogram::new();
        let before = h.resident_bytes();
        for i in 0..10_000 {
            h.record(0.01 * (i + 1) as f64);
        }
        assert_eq!(h.resident_bytes(), before);
    }
}
