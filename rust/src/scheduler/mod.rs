//! Multi-app scheduler: N concurrent DL apps with per-app SLOs on one
//! device.
//!
//! OODIn optimises a *single* app's design σ = <m_ref, t, hw>; this layer
//! hosts several at once — the multi-DNN reality the paper's motivation
//! (and its follow-up CARIn) describes, where processor contention is the
//! dominant source of latency variability.  Three mechanisms:
//!
//! 1. **Joint optimisation** ([`joint`]) — the enumerative LUT search
//!    extended to a design vector (σ₁…σ_N) under global constraints
//!    (exclusive GPU/NNAPI ownership, shared CPU-core budget, total
//!    model-memory cap, per-engine time budget).
//! 2. **Engine arbitration + admission control** ([`arbiter`] +
//!    [`Scheduler::register`]) — time-sliced windows in which no two apps
//!    hold a contended offload engine in the same slice and no admitted
//!    app starves; apps that cannot fit are degraded (lower precision /
//!    recognition rate, via the joint search's candidate ladder) or
//!    rejected.
//! 3. **Joint re-adaptation** ([`Scheduler::observe`]) — on a significant
//!    condition shift the joint search re-runs under adjusted latencies
//!    (reusing the Runtime Manager's [`manager::adjusted_latency`]
//!    scoring) and issues *coordinated* switches, instead of N
//!    independent, oscillating managers.  Per-app candidates come from
//!    cached Pareto frontiers ([`crate::designspace`]) shared across all
//!    admission/re-adaptation events, so each event composes O(frontier)
//!    ladders instead of re-enumerating the σ-space.

pub mod arbiter;
pub mod joint;

pub use arbiter::{Arbiter, Grant, Slice, Window};
pub use joint::{GlobalBudget, JointAssignment, JointSearch, PredictedApp};

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::designspace::{CacheStats, DeltaOutcome, DesignSpace,
                         FrontierCache, LutDelta};
use crate::device::{DeviceProfile, EngineKind};
use crate::devicesim::DeviceSim;
use crate::manager::{design_id, Conditions, Policy, Reason, Switch};
use crate::telemetry::trace::{FlightRecorder, TraceEvent};
use crate::measurements::Lut;
use crate::model::Registry;
use crate::optimizer::{Design, Objective};

/// What one app asks of the device: its model family, arrival pattern and
/// service-level objective.  This is the workload-descriptor format the
/// `multi` CLI scenario and the multi-app experiment driver feed in.
#[derive(Debug, Clone)]
pub struct WorkloadDescriptor {
    /// Unique tenant id.
    pub app_id: String,
    /// Model family the app was built around (the user-supplied DNN).
    pub family: String,
    /// Arrival pattern: frames/s offered to the app.
    pub arrival_fps: f64,
    /// The app's own optimisation objective (one of the optimizer's).
    pub objective: Objective,
    /// SLO: per-inference latency bound (ms).
    pub slo_latency_ms: f64,
}

/// Admission-control outcome for a registering app.
#[derive(Debug, Clone)]
pub enum Admission {
    /// Hosted with this design; `degraded` when the joint search had to go
    /// below the app's solo-optimal accuracy or recognition rate to fit.
    Admitted { design: Design, degraded: bool },
    /// No design vector fits the global budget with this app included.
    Rejected { reason: String },
}

/// Per-app window statistics from one arbitration window.
#[derive(Debug, Clone)]
pub struct AppWindowStats {
    /// Which app the stats describe.
    pub app_id: String,
    /// Inferences served this window.
    pub inferences: u64,
    /// Inferences that missed the app's SLO.
    pub violations: u64,
    /// Mean latency over the window (ms).
    pub mean_latency_ms: f64,
}

/// The report one [`Scheduler::run_window`] call produces.
#[derive(Debug, Clone)]
pub struct WindowReport {
    /// Device-timeline instant at the start of the window (ms).
    pub at_ms: f64,
    /// Per-app outcomes, sorted by app id.
    pub apps: Vec<AppWindowStats>,
}

struct AppState {
    desc: WorkloadDescriptor,
    design: Design,
    degraded: bool,
    inferences: u64,
    violations: u64,
}

/// The multi-app scheduler.
pub struct Scheduler {
    device: Arc<DeviceProfile>,
    registry: Arc<Registry>,
    lut: Arc<Lut>,
    budget: GlobalBudget,
    policy: Policy,
    /// The time-slice arbiter planning execution windows.
    pub arbiter: Arbiter,
    apps: Vec<AppState>,
    last_loads: BTreeMap<EngineKind, f64>,
    last_adapt_ms: f64,
    /// Per-app Pareto frontiers shared across every admission and
    /// re-adaptation event (the design-space layer's cache).
    frontiers: Arc<Mutex<FrontierCache>>,
    /// Attached flight recorder plus this scheduler's scope label;
    /// admissions, arbitration windows and coordinated switches are
    /// emitted when set.
    recorder: Option<(Arc<FlightRecorder>, String)>,
    /// Coordinated reconfigurations issued so far: (app_id, switch).
    pub switches: Vec<(String, Switch)>,
}

impl Scheduler {
    /// An empty scheduler with the device's own budget and default policy.
    pub fn new(device: Arc<DeviceProfile>, registry: Arc<Registry>,
               lut: Arc<Lut>) -> Self {
        let budget = GlobalBudget::of(&device);
        Scheduler {
            device,
            registry,
            lut,
            budget,
            policy: Policy::default(),
            arbiter: Arbiter::default(),
            apps: Vec::new(),
            last_loads: BTreeMap::new(),
            last_adapt_ms: f64::NEG_INFINITY,
            frontiers: Arc::new(Mutex::new(FrontierCache::new())),
            recorder: None,
            switches: Vec::new(),
        }
    }

    /// Attach a flight recorder under `scope` (the scheduler's scenario
    /// label): admission outcomes, arbitration windows and coordinated
    /// switches are emitted, and the shared frontier cache's
    /// build/hit/evict/delta transitions are recorded under the same
    /// scope.  Recording never changes scheduling decisions.
    pub fn set_recorder(&mut self, recorder: Arc<FlightRecorder>,
                        scope: &str) {
        self.frontiers
            .lock()
            .unwrap()
            .set_recorder(Arc::clone(&recorder), scope);
        self.recorder = Some((recorder, scope.to_string()));
    }

    /// Override the global resource budget.
    pub fn with_budget(mut self, budget: GlobalBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Override the re-adaptation policy.
    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    fn joint(&self) -> JointSearch<'_> {
        JointSearch::new(&self.device, &self.registry, &self.lut,
                         self.budget.clone())
            .with_cache(Arc::clone(&self.frontiers))
    }

    /// Frontier-cache effectiveness counters across every admission and
    /// re-adaptation event this scheduler has run.
    pub fn frontier_stats(&self) -> CacheStats {
        self.frontiers.lock().unwrap().stats
    }

    /// Swap in a corrected LUT, delta-updating every per-app frontier the
    /// joint search has cached ([`FrontierCache::apply_delta`]) instead of
    /// cold-starting them.  `delta` must describe every difference between
    /// the current and the new LUT; subsequent [`JointSearch`] passes
    /// (admission, re-adaptation) then hit the carried frontiers.
    pub fn apply_lut_delta(&mut self, new_lut: Arc<Lut>, delta: &LutDelta)
                           -> DeltaOutcome {
        let outcome = {
            let old_ds =
                DesignSpace::new(&self.device, &self.registry, &self.lut);
            let new_ds =
                DesignSpace::new(&self.device, &self.registry, &new_lut);
            self.frontiers.lock().unwrap().apply_delta(&old_ds, &new_ds,
                                                       delta)
        };
        self.lut = new_lut;
        outcome
    }

    /// Number of hosted apps.
    pub fn len(&self) -> usize {
        self.apps.len()
    }

    /// True when no app is hosted.
    pub fn is_empty(&self) -> bool {
        self.apps.is_empty()
    }

    /// Hosted workload descriptors, in registration order.
    pub fn descriptors(&self) -> Vec<WorkloadDescriptor> {
        self.apps.iter().map(|a| a.desc.clone()).collect()
    }

    /// Current (app_id, design) pairs, in registration order.
    pub fn designs(&self) -> Vec<(String, Design)> {
        self.apps
            .iter()
            .map(|a| (a.desc.app_id.clone(), a.design.clone()))
            .collect()
    }

    /// The running design of one hosted app.
    pub fn design_of(&self, app_id: &str) -> Option<&Design> {
        self.apps
            .iter()
            .find(|a| a.desc.app_id == app_id)
            .map(|a| &a.design)
    }

    /// Apps currently running below their solo-optimal accuracy or
    /// recognition rate to fit the joint budget.
    pub fn degraded_ids(&self) -> Vec<String> {
        self.apps
            .iter()
            .filter(|a| a.degraded)
            .map(|a| a.desc.app_id.clone())
            .collect()
    }

    /// Cumulative (inferences, SLO violations) of one app.
    pub fn totals_of(&self, app_id: &str) -> Option<(u64, u64)> {
        self.apps
            .iter()
            .find(|a| a.desc.app_id == app_id)
            .map(|a| (a.inferences, a.violations))
    }

    /// Admission control: joint-search the current tenants plus the
    /// newcomer.  On success the whole design vector is (re)applied —
    /// existing tenants may be coordinately reconfigured to make room; on
    /// failure the newcomer is rejected and the incumbents are untouched.
    pub fn register(&mut self, desc: WorkloadDescriptor, now_ms: f64,
                    conds: &Conditions) -> Result<Admission> {
        if self.apps.iter().any(|a| a.desc.app_id == desc.app_id) {
            bail!("app `{}` already registered", desc.app_id);
        }
        let mut descs = self.descriptors();
        descs.push(desc.clone());
        let assignment = match self.joint().search(&descs, conds) {
            Ok(a) => a,
            Err(e) => {
                let reason = format!("{e:#}");
                if let Some((rec, _)) = &self.recorder {
                    rec.emit(TraceEvent::Admission {
                        scope: desc.app_id.clone(),
                        outcome: "rejected".to_string(),
                        detail: reason.clone(),
                    });
                }
                return Ok(Admission::Rejected { reason });
            }
        };
        self.apply(&assignment, now_ms, Reason::LoadChange);
        // Admission is itself a coordinated reconfiguration: start the
        // shared cooldown so observe() cannot re-shuffle the incumbents
        // again one tick later.
        for k in EngineKind::ALL {
            self.last_loads.insert(k, conds.load(k));
        }
        self.last_adapt_ms = now_ms;
        let newcomer = assignment
            .apps
            .iter()
            .find(|p| p.app_id == desc.app_id)
            .expect("joint assignment covers every descriptor");
        if let Some((rec, _)) = &self.recorder {
            rec.emit(TraceEvent::Admission {
                scope: desc.app_id.clone(),
                outcome: if newcomer.degraded {
                    "admitted_degraded".to_string()
                } else {
                    "admitted".to_string()
                },
                detail: design_id(&newcomer.design),
            });
        }
        self.apps.push(AppState {
            desc,
            design: newcomer.design.clone(),
            degraded: newcomer.degraded,
            inferences: 0,
            violations: 0,
        });
        Ok(Admission::Admitted {
            design: newcomer.design.clone(),
            degraded: newcomer.degraded,
        })
    }

    /// Apply a joint assignment to the hosted apps, recording a coordinated
    /// [`Switch`] for every design that changed.  Returns the issued
    /// switches.  (Descriptors not yet hosted — a registering newcomer —
    /// are skipped; the caller installs them.)
    fn apply(&mut self, assignment: &JointAssignment, now_ms: f64,
             reason: Reason) -> Vec<(String, Switch)> {
        let mut issued = Vec::new();
        for p in &assignment.apps {
            let Some(app) = self.apps.iter_mut()
                .find(|a| a.desc.app_id == p.app_id)
            else {
                continue;
            };
            app.degraded = p.degraded;
            if app.design != p.design {
                let sw = Switch {
                    from: app.design.clone(),
                    to: p.design.clone(),
                    at_ms: now_ms,
                    detection_ms: 0.0,
                    reason,
                };
                app.design = p.design.clone();
                if let Some((rec, _)) = &self.recorder {
                    rec.emit(TraceEvent::Switch {
                        scope: p.app_id.clone(),
                        from: design_id(&sw.from),
                        to: design_id(&sw.to),
                        reason: match reason {
                            Reason::LoadChange => "load".to_string(),
                            Reason::Degradation => "degradation".to_string(),
                        },
                        detection_ms: sw.detection_ms,
                    });
                }
                self.switches.push((p.app_id.clone(), sw.clone()));
                issued.push((p.app_id.clone(), sw));
            }
        }
        issued
    }

    /// Execute one arbitration window on the simulated device: the arbiter
    /// plans the slices, every grant runs one inference, and per-app SLO
    /// violations are accounted.
    pub fn run_window(&mut self, sim: &mut DeviceSim) -> Result<WindowReport> {
        if self.apps.is_empty() {
            bail!("run_window with no registered apps");
        }
        let plan_input: Vec<(String, EngineKind, f64)> = self
            .apps
            .iter()
            .map(|a| {
                (a.desc.app_id.clone(),
                 a.design.hw.engine,
                 a.desc.arrival_fps * a.design.hw.recognition_rate)
            })
            .collect();
        let window = self.arbiter.plan(&plan_input);
        if let Some((rec, scope)) = &self.recorder {
            let grants: usize =
                window.slices.iter().map(|s| s.grants.len()).sum();
            rec.emit(TraceEvent::Arbitration {
                scope: scope.clone(),
                window_ms: self.arbiter.window_ms,
                grants: grants as u64,
            });
        }

        let at_ms = sim.clock.now_ms();
        let mut stats: BTreeMap<String, (u64, u64, f64)> = BTreeMap::new();
        for slice in &window.slices {
            for grant in &slice.grants {
                let app = self
                    .apps
                    .iter_mut()
                    .find(|a| a.desc.app_id == grant.app_id)
                    .expect("grant for an unhosted app");
                let v = self
                    .registry
                    .get(&app.design.variant)
                    .context("scheduled variant not in registry")?
                    .clone();
                let exec = sim.run_inference(
                    &v,
                    app.design.hw.engine,
                    app.design.hw.threads,
                    app.design.hw.governor,
                )?;
                let violated = exec.latency_ms > app.desc.slo_latency_ms;
                app.inferences += 1;
                if violated {
                    app.violations += 1;
                }
                let e = stats.entry(grant.app_id.clone()).or_insert((0, 0, 0.0));
                e.0 += 1;
                if violated {
                    e.1 += 1;
                }
                e.2 += exec.latency_ms;
            }
        }
        // Idle out the remainder of the window span, if any.
        let span = sim.clock.now_ms() - at_ms;
        if span < self.arbiter.window_ms {
            sim.idle(self.arbiter.window_ms - span);
        }

        Ok(WindowReport {
            at_ms,
            apps: stats
                .into_iter()
                .map(|(app_id, (inferences, violations, sum_ms))| {
                    AppWindowStats {
                        app_id,
                        inferences,
                        violations,
                        mean_latency_ms: sum_ms / inferences.max(1) as f64,
                    }
                })
                .collect(),
        })
    }

    /// Joint re-adaptation: when per-engine conditions shift by more than
    /// the policy's re-evaluation threshold (or a hosted engine throttles),
    /// re-run the joint search under adjusted latencies and issue
    /// coordinated switches — one decision for all tenants.  Hysteresis and
    /// a shared cooldown guard against the oscillation N independent
    /// managers would exhibit.
    pub fn observe(&mut self, now_ms: f64, conds: &Conditions)
                   -> Vec<(String, Switch)> {
        if self.apps.is_empty()
            || now_ms - self.last_adapt_ms < self.policy.cooldown_ms
        {
            return Vec::new();
        }
        let load_changed = EngineKind::ALL.iter().any(|&k| {
            let prev = self.last_loads.get(&k).copied().unwrap_or(0.0);
            (conds.load(k) - prev).abs() >= self.policy.load_delta
        });
        let throttling = self.apps.iter().any(|a| {
            conds.thermal_scale(a.design.hw.engine)
                < self.policy.thermal_alert_scale
        });
        if !load_changed && !throttling {
            return Vec::new();
        }
        for k in EngineKind::ALL {
            self.last_loads.insert(k, conds.load(k));
        }
        self.last_adapt_ms = now_ms;

        let descs = self.descriptors();
        let designs: Vec<Design> =
            self.apps.iter().map(|a| a.design.clone()).collect();
        let joint = self.joint();
        let Ok(candidate) = joint.search(&descs, conds) else {
            return Vec::new();
        };
        let Ok((cur_viol, cur_pressure)) =
            joint.evaluate(&descs, &designs, conds)
        else {
            return Vec::new();
        };
        // Coordinated hysteresis: switch only for strictly fewer predicted
        // violations, or for a pressure win above the improvement margin.
        let improves = candidate.violations < cur_viol
            || (candidate.violations == cur_viol
                && cur_pressure / candidate.pressure.max(1e-9)
                    >= self.policy.min_improvement);
        if !improves {
            return Vec::new();
        }
        let reason = if throttling && !load_changed {
            Reason::Degradation
        } else {
            Reason::LoadChange
        };
        self.apply(&candidate, now_ms, reason)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::measurements::Measurer;
    use crate::model::test_fixtures::fake_registry;
    use crate::util::clock::Clock;
    use crate::util::stats::Percentile;

    fn desc(id: &str, family: &str, fps: f64, slo_ms: f64) -> WorkloadDescriptor {
        WorkloadDescriptor {
            app_id: id.to_string(),
            family: family.to_string(),
            arrival_fps: fps,
            objective: Objective::MinLatency {
                stat: Percentile::Avg,
                epsilon: 0.05,
            },
            slo_latency_ms: slo_ms,
        }
    }

    fn setup() -> (Arc<DeviceProfile>, Arc<Registry>, Arc<Lut>) {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        (Arc::new(dev), Arc::new(reg), Arc::new(lut))
    }

    #[test]
    fn register_admits_and_duplicate_errors() {
        let (dev, reg, lut) = setup();
        let mut sched = Scheduler::new(dev, reg, lut);
        let idle = Conditions::idle();
        let adm = sched
            .register(desc("cam", "mobilenet_v2_100", 30.0, 50.0), 0.0, &idle)
            .unwrap();
        assert!(matches!(adm, Admission::Admitted { .. }));
        assert_eq!(sched.len(), 1);
        assert!(sched
            .register(desc("cam", "inception_v3", 30.0, 50.0), 0.0, &idle)
            .is_err());
    }

    #[test]
    fn unknown_family_rejected_not_admitted() {
        let (dev, reg, lut) = setup();
        let mut sched = Scheduler::new(dev, reg, lut);
        let adm = sched
            .register(desc("ghost", "no_such_family", 30.0, 50.0), 0.0,
                      &Conditions::idle())
            .unwrap();
        assert!(matches!(adm, Admission::Rejected { .. }));
        assert!(sched.is_empty());
    }

    #[test]
    fn offload_engines_exclusively_owned() {
        let (dev, reg, lut) = setup();
        let mut sched = Scheduler::new(dev, reg, lut);
        let idle = Conditions::idle();
        for (id, fam) in [("a", "mobilenet_v2_100"), ("b", "inception_v3"),
                          ("c", "efficientnet_lite4")] {
            let adm = sched
                .register(desc(id, fam, 30.0, 1e6), 0.0, &idle)
                .unwrap();
            assert!(matches!(adm, Admission::Admitted { .. }), "{id}");
        }
        let mut gpu = 0;
        let mut npu = 0;
        for (_, d) in sched.designs() {
            match d.hw.engine {
                EngineKind::Gpu => gpu += 1,
                EngineKind::Npu => npu += 1,
                EngineKind::Cpu => {}
            }
        }
        assert!(gpu <= 1 && npu <= 1, "offload engines shared: {:?}",
                sched.designs());
    }

    #[test]
    fn run_window_serves_every_app() {
        let (dev, reg, lut) = setup();
        let mut sched = Scheduler::new(Arc::clone(&dev), reg, lut);
        let idle = Conditions::idle();
        sched.register(desc("a", "mobilenet_v2_100", 60.0, 1e6), 0.0, &idle)
            .unwrap();
        sched.register(desc("b", "inception_v3", 10.0, 1e6), 0.0, &idle)
            .unwrap();
        let mut sim = DeviceSim::new((*dev).clone(), Clock::sim());
        let rep = sched.run_window(&mut sim).unwrap();
        assert_eq!(rep.apps.len(), 2);
        for a in &rep.apps {
            assert!(a.inferences >= 1, "{} starved", a.app_id);
            assert!(a.mean_latency_ms > 0.0);
        }
        assert!(sim.clock.now_ms() >= sched.arbiter.window_ms - 1e-9);
    }

    #[test]
    fn load_shift_triggers_coordinated_reoptimisation() {
        let (dev, reg, lut) = setup();
        let mut sched = Scheduler::new(dev, reg, lut);
        let idle = Conditions::idle();
        sched.register(desc("a", "mobilenet_v2_100", 60.0, 1e6), 0.0, &idle)
            .unwrap();
        let e0 = sched.design_of("a").unwrap().hw.engine;
        // Heavy external load on the app's engine: the joint re-adaptation
        // must migrate it off, in one coordinated decision.
        let mut conds = Conditions::idle();
        conds.loads.insert(e0, 3.0);
        let issued = sched.observe(5000.0, &conds);
        assert_eq!(issued.len(), 1, "expected one coordinated switch");
        assert_ne!(sched.design_of("a").unwrap().hw.engine, e0);
        // Within the cooldown no further joint switches are issued.
        let again = sched.observe(5100.0, &conds);
        assert!(again.is_empty());
    }

    #[test]
    fn recorder_captures_admissions_windows_and_switches() {
        let (dev, reg, lut) = setup();
        let mut sched = Scheduler::new(Arc::clone(&dev), reg, lut);
        let rec = Arc::new(FlightRecorder::new());
        sched.set_recorder(Arc::clone(&rec), "multi");
        let idle = Conditions::idle();
        sched.register(desc("a", "mobilenet_v2_100", 60.0, 1e6), 0.0, &idle)
            .unwrap();
        sched.register(desc("ghost", "no_such_family", 30.0, 50.0), 0.0,
                       &idle)
            .unwrap();
        let admissions: Vec<String> = rec
            .records()
            .iter()
            .filter_map(|r| match &r.event {
                TraceEvent::Admission { outcome, .. } => {
                    Some(outcome.clone())
                }
                _ => None,
            })
            .collect();
        assert_eq!(admissions.len(), 2);
        assert!(admissions[0].starts_with("admitted"));
        assert_eq!(admissions[1], "rejected");
        let mut sim = DeviceSim::new((*dev).clone(), Clock::sim());
        sched.run_window(&mut sim).unwrap();
        assert!(rec
            .records()
            .iter()
            .any(|r| matches!(r.event, TraceEvent::Arbitration { .. })));
        // A coordinated re-adaptation switch is traced per app.
        let e0 = sched.design_of("a").unwrap().hw.engine;
        let mut conds = Conditions::idle();
        conds.loads.insert(e0, 3.0);
        let issued = sched.observe(5000.0, &conds);
        let switches = rec
            .records()
            .iter()
            .filter(|r| matches!(r.event, TraceEvent::Switch { .. }))
            .count();
        assert_eq!(switches, issued.len());
    }

    #[test]
    fn readaptation_reuses_cached_frontiers() {
        let (dev, reg, lut) = setup();
        let mut sched = Scheduler::new(dev, reg, lut);
        let idle = Conditions::idle();
        sched.register(desc("a", "mobilenet_v2_100", 60.0, 1e6), 0.0, &idle)
            .unwrap();
        let after_register = sched.frontier_stats();
        assert!(after_register.builds >= 1);
        // Alternate between two condition vectors in the same two buckets:
        // after the first visit to each bucket, every further event is a
        // cache hit — no frontier is ever rebuilt.
        let e0 = sched.design_of("a").unwrap().hw.engine;
        let mut loaded = Conditions::idle();
        loaded.loads.insert(e0, 3.0);
        let mut t = 5000.0;
        for _ in 0..6 {
            sched.observe(t, &loaded);
            t += 2000.0;
            sched.observe(t, &idle);
            t += 2000.0;
        }
        let stats = sched.frontier_stats();
        assert!(stats.builds <= after_register.builds + 2,
                "re-adaptation kept rebuilding frontiers: {stats:?}");
        assert!(stats.hits >= 8, "expected cache hits, got {stats:?}");
        assert_eq!(stats.invalidations, 0);
    }
}
