//! Joint optimisation: extend the single-app enumerative LUT search to a
//! design *vector* (σ₁…σ_N) over all co-resident apps, under global
//! resource constraints:
//!
//! * **engine exclusivity** — the GPU and the NNAPI accelerator are owned
//!   by at most one app each (contended offload engines are shared across
//!   arbitration slices, never inside one);
//! * **shared CPU-core budget** — Σ threads of CPU-resident apps stays
//!   within the device's cores;
//! * **total model-memory cap** — Σ working-set bytes of the admitted
//!   designs stays within the device budget;
//! * **per-engine time budget** — Σ latency·rate on an engine stays below
//!   `util_cap` of wall time (dropping the recognition rate r is how an
//!   app degrades itself into fitting).
//!
//! Per-app candidates come from the app's *cached Pareto frontier*
//! ([`crate::designspace::frontier`]) at the current conditions bucket —
//! pruned per engine/thread group, re-scored under the exact current
//! conditions with the Runtime Manager's [`manager::adjusted_latency`] —
//! so a re-adaptation event composes per-app frontiers under the global
//! budget instead of re-scoring the raw product space.  The joint
//! objective is lexicographic: fewest predicted SLO violations, then
//! minimal total SLO pressure Σ latency/SLO.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::designspace::{ConditionsBucket, DesignSpace, FrontierCache};
use crate::device::{DeviceProfile, EngineKind};
use crate::manager::{self, Conditions};
use crate::measurements::Lut;
use crate::model::Registry;
use crate::optimizer::{Design, SearchSpace};

use super::WorkloadDescriptor;

/// Global resource constraints shared by every co-resident app.
#[derive(Debug, Clone)]
pub struct GlobalBudget {
    /// Shared CPU-core budget: Σ threads of CPU-resident apps.
    pub cpu_threads: usize,
    /// Total model working-set cap (bytes) across admitted designs.
    pub mem_bytes: u64,
    /// Per-engine time budget: Σ latency·rate must stay below this
    /// fraction of wall time on every engine.
    pub util_cap: f64,
}

impl GlobalBudget {
    /// The device's own limits (all cores, full memory budget, 100% time).
    pub fn of(device: &DeviceProfile) -> Self {
        GlobalBudget {
            cpu_threads: device.n_cores,
            mem_bytes: device.mem_budget_bytes,
            util_cap: 1.0,
        }
    }
}

/// One app's slice of a joint assignment, with its predicted metrics.
#[derive(Debug, Clone)]
pub struct PredictedApp {
    /// The app this slice belongs to.
    pub app_id: String,
    /// Its jointly-chosen design.
    pub design: Design,
    /// Condition-adjusted LUT latency (ms).
    pub latency_ms: f64,
    /// Accuracy of the chosen variant.
    pub accuracy: f64,
    /// Working-set bytes of the chosen variant.
    pub mem_bytes: u64,
    /// Predicted to meet its latency SLO.
    pub slo_ok: bool,
    /// The joint constraints forced this app below its solo-optimal
    /// accuracy or recognition rate (the admission-control "degrade" path).
    pub degraded: bool,
}

/// A feasible design vector for all apps.
#[derive(Debug, Clone)]
pub struct JointAssignment {
    /// One entry per descriptor, in input order.
    pub apps: Vec<PredictedApp>,
    /// Number of apps predicted to miss their latency SLO.
    pub violations: usize,
    /// Σ latency/SLO across apps (lower is better; the tie-break score).
    pub pressure: f64,
}

/// One pruned, condition-adjusted candidate for one app.
#[derive(Debug, Clone)]
struct Cand {
    design: Design,
    latency_ms: f64,
    accuracy: f64,
    mem_bytes: u64,
}

/// Mutable resource state threaded through the assignment search.
struct DfsState {
    cpu_threads: usize,
    mem_bytes: u64,
    util: BTreeMap<EngineKind, f64>,
    offload_owned: Vec<EngineKind>,
    choice: Vec<usize>,
}

/// The joint-optimisation search.
pub struct JointSearch<'a> {
    /// Target device.
    pub device: &'a DeviceProfile,
    /// Model space M.
    pub registry: &'a Registry,
    /// Device measurements driving the per-app rankings.
    pub lut: &'a Lut,
    /// Global constraints the design vector must satisfy.
    pub budget: GlobalBudget,
    /// Ranked candidates kept per (engine, threads) group — the pruning
    /// knob bounding the assignment enumeration.
    pub keep_per_group: usize,
    /// Cached per-app Pareto frontiers; [`crate::scheduler::Scheduler`]
    /// shares one cache across all its re-adaptation events.
    pub frontiers: Arc<Mutex<FrontierCache>>,
}

impl<'a> JointSearch<'a> {
    /// A joint search with the default pruning depth and a private
    /// frontier cache.
    pub fn new(device: &'a DeviceProfile, registry: &'a Registry, lut: &'a Lut,
               budget: GlobalBudget) -> Self {
        JointSearch {
            device,
            registry,
            lut,
            budget,
            keep_per_group: 3,
            frontiers: Arc::new(Mutex::new(FrontierCache::new())),
        }
    }

    /// Share a frontier cache (so repeated searches — admission events,
    /// re-adaptations — reuse each app's cached frontiers).
    pub fn with_cache(mut self, cache: Arc<Mutex<FrontierCache>>) -> Self {
        self.frontiers = cache;
        self
    }

    /// One app's candidate list: its cached Pareto frontier at the current
    /// conditions bucket, pruned to the best `keep_per_group` per (engine,
    /// threads) group, with latencies re-scored under the exact `conds`.
    /// Frontier order is the canonical selection order, so index 0 is the
    /// app's solo-optimal choice (the `degraded` reference point); the
    /// lower-rate / lower-accuracy frontier points behind it are the
    /// degrade ladder admission control falls down.
    fn candidates(&self, desc: &WorkloadDescriptor, conds: &Conditions)
                  -> Result<Vec<Cand>> {
        let bucket = ConditionsBucket::of(conds);
        let sspace = SearchSpace::family(&desc.family);
        let space = DesignSpace::new(self.device, self.registry, self.lut);
        let frontier = self.frontiers.lock().unwrap().frontier(
            &space, desc.objective, &sspace, &bucket);
        let mut counts: BTreeMap<(EngineKind, usize), usize> = BTreeMap::new();
        let mut kept = Vec::new();
        for c in frontier.points() {
            let group = (c.design.hw.engine, c.design.hw.threads);
            let n = counts.entry(group).or_insert(0);
            if *n >= self.keep_per_group {
                continue;
            }
            let Some(adj) = manager::adjusted_latency(
                self.lut, &c.design, desc.objective.stat(), conds)
            else {
                continue;
            };
            *n += 1;
            kept.push(Cand {
                design: c.design.clone(),
                latency_ms: adj,
                accuracy: c.accuracy,
                mem_bytes: c.mem_bytes,
            });
        }
        if kept.is_empty() {
            bail!("app `{}`: no deployable candidate for family `{}`",
                  desc.app_id, desc.family);
        }
        Ok(kept)
    }

    /// Find the best feasible design vector for `descs` under `conds`.
    /// Errors when no assignment fits the global budget (admission control
    /// rejects the newcomer on that signal).
    pub fn search(&self, descs: &[WorkloadDescriptor], conds: &Conditions)
                  -> Result<JointAssignment> {
        if descs.is_empty() {
            bail!("joint search over zero apps");
        }
        let cands: Vec<Vec<Cand>> = descs
            .iter()
            .map(|d| self.candidates(d, conds))
            .collect::<Result<_>>()?;

        let mut state = DfsState {
            cpu_threads: 0,
            mem_bytes: 0,
            util: BTreeMap::new(),
            offload_owned: Vec::new(),
            choice: Vec::new(),
        };
        let mut best: Option<(usize, f64, Vec<usize>)> = None;
        self.assign(descs, &cands, 0, 0, 0.0, &mut state, &mut best);
        let Some((violations, pressure, choice)) = best else {
            bail!(
                "no joint assignment of {} apps fits the global budget \
                 ({} CPU threads, {} MB, {:.0}% engine time)",
                descs.len(),
                self.budget.cpu_threads,
                self.budget.mem_bytes / (1024 * 1024),
                self.budget.util_cap * 100.0
            );
        };

        let apps = descs
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let c = &cands[i][choice[i]];
                let solo = &cands[i][0];
                PredictedApp {
                    app_id: d.app_id.clone(),
                    design: c.design.clone(),
                    latency_ms: c.latency_ms,
                    accuracy: c.accuracy,
                    mem_bytes: c.mem_bytes,
                    slo_ok: c.latency_ms <= d.slo_latency_ms,
                    degraded: c.accuracy < solo.accuracy - 1e-12
                        || c.design.hw.recognition_rate
                            < solo.design.hw.recognition_rate,
                }
            })
            .collect();
        Ok(JointAssignment { apps, violations, pressure })
    }

    /// Depth-first assignment with constraint pruning.  `violations` and
    /// `pressure` are passed by value (exact backtracking); the resource
    /// state is mutated and restored.
    #[allow(clippy::too_many_arguments)]
    fn assign(&self, descs: &[WorkloadDescriptor], cands: &[Vec<Cand>],
              i: usize, violations: usize, pressure: f64,
              state: &mut DfsState, best: &mut Option<(usize, f64, Vec<usize>)>) {
        if let Some((bv, bp, _)) = best {
            // Pressure only grows with depth: prune dominated prefixes.
            if violations > *bv || (violations == *bv && pressure >= *bp) {
                return;
            }
        }
        if i == descs.len() {
            *best = Some((violations, pressure, state.choice.clone()));
            return;
        }
        let desc = &descs[i];
        for (ci, c) in cands[i].iter().enumerate() {
            // A partitioned design occupies *every* engine of its
            // pipeline for the whole inference: exclusivity, thread and
            // time budgets are charged on each touched engine.
            let engines = c.design.engines();
            let uses_cpu = engines.contains(&EngineKind::Cpu);
            let threads = if uses_cpu { c.design.hw.threads } else { 0 };
            if engines.iter().any(|e| {
                *e != EngineKind::Cpu && state.offload_owned.contains(e)
            }) {
                continue; // exclusive GPU/NNAPI ownership
            }
            if state.cpu_threads + threads > self.budget.cpu_threads {
                continue; // shared CPU-core budget
            }
            if state.mem_bytes + c.mem_bytes > self.budget.mem_bytes {
                continue; // total model-memory cap
            }
            let util = c.latency_ms
                * (desc.arrival_fps * c.design.hw.recognition_rate).max(0.0)
                / 1000.0;
            let prev_util: Vec<f64> = engines
                .iter()
                .map(|e| state.util.get(e).copied().unwrap_or(0.0))
                .collect();
            if prev_util.iter().any(|u| u + util > self.budget.util_cap) {
                continue; // per-engine time budget
            }

            state.cpu_threads += threads;
            state.mem_bytes += c.mem_bytes;
            let mut pushed = 0usize;
            for (e, u) in engines.iter().zip(&prev_util) {
                state.util.insert(*e, u + util);
                if *e != EngineKind::Cpu {
                    state.offload_owned.push(*e);
                    pushed += 1;
                }
            }
            state.choice.push(ci);
            let v = violations
                + usize::from(c.latency_ms > desc.slo_latency_ms);
            let p = pressure + c.latency_ms / desc.slo_latency_ms.max(1e-9);
            self.assign(descs, cands, i + 1, v, p, state, best);
            state.choice.pop();
            for _ in 0..pushed {
                state.offload_owned.pop();
            }
            for (e, u) in engines.iter().zip(&prev_util) {
                state.util.insert(*e, *u);
            }
            state.mem_bytes -= c.mem_bytes;
            state.cpu_threads -= threads;
        }
    }

    /// Predicted metrics of a *fixed* design vector under `conds` (used by
    /// the scheduler's re-adaptation hysteresis to score the incumbent).
    pub fn evaluate(&self, descs: &[WorkloadDescriptor],
                    designs: &[Design], conds: &Conditions)
                    -> Result<(usize, f64)> {
        if descs.len() != designs.len() {
            bail!("evaluate: {} descriptors vs {} designs",
                  descs.len(), designs.len());
        }
        let mut violations = 0;
        let mut pressure = 0.0;
        for (d, design) in descs.iter().zip(designs) {
            let adj = manager::adjusted_latency(
                self.lut, design, d.objective.stat(), conds)
                .ok_or_else(|| anyhow!("design of `{}` missing from LUT",
                                       d.app_id))?;
            violations += usize::from(adj > d.slo_latency_ms);
            pressure += adj / d.slo_latency_ms.max(1e-9);
        }
        Ok((violations, pressure))
    }
}
