//! Engine arbitration: time-sliced execution windows over the admitted
//! apps.
//!
//! The joint search gives every app a design (and therefore an engine);
//! the arbiter turns that assignment into a *window plan*: a fixed number
//! of slices, each granting engines to apps such that
//!
//! * within one slice an engine is granted to at most one app (the
//!   engine-exclusivity invariant — contended engines are shared across
//!   slices by round-robin, never inside one), and
//! * every app receives at least one grant per window (no admitted app
//!   starves), with extra grants proportional to its demanded rate.

use std::collections::BTreeMap;

use crate::device::EngineKind;

/// One engine grant: `app_id` owns `engine` for the slice.
#[derive(Debug, Clone, PartialEq)]
pub struct Grant {
    /// The app receiving the engine.
    pub app_id: String,
    /// The granted engine.
    pub engine: EngineKind,
}

/// One time slice: concurrently granted, pairwise-distinct engines.
#[derive(Debug, Clone, Default)]
pub struct Slice {
    /// Grants active in this slice (engines pairwise distinct).
    pub grants: Vec<Grant>,
}

/// A planned arbitration window.
#[derive(Debug, Clone)]
pub struct Window {
    /// The planned slices, in execution order.
    pub slices: Vec<Slice>,
}

impl Window {
    /// Grants issued to one app across the window.
    pub fn grants_for(&self, app_id: &str) -> usize {
        self.slices
            .iter()
            .flat_map(|s| &s.grants)
            .filter(|g| g.app_id == app_id)
            .count()
    }

    /// Grants issued across the whole window.
    pub fn total_grants(&self) -> usize {
        self.slices.iter().map(|s| s.grants.len()).sum()
    }
}

/// The engine arbiter.
#[derive(Debug, Clone)]
pub struct Arbiter {
    /// Slices per window (raised to the app count when more apps than
    /// slices are hosted, so no app can starve).
    pub slices_per_window: usize,
    /// Wall span one window covers (ms) — also the re-adaptation tick.
    pub window_ms: f64,
}

impl Default for Arbiter {
    fn default() -> Self {
        Arbiter { slices_per_window: 8, window_ms: 250.0 }
    }
}

impl Arbiter {
    /// Plan one window for `apps` = (app_id, engine, demand weight).
    /// Each app gets `max(1, ⌊slices · weight / Σweights-on-engine⌋)`
    /// credits, trimmed so one engine's credits fit the window, then the
    /// engine is granted round-robin across slices.
    pub fn plan(&self, apps: &[(String, EngineKind, f64)]) -> Window {
        let s = self.slices_per_window.max(apps.len()).max(1);
        let mut slices = vec![Slice::default(); s];

        // Group apps by engine, credits per app (registration order kept).
        let mut by_engine: BTreeMap<EngineKind, Vec<(usize, usize)>> =
            BTreeMap::new();
        for (i, (_, engine, _)) in apps.iter().enumerate() {
            by_engine.entry(*engine).or_default().push((i, 0));
        }
        for members in by_engine.values_mut() {
            let total: f64 = members
                .iter()
                .map(|&(i, _)| apps[i].2.max(0.0))
                .sum();
            for (i, credits) in members.iter_mut() {
                let w = apps[*i].2.max(0.0);
                let share = if total > 0.0 {
                    (s as f64 * w / total).floor() as usize
                } else {
                    1
                };
                *credits = share.max(1);
            }
            // Trim the largest credit until the engine's total fits the
            // window (every member keeps >= 1; s >= members.len()).
            loop {
                let sum: usize = members.iter().map(|&(_, c)| c).sum();
                if sum <= s {
                    break;
                }
                let (_, c) = members
                    .iter_mut()
                    .max_by_key(|(_, c)| *c)
                    .expect("engine group is non-empty");
                debug_assert!(*c > 1);
                *c -= 1;
            }
        }

        // Round-robin each engine across the slices: one grant per engine
        // per slice, cycling its apps until credits run out.
        for (engine, members) in by_engine.iter_mut() {
            let n = members.len();
            let mut rr = 0usize;
            for slice in slices.iter_mut() {
                let mut granted = false;
                for k in 0..n {
                    let idx = (rr + k) % n;
                    if members[idx].1 > 0 {
                        members[idx].1 -= 1;
                        slice.grants.push(Grant {
                            app_id: apps[members[idx].0].0.clone(),
                            engine: *engine,
                        });
                        rr = (idx + 1) % n;
                        granted = true;
                        break;
                    }
                }
                if !granted {
                    break; // this engine's credits are exhausted
                }
            }
        }
        Window { slices }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn apps(v: &[(&str, EngineKind, f64)]) -> Vec<(String, EngineKind, f64)> {
        v.iter().map(|(id, e, w)| (id.to_string(), *e, *w)).collect()
    }

    #[test]
    fn every_app_gets_a_grant() {
        let arb = Arbiter::default();
        let w = arb.plan(&apps(&[
            ("a", EngineKind::Npu, 60.0),
            ("b", EngineKind::Npu, 1.0),
            ("c", EngineKind::Cpu, 30.0),
        ]));
        for id in ["a", "b", "c"] {
            assert!(w.grants_for(id) >= 1, "{id} starved: {w:?}");
        }
        // Demand-proportional: the heavy NPU app gets more slices.
        assert!(w.grants_for("a") > w.grants_for("b"));
    }

    #[test]
    fn engine_exclusive_within_slice() {
        let arb = Arbiter::default();
        let w = arb.plan(&apps(&[
            ("a", EngineKind::Gpu, 10.0),
            ("b", EngineKind::Gpu, 10.0),
            ("c", EngineKind::Cpu, 10.0),
            ("d", EngineKind::Npu, 10.0),
        ]));
        for slice in &w.slices {
            let mut seen = Vec::new();
            for g in &slice.grants {
                assert!(!seen.contains(&g.engine),
                        "engine {:?} granted twice in one slice", g.engine);
                seen.push(g.engine);
            }
        }
    }

    #[test]
    fn more_apps_than_slices_widens_window() {
        let arb = Arbiter { slices_per_window: 2, window_ms: 100.0 };
        let many: Vec<(String, EngineKind, f64)> = (0..5)
            .map(|i| (format!("app{i}"), EngineKind::Cpu, 1.0))
            .collect();
        let w = arb.plan(&many);
        assert_eq!(w.slices.len(), 5);
        for i in 0..5 {
            assert_eq!(w.grants_for(&format!("app{i}")), 1);
        }
    }

    #[test]
    fn zero_weight_still_served() {
        let arb = Arbiter::default();
        let w = arb.plan(&apps(&[
            ("a", EngineKind::Cpu, 0.0),
            ("b", EngineKind::Cpu, 100.0),
        ]));
        assert!(w.grants_for("a") >= 1);
        assert!(w.grants_for("b") >= w.grants_for("a"));
    }
}
