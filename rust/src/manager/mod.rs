//! Runtime Manager (paper §III-B2 / §IV-C): run-time adaptation.
//!
//! The online component periodically transmits system statistics (per-engine
//! load, temperatures/frequency scales, recent inference latency) to the
//! Runtime Manager.  On a significant resource-availability change (the
//! paper's example: 10% difference in GPU load) or a detected performance
//! degradation, the manager re-searches the *device-resident look-up tables*
//! — it stores nothing else (§III-D) — under latencies adjusted for current
//! conditions, and issues a reconfiguration when an alternative design wins
//! by more than a hysteresis margin.
//!
//! Detection timing (Fig 8: ~800 ms / ~1150 ms) falls out of the check
//! interval × consecutive-confirmation policy rather than being hard-coded.
//!
//! Since the design-space refactor the re-search no longer re-enumerates
//! the σ-space per event: `best_under` buckets the observed conditions
//! ([`crate::designspace::ConditionsBucket`]) and selects from the cached
//! Pareto frontier of that bucket — O(frontier) per adaptation event, with
//! the enumeration paid once per bucket and invalidated only when the LUT
//! or registry changes.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::designspace::{CacheStats, ConditionsBucket, DeltaOutcome,
                         DesignSpace, FrontierCache, LutDelta};
use crate::device::{DeviceProfile, EngineKind};
use crate::measurements::Lut;
use crate::model::Registry;
use crate::optimizer::{Design, Objective, SearchSpace};
use crate::perf;
use crate::telemetry::trace::{round3, FlightRecorder, TraceEvent};
use crate::util::stats::{Percentile, RollingWindow};

/// Canonical design id used across trace events and experiment reports:
/// `variant|engine|threads|governor|r=rate`.  Partitioned designs render
/// their plan id (`cpu>gpu@500`) in the engine slot.
pub fn design_id(d: &Design) -> String {
    let engine = match &d.hw.plan {
        crate::measurements::ExecPlan::Mono => d.hw.engine.name().to_string(),
        crate::measurements::ExecPlan::Split(p) => p.id(),
    };
    format!("{}|{}|{}|{}|r={}", d.variant, engine, d.hw.threads,
            d.hw.governor.name(), d.hw.recognition_rate)
}

/// Canonical hold-reason label (the trace schema's `reason` field).
pub fn hold_label(r: &HoldReason) -> &'static str {
    match r {
        HoldReason::NotDue => "not_due",
        HoldReason::Cooldown { .. } => "cooldown",
        HoldReason::NoTrigger => "no_trigger",
        HoldReason::NoAlternative => "no_alternative",
        HoldReason::CurrentStillBest => "current_still_best",
        HoldReason::BelowHysteresis { .. } => "below_hysteresis",
    }
}

/// Condition-adjusted LUT latency of a design: `lut(stat) · 2^load /
/// thermal_scale` on the design's engine.  This is the Runtime Manager's
/// re-ranking score, exposed as a free function so the multi-app
/// `scheduler` can reuse it in joint re-optimisation.
pub fn adjusted_latency(lut: &Lut, design: &Design, stat: Percentile,
                        conds: &Conditions) -> Option<f64> {
    let e = lut.get(&design.lut_key())?;
    if e.stages.is_empty() {
        let k = design.hw.engine;
        Some(e.latency.metric(stat)
             * perf::contention(conds.load(k))
             / conds.thermal_scale(k).max(1e-3))
    } else {
        // Pipelined plan: the bottleneck stage may move under load, so
        // re-derive the steady-state factor from the per-stage costs.
        let f = perf::plan_condition_factor(&e.stages,
                                            |k| conds.load(k),
                                            |k| conds.thermal_scale(k));
        Some(e.latency.metric(stat) * f)
    }
}

/// Instantaneous per-engine conditions, as reported by MDCL middleware c.
#[derive(Debug, Clone, Default)]
pub struct Conditions {
    /// External load factor per engine (latency multiplier 2^l).
    pub loads: BTreeMap<EngineKind, f64>,
    /// Thermal frequency scale per engine (1.0 = cool, <1 = throttling).
    pub thermal: BTreeMap<EngineKind, f64>,
}

impl Conditions {
    /// Idle, cool conditions on every engine.
    pub fn idle() -> Self {
        Conditions::default()
    }

    /// External load factor on `e` (0.0 when unreported).
    pub fn load(&self, e: EngineKind) -> f64 {
        self.loads.get(&e).copied().unwrap_or(0.0)
    }

    /// Thermal frequency scale on `e` (1.0 when unreported).
    pub fn thermal_scale(&self, e: EngineKind) -> f64 {
        self.thermal.get(&e).copied().unwrap_or(1.0)
    }
}

/// Why the manager reconfigured.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reason {
    /// Per-engine load shifted by more than the re-evaluation threshold.
    LoadChange,
    /// Sustained measured-latency degradation (thermal throttling path).
    Degradation,
}

/// Why an observation tick did *not* produce a reconfiguration — the
/// debuggability signal joint re-adaptation (the `scheduler` layer) needs to
/// distinguish "holding by policy" from "nothing to react to".
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HoldReason {
    /// The check interval has not elapsed since the last evaluation.
    NotDue,
    /// Inside the post-switch quiet period; `remaining_ms` until it lifts.
    Cooldown { remaining_ms: f64 },
    /// Conditions are stable: no load shift, no confirmed degradation.
    NoTrigger,
    /// A trigger fired but the re-search found no feasible alternative.
    NoAlternative,
    /// The re-search picked the already-running design.
    CurrentStillBest,
    /// An alternative won, but by less than the hysteresis margin;
    /// `predicted_gain` is its cur/best adjusted-latency ratio.
    BelowHysteresis { predicted_gain: f64 },
}

/// Outcome of one observation tick: either a reconfiguration or the reason
/// the manager held position.
#[derive(Debug, Clone)]
pub enum Decision {
    /// Reconfigure to a new design.
    Switch(Switch),
    /// Keep the current design, for the stated reason.
    Hold(HoldReason),
}

/// A reconfiguration decision.
#[derive(Debug, Clone)]
pub struct Switch {
    /// Design being replaced.
    pub from: Design,
    /// Design taking over.
    pub to: Design,
    /// Device-timeline instant of the decision (ms).
    pub at_ms: f64,
    /// Time from degradation onset to the decision (ms); 0 for pure
    /// load-triggered switches evaluated on the same tick.
    pub detection_ms: f64,
    /// What triggered the reconfiguration.
    pub reason: Reason,
}

/// Tunable adaptation policy.
#[derive(Debug, Clone)]
pub struct Policy {
    /// Re-evaluate when any engine load moves by this much (paper: 0.1).
    pub load_delta: f64,
    /// Minimum predicted improvement ratio required to switch (hysteresis).
    pub min_improvement: f64,
    /// Milliseconds between condition checks.
    pub check_interval_ms: f64,
    /// Consecutive degraded checks before declaring Degradation.
    pub confirmations: usize,
    /// Measured/expected latency ratio counting as degraded.
    pub violation_ratio: f64,
    /// Quiet period after a switch (avoid flapping).
    pub cooldown_ms: f64,
    /// Thermal frequency scale below which the engine counts as degraded
    /// even when measured latency looks fine (middleware-c warning level).
    pub thermal_alert_scale: f64,
    /// Measured-latency samples kept in the rolling degradation window.
    pub latency_window: usize,
}

impl Default for Policy {
    fn default() -> Self {
        Policy {
            load_delta: 0.1,
            min_improvement: 1.10,
            check_interval_ms: 250.0,
            confirmations: 3,
            violation_ratio: 1.25,
            cooldown_ms: 1000.0,
            thermal_alert_scale: 0.95,
            latency_window: 8,
        }
    }
}

/// The Runtime Manager.
pub struct RuntimeManager {
    device: Arc<DeviceProfile>,
    registry: Arc<Registry>,
    lut: Arc<Lut>,
    objective: Objective,
    space: SearchSpace,
    policy: Policy,
    current: Design,
    // -- adaptation state --
    last_loads: BTreeMap<EngineKind, f64>,
    last_check_ms: f64,
    last_switch_ms: f64,
    violations: usize,
    degradation_start_ms: Option<f64>,
    window: RollingWindow,
    /// Cached Pareto frontiers per conditions-bucket (interior-mutable so
    /// `best_under` keeps its `&self` signature).  Private by default; the
    /// fleet layer injects one shared cache per device cohort through
    /// [`RuntimeManager::with_frontier_cache`] so frontier builds amortise
    /// across a whole population of near-identical devices.
    frontiers: Arc<Mutex<FrontierCache>>,
    /// Attached flight recorder plus this manager's scope label (device
    /// or app id); every decide outcome is emitted when set.
    recorder: Option<(Arc<FlightRecorder>, String)>,
    /// History of all switches (experiment reporting).
    pub switches: Vec<Switch>,
}

impl RuntimeManager {
    /// A manager owning `initial` as the running design, default policy.
    pub fn new(device: Arc<DeviceProfile>, registry: Arc<Registry>, lut: Arc<Lut>,
               objective: Objective, space: SearchSpace, initial: Design) -> Self {
        let policy = Policy::default();
        RuntimeManager {
            device,
            registry,
            lut,
            objective,
            space,
            current: initial,
            last_loads: BTreeMap::new(),
            last_check_ms: f64::NEG_INFINITY,
            last_switch_ms: f64::NEG_INFINITY,
            violations: 0,
            degradation_start_ms: None,
            window: RollingWindow::new(policy.latency_window.max(1)),
            frontiers: Arc::new(Mutex::new(FrontierCache::new())),
            recorder: None,
            policy,
            switches: Vec::new(),
        }
    }

    /// Replace the adaptation policy (resets the latency window).
    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.window = RollingWindow::new(policy.latency_window.max(1));
        self.policy = policy;
        self
    }

    /// Share an external frontier cache instead of the manager's private
    /// one.  Managers of devices in the same fleet cohort point at one
    /// cache over the same (representative device, LUT), so each
    /// (task, conditions-bucket) frontier is built once per cohort rather
    /// than once per device.
    pub fn with_frontier_cache(mut self,
                               cache: Arc<Mutex<FrontierCache>>) -> Self {
        self.frontiers = cache;
        self
    }

    /// Attach a flight recorder under `scope` (this manager's device or
    /// app id): every [`RuntimeManager::decide`] outcome — hold with
    /// trigger + reason, switch, and its `explain` record — is emitted as
    /// a [`TraceEvent`].  Recording never changes decisions or cache
    /// behaviour.
    pub fn with_recorder(mut self, recorder: Arc<FlightRecorder>,
                         scope: &str) -> Self {
        self.recorder = Some((recorder, scope.to_string()));
        self
    }

    /// The currently running design.
    pub fn current(&self) -> &Design {
        &self.current
    }

    /// The active adaptation policy.
    pub fn policy(&self) -> &Policy {
        &self.policy
    }

    /// LUT latency of a design adjusted for current conditions:
    /// `lut · 2^load / thermal_scale` on the design's engine.
    pub fn adjusted_latency(&self, design: &Design, conds: &Conditions)
                            -> Option<f64> {
        adjusted_latency(&self.lut, design, self.objective.stat(), conds)
    }

    /// Best design under adjusted conditions.  The observed conditions are
    /// quantised to a [`ConditionsBucket`]; the bucket's cached Pareto
    /// frontier (built on first use) is walked in the canonical selection
    /// order — the same search the offline optimiser runs over
    /// condition-scaled latencies, at O(frontier) instead of O(space) per
    /// event.  For a hard latency target the walk re-checks the budget at
    /// the *exact* observed conditions (the bucket's representative can
    /// sit up to half a quantisation step away), so a returned design
    /// never violates the target the way a quantised-only check could;
    /// the residual quantisation error is conservative (a design just
    /// inside budget at the exact conditions but outside at the bucket
    /// centre may be missed).
    pub fn best_under(&self, conds: &Conditions) -> Result<Design> {
        self.best_under_explained(conds).map(|(d, _, _)| d)
    }

    /// [`best_under`](Self::best_under) plus the explain payload: the
    /// bucket id of the frontier slice walked and the frontier's length
    /// (alternatives considered).  One code path serves both so tracing
    /// can never diverge from the selection it describes.
    fn best_under_explained(&self, conds: &Conditions)
                            -> Result<(Design, String, usize)> {
        let bucket = ConditionsBucket::of(conds);
        let space = DesignSpace::new(&self.device, &self.registry, &self.lut);
        let frontier = self.frontiers.lock().unwrap().frontier(
            &space, self.objective, &self.space, &bucket);
        crate::designspace::select_from_frontier(&frontier, &self.lut,
                                                 self.objective, conds)
            .map(|c| (c.design.clone(), bucket.id(), frontier.len()))
            .ok_or_else(|| anyhow::anyhow!("no feasible design under conditions"))
    }

    /// Emit a hold event (when a recorder is attached) and return the
    /// hold decision.  `trigger` is what fired before the manager held
    /// (`load`, `degradation`) or `none` for pre-trigger holds.
    fn hold(&self, trigger: &str, reason: HoldReason) -> Decision {
        if let Some((rec, scope)) = &self.recorder {
            rec.emit(TraceEvent::Hold {
                scope: scope.clone(),
                trigger: trigger.to_string(),
                reason: hold_label(&reason).to_string(),
            });
        }
        Decision::Hold(reason)
    }

    /// Frontier-cache effectiveness counters (adaptation-cost telemetry
    /// reported by `oodin opt-bench`).
    pub fn frontier_stats(&self) -> CacheStats {
        self.frontiers.lock().unwrap().stats
    }

    /// Swap in a corrected LUT, carrying the (possibly cohort-shared)
    /// frontier cache across the transition incrementally instead of
    /// cold-starting it ([`FrontierCache::apply_delta`]).  `delta` must
    /// describe every difference between the current and the new LUT.
    /// Idempotent on a shared cache: the first manager of a cohort pays
    /// the delta update, the rest see every entry already at the new
    /// fingerprint.
    pub fn apply_lut_delta(&mut self, new_lut: Arc<Lut>, delta: &LutDelta)
                           -> DeltaOutcome {
        let outcome = {
            let old_ds =
                DesignSpace::new(&self.device, &self.registry, &self.lut);
            let new_ds =
                DesignSpace::new(&self.device, &self.registry, &new_lut);
            self.frontiers.lock().unwrap().apply_delta(&old_ds, &new_ds,
                                                       delta)
        };
        self.lut = new_lut;
        outcome
    }

    /// Record one measured inference latency (ms) on the current design.
    pub fn record_latency(&mut self, ms: f64) {
        self.window.push(ms);
    }

    /// Periodic observation tick.  Returns a reconfiguration if one was
    /// decided at this tick.
    pub fn observe(&mut self, now_ms: f64, conds: &Conditions) -> Option<Switch> {
        match self.decide(now_ms, conds) {
            Decision::Switch(sw) => Some(sw),
            Decision::Hold(_) => None,
        }
    }

    /// Periodic observation tick with the declination reason made explicit:
    /// either a reconfiguration, or *why* the manager held position (e.g.
    /// `Cooldown`) — the signal joint re-adaptation consumes.
    pub fn decide(&mut self, now_ms: f64, conds: &Conditions) -> Decision {
        if now_ms - self.last_check_ms < self.policy.check_interval_ms {
            return self.hold("none", HoldReason::NotDue);
        }
        self.last_check_ms = now_ms;
        if now_ms - self.last_switch_ms < self.policy.cooldown_ms {
            return self.hold("none", HoldReason::Cooldown {
                remaining_ms: self.policy.cooldown_ms - (now_ms - self.last_switch_ms),
            });
        }

        // Trigger 1: significant load change on any engine.
        let load_changed = EngineKind::ALL.iter().any(|&k| {
            let prev = self.last_loads.get(&k).copied().unwrap_or(0.0);
            (conds.load(k) - prev).abs() >= self.policy.load_delta
        });

        // Trigger 2: sustained measured degradation vs LUT expectation
        // (covers throttling even when temperature telemetry is missing).
        let expected = self
            .lut
            .get(&self.current.lut_key())
            .map(|e| e.latency.avg)
            .unwrap_or(f64::INFINITY)
            * perf::contention(conds.load(self.current.hw.engine));
        let degraded_now = self
            .window
            .mean()
            .map_or(false, |m| m > expected * self.policy.violation_ratio)
            || conds.thermal_scale(self.current.hw.engine)
                < self.policy.thermal_alert_scale;
        if degraded_now {
            if self.degradation_start_ms.is_none() {
                self.degradation_start_ms = Some(now_ms);
            }
            self.violations += 1;
        } else {
            self.violations = 0;
            self.degradation_start_ms = None;
        }
        let degradation_confirmed = self.violations >= self.policy.confirmations;

        if !load_changed && !degradation_confirmed {
            return self.hold("none", HoldReason::NoTrigger);
        }
        let trigger = if degradation_confirmed { "degradation" } else { "load" };
        if load_changed {
            for k in EngineKind::ALL {
                self.last_loads.insert(k, conds.load(k));
            }
        }

        // When degradation was confirmed from measurements alone, infer the
        // current engine's effective slowdown so the re-search sees it even
        // without thermal telemetry (the paper's middleware-c warnings may
        // lag the latency signal).
        let mut eff = conds.clone();
        if degradation_confirmed {
            if let Some(mean) = self.window.mean() {
                let lut_avg = self
                    .lut
                    .get(&self.current.lut_key())
                    .map(|e| e.latency.avg)
                    .unwrap_or(mean);
                let inferred = (lut_avg / mean).clamp(1e-3, 1.0);
                let k = self.current.hw.engine;
                let cur = eff.thermal.get(&k).copied().unwrap_or(1.0);
                eff.thermal.insert(k, cur.min(inferred));
            }
        }
        let conds = &eff;
        let Ok((best, bucket_id, frontier_len)) =
            self.best_under_explained(conds)
        else {
            return self.hold(trigger, HoldReason::NoAlternative);
        };
        if best == self.current {
            return self.hold(trigger, HoldReason::CurrentStillBest);
        }
        let (Some(cur_adj), Some(best_adj)) = (
            self.adjusted_latency(&self.current, conds),
            self.adjusted_latency(&best, conds),
        ) else {
            return self.hold(trigger, HoldReason::NoAlternative);
        };
        if cur_adj / best_adj < self.policy.min_improvement {
            return self.hold(trigger, HoldReason::BelowHysteresis {
                predicted_gain: cur_adj / best_adj,
            });
        }

        let reason = if degradation_confirmed {
            Reason::Degradation
        } else {
            Reason::LoadChange
        };
        let detection_ms = self
            .degradation_start_ms
            .map(|t0| now_ms - t0)
            .unwrap_or(0.0);
        let sw = Switch {
            from: self.current.clone(),
            to: best.clone(),
            at_ms: now_ms,
            detection_ms,
            reason,
        };
        if let Some((rec, scope)) = &self.recorder {
            rec.emit(TraceEvent::Switch {
                scope: scope.clone(),
                from: design_id(&sw.from),
                to: design_id(&sw.to),
                reason: trigger.to_string(),
                detection_ms: sw.detection_ms,
            });
            rec.emit(TraceEvent::Explain {
                scope: scope.clone(),
                bucket: bucket_id,
                chosen: design_id(&sw.to),
                score: round3(best_adj),
                frontier: frontier_len as u64,
                alternatives: frontier_len.saturating_sub(1) as u64,
            });
        }
        self.current = best;
        self.last_switch_ms = now_ms;
        self.violations = 0;
        self.degradation_start_ms = None;
        self.window.clear();
        self.switches.push(sw.clone());
        Decision::Switch(sw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::measurements::Measurer;
    use crate::model::test_fixtures::fake_registry;
    use crate::optimizer::{Objective, Optimizer};
    use crate::util::stats::Percentile;

    fn mk_manager(dev: &DeviceProfile, reg: &Registry, lut: &Lut)
                  -> RuntimeManager {
        let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 };
        let space = SearchSpace::family("mobilenet_v2_100");
        let opt = Optimizer::new(dev, reg, lut);
        let init = opt.optimize(obj, &space).unwrap().design;
        RuntimeManager::new(Arc::new(dev.clone()), Arc::new(reg.clone()),
                            Arc::new(lut.clone()), obj, space, init)
    }

    use crate::model::Registry;
    use crate::device::DeviceProfile;

    #[test]
    fn no_switch_when_idle() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut mgr = mk_manager(&dev, &reg, &lut);
        let conds = Conditions::idle();
        for t in 0..40 {
            assert!(mgr.observe(t as f64 * 250.0, &conds).is_none());
        }
    }

    #[test]
    fn load_on_current_engine_triggers_switch() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut mgr = mk_manager(&dev, &reg, &lut);
        let initial_engine = mgr.current().hw.engine;

        let mut conds = Conditions::idle();
        conds.loads.insert(initial_engine, 3.0); // 8x slower
        let mut switched = None;
        for t in 0..20 {
            if let Some(sw) = mgr.observe(2000.0 + t as f64 * 250.0, &conds) {
                switched = Some(sw);
                break;
            }
        }
        let sw = switched.expect("manager should migrate off the loaded engine");
        assert_eq!(sw.reason, Reason::LoadChange);
        assert_ne!(sw.to.hw.engine, initial_engine);
    }

    #[test]
    fn small_load_change_is_ignored() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut mgr = mk_manager(&dev, &reg, &lut);
        let e = mgr.current().hw.engine;
        let mut conds = Conditions::idle();
        conds.loads.insert(e, 0.05); // below the 0.1 threshold
        for t in 0..20 {
            assert!(mgr.observe(2000.0 + t as f64 * 250.0, &conds).is_none());
        }
    }

    #[test]
    fn thermal_throttle_triggers_degradation_switch_with_detection_time() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut mgr = mk_manager(&dev, &reg, &lut);
        let e = mgr.current().hw.engine;

        // Cool for a while...
        let idle = Conditions::idle();
        for t in 0..8 {
            assert!(mgr.observe(t as f64 * 250.0, &idle).is_none());
        }
        // ...then the engine throttles hard.
        let mut hot = Conditions::idle();
        hot.thermal.insert(e, 0.4);
        let t_onset = 8.0 * 250.0;
        let mut sw = None;
        for i in 0..30 {
            if let Some(s) = mgr.observe(t_onset + i as f64 * 250.0, &hot) {
                sw = Some(s);
                break;
            }
        }
        let sw = sw.expect("throttling must trigger a migration");
        assert_eq!(sw.reason, Reason::Degradation);
        assert_ne!(sw.to.hw.engine, e);
        // detection = confirmations x check interval (approx. the paper's
        // sub-second detection)
        assert!(sw.detection_ms >= 250.0 && sw.detection_ms <= 1500.0,
                "detection {}", sw.detection_ms);
    }

    #[test]
    fn cooldown_prevents_flapping() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut mgr = mk_manager(&dev, &reg, &lut);
        let e0 = mgr.current().hw.engine;
        let mut conds = Conditions::idle();
        conds.loads.insert(e0, 3.0);
        let mut t = 1000.0;
        let mut first = None;
        for _ in 0..30 {
            if let Some(s) = mgr.observe(t, &conds) {
                first = Some((s, t));
                break;
            }
            t += 250.0;
        }
        let (first, t_sw) = first.unwrap();
        // Immediately load the new engine too: within the cooldown the
        // manager must hold position — and say that cooldown is why.
        conds.loads.insert(first.to.hw.engine, 3.0);
        match mgr.decide(t_sw + 300.0, &conds) {
            Decision::Hold(HoldReason::Cooldown { remaining_ms }) => {
                assert!(remaining_ms > 0.0 && remaining_ms < 1000.0,
                        "remaining {remaining_ms}");
            }
            other => panic!("expected a cooldown hold, got {other:?}"),
        }
    }

    #[test]
    fn idle_hold_reports_no_trigger() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut mgr = mk_manager(&dev, &reg, &lut);
        let conds = Conditions::idle();
        assert!(matches!(mgr.decide(0.0, &conds),
                         Decision::Hold(HoldReason::NoTrigger)));
        assert!(matches!(mgr.decide(10.0, &conds),
                         Decision::Hold(HoldReason::NotDue)));
    }

    #[test]
    fn degradation_via_measured_latency_only() {
        // No thermal telemetry: repeated slow measurements alone must
        // eventually trigger a Degradation switch.
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut mgr = mk_manager(&dev, &reg, &lut);
        let expected = mgr
            .adjusted_latency(&mgr.current().clone(), &Conditions::idle())
            .unwrap();
        let conds = Conditions::idle();
        let mut sw = None;
        for i in 0..30 {
            for _ in 0..4 {
                mgr.record_latency(expected * 3.0);
            }
            if let Some(s) = mgr.observe(1000.0 + i as f64 * 250.0, &conds) {
                sw = Some(s);
                break;
            }
        }
        let sw = sw.expect("measured degradation must trigger migration");
        assert_eq!(sw.reason, Reason::Degradation);
    }

    #[test]
    fn best_under_idle_equals_offline_choice() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mgr = mk_manager(&dev, &reg, &lut);
        let best = mgr.best_under(&Conditions::idle()).unwrap();
        assert_eq!(&best, mgr.current());
    }

    #[test]
    fn repeated_best_under_hits_the_frontier_cache() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap();
        let mgr = mk_manager(&dev, &reg, &lut);
        let idle = Conditions::idle();
        let a = mgr.best_under(&idle).unwrap();
        let b = mgr.best_under(&idle).unwrap();
        assert_eq!(a, b);
        let stats = mgr.frontier_stats();
        assert_eq!(stats.builds, 1, "second call must not re-enumerate");
        assert_eq!(stats.hits, 1);
        // A different conditions bucket builds its own frontier once.
        let mut loaded = Conditions::idle();
        loaded.loads.insert(a.hw.engine, 2.0);
        mgr.best_under(&loaded).unwrap();
        mgr.best_under(&loaded).unwrap();
        let stats = mgr.frontier_stats();
        assert_eq!(stats.builds, 2);
        assert_eq!(stats.hits, 2);
    }
}
