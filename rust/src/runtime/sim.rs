//! The deterministic simulation backend.
//!
//! `SimBackend` implements [`Backend`] with no external artifacts: outputs
//! are synthesised from the same class-conditional scene model the
//! synthetic camera emits (`sil::camera`), and latencies come from the
//! existing device substrate — the `perf` roofline model conditioned by the
//! `devicesim` contention/thermal state under the configured `dvfs`
//! governor.  That makes the full OODIn stack (DLACL, serving, Runtime
//! Manager, experiment drivers) runnable and testable on a machine with no
//! Python, no XLA and no `artifacts/` directory, while preserving the
//! statistical behaviour the upper layers care about:
//!
//! * **Accuracy-faithful classification.**  A matched filter decodes the
//!   scene class from the staged input exactly the way the trained models
//!   do on the real path; a deterministic per-frame hash then corrupts the
//!   prediction at rate `1 - accuracy`, so online top-1 through the full
//!   stack tracks the manifest accuracy of whichever variant is resident.
//!   The corruption hash depends only on the frame content — the three
//!   precision transformations of one family agree on a frame unless it
//!   falls inside their (narrow) accuracy gap, matching the real zoo.
//! * **Condition-faithful latency.**  Each execution runs through
//!   `DeviceSim::run_inference`, so injected engine load, DVFS governor
//!   scaling and accumulated thermal throttling all shape `host_ms`.

use std::collections::BTreeSet;
use std::path::Path;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::{Backend, ExecHint, ExecOutput};
use crate::device::{DeviceProfile, EngineKind};
use crate::devicesim::DeviceSim;
use crate::dvfs::Governor;
use crate::model::{ModelVariant, Registry, Task};
use crate::sil::camera::{class_template, BLOB_AMP, BLOB_SECONDARY, NUM_CLASSES};
use crate::util::clock::Clock;

/// The system configuration simulated executions run under.  OODIn's upper
/// layers decide the *design* σ; the backend only needs to know which
/// engine/threads/governor to charge the work to.
#[derive(Debug, Clone, Copy)]
pub struct SimExecConfig {
    /// Engine the simulated work is charged to.
    pub engine: EngineKind,
    /// CPU threads (ignored by offload engines).
    pub threads: usize,
    /// DVFS governor in effect.
    pub governor: Governor,
}

struct SimState {
    loaded: BTreeSet<String>,
    sim: DeviceSim,
    exec: SimExecConfig,
    /// When set, un-hinted executions run as a pipelined multi-engine
    /// partition (per-segment engines, interior cut points in per-mille)
    /// instead of monolithically on `exec.engine`.
    plan: Option<(Vec<EngineKind>, Vec<u32>)>,
    /// Optional real sleep per execution (test knob: makes queueing effects
    /// such as serving backpressure deterministic on a fast machine).
    wall_delay_ms: f64,
    executions: u64,
}

/// Hermetic, deterministic [`Backend`] over the simulated device substrate.
pub struct SimBackend {
    registry: Registry,
    state: Mutex<SimState>,
}

impl SimBackend {
    /// Simulate executions on `profile`'s CPU engine (all cores,
    /// performance governor) by default; see [`SimBackend::with_execution`].
    pub fn new(profile: DeviceProfile, registry: Registry) -> Self {
        let exec = SimExecConfig {
            engine: EngineKind::Cpu,
            threads: profile.n_cores,
            governor: Governor::Performance,
        };
        SimBackend {
            registry,
            state: Mutex::new(SimState {
                loaded: BTreeSet::new(),
                sim: DeviceSim::new(profile, Clock::sim()),
                exec,
                plan: None,
                wall_delay_ms: 0.0,
                executions: 0,
            }),
        }
    }

    /// Charge executions to a specific engine/threads/governor.
    pub fn with_execution(self, engine: EngineKind, threads: usize,
                          governor: Governor) -> Self {
        self.state.lock().unwrap().exec = SimExecConfig { engine, threads, governor };
        self
    }

    /// Run un-hinted executions as a pipelined multi-engine partition
    /// (the intra-model co-execution path): per-segment `engines` with
    /// interior cut points `cuts_pm` in per-mille, under the configured
    /// governor.  Per-engine hints still override per call.
    pub fn with_execution_plan(self, engines: Vec<EngineKind>,
                               cuts_pm: Vec<u32>) -> Self {
        self.state.lock().unwrap().plan = Some((engines, cuts_pm));
        self
    }

    /// Sleep this long (wall clock) per execution — test-only pacing knob.
    pub fn with_wall_delay_ms(self, ms: f64) -> Self {
        self.state.lock().unwrap().wall_delay_ms = ms.max(0.0);
        self
    }

    /// Override the log-normal latency-jitter sigma (default 0.03).  The
    /// serve-bench harness sets 0.0 so its latency curves are byte-stable
    /// against a pinned golden snapshot.
    pub fn with_noise_sigma(self, sigma: f64) -> Self {
        self.state.lock().unwrap().sim.set_noise_sigma(sigma);
        self
    }

    /// Inject external engine load (the Fig 7 contention model); affects
    /// every subsequent execution's simulated latency.
    pub fn set_load(&self, engine: EngineKind, load: f64) {
        self.state.lock().unwrap().sim.set_load(engine, load);
    }

    /// Total executions served (telemetry/tests).
    pub fn executions(&self) -> u64 {
        self.state.lock().unwrap().executions
    }
}

impl Backend for SimBackend {
    fn kind(&self) -> &'static str {
        "sim"
    }

    /// "Compile" a variant: the artifact file is not required — the
    /// registry entry carries everything the simulator needs.
    fn load(&self, name: &str, _path: &Path) -> Result<()> {
        if self.registry.get(name).is_none() {
            bail!("variant `{name}` not in registry — SimBackend can only \
                   load manifest-declared models");
        }
        self.state.lock().unwrap().loaded.insert(name.to_string());
        Ok(())
    }

    fn execute(&self, name: &str, input: Vec<f32>, shape: &[usize])
               -> Result<ExecOutput> {
        self.execute_hinted(name, input, shape, None)
    }

    /// Charge the execution to the hinted engine/threads/governor instead
    /// of the backend-wide [`SimBackend::with_execution`] configuration —
    /// this is what lets the serving pipeline run per-engine worker lanes
    /// over one shared simulated device.
    fn execute_hinted(&self, name: &str, input: Vec<f32>, shape: &[usize],
                      hint: Option<&ExecHint>) -> Result<ExecOutput> {
        let n: usize = shape.iter().product();
        if n != input.len() {
            bail!("input length {} != shape product {n}", input.len());
        }
        let (v, latency_ms, wall_delay_ms) = {
            let mut st = self.state.lock().unwrap();
            if !st.loaded.contains(name) {
                bail!("executable `{name}` not loaded");
            }
            let v = self
                .registry
                .get(name)
                .ok_or_else(|| anyhow!("variant `{name}` not in registry"))?
                .clone();
            if n != v.input_elems() {
                bail!("input length {n} != `{name}` input elems {}", v.input_elems());
            }
            let exec = match hint {
                Some(h) => SimExecConfig {
                    engine: h.engine,
                    threads: h.threads,
                    governor: h.governor,
                },
                None => st.exec,
            };
            let r = match (&hint, st.plan.clone()) {
                (None, Some((engines, cuts))) => st.sim
                    .run_pipelined(&v, &engines, &cuts, exec.governor)?,
                _ => st.sim
                    .run_inference(&v, exec.engine, exec.threads,
                                   exec.governor)?,
            };
            st.executions += 1;
            (v, r.latency_ms, st.wall_delay_ms)
        };
        if wall_delay_ms > 0.0 {
            std::thread::sleep(Duration::from_micros((wall_delay_ms * 1e3) as u64));
        }
        Ok(ExecOutput { values: synthesize_output(&v, &input), host_ms: latency_ms })
    }

    fn evict(&self, name: &str) -> Result<bool> {
        Ok(self.state.lock().unwrap().loaded.remove(name))
    }

    fn loaded(&self) -> Result<Vec<String>> {
        Ok(self.state.lock().unwrap().loaded.iter().cloned().collect())
    }
}

/// Synthesise the output tensor for one execution.
fn synthesize_output(v: &ModelVariant, input: &[f32]) -> Vec<f32> {
    let out_elems = v.output_elems();
    let batch = v.batch.max(1);
    let mut out = vec![0.0f32; out_elems];
    if out_elems == 0 || v.input_elems() == 0 {
        return out;
    }
    let out_stride = out_elems / batch;
    let in_stride = v.input_elems() / batch;
    for b in 0..batch {
        let sample = &input[b * in_stride..(b + 1) * in_stride];
        let o = &mut out[b * out_stride..(b + 1) * out_stride];
        match v.task {
            Task::Classification => {
                let cls = predicted_class(sample, v.resolution, v.accuracy);
                for (c, slot) in o.iter_mut().enumerate() {
                    *slot = if c == cls {
                        2.5
                    } else {
                        -1.0 + 0.01 * (c % NUM_CLASSES) as f32
                    };
                }
            }
            Task::Segmentation => {
                // Per-pixel logits keyed to local luminance: finite,
                // deterministic, input-dependent.
                if sample.len() < 3 {
                    continue;
                }
                let classes = v.output_shape.last().copied().unwrap_or(1).max(1);
                let pixels = out_stride / classes;
                for p in 0..pixels {
                    let i = (p * 3).min(sample.len().saturating_sub(3));
                    let lum = sample[i] + sample[i + 1] + sample[i + 2];
                    for c in 0..classes {
                        o[p * classes + c] =
                            lum * 0.1 - c as f32 * 0.05 + if c == 0 { 0.0 } else { 0.02 };
                    }
                }
            }
        }
    }
    out
}

/// The class the simulated model reports for one staged sample: the decoded
/// scene class, corrupted at rate `1 - accuracy` by a deterministic
/// frame-content hash (so reruns and sibling precisions behave
/// consistently).
pub fn predicted_class(sample: &[f32], res: usize, accuracy: f64) -> usize {
    let truth = decode_scene(sample, res);
    if unit_hash(sample, 0x5EED) < accuracy {
        truth
    } else {
        // A deterministic wrong class, shared by every variant shown the
        // same frame (all-wrong variants still agree, as real siblings do).
        let off = 1 + (unit_hash(sample, 0x0BAD) * (NUM_CLASSES - 1) as f64) as usize;
        (truth + off.min(NUM_CLASSES - 1)) % NUM_CLASSES
    }
}

/// Matched-filter decode of the synthetic scene (see `sil::camera`): score
/// each class template (ring position + dominant-channel pattern) against
/// the frame and return the argmax.  Empirically >= 93% accurate on noisy
/// camera frames at res >= 16, ~100% on clean class frames.
pub fn decode_scene(sample: &[f32], res: usize) -> usize {
    if res == 0 || sample.len() < res * res * 3 {
        return 0;
    }
    let mut best = 0usize;
    let mut best_score = f64::NEG_INFINITY;
    for class in 0..NUM_CLASSES {
        let (cy, cx, sigma) = class_template(res, class);
        let dom = class % 3;
        let mut score = 0.0f64;
        for y in 0..res {
            for x in 0..res {
                let d2 = (y as f64 - cy).powi(2) + (x as f64 - cx).powi(2);
                let g = (-d2 / (2.0 * sigma * sigma)).exp();
                if g < 1e-4 {
                    continue;
                }
                let i = (y * res + x) * 3;
                score += g
                    * (BLOB_AMP as f64 * sample[i + dom] as f64
                        + BLOB_SECONDARY as f64 * sample[i + (dom + 1) % 3] as f64
                        - sample[i + (dom + 2) % 3] as f64);
            }
        }
        if score > best_score {
            best_score = score;
            best = class;
        }
    }
    best
}

/// Deterministic hash of the (quantised) frame content to a uniform value
/// in [0, 1).
fn unit_hash(sample: &[f32], salt: u64) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325 ^ salt;
    for &x in sample {
        let q = (x * 256.0).round() as i64 as u64;
        h ^= q;
        h = h.wrapping_mul(0x100000001b3);
    }
    // SplitMix finalizer for output uniformity.
    let mut z = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::dlacl::decode_top1;
    use crate::model::test_fixtures::fake_registry;
    use crate::sil::camera::class_frame;
    use crate::sil::SyntheticCamera;

    fn backend() -> SimBackend {
        SimBackend::new(samsung_a71(), fake_registry())
    }

    #[test]
    fn load_execute_evict_roundtrip_without_artifacts() {
        let be = backend();
        let name = "mobilenet_v2_100__fp32__b1";
        let path = Path::new("/nonexistent/does-not-matter.hlo.txt");
        be.load(name, path).unwrap();
        be.load(name, path).unwrap(); // idempotent
        assert_eq!(be.loaded().unwrap(), vec![name.to_string()]);

        let v = fake_registry().get(name).unwrap().clone();
        let out = be
            .execute(name, vec![0.1; v.input_elems()], &v.input_shape)
            .unwrap();
        assert_eq!(out.values.len(), v.output_elems());
        assert!(out.values.iter().all(|x| x.is_finite()));
        assert!(out.host_ms > 0.0);

        assert!(be.evict(name).unwrap());
        assert!(!be.evict(name).unwrap());
        assert!(be.execute(name, vec![0.0; v.input_elems()], &v.input_shape).is_err());
        assert_eq!(be.executions(), 1);
    }

    #[test]
    fn unknown_variant_rejected() {
        let be = backend();
        assert!(be.load("ghost__fp32__b1", Path::new("/x")).is_err());
        assert!(be.execute("ghost__fp32__b1", vec![1.0], &[1]).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let be = backend();
        let name = "mobilenet_v2_100__fp32__b1";
        be.load(name, Path::new("/x")).unwrap();
        // shape product != input length
        assert!(be.execute(name, vec![1.0, 2.0], &[4]).is_err());
        // consistent shape, but not the variant's input size
        assert!(be.execute(name, vec![1.0; 4], &[4]).is_err());
    }

    #[test]
    fn clean_class_frames_decode_exactly() {
        for res in [16usize, 24, 32, 48] {
            for c in 0..NUM_CLASSES {
                assert_eq!(decode_scene(&class_frame(res, c), res), c,
                           "res {res} class {c}");
            }
        }
    }

    #[test]
    fn noisy_camera_frames_decode_accurately() {
        let mut cam = SyntheticCamera::new(24, 30.0, 41);
        let n = 100;
        let mut ok = 0;
        for i in 0..n {
            let f = cam.capture(i as f64);
            if decode_scene(&f.data, 24) == f.label {
                ok += 1;
            }
        }
        assert!(ok * 100 >= n * 85, "decoder accuracy {ok}/{n}");
    }

    #[test]
    fn prediction_accuracy_tracks_manifest() {
        // accuracy=1.0 never corrupts; accuracy=0.0 always corrupts.
        let frame = class_frame(24, 4);
        assert_eq!(predicted_class(&frame, 24, 1.0), 4);
        assert_ne!(predicted_class(&frame, 24, 0.0), 4);
        // Corruption is deterministic per frame.
        assert_eq!(predicted_class(&frame, 24, 0.0),
                   predicted_class(&frame, 24, 0.0));
    }

    #[test]
    fn classification_output_decodes_with_top1() {
        let be = backend();
        let reg = fake_registry();
        let v = reg.get("inception_v3__fp32__b1").unwrap();
        be.load(&v.name, Path::new("/x")).unwrap();
        let frame = class_frame(v.resolution, 7);
        let out = be.execute(&v.name, frame, &v.input_shape).unwrap();
        let (cls, conf) = decode_top1(&out.values, NUM_CLASSES);
        assert_eq!(cls, 7);
        assert!(conf > 0.0);
    }

    #[test]
    fn segmentation_output_has_full_map() {
        let be = backend();
        let reg = fake_registry();
        let v = reg.get("deeplab_v3__int8__b1").unwrap();
        be.load(&v.name, Path::new("/x")).unwrap();
        let out = be
            .execute(&v.name, vec![0.3; v.input_elems()], &v.input_shape)
            .unwrap();
        assert_eq!(out.values.len(), v.resolution * v.resolution * 5);
        assert!(out.values.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn injected_load_scales_latency() {
        let be = backend();
        let reg = fake_registry();
        let v = reg.get("mobilenet_v2_100__fp32__b1").unwrap();
        be.load(&v.name, Path::new("/x")).unwrap();
        let input = vec![0.1f32; v.input_elems()];
        let base = be.execute(&v.name, input.clone(), &v.input_shape).unwrap();
        be.set_load(EngineKind::Cpu, 2.0);
        let loaded = be.execute(&v.name, input, &v.input_shape).unwrap();
        let ratio = loaded.host_ms / base.host_ms;
        assert!((3.0..5.5).contains(&ratio), "2^2 contention, got {ratio}x");
    }

    #[test]
    fn execution_config_governor_slows_latency() {
        let reg = fake_registry();
        let v = reg.get("inception_v3__fp32__b1").unwrap().clone();
        let input = vec![0.1f32; v.input_elems()];
        let perf = SimBackend::new(samsung_a71(), reg.clone());
        perf.load(&v.name, Path::new("/x")).unwrap();
        let eco = SimBackend::new(samsung_a71(), reg)
            .with_execution(EngineKind::Cpu, 8, Governor::EnergyStep);
        eco.load(&v.name, Path::new("/x")).unwrap();
        let fast = perf.execute(&v.name, input.clone(), &v.input_shape).unwrap();
        let slow = eco.execute(&v.name, input, &v.input_shape).unwrap();
        assert!(slow.host_ms > fast.host_ms * 1.15,
                "energy_step {} vs performance {}", slow.host_ms, fast.host_ms);
    }

    #[test]
    fn zero_noise_sigma_yields_constant_latency_while_cool() {
        let reg = fake_registry();
        let be = SimBackend::new(samsung_a71(), reg.clone()).with_noise_sigma(0.0);
        let v = reg.get("mobilenet_v2_100__fp32__b1").unwrap().clone();
        be.load(&v.name, Path::new("/x")).unwrap();
        let input = vec![0.1f32; v.input_elems()];
        let a = be.execute(&v.name, input.clone(), &v.input_shape).unwrap();
        let b = be.execute(&v.name, input, &v.input_shape).unwrap();
        assert_eq!(a.host_ms, b.host_ms,
                   "noise-free latency must be bitwise constant while cool");
    }

    #[test]
    fn hinted_execution_charges_requested_engine() {
        use crate::runtime::ExecHint;
        let reg = fake_registry();
        let be = SimBackend::new(samsung_a71(), reg.clone()).with_noise_sigma(0.0);
        let v = reg.get("mobilenet_v2_100__fp32__b1").unwrap().clone();
        be.load(&v.name, Path::new("/x")).unwrap();
        let input = vec![0.1f32; v.input_elems()];
        let cpu = be
            .execute_hinted(&v.name, input.clone(), &v.input_shape, None)
            .unwrap();
        let hint = ExecHint {
            engine: EngineKind::Gpu,
            threads: 1,
            governor: Governor::Performance,
        };
        let gpu = be
            .execute_hinted(&v.name, input, &v.input_shape, Some(&hint))
            .unwrap();
        assert_ne!(cpu.host_ms, gpu.host_ms,
                   "hinted engine must change the charged latency");
    }

    #[test]
    fn execution_plan_routes_through_pipelined_path() {
        let reg = fake_registry();
        let v = reg.get("deeplab_v3__int8__b1").unwrap().clone();
        let mono = SimBackend::new(samsung_a71(), reg.clone())
            .with_noise_sigma(0.0)
            .with_execution(EngineKind::Gpu, 1, Governor::Performance);
        mono.load(&v.name, Path::new("/x")).unwrap();
        let split = SimBackend::new(samsung_a71(), reg)
            .with_noise_sigma(0.0)
            .with_execution(EngineKind::Gpu, 8, Governor::Performance)
            .with_execution_plan(vec![EngineKind::Gpu, EngineKind::Cpu],
                                 vec![500]);
        split.load(&v.name, Path::new("/x")).unwrap();
        let input = vec![0.1f32; v.input_elems()];
        let m = mono.execute(&v.name, input.clone(), &v.input_shape).unwrap();
        let s = split.execute(&v.name, input, &v.input_shape).unwrap();
        // Splitting this bandwidth-heavy model halves each stage's
        // memory traffic: the pipelined run must beat the monolithic GPU
        // run at idle.
        assert!(s.host_ms < m.host_ms,
                "split {} vs mono {}", s.host_ms, m.host_ms);
    }

    #[test]
    fn backend_is_shareable_across_threads() {
        use std::sync::Arc;
        let be: Arc<dyn Backend> = Arc::new(backend());
        let reg = fake_registry();
        let v = reg.get("mobilenet_v2_100__fp32__b1").unwrap().clone();
        be.load(&v.name, Path::new("/x")).unwrap();
        let handles: Vec<_> = (1..=4)
            .map(|label| {
                let be = Arc::clone(&be);
                let v = v.clone();
                std::thread::spawn(move || {
                    let frame = class_frame(v.resolution, label);
                    let out = be.execute(&v.name, frame, &v.input_shape).unwrap();
                    decode_top1(&out.values, NUM_CLASSES).0
                })
            })
            .collect();
        let mut got: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3, 4]);
    }
}
