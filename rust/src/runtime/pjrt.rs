//! PJRT backend: loads AOT HLO-text artifacts and executes them on the CPU
//! PJRT client.  This is the only place Python-produced bits are touched at
//! run time — and they are data (HLO text), not code.
//!
//! The `xla` crate's handles are raw C pointers (neither `Send` nor `Sync`),
//! so the client, the compiled-executable cache and all executions live on
//! one dedicated **executor thread**; the rest of the system talks to it
//! through the cloneable [`RuntimeHandle`] (mpsc request/reply), which
//! implements [`Backend`].  This mirrors the production shape of an
//! inference server: one owning executor per accelerator context, many
//! coordinator threads.
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos jax >= 0.5 emits that
//! xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::{Backend, ExecOutput};

/// A request processed by the executor thread.
enum Msg {
    /// Compile `path` and cache under `name` (idempotent).
    Load { name: String, path: PathBuf, reply: mpsc::Sender<Result<()>> },
    /// Execute cached executable `name` on `input` (f32, given shape).
    Execute {
        name: String,
        input: Vec<f32>,
        shape: Vec<usize>,
        reply: mpsc::Sender<Result<ExecOutput>>,
    },
    /// Drop a cached executable (DLACL model eviction).
    Evict { name: String, reply: mpsc::Sender<bool> },
    /// Names currently cached.
    Loaded { reply: mpsc::Sender<Vec<String>> },
    Shutdown,
}

/// Cloneable, `Send` handle to the PJRT executor thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Msg>,
}

impl RuntimeHandle {
    /// Spawn the executor thread with a fresh CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_main(rx, ready_tx))
            .context("spawning pjrt-executor")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during init"))??;
        Ok(RuntimeHandle { tx })
    }

    fn send(&self, msg: Msg) -> Result<()> {
        self.tx.send(msg).map_err(|_| anyhow!("executor thread gone"))
    }

    /// Compile the HLO-text artifact at `path`, caching it as `name`.
    pub fn load(&self, name: &str, path: impl Into<PathBuf>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Load { name: name.to_string(), path: path.into(), reply })?;
        rx.recv().map_err(|_| anyhow!("executor thread gone"))?
    }

    /// Execute a cached executable. `shape` is the logical input shape; the
    /// flat `input` length must match its product.
    pub fn execute(&self, name: &str, input: Vec<f32>, shape: &[usize])
                   -> Result<ExecOutput> {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Execute {
            name: name.to_string(),
            input,
            shape: shape.to_vec(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("executor thread gone"))?
    }

    /// Remove a cached executable; returns whether it existed.
    pub fn evict(&self, name: &str) -> Result<bool> {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Evict { name: name.to_string(), reply })?;
        rx.recv().map_err(|_| anyhow!("executor thread gone"))
    }

    /// Names currently loaded, sorted.
    pub fn loaded(&self) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Loaded { reply })?;
        rx.recv().map_err(|_| anyhow!("executor thread gone"))
    }

    /// Stop the executor thread (idempotent).
    pub fn shutdown(&self) {
        let _ = self.send(Msg::Shutdown);
    }
}

impl Backend for RuntimeHandle {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn load(&self, name: &str, path: &Path) -> Result<()> {
        RuntimeHandle::load(self, name, path)
    }

    fn execute(&self, name: &str, input: Vec<f32>, shape: &[usize])
               -> Result<ExecOutput> {
        RuntimeHandle::execute(self, name, input, shape)
    }

    fn evict(&self, name: &str) -> Result<bool> {
        RuntimeHandle::evict(self, name)
    }

    fn loaded(&self) -> Result<Vec<String>> {
        RuntimeHandle::loaded(self)
    }

    fn shutdown(&self) {
        RuntimeHandle::shutdown(self)
    }
}

fn executor_main(rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu: {e}")));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Load { name, path, reply } => {
                let r = if cache.contains_key(&name) {
                    Ok(())
                } else {
                    compile(&client, &path).map(|exe| {
                        cache.insert(name, exe);
                    })
                };
                let _ = reply.send(r);
            }
            Msg::Execute { name, input, shape, reply } => {
                let r = match cache.get(&name) {
                    None => Err(anyhow!("executable `{name}` not loaded")),
                    Some(exe) => run(exe, &input, &shape),
                };
                let _ = reply.send(r);
            }
            Msg::Evict { name, reply } => {
                let _ = reply.send(cache.remove(&name).is_some());
            }
            Msg::Loaded { reply } => {
                let mut names: Vec<String> = cache.keys().cloned().collect();
                names.sort();
                let _ = reply.send(names);
            }
            Msg::Shutdown => break,
        }
    }
}

fn compile(client: &xla::PjRtClient, path: &PathBuf)
           -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
}

fn run(exe: &xla::PjRtLoadedExecutable, input: &[f32], shape: &[usize])
       -> Result<ExecOutput> {
    let n: usize = shape.iter().product();
    if n != input.len() {
        bail!("input length {} != shape product {n}", input.len());
    }
    // Build the input literal in one shot (vec1 + reshape would copy twice
    // — §Perf iteration 3).
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(input.as_ptr() as *const u8, input.len() * 4)
    };
    let lit = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("create literal: {e}"))?;
    let t0 = Instant::now();
    let bufs = exe.execute::<xla::Literal>(&[lit]).map_err(|e| anyhow!("execute: {e}"))?;
    let out = bufs[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e}"))?;
    let host_ms = t0.elapsed().as_secs_f64() * 1e3;
    // aot.py lowers with return_tuple=True: the root is a 1-tuple.
    let out = out.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e}"))?;
    let values = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
    Ok(ExecOutput { values, host_ms })
}

#[cfg(test)]
mod tests {
    use super::super::write_tiny_hlo;
    use super::*;

    #[test]
    fn load_execute_evict_roundtrip() {
        let rt = RuntimeHandle::cpu().unwrap();
        let path = write_tiny_hlo();
        rt.load("tiny", &path).unwrap();
        rt.load("tiny", &path).unwrap(); // idempotent
        assert_eq!(rt.loaded().unwrap(), vec!["tiny".to_string()]);

        let out = rt.execute("tiny", vec![0.0, 1.0, 2.0, 3.0], &[4]).unwrap();
        assert_eq!(out.values, vec![1.0, 3.0, 5.0, 7.0]);
        assert!(out.host_ms >= 0.0);

        assert!(rt.evict("tiny").unwrap());
        assert!(!rt.evict("tiny").unwrap());
        assert!(rt.execute("tiny", vec![0.0; 4], &[4]).is_err());
        rt.shutdown();
    }

    #[test]
    fn execute_unknown_fails() {
        let rt = RuntimeHandle::cpu().unwrap();
        assert!(rt.execute("nope", vec![1.0], &[1]).is_err());
        rt.shutdown();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let rt = RuntimeHandle::cpu().unwrap();
        let path = write_tiny_hlo();
        rt.load("tiny2", &path).unwrap();
        assert!(rt.execute("tiny2", vec![1.0, 2.0], &[4]).is_err());
        rt.shutdown();
    }

    #[test]
    fn missing_artifact_file_fails_cleanly() {
        let rt = RuntimeHandle::cpu().unwrap();
        let err = rt.load("ghost", "/nonexistent/ghost.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");
        rt.shutdown();
    }

    #[test]
    fn handle_is_cloneable_across_threads() {
        let rt = RuntimeHandle::cpu().unwrap();
        let path = write_tiny_hlo();
        rt.load("tiny3", &path).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let rt = rt.clone();
                std::thread::spawn(move || {
                    rt.execute("tiny3", vec![i as f32; 4], &[4]).unwrap().values[0]
                })
            })
            .collect();
        let mut got: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, vec![1.0, 3.0, 5.0, 7.0]);
        rt.shutdown();
    }
}
