//! Execution backends: the [`Backend`] trait and its two implementations.
//!
//! Everything above this layer — DLACL model slots, the batched serving
//! front-end, the Runtime Manager's measurement loop, the experiment
//! drivers — talks to the execution engine exclusively through the
//! [`Backend`] trait (load / execute / evict / loaded / shutdown).  That is
//! the property OODIn's multi-layer design claims (paper §III-C): the
//! execution engine is swappable beneath an unchanged upper stack.
//!
//! * [`sim::SimBackend`] — the default.  Deterministic and hermetic: it
//!   synthesises outputs from the synthetic scene model and latencies from
//!   the `perf` roofline model + `devicesim` contention/thermal state +
//!   `dvfs` governor state.  No Python, no XLA, no artifacts directory.
//! * [`pjrt::RuntimeHandle`] — behind the `pjrt` cargo feature: the real
//!   PJRT executor thread compiling and running the AOT HLO-text artifacts
//!   on the host CPU client.
//!
//! [`default_backend`] picks PJRT when the feature is on and the artifacts
//! exist, and falls back to the simulator otherwise — so the same binary,
//! tests and benches run end-to-end in both environments.

pub mod sim;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use sim::SimBackend;

#[cfg(feature = "pjrt")]
pub use pjrt::RuntimeHandle;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::device::{DeviceProfile, EngineKind};
use crate::dvfs::Governor;
use crate::model::Registry;

/// Result of one execution.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    /// Flattened f32 output tensor (batch-major).
    pub values: Vec<f32>,
    /// Host wall-clock of the execution for PJRT; the simulated device
    /// latency for SimBackend (compile/load time excluded in both).
    pub host_ms: f64,
}

/// Which system configuration an execution should be charged to — the
/// hardware half of a design σ's `hw = <CE, threads, governor>`.
///
/// The serving pipeline's per-engine worker lanes pass this through
/// [`Backend::execute_hinted`] so one shared backend can host lanes on
/// different engines.  Backends that have no notion of engines (the real
/// PJRT host executor) are free to ignore it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecHint {
    /// Engine the work is charged to.
    pub engine: EngineKind,
    /// CPU threads (ignored by offload engines).
    pub threads: usize,
    /// DVFS governor in effect.
    pub governor: Governor,
}

/// An execution engine hosting compiled models: the seam between OODIn's
/// model-aware layers and whatever actually runs the numbers.
///
/// Implementations must be thread-safe: the serving front-end and the
/// application loop share one backend across threads.
pub trait Backend: Send + Sync {
    /// Short identifier for logs/telemetry ("sim", "pjrt").
    fn kind(&self) -> &'static str;

    /// Make the executable `name` available, compiling/ingesting the
    /// artifact at `path` if needed (idempotent).
    fn load(&self, name: &str, path: &Path) -> Result<()>;

    /// Execute loaded executable `name` on `input` (f32, logical `shape`).
    fn execute(&self, name: &str, input: Vec<f32>, shape: &[usize])
               -> Result<ExecOutput>;

    /// [`Backend::execute`] with an optional engine/threads/governor hint:
    /// backends that model heterogeneous engines (the simulator) charge the
    /// execution to the hinted engine; others fall back to plain `execute`.
    fn execute_hinted(&self, name: &str, input: Vec<f32>, shape: &[usize],
                      hint: Option<&ExecHint>) -> Result<ExecOutput> {
        let _ = hint;
        self.execute(name, input, shape)
    }

    /// Drop a loaded executable (DLACL model eviction); returns whether it
    /// existed.
    fn evict(&self, name: &str) -> Result<bool>;

    /// Names currently loaded, sorted.
    fn loaded(&self) -> Result<Vec<String>>;

    /// Release engine resources.  Safe to call more than once.
    fn shutdown(&self) {}
}

/// Pick the execution backend for a device + registry: the PJRT executor
/// when the `pjrt` feature is enabled and the registry's artifacts are on
/// disk, the deterministic simulator otherwise.
pub fn default_backend(profile: &DeviceProfile, registry: &Registry)
                       -> Result<Arc<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    {
        let have_artifacts = registry
            .variants()
            .first()
            .map(|v| registry.hlo_path(v).exists())
            .unwrap_or(false);
        if have_artifacts {
            return Ok(Arc::new(pjrt::RuntimeHandle::cpu()?));
        }
    }
    Ok(Arc::new(sim::SimBackend::new(profile.clone(), registry.clone())))
}

/// HLO for f(x) = (2*x + 1,) over f32[4] — used by the PJRT tests and
/// benches so the executor machinery is exercisable without Python
/// artifacts.
pub const TINY_HLO: &str = r#"HloModule tiny, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  two = f32[] constant(2)
  twos = f32[4]{0} broadcast(two), dimensions={}
  one = f32[] constant(1)
  ones = f32[4]{0} broadcast(one), dimensions={}
  mul = f32[4]{0} multiply(x, twos)
  add = f32[4]{0} add(mul, ones)
  ROOT out = (f32[4]{0}) tuple(add)
}
"#;

/// Write the tiny test module to a temp file and return its path.
pub fn write_tiny_hlo() -> PathBuf {
    let dir = std::env::temp_dir().join("oodin_test_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.hlo.txt");
    std::fs::write(&path, TINY_HLO).unwrap();
    path
}
